//! Million-client federation mechanics, end to end: a 10⁵-client fleet
//! (scale with CLIENTS) in which only the clients holding data — a few
//! hundred, set by TRAIN — ever train, run with `[scale] lazy_state =
//! true` over an 8-shard edge aggregation tree. Per-client state is
//! materialized only while a client is in the dispatch cohort; between
//! participations its EF residual lives in a compact spill slab. So
//! resident memory tracks the *cohort*, not the fleet.
//!
//! The point to watch: `peak resident` stays at the active-cohort size
//! while `fleet` is orders of magnitude larger, and the trajectory is
//! bit-identical to an eager, unsharded run of the same seed (pinned by
//! tests/shard_test.rs — here we just print the accounting). Runs on
//! the pure-Rust native backend in a bare container.
//!
//!     cargo run --release --example scale_edge
//!
//! Scale knobs (env): CLIENTS (default 100000), ROUNDS (4), TRAIN
//! (2000), SHARDS (8), THREADS (0 = all cores).

use fed3sfc::bench::{env_usize, fmt_bytes_opt, peak_rss_bytes};
use fed3sfc::config::{CompressorKind, DatasetKind, SpillKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::open_backend;

fn main() -> anyhow::Result<()> {
    let clients = env_usize("CLIENTS", 100_000);
    let rounds = env_usize("ROUNDS", 4);
    let train = env_usize("TRAIN", 2000);
    let shards = env_usize("SHARDS", 8);
    let threads = env_usize("THREADS", 0);

    println!(
        "== lazy sharded federation ({clients} clients, {train} samples, {shards} shards, \
         {rounds} rounds, spill=slab) =="
    );
    let builder = Experiment::builder()
        .name("scale_edge")
        .dataset(DatasetKind::SynthSmall)
        .compressor(CompressorKind::ThreeSfc)
        .clients(clients)
        .rounds(rounds)
        .lr(0.05)
        .train_samples(train)
        .test_samples(100)
        .eval_every(rounds.max(1))
        .threads(threads)
        .n_shards(shards)
        .lazy_state(true)
        .spill(SpillKind::Slab);
    let backend = open_backend(builder.config())?;
    let mut exp = builder.build(backend.as_ref())?;
    let active = exp.clients.active_mask().iter().filter(|&&a| a).count();
    println!(
        "fleet {clients}; {active} clients hold data (the Dirichlet partition spread \
         {train} samples) — that active set is the whole dispatch cohort"
    );
    for _ in 0..rounds {
        let rec = exp.run_round()?;
        println!(
            "round {:>3}  sel {:>4}  resident {:>4} (peak {:>4})  spilled {:>5} \
             ({:>8} B)  edge arrivals/shard {:?}",
            rec.round,
            rec.n_selected,
            exp.clients.resident_count(),
            exp.clients.peak_resident(),
            exp.clients.spilled_count(),
            exp.clients.spilled_bytes(),
            exp.fed.shard_arrivals(),
        );
    }

    println!(
        "\nfleet {}  peak resident {}  spill events {}  spilled bytes {}  peak RSS {}",
        exp.clients.len(),
        exp.clients.peak_resident(),
        exp.clients.spill_events(),
        exp.clients.spilled_bytes(),
        fmt_bytes_opt(peak_rss_bytes()),
    );
    println!(
        "Reading the numbers: the store materialized at most `peak resident` dense \
         client states at once — the dispatch cohort — while the other {} clients \
         existed only as partition slots or spill slabs. The {shards}-shard edge \
         tree buffered uploads per `client % shards` and drained them in global \
         arrival order, so this trajectory is bit-identical to shards=1, \
         lazy_state=false. See EXPERIMENTS.md §Scale.",
        exp.clients.len() - exp.clients.peak_resident(),
    );
    Ok(())
}
