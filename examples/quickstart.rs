//! Quickstart: the smallest complete use of the public API.
//!
//! Runs 10 rounds of 3SFC-compressed federated learning on the toy
//! dataset/model pair and prints the per-round accuracy + traffic.
//!
//!     cargo run --release --example quickstart

use fed3sfc::config::{CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend, Backend};

fn main() -> anyhow::Result<()> {
    // 1. Describe the experiment. Everything has paper-faithful defaults
    //    (full participation, unit-step server GD, edge link model);
    //    here: 4 clients, non-iid Dirichlet(0.5) split, 3SFC at budget B
    //    (one synthetic sample), error feedback on.
    let builder = Experiment::builder()
        .dataset(DatasetKind::SynthSmall)
        .compressor(CompressorKind::ThreeSfc)
        .clients(4)
        .rounds(10)
        .lr(0.05)
        .syn_steps(15)
        .train_samples(400)
        .test_samples(100);

    // 2. Open a compute backend: the AOT artifact path (built once by
    //    `make artifacts`) when available, the pure-Rust native backend
    //    otherwise — so this example runs in a bare container too.
    //    Override with FED3SFC_BACKEND=native|pjrt or `.backend(...)`.
    let backend = open_backend(builder.config())?;
    println!("backend: {}", backend.backend_name());
    let mut exp = builder.build(backend.as_ref())?;

    // 3. Run. Each round: local SGD on every selected client -> 3SFC
    //    encode -> (simulated) upload -> server decode + aggregate ->
    //    server-optimizer step.
    for _ in 0..exp.cfg.rounds {
        let r = exp.run_round()?;
        println!(
            "round {:>2}: acc {:.3}  loss {:.3}  uploaded {} B  (ratio {:.0}x, efficiency {:.2}, comm {:.2}s)",
            r.round, r.test_acc, r.test_loss, r.up_bytes_round, r.ratio, r.efficiency, r.comm_time_s
        );
    }
    println!(
        "total upload: {} B vs {} B dense — saved {:.1}%; modeled edge-link comm {:.1}s",
        exp.traffic().uplink_bytes,
        exp.traffic().downlink_bytes,
        100.0 * (1.0 - exp.traffic().uplink_bytes as f64 / exp.traffic().downlink_bytes as f64),
        exp.traffic().comm_s
    );
    Ok(())
}
