//! Byzantine content attacks vs robust aggregation, end to end: a
//! sign-flipping minority (`[faults] byzantine_frac`) poisons its
//! decoded recons inside an *async* session, and the same workload runs
//! under the plain weighted mean, the coordinate-wise trimmed mean and
//! Krum. The reliability gate rides along, quarantining clients that
//! keep losing uploads.
//!
//! The point to watch: under attack the plain mean's loss drifts (or
//! diverges outright) while the robust estimators track the attack-free
//! trajectory, paying only their detection overhead (`trim_frac`,
//! `rejected`). Runs on the pure-Rust native backend in a bare
//! container.
//!
//!     cargo run --release --example byzantine_edge
//!
//! Scale knobs (env): ROUNDS (default 6), CLIENTS (6), TRAIN (300),
//! THREADS (0 = all cores).

use fed3sfc::bench::env_usize;
use fed3sfc::config::{AggregatorKind, CompressorKind, DatasetKind, SessionKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::simnet::ByzantineMode;

use fed3sfc::runtime::open_backend;

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 6);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 300);
    let threads = env_usize("THREADS", 0);

    println!(
        "== byzantine minority on the edge link ({clients} clients, {rounds} async steps, \
         sign-flip frac 0.34, dropout 0.15, reliability gate on) =="
    );
    let defenses = [
        (AggregatorKind::WeightedMean, "the undefended baseline"),
        (AggregatorKind::TrimmedMean, "coordinate-wise beta-trim"),
        (AggregatorKind::Krum, "geometric selection, f attackers assumed"),
    ];
    for (kind, blurb) in defenses {
        let builder = Experiment::builder()
            .name(format!("byzantine_edge-{}", kind.name()))
            .dataset(DatasetKind::SynthSmall)
            .compressor(CompressorKind::ThreeSfc)
            .clients(clients)
            .rounds(rounds)
            .lr(0.05)
            .train_samples(train)
            .test_samples(100)
            .threads(threads)
            .session(SessionKind::Async)
            .buffer_k(2)
            .staleness_decay(0.5)
            .faults(true)
            .dropout_p(0.15)
            .fault_recovery(0.5)
            .byzantine(0.34, ByzantineMode::SignFlip)
            .aggregator(kind)
            .trim_beta(0.34)
            .krum(clients.div_ceil(3), 1)
            .reliability(true)
            .quarantine_rounds(2)
            .reliability_ewma(0.5, 0.7);
        let backend = open_backend(builder.config())?;
        let mut exp = builder.build(backend.as_ref())?;
        let recs = exp.run()?;
        let last = recs.last().unwrap();
        println!(
            "aggregator={:<13} ({blurb})\n  steps {:>3}  loss {:.4}  acc {:.3}  \
             rejected(last) {:>2}  trim_frac(last) {:.2}  lost {:>3}  \
             quarantine events {:>2}  quarantined now {:?}",
            exp.fed.aggregator_name(),
            recs.len(),
            last.test_loss,
            last.test_acc,
            last.rejected_clients,
            last.trim_frac,
            exp.fed.lost_uploads(),
            exp.fed.quarantine_events(),
            exp.fed.quarantined_now(),
        );
    }

    println!(
        "\nReading the table: every run sees the *same* attack — the last \
         ceil(0.34*n) client indices flip the sign of their decoded recon at \
         the server boundary. The weighted mean averages the poison in; the \
         trimmed mean drops each coordinate's extremes (trim_frac is the \
         influence it discards); Krum forwards only the most centrally \
         located contribution and reports everyone else as rejected. The \
         reliability gate is orthogonal: clients whose uploads keep dying \
         accumulate EWMA loss mass and sit out quarantine_rounds dispatches. \
         See EXPERIMENTS.md §Defenses."
    );
    Ok(())
}
