//! Table-4-style 3SFC ablation on one pair: EF on/off, budget, local K.
//!
//!     cargo run --release --example ablation -- --dataset synth_mnist --rounds 12

use anyhow::Result;
use fed3sfc::cli::Args;
use fed3sfc::config::DatasetKind;
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &[])?;
    let dataset = DatasetKind::parse(args.get("dataset").unwrap_or("synth_mnist"))?;
    let clients = args.get_usize("clients", 10)?;
    let rounds = args.get_usize("rounds", 12)?;
    let backend = open_backend_kind(fed3sfc::config::BackendKind::Auto)?;

    println!(
        "3SFC ablation on {} ({} backend; {clients} clients, {rounds} rounds)\n",
        dataset.name(),
        backend.backend_name()
    );
    let variants: [(&str, bool, usize, usize); 6] = [
        ("base (EF, B, K=5)", true, 1, 5),
        ("w/o EF", false, 1, 5),
        ("2xB", true, 2, 5),
        ("4xB", true, 4, 5),
        ("K=1", true, 1, 1),
        ("K=10", true, 1, 10),
    ];
    println!("{:<20} {:>10} {:>10} {:>10}", "variant", "final acc", "best acc", "ratio");
    for (label, ef, budget, k) in variants {
        let mut exp = Experiment::builder()
            .dataset(dataset)
            .error_feedback(ef)
            .budget_mult(budget)
            .k_local(k)
            .clients(clients)
            .rounds(rounds)
            .lr(0.05)
            .eval_every(1)
            .syn_steps(20)
            .build(backend.as_ref())?;
        let recs = exp.run()?;
        let last = recs.last().unwrap();
        println!(
            "{:<20} {:>10.4} {:>10.4} {:>9.1}x",
            label,
            last.test_acc,
            exp.metrics.best_acc(),
            last.ratio
        );
    }
    println!("\nexpected: w/o EF and K=1 degrade; 2xB/4xB and K=10 improve (paper Table 4).");
    Ok(())
}
