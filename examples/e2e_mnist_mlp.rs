//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): trains the paper-scale MLP
//! (198,760 params — the paper's Fig-1 model) on non-i.i.d. synth-MNIST
//! with 20 clients for a few hundred rounds, 3SFC vs FedAvg, logging the
//! full loss/accuracy curves and exact traffic. Proves all three layers
//! compose: rust coordinator -> PJRT executables -> jax/pallas compute.
//!
//!     cargo run --release --example e2e_mnist_mlp            # 200 rounds
//!     ROUNDS=50 cargo run --release --example e2e_mnist_mlp  # scaled
//!     FRAC=50 CLIENTS=40 ... # percent participation (uniform sampling)
//!     THREADS=1 ...          # sequential clients (default: all cores;
//!                            # trajectories identical either way)
//!
//! Writes e2e_<method>.jsonl next to cwd for plotting.

use fed3sfc::bench::env_usize;
use fed3sfc::config::{CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 200);
    let clients = env_usize("CLIENTS", 20);
    let frac_pct = env_usize("FRAC", 100);
    let threads = env_usize("THREADS", 0);
    let frac = (frac_pct as f64 / 100.0).clamp(0.01, 1.0);
    // mlp10 is in both manifests: PJRT artifacts when present, native
    // otherwise (FED3SFC_BACKEND overrides).
    let backend = open_backend_kind(fed3sfc::config::BackendKind::Auto)?;

    for method in [CompressorKind::ThreeSfc, CompressorKind::FedAvg] {
        println!(
            "=== e2e: {} | mlp10 (P=198760) on synth_mnist ({} backend), {clients} clients ({frac_pct}%), {rounds} rounds ===",
            method.name(),
            backend.backend_name()
        );
        let mut exp = Experiment::builder()
            .name(format!("e2e-{}", method.name()))
            .dataset(DatasetKind::SynthMnist)
            .compressor(method)
            .clients(clients)
            .rounds(rounds)
            .lr(0.05)
            .k_local(5)
            .syn_steps(20)
            .train_samples(2000)
            .test_samples(500)
            .eval_every(5)
            .client_frac(frac)
            .threads(threads)
            .metrics_path(format!("e2e_{}.jsonl", method.name()))
            .build(backend.as_ref())?;
        println!("client execution: {} thread(s)", exp.threads());
        let t0 = std::time::Instant::now();
        for i in 0..rounds {
            let r = exp.run_round()?;
            if (i + 1) % 5 == 0 || i == 0 {
                println!(
                    "round {:>4}  acc {:.4}  loss {:.4}  sel {:>3}  cum-up {:>12} B  eff {:.3}",
                    r.round, r.test_acc, r.test_loss, r.n_selected, r.up_bytes_cum, r.efficiency
                );
            }
        }
        exp.metrics.flush()?;
        let t = exp.traffic();
        println!(
            "{}: best acc {:.4}, wall {:.1}s, upload {} B, modeled edge-link comm {:.1}s\n",
            method.name(),
            exp.metrics.best_acc(),
            t0.elapsed().as_secs_f64(),
            t.uplink_bytes,
            t.comm_s,
        );
    }
    println!("loss curves in e2e_3sfc.jsonl / e2e_fedavg.jsonl");
    Ok(())
}
