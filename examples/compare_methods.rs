//! Table-2-style comparison for one dataset/model pair, all methods —
//! the fastest way to see the paper's headline ordering on your machine.
//!
//! Optionally runs the whole grid under partial participation and a
//! server optimizer, e.g.:
//!
//!     cargo run --release --example compare_methods -- \
//!         --dataset synth_fmnist --model mnistnet --clients 10 --rounds 10 \
//!         --client-frac 0.5 --server-opt fedadam

use anyhow::Result;
use fed3sfc::cli::Args;
use fed3sfc::config::{CompressorKind, DatasetKind, ServerOptKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &[])?;
    let dataset = DatasetKind::parse(args.get("dataset").unwrap_or("synth_mnist"))?;
    let model = args.get("model").unwrap_or("").to_string();
    let clients = args.get_usize("clients", 10)?;
    let rounds = args.get_usize("rounds", 10)?;
    let frac = args.get_f64("client-frac", 1.0)?;
    let server_opt = ServerOptKind::parse(args.get("server-opt").unwrap_or("gd"))?;

    let backend = match args.get("backend") {
        Some(v) => open_backend_kind(fed3sfc::config::BackendKind::parse(v)?)?,
        None => open_backend_kind(fed3sfc::config::BackendKind::Auto)?,
    };
    println!(
        "method comparison: {} / {} ({} backend) — {clients} clients (frac {frac}), {rounds} rounds, server_opt {}\n",
        dataset.name(),
        if model.is_empty() { dataset.default_model() } else { &model },
        backend.backend_name(),
        server_opt.name(),
    );
    println!(
        "{:<10} {:>10} {:>10} {:>12} {:>14} {:>12}",
        "method", "final acc", "best acc", "ratio", "upload bytes", "comm time"
    );
    for method in [
        CompressorKind::FedAvg,
        CompressorKind::Dgc,
        CompressorKind::SignSgd,
        CompressorKind::Stc,
        CompressorKind::ThreeSfc,
    ] {
        // client_frac < 1 implies uniform sampling (effective_schedule).
        let mut exp = Experiment::builder()
            .dataset(dataset)
            .model(model.clone())
            .compressor(method)
            .clients(clients)
            .rounds(rounds)
            .lr(0.05)
            .eval_every(1)
            .syn_steps(20)
            .client_frac(frac)
            .server_opt(server_opt)
            .build(backend.as_ref())?;
        let recs = exp.run()?;
        let last = recs.last().unwrap();
        let t = exp.traffic();
        println!(
            "{:<10} {:>10.4} {:>10.4} {:>11.1}x {:>14} {:>11.1}s",
            method.name(),
            last.test_acc,
            exp.metrics.best_acc(),
            last.ratio,
            t.uplink_bytes,
            t.comm_s,
        );
    }
    Ok(())
}
