//! Table-2-style comparison for one dataset/model pair, all methods —
//! the fastest way to see the paper's headline ordering on your machine.
//!
//!     cargo run --release --example compare_methods -- \
//!         --dataset synth_fmnist --model mnistnet --clients 10 --rounds 10

use anyhow::Result;
use fed3sfc::cli::Args;
use fed3sfc::config::{CompressorKind, DatasetKind, ExperimentConfig};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::Runtime;
use fed3sfc::simnet::NetworkModel;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &[])?;
    let dataset = DatasetKind::parse(args.get("dataset").unwrap_or("synth_mnist"))?;
    let model = args.get("model").unwrap_or("").to_string();
    let clients = args.get_usize("clients", 10)?;
    let rounds = args.get_usize("rounds", 10)?;

    let rt = Runtime::open(&fed3sfc::artifacts_dir())?;
    let net = NetworkModel::edge();
    println!(
        "method comparison: {} / {} — {clients} clients, {rounds} rounds\n",
        dataset.name(),
        if model.is_empty() { dataset.default_model() } else { &model },
    );
    println!(
        "{:<10} {:>10} {:>10} {:>12} {:>14} {:>12}",
        "method", "final acc", "best acc", "ratio", "upload bytes", "comm time"
    );
    for method in [
        CompressorKind::FedAvg,
        CompressorKind::Dgc,
        CompressorKind::SignSgd,
        CompressorKind::Stc,
        CompressorKind::ThreeSfc,
    ] {
        let cfg = ExperimentConfig {
            dataset,
            model: model.clone(),
            compressor: method,
            n_clients: clients,
            rounds,
            lr: 0.05,
            eval_every: 1,
            syn_steps: 20,
            ..ExperimentConfig::default()
        };
        let mut exp = Experiment::new(cfg, &rt)?;
        let recs = exp.run()?;
        let last = recs.last().unwrap();
        let t = exp.traffic;
        println!(
            "{:<10} {:>10.4} {:>10.4} {:>11.1}x {:>14} {:>11.1}s",
            method.name(),
            last.test_acc,
            exp.metrics.best_acc(),
            last.ratio,
            t.up_bytes,
            net.total_time_s(t.rounds, t.up_bytes, t.down_bytes, clients),
        );
    }
    Ok(())
}
