//! Fig-1-style sweep, CLI-configurable: accuracy-vs-round for a list of
//! top-k rates plus 3SFC at matched budget, on any dataset/model pair.
//!
//!     cargo run --release --example compression_sweep -- \
//!         --dataset synth_mnist --clients 20 --rounds 15 \
//!         --rates 1.0,0.01,0.001

use anyhow::Result;
use fed3sfc::cli::Args;
use fed3sfc::config::{CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::{Experiment, ExperimentBuilder};
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1).collect::<Vec<_>>(), &[])?;
    let dataset = DatasetKind::parse(args.get("dataset").unwrap_or("synth_mnist"))?;
    let clients = args.get_usize("clients", 10)?;
    let rounds = args.get_usize("rounds", 12)?;
    let rates: Vec<f64> = args
        .get("rates")
        .unwrap_or("1.0,0.1,0.01,0.001")
        .split(',')
        .map(|s| s.parse().unwrap())
        .collect();

    let backend = open_backend_kind(fed3sfc::config::BackendKind::Auto)?;
    println!(
        "compression sweep on {} ({} backend; {clients} clients, {rounds} rounds)",
        dataset.name(),
        backend.backend_name()
    );

    let run = |name: String, builder: ExperimentBuilder| -> Result<()> {
        let mut exp = builder.build(backend.as_ref())?;
        let recs = exp.run()?;
        let accs: Vec<String> = recs.iter().map(|r| format!("{:.3}", r.test_acc)).collect();
        println!(
            "{name:<18} ratio {:>8.1}x  final {:.4}  series [{}]",
            recs.last().unwrap().ratio,
            recs.last().unwrap().test_acc,
            accs.join(" ")
        );
        Ok(())
    };

    for &rate in &rates {
        let method = if rate >= 1.0 { CompressorKind::FedAvg } else { CompressorKind::Dgc };
        let builder = Experiment::builder()
            .dataset(dataset)
            .compressor(method)
            .topk_rate(rate)
            .clients(clients)
            .rounds(rounds)
            .lr(0.05)
            .eval_every(1);
        run(format!("topk rate={rate}"), builder)?;
    }
    // 3SFC reference at budget B.
    let builder = Experiment::builder()
        .dataset(dataset)
        .compressor(CompressorKind::ThreeSfc)
        .clients(clients)
        .rounds(rounds)
        .lr(0.05)
        .eval_every(1)
        .syn_steps(20);
    run("3sfc (B)".into(), builder)?;
    Ok(())
}
