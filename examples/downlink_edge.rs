//! Double-way compression on the edge link: the same workload with the
//! broadcast direction dense (keyframes only) vs compressed (top-k /
//! 3SFC model deltas against each client's last acked version), under a
//! synchronous barrier and a FedBuff-style async session.
//!
//! The point to watch: once uploads are compressed, dense broadcasts
//! dominate the wire — the downlink ledger (compress::downlink) trades
//! them for small deltas plus the occasional keyframe resync, and the
//! per-direction traffic split shows exactly where the bytes went.
//! Runs on the pure-Rust native backend in a bare container.
//!
//!     cargo run --release --example downlink_edge
//!
//! Scale knobs (env): ROUNDS (default 6), CLIENTS (6), TRAIN (300),
//! THREADS (0 = all cores), GAP (4 = keyframe fallback threshold).

use fed3sfc::bench::env_usize;
use fed3sfc::config::{CompressorKind, DatasetKind, DownlinkKind, SessionKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend, Backend};

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 6);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 300);
    let threads = env_usize("THREADS", 0);
    let gap = env_usize("GAP", 4);

    println!(
        "== downlink compression on the edge link ({clients} clients, {rounds} steps, gap {gap}) =="
    );
    let sessions = [
        (SessionKind::Sync, "barrier on the full cohort"),
        (SessionKind::Async, "aggregate every 2 arrivals, stale-discounted"),
    ];
    let downlinks = [DownlinkKind::Identity, DownlinkKind::TopK, DownlinkKind::ThreeSfc];
    for (session, blurb) in sessions {
        println!("\n-- session = {} ({blurb}) --", session.name());
        let mut dense_total = 0u64;
        for kind in downlinks {
            let builder = Experiment::builder()
                .name(format!("downlink_edge-{}-{}", session.name(), kind.name()))
                .dataset(DatasetKind::SynthSmall)
                .compressor(CompressorKind::Dgc)
                .topk_rate(0.01)
                .clients(clients)
                .rounds(rounds)
                .lr(0.05)
                .train_samples(train)
                .test_samples(100)
                .threads(threads)
                .jitter(0.4)
                .session(session)
                .buffer_k(2)
                .staleness_decay(0.5)
                .downlink(kind)
                .downlink_gap(gap)
                .downlink_rate(0.01);
            let backend = open_backend(builder.config())?;
            let mut exp = builder.build(backend.as_ref())?;
            let recs = exp.run()?;
            let t = exp.traffic();
            let total = t.total_bytes();
            if kind == DownlinkKind::Identity {
                dense_total = total;
            }
            let last = recs.last().unwrap();
            println!(
                "down={:<8} up {:>10} B  down {:>10} B  total {:>10} B ({:>5.1}% saved)  \
                 acc {:.3}  vtime {:.2}s",
                kind.name(),
                t.uplink_bytes,
                t.downlink_bytes,
                total,
                100.0 * (1.0 - total as f64 / dense_total as f64),
                last.test_acc,
                last.sim_time_s,
            );
        }
    }
    println!(
        "\nReading the table: identity keyframes every broadcast (the classic dense \
         path, bit-identical to it); top-k / 3SFC ship model deltas against each \
         client's ledger version with server-side EF, falling back to a keyframe \
         past the version gap. See EXPERIMENTS.md §Downlink."
    );
    Ok(())
}
