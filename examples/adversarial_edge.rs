//! Adversarial reality on the edge link: the same compressed workload
//! under the `[faults]` layer — per-dispatch dropouts, crash-and-recover
//! windows, a diurnal availability wave, and three correlated
//! device-class tiers — across all three aggregation policies.
//!
//! The point to watch: deadline and async sessions *absorb* the losses
//! (thinner steps, staleness, recovered clients) and still converge,
//! while the synchronous barrier fails fast with a typed diagnostic the
//! moment a cohort member drops — it can never complete, so the server
//! refuses to hang. Runs on the pure-Rust native backend in a bare
//! container.
//!
//!     cargo run --release --example adversarial_edge
//!
//! Scale knobs (env): ROUNDS (default 6), CLIENTS (6), TRAIN (300),
//! THREADS (0 = all cores).

use fed3sfc::bench::env_usize;
use fed3sfc::config::{CompressorKind, DatasetKind, SessionKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::coordinator::UploadError;
use fed3sfc::runtime::open_backend;

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 6);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 300);
    let threads = env_usize("THREADS", 0);

    println!(
        "== adversarial reality on the edge link ({clients} clients, {rounds} steps, \
         dropout 0.2, 3 device tiers) =="
    );
    let sessions = [
        (SessionKind::Deadline, "aggregate whatever beat the deadline"),
        (SessionKind::Async, "aggregate every 2 arrivals, stale-discounted"),
    ];
    for (session, blurb) in sessions {
        let builder = Experiment::builder()
            .name(format!("adversarial_edge-{}", session.name()))
            .dataset(DatasetKind::SynthSmall)
            .compressor(CompressorKind::ThreeSfc)
            .clients(clients)
            .rounds(rounds)
            .lr(0.05)
            .train_samples(train)
            .test_samples(100)
            .threads(threads)
            .jitter(0.3)
            .session(session)
            .deadline_s(0.25)
            .buffer_k(2)
            .staleness_decay(0.5)
            .faults(true)
            .dropout_p(0.2)
            .fault_recovery(0.5)
            .diurnal(0.4, 10.0)
            .device_tiers(3, 0.6, 0.02);
        let backend = open_backend(builder.config())?;
        let mut exp = builder.build(backend.as_ref())?;
        let recs = exp.run()?;
        let last = recs.last().unwrap();
        let aggregated: usize = recs.iter().map(|r| r.n_selected).sum();
        println!(
            "session={:<9} ({blurb})\n  steps {:>3}  aggregated {:>3}  lost {:>3}  \
             recovered {:>3}  stale(last) {:.2}  acc {:.3}  vtime {:.2}s",
            session.name(),
            recs.len(),
            aggregated,
            exp.fed.lost_uploads(),
            exp.fed.recovered_clients(),
            last.stale_mean,
            last.test_acc,
            last.sim_time_s,
        );
    }

    // The same faults under a barrier: a typed diagnostic, not a hang.
    let builder = Experiment::builder()
        .name("adversarial_edge-sync")
        .dataset(DatasetKind::SynthSmall)
        .compressor(CompressorKind::ThreeSfc)
        .clients(clients)
        .rounds(rounds)
        .lr(0.05)
        .train_samples(train)
        .test_samples(100)
        .threads(threads)
        .session(SessionKind::Sync)
        .faults(true)
        .dropout_p(1.0);
    let backend = open_backend(builder.config())?;
    let mut exp = builder.build(backend.as_ref())?;
    match exp.run() {
        Ok(_) => anyhow::bail!("sync session unexpectedly survived certain dropouts"),
        Err(e) => {
            let typed = e
                .downcast_ref::<UploadError>()
                .map(|u| matches!(u, UploadError::LossUnderBarrier { .. }))
                .unwrap_or(false);
            println!("\nsession=sync      refused as designed (typed: {typed})\n  {e:#}");
        }
    }

    println!(
        "\nReading the table: lost counts uploads the fault layer killed mid-transfer \
         (each opens a crash window); recovered counts clients whose window elapsed. \
         Deadline steps thin out when casualties miss the cutoff; async keeps stepping \
         every K arrivals and re-dispatches recovered clients immediately. The barrier \
         cannot absorb a loss, so it fails fast with the LossUnderBarrier diagnostic. \
         See EXPERIMENTS.md §Scenarios."
    );
    Ok(())
}
