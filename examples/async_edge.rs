//! Event-driven sessions on a jittery edge network: the same workload
//! under the three aggregation policies — synchronous cohort barrier,
//! semi-sync deadline, and FedBuff-style buffered asynchrony — compared
//! on *virtual* time-to-accuracy.
//!
//! Every client gets its own link (base edge preset × a seed-pinned
//! jitter factor), every message is delivered on the simnet virtual
//! clock, and stragglers behave per policy: the barrier waits for them,
//! the deadline carries them over with a staleness discount, the async
//! buffer absorbs them. Runs on the pure-Rust native backend in a bare
//! container.
//!
//!     cargo run --release --example async_edge
//!
//! Scale knobs (env): ROUNDS (default 8), CLIENTS (8), TRAIN (400),
//! THREADS (0 = all cores).

use fed3sfc::bench::env_usize;
use fed3sfc::config::{CompressorKind, DatasetKind, SessionKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend, Backend};

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 8);
    let clients = env_usize("CLIENTS", 8);
    let train = env_usize("TRAIN", 400);
    let threads = env_usize("THREADS", 0);

    println!(
        "== event-driven sessions on a jittery edge link ({clients} clients, {rounds} steps) =="
    );
    let sessions = [
        (SessionKind::Sync, "barrier on the full cohort"),
        (SessionKind::Deadline, "aggregate whatever arrived each 62.5 ms"),
        (SessionKind::Async, "aggregate every 3 arrivals, stale-discounted"),
    ];
    for (session, blurb) in sessions {
        let builder = Experiment::builder()
            .name(format!("async_edge-{}", session.name()))
            .dataset(DatasetKind::SynthSmall)
            .compressor(CompressorKind::ThreeSfc)
            .clients(clients)
            .rounds(rounds)
            .lr(0.05)
            .syn_steps(10)
            .train_samples(train)
            .test_samples(100)
            .threads(threads)
            // Per-client bandwidth spread of ±60% around the edge preset
            // (10 Mbps up / 50 Mbps down / 30 ms), on a dedicated seeded
            // stream — the same five slow clients in every run.
            .jitter(0.6)
            .session(session)
            .deadline_s(0.0625)
            .buffer_k(3)
            .staleness_decay(0.5);
        let backend = open_backend(builder.config())?;
        let mut exp = builder.build(backend.as_ref())?;
        println!(
            "\n-- session = {} ({blurb}; {} backend) --",
            session.name(),
            backend.backend_name()
        );
        for _ in 0..rounds {
            let r = exp.run_round()?;
            println!(
                "step {:>2}: acc {:.3}  loss {:.3}  aggregated {:>2} upload(s)  stale {:.2}  \
                 vtime {:>6.2}s  (+{:.3}s)",
                r.round, r.test_acc, r.test_loss, r.n_selected, r.stale_mean, r.sim_time_s,
                r.comm_time_s
            );
        }
        let last = exp.metrics.last().unwrap();
        println!(
            "=> {}: best acc {:.3} after {:.2} virtual seconds, {} B uploaded",
            session.name(),
            exp.metrics.best_acc(),
            last.sim_time_s,
            exp.traffic().uplink_bytes
        );
    }
    println!(
        "\nReading the table: sync pays the slowest straggler every step; the deadline \
         session trades staleness for a fixed cadence; the async session keeps every \
         link busy. See EXPERIMENTS.md §Sessions for the protocol."
    );
    Ok(())
}
