"""L2: model zoo over FLAT parameter vectors.

Every model is a pure function ``apply(w_flat, x) -> logits`` where
``w_flat: f32[P]`` is the packed parameter vector. The rust coordinator only
ever sees flat vectors — packing/unpacking lives here, recorded in the
artifact manifest so both sides agree on ``P``.

Dense layers go through the L1 Pallas ``matmul`` kernel; convolutions use
``lax.conv_general_dilated`` (XLA's conv is already the fused hot path — the
paper's models are conv/dense mixes and the compressor math, not the conv,
is the contribution).

Models (paper → here, scaled for the 1-CPU testbed; see DESIGN.md §3):
  * ``mlp_small``  — 64→32→8, test/CI-sized.
  * ``mlp10/26``   — 784→250→{10,26}; ≈199k params like the paper's MLP.
  * ``mnistnet``   — 2 conv + 2 fc on 28×28×1 (paper's MnistNet).
  * ``convnet``    — 4 conv + 1 fc on 16×16×3 (paper's ConvNet, 32→16 px).
  * ``resnet8``    — stem + 3 residual blocks, no BN (paper removes BN).
  * ``regnet_tiny``— stem + 2 grouped-conv bottleneck blocks, no BN.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple
    fan_in: int  # for He-normal init


@dataclasses.dataclass(frozen=True)
class ModelDef:
    """A model: flat-param apply fn + metadata the manifest exports."""

    name: str
    input_shape: tuple  # per-sample shape, e.g. (784,) or (28, 28, 1)
    n_classes: int
    params: tuple  # tuple[ParamSpec]
    apply: Callable  # (w_flat, x_batch) -> logits

    @property
    def n_params(self) -> int:
        return int(sum(int(np.prod(p.shape)) for p in self.params))

    def unpack(self, w: jax.Array) -> list:
        out, off = [], 0
        for p in self.params:
            n = int(np.prod(p.shape))
            out.append(w[off : off + n].reshape(p.shape))
            off += n
        return out

    def init(self, seed: int = 0) -> np.ndarray:
        """He-normal packed init, deterministic; exported as .init.bin."""
        rng = np.random.default_rng(seed)
        chunks = []
        for p in self.params:
            if len(p.shape) == 1:  # biases start at zero
                chunks.append(np.zeros(p.shape, np.float32))
            else:
                std = float(np.sqrt(2.0 / max(p.fan_in, 1)))
                chunks.append(
                    rng.normal(0.0, std, size=p.shape).astype(np.float32)
                )
        return np.concatenate([c.ravel() for c in chunks])


# ---------------------------------------------------------------- helpers

def _dense(x, w, b):
    return kernels.matmul(x, w) + b


def _conv(x, k, stride=1, padding="SAME", groups=1):
    return jax.lax.conv_general_dilated(
        x,
        k,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _gap(x):  # global average pool NHWC -> NC
    return jnp.mean(x, axis=(1, 2))


# ------------------------------------------------------------------ MLPs

def make_mlp(name: str, d_in: int, d_hidden: int, n_classes: int) -> ModelDef:
    params = (
        ParamSpec("w1", (d_in, d_hidden), d_in),
        ParamSpec("b1", (d_hidden,), d_in),
        ParamSpec("w2", (d_hidden, n_classes), d_hidden),
        ParamSpec("b2", (n_classes,), d_hidden),
    )

    def apply(w, x):
        md = _REGISTRY[name]
        w1, b1, w2, b2 = md.unpack(w)
        h = jax.nn.relu(_dense(x, w1, b1))
        return _dense(h, w2, b2)

    return ModelDef(name, (d_in,), n_classes, params, apply)


# -------------------------------------------------------------- MnistNet

def make_mnistnet(name: str, n_classes: int) -> ModelDef:
    # 28x28x1 -> conv5 8 -> pool -> conv5 16 -> pool -> fc64 -> fc C
    params = (
        ParamSpec("c1", (5, 5, 1, 8), 25),
        ParamSpec("cb1", (8,), 25),
        ParamSpec("c2", (5, 5, 8, 16), 200),
        ParamSpec("cb2", (16,), 200),
        ParamSpec("w1", (4 * 4 * 16, 64), 256),
        ParamSpec("b1", (64,), 256),
        ParamSpec("w2", (64, n_classes), 64),
        ParamSpec("b2", (n_classes,), 64),
    )

    def apply(w, x):
        md = _REGISTRY[name]
        c1, cb1, c2, cb2, w1, b1, w2, b2 = md.unpack(w)
        h = jax.nn.relu(_conv(x, c1, padding="VALID") + cb1)  # 24
        h = _maxpool2(h)  # 12
        h = jax.nn.relu(_conv(h, c2, padding="VALID") + cb2)  # 8
        h = _maxpool2(h)  # 4
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.relu(_dense(h, w1, b1))
        return _dense(h, w2, b2)

    return ModelDef(name, (28, 28, 1), n_classes, params, apply)


# --------------------------------------------------------------- ConvNet

def make_convnet(name: str, n_classes: int) -> ModelDef:
    # 16x16x3: 4 conv (3x3) + 1 fc, pools after conv2 and conv4.
    params = (
        ParamSpec("c1", (3, 3, 3, 16), 27),
        ParamSpec("cb1", (16,), 27),
        ParamSpec("c2", (3, 3, 16, 16), 144),
        ParamSpec("cb2", (16,), 144),
        ParamSpec("c3", (3, 3, 16, 32), 144),
        ParamSpec("cb3", (32,), 144),
        ParamSpec("c4", (3, 3, 32, 32), 288),
        ParamSpec("cb4", (32,), 288),
        ParamSpec("w1", (4 * 4 * 32, n_classes), 512),
        ParamSpec("b1", (n_classes,), 512),
    )

    def apply(w, x):
        md = _REGISTRY[name]
        c1, cb1, c2, cb2, c3, cb3, c4, cb4, w1, b1 = md.unpack(w)
        h = jax.nn.relu(_conv(x, c1) + cb1)
        h = jax.nn.relu(_conv(h, c2) + cb2)
        h = _maxpool2(h)  # 8
        h = jax.nn.relu(_conv(h, c3) + cb3)
        h = jax.nn.relu(_conv(h, c4) + cb4)
        h = _maxpool2(h)  # 4
        h = h.reshape(h.shape[0], -1)
        return _dense(h, w1, b1)

    return ModelDef(name, (16, 16, 3), n_classes, params, apply)


# --------------------------------------------------------------- ResNet8

def make_resnet8(name: str, n_classes: int) -> ModelDef:
    # Stem + 3 residual blocks (2 convs each), no BN (paper removes BN), GAP.
    width = 16
    ps = [ParamSpec("stem", (3, 3, 3, width), 27), ParamSpec("stemb", (width,), 27)]
    for b in range(3):
        for c in range(2):
            ps.append(ParamSpec(f"r{b}c{c}", (3, 3, width, width), 9 * width))
            ps.append(ParamSpec(f"r{b}cb{c}", (width,), 9 * width))
    ps.append(ParamSpec("fc", (width, n_classes), width))
    ps.append(ParamSpec("fcb", (n_classes,), width))
    params = tuple(ps)

    def apply(w, x):
        md = _REGISTRY[name]
        u = md.unpack(w)
        h = jax.nn.relu(_conv(x, u[0]) + u[1])
        i = 2
        for _ in range(3):
            r = jax.nn.relu(_conv(h, u[i]) + u[i + 1])
            r = _conv(r, u[i + 2]) + u[i + 3]
            h = jax.nn.relu(h + r)
            i += 4
        h = _gap(h)
        return _dense(h, u[i], u[i + 1])

    return ModelDef(name, (16, 16, 3), n_classes, params, apply)


# ----------------------------------------------------------- RegNet-tiny

def make_regnet_tiny(name: str, n_classes: int) -> ModelDef:
    # Stem + 2 bottleneck blocks with grouped 3x3 (groups=4), no BN, GAP.
    win, wmid, groups = 16, 32, 4
    ps = [ParamSpec("stem", (3, 3, 3, win), 27), ParamSpec("stemb", (win,), 27)]
    for b in range(2):
        ps.append(ParamSpec(f"b{b}p1", (1, 1, win, wmid), win))
        ps.append(ParamSpec(f"b{b}pb1", (wmid,), win))
        ps.append(
            ParamSpec(f"b{b}g", (3, 3, wmid // groups, wmid), 9 * wmid // groups)
        )
        ps.append(ParamSpec(f"b{b}gb", (wmid,), 9 * wmid // groups))
        ps.append(ParamSpec(f"b{b}p2", (1, 1, wmid, win), wmid))
        ps.append(ParamSpec(f"b{b}pb2", (win,), wmid))
    ps.append(ParamSpec("fc", (win, n_classes), win))
    ps.append(ParamSpec("fcb", (n_classes,), win))
    params = tuple(ps)

    def apply(w, x):
        md = _REGISTRY[name]
        u = md.unpack(w)
        h = jax.nn.relu(_conv(x, u[0]) + u[1])
        i = 2
        for _ in range(2):
            r = jax.nn.relu(_conv(h, u[i]) + u[i + 1])
            r = jax.nn.relu(_conv(r, u[i + 2], groups=groups) + u[i + 3])
            r = _conv(r, u[i + 4]) + u[i + 5]
            h = jax.nn.relu(h + r)
            i += 6
        h = _gap(h)
        return _dense(h, u[i], u[i + 1])

    return ModelDef(name, (16, 16, 3), n_classes, params, apply)


# --------------------------------------------------------------- registry

_REGISTRY: dict = {}


def _register(md: ModelDef) -> ModelDef:
    _REGISTRY[md.name] = md
    return md


MLP_SMALL = _register(make_mlp("mlp_small", 64, 32, 8))
MLP10 = _register(make_mlp("mlp10", 784, 250, 10))
MLP26 = _register(make_mlp("mlp26", 784, 250, 26))
MNISTNET = _register(make_mnistnet("mnistnet", 10))
CONVNET = _register(make_convnet("convnet", 10))
RESNET8_C10 = _register(make_resnet8("resnet8_c10", 10))
RESNET8_C20 = _register(make_resnet8("resnet8_c20", 20))
REGNET_C10 = _register(make_regnet_tiny("regnet_c10", 10))
REGNET_C20 = _register(make_regnet_tiny("regnet_c20", 20))

ALL_MODELS: Sequence[ModelDef] = tuple(_REGISTRY.values())


def get(name: str) -> ModelDef:
    return _REGISTRY[name]
