"""AOT compiler: lower every (model × fed-op × shape variant) to HLO text.

Build-time only — ``make artifacts`` runs this once; rust never imports
python. The interchange format is HLO **text** (``as_hlo_text()``), NOT a
serialized ``HloModuleProto``: jax ≥ 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published `xla`
crate binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Outputs, under ``artifacts/``:
  * ``<model>__<op>.hlo.txt``  one per op variant
  * ``<model>.init.bin``       packed He-normal initial weights (f32 LE)
  * ``manifest.json``          every shape the rust side needs
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import fedops, models

F32 = jnp.float32
I32 = jnp.int32

# Per-model static batch sizes (train / eval).
TRAIN_BATCH = {"mlp_small": 16}
EVAL_BATCH = {"mlp_small": 50}
DEFAULT_TRAIN_BATCH = 32
DEFAULT_EVAL_BATCH = 100

# Which local-iteration counts K get a train artifact (Table 4 ablates K).
TRAIN_KS = {
    "mlp_small": (1, 5, 10),
    "mlp10": (1, 5, 10),
    "mlp26": (1, 5, 10),
    "mnistnet": (1, 5, 10),
    "convnet": (1, 5, 10),
    "resnet8_c10": (1, 5, 10),
    "resnet8_c20": (1, 5, 10),
    "regnet_c10": (1, 5, 10),
    "regnet_c20": (1, 5, 10),
}
# Synthetic-sample counts m (communication budget B, 2B, 4B ~ m=1,2,4).
SYN_MS = (1, 2, 4)
# Fused-encoder step counts (perf pass): one dispatch runs S Adam steps.
SYN_OPT_S = (10, 20, 40)
# FedSynth unroll depths (Figs 2-3 sweep on mlp_small; Table 1 pairs use 4).
FEDSYNTH_KS = {
    "mlp_small": (1, 2, 4, 8, 16),
    "mlp10": (4,),
    "mlp26": (4,),
    "mnistnet": (4,),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_op_table(md: models.ModelDef):
    """Yield (op_name, fn, arg_specs, meta) for one model."""
    P = md.n_params
    ins = md.input_shape
    C = md.n_classes
    bt = TRAIN_BATCH.get(md.name, DEFAULT_TRAIN_BATCH)
    be = EVAL_BATCH.get(md.name, DEFAULT_EVAL_BATCH)
    scalar = _spec(())

    for k in TRAIN_KS.get(md.name, (5,)):
        yield (
            f"train_k{k}",
            fedops.make_local_train(md, k),
            [_spec((P,)), _spec((k, bt) + ins), _spec((k, bt), I32), scalar],
            {"kind": "train", "k": k, "batch": bt},
        )
    if md.name == "mlp_small":
        yield (
            "grad",
            fedops.make_grad_batch(md),
            [_spec((P,)), _spec((bt,) + ins), _spec((bt,), I32)],
            {"kind": "grad", "batch": bt},
        )
    for m in SYN_MS:
        yield (
            f"syn_step_m{m}",
            fedops.make_syn_step(md),
            [
                _spec((P,)),
                _spec((P,)),
                _spec((m,) + ins),
                _spec((m, C)),
                scalar,
                scalar,
            ],
            {"kind": "syn_step", "m": m},
        )
        yield (
            f"syn_grad_m{m}",
            fedops.make_syn_grad(md),
            [_spec((P,)), _spec((m,) + ins), _spec((m, C))],
            {"kind": "syn_grad", "m": m},
        )
        for s in SYN_OPT_S:
            yield (
                f"syn_opt_m{m}_s{s}",
                fedops.make_syn_opt(md, s),
                [
                    _spec((P,)),
                    _spec((P,)),
                    _spec((m,) + ins),
                    _spec((m, C)),
                    scalar,
                    scalar,
                ],
                {"kind": "syn_opt", "m": m, "k": s},
            )
    yield (
        "eval",
        fedops.make_eval_batch(md),
        [_spec((P,)), _spec((be,) + ins), _spec((be,), I32)],
        {"kind": "eval", "batch": be},
    )
    for k in FEDSYNTH_KS.get(md.name, ()):
        m = 1
        yield (
            f"fedsynth_k{k}_m{m}",
            fedops.make_fedsynth_step(md, k),
            [
                _spec((P,)),
                _spec((P,)),
                _spec((k, m) + ins),
                _spec((k, m, C)),
                scalar,
                scalar,
            ],
            {"kind": "fedsynth", "k": k, "m": m},
        )
        yield (
            f"fedsynth_apply_k{k}_m{m}",
            fedops.make_fedsynth_apply(md, k),
            [_spec((P,)), _spec((k, m) + ins), _spec((k, m, C)), scalar],
            {"kind": "fedsynth_apply", "k": k, "m": m},
        )


def lower_model(md: models.ModelDef, out_dir: str, manifest: dict, only=None):
    ops = {}
    for op_name, fn, specs, meta in build_op_table(md):
        if only and op_name not in only:
            continue
        fname = f"{md.name}__{op_name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        meta = dict(meta)
        meta["file"] = fname
        ops[op_name] = meta
        print(
            f"  {md.name:12s} {op_name:18s} {len(text)/1024:8.1f} KiB"
            f"  {time.time()-t0:5.1f}s",
            flush=True,
        )
    init = md.init(seed=0)
    init_file = f"{md.name}.init.bin"
    init.tofile(os.path.join(out_dir, init_file))
    manifest["models"][md.name] = {
        "params": md.n_params,
        "input_shape": list(md.input_shape),
        "n_classes": md.n_classes,
        "train_batch": TRAIN_BATCH.get(md.name, DEFAULT_TRAIN_BATCH),
        "eval_batch": EVAL_BATCH.get(md.name, DEFAULT_EVAL_BATCH),
        "init": init_file,
        "ops": ops,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--models",
        default="",
        help="comma-separated subset of model names (default: all)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    want = [m for m in args.models.split(",") if m] or None

    manifest = {"version": 1, "models": {}}
    t0 = time.time()
    for md in models.ALL_MODELS:
        if want and md.name not in want:
            continue
        print(f"model {md.name}  P={md.n_params}", flush=True)
        lower_model(md, args.out_dir, manifest)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"done in {time.time()-t0:.0f}s -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
