"""L2: federated ops — the functions the rust coordinator executes.

Each op is a pure jax function over flat parameter vectors, lowered once to
HLO text by :mod:`compile.aot`. Shapes are static per artifact; the rust
side picks the right variant from the manifest.

Ops
---
``local_train_K``   K SGD steps over pre-batched local data (lax.scan) —
                    produces the model delta every compressor consumes.
``grad_batch``      one-batch gradient (tests + FedSynth target).
``syn_step``        ONE optimization step of the 3SFC encoder: gradient of
                    ``1 - |cos(∇_w F(D_syn, w), g_t)| + λ‖D_syn‖²`` wrt the
                    synthetic features (second-order autodiff through the
                    model). rust loops this S times (Algorithm 1, line 7).
``syn_grad``        decoder: ∇_w F(D_syn, w) (Eq. 10; rust applies s).
``eval_batch``      (Σ loss, #correct) over an eval batch.
``fedsynth_step``   the multi-step L2-matching baseline (FedSynth, Table 1 /
                    Figs 2–3): unrolled K_sim inner SGD on per-step synthetic
                    batches, ‖simulated Δw − g_t‖² objective, plus per-step
                    gradient norms to reproduce the Fig 3 explosion series.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels
from .models import ModelDef


def _ce_loss(model: ModelDef, w, x, y_soft):
    """Cross-entropy against soft labels (one-hot for real data)."""
    logits = model.apply(w, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(y_soft * logp, axis=-1))


def make_loss_hard(model: ModelDef):
    def loss(w, x, y):
        y1 = jax.nn.one_hot(y, model.n_classes, dtype=jnp.float32)
        return _ce_loss(model, w, x, y1)

    return loss


# ------------------------------------------------------------ local train

def make_local_train(model: ModelDef, k: int):
    """(w[P], xs[K,B,*in], ys[K,B]i32, lr) -> w' after K SGD steps."""
    loss = make_loss_hard(model)

    def step(w, batch):
        x, y = batch
        g = jax.grad(loss)(w, x, y)
        # L1 axpy kernel: w <- w - lr*g (lr closed over via carry aux)
        return w, g

    def fn(w, xs, ys, lr):
        def body(carry, batch):
            wc = carry
            x, y = batch
            g = jax.grad(loss)(wc, x, y)
            wc = kernels.axpy(-lr, g, wc)
            return wc, jnp.float32(0.0)

        w_out, _ = jax.lax.scan(body, w, (xs, ys))
        return (w_out,)

    return fn


# ------------------------------------------------------------- grad batch

def make_grad_batch(model: ModelDef):
    """(w, x[B,*in], y[B]i32) -> (g[P],)."""
    loss = make_loss_hard(model)

    def fn(w, x, y):
        return (jax.grad(loss)(w, x, y),)

    return fn


# ------------------------------------------------------- 3SFC encoder step

def _syn_objective(model: ModelDef, w, g_target, dx, dy_logits, lam):
    """Eq. 9: 1 - |cos(∇_w F(D_syn, w), g+e)| + λ‖D_syn‖²."""
    y_soft = jax.nn.softmax(dy_logits)
    g = jax.grad(_ce_loss, argnums=1)(model, w, dx, y_soft)
    cos = kernels.cosine(g, g_target)
    reg = lam * (kernels.sumsq(dx.ravel()) + kernels.sumsq(dy_logits.ravel()))
    return 1.0 - jnp.abs(cos) + reg, cos


def make_syn_step(model: ModelDef):
    """(w, g_t[P], dx[m,*in], dy[m,C], lr_syn, lam) -> (dx', dy', cos).

    One SGD step on the synthetic features. Differentiates THROUGH the
    model's gradient — all L1 kernels carry second-order-capable vjps.
    """

    def fn(w, g_target, dx, dy_logits, lr_syn, lam):
        def obj(dx_, dy_):
            v, cos = _syn_objective(model, w, g_target, dx_, dy_logits=dy_, lam=lam)
            return v, cos

        (val, cos), grads = jax.value_and_grad(obj, argnums=(0, 1), has_aux=True)(
            dx, dy_logits
        )
        gdx, gdy = grads
        dx2 = dx - lr_syn * gdx
        dy2 = dy_logits - lr_syn * gdy
        return dx2, dy2, cos

    return fn


def make_syn_opt(model: ModelDef, s_steps: int):
    """Fused 3SFC encoder: S Adam steps on the similarity objective in ONE
    dispatch (perf pass, EXPERIMENTS §Perf).

    (w, g_t[P], dx[m,*in], dy[m,C], lr_syn, lam)
        -> (dx', dy', best_dx, best_dy, best_cos, last_cos)

    Equivalent to looping the single `syn_step` artifact S times with
    host-side Adam, but avoids S× re-uploading w and g_t (2·4P bytes per
    step) and S× dispatch latency. Adam state lives in the scan carry;
    the best-|cos| iterate is tracked in-graph.
    """
    b1, b2, eps = 0.9, 0.999, 1e-8

    def fn(w, g_target, dx, dy_logits, lr_syn, lam):
        alpha = lr_syn / 50.0  # same mapping as the rust host loop

        def obj(dx_, dy_):
            v, cos = _syn_objective(model, w, g_target, dx_, dy_logits=dy_, lam=lam)
            return v, cos

        def body(carry, t):
            dx_, dy_, mx, vx, my, vy, bdx, bdy, bcos = carry
            (_, cos), (gdx, gdy) = jax.value_and_grad(
                obj, argnums=(0, 1), has_aux=True
            )(dx_, dy_)
            better = jnp.abs(cos) > bcos
            bdx = jnp.where(better, dx_, bdx)
            bdy = jnp.where(better, dy_, bdy)
            bcos = jnp.maximum(bcos, jnp.abs(cos))
            mx = b1 * mx + (1 - b1) * gdx
            vx = b2 * vx + (1 - b2) * gdx * gdx
            my = b1 * my + (1 - b1) * gdy
            vy = b2 * vy + (1 - b2) * gdy * gdy
            tf = t.astype(jnp.float32) + 1.0
            cx = mx / (1 - b1**tf)
            cvx = vx / (1 - b2**tf)
            cy = my / (1 - b1**tf)
            cvy = vy / (1 - b2**tf)
            dx_ = dx_ - alpha * cx / (jnp.sqrt(cvx) + eps)
            dy_ = dy_ - alpha * cy / (jnp.sqrt(cvy) + eps)
            return (dx_, dy_, mx, vx, my, vy, bdx, bdy, bcos), cos

        z = jnp.zeros_like
        carry0 = (dx, dy_logits, z(dx), z(dx), z(dy_logits), z(dy_logits),
                  dx, dy_logits, jnp.float32(-1.0))
        carry, coses = jax.lax.scan(body, carry0, jnp.arange(s_steps))
        dx_f, dy_f, _, _, _, _, bdx, bdy, bcos = carry
        return dx_f, dy_f, bdx, bdy, bcos, coses[-1]

    return fn


def make_syn_grad(model: ModelDef):
    """Decoder / finalizer: (w, dx, dy) -> (∇_w F(D_syn, w),)."""

    def fn(w, dx, dy_logits):
        y_soft = jax.nn.softmax(dy_logits)
        return (jax.grad(_ce_loss, argnums=1)(model, w, dx, y_soft),)

    return fn


# ------------------------------------------------------------------- eval

def make_eval_batch(model: ModelDef):
    """(w, x[B,*in], y[B]i32) -> (Σ loss, #correct) both f32."""

    def fn(w, x, y):
        logits = model.apply(w, x)
        logp = jax.nn.log_softmax(logits)
        y1 = jax.nn.one_hot(y, model.n_classes, dtype=jnp.float32)
        losses = -jnp.sum(y1 * logp, axis=-1)
        correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        return jnp.sum(losses), correct

    return fn


def make_fedsynth_apply(model: ModelDef, k_sim: int):
    """FedSynth decoder: (w, dxs[K,m,*in], dys[K,m,C], lr_inner) -> (Δw,).

    Replays the K_sim-step inner simulation on the synthetic batches and
    returns the simulated model delta ``w - w_K`` (the server's
    reconstruction of the client's accumulated gradient).
    """

    def fn(w, dxs, dys, lr_inner):
        wc = w
        for j in range(k_sim):
            y_soft = jax.nn.softmax(dys[j])
            g = jax.grad(_ce_loss, argnums=1)(model, wc, dxs[j], y_soft)
            wc = kernels.axpy(-lr_inner, g, wc)
        return (w - wc,)

    return fn


# -------------------------------------------------- FedSynth baseline step

def make_fedsynth_step(model: ModelDef, k_sim: int):
    """Multi-step L2 distillation baseline (the one that collapses).

    (w, g_t, dxs[K,m,*in], dys[K,m,C], lr_inner, lr_syn)
        -> (dxs', dys', fit, norms[K])

    Simulates K_sim inner SGD steps, each on its own synthetic batch
    (matching FedSynth's per-step distilled batches), minimizes
    ‖(w - w_K) - g_t‖², and reports ‖∂fit/∂dxs[j]‖ per step j — the Fig 3
    gradient-explosion series.
    """

    def fit(dxs, dys):
        wc = None
        wc = w_holder[0]
        for j in range(k_sim):
            y_soft = jax.nn.softmax(dys[j])
            g = jax.grad(_ce_loss, argnums=1)(model, wc, dxs[j], y_soft)
            wc = wc - lr_holder[0] * g
        delta = w_holder[0] - wc
        return kernels.sumsq(delta - g_holder[0])

    # Holders let us keep `fit` a function of (dxs, dys) only; rebound per call.
    w_holder, g_holder, lr_holder = [None], [None], [None]

    def fn(w, g_target, dxs, dys, lr_inner, lr_syn):
        w_holder[0] = w
        g_holder[0] = g_target
        lr_holder[0] = lr_inner
        val, grads = jax.value_and_grad(fit, argnums=(0, 1))(dxs, dys)
        gdx, gdy = grads
        # Per-step gradient magnitude wrt the step's synthetic batch (Fig 3).
        norms = jnp.sqrt(jnp.sum(gdx.reshape(k_sim, -1) ** 2, axis=-1))
        dxs2 = dxs - lr_syn * gdx
        dys2 = dys - lr_syn * gdy
        return dxs2, dys2, val, norms

    return fn
