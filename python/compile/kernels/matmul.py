"""L1 Pallas kernel: tiled matrix multiply.

The dense layers of every model in this repo go through :func:`matmul`
instead of ``jnp.dot`` so that the hot path is an explicitly tiled kernel.

TPU mapping (see DESIGN.md §8): the grid walks (M/bm, N/bn) output tiles and
streams the full K dimension through VMEM per tile; ``bm``/``bn`` default to
the MXU-native 128. On this image the kernel runs with ``interpret=True``
(CPU PJRT cannot execute Mosaic custom-calls) — correctness is validated
against the pure-jnp oracle in :mod:`compile.kernels.ref`, TPU efficiency is
estimated analytically in EXPERIMENTS.md §Perf.

Differentiation: :func:`matmul` carries a ``jax.custom_vjp`` whose backward
pass is built from :func:`matmul` itself (on transposes), so it is
differentiable to arbitrary order — the 3SFC encoder needs second-order
(gradient of a gradient) and this is where that bottoms out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile. Shrunk automatically for small operands.
_BM = 128
_BN = 128
# Lane-aligned K padding (TPU VPU lanes = 128, sublanes = 8).
_KALIGN = 8


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _mm_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: full-K contraction held in VMEM."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def _matmul_pallas(x: jax.Array, w: jax.Array, bm: int, bn: int) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, _KALIGN)
    xq = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wq = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out = pl.pallas_call(
        _mm_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((kp, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xq, wq)
    return out[:m, :n]


def _pick_block(dim: int, pref: int) -> int:
    """Largest TPU-plausible tile ≤ pref covering `dim` (multiple of 8)."""
    if dim >= pref:
        return pref
    return max(8, _ceil_to(dim, 8))


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """``x @ w`` via the tiled Pallas kernel. f32 in, f32 out."""
    bm = _pick_block(x.shape[0], _BM)
    bn = _pick_block(w.shape[1], _BN)
    return _matmul_pallas(x, w, bm, bn)


def _matmul_fwd(x, w):
    return matmul(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    # Backward is two more tiled matmuls — recursively differentiable,
    # which is what lets the 3SFC encoder take grad-of-grad through the
    # model's dense layers.
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
