"""L1 Pallas kernels: fused flat-vector reductions.

The 3SFC encoder's objective is built on ``cos(a, b)`` over *flattened
parameter-sized* vectors (P can be hundreds of thousands of floats). Three
separate reductions (a·b, ‖a‖², ‖b‖²) would read HBM three times; the paper's
CUDA implementation fuses them, and so do we: :func:`dot3` streams both
vectors once through VMEM in lane-aligned chunks and accumulates all three
scalars in a single pass.

``interpret=True`` (CPU PJRT); the grid is sequential in interpret mode so
the read-modify-write accumulation into the (1, 3) output block is exact.

Both kernels carry ``custom_vjp`` rules whose backward passes are plain
elementwise expressions — differentiable again, which the encoder's
second-order objective requires.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Chunk of the flat vector staged into VMEM per grid step: 8 sublanes x 128
# lanes x 32 = 32768 f32 = 128 KiB per operand — comfortably inside the
# ~16 MiB VMEM budget together with double-buffering.
_CHUNK = 32768


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _dot3_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    o_ref[0, 0] += jnp.sum(a * b)
    o_ref[0, 1] += jnp.sum(a * a)
    o_ref[0, 2] += jnp.sum(b * b)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _dot3_pallas(a: jax.Array, b: jax.Array, chunk: int):
    n = a.shape[0]
    npad = _ceil_to(max(n, 1), chunk)
    aq = jnp.pad(a, (0, npad - n)).reshape(npad // chunk, chunk)
    bq = jnp.pad(b, (0, npad - n)).reshape(npad // chunk, chunk)
    out = pl.pallas_call(
        _dot3_kernel,
        grid=(npad // chunk,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 3), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 3), jnp.float32),
        interpret=True,
    )(aq, bq)
    return out[0, 0], out[0, 1], out[0, 2]


@jax.custom_vjp
def dot3(a: jax.Array, b: jax.Array):
    """Fused single-pass ``(a·b, ‖a‖², ‖b‖²)`` over flat f32 vectors."""
    chunk = min(_CHUNK, _ceil_to(max(a.shape[0], 1), 128))
    return _dot3_pallas(a, b, chunk)


def _dot3_fwd(a, b):
    return dot3(a, b), (a, b)


def _dot3_bwd(res, cts):
    a, b = res
    gd, gna, gnb = cts
    da = gd * b + 2.0 * gna * a
    db = gd * a + 2.0 * gnb * b
    return da, db


dot3.defvjp(_dot3_fwd, _dot3_bwd)


def _sumsq_kernel(a_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    o_ref[0, 0] += jnp.sum(a * a)


@functools.partial(jax.jit, static_argnames=("chunk",))
def _sumsq_pallas(a: jax.Array, chunk: int):
    n = a.shape[0]
    npad = _ceil_to(max(n, 1), chunk)
    aq = jnp.pad(a, (0, npad - n)).reshape(npad // chunk, chunk)
    out = pl.pallas_call(
        _sumsq_kernel,
        grid=(npad // chunk,),
        in_specs=[pl.BlockSpec((1, chunk), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(aq)
    return out[0, 0]


@jax.custom_vjp
def sumsq(a: jax.Array):
    """``‖a‖²`` over a flat f32 vector, single VMEM pass."""
    chunk = min(_CHUNK, _ceil_to(max(a.shape[0], 1), 128))
    return _sumsq_pallas(a, chunk)


def _sumsq_fwd(a):
    return sumsq(a), (a,)


def _sumsq_bwd(res, ct):
    (a,) = res
    return (2.0 * ct * a,)


sumsq.defvjp(_sumsq_fwd, _sumsq_bwd)


def cosine(a: jax.Array, b: jax.Array, eps: float = 1e-12):
    """Cosine similarity of two flat vectors via the fused reduction."""
    d, na2, nb2 = dot3(a, b)
    return d * jax.lax.rsqrt(na2 * nb2 + eps)
