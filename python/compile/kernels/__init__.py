"""L1: Pallas kernels for the 3SFC compute hot-spots.

Exports the tiled/fused kernels used by the L2 fed-ops. All kernels run
``interpret=True`` (CPU PJRT) and carry ``custom_vjp`` rules built from the
same kernels, so the encoder's second-order objective differentiates cleanly.
"""

from .elementwise import axpy, scale
from .matmul import matmul
from .reduce import cosine, dot3, sumsq

__all__ = ["axpy", "scale", "matmul", "cosine", "dot3", "sumsq"]
