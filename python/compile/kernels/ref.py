"""Pure-jnp oracles for every L1 kernel.

These are the correctness ground truth: pytest asserts each Pallas kernel
(and its first/second-order gradients) matches the oracle to f32 tolerance
across hypothesis-driven shape sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def dot3(a: jax.Array, b: jax.Array):
    return jnp.sum(a * b), jnp.sum(a * a), jnp.sum(b * b)


def sumsq(a: jax.Array):
    return jnp.sum(a * a)


def axpy(alpha: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    return y + alpha * x


def scale(s: jax.Array, x: jax.Array) -> jax.Array:
    return s * x


def cosine(a: jax.Array, b: jax.Array, eps: float = 1e-12):
    return jnp.sum(a * b) * jax.lax.rsqrt(jnp.sum(a * a) * jnp.sum(b * b) + eps)
