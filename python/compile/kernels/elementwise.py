"""L1 Pallas kernels: elementwise flat-vector updates.

:func:`axpy` (``y + alpha * x``) is the SGD/error-feedback workhorse — every
local training step, every EF accumulation, and the decoder's ``s * g`` scale
are this shape. One streaming VMEM pass, lane-aligned chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .reduce import dot3

_CHUNK = 32768


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _axpy_kernel(alpha_ref, x_ref, y_ref, o_ref):
    o_ref[...] = y_ref[...] + alpha_ref[0, 0] * x_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk",))
def _axpy_pallas(alpha: jax.Array, x: jax.Array, y: jax.Array, chunk: int):
    n = x.shape[0]
    npad = _ceil_to(max(n, 1), chunk)
    xq = jnp.pad(x, (0, npad - n)).reshape(npad // chunk, chunk)
    yq = jnp.pad(y, (0, npad - n)).reshape(npad // chunk, chunk)
    aq = jnp.reshape(alpha.astype(jnp.float32), (1, 1))
    out = pl.pallas_call(
        _axpy_kernel,
        grid=(npad // chunk,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((npad // chunk, chunk), jnp.float32),
        interpret=True,
    )(aq, xq, yq)
    return out.reshape(npad)[:n]


@jax.custom_vjp
def axpy(alpha: jax.Array, x: jax.Array, y: jax.Array) -> jax.Array:
    """``y + alpha * x`` over flat f32 vectors (alpha is a scalar)."""
    chunk = min(_CHUNK, _ceil_to(max(x.shape[0], 1), 128))
    return _axpy_pallas(alpha, x, y, chunk)


def _axpy_fwd(alpha, x, y):
    return axpy(alpha, x, y), (alpha, x)


def _axpy_bwd(res, g):
    alpha, x = res
    d, _, _ = dot3(g, x)          # dα = <g, x> (fused kernel, differentiable)
    return d, alpha * g, g


axpy.defvjp(_axpy_fwd, _axpy_bwd)


def scale(s: jax.Array, x: jax.Array) -> jax.Array:
    """``s * x`` as axpy against a zero vector (keeps one code path hot)."""
    return axpy(s, x, jnp.zeros_like(x))
