"""L2 model zoo: shapes, packing, gradient flow, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import fedops, models


@pytest.mark.parametrize("name", [m.name for m in models.ALL_MODELS])
def test_apply_shapes(name):
    md = models.get(name)
    w = jnp.array(md.init(0))
    assert w.shape == (md.n_params,)
    x = jax.random.normal(jax.random.PRNGKey(0), (3,) + md.input_shape)
    logits = md.apply(w, x)
    assert logits.shape == (3, md.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", [m.name for m in models.ALL_MODELS])
def test_unpack_roundtrip(name):
    md = models.get(name)
    w = jnp.arange(md.n_params, dtype=jnp.float32)
    parts = md.unpack(w)
    flat = jnp.concatenate([p.ravel() for p in parts])
    np.testing.assert_array_equal(flat, w)
    assert sum(int(np.prod(p.shape)) for p in md.params) == md.n_params


def test_init_deterministic_and_biases_zero():
    md = models.get("mlp10")
    a, b = md.init(0), md.init(0)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, md.init(1))
    # biases (b1) start at zero
    off = 784 * 250
    assert np.all(a[off : off + 250] == 0.0)


@pytest.mark.parametrize("name", [m.name for m in models.ALL_MODELS])
def test_gradient_flows_to_all_params(name):
    """No dead parameters: every layer receives gradient signal."""
    md = models.get(name)
    w = jnp.array(md.init(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4,) + md.input_shape)
    y = jnp.arange(4, dtype=jnp.int32) % md.n_classes
    loss = fedops.make_loss_hard(md)
    g = jax.grad(loss)(w, x, y)
    assert bool(jnp.all(jnp.isfinite(g)))
    # check per-parameter-group norms are nonzero
    off = 0
    for p in md.params:
        n = int(np.prod(p.shape))
        gn = float(jnp.linalg.norm(g[off : off + n]))
        assert gn > 0.0, f"parameter {p.name} got zero gradient"
        off += n


def test_mlp_matches_paper_scale():
    # Paper Fig 1: MLP with 199,210 params; ours is the same 784-250-10
    # architecture (198,760 — the paper likely counts a slightly different
    # hidden width; same order).
    assert models.get("mlp10").n_params == 784 * 250 + 250 + 250 * 10 + 10


def test_mlp_small_is_trainable():
    md = models.get("mlp_small")
    w = jnp.array(md.init(0))
    k = jax.random.PRNGKey(2)
    x = jax.random.normal(k, (64,) + md.input_shape)
    y = jax.random.randint(jax.random.PRNGKey(3), (64,), 0, md.n_classes)
    loss = fedops.make_loss_hard(md)
    l0 = float(loss(w, x, y))
    g = jax.grad(loss)
    for _ in range(30):
        w = w - 0.1 * g(w, x, y)
    l1 = float(loss(w, x, y))
    assert l1 < l0 * 0.7, f"{l0} -> {l1}"
