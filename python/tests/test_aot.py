"""AOT pipeline: op tables are complete and HLO text round-trips."""

import json
import os

import jax
import pytest

from compile import aot, models


def test_op_table_covers_required_ops():
    for md in models.ALL_MODELS:
        ops = {name for name, *_ in aot.build_op_table(md)}
        assert "eval" in ops
        assert any(o.startswith("train_k") for o in ops)
        for m in aot.SYN_MS:
            assert f"syn_step_m{m}" in ops
            assert f"syn_grad_m{m}" in ops


def test_fedsynth_ops_paired():
    md = models.get("mlp_small")
    ops = {name for name, *_ in aot.build_op_table(md)}
    for k in aot.FEDSYNTH_KS["mlp_small"]:
        assert f"fedsynth_k{k}_m1" in ops
        assert f"fedsynth_apply_k{k}_m1" in ops


def test_hlo_text_is_parseable_format():
    """Lower one small op and sanity-check the HLO text structure."""
    md = models.get("mlp_small")
    table = {name: (fn, specs) for name, fn, specs, _ in aot.build_op_table(md)}
    fn, specs = table["eval"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True → root is a tuple
    assert "tuple(" in text or "tuple (" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
def test_manifest_matches_registry():
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    for name, entry in manifest["models"].items():
        md = models.get(name)
        assert entry["params"] == md.n_params
        assert tuple(entry["input_shape"]) == md.input_shape
        assert entry["n_classes"] == md.n_classes
        d = os.path.dirname(path)
        for op in entry["ops"].values():
            assert os.path.exists(os.path.join(d, op["file"])), op["file"]
        init = os.path.join(d, entry["init"])
        assert os.path.getsize(init) == 4 * md.n_params
