"""Fused encoder (`syn_opt`) vs the single-step loop it replaces."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import fedops, models

MD = models.get("mlp_small")


def _target():
    w = jnp.array(MD.init(0))
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(16, 64)).astype(np.float32))
    y = jnp.array((np.arange(16) % 8).astype(np.int32))
    lt = fedops.make_local_train(MD, 5)
    (w2,) = lt(w, jnp.stack([x] * 5), jnp.stack([y] * 5), jnp.float32(0.05))
    return w, w - w2


def test_syn_opt_improves_cosine_like_host_loop():
    w, gt = _target()
    rng = np.random.default_rng(1)
    dx0 = jnp.array(rng.normal(size=(1, 64)).astype(np.float32)) * 0.5
    dy0 = jnp.zeros((1, 8))

    so = jax.jit(fedops.make_syn_opt(MD, 20))
    dxf, dyf, bdx, bdy, bcos, last_cos = so(
        w, gt, dx0, dy0, jnp.float32(5.0), jnp.float32(0.0)
    )
    assert float(bcos) > 0.2, float(bcos)
    assert np.all(np.isfinite(dxf)) and np.all(np.isfinite(dyf))

    # Host-equivalent loop: syn_step(lr=1) + Adam, identical math.
    ss = jax.jit(fedops.make_syn_step(MD))
    dx, dy = dx0, dy0
    mx = np.zeros_like(dx0)
    vx = np.zeros_like(dx0)
    my = np.zeros_like(dy0)
    vy = np.zeros_like(dy0)
    alpha, b1, b2, eps = 5.0 / 50.0, 0.9, 0.999, 1e-8
    best = -1.0
    for t in range(1, 21):
        ndx, ndy, cos = ss(w, gt, dx, dy, jnp.float32(1.0), jnp.float32(0.0))
        best = max(best, abs(float(cos)))
        gdx = np.array(dx) - np.array(ndx)
        gdy = np.array(dy) - np.array(ndy)
        mx = b1 * mx + (1 - b1) * gdx
        vx = b2 * vx + (1 - b2) * gdx * gdx
        my = b1 * my + (1 - b1) * gdy
        vy = b2 * vy + (1 - b2) * gdy * gdy
        dx = jnp.array(np.array(dx) - alpha * (mx / (1 - b1**t)) / (np.sqrt(vx / (1 - b2**t)) + eps))
        dy = jnp.array(np.array(dy) - alpha * (my / (1 - b1**t)) / (np.sqrt(vy / (1 - b2**t)) + eps))

    np.testing.assert_allclose(dxf, dx, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(dyf, dy, rtol=1e-3, atol=1e-4)
    assert abs(float(bcos) - best) < 5e-3


def test_syn_opt_best_tracking():
    w, gt = _target()
    rng = np.random.default_rng(2)
    dx0 = jnp.array(rng.normal(size=(1, 64)).astype(np.float32)) * 0.5
    dy0 = jnp.zeros((1, 8))
    so = jax.jit(fedops.make_syn_opt(MD, 10))
    _, _, bdx, bdy, bcos, _ = so(w, gt, dx0, dy0, jnp.float32(5.0), jnp.float32(0.0))
    # The best iterate must actually score bcos.
    sg = fedops.make_syn_grad(MD)
    (g,) = sg(w, bdx, bdy)
    cos = float(jnp.dot(g, gt) / (jnp.linalg.norm(g) * jnp.linalg.norm(gt)))
    assert abs(abs(cos) - float(bcos)) < 5e-3
