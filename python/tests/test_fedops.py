"""L2 fed-ops: the exact functions the rust coordinator executes.

Checks the paper's math: local_train == K explicit SGD steps, syn_step
increases |cos|, the closed-form scale (Eq. 8) minimizes the L2 error
(Eq. 7), the decoder reconstructs the encoder's gradient, and fedsynth's
per-step norms exhibit the Fig-3 backward growth.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import fedops, models

MD = models.get("mlp_small")


@pytest.fixture(scope="module")
def setup():
    w = jnp.array(MD.init(0))
    rng = np.random.default_rng(0)
    x = rng.normal(size=(16, 64)).astype(np.float32)
    y = (np.arange(16) % 8).astype(np.int32)
    return w, jnp.array(x), jnp.array(y)


def local_delta(w, x, y, k=5, lr=0.05):
    lt = fedops.make_local_train(MD, k)
    xs = jnp.stack([x] * k)
    ys = jnp.stack([y] * k)
    (w2,) = lt(w, xs, ys, jnp.float32(lr))
    return w - w2


def test_local_train_equals_manual_sgd(setup):
    w, x, y = setup
    loss = fedops.make_loss_hard(MD)
    lt = fedops.make_local_train(MD, 3)
    (w_op,) = lt(w, jnp.stack([x] * 3), jnp.stack([y] * 3), jnp.float32(0.05))
    w_manual = w
    for _ in range(3):
        w_manual = w_manual - 0.05 * jax.grad(loss)(w_manual, x, y)
    np.testing.assert_allclose(w_op, w_manual, rtol=1e-4, atol=1e-6)


def test_local_train_uses_distinct_batches(setup):
    w, x, y = setup
    lt = fedops.make_local_train(MD, 2)
    xs = jnp.stack([x, x * 0.0])  # second batch all-zero inputs
    ys = jnp.stack([y, y])
    (w2,) = lt(w, xs, ys, jnp.float32(0.05))
    # Must differ from training on x twice.
    (w_same,) = lt(w, jnp.stack([x, x]), ys, jnp.float32(0.05))
    assert not np.allclose(w2, w_same)


def test_grad_batch_is_loss_grad(setup):
    w, x, y = setup
    loss = fedops.make_loss_hard(MD)
    gb = fedops.make_grad_batch(MD)
    (g,) = gb(w, x, y)
    np.testing.assert_allclose(g, jax.grad(loss)(w, x, y), rtol=1e-4, atol=1e-6)


def test_syn_step_improves_cosine(setup):
    w, x, y = setup
    gt = local_delta(w, x, y)
    ss = jax.jit(fedops.make_syn_step(MD))
    rng = np.random.default_rng(1)
    dx = jnp.array(rng.normal(size=(1, 64)).astype(np.float32)) * 0.5
    dy = jnp.zeros((1, 8))
    first = None
    for i in range(30):
        dx, dy, cos = ss(w, gt, dx, dy, jnp.float32(5.0), jnp.float32(0.0))
        if i == 0:
            first = abs(float(cos))
    assert abs(float(cos)) > first + 0.1, f"{first} -> {float(cos)}"
    assert np.all(np.isfinite(dx)) and np.all(np.isfinite(dy))


def test_syn_step_lambda_shrinks_features(setup):
    w, x, y = setup
    gt = local_delta(w, x, y)
    ss = jax.jit(fedops.make_syn_step(MD))
    rng = np.random.default_rng(2)
    dx0 = jnp.array(rng.normal(size=(1, 64)).astype(np.float32))
    dy0 = jnp.zeros((1, 8))
    dx_noreg, dx_reg = dx0, dx0
    dy_noreg, dy_reg = dy0, dy0
    for _ in range(20):
        dx_noreg, dy_noreg, _ = ss(w, gt, dx_noreg, dy_noreg, jnp.float32(2.0), jnp.float32(0.0))
        dx_reg, dy_reg, _ = ss(w, gt, dx_reg, dy_reg, jnp.float32(2.0), jnp.float32(0.05))
    assert float(jnp.sum(dx_reg**2)) < float(jnp.sum(dx_noreg**2))


def test_optimal_scale_minimizes_l2(setup):
    """Eq. 8: s* = <g, gs>/||gs||² beats nearby scales on ||s·gs − g||²."""
    w, x, y = setup
    gt = local_delta(w, x, y)
    sg = fedops.make_syn_grad(MD)
    rng = np.random.default_rng(3)
    dx = jnp.array(rng.normal(size=(1, 64)).astype(np.float32))
    dy = jnp.array(rng.normal(size=(1, 8)).astype(np.float32))
    (gs,) = sg(w, dx, dy)
    s_star = float(jnp.dot(gt, gs) / jnp.dot(gs, gs))

    def err(s):
        return float(jnp.sum((s * gs - gt) ** 2))

    e_star = err(s_star)
    for ds in (-0.1, -0.01, 0.01, 0.1):
        assert e_star <= err(s_star * (1 + ds) + ds) + 1e-6


def test_syn_grad_matches_decoder_semantics(setup):
    """Encoder and decoder share F: same (dx, dy, w) → same gradient."""
    w, x, y = setup
    sg = fedops.make_syn_grad(MD)
    rng = np.random.default_rng(4)
    dx = jnp.array(rng.normal(size=(2, 64)).astype(np.float32))
    dy = jnp.array(rng.normal(size=(2, 8)).astype(np.float32))
    (g1,) = sg(w, dx, dy)
    (g2,) = sg(w, dx, dy)
    np.testing.assert_array_equal(g1, g2)


def test_eval_batch_counts(setup):
    w, x, y = setup
    ev = fedops.make_eval_batch(MD)
    # Build an eval batch of size 50 (the artifact batch for mlp_small).
    rng = np.random.default_rng(5)
    xl = jnp.array(rng.normal(size=(50, 64)).astype(np.float32))
    yl = jnp.array((np.arange(50) % 8).astype(np.int32))
    loss_sum, ncorrect = ev(w, xl, yl)
    logits = MD.apply(w, xl)
    want_correct = float(jnp.sum(jnp.argmax(logits, -1) == yl))
    assert float(ncorrect) == pytest.approx(want_correct)
    assert float(loss_sum) > 0.0


def test_fedsynth_apply_consistent_with_step(setup):
    """fit == ||Δw_sim − g||² where Δw_sim = fedsynth_apply output."""
    w, x, y = setup
    gt = local_delta(w, x, y)
    k = 4
    fs = fedops.make_fedsynth_step(MD, k)
    fa = fedops.make_fedsynth_apply(MD, k)
    rng = np.random.default_rng(6)
    dxs = jnp.array(rng.normal(size=(k, 1, 64)).astype(np.float32)) * 0.5
    dys = jnp.zeros((k, 1, 8))
    _, _, fit, norms = fs(w, gt, dxs, dys, jnp.float32(0.05), jnp.float32(0.0))
    (delta,) = fa(w, dxs, dys, jnp.float32(0.05))
    want = float(jnp.sum((delta - gt) ** 2))
    assert float(fit) == pytest.approx(want, rel=1e-4)
    assert norms.shape == (k,)


def test_fedsynth_step_norms_grow_backward(setup):
    """Fig 3: gradient magnitudes grow toward the first simulated step."""
    w, x, y = setup
    gt = local_delta(w, x, y, k=5, lr=0.05)
    k = 8
    fs = jax.jit(fedops.make_fedsynth_step(MD, k))
    rng = np.random.default_rng(7)
    dxs = jnp.array(rng.normal(size=(k, 1, 64)).astype(np.float32)) * 0.5
    dys = jnp.zeros((k, 1, 8))
    # use an aggressive inner lr to surface the compounding
    _, _, _, norms = fs(w, gt, dxs, dys, jnp.float32(0.5), jnp.float32(0.0))
    norms = np.array(norms)
    assert norms[0] > norms[-1], f"expected backward growth, got {norms}"
