"""L1 Pallas kernels vs the pure-jnp oracle — the core numerics signal.

Hypothesis sweeps shapes; every kernel is checked for values and for
first- AND second-order gradients (the 3SFC encoder differentiates through
a gradient, so second-order correctness is load-bearing).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ------------------------------------------------------------------ matmul

@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
)
def test_matmul_matches_ref(m, k, n):
    x = rand(1, (m, k))
    w = rand(2, (k, n))
    np.testing.assert_allclose(
        kernels.matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=1e-5
    )


@settings(max_examples=8, deadline=None)
@given(m=st.integers(2, 16), k=st.integers(2, 16), n=st.integers(2, 12))
def test_matmul_grads_match_ref(m, k, n):
    x = rand(3, (m, k))
    w = rand(4, (k, n))

    def f_ker(x, w):
        return jnp.sum(jnp.tanh(kernels.matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.tanh(ref.matmul(x, w)))

    gx_k, gw_k = jax.grad(f_ker, argnums=(0, 1))(x, w)
    gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw_k, gw_r, rtol=1e-4, atol=1e-5)


def test_matmul_large_tiles_exercise_grid():
    # > one 128x128 tile in each direction.
    x = rand(5, (300, 200))
    w = rand(6, (200, 260))
    np.testing.assert_allclose(
        kernels.matmul(x, w), ref.matmul(x, w), rtol=1e-4, atol=1e-4
    )


# ----------------------------------------------------------- dot3 / sumsq

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 100_000))
def test_dot3_matches_ref(n):
    a = rand(7, (n,))
    b = rand(8, (n,))
    got = kernels.dot3(a, b)
    want = ref.dot3(a, b)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=1e-4)


def test_dot3_grad_matches_ref():
    a = rand(9, (513,))
    b = rand(10, (513,))

    def f_ker(a, b):
        d, na, nb = kernels.dot3(a, b)
        return d * 2.0 + na - 0.5 * nb

    def f_ref(a, b):
        d, na, nb = ref.dot3(a, b)
        return d * 2.0 + na - 0.5 * nb

    ga_k, gb_k = jax.grad(f_ker, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_k, ga_r, rtol=1e-5)
    np.testing.assert_allclose(gb_k, gb_r, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 50_000))
def test_sumsq_matches_ref(n):
    a = rand(11, (n,))
    np.testing.assert_allclose(kernels.sumsq(a), ref.sumsq(a), rtol=2e-4)


# ------------------------------------------------------------------- axpy

@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 70_000), alpha=st.floats(-3, 3))
def test_axpy_matches_ref(n, alpha):
    x = rand(12, (n,))
    y = rand(13, (n,))
    np.testing.assert_allclose(
        kernels.axpy(jnp.float32(alpha), x, y),
        ref.axpy(jnp.float32(alpha), x, y),
        rtol=1e-5,
        atol=1e-6,
    )


def test_axpy_grads_match_ref():
    x = rand(14, (1000,))
    y = rand(15, (1000,))

    def f_ker(alpha, x, y):
        return kernels.sumsq(kernels.axpy(alpha, x, y))

    def f_ref(alpha, x, y):
        return ref.sumsq(ref.axpy(alpha, x, y))

    got = jax.grad(f_ker, argnums=(0, 1, 2))(jnp.float32(0.7), x, y)
    want = jax.grad(f_ref, argnums=(0, 1, 2))(jnp.float32(0.7), x, y)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=1e-4)


# ---------------------------------------------------------------- cosine

def test_cosine_identities():
    a = rand(16, (2048,))
    assert float(kernels.cosine(a, a)) == pytest.approx(1.0, abs=1e-5)
    assert float(kernels.cosine(a, -a)) == pytest.approx(-1.0, abs=1e-5)
    z = jnp.zeros_like(a)
    assert np.isfinite(float(kernels.cosine(a, z)))


def test_cosine_matches_ref():
    a = rand(17, (3001,))
    b = rand(18, (3001,))
    np.testing.assert_allclose(
        kernels.cosine(a, b), ref.cosine(a, b), rtol=1e-4
    )


# --------------------------------------------------------- second order

def test_second_order_through_kernels():
    """grad wrt data of |cos(grad_w loss, target)| — the encoder's shape."""
    x = rand(19, (6, 10))
    wv = rand(20, (10 * 4,))
    tgt = rand(21, (10 * 4,))

    def loss_k(wv, xv):
        return kernels.sumsq(kernels.matmul(xv, wv.reshape(10, 4)).ravel())

    def loss_r(wv, xv):
        return ref.sumsq(ref.matmul(xv, wv.reshape(10, 4)).ravel())

    def enc(loss):
        def inner(xv):
            g = jax.grad(loss)(wv, xv)
            return 1.0 - jnp.abs(ref.cosine(g, tgt))

        return inner

    gk = jax.grad(enc(loss_k))(x)
    gr = jax.grad(enc(loss_r))(x)
    np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-5)
