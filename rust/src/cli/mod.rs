//! Hand-rolled CLI argument parser (offline substrate for `clap`).
//!
//! Grammar: `fed3sfc <subcommand> [--key value | --key=value | --flag] ...`

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

pub mod scenarios;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse argv (excluding program name). Keys listed in `flag_names`
    /// are boolean flags; everything else starting with `--` takes a value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{rest} needs a value"))?;
                    out.options.insert(rest.to_string(), v);
                }
            } else if tok.starts_with('-') && tok.len() > 1 {
                bail!("short options not supported: {tok}");
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            argv(&["run", "--rounds", "20", "--dataset=mnist", "--verbose", "pos"]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand, "run");
        assert_eq!(a.get("rounds"), Some("20"));
        assert_eq!(a.get("dataset"), Some("mnist"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positionals, vec!["pos"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv(&["run", "--rounds"]), &[]).is_err());
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(argv(&["x", "--n", "7", "--lr", "0.5"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 1).unwrap(), 7);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_f32("lr", 0.0).unwrap(), 0.5);
        assert_eq!(a.get_f32("absent", 2.5).unwrap(), 2.5);
        assert_eq!(a.get_usize("absent", 3).unwrap(), 3);
        assert!(a.get_usize("lr", 1).is_err());
    }
}
