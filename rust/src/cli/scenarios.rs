//! Adversarial-reality scenario pack: the `bench` and `report`
//! subcommands.
//!
//! Each `bench` scenario is a small, fully deterministic harness over the
//! protocol layer — no training, no wall clock, no thread pool — so its
//! stdout is byte-stable across machines and is pinned by the snapshot
//! tests in `tests/cli_snapshot_test.rs`:
//!
//! * `bench byzantine` — fires one of every malformed upload envelope at
//!   a live [`FedServer`] and tabulates the typed rejections
//!   ([`crate::coordinator::UploadError`]); then runs the content-attack
//!   defense matrix: every `[faults]` byzantine mode × every
//!   [`crate::coordinator::RobustAggregator`] on a draw-free toy
//!   quadratic, reporting final distance to the honest optimum and what
//!   each estimator rejected or trimmed.
//! * `bench faults` — replays *one* fault stream (same seed, same
//!   dropout draws) through all three aggregation policies: deadline and
//!   async absorb the losses, the synchronous barrier fails with its
//!   diagnostic.
//! * `bench tiers` — prints the correlated device-class fate table a
//!   `[faults]` config draws (tier → bandwidth × compute × reliability).
//! * `bench new` — emits a ready-to-run `[faults]`+`[defense]` TOML
//!   preset (self-validated through [`ExperimentConfig::from_toml_str`]).
//! * `bench scale` — drives the `[scale]` machinery (lazy
//!   [`crate::coordinator::ClientStore`] + sharded
//!   [`crate::coordinator::EdgeAggregator`]) over disjoint cohorts of a
//!   large synthetic fleet: per-round shard occupancy and spill
//!   accounting, a drain-order invariance check across shard counts, a
//!   spill round-trip bit-exactness count, and an eager-store contrast.
//!   `--measure` adds wall-clock rounds/s and peak RSS (deliberately
//!   excluded from the snapshot golden: timing is machine-local).
//!
//! `report` summarizes a metrics JSONL file written by `run --metrics`,
//! rendering the ledger's NaN no-data sentinels (serialized as JSON
//! `null`) as `-` instead of a misleading zero.

use anyhow::{anyhow, bail, Context, Result};

use crate::bench::{fmt_bytes_opt, peak_rss_bytes, time_it};
use crate::cli::Args;
use crate::compress::{DenseDownlink, Payload};
use crate::config::{ExperimentConfig, SpillKind};
use crate::coordinator::{
    AggregationPolicy, BufferedAsync, ClientMsg, ClientStore, CoordinateMedian,
    Deadline, Directive, EdgeAggregator, FedServer, FullParticipation, MultiKrum,
    NormClip, RobustAggregator, Server, ServerMsg, Synchronous, TrimmedMean, Upload,
    WeightedMean,
};
use crate::simnet::{ByzantineMode, FaultLayer, FaultsConfig, NetworkModel};
use crate::util::json::{parse as parse_json, Value};
use crate::util::rng::{stream, Rng};

/// Every scenario RNG descends from here.
fn scenario_rng(seed: u64) -> Rng {
    // detlint: allow(DET003) -- CLI seed plumbing: scenario harnesses
    // rebuild their root from an explicit seed, exactly like `run`.
    Rng::new(seed)
}

/// The bench scenario registry. `cmd_bench` dispatches over this one
/// table *and* enumerates it in the unknown-scenario diagnostic, so a
/// new scenario can never be missing from the error message.
const SCENARIOS: &[(&str, fn(&Args) -> Result<String>)] = &[
    ("byzantine", bench_byzantine),
    ("faults", bench_faults),
    ("tiers", bench_tiers),
    ("new", bench_new),
    ("scale", bench_scale),
];

pub fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positionals.first().map(|s| s.as_str()).unwrap_or("");
    let Some((_, scenario)) = SCENARIOS.iter().find(|(name, _)| *name == which) else {
        let names: Vec<&str> = SCENARIOS.iter().map(|(name, _)| *name).collect();
        bail!("unknown bench scenario '{which}' (valid: {})", names.join("|"));
    };
    print!("{}", scenario(args)?);
    Ok(())
}

fn sign_payload() -> Payload {
    Payload::Sign { n: 8, bits: vec![0u8], scale: 1.0 }
}

fn envelope(
    client: usize,
    round: usize,
    sent_at: f64,
    recon: Vec<f32>,
    weight: f32,
    payload: Payload,
) -> ClientMsg {
    ClientMsg::Upload(Upload {
        client,
        round,
        sent_at,
        payload,
        recon,
        weight,
        efficiency: 1.0,
        ratio: 32.0,
    })
}

fn bench_byzantine(args: &Args) -> Result<String> {
    // 3 clients on identical custom links (1 Mbps up / 10 Mbps down /
    // 25 ms), client 2 idle (zero samples): its envelope has no
    // broadcast to answer. P = 4 model, synchronous barrier.
    let links =
        NetworkModel::custom(1.0, 10.0, 25.0).client_links(3, 0.0, &mut scenario_rng(1));
    let mut fed = FedServer::new(
        Server::new(vec![0.0f32; 4]),
        Box::new(FullParticipation),
        Box::new(Synchronous),
        links,
        vec![true, true, false],
        4,
    );
    let mut dl = DenseDownlink::new();
    let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl)? else {
        bail!("expected the opening dispatch");
    };
    let t0 = bcasts[0].recv_at;
    let good = || vec![0.1f32; 4];

    let mut out = String::new();
    out.push_str("fed3sfc bench byzantine — upload-envelope validation at the server boundary\n");
    out.push_str("fleet 3 (client 2 idle), model P=4, links 1/10 Mbps 25 ms, policy sync\n\n");
    out.push_str(&format!("{:<22}  {:<8}  {}\n", "probe", "verdict", "server says"));
    out.push_str(&format!("{:-<22}  {:-<8}  {:-<11}\n", "", "", ""));
    let mut rows = String::new();
    let mut probe = |fed: &mut FedServer, name: &str, msg: ClientMsg| {
        let cell = match fed.submit_upload(msg) {
            Ok(ServerMsg::Ack(a)) => ("accepted", format!("ack, lands at t={:.6}s", a.recv_at)),
            Ok(other) => ("accepted", format!("{other:?}")),
            Err(e) => ("rejected", format!("{e}")),
        };
        rows.push_str(&format!("{:<22}  {:<8}  {}\n", name, cell.0, cell.1));
    };

    probe(&mut fed, "future round", envelope(0, 7, t0, good(), 1.0, sign_payload()));
    probe(&mut fed, "short recon", envelope(0, 0, t0, vec![0.1; 3], 1.0, sign_payload()));
    probe(
        &mut fed,
        "NaN recon",
        envelope(0, 0, t0, vec![0.1, 0.1, f32::NAN, 0.1], 1.0, sign_payload()),
    );
    probe(&mut fed, "infinite weight", envelope(0, 0, t0, good(), f32::INFINITY, sign_payload()));
    probe(&mut fed, "negative weight", envelope(0, 0, t0, good(), -2.0, sign_payload()));
    probe(
        &mut fed,
        "lying sign header",
        envelope(0, 0, t0, good(), 1.0, Payload::Sign { n: 8, bits: vec![], scale: 1.0 }),
    );
    probe(
        &mut fed,
        "non-finite scale",
        envelope(0, 0, t0, good(), 1.0, Payload::Sign { n: 8, bits: vec![0u8], scale: f32::NAN }),
    );
    probe(&mut fed, "time travel", envelope(0, 0, -1.0, good(), 1.0, sign_payload()));
    probe(&mut fed, "unknown client", envelope(9, 0, t0, good(), 1.0, sign_payload()));
    probe(&mut fed, "idle client", envelope(2, 0, t0, good(), 1.0, sign_payload()));
    probe(&mut fed, "honest envelope", envelope(0, 0, t0, good(), 1.0, sign_payload()));
    probe(&mut fed, "duplicate", envelope(0, 0, t0, good(), 1.0, sign_payload()));
    out.push_str(&rows);

    // The rejections left no residue: the barrier completes on the two
    // honest envelopes alone.
    let t1 = bcasts[1].recv_at;
    fed.submit_upload(envelope(1, 0, t1, good(), 1.0, sign_payload()))?;
    let Directive::Step(s) = fed.next_directive(&mut dl)? else {
        bail!("expected the barrier step");
    };
    out.push_str(&format!(
        "\nbarrier step: round {}, clients {:?}, t={:.6}s, w[0]={:.4}\n",
        s.round, s.clients, s.sim_time_s, fed.server.w[0]
    ));
    out.push('\n');
    out.push_str(&defense_matrix(args)?);
    Ok(out)
}

/// One defense-matrix cell: final distance to the honest optimum plus
/// the last step's detection counters.
struct MatrixCell {
    loss: f64,
    rejected: usize,
    trim_frac: f64,
}

/// Drive one (attack, aggregator) pair over the toy quadratic: client
/// `i` pulls toward its own target, compromised recons pass through the
/// real [`FaultLayer::corrupt`], the estimator's aggregate is applied by
/// unit-lr GD. Fully draw-free for the non-gaussian modes, so the cell
/// is a pure function of `(n, frac, mode, aggregator)`.
fn defense_cell(
    n: usize,
    seed: u64,
    frac: f64,
    mode: ByzantineMode,
    agg: &dyn RobustAggregator,
) -> MatrixCell {
    const P: usize = 8;
    const ROUNDS: usize = 20;
    const GAIN: f32 = 0.6;
    let fcfg = FaultsConfig {
        enabled: true,
        byzantine_frac: frac,
        byzantine_mode: mode,
        ..FaultsConfig::default()
    };
    let mut layer = FaultLayer::new(&fcfg, n, scenario_rng(seed).split(stream::FAULTS));
    // Heterogeneous targets: a shared ramp over the coordinates plus a
    // per-client offset, so estimators that pick *one* contribution
    // (Krum) still land near — not exactly on — the honest mean.
    let mid = 0.5f32 * (n as f32 - 1.0);
    let targets: Vec<Vec<f32>> = (0..n)
        .map(|i| {
            let off = 0.05f32 * (i as f32 - mid);
            (0..P).map(|j| 0.1f32 * (j as f32 + 1.0) + off).collect()
        })
        .collect();
    // Attackers are the top client indices; the honest optimum is the
    // mean target of everyone else.
    let honest = n - layer.byzantine_count();
    let mut tbar = vec![0.0f64; P];
    for t in targets.iter().take(honest) {
        for (s, &v) in tbar.iter_mut().zip(t.iter()) {
            *s += v as f64;
        }
    }
    for s in tbar.iter_mut() {
        *s /= honest as f64;
    }

    let clients: Vec<usize> = (0..n).collect();
    let weights = vec![1.0f32; n];
    let mut w = vec![0.0f32; P];
    let mut cell = MatrixCell { loss: 0.0, rejected: 0, trim_frac: 0.0 };
    for _ in 0..ROUNDS {
        let mut recons: Vec<Vec<f32>> = (0..n)
            .map(|i| (0..P).map(|j| GAIN * (w[j] - targets[i][j])).collect())
            .collect();
        for (c, recon) in recons.iter_mut().enumerate() {
            layer.corrupt(c, recon);
        }
        let out = agg.aggregate(&clients, &recons, &weights, P);
        if let Some(u) = &out.update {
            for (wj, uj) in w.iter_mut().zip(u.iter()) {
                *wj -= uj;
            }
        }
        cell.rejected = out.rejected.len();
        cell.trim_frac = out.trim_frac;
    }
    let mut l2 = 0.0f64;
    for (wj, tj) in w.iter().zip(tbar.iter()) {
        let d = *wj as f64 - tj;
        l2 += d * d;
    }
    cell.loss = l2.sqrt();
    cell
}

fn defense_matrix(args: &Args) -> Result<String> {
    let n = args.get_usize("clients", 10)?;
    let seed = args.get_u64("seed", 1)?;
    if n < 4 {
        bail!("the defense matrix needs at least 4 clients, got {n}");
    }
    let frac = 0.3;
    let krum_f = ((frac * n as f64).round() as usize).max(1);
    let attacks: [(&str, f64, ByzantineMode); 4] = [
        ("none", 0.0, ByzantineMode::SignFlip),
        ("sign_flip", frac, ByzantineMode::SignFlip),
        ("scale_amplify", frac, ByzantineMode::ScaleAmplify),
        ("collude", frac, ByzantineMode::Collude),
    ];
    let defenses: Vec<(&str, Box<dyn RobustAggregator>)> = vec![
        ("weighted_mean", Box::new(WeightedMean)),
        ("trimmed_mean", Box::new(TrimmedMean { beta: 0.3 })),
        ("coordinate_median", Box::new(CoordinateMedian)),
        ("krum", Box::new(MultiKrum { f: krum_f, m: 1 })),
        ("norm_clip", Box::new(NormClip { tau: 1.0 })),
    ];

    let cells: Vec<Vec<MatrixCell>> = attacks
        .iter()
        .map(|&(_, f, mode)| {
            defenses
                .iter()
                .map(|(_, agg)| defense_cell(n, seed, f, mode, agg.as_ref()))
                .collect()
        })
        .collect();

    let mut out = String::new();
    out.push_str(&format!(
        "defense matrix — toy quadratic, fleet {n}, P=8, 20 rounds, gain 0.6, unit-lr GD\n"
    ));
    out.push_str(&format!(
        "attackers: byzantine_frac 0.3 (top client indices); defenses: trim_beta 0.3, \
         krum_f {krum_f}, clip_tau 1.0\n(gaussian_noise omitted: the one draw-consuming \
         mode; this table stays draw-free)\n\n",
    ));
    out.push_str("final ‖w − honest-target mean‖ (lower is better):\n");
    out.push_str(&format!("{:<13}", "attack"));
    for (name, _) in &defenses {
        out.push_str(&format!("  {name:>17}"));
    }
    out.push('\n');
    for (row, &(attack, _, _)) in cells.iter().zip(attacks.iter()) {
        out.push_str(&format!("{attack:<13}"));
        for cell in row {
            out.push_str(&format!("  {:>17.4}", cell.loss));
        }
        out.push('\n');
    }
    out.push_str("\nlast-step detection, rejected clients / trimmed influence:\n");
    out.push_str(&format!("{:<13}", "attack"));
    for (name, _) in &defenses {
        out.push_str(&format!("  {name:>17}"));
    }
    out.push('\n');
    for (row, &(attack, _, _)) in cells.iter().zip(attacks.iter()) {
        out.push_str(&format!("{attack:<13}"));
        for cell in row {
            let det = format!("{}/{:.2}", cell.rejected, cell.trim_frac);
            out.push_str(&format!("  {det:>17}"));
        }
        out.push('\n');
    }
    Ok(out)
}

/// One row of the `bench faults` table.
struct SessionRow {
    kind: &'static str,
    steps: usize,
    aggregated: usize,
    lost: u64,
    recovered: u64,
    round: usize,
    sim_time_s: f64,
}

/// Drive one fabricated-upload session under the shared fault stream
/// until `target_steps` aggregations complete.
fn drive_session(policy: Box<dyn AggregationPolicy>, target_steps: usize) -> Result<SessionRow> {
    let n = 4;
    let kind = policy.name();
    let links =
        NetworkModel::custom(1.0, 10.0, 25.0).client_links(n, 0.0, &mut scenario_rng(7));
    let fcfg = FaultsConfig {
        enabled: true,
        dropout_p: 0.25,
        recover_s: 2.0,
        ..FaultsConfig::default()
    };
    let faults = FaultLayer::new(&fcfg, n, scenario_rng(7).split(stream::FAULTS));
    let mut fed = FedServer::with_faults(
        Server::new(vec![0.0f32]),
        Box::new(FullParticipation),
        policy,
        links,
        vec![true; n],
        1,
        faults,
    );
    let mut dl = DenseDownlink::new();
    let (mut steps, mut aggregated, mut round) = (0usize, 0usize, 0usize);
    let mut sim_time_s = 0.0;
    let mut pumps = 0usize;
    while steps < target_steps {
        pumps += 1;
        if pumps > 10_000 {
            bail!("scenario runaway: {kind} did not reach {target_steps} steps");
        }
        match fed.next_directive(&mut dl)? {
            Directive::Dispatch(bcasts) => {
                for bc in &bcasts {
                    // Dropped replies are the point of the scenario;
                    // everything else must ack.
                    fed.submit_upload(envelope(
                        bc.client,
                        bc.round,
                        bc.recv_at,
                        vec![0.1],
                        1.0,
                        sign_payload(),
                    ))?;
                }
            }
            Directive::Step(s) => {
                steps += 1;
                aggregated += s.clients.len();
                round = s.round;
                sim_time_s = s.sim_time_s;
            }
        }
    }
    Ok(SessionRow {
        kind,
        steps,
        aggregated,
        lost: fed.lost_uploads(),
        recovered: fed.recovered_clients(),
        round,
        sim_time_s,
    })
}

fn bench_faults(_args: &Args) -> Result<String> {
    let mut out = String::new();
    out.push_str("fed3sfc bench faults — one fault stream, three aggregation policies\n");
    out.push_str(
        "fleet 4, links 1/10 Mbps 25 ms, dropout_p 0.25, recover_s 2.0, seed 7, 6 steps\n\n",
    );
    out.push_str(&format!(
        "{:<9}  {:>5}  {:>10}  {:>4}  {:>9}  {:>5}  {:>9}\n",
        "session", "steps", "aggregated", "lost", "recovered", "round", "sim_s"
    ));
    for policy in [
        Box::new(Deadline::new(0.5, 0.5)) as Box<dyn AggregationPolicy>,
        Box::new(BufferedAsync::new(2, 0.5)),
    ] {
        let r = drive_session(policy, 6)?;
        out.push_str(&format!(
            "{:<9}  {:>5}  {:>10}  {:>4}  {:>9}  {:>5}  {:>9.3}\n",
            r.kind, r.steps, r.aggregated, r.lost, r.recovered, r.round, r.sim_time_s
        ));
    }
    // The same stream under a barrier: the first doomed upload is a
    // diagnostic error, not a hang.
    match drive_session(Box::new(Synchronous), 6) {
        Ok(_) => bail!("sync session unexpectedly survived certain dropouts"),
        Err(e) => out.push_str(&format!("\nsync: failed as designed — {e}\n")),
    }
    Ok(out)
}

fn bench_tiers(args: &Args) -> Result<String> {
    let n = args.get_usize("clients", 8)?;
    let seed = args.get_u64("seed", 11)?;
    let fcfg = FaultsConfig {
        enabled: true,
        dropout_p: args.get_f64("dropout-p", 0.1)?,
        tiers: args.get_usize("tiers", 4)?,
        tier_spread: args.get_f64("tier-spread", 0.8)?,
        tier_compute_s: args.get_f64("tier-compute-s", 0.1)?,
        ..FaultsConfig::default()
    };
    let layer = FaultLayer::new(&fcfg, n, scenario_rng(seed).split(stream::FAULTS));
    let mut links = NetworkModel::edge().client_links(n, 0.0, &mut scenario_rng(seed));
    layer.scale_links(&mut links);
    let mut out = String::new();
    out.push_str("fed3sfc bench tiers — correlated device-class fates\n");
    out.push_str(&format!(
        "fleet {n}, {} tiers, spread {}, compute_s {}, dropout_p {}, seed {seed}, edge links\n\n",
        fcfg.tiers, fcfg.tier_spread, fcfg.tier_compute_s, fcfg.dropout_p
    ));
    out.push_str(&format!(
        "{:>6}  {:>4}  {:>7}  {:>9}  {:>8}  {:>6}  {:>7}  {:>9}\n",
        "client", "tier", "bw_mult", "compute_s", "rel_mult", "loss_p", "up_mbps", "down_mbps"
    ));
    let mut per_tier = vec![0usize; fcfg.tiers];
    for (c, (fate, link)) in layer.fates().iter().zip(&links).enumerate() {
        per_tier[fate.tier] += 1;
        out.push_str(&format!(
            "{:>6}  {:>4}  {:>7.3}  {:>9.3}  {:>8.3}  {:>6.3}  {:>7.2}  {:>9.2}\n",
            c,
            fate.tier,
            fate.bw_mult,
            fate.compute_s,
            fate.rel_mult,
            layer.loss_probability(c, 0.0),
            link.up_bps / 1e6,
            link.down_bps / 1e6,
        ));
    }
    let counts: Vec<String> =
        per_tier.iter().enumerate().map(|(t, k)| format!("tier {t}: {k}")).collect();
    out.push_str(&format!("\n{}\n", counts.join(", ")));
    Ok(out)
}

/// The preset `bench new` emits — kept in sync with the `[faults]` and
/// `[defense]` config tables by the self-validation below and the
/// snapshot test.
const FAULTS_PRESET: &str = "\
# fed3sfc adversarial-reality preset: a deadline session that tolerates
# mid-round dropouts, crash windows, a diurnal outage wave, three
# correlated device-class tiers and a sign-flipping byzantine minority —
# defended by a trimmed mean plus reliability quarantine. Run with:
#   fed3sfc run --config faults.toml
clients = 8
rounds = 10

[session]
kind = \"deadline\"
deadline_s = 0.5
staleness_decay = 0.5

[network]
kind = \"edge\"

[faults]
enabled = true
dropout_p = 0.15
recover_s = 2.0
diurnal_amp = 0.3
diurnal_period_s = 600.0
tiers = 3
tier_spread = 0.6
tier_compute_s = 0.05
byzantine_frac = 0.25
byzantine_mode = \"sign_flip\"

[defense]
aggregator = \"trimmed_mean\"
trim_beta = 0.25
reliability = true
quarantine_rounds = 3
ewma_alpha = 0.3
threshold = 0.5
";

fn bench_new(args: &Args) -> Result<String> {
    let cfg = ExperimentConfig::from_toml_str(FAULTS_PRESET)
        .context("generated preset failed self-validation")?;
    debug_assert!(cfg.faults_config().enabled);
    debug_assert!(cfg.reliability);
    if let Some(path) = args.get("out") {
        if path != "-" {
            std::fs::write(path, FAULTS_PRESET)
                .map_err(|_| anyhow!("cannot write preset to '{path}'"))?;
            return Ok(format!("wrote {path} ({} bytes)\n", FAULTS_PRESET.len()));
        }
    }
    Ok(FAULTS_PRESET.to_string())
}

/// The deterministic EF residual `bench scale` writes into client `id`
/// — a pure function of the id, so restore-after-spill is checkable
/// without keeping the originals around.
fn scale_ef(id: usize, n_params: usize) -> Vec<f32> {
    (0..n_params).map(|j| ((id * 31 + j) % 97) as f32 * 0.125).collect()
}

/// A fabricated upload for `bench scale`: the edge tree only inspects
/// `client` (routing) and `weight` (partial sums), so the payload is a
/// one-coordinate stand-in.
fn scale_upload(id: usize, round: usize) -> Upload {
    Upload {
        client: id,
        round,
        sent_at: round as f64,
        payload: Payload::Dense { g: vec![id as f32] },
        recon: vec![id as f32],
        weight: 1.0,
        efficiency: 1.0,
        ratio: 32.0,
    }
}

/// One round's store/edge accounting in the `bench scale` table.
struct ScaleRow {
    arrivals: usize,
    occ_max: usize,
    res_now: usize,
    res_peak: usize,
    spilled: usize,
    spill_b: usize,
}

/// Drive `rounds` disjoint cohorts of `cohort` clients through a
/// [`ClientStore`] + [`EdgeAggregator`] pair — materialize, write a
/// deterministic EF, push an upload, drain, release. No training, no
/// clock: the numbers are a pure function of the knobs.
fn run_scale(
    n_clients: usize,
    cohort: usize,
    n_shards: usize,
    rounds: usize,
    n_params: usize,
    lazy: bool,
    seed: u64,
) -> (ClientStore, Vec<ScaleRow>, Vec<f64>) {
    let parts: Vec<Vec<u32>> = (0..n_clients).map(|i| vec![i as u32]).collect();
    let root = scenario_rng(seed);
    let mut store = ClientStore::new(parts, n_params, &root, lazy, SpillKind::Slab);
    let mut edge = EdgeAggregator::new(n_shards);
    let mut rows = Vec::with_capacity(rounds);
    let mut last_weights = Vec::new();
    for r in 0..rounds {
        let ids: Vec<usize> = (r * cohort..(r + 1) * cohort).collect();
        for &id in &ids {
            let c = store.client(id);
            c.ef = scale_ef(id, n_params);
            c.rounds_participated += 1;
            edge.push(scale_upload(id, r));
        }
        let occ_max = edge.occupancy().into_iter().max().unwrap_or(0);
        last_weights = edge.weight_totals();
        let batch = edge.drain_ordered();
        for &id in &ids {
            store.release(id);
        }
        rows.push(ScaleRow {
            arrivals: batch.len(),
            occ_max,
            res_now: store.resident_count(),
            res_peak: store.peak_resident(),
            spilled: store.spilled_count(),
            spill_b: store.spilled_bytes(),
        });
    }
    (store, rows, last_weights)
}

fn bench_scale(args: &Args) -> Result<String> {
    let n_clients = args.get_usize("clients", 100_000)?;
    let cohort = args.get_usize("cohort", 64)?;
    let n_shards = args.get_usize("shards", 8)?;
    let rounds = args.get_usize("rounds", 5)?;
    let n_params = args.get_usize("params", 32)?;
    let seed = args.get_u64("seed", 17)?;
    if cohort == 0 || n_shards == 0 || rounds == 0 || n_params == 0 {
        bail!("bench scale needs cohort, shards, rounds and params all >= 1");
    }
    if n_clients < cohort * rounds {
        bail!(
            "bench scale walks disjoint cohorts: needs clients >= cohort*rounds, \
             got {n_clients} < {}",
            cohort * rounds
        );
    }

    let (store, rows, last_weights) =
        run_scale(n_clients, cohort, n_shards, rounds, n_params, true, seed);

    let mut out = String::new();
    out.push_str("fed3sfc bench scale — sharded edge aggregation with lazy client state\n");
    out.push_str(&format!(
        "fleet {n_clients}, cohort {cohort}, shards {n_shards}, rounds {rounds}, \
         P={n_params}, spill slab, seed {seed}\n\n"
    ));
    out.push_str(&format!(
        "{:>5}  {:>8}  {:>7}  {:>7}  {:>8}  {:>7}  {:>8}\n",
        "round", "arrivals", "occ_max", "res_now", "res_peak", "spilled", "spill_B"
    ));
    for (r, row) in rows.iter().enumerate() {
        out.push_str(&format!(
            "{:>5}  {:>8}  {:>7}  {:>7}  {:>8}  {:>7}  {:>8}\n",
            r, row.arrivals, row.occ_max, row.res_now, row.res_peak, row.spilled,
            row.spill_b
        ));
    }
    out.push_str(&format!(
        "\nshard weight partials, last round pre-drain: {last_weights:?}\n"
    ));

    // Bitwise K-invariance: the same arrival stream drained through 1,
    // 2, 7 and `n_shards` shards must come back in the identical order
    // (it is the *reduction order* — the whole trajectory contract).
    let mut flat: Option<Vec<(usize, usize)>> = None;
    let mut invariant = true;
    for k in [1usize, 2, 7, n_shards] {
        let mut e = EdgeAggregator::new(k);
        let mut got = Vec::new();
        for r in 0..rounds {
            for id in r * cohort..(r + 1) * cohort {
                e.push(scale_upload(id, r));
            }
            got.extend(e.drain_ordered().into_iter().map(|u| (u.client, u.round)));
        }
        match &flat {
            None => flat = Some(got),
            Some(f) => invariant &= *f == got,
        }
    }
    out.push_str(&format!(
        "drain order invariant across shards {{1,2,7,{n_shards}}}: {}\n",
        if invariant { "yes" } else { "NO" }
    ));

    // Spill round-trip: every participant's restored EF must equal the
    // deterministic pattern bit-for-bit.
    let participants = rounds * cohort;
    let exact = (0..participants)
        .filter(|&id| {
            let want: Vec<u32> =
                scale_ef(id, n_params).iter().map(|x| x.to_bits()).collect();
            let got: Vec<u32> = store.ef_of(id).iter().map(|x| x.to_bits()).collect();
            want == got
        })
        .count();
    out.push_str(&format!(
        "spill round-trip: {exact}/{participants} EF vectors bit-exact\n"
    ));

    // Eager contrast: lazy off keeps everyone resident, spills nothing,
    // and holds the same EF bits.
    let (eager, _, _) =
        run_scale(n_clients, cohort, n_shards, rounds, n_params, false, seed);
    let ef_equal = (0..participants)
        .filter(|&id| {
            store.ef_of(id).iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                == eager.ef_of(id).iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        })
        .count();
    out.push_str(&format!(
        "eager contrast (lazy_state=false): resident {}, spilled {} B, \
         EF bit-equal {ef_equal}/{participants}\n",
        eager.resident_count(),
        eager.spilled_bytes()
    ));

    if args.has_flag("measure") {
        // Wall-clock + RSS are machine-local, so they live behind the
        // flag and stay out of the snapshot golden.
        let t = time_it(0, 1, || {
            let _ = run_scale(n_clients, cohort, n_shards, rounds, n_params, true, seed);
        });
        let secs = t.median() / 1e3;
        let rps = if secs > 0.0 { rounds as f64 / secs } else { f64::INFINITY };
        out.push_str(&format!(
            "peak RSS: {}  ({rps:.0} rounds/s over {rounds} rounds)\n",
            fmt_bytes_opt(peak_rss_bytes())
        ));
    } else {
        out.push_str("peak RSS: - (pass --measure for wall-clock rounds/s and VmHWM)\n");
    }
    Ok(out)
}

/// Numeric field of one JSONL record; `None` for JSON `null` (the NaN
/// no-data sentinel) and for absent keys.
fn num(rec: &Value, key: &str) -> Option<f64> {
    match rec.get(key) {
        Some(Value::Num(x)) => Some(*x),
        _ => None,
    }
}

/// `{v:.prec$}`, or `-` when the value is a no-data sentinel.
fn opt_cell(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{x:.prec$}"),
        _ => "-".to_string(),
    }
}

pub fn cmd_report(args: &Args) -> Result<()> {
    let path = args
        .get("metrics")
        .ok_or_else(|| anyhow!("report needs --metrics PATH (a JSONL file from `run`)"))?;
    let text = std::fs::read_to_string(path)
        .map_err(|_| anyhow!("cannot read metrics file '{path}'"))?;
    let mut out = String::new();
    out.push_str(&format!("fed3sfc report — {path}\n\n"));
    out.push_str(&format!(
        "{:>5}  {:>7}  {:>7}  {:>4}  {:>11}  {:>11}  {:>8}  {:>6}  {:>8}\n",
        "round", "acc", "loss", "sel", "up_cum", "down_cum", "ratio", "stale", "sim_s"
    ));
    let mut rounds = 0usize;
    let mut best_acc: Option<f64> = None;
    let mut last_up = 0.0f64;
    let mut last_down = 0.0f64;
    let mut ratio_sum = 0.0f64;
    let mut ratio_n = 0usize;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let rec = parse_json(line).with_context(|| format!("{path}:{} bad JSONL", i + 1))?;
        let sel = num(&rec, "n_selected").unwrap_or(0.0);
        let acc = num(&rec, "test_acc");
        if let Some(a) = acc {
            best_acc = Some(best_acc.map_or(a, |b: f64| b.max(a)));
        }
        if let Some(r) = num(&rec, "ratio") {
            if sel > 0.0 {
                ratio_sum += r;
                ratio_n += 1;
            }
        }
        last_up = num(&rec, "up_bytes_cum").unwrap_or(last_up);
        last_down = num(&rec, "down_bytes_cum").unwrap_or(last_down);
        rounds += 1;
        out.push_str(&format!(
            "{:>5}  {:>7}  {:>7}  {:>4}  {:>11}  {:>11}  {:>8}  {:>6}  {:>8}\n",
            opt_cell(num(&rec, "round"), 0),
            opt_cell(acc, 4),
            opt_cell(num(&rec, "test_loss"), 4),
            opt_cell(num(&rec, "n_selected"), 0),
            opt_cell(num(&rec, "up_bytes_cum"), 0),
            opt_cell(num(&rec, "down_bytes_cum"), 0),
            opt_cell(num(&rec, "ratio"), 1),
            opt_cell(num(&rec, "stale_mean"), 2),
            opt_cell(num(&rec, "sim_time_s"), 3),
        ));
    }
    if rounds == 0 {
        out.push_str("(no rounds recorded)\n");
    }
    let mean_ratio = if ratio_n > 0 { Some(ratio_sum / ratio_n as f64) } else { None };
    out.push_str(&format!(
        "\nrounds {rounds}; best acc {}; total up {:.0} B, down {:.0} B; mean ratio {}\n",
        opt_cell(best_acc, 4),
        last_up,
        last_down,
        match mean_ratio {
            Some(r) => format!("{r:.1}x"),
            None => "-".to_string(),
        }
    ));
    print!("{out}");
    Ok(())
}
