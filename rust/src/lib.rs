//! # fed3sfc
//!
//! Production-quality reproduction of *"Communication-efficient Federated
//! Learning with Single-Step Synthetic Features Compressor for Faster
//! Convergence"* (Zhou et al., 2023).
//!
//! Three-layer architecture (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — the federated-learning coordinator:
//!   event-driven federation sessions (a message-passing `FedServer`
//!   with sync / deadline / buffered-async aggregation policies on a
//!   simnet virtual clock, pluggable client schedulers and server
//!   optimizers), the full compressor zoo (FedAvg / DGC / signSGD / STC
//!   / 3SFC / FedSynth), error-feedback state, non-i.i.d. data
//!   partitioning, wire-honest traffic accounting, metrics, config and
//!   CLI.
//! * **L2 (python/compile)** — jax fed-ops over flat parameter vectors,
//!   AOT-lowered once to HLO text artifacts (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels (tiled matmul, fused
//!   reductions, axpy) with second-order-capable custom vjps.
//!
//! The runtime layer is a pluggable [`runtime::Backend`]: the default
//! `pjrt` path loads `artifacts/*.hlo.txt` through the PJRT CPU client
//! (`xla` crate) — python never runs on the round path — while the
//! `native` path re-implements every fed-op in pure Rust
//! ([`runtime::mlp`]) so experiments and the whole test tier run with no
//! artifacts at all. Select with `[runtime] backend`, `--backend`, or
//! `FED3SFC_BACKEND`; the two implementations are differentially tested
//! against each other (`tests/backend_parity_test.rs`).

pub mod bench;
pub mod cli;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod runtime;
pub mod simnet;
pub mod testing;
pub mod util;

pub use coordinator::experiment::{Experiment, ExperimentBuilder, RoundRecord};
pub use coordinator::{AggregationPolicy, FedServer};
pub use runtime::{open_backend, Backend, NativeBackend};
#[cfg(feature = "pjrt")]
pub use runtime::{PjrtBackend, Runtime};

/// Default location of the AOT artifact directory, overridable with the
/// `FED3SFC_ARTIFACTS` environment variable (used by tests/benches so they
/// work from any cwd).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("FED3SFC_ARTIFACTS") {
        return p.into();
    }
    // Walk up from cwd looking for `artifacts/manifest.json`.
    let mut d = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = d.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !d.pop() {
            return "artifacts".into();
        }
    }
}
