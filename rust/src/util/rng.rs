//! Deterministic, splittable PRNG: splitmix64 seeding + xoshiro256** core.
//!
//! Every stochastic choice in the system (dataset synthesis, Dirichlet
//! partitioning, batch sampling, synthetic-feature init) flows through this
//! generator so whole experiments replay bit-for-bit from a single seed.

/// xoshiro256** (Blackman & Vigna), seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box-Muller draw.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (client-id, round, purpose...).
    pub fn split(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style bounded draw without bias for practical n.
        (self.f64() * n as f64) as usize % n
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; boosted for shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `n` categories.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let s: f64 = g.iter().sum();
        for v in &mut g {
            *v /= s;
        }
        g
    }

    /// Draw an index from an (unnormalized) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill with standard-normal f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * std;
        }
    }
}

/// The registry of RNG stream tags.
///
/// Every purpose that splits a stream off the experiment root gets its own
/// named constant here, so two purposes can never silently share a tag
/// (shared tags mean correlated draws: `split` derives the child purely
/// from parent state + tag). detlint's DET004 rule enforces this table:
/// literal `split(0x…)` call sites are rejected when a value recurs, and
/// the constants below are themselves part of the duplicate scan.
pub mod stream {
    /// Dirichlet partition of the dataset across clients
    /// (`coordinator/experiment.rs` and the `partition-viz` CLI share this
    /// stream deliberately: the viz must show the exact partition a run uses).
    pub const PARTITION: u64 = 0x9A87_1710;
    /// Per-round link jitter in simulated network delays.
    pub const LINK_JITTER: u64 = 0x11A7_71E5;
    /// Downlink (server→client) broadcast path.
    pub const DOWNLINK: u64 = 0xD114_C0DE;
    /// Client participation scheduling.
    pub const SCHEDULE: u64 = 0x5C4E_D111;
    /// Base tag for per-client batch samplers (client id is added).
    pub const CLIENT_SAMPLER_BASE: u64 = 0xC11E00;
    /// Base tag for per-client local RNGs (client id is added).
    pub const CLIENT_LOCAL_BASE: u64 = 0xC11EFF;
    /// Dataset synthesis, xor-mixed with the split index.
    pub const DATA_SPLIT: u64 = 0xDA7A;
    /// Adversarial fault layer (`simnet::faults`): device-class tier
    /// assignment, per-dispatch dropout draws, and the gaussian-noise
    /// byzantine attacker's per-coordinate perturbations share this one
    /// stream — tier factors are *correlated by construction* (one draw
    /// decides compute × bandwidth × reliability together), draw-free
    /// attack modes and trace replays consume nothing, and a disabled
    /// layer consumes zero draws so `[faults]`-off trajectories are
    /// bit-identical to runs built before the layer existed.
    pub const FAULTS: u64 = 0xFA_0175;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(3);
        for &shape in &[0.3, 0.5, 1.0, 2.5, 10.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.15 * shape.max(1.0),
                "shape {shape} mean {mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(4);
        for &a in &[0.1, 0.5, 5.0] {
            let p = r.dirichlet(a, 13);
            assert_eq!(p.len(), 13);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let w = [0.0, 3.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio {ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
