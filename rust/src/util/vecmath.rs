//! Flat f32 vector math — the L3 hot path.
//!
//! The coordinator manipulates parameter-sized vectors (P up to ~200k)
//! every round: error-feedback accumulation, aggregation, reconstruction
//! scaling, cosine-efficiency metrics. Loops are written 4-way unrolled
//! over chunks so LLVM auto-vectorizes them; see EXPERIMENTS.md §Perf.

/// `a · b`
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] as f64 * b[j] as f64;
        acc[1] += a[j + 1] as f64 * b[j + 1] as f64;
        acc[2] += a[j + 2] as f64 * b[j + 2] as f64;
        acc[3] += a[j + 3] as f64 * b[j + 3] as f64;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] as f64 * b[j] as f64;
    }
    s
}

/// `‖a‖²`
#[inline]
pub fn norm2(a: &[f32]) -> f64 {
    dot(a, a)
}

/// `‖a‖`
#[inline]
pub fn norm(a: &[f32]) -> f64 {
    norm2(a).sqrt()
}

/// Cosine similarity; 0 when either vector is (near-)zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = norm2(a);
    let nb = norm2(b);
    if na <= 1e-30 || nb <= 1e-30 {
        return 0.0;
    }
    dot(a, b) / (na.sqrt() * nb.sqrt())
}

/// `y += alpha * x`
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `y = a - b` elementwise into a fresh vector.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x - y).collect()
}

/// `a += b`
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    axpy(1.0, b, a)
}

/// `a *= s`
pub fn scale_assign(a: &mut [f32], s: f32) {
    for v in a.iter_mut() {
        *v *= s;
    }
}

/// Weighted accumulate: `acc += w * x` (aggregation inner loop).
pub fn weighted_add(acc: &mut [f32], x: &[f32], w: f32) {
    axpy(w, x, acc)
}

/// Selection key for top-k by magnitude: |v| with NaN mapped *below*
/// every finite value, so divergent coordinates (NaN gradients from a
/// runaway lr) are selected last and every comparison is total —
/// `partial_cmp(..).unwrap()` here used to abort whole experiments the
/// moment one coordinate went NaN.
#[inline]
fn mag_key(v: f32) -> f32 {
    let a = v.abs();
    if a.is_nan() {
        f32::NEG_INFINITY
    } else {
        a
    }
}

/// Magnitude of the k-th largest |value| via quickselect (O(n) average).
/// Returns the magnitude threshold; ties included above it may exceed k —
/// callers slice to exactly k. NaN inputs rank below every finite value
/// (the threshold is −∞ only if fewer than k values are non-NaN).
pub fn kth_magnitude(values: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= values.len());
    let mut mags: Vec<f32> = values.iter().map(|&v| mag_key(v)).collect();
    let idx = mags.len() - k; // k-th largest == (n-k)-th smallest
    let (_, kth, _) = mags.select_nth_unstable_by(idx, f32::total_cmp);
    *kth
}

/// Top-k indices by |value|, ascending index order. O(n + k log k): one
/// `select_nth_unstable_by` partial selection over an index permutation —
/// no full sort, no threshold re-scans, one allocation. Magnitude ties
/// keep the *smallest* indices (matching the historical scan order, so
/// selections are stable under permutation of the tie-free prefix).
/// Total over NaN inputs: NaN coordinates lose to every finite one and
/// only pad the result when fewer than k values are finite.
pub fn topk_indices(values: &[f32], k: usize) -> Vec<u32> {
    let n = values.len();
    let k = k.min(n).max(1);
    let mut idx: Vec<u32> = (0..n as u32).collect();
    if k < n {
        // Ascending by (magnitude, descending index): the k winners land
        // in the tail, and boundary ties resolve toward smaller indices.
        let split = n - k;
        let _ = idx.select_nth_unstable_by(split, |&x, &y| {
            mag_key(values[x as usize])
                .total_cmp(&mag_key(values[y as usize]))
                .then_with(|| y.cmp(&x))
        });
        idx.drain(..split);
    }
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..103).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..103).map(|i| (i as f32).cos()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn cosine_basics() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 2.0];
        assert!(cosine(&a, &a) > 0.999999);
        assert!(cosine(&a, &b).abs() < 1e-9);
        assert!(cosine(&a, &[0.0, 0.0]) == 0.0);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(0.5, &x, &mut y);
        assert_eq!(y, [10.5, 11.0, 11.5]);
    }

    #[test]
    fn topk_selects_largest_magnitudes() {
        let v = [0.1f32, -5.0, 3.0, 0.0, -2.0, 4.0];
        let idx = topk_indices(&v, 3);
        assert_eq!(idx, vec![1, 2, 5]);
    }

    #[test]
    fn topk_handles_ties_and_k_equals_n() {
        let v = [1.0f32, 1.0, 1.0, 1.0];
        let idx = topk_indices(&v, 2);
        assert_eq!(idx.len(), 2);
        let idx = topk_indices(&v, 4);
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }

    #[test]
    fn kth_magnitude_orders() {
        let v = [3.0f32, -1.0, 2.0, -4.0];
        assert_eq!(kth_magnitude(&v, 1), 4.0);
        assert_eq!(kth_magnitude(&v, 2), 3.0);
        assert_eq!(kth_magnitude(&v, 4), 1.0);
    }

    #[test]
    fn topk_tolerates_nan_inputs() {
        // Divergent gradients must degrade selection, not abort it.
        let v = [f32::NAN, 1.0, -3.0, f32::NAN, 2.0, 0.5];
        assert_eq!(kth_magnitude(&v, 3), 1.0);
        assert_eq!(topk_indices(&v, 3), vec![1, 2, 4]); // finite coords win
        // Asking for more than the finite count pads with NaN positions.
        assert_eq!(topk_indices(&v, 5), vec![0, 1, 2, 4, 5]);
    }

    #[test]
    fn topk_all_nan_still_returns_k() {
        let v = [f32::NAN; 4];
        assert_eq!(kth_magnitude(&v, 2), f32::NEG_INFINITY);
        let idx = topk_indices(&v, 2);
        assert_eq!(idx.len(), 2);
        assert_eq!(idx, vec![0, 1]);
    }
}
