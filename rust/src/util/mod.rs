//! Shared substrates: PRNG, vector math, statistics, minimal JSON.
//!
//! The offline registry only carries the `xla` crate closure, so the usual
//! `rand` / `serde_json` / `statrs` stack is reimplemented here to exactly
//! the extent the system needs — each piece unit-tested in its module.

pub mod json;
pub mod rng;
pub mod stats;
pub mod vecmath;
