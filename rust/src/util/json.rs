//! Minimal JSON: a recursive-descent parser (for `artifacts/manifest.json`)
//! and a tiny writer (for metrics JSONL). Covers the full JSON grammar we
//! produce/consume; not a general-purpose library (no \u surrogate pairs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }
}

pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }
    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }
    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape"),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multi-byte UTF-8.
                    let start = self.i - 1;
                    let ch_len = utf8_len(c);
                    self.i = start + ch_len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Escape a string for JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Tiny ordered-key object writer for metrics lines.
pub struct ObjWriter {
    buf: String,
    first: bool,
}

impl ObjWriter {
    pub fn new() -> Self {
        ObjWriter { buf: String::from("{"), first: true }
    }
    fn key(&mut self, k: &str) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        let _ = write!(self.buf, "\"{}\":", escape(k));
    }
    pub fn num(mut self, k: &str, v: f64) -> Self {
        self.key(k);
        if v.is_finite() {
            let _ = write!(self.buf, "{v}");
        } else {
            self.buf.push_str("null");
        }
        self
    }
    pub fn int(mut self, k: &str, v: i64) -> Self {
        self.key(k);
        let _ = write!(self.buf, "{v}");
        self
    }
    pub fn str(mut self, k: &str, v: &str) -> Self {
        self.key(k);
        let _ = write!(self.buf, "\"{}\"", escape(v));
        self
    }
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

impl Default for ObjWriter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_doc() {
        let doc = r#"{"version":1,"models":{"mlp":{"params":1234,
            "input_shape":[784],"ops":{"eval":{"file":"a.txt","batch":100}}}}}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.req("version").unwrap().as_usize().unwrap(), 1);
        let mlp = v.req("models").unwrap().req("mlp").unwrap();
        assert_eq!(mlp.req("params").unwrap().as_usize().unwrap(), 1234);
        assert_eq!(
            mlp.req("input_shape").unwrap().as_arr().unwrap()[0]
                .as_usize()
                .unwrap(),
            784
        );
    }

    #[test]
    fn parses_scalars_and_arrays() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(
            parse(r#"["a", 1, false]"#).unwrap(),
            Value::Arr(vec![
                Value::Str("a".into()),
                Value::Num(1.0),
                Value::Bool(false)
            ])
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = parse(r#""a\nb\"cA""#).unwrap();
        assert_eq!(v, Value::Str("a\nb\"cA".into()));
        assert_eq!(escape("x\"y\n"), "x\\\"y\\n");
    }

    #[test]
    fn obj_writer_builds_valid_json() {
        let line = ObjWriter::new()
            .int("round", 3)
            .num("acc", 0.75)
            .str("method", "3sfc")
            .finish();
        let v = parse(&line).unwrap();
        assert_eq!(v.req("acc").unwrap().as_f64().unwrap(), 0.75);
    }
}
