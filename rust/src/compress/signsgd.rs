//! signSGD with error feedback (Bernstein et al. 2018 + Karimireddy et
//! al. 2019): one sign bit per coordinate plus a single scale, the mean
//! |target| — the scale that makes sign compression an EF-contraction.

use anyhow::{bail, Result};

use super::payload::{get_bit, pack_bits};
use super::{Compressor, DecodeCtx, EncodeCtx, EncodeStats, Payload};

#[derive(Default)]
pub struct SignSgd;

impl SignSgd {
    pub fn new() -> SignSgd {
        SignSgd
    }
}

impl Compressor for SignSgd {
    fn name(&self) -> String {
        "signsgd".into()
    }

    fn encode(
        &self,
        _ctx: &mut EncodeCtx,
        target: &[f32],
    ) -> Result<(Payload, Vec<f32>, EncodeStats)> {
        let n = target.len();
        let scale = target.iter().map(|v| v.abs() as f64).sum::<f64>() / n.max(1) as f64;
        let scale = scale as f32;
        let bits = pack_bits(target.iter().map(|&v| v < 0.0), n);
        let recon: Vec<f32> = target
            .iter()
            .map(|&v| if v < 0.0 { -scale } else { scale })
            .collect();
        Ok((Payload::Sign { n, bits, scale }, recon, EncodeStats::default()))
    }

    fn decode(&self, _ctx: &DecodeCtx, payload: &Payload) -> Result<Vec<f32>> {
        let Payload::Sign { n, bits, scale } = payload else {
            bail!("signsgd got {:?}", payload.kind());
        };
        Ok((0..*n)
            .map(|i| if get_bit(bits, i) { -scale } else { *scale })
            .collect())
    }
}
