//! The compressor zoo: the paper's contribution (3SFC) and every
//! competitor it is evaluated against (Table 2): FedAvg (identity), DGC
//! (top-k sparsification), signSGD (1-bit + scale), STC (ternary top-k),
//! and the FedSynth multi-step distillation baseline (Table 1, Figs 2–3).
//!
//! Contract: `encode` maps the EF-corrected accumulated gradient
//! `target = g + e` to a wire [`Payload`] **and** the reconstruction the
//! decoder would produce (the simulation computes it once; `decode` is the
//! server-side path and tests assert the two agree bit-for-bit). The
//! coordinator owns the error-feedback state (Eq. 6).

pub mod fedsynth;
pub mod identity;
pub mod payload;
pub mod signsgd;
pub mod stc;
pub mod threesfc;
pub mod topk;

use anyhow::Result;

pub use fedsynth::FedSynth;
pub use identity::Identity;
pub use payload::Payload;
pub use signsgd::SignSgd;
pub use stc::Stc;
pub use threesfc::ThreeSfc;
pub use topk::TopK;

use crate::config::{CompressorKind, ExperimentConfig};
use crate::model::ModelInfo;
use crate::runtime::FedOps;
use crate::util::rng::Rng;

/// Everything a compressor may need while encoding on a client.
pub struct EncodeCtx<'a, 'b> {
    /// Fed-op facade for the experiment's model (3SFC / FedSynth need it).
    pub ops: &'a FedOps<'b>,
    /// Current global weights w^t (the encoder optimizes at w^t, Eq. 7).
    pub w_global: &'a [f32],
    /// Per-client stream for synthetic-feature init.
    pub rng: &'a mut Rng,
}

/// Server-side decode context (Eq. 10 needs w^t and the shared model).
pub struct DecodeCtx<'a, 'b> {
    pub ops: &'a FedOps<'b>,
    pub w_global: &'a [f32],
}

/// A gradient compressor (client encoder + server decoder).
pub trait Compressor {
    fn name(&self) -> String;

    /// Compress `target = g + e`. Returns (wire payload, reconstruction).
    fn encode(&mut self, ctx: &mut EncodeCtx, target: &[f32]) -> Result<(Payload, Vec<f32>)>;

    /// Server-side reconstruction of the gradient from the payload.
    fn decode(&self, ctx: &DecodeCtx, payload: &Payload) -> Result<Vec<f32>>;
}

/// Build the compressor an [`ExperimentConfig`] asks for.
///
/// Budget protocol (paper §6.1): DGC is given the *same byte budget* as
/// 3SFC at the same multiplier; signSGD/STC sit at their natural 32× rate
/// unless `topk_rate` overrides DGC explicitly (Fig 1 sweeps).
pub fn build(cfg: &ExperimentConfig, model: &ModelInfo) -> Box<dyn Compressor> {
    let n = model.params;
    match cfg.compressor {
        CompressorKind::FedAvg => Box::new(Identity::new()),
        CompressorKind::Dgc => {
            let k = if cfg.topk_rate > 0.0 {
                ((n as f64 * cfg.topk_rate).round() as usize).clamp(1, n)
            } else {
                // Match 3SFC's wire bytes: top-k costs 8 bytes/coordinate.
                (model.syn_payload_bytes(cfg.syn_m()) / 8).clamp(1, n)
            };
            Box::new(TopK::new(k))
        }
        CompressorKind::SignSgd => Box::new(SignSgd::new()),
        CompressorKind::Stc => Box::new(Stc::with_rate(n, 1.0 / 32.0)),
        CompressorKind::ThreeSfc => Box::new(ThreeSfc::new(
            cfg.syn_m(),
            cfg.syn_steps,
            cfg.lr_syn,
            cfg.lambda,
        )),
        CompressorKind::FedSynth => Box::new(FedSynth::new(
            cfg.fedsynth_ksim,
            1,
            cfg.fedsynth_steps,
            cfg.fedsynth_lr_inner,
            cfg.fedsynth_lr_syn,
        )),
    }
}
