//! The compressor zoo: the paper's contribution (3SFC) and every
//! competitor it is evaluated against (Table 2): FedAvg (identity), DGC
//! (top-k sparsification), signSGD (1-bit + scale), STC (ternary top-k),
//! and the FedSynth multi-step distillation baseline (Table 1, Figs 2–3).
//!
//! Contract: `encode` maps the EF-corrected accumulated gradient
//! `target = g + e` to a wire [`Payload`], the reconstruction the decoder
//! would produce (the simulation computes it once; `decode` is the
//! server-side path and tests assert the two agree bit-for-bit), and an
//! [`EncodeStats`] carrying encoder diagnostics. The coordinator owns the
//! error-feedback state (Eq. 6).
//!
//! Thread safety: `encode` takes `&self` and every per-encode output
//! (including the diagnostics that used to live as mutable compressor
//! fields) is returned by value, so one compressor instance — or one
//! instance per worker — can encode many clients concurrently. The trait
//! requires `Send + Sync`; all state a compressor holds is immutable
//! configuration.

pub mod downlink;
pub mod fedsynth;
pub mod identity;
pub mod payload;
pub mod signsgd;
pub mod spill;
pub mod stc;
pub mod threesfc;
pub mod topk;

use anyhow::Result;

pub use downlink::{build_downlink, DeltaDownlink, DeltaPayload, DenseDownlink, DownlinkTx};
pub use fedsynth::FedSynth;
pub use identity::Identity;
pub use payload::Payload;
pub use signsgd::SignSgd;
pub use spill::{restore, spill, SpilledEf};
pub use stc::Stc;
pub use threesfc::ThreeSfc;
pub use topk::TopK;

use crate::config::{CompressorKind, ExperimentConfig};
use crate::model::ModelInfo;
use crate::runtime::FedOps;
use crate::util::rng::Rng;

/// Everything a compressor may need while encoding on a client.
pub struct EncodeCtx<'a, 'b> {
    /// Fed-op facade for the experiment's model (3SFC / FedSynth need it).
    pub ops: &'a FedOps<'b>,
    /// Current global weights w^t (the encoder optimizes at w^t, Eq. 7).
    pub w_global: &'a [f32],
    /// Per-client stream for synthetic-feature init.
    pub rng: &'a mut Rng,
}

/// Server-side decode context (Eq. 10 needs w^t and the shared model).
pub struct DecodeCtx<'a, 'b> {
    pub ops: &'a FedOps<'b>,
    pub w_global: &'a [f32],
}

/// Per-encode diagnostics, returned by value so `encode` can stay `&self`
/// (these used to be mutable compressor fields, which made concurrent
/// encoding impossible).
#[derive(Clone, Debug)]
pub struct EncodeStats {
    /// Encoder-internal |cos| of the kept iterate (3SFC, Fig 7's
    /// compression-efficiency trace). NaN when not applicable.
    pub cos: f32,
    /// Final fit loss ‖Δw_sim − g‖² (FedSynth, Fig 2). NaN when n/a.
    pub fit: f32,
    /// Per-step gradient norms of the FedSynth unroll (Fig 3's explosion
    /// series). Empty when not applicable.
    pub step_norms: Vec<f32>,
}

impl Default for EncodeStats {
    fn default() -> Self {
        EncodeStats { cos: f32::NAN, fit: f32::NAN, step_norms: Vec::new() }
    }
}

/// A gradient compressor (client encoder + server decoder).
///
/// `Send + Sync` so the round engine can encode selected clients in
/// parallel (each worker holds its own instance or shares one; either way
/// no encode mutates the compressor).
pub trait Compressor: Send + Sync {
    fn name(&self) -> String;

    /// Compress `target = g + e`.
    /// Returns (wire payload, reconstruction, encoder diagnostics).
    fn encode(
        &self,
        ctx: &mut EncodeCtx,
        target: &[f32],
    ) -> Result<(Payload, Vec<f32>, EncodeStats)>;

    /// Server-side reconstruction of the gradient from the payload.
    fn decode(&self, ctx: &DecodeCtx, payload: &Payload) -> Result<Vec<f32>>;
}

/// Build the compressor an [`ExperimentConfig`] asks for.
///
/// Budget protocol (paper §6.1): DGC is given the *same byte budget* as
/// 3SFC at the same multiplier; signSGD/STC sit at their natural 32× rate
/// unless `topk_rate` overrides DGC explicitly (Fig 1 sweeps).
pub fn build(cfg: &ExperimentConfig, model: &ModelInfo) -> Box<dyn Compressor> {
    let n = model.params;
    match cfg.compressor {
        CompressorKind::FedAvg => Box::new(Identity::new()),
        CompressorKind::Dgc => {
            let k = if cfg.topk_rate > 0.0 {
                ((n as f64 * cfg.topk_rate).round() as usize).clamp(1, n)
            } else {
                // Match 3SFC's wire bytes: top-k costs 8 bytes/coordinate
                // plus a 4-byte length header.
                (model.syn_payload_bytes(cfg.syn_m()).saturating_sub(4) / 8).clamp(1, n)
            };
            Box::new(TopK::new(k))
        }
        CompressorKind::SignSgd => Box::new(SignSgd::new()),
        CompressorKind::Stc => Box::new(Stc::with_rate(n, 1.0 / 32.0)),
        CompressorKind::ThreeSfc => Box::new(ThreeSfc::new(
            cfg.syn_m(),
            cfg.syn_steps,
            cfg.lr_syn,
            cfg.lambda,
        )),
        CompressorKind::FedSynth => Box::new(FedSynth::new(
            cfg.fedsynth_ksim,
            1,
            cfg.fedsynth_steps,
            cfg.fedsynth_lr_inner,
            cfg.fedsynth_lr_syn,
        )),
    }
}
