//! FedAvg "compressor": the identity (1× baseline of every table).

use anyhow::Result;

use super::{Compressor, DecodeCtx, EncodeCtx, EncodeStats, Payload};

#[derive(Default)]
pub struct Identity;

impl Identity {
    pub fn new() -> Identity {
        Identity
    }
}

impl Compressor for Identity {
    fn name(&self) -> String {
        "fedavg".into()
    }

    fn encode(
        &self,
        _ctx: &mut EncodeCtx,
        target: &[f32],
    ) -> Result<(Payload, Vec<f32>, EncodeStats)> {
        Ok((
            Payload::Dense { g: target.to_vec() },
            target.to_vec(),
            EncodeStats::default(),
        ))
    }

    fn decode(&self, _ctx: &DecodeCtx, payload: &Payload) -> Result<Vec<f32>> {
        match payload {
            Payload::Dense { g } => Ok(g.clone()),
            _ => anyhow::bail!("identity got {:?}", payload.kind()),
        }
    }
}
