//! 3SFC — the paper's Single-Step Synthetic Features Compressor.
//!
//! Encoder (Algorithm 1, client side): initialize a tiny synthetic dataset
//! `D_syn = (dx, dy)` (m samples of model inputs + label logits), run S
//! SGD steps on the similarity objective
//!
//! ```text
//!   min  1 - |cos(∇_w F(D_syn, w^t), g + e)| + λ‖D_syn‖²        (Eq. 9)
//! ```
//!
//! via the AOT `syn_step` artifact (a *second-order* fed-op: it
//! differentiates through the model's gradient), keep the best iterate by
//! |cos|, then compute the closed-form scale
//!
//! ```text
//!   s = ⟨g + e, ∇F(D_syn)⟩ / ‖∇F(D_syn)‖²                        (Eq. 8)
//! ```
//!
//! Decoder (Eq. 10, server side): one forward/backward of the *shared*
//! model on `D_syn` at `w^t`, scaled by `s`.

use anyhow::{bail, Result};

use super::{Compressor, DecodeCtx, EncodeCtx, EncodeStats, Payload};
use crate::util::vecmath;

pub struct ThreeSfc {
    /// Synthetic sample count m (budget: ‖D‖₀ + 1 ≤ B).
    pub m: usize,
    /// Encoder iterations S.
    pub steps: usize,
    /// Adam step size for the synthetic features (see `encode`).
    pub lr_syn: f32,
    pub lambda: f32,
    /// Std-dev of the synthetic-input init.
    pub init_scale: f32,
}

/// Host-side Adam state for one flat buffer.
struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
}

impl Adam {
    fn new(n: usize) -> Adam {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    fn step(&mut self, x: &mut [f32], g: &[f32], alpha: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        self.t += 1;
        let bc1 = 1.0 - B1.powi(self.t);
        let bc2 = 1.0 - B2.powi(self.t);
        for i in 0..x.len() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * g[i];
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * g[i] * g[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            x[i] -= alpha * mh / (vh.sqrt() + EPS);
        }
    }
}

impl ThreeSfc {
    pub fn new(m: usize, steps: usize, lr_syn: f32, lambda: f32) -> ThreeSfc {
        assert!(m >= 1 && steps >= 1);
        ThreeSfc { m, steps, lr_syn, lambda, init_scale: 0.5 }
    }

    /// Closed-form Eq. 8 scale.
    pub fn optimal_scale(target: &[f32], g_syn: &[f32]) -> f32 {
        let denom = vecmath::norm2(g_syn);
        if denom <= 1e-30 {
            return 0.0;
        }
        (vecmath::dot(target, g_syn) / denom) as f32
    }
}

impl Compressor for ThreeSfc {
    fn name(&self) -> String {
        format!("3sfc(m={},S={})", self.m, self.steps)
    }

    fn encode(
        &self,
        ctx: &mut EncodeCtx,
        target: &[f32],
    ) -> Result<(Payload, Vec<f32>, EncodeStats)> {
        let model = ctx.ops.model;
        let d = model.feature_len();
        let c = model.n_classes;

        // Init: small random inputs, zero (uniform) label logits.
        let mut dx = vec![0.0f32; self.m * d];
        ctx.rng.fill_normal(&mut dx, self.init_scale);
        let mut dy = vec![0.0f32; self.m * c];

        // S similarity steps with Adam. Fast path (perf pass, EXPERIMENTS
        // §Perf): the fused `syn_opt` artifact runs all S steps in one
        // dispatch, avoiding S× re-upload of w and g_target. Fallback:
        // loop the single `syn_step` artifact with lr=1 so the raw
        // objective gradient is recoverable as (x - x'), and apply Adam
        // host-side — identical math, S dispatches.
        let (mut best_dx, mut best_dy, mut best_cos);
        if ctx.ops.has_syn_opt(self.m, self.steps) {
            let (fdx, fdy, bdx, bdy, bcos, _last) = ctx.ops.syn_opt(
                self.m,
                self.steps,
                ctx.w_global,
                target,
                &dx,
                &dy,
                self.lr_syn,
                self.lambda,
            )?;
            dx = fdx;
            dy = fdy;
            best_dx = bdx;
            best_dy = bdy;
            best_cos = bcos;
        } else {
            let mut adam_x = Adam::new(dx.len());
            let mut adam_y = Adam::new(dy.len());
            let alpha = self.lr_syn / 50.0; // default lr_syn=5.0 → Adam α=0.1
            best_dx = dx.clone();
            best_dy = dy.clone();
            best_cos = -1.0f32;
            for _ in 0..self.steps {
                let (ndx, ndy, cos) = ctx.ops.syn_step(
                    self.m,
                    ctx.w_global,
                    target,
                    &dx,
                    &dy,
                    1.0,
                    self.lambda,
                )?;
                // `cos` was evaluated at the *pre-step* iterate.
                if cos.abs() > best_cos {
                    best_cos = cos.abs();
                    best_dx.copy_from_slice(&dx);
                    best_dy.copy_from_slice(&dy);
                }
                let gdx: Vec<f32> =
                    dx.iter().zip(ndx.iter()).map(|(a, b)| a - b).collect();
                let gdy: Vec<f32> =
                    dy.iter().zip(ndy.iter()).map(|(a, b)| a - b).collect();
                adam_x.step(&mut dx, &gdx, alpha);
                adam_y.step(&mut dy, &gdy, alpha);
            }
        }
        // Score the final iterate too.
        let g_final = ctx.ops.syn_grad(self.m, ctx.w_global, &dx, &dy)?;
        let cos_final = vecmath::cosine(&g_final, target) as f32;
        let (dx, dy, g_syn, kept_cos) = if cos_final.abs() >= best_cos {
            (dx, dy, g_final, cos_final.abs())
        } else {
            let g = ctx.ops.syn_grad(self.m, ctx.w_global, &best_dx, &best_dy)?;
            (best_dx, best_dy, g, best_cos)
        };

        let s = Self::optimal_scale(target, &g_syn);
        let mut recon = g_syn;
        vecmath::scale_assign(&mut recon, s);
        let stats = EncodeStats { cos: kept_cos, ..EncodeStats::default() };
        Ok((Payload::Syn { m: self.m, dx, dy, s }, recon, stats))
    }

    fn decode(&self, ctx: &DecodeCtx, payload: &Payload) -> Result<Vec<f32>> {
        let Payload::Syn { m, dx, dy, s } = payload else {
            bail!("3sfc got {:?}", payload.kind());
        };
        // Eq. 10: g + e = s · ∇_w F(D_syn, w^t) on the shared model.
        let mut g = ctx.ops.syn_grad(*m, ctx.w_global, dx, dy)?;
        vecmath::scale_assign(&mut g, *s);
        Ok(g)
    }
}
