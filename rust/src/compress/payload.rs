//! Wire payloads with exact byte accounting.
//!
//! Every experiment reports "compression rate" = wire bytes / 4P (Eq. 1);
//! the numbers below are what a real implementation would put on the wire.

/// What a client uploads for one round.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Uncompressed gradient (FedAvg).
    Dense { g: Vec<f32> },
    /// Top-k values + u32 indices (DGC).
    TopK { n: usize, idx: Vec<u32>, val: Vec<f32> },
    /// Sign bit per coordinate + one f32 scale (signSGD w/ EF).
    Sign { n: usize, bits: Vec<u8>, scale: f32 },
    /// STC: top-k indices + sign bitset over those k + mean magnitude μ.
    Ternary { n: usize, idx: Vec<u32>, neg: Vec<u8>, mu: f32 },
    /// 3SFC: m synthetic samples (inputs + label logits) + scale s.
    Syn { m: usize, dx: Vec<f32>, dy: Vec<f32>, s: f32 },
    /// FedSynth: K_sim per-step synthetic batches (no scale).
    SynMulti { k: usize, m: usize, dxs: Vec<f32>, dys: Vec<f32> },
}

impl Payload {
    /// Exact upload size in bytes.
    ///
    /// Every length/shape header the enum carries (`n`, `m`, `k`) is
    /// charged as a u32 on the wire, exactly like the f32 scales already
    /// were — a real serializer has to send them for the receiver to
    /// frame the buffers. `Dense` carries no header field: the receiver
    /// knows the model size, so the 4P baseline stays exact (rate = 1).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::Dense { g } => 4 * g.len(),
            Payload::TopK { idx, val, .. } => 4 + 4 * idx.len() + 4 * val.len(),
            Payload::Sign { bits, .. } => 4 + bits.len() + 4,
            Payload::Ternary { idx, neg, .. } => 4 + 4 * idx.len() + neg.len() + 4,
            Payload::Syn { dx, dy, .. } => 4 + 4 * dx.len() + 4 * dy.len() + 4,
            Payload::SynMulti { dxs, dys, .. } => 8 + 4 * dxs.len() + 4 * dys.len(),
        }
    }

    /// Compression rate vs a dense f32 gradient of `n_params` (Eq. 1).
    pub fn rate(&self, n_params: usize) -> f64 {
        self.wire_bytes() as f64 / (4.0 * n_params as f64)
    }

    /// `1 / rate` — the "compression ratio ×" the paper's tables print.
    pub fn ratio(&self, n_params: usize) -> f64 {
        1.0 / self.rate(n_params).max(1e-300)
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Dense { .. } => "dense",
            Payload::TopK { .. } => "topk",
            Payload::Sign { .. } => "sign",
            Payload::Ternary { .. } => "ternary",
            Payload::Syn { .. } => "syn",
            Payload::SynMulti { .. } => "syn_multi",
        }
    }

    /// The actual wire encoding (little-endian): exactly the headers
    /// [`Payload::wire_bytes`] charges, in declaration order — so the byte
    /// accounting every table/figure reports is backed by a real
    /// serializer, not an estimate (`serialize().len() == wire_bytes()`
    /// is property-tested).
    ///
    /// Payload kind and model geometry travel out of band (the receiver
    /// knows which compressor and model the round runs), matching the
    /// accounting convention that `Dense` costs exactly 4P.
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        let push_u32 = |out: &mut Vec<u8>, v: usize| out.extend((v as u32).to_le_bytes());
        let push_f32s = |out: &mut Vec<u8>, vs: &[f32]| {
            for v in vs {
                out.extend(v.to_le_bytes());
            }
        };
        match self {
            Payload::Dense { g } => push_f32s(&mut out, g),
            Payload::TopK { idx, val, .. } => {
                push_u32(&mut out, idx.len());
                for i in idx {
                    out.extend(i.to_le_bytes());
                }
                push_f32s(&mut out, val);
            }
            Payload::Sign { n, bits, scale } => {
                push_u32(&mut out, *n);
                out.extend_from_slice(bits);
                out.extend(scale.to_le_bytes());
            }
            Payload::Ternary { idx, neg, mu, .. } => {
                push_u32(&mut out, idx.len());
                for i in idx {
                    out.extend(i.to_le_bytes());
                }
                out.extend_from_slice(neg);
                out.extend(mu.to_le_bytes());
            }
            Payload::Syn { m, dx, dy, s } => {
                push_u32(&mut out, *m);
                push_f32s(&mut out, dx);
                push_f32s(&mut out, dy);
                out.extend(s.to_le_bytes());
            }
            Payload::SynMulti { k, m, dxs, dys } => {
                push_u32(&mut out, *k);
                push_u32(&mut out, *m);
                push_f32s(&mut out, dxs);
                push_f32s(&mut out, dys);
            }
        }
        out
    }

    /// Inverse of [`Payload::serialize`]. `kind` is the out-of-band
    /// payload tag ([`Payload::kind`]); the model geometry
    /// (`n_params`, per-sample feature length, class count) supplies the
    /// shapes the wire format deliberately does not repeat.
    pub fn deserialize(
        kind: &str,
        bytes: &[u8],
        n_params: usize,
        feature_len: usize,
        n_classes: usize,
    ) -> anyhow::Result<Payload> {
        use anyhow::ensure;
        let mut off = 0usize;
        let take_u32 = |off: &mut usize| -> anyhow::Result<usize> {
            ensure!(*off + 4 <= bytes.len(), "truncated header");
            let v = u32::from_le_bytes(bytes[*off..*off + 4].try_into().unwrap());
            *off += 4;
            Ok(v as usize)
        };
        let take_f32s = |off: &mut usize, n: usize| -> anyhow::Result<Vec<f32>> {
            ensure!(*off + 4 * n <= bytes.len(), "truncated f32 block");
            let vs = bytes[*off..*off + 4 * n]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            *off += 4 * n;
            Ok(vs)
        };
        let take_u32s = |off: &mut usize, n: usize| -> anyhow::Result<Vec<u32>> {
            ensure!(*off + 4 * n <= bytes.len(), "truncated u32 block");
            let vs = bytes[*off..*off + 4 * n]
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            *off += 4 * n;
            Ok(vs)
        };
        // Element counts are driven by untrusted wire headers: multiply
        // checked so a hostile header cannot wrap, and bound every index
        // by the model size so decode cannot go out of bounds.
        let counted = |a: usize, b: usize| -> anyhow::Result<usize> {
            a.checked_mul(b)
                .filter(|&n| n <= bytes.len())
                .ok_or_else(|| anyhow::anyhow!("implausible element count {a}x{b}"))
        };
        let check_idx = |idx: &[u32]| -> anyhow::Result<()> {
            for &i in idx {
                ensure!(
                    (i as usize) < n_params,
                    "coordinate index {i} out of range for {n_params} params"
                );
            }
            Ok(())
        };
        let payload = match kind {
            "dense" => Payload::Dense { g: take_f32s(&mut off, n_params)? },
            "topk" => {
                let k = take_u32(&mut off)?;
                ensure!(k <= n_params, "top-k count {k} exceeds {n_params} params");
                let idx = take_u32s(&mut off, k)?;
                check_idx(&idx)?;
                let val = take_f32s(&mut off, k)?;
                Payload::TopK { n: n_params, idx, val }
            }
            "sign" => {
                let n = take_u32(&mut off)?;
                ensure!(n == n_params, "sign payload for {n} coords, model has {n_params}");
                let nb = n.div_ceil(8);
                ensure!(off + nb + 4 <= bytes.len(), "truncated sign payload");
                let bits = bytes[off..off + nb].to_vec();
                off += nb;
                let scale = take_f32s(&mut off, 1)?[0];
                Payload::Sign { n, bits, scale }
            }
            "ternary" => {
                let k = take_u32(&mut off)?;
                ensure!(k <= n_params, "ternary count {k} exceeds {n_params} params");
                let idx = take_u32s(&mut off, k)?;
                check_idx(&idx)?;
                let nb = k.div_ceil(8);
                ensure!(off + nb + 4 <= bytes.len(), "truncated ternary payload");
                let neg = bytes[off..off + nb].to_vec();
                off += nb;
                let mu = take_f32s(&mut off, 1)?[0];
                Payload::Ternary { n: n_params, idx, neg, mu }
            }
            "syn" => {
                let m = take_u32(&mut off)?;
                let dx = take_f32s(&mut off, counted(m, feature_len)?)?;
                let dy = take_f32s(&mut off, counted(m, n_classes)?)?;
                let s = take_f32s(&mut off, 1)?[0];
                Payload::Syn { m, dx, dy, s }
            }
            "syn_multi" => {
                let k = take_u32(&mut off)?;
                let m = take_u32(&mut off)?;
                let km = counted(k, m)?;
                let dxs = take_f32s(&mut off, counted(km, feature_len)?)?;
                let dys = take_f32s(&mut off, counted(km, n_classes)?)?;
                Payload::SynMulti { k, m, dxs, dys }
            }
            other => anyhow::bail!("unknown payload kind '{other}'"),
        };
        ensure!(off == bytes.len(), "trailing bytes after {kind} payload");
        Ok(payload)
    }

    /// Structural self-consistency check for an *untrusted* in-memory
    /// payload (the uplink boundary's mirror of the header checks
    /// [`Payload::deserialize`] applies to untrusted bytes): buffer
    /// lengths must match the declared counts and scalar scales must be
    /// finite, otherwise [`Payload::wire_bytes`] — and therefore the
    /// traffic ledger — would be priced off a lie. Returns a short
    /// description of the first violation, or `None` for a well-formed
    /// payload. Value finiteness of the update itself is checked on
    /// `Upload::recon` (what is actually aggregated), not here.
    pub fn shape_error(&self) -> Option<&'static str> {
        match self {
            Payload::Dense { .. } => None,
            Payload::TopK { n, idx, val } => {
                if idx.len() != val.len() {
                    Some("top-k index/value length mismatch")
                } else if idx.len() > *n || idx.iter().any(|&i| i as usize >= *n) {
                    Some("top-k index out of range")
                } else {
                    None
                }
            }
            Payload::Sign { n, bits, scale } => {
                if bits.len() != n.div_ceil(8) {
                    Some("sign bitset length disagrees with n")
                } else if !scale.is_finite() {
                    Some("sign scale is not finite")
                } else {
                    None
                }
            }
            Payload::Ternary { n, idx, neg, mu } => {
                if neg.len() != idx.len().div_ceil(8) {
                    Some("ternary sign bitset length disagrees with k")
                } else if idx.len() > *n || idx.iter().any(|&i| i as usize >= *n) {
                    Some("ternary index out of range")
                } else if !mu.is_finite() {
                    Some("ternary magnitude is not finite")
                } else {
                    None
                }
            }
            Payload::Syn { m, dx, dy, s } => {
                if *m == 0 || dx.len() % *m != 0 || dy.len() % *m != 0 {
                    Some("synthetic batch shape disagrees with m")
                } else if !s.is_finite() {
                    Some("synthetic scale is not finite")
                } else {
                    None
                }
            }
            Payload::SynMulti { k, m, dxs, dys } => {
                let km = k.checked_mul(*m).unwrap_or(0);
                if km == 0 || dxs.len() % km != 0 || dys.len() % km != 0 {
                    Some("multi-batch shape disagrees with k x m")
                } else {
                    None
                }
            }
        }
    }
}

/// Pack sign bits (true = negative) into a byte vector, LSB-first.
pub fn pack_bits(signs: impl Iterator<Item = bool>, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n.div_ceil(8)];
    for (i, s) in signs.enumerate() {
        if s {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

/// Read bit `i` from a packed bitset.
#[inline]
pub fn get_bit(bits: &[u8], i: usize) -> bool {
    bits[i / 8] & (1 << (i % 8)) != 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting() {
        let p = Payload::Dense { g: vec![0.0; 100] };
        assert_eq!(p.wire_bytes(), 400);
        assert_eq!(p.rate(100), 1.0);

        // 4 (n header) + 5 idx u32 + 5 val f32.
        let p = Payload::TopK { n: 100, idx: vec![0; 5], val: vec![0.0; 5] };
        assert_eq!(p.wire_bytes(), 4 + 40);
        assert!((p.ratio(100) - 400.0 / 44.0).abs() < 1e-12);

        // 4 (n header) + 13 sign bytes + 4 (scale).
        let p = Payload::Sign { n: 100, bits: vec![0; 13], scale: 1.0 };
        assert_eq!(p.wire_bytes(), 21);

        // 4 (n header) + 5 idx u32 + 1 sign byte + 4 (μ).
        let p = Payload::Ternary { n: 100, idx: vec![0; 5], neg: vec![0; 1], mu: 1.0 };
        assert_eq!(p.wire_bytes(), 4 + 20 + 1 + 4);

        // 4 (m header) + (64 + 8) f32 + 4 (scale).
        let p = Payload::Syn { m: 1, dx: vec![0.0; 64], dy: vec![0.0; 8], s: 1.0 };
        assert_eq!(p.wire_bytes(), 4 * (64 + 8 + 1) + 4);

        // 8 (k + m headers) + 2·(64 + 8) f32.
        let p = Payload::SynMulti {
            k: 2,
            m: 1,
            dxs: vec![0.0; 2 * 64],
            dys: vec![0.0; 2 * 8],
        };
        assert_eq!(p.wire_bytes(), 8 + 4 * 2 * (64 + 8));
    }

    #[test]
    fn serialized_length_is_wire_bytes_and_roundtrips() {
        let payloads = vec![
            Payload::Dense { g: (0..20).map(|i| i as f32 * 0.5).collect() },
            Payload::TopK { n: 20, idx: vec![1, 7, 13], val: vec![0.5, -2.0, 3.5] },
            Payload::Sign { n: 20, bits: vec![0b1010_1010, 0b0101_0101, 0b1111_0000], scale: 0.25 },
            Payload::Ternary { n: 20, idx: vec![2, 3, 9], neg: vec![0b101], mu: 1.5 },
            Payload::Syn { m: 2, dx: vec![0.1; 2 * 4], dy: vec![0.2; 2 * 3], s: -1.25 },
            Payload::SynMulti { k: 2, m: 1, dxs: vec![0.3; 2 * 4], dys: vec![0.4; 2 * 3] },
        ];
        for p in payloads {
            let bytes = p.serialize();
            assert_eq!(bytes.len(), p.wire_bytes(), "{}", p.kind());
            let back = Payload::deserialize(p.kind(), &bytes, 20, 4, 3).unwrap();
            assert_eq!(back.kind(), p.kind());
            assert_eq!(back.serialize(), bytes, "{} roundtrip", p.kind());
        }
    }

    #[test]
    fn deserialize_rejects_malformed() {
        let p = Payload::Sign { n: 20, bits: vec![0; 3], scale: 1.0 };
        let bytes = p.serialize();
        assert!(Payload::deserialize("sign", &bytes[..bytes.len() - 1], 20, 4, 3).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(Payload::deserialize("sign", &trailing, 20, 4, 3).is_err());
        assert!(Payload::deserialize("zip", &bytes, 20, 4, 3).is_err());
        // A sign payload framed for a different model size is rejected.
        assert!(Payload::deserialize("sign", &bytes, 24, 4, 3).is_err());
        // Out-of-range coordinate indices must not survive into decode.
        let bad = Payload::TopK { n: 20, idx: vec![1, 25], val: vec![0.5, 0.5] };
        assert!(Payload::deserialize("topk", &bad.serialize(), 20, 4, 3).is_err());
        // k > n_params is implausible framing.
        let fat = Payload::TopK { n: 20, idx: vec![0; 21], val: vec![0.0; 21] };
        assert!(Payload::deserialize("topk", &fat.serialize(), 20, 4, 3).is_err());
    }

    #[test]
    fn shape_error_flags_inconsistent_payloads() {
        // Honest shapes pass…
        assert!(Payload::Dense { g: vec![0.0; 4] }.shape_error().is_none());
        assert!(Payload::Sign { n: 20, bits: vec![0; 3], scale: 1.0 }.shape_error().is_none());
        assert!(Payload::TopK { n: 20, idx: vec![1, 7], val: vec![0.5, -2.0] }
            .shape_error()
            .is_none());
        assert!(Payload::Ternary { n: 20, idx: vec![2, 9], neg: vec![0b01], mu: 1.5 }
            .shape_error()
            .is_none());
        assert!(Payload::Syn { m: 2, dx: vec![0.1; 8], dy: vec![0.2; 6], s: 1.0 }
            .shape_error()
            .is_none());
        // …lying headers and non-finite scales do not. A short bitset
        // would under-price `wire_bytes` — the ledger's honesty is the
        // point of the check.
        assert!(Payload::Sign { n: 20, bits: vec![0; 2], scale: 1.0 }.shape_error().is_some());
        assert!(Payload::Sign { n: 20, bits: vec![0; 3], scale: f32::NAN }
            .shape_error()
            .is_some());
        assert!(Payload::TopK { n: 20, idx: vec![1], val: vec![0.5, 0.5] }
            .shape_error()
            .is_some());
        assert!(Payload::TopK { n: 20, idx: vec![25], val: vec![0.5] }.shape_error().is_some());
        assert!(Payload::Ternary { n: 20, idx: vec![2, 9], neg: vec![], mu: 1.5 }
            .shape_error()
            .is_some());
        assert!(Payload::Syn { m: 0, dx: vec![], dy: vec![], s: 1.0 }.shape_error().is_some());
        assert!(Payload::Syn { m: 3, dx: vec![0.1; 8], dy: vec![0.2; 6], s: 1.0 }
            .shape_error()
            .is_some());
        assert!(Payload::SynMulti { k: 0, m: 1, dxs: vec![], dys: vec![] }
            .shape_error()
            .is_some());
    }

    #[test]
    fn bit_packing_roundtrip() {
        let signs = [true, false, false, true, true, false, true, false, true];
        let bits = pack_bits(signs.iter().copied(), signs.len());
        assert_eq!(bits.len(), 2);
        for (i, &s) in signs.iter().enumerate() {
            assert_eq!(get_bit(&bits, i), s);
        }
    }
}
