//! Wire payloads with exact byte accounting.
//!
//! Every experiment reports "compression rate" = wire bytes / 4P (Eq. 1);
//! the numbers below are what a real implementation would put on the wire.

/// What a client uploads for one round.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Uncompressed gradient (FedAvg).
    Dense { g: Vec<f32> },
    /// Top-k values + u32 indices (DGC).
    TopK { n: usize, idx: Vec<u32>, val: Vec<f32> },
    /// Sign bit per coordinate + one f32 scale (signSGD w/ EF).
    Sign { n: usize, bits: Vec<u8>, scale: f32 },
    /// STC: top-k indices + sign bitset over those k + mean magnitude μ.
    Ternary { n: usize, idx: Vec<u32>, neg: Vec<u8>, mu: f32 },
    /// 3SFC: m synthetic samples (inputs + label logits) + scale s.
    Syn { m: usize, dx: Vec<f32>, dy: Vec<f32>, s: f32 },
    /// FedSynth: K_sim per-step synthetic batches (no scale).
    SynMulti { k: usize, m: usize, dxs: Vec<f32>, dys: Vec<f32> },
}

impl Payload {
    /// Exact upload size in bytes.
    ///
    /// Every length/shape header the enum carries (`n`, `m`, `k`) is
    /// charged as a u32 on the wire, exactly like the f32 scales already
    /// were — a real serializer has to send them for the receiver to
    /// frame the buffers. `Dense` carries no header field: the receiver
    /// knows the model size, so the 4P baseline stays exact (rate = 1).
    pub fn wire_bytes(&self) -> usize {
        match self {
            Payload::Dense { g } => 4 * g.len(),
            Payload::TopK { idx, val, .. } => 4 + 4 * idx.len() + 4 * val.len(),
            Payload::Sign { bits, .. } => 4 + bits.len() + 4,
            Payload::Ternary { idx, neg, .. } => 4 + 4 * idx.len() + neg.len() + 4,
            Payload::Syn { dx, dy, .. } => 4 + 4 * dx.len() + 4 * dy.len() + 4,
            Payload::SynMulti { dxs, dys, .. } => 8 + 4 * dxs.len() + 4 * dys.len(),
        }
    }

    /// Compression rate vs a dense f32 gradient of `n_params` (Eq. 1).
    pub fn rate(&self, n_params: usize) -> f64 {
        self.wire_bytes() as f64 / (4.0 * n_params as f64)
    }

    /// `1 / rate` — the "compression ratio ×" the paper's tables print.
    pub fn ratio(&self, n_params: usize) -> f64 {
        1.0 / self.rate(n_params).max(1e-300)
    }

    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Dense { .. } => "dense",
            Payload::TopK { .. } => "topk",
            Payload::Sign { .. } => "sign",
            Payload::Ternary { .. } => "ternary",
            Payload::Syn { .. } => "syn",
            Payload::SynMulti { .. } => "syn_multi",
        }
    }
}

/// Pack sign bits (true = negative) into a byte vector, LSB-first.
pub fn pack_bits(signs: impl Iterator<Item = bool>, n: usize) -> Vec<u8> {
    let mut out = vec![0u8; n.div_ceil(8)];
    for (i, s) in signs.enumerate() {
        if s {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    out
}

/// Read bit `i` from a packed bitset.
#[inline]
pub fn get_bit(bits: &[u8], i: usize) -> bool {
    bits[i / 8] & (1 << (i % 8)) != 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting() {
        let p = Payload::Dense { g: vec![0.0; 100] };
        assert_eq!(p.wire_bytes(), 400);
        assert_eq!(p.rate(100), 1.0);

        // 4 (n header) + 5 idx u32 + 5 val f32.
        let p = Payload::TopK { n: 100, idx: vec![0; 5], val: vec![0.0; 5] };
        assert_eq!(p.wire_bytes(), 4 + 40);
        assert!((p.ratio(100) - 400.0 / 44.0).abs() < 1e-12);

        // 4 (n header) + 13 sign bytes + 4 (scale).
        let p = Payload::Sign { n: 100, bits: vec![0; 13], scale: 1.0 };
        assert_eq!(p.wire_bytes(), 21);

        // 4 (n header) + 5 idx u32 + 1 sign byte + 4 (μ).
        let p = Payload::Ternary { n: 100, idx: vec![0; 5], neg: vec![0; 1], mu: 1.0 };
        assert_eq!(p.wire_bytes(), 4 + 20 + 1 + 4);

        // 4 (m header) + (64 + 8) f32 + 4 (scale).
        let p = Payload::Syn { m: 1, dx: vec![0.0; 64], dy: vec![0.0; 8], s: 1.0 };
        assert_eq!(p.wire_bytes(), 4 * (64 + 8 + 1) + 4);

        // 8 (k + m headers) + 2·(64 + 8) f32.
        let p = Payload::SynMulti {
            k: 2,
            m: 1,
            dxs: vec![0.0; 2 * 64],
            dys: vec![0.0; 2 * 8],
        };
        assert_eq!(p.wire_bytes(), 8 + 4 * 2 * (64 + 8));
    }

    #[test]
    fn bit_packing_roundtrip() {
        let signs = [true, false, false, true, true, false, true, false, true];
        let bits = pack_bits(signs.iter().copied(), signs.len());
        assert_eq!(bits.len(), 2);
        for (i, &s) in signs.iter().enumerate() {
            assert_eq!(get_bit(&bits, i), s);
        }
    }
}
