//! EF-residual spill codec for the lazy client store
//! ([`crate::coordinator::ClientStore`]).
//!
//! Between participations a lazy store evicts each client's dense
//! error-feedback vector and keeps only a compact slab, keyed by client
//! index. The codec must be **bit-exact**: EF memory feeds straight
//! back into the compressor's input (Eq. 6), so a single flipped bit in
//! a restored residual would fork the trajectory and break the store's
//! `lazy_state = false` ≡ `lazy_state = true` equivalence contract
//! (pinned by `tests/shard_test.rs`).
//!
//! Two slab encodings, selected by `[scale] spill`:
//!
//! * [`SpillKind::Boxed`] — the f32 vector moved off the hot path as-is
//!   (4 bytes/param, zero transcoding);
//! * [`SpillKind::Slab`] — the vector run through the dense wire codec
//!   ([`crate::compress::Payload::Dense`] `serialize`/`deserialize`):
//!   flat little-endian f32 bytes, the same machinery the uplink uses,
//!   so the spill format is exercised by the payload property suite.
//!
//! Both are lossless by construction; on top of either, an **all-zero
//! EF is elided entirely** ([`SpilledEf::Zero`]) — the common case for
//! clients that never accumulated error (EF disabled, or a compressor
//! with zero residual). Zero-detection compares *bit patterns*
//! (`to_bits() == 0`), not values: `-0.0 == 0.0` numerically, but
//! restoring `-0.0` as `+0.0` would not be bit-exact.

use crate::compress::Payload;
use crate::config::SpillKind;

/// A client's EF residual in its evicted (spilled) form.
#[derive(Clone, Debug)]
pub enum SpilledEf {
    /// All `n_params` coordinates are bit-pattern `+0.0` — nothing
    /// stored; restore synthesizes the zero vector.
    Zero,
    /// The exact f32 vector, boxed off the resident path.
    Boxed(Vec<f32>),
    /// Dense-payload wire bytes (flat little-endian f32).
    Slab(Vec<u8>),
}

impl SpilledEf {
    /// Heap bytes this spilled residual occupies (the store's memory
    /// accounting; 0 for an elided zero vector).
    pub fn spilled_bytes(&self) -> usize {
        match self {
            SpilledEf::Zero => 0,
            SpilledEf::Boxed(v) => 4 * v.len(),
            SpilledEf::Slab(b) => b.len(),
        }
    }
}

/// Encode an EF vector into its spill form.
pub fn spill(ef: &[f32], kind: SpillKind) -> SpilledEf {
    if ef.iter().all(|x| x.to_bits() == 0) {
        return SpilledEf::Zero;
    }
    match kind {
        SpillKind::Boxed => SpilledEf::Boxed(ef.to_vec()),
        SpillKind::Slab => {
            SpilledEf::Slab(Payload::Dense { g: ef.to_vec() }.serialize())
        }
    }
}

/// Decode a spill back to the dense EF vector. Bit-exact inverse of
/// [`spill`] for every f32 bit pattern (±0, subnormals, NaN payloads).
pub fn restore(spilled: &SpilledEf, n_params: usize) -> Vec<f32> {
    match spilled {
        SpilledEf::Zero => vec![0.0f32; n_params],
        SpilledEf::Boxed(v) => {
            debug_assert_eq!(v.len(), n_params, "boxed spill length drifted");
            v.clone()
        }
        SpilledEf::Slab(bytes) => {
            let p = Payload::deserialize("dense", bytes, n_params, 0, 0)
                .expect("slab spill is store-internal and framed at encode time");
            match p {
                Payload::Dense { g } => g,
                _ => unreachable!("'dense' deserializes to Payload::Dense"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn roundtrip_is_bit_exact_for_hostile_patterns() {
        // Negative zero, subnormals, and a payload-carrying NaN all
        // survive both encodings bit-for-bit.
        let ef = vec![
            1.5f32,
            -0.0,
            f32::from_bits(1),          // smallest subnormal
            f32::from_bits(0x7FC0_1234), // NaN with payload bits
            -3.25e-38,
            0.0,
        ];
        for kind in [SpillKind::Boxed, SpillKind::Slab] {
            let s = spill(&ef, kind);
            let back = restore(&s, ef.len());
            assert_eq!(bits(&back), bits(&ef), "{}", kind.name());
        }
    }

    #[test]
    fn all_zero_ef_is_elided() {
        let ef = vec![0.0f32; 64];
        for kind in [SpillKind::Boxed, SpillKind::Slab] {
            let s = spill(&ef, kind);
            assert!(matches!(s, SpilledEf::Zero), "{}", kind.name());
            assert_eq!(s.spilled_bytes(), 0);
            assert_eq!(restore(&s, 64), ef);
        }
    }

    #[test]
    fn negative_zero_defeats_elision() {
        // -0.0 == 0.0 numerically but its bit pattern must survive.
        let ef = vec![0.0f32, -0.0, 0.0];
        let s = spill(&ef, SpillKind::Slab);
        assert!(!matches!(s, SpilledEf::Zero));
        assert_eq!(bits(&restore(&s, 3)), bits(&ef));
    }

    #[test]
    fn spilled_bytes_accounts_for_the_slab() {
        let ef = vec![1.0f32; 10];
        assert_eq!(spill(&ef, SpillKind::Boxed).spilled_bytes(), 40);
        assert_eq!(spill(&ef, SpillKind::Slab).spilled_bytes(), 40);
    }
}
