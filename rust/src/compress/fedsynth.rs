//! FedSynth baseline (Hu et al. 2022): multi-step data distillation.
//!
//! The contrast class for 3SFC (paper §2, Table 1, Figs 2–3): distill the
//! accumulated gradient into K_sim per-step synthetic batches by
//! simulating K_sim inner SGD steps and minimizing the **L2 distance**
//! between the simulated and real model deltas. The deep unroll is what
//! makes it slow and collapse-prone — [`super::EncodeStats::step_norms`]
//! exposes the per-step gradient magnitudes so the Fig 3 explosion series
//! can be reproduced.

use anyhow::{bail, Result};

use super::{Compressor, DecodeCtx, EncodeCtx, EncodeStats, Payload};

pub struct FedSynth {
    /// Inner simulation depth K_sim (the paper's collapses at 128).
    pub k_sim: usize,
    /// Samples per simulated step.
    pub m: usize,
    /// Outer distillation iterations.
    pub steps: usize,
    pub lr_inner: f32,
    pub lr_syn: f32,
}

impl FedSynth {
    pub fn new(k_sim: usize, m: usize, steps: usize, lr_inner: f32, lr_syn: f32) -> FedSynth {
        assert!(k_sim >= 1 && m >= 1 && steps >= 1);
        FedSynth { k_sim, m, steps, lr_inner, lr_syn }
    }
}

impl Compressor for FedSynth {
    fn name(&self) -> String {
        format!("fedsynth(K={},S={})", self.k_sim, self.steps)
    }

    fn encode(
        &self,
        ctx: &mut EncodeCtx,
        target: &[f32],
    ) -> Result<(Payload, Vec<f32>, EncodeStats)> {
        let model = ctx.ops.model;
        let d = model.feature_len();
        let c = model.n_classes;
        let mut dxs = vec![0.0f32; self.k_sim * self.m * d];
        ctx.rng.fill_normal(&mut dxs, 0.5);
        let mut dys = vec![0.0f32; self.k_sim * self.m * c];

        let mut fit = f32::NAN;
        let mut step_norms = Vec::new();
        for _ in 0..self.steps {
            let (ndxs, ndys, f, norms) = ctx.ops.fedsynth_step(
                self.k_sim,
                self.m,
                ctx.w_global,
                target,
                &dxs,
                &dys,
                self.lr_inner,
                self.lr_syn,
            )?;
            dxs = ndxs;
            dys = ndys;
            fit = f;
            step_norms = norms;
        }

        let recon = ctx.ops.fedsynth_apply(
            self.k_sim,
            self.m,
            ctx.w_global,
            &dxs,
            &dys,
            self.lr_inner,
        )?;
        let stats = EncodeStats { fit, step_norms, ..EncodeStats::default() };
        Ok((
            Payload::SynMulti { k: self.k_sim, m: self.m, dxs, dys },
            recon,
            stats,
        ))
    }

    fn decode(&self, ctx: &DecodeCtx, payload: &Payload) -> Result<Vec<f32>> {
        let Payload::SynMulti { k, m, dxs, dys } = payload else {
            bail!("fedsynth got {:?}", payload.kind());
        };
        ctx.ops
            .fedsynth_apply(*k, *m, ctx.w_global, dxs, dys, self.lr_inner)
    }
}
