//! Downlink (server → client) broadcast compression — the E-3SFC
//! double-way extension (arXiv 2502.03092; STC, arXiv 1903.02891, makes
//! the same argument): once uplink payloads are compressed, the dense
//! model broadcast (4 + 4P bytes per client per round) dominates total
//! wire traffic, so the server synthesizes/sparsifies its *model delta*
//! too.
//!
//! Shape of the subsystem:
//!
//! * [`DeltaPayload`] is the broadcast wire format: either a dense
//!   [`DeltaPayload::Keyframe`] (priced exactly like the legacy dense
//!   broadcast, u32 length header + 4P) or a compressed
//!   [`DeltaPayload::Delta`] — a base model *version* plus any upload
//!   [`Payload`] from the existing zoo, encoding `w^t − ŵ_c` against the
//!   weights client `c` already holds.
//! * [`DownlinkTx`] is the server-side encoder slot. [`FedServer`]
//!   (`coordinator::fedserver`) stays compute-free: its driver passes the
//!   encoder into `next_directive`, and the server calls it once per
//!   dispatched client, charging `wire_bytes()` per broadcast.
//! * [`DenseDownlink`] is the bit-identical default: every broadcast is a
//!   keyframe sharing one `Arc` per model version — byte-for-byte and
//!   trajectory-identical to the pre-downlink dense path.
//! * [`DeltaDownlink`] holds the per-client **ledger**: the last version
//!   sent to each client and a *shadow replica* of the client's
//!   reconstructed weights. Each delta targets `w^t − shadow_c`, so the
//!   residual the inner compressor drops stays in the next round's
//!   target — the shadow **is** the server-side error-feedback memory
//!   (ŵ^{t+1} = ŵ^t + C(w^t − ŵ^t), the per-client form of E-3SFC's
//!   Eq. 6-style server EF). Clients that fall more than `gap` versions
//!   behind (stragglers, new arrivals) get a dense keyframe, which
//!   resynchronizes the shadow exactly.
//!
//! Determinism: encoding runs on the main thread in dispatch order with
//! a dedicated RNG stream, so downlink-compressed sessions stay
//! bit-identical across thread counts and session modes
//! (`tests/downlink_test.rs`).
//!
//! [`FedServer`]: crate::coordinator::FedServer

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::compress::{Compressor, EncodeCtx, Payload, Stc, ThreeSfc, TopK};
use crate::config::{DownlinkKind, ExperimentConfig};
use crate::model::ModelInfo;
use crate::runtime::FedOps;
use crate::util::rng::Rng;
use crate::util::vecmath;

/// What the server puts on the wire for one broadcast.
#[derive(Clone, Debug)]
pub enum DeltaPayload {
    /// Dense weights — the resynchronization frame. Priced exactly like
    /// the legacy dense broadcast (u32 length header + 4P), so an
    /// identity downlink is byte-identical to the pre-downlink ledger.
    /// `Arc`-backed: one allocation per model version, shared across the
    /// cohort and with the envelope's reconstruction cache.
    Keyframe { w: Arc<Vec<f32>> },
    /// A compressed model delta against the weights the client holds:
    /// `base` is the model version of those weights (the ledger's last
    /// acked version for this client), `inner` any upload payload
    /// encoding `w^t − ŵ_c`.
    Delta { base: u32, inner: Payload },
}

impl DeltaPayload {
    /// Exact broadcast size in bytes. Keyframes charge the u32 length
    /// header + dense f32s (= the legacy dense-broadcast price); deltas
    /// charge a u32 base-version header + the inner payload's own
    /// wire bytes.
    pub fn wire_bytes(&self) -> usize {
        match self {
            DeltaPayload::Keyframe { w } => 4 + 4 * w.len(),
            DeltaPayload::Delta { inner, .. } => 4 + inner.wire_bytes(),
        }
    }

    /// Downlink compression ratio (× vs the dense keyframe price).
    pub fn ratio(&self, n_params: usize) -> f64 {
        (4 + 4 * n_params) as f64 / (self.wire_bytes() as f64).max(1e-300)
    }

    /// Out-of-band payload tag: `"keyframe"` or `"delta:<inner kind>"`.
    pub fn kind(&self) -> String {
        match self {
            DeltaPayload::Keyframe { .. } => "keyframe".to_string(),
            DeltaPayload::Delta { inner, .. } => format!("delta:{}", inner.kind()),
        }
    }

    /// The ledger version a delta is based on (`None` for keyframes).
    pub fn base_version(&self) -> Option<usize> {
        match self {
            DeltaPayload::Keyframe { .. } => None,
            DeltaPayload::Delta { base, .. } => Some(*base as usize),
        }
    }

    /// The actual wire encoding (little-endian), mirroring
    /// [`Payload::serialize`]: exactly the headers [`wire_bytes`] charges,
    /// in declaration order — `serialize().len() == wire_bytes()` is
    /// property-tested (`tests/prop_compressor_test.rs`).
    ///
    /// [`wire_bytes`]: DeltaPayload::wire_bytes
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.wire_bytes());
        match self {
            DeltaPayload::Keyframe { w } => {
                out.extend((w.len() as u32).to_le_bytes());
                for v in w.iter() {
                    out.extend(v.to_le_bytes());
                }
            }
            DeltaPayload::Delta { base, inner } => {
                out.extend(base.to_le_bytes());
                out.extend(inner.serialize());
            }
        }
        out
    }

    /// Inverse of [`DeltaPayload::serialize`]. `kind` is the out-of-band
    /// tag ([`DeltaPayload::kind`]); model geometry supplies the shapes
    /// the wire format does not repeat, exactly like
    /// [`Payload::deserialize`].
    pub fn deserialize(
        kind: &str,
        bytes: &[u8],
        n_params: usize,
        feature_len: usize,
        n_classes: usize,
    ) -> Result<DeltaPayload> {
        if kind == "keyframe" {
            ensure!(bytes.len() >= 4, "truncated keyframe header");
            let n = u32::from_le_bytes(bytes[..4].try_into().unwrap()) as usize;
            ensure!(n == n_params, "keyframe for {n} params, model has {n_params}");
            ensure!(bytes.len() == 4 + 4 * n, "keyframe length mismatch");
            let w = bytes[4..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            return Ok(DeltaPayload::Keyframe { w: Arc::new(w) });
        }
        let Some(inner_kind) = kind.strip_prefix("delta:") else {
            bail!("unknown downlink payload kind '{kind}'");
        };
        ensure!(bytes.len() >= 4, "truncated delta base-version header");
        let base = u32::from_le_bytes(bytes[..4].try_into().unwrap());
        let inner =
            Payload::deserialize(inner_kind, &bytes[4..], n_params, feature_len, n_classes)?;
        Ok(DeltaPayload::Delta { base, inner })
    }
}

/// The server-side downlink encoder slot.
///
/// Object-safe so [`crate::coordinator::FedServer`] can take
/// `&mut dyn DownlinkTx` per `next_directive` pump and stay compute-free
/// — all encoding state (ledger, shadows, RNG) lives behind this trait,
/// held by the driver.
///
/// `encode` returns the wire payload *and* the exact weights the client
/// reconstructs from it (keyframe weights, or `shadow + decode(delta)`),
/// which the broadcast envelope carries as its reconstruction cache —
/// the mirror of `Upload::recon` on the uplink.
pub trait DownlinkTx {
    fn name(&self) -> String;

    /// Encode the broadcast for `client` at model `version` (the server
    /// round counter) with current global weights `w`.
    fn encode(
        &mut self,
        client: usize,
        version: usize,
        w: &[f32],
    ) -> Result<(DeltaPayload, Arc<Vec<f32>>)>;
}

/// The bit-identical default: every broadcast is a dense keyframe.
///
/// Keeps one `Arc` per model version (the version only changes at an
/// aggregation step), so a cohort of N clients — or an async session's
/// K−1 same-version redispatches — share a single clone of the weights,
/// exactly like the pre-downlink `w_cache`.
#[derive(Default)]
pub struct DenseDownlink {
    cache: Option<(usize, Arc<Vec<f32>>)>,
}

impl DenseDownlink {
    pub fn new() -> DenseDownlink {
        DenseDownlink { cache: None }
    }
}

impl DownlinkTx for DenseDownlink {
    fn name(&self) -> String {
        "identity".to_string()
    }

    fn encode(
        &mut self,
        _client: usize,
        version: usize,
        w: &[f32],
    ) -> Result<(DeltaPayload, Arc<Vec<f32>>)> {
        let arc = match &self.cache {
            Some((v, a)) if *v == version => Arc::clone(a),
            _ => {
                let a = Arc::new(w.to_vec());
                self.cache = Some((version, Arc::clone(&a)));
                a
            }
        };
        Ok((DeltaPayload::Keyframe { w: Arc::clone(&arc) }, arc))
    }
}

/// One ledger entry: what the server knows client `c` holds.
struct LedgerSlot {
    /// Model version of the client's weights (last broadcast sent).
    version: usize,
    /// Exact replica of the client's reconstructed weights ŵ_c. The
    /// residual `w^t − shadow` accumulates everything past deltas
    /// dropped, so this doubles as the per-client server-side EF memory.
    shadow: Vec<f32>,
}

/// Compressing downlink: per-client version ledger + shadow-replica EF,
/// any zoo [`Compressor`] on the model delta.
pub struct DeltaDownlink<'a> {
    ops: FedOps<'a>,
    comp: Box<dyn Compressor>,
    /// Keyframe fallback threshold: a client whose ledger version trails
    /// the current model by *more than* `gap` versions is resynchronized
    /// with a dense keyframe (`gap = 0` → keyframe whenever the version
    /// advanced at all, i.e. dense-equivalent in server-paced sessions).
    gap: usize,
    /// Dedicated stream (synthetic-feature init for a 3SFC downlink);
    /// encoding happens sequentially in dispatch order on the main
    /// thread, so consumption is thread-count independent.
    rng: Rng,
    slots: Vec<Option<LedgerSlot>>,
    /// One dense clone per model version for keyframe broadcasts.
    kf_cache: Option<(usize, Arc<Vec<f32>>)>,
    /// Keyframes / deltas sent (diagnostics, tests).
    pub keyframes: u64,
    pub deltas: u64,
}

impl<'a> DeltaDownlink<'a> {
    pub fn new(
        ops: FedOps<'a>,
        comp: Box<dyn Compressor>,
        n_clients: usize,
        gap: usize,
        rng: Rng,
    ) -> DeltaDownlink<'a> {
        DeltaDownlink {
            ops,
            comp,
            gap,
            rng,
            slots: (0..n_clients).map(|_| None).collect(),
            kf_cache: None,
            keyframes: 0,
            deltas: 0,
        }
    }

    /// The ledger's last-sent model version for `client` (tests).
    pub fn ledger_version(&self, client: usize) -> Option<usize> {
        self.slots.get(client)?.as_ref().map(|s| s.version)
    }

    /// The shadow replica of `client`'s weights (tests pin it against
    /// the client's actual reconstruction bit-for-bit).
    pub fn shadow(&self, client: usize) -> Option<&[f32]> {
        self.slots.get(client)?.as_ref().map(|s| s.shadow.as_slice())
    }

    fn keyframe(&mut self, client: usize, version: usize, w: &[f32]) -> (DeltaPayload, Arc<Vec<f32>>) {
        let arc = match &self.kf_cache {
            Some((v, a)) if *v == version => Arc::clone(a),
            _ => {
                let a = Arc::new(w.to_vec());
                self.kf_cache = Some((version, Arc::clone(&a)));
                a
            }
        };
        // The keyframe resynchronizes the shadow exactly — any
        // accumulated EF residual is flushed by construction.
        self.slots[client] = Some(LedgerSlot { version, shadow: w.to_vec() });
        self.keyframes += 1;
        (DeltaPayload::Keyframe { w: Arc::clone(&arc) }, arc)
    }
}

impl DownlinkTx for DeltaDownlink<'_> {
    fn name(&self) -> String {
        format!("{}(gap {})", self.comp.name(), self.gap)
    }

    fn encode(
        &mut self,
        client: usize,
        version: usize,
        w: &[f32],
    ) -> Result<(DeltaPayload, Arc<Vec<f32>>)> {
        ensure!(client < self.slots.len(), "downlink encode for unknown client {client}");
        let stale = match &self.slots[client] {
            None => return Ok(self.keyframe(client, version, w)),
            Some(s) => version.saturating_sub(s.version),
        };
        if stale > self.gap {
            return Ok(self.keyframe(client, version, w));
        }
        let mut slot = self.slots[client].take().expect("ledger slot checked above");
        // Delta target: everything the client is missing, *including* the
        // residual of past compressed deltas (shadow-replica EF).
        let target = vecmath::sub(w, &slot.shadow);
        // The encoder optimizes at the weights the client actually holds
        // (a 3SFC downlink decodes at ŵ_c, Eq. 10 symmetry).
        let mut ctx =
            EncodeCtx { ops: &self.ops, w_global: &slot.shadow, rng: &mut self.rng };
        let (inner, recon, _stats) = self.comp.encode(&mut ctx, &target)?;
        vecmath::add_assign(&mut slot.shadow, &recon);
        let base = slot.version as u32;
        slot.version = version;
        let w_client = Arc::new(slot.shadow.clone());
        self.slots[client] = Some(slot);
        self.deltas += 1;
        Ok((DeltaPayload::Delta { base, inner }, w_client))
    }
}

/// Build the downlink encoder an [`ExperimentConfig`] asks for.
///
/// Identity (the default) is [`DenseDownlink`] — bit-identical to the
/// pre-downlink dense path. The compressed kinds wrap a zoo encoder in a
/// [`DeltaDownlink`]: 3SFC reuses the uplink's synthetic-feature knobs
/// (`budget_mult`, `syn_steps`, `lr_syn`, `lambda`); top-k takes
/// `downlink_rate` or, at 0, the 3SFC byte budget (the same protocol the
/// uplink zoo uses); STC takes `downlink_rate` or its natural 1/32.
pub fn build_downlink<'a>(
    cfg: &ExperimentConfig,
    model: &ModelInfo,
    ops: FedOps<'a>,
    rng: Rng,
) -> Box<dyn DownlinkTx + 'a> {
    let n = model.params;
    let comp: Box<dyn Compressor> = match cfg.downlink {
        DownlinkKind::Identity => return Box::new(DenseDownlink::new()),
        DownlinkKind::ThreeSfc => Box::new(ThreeSfc::new(
            cfg.syn_m(),
            cfg.syn_steps,
            cfg.lr_syn,
            cfg.lambda,
        )),
        DownlinkKind::TopK => {
            let k = if cfg.downlink_rate > 0.0 {
                ((n as f64 * cfg.downlink_rate).round() as usize).clamp(1, n)
            } else {
                (model.syn_payload_bytes(cfg.syn_m()).saturating_sub(4) / 8).clamp(1, n)
            };
            Box::new(TopK::new(k))
        }
        DownlinkKind::Stc => {
            let rate = if cfg.downlink_rate > 0.0 { cfg.downlink_rate } else { 1.0 / 32.0 };
            Box::new(Stc::with_rate(n, rate))
        }
    };
    Box::new(DeltaDownlink::new(ops, comp, cfg.n_clients, cfg.downlink_gap, rng))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Backend, NativeBackend};

    #[test]
    fn delta_payload_byte_accounting_and_roundtrip() {
        let kf = DeltaPayload::Keyframe { w: Arc::new(vec![0.5f32; 10]) };
        assert_eq!(kf.wire_bytes(), 4 + 40, "keyframe = the legacy dense broadcast price");
        assert_eq!(kf.kind(), "keyframe");
        assert_eq!(kf.base_version(), None);

        let delta = DeltaPayload::Delta {
            base: 7,
            inner: Payload::TopK { n: 10, idx: vec![1, 4], val: vec![0.5, -1.0] },
        };
        assert_eq!(delta.wire_bytes(), 4 + (4 + 8 + 8));
        assert_eq!(delta.kind(), "delta:topk");
        assert_eq!(delta.base_version(), Some(7));
        assert!(delta.ratio(10) > 1.0);

        for p in [kf, delta] {
            let bytes = p.serialize();
            assert_eq!(bytes.len(), p.wire_bytes(), "{}", p.kind());
            let back = DeltaPayload::deserialize(&p.kind(), &bytes, 10, 4, 3).unwrap();
            assert_eq!(back.kind(), p.kind());
            assert_eq!(back.serialize(), bytes, "{} roundtrip", p.kind());
        }
    }

    #[test]
    fn delta_payload_rejects_malformed() {
        let kf = DeltaPayload::Keyframe { w: Arc::new(vec![0.0f32; 10]) };
        let bytes = kf.serialize();
        // Truncated, trailing, wrong model size, unknown kind.
        assert!(DeltaPayload::deserialize("keyframe", &bytes[..bytes.len() - 1], 10, 4, 3)
            .is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(DeltaPayload::deserialize("keyframe", &trailing, 10, 4, 3).is_err());
        assert!(DeltaPayload::deserialize("keyframe", &bytes, 12, 4, 3).is_err());
        assert!(DeltaPayload::deserialize("zip", &bytes, 10, 4, 3).is_err());
        // A delta with an out-of-range inner index must not survive.
        let bad = DeltaPayload::Delta {
            base: 0,
            inner: Payload::TopK { n: 10, idx: vec![99], val: vec![1.0] },
        };
        assert!(DeltaPayload::deserialize("delta:topk", &bad.serialize(), 10, 4, 3).is_err());
    }

    #[test]
    fn dense_downlink_shares_one_arc_per_version() {
        let mut dl = DenseDownlink::new();
        let w = vec![1.0f32, 2.0];
        let (p0, r0) = dl.encode(0, 5, &w).unwrap();
        let (_p1, r1) = dl.encode(1, 5, &w).unwrap();
        assert!(Arc::ptr_eq(&r0, &r1), "same version → same allocation");
        let DeltaPayload::Keyframe { w: kw } = p0 else { panic!("identity sends keyframes") };
        assert!(Arc::ptr_eq(&kw, &r0), "payload and recon share the Arc");
        // A new version invalidates the cache.
        let (_, r2) = dl.encode(0, 6, &w).unwrap();
        assert!(!Arc::ptr_eq(&r0, &r2));
        assert_eq!(*r2, w);
    }

    #[test]
    fn delta_downlink_ledger_keyframes_then_deltas_and_gap_resync() {
        let backend = NativeBackend::new();
        let ops = FedOps::new(&backend, "mlp_small").unwrap();
        let n = ops.model.params;
        let ops2 = FedOps::new(&backend, "mlp_small").unwrap();
        let comp: Box<dyn Compressor> = Box::new(TopK::new(n / 10));
        let mut dl = DeltaDownlink::new(ops2, comp, 2, 1, Rng::new(7));

        let w0 = backend.load_init(ops.model).unwrap();
        // First contact is always a keyframe and seeds the shadow exactly.
        let (p, recon) = dl.encode(0, 0, &w0).unwrap();
        assert_eq!(p.kind(), "keyframe");
        assert_eq!(*recon, w0);
        assert_eq!(dl.ledger_version(0), Some(0));
        assert_eq!(dl.shadow(0).unwrap(), &w0[..]);

        // One version later: a delta against base 0, and the returned
        // reconstruction is exactly shadow_before + decode(inner).
        let mut w1 = w0.clone();
        for (i, v) in w1.iter_mut().enumerate() {
            *v += 0.01 * ((i % 13) as f32 - 6.0);
        }
        let shadow_before = dl.shadow(0).unwrap().to_vec();
        let (p, recon) = dl.encode(0, 1, &w1).unwrap();
        assert_eq!(p.base_version(), Some(0));
        let DeltaPayload::Delta { inner, .. } = &p else { panic!("expected a delta") };
        let dctx = crate::compress::DecodeCtx { ops: &ops, w_global: &shadow_before };
        let decoded = TopK::new(n / 10).decode(&dctx, inner).unwrap();
        let mut expect = shadow_before.clone();
        vecmath::add_assign(&mut expect, &decoded);
        for (a, b) in recon.iter().zip(expect.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "recon must be shadow + decode(inner)");
        }
        assert_eq!(dl.shadow(0).unwrap(), &expect[..]);
        assert_eq!((dl.keyframes, dl.deltas), (1, 1));

        // A client 3 versions behind gap=1 is resynchronized densely.
        let (p, recon) = dl.encode(0, 4, &w1).unwrap();
        assert_eq!(p.kind(), "keyframe", "stale past the gap → keyframe");
        assert_eq!(*recon, w1);
        assert_eq!(dl.ledger_version(0), Some(4));

        // An unseen client starts with a keyframe regardless of version.
        let (p, _) = dl.encode(1, 4, &w1).unwrap();
        assert_eq!(p.kind(), "keyframe");
    }

    #[test]
    fn delta_downlink_ef_residual_is_carried_by_the_shadow() {
        // With a heavily truncating inner compressor, w − shadow after a
        // delta is exactly the dropped residual, and the next target
        // includes it — the EF identity ŵ' = ŵ + C(w − ŵ).
        let backend = NativeBackend::new();
        let ops = FedOps::new(&backend, "mlp_small").unwrap();
        let comp: Box<dyn Compressor> = Box::new(TopK::new(1));
        let mut dl = DeltaDownlink::new(ops, comp, 1, usize::MAX, Rng::new(3));
        let ops_chk = FedOps::new(&backend, "mlp_small").unwrap();
        let w0 = backend.load_init(ops_chk.model).unwrap();
        dl.encode(0, 0, &w0).unwrap();
        let mut w1 = w0.clone();
        w1[0] += 1.0;
        w1[1] += 0.25;
        dl.encode(0, 1, &w1).unwrap();
        // Top-1 keeps only coordinate 0; the shadow carries the miss.
        let shadow = dl.shadow(0).unwrap();
        assert!((shadow[0] - w1[0]).abs() < 1e-6);
        assert_eq!(shadow[1], w0[1], "dropped coordinate stays in the residual");
        // Next delta at the same weights: the residual is the target.
        let (p, recon) = dl.encode(0, 2, &w1).unwrap();
        assert_eq!(p.base_version(), Some(1));
        assert!(
            (recon[1] - w1[1]).abs() < 1e-6,
            "EF residual recovered one round later"
        );
    }
}
