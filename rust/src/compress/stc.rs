//! STC — sparse ternary compression (Sattler et al. 2019): top-k
//! sparsification + ternarization (sign × mean magnitude of the kept
//! coordinates) + error feedback. The paper runs STC at its natural 32×
//! rate; `with_rate` picks k so the honest wire size hits that rate.

use anyhow::{bail, Result};

use super::payload::{get_bit, pack_bits};
use super::{Compressor, DecodeCtx, EncodeCtx, EncodeStats, Payload};
use crate::util::vecmath;

pub struct Stc {
    k: usize,
}

impl Stc {
    pub fn new(k: usize) -> Stc {
        assert!(k >= 1);
        Stc { k }
    }

    /// Pick k so wire bytes ≈ rate · 4n.
    /// Wire = 4 (n header) + 4k (idx) + k/8 (signs) + 4 (μ) ≈ 4.125k + 8.
    pub fn with_rate(n_params: usize, rate: f64) -> Stc {
        let budget = rate * 4.0 * n_params as f64;
        let k = ((budget - 8.0) / 4.125).floor().max(1.0) as usize;
        Stc::new(k.min(n_params))
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

impl Compressor for Stc {
    fn name(&self) -> String {
        format!("stc(k={})", self.k)
    }

    fn encode(
        &self,
        _ctx: &mut EncodeCtx,
        target: &[f32],
    ) -> Result<(Payload, Vec<f32>, EncodeStats)> {
        let n = target.len();
        let k = self.k.min(n);
        let idx = vecmath::topk_indices(target, k);
        let mu = (idx
            .iter()
            .map(|&i| target[i as usize].abs() as f64)
            .sum::<f64>()
            / k.max(1) as f64) as f32;
        let neg = pack_bits(idx.iter().map(|&i| target[i as usize] < 0.0), k);
        let mut recon = vec![0.0f32; n];
        for (j, &i) in idx.iter().enumerate() {
            recon[i as usize] = if get_bit(&neg, j) { -mu } else { mu };
        }
        Ok((Payload::Ternary { n, idx, neg, mu }, recon, EncodeStats::default()))
    }

    fn decode(&self, _ctx: &DecodeCtx, payload: &Payload) -> Result<Vec<f32>> {
        let Payload::Ternary { n, idx, neg, mu } = payload else {
            bail!("stc got {:?}", payload.kind());
        };
        let mut g = vec![0.0f32; *n];
        for (j, &i) in idx.iter().enumerate() {
            g[i as usize] = if get_bit(neg, j) { -*mu } else { *mu };
        }
        Ok(g)
    }
}
