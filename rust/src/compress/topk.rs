//! DGC-style top-k sparsification (Lin et al. 2017) — the paper's main
//! equal-budget competitor. Sends the k largest-magnitude coordinates;
//! error feedback (kept by the coordinator) supplies the momentum-style
//! correction of the dropped mass.

use anyhow::{bail, Result};

use super::{Compressor, DecodeCtx, EncodeCtx, EncodeStats, Payload};
use crate::util::vecmath;

pub struct TopK {
    k: usize,
}

impl TopK {
    pub fn new(k: usize) -> TopK {
        assert!(k >= 1);
        TopK { k }
    }

    pub fn k(&self) -> usize {
        self.k
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("dgc(k={})", self.k)
    }

    fn encode(
        &self,
        _ctx: &mut EncodeCtx,
        target: &[f32],
    ) -> Result<(Payload, Vec<f32>, EncodeStats)> {
        let k = self.k.min(target.len());
        let idx = vecmath::topk_indices(target, k);
        let val: Vec<f32> = idx.iter().map(|&i| target[i as usize]).collect();
        let mut recon = vec![0.0f32; target.len()];
        for (&i, &v) in idx.iter().zip(val.iter()) {
            recon[i as usize] = v;
        }
        Ok((
            Payload::TopK { n: target.len(), idx, val },
            recon,
            EncodeStats::default(),
        ))
    }

    fn decode(&self, _ctx: &DecodeCtx, payload: &Payload) -> Result<Vec<f32>> {
        let Payload::TopK { n, idx, val } = payload else {
            bail!("topk got {:?}", payload.kind());
        };
        let mut g = vec![0.0f32; *n];
        for (&i, &v) in idx.iter().zip(val.iter()) {
            g[i as usize] = v;
        }
        Ok(g)
    }
}
