//! fed3sfc CLI — the leader entrypoint.
//!
//! Subcommands:
//!   run            run one FL experiment (flags or --config preset)
//!   bench          deterministic adversarial scenarios (snapshot-tested)
//!   report         summarize a metrics JSONL file from `run`
//!   partition-viz  print the Fig-5-style Dirichlet partition histogram
//!   list-models    list models/ops available in the artifact manifest
//!   info           runtime/platform details
//!
//! Example:
//!   fed3sfc run --dataset synth_mnist --compressor 3sfc --clients 10 \
//!               --rounds 30 --k 5 --metrics run.jsonl

use anyhow::{bail, Result};

use fed3sfc::cli::Args;
use fed3sfc::config::{
    AggregatorKind, BackendKind, CompressorKind, DatasetKind, DownlinkKind,
    ExperimentConfig, NetworkKind, ScheduleKind, ServerOptKind, SessionKind,
    SpillKind,
};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::data::{dirichlet_partition, Dataset};
use fed3sfc::runtime::{open_backend, open_backend_kind, Backend};
use fed3sfc::simnet::ByzantineMode;
use fed3sfc::util::rng::{stream, Rng};

const USAGE: &str = "\
fed3sfc — Single-Step Synthetic Features Compressor for federated learning

USAGE: fed3sfc <run|bench|report|partition-viz|list-models|info> [--options]

run options:
  --config PATH          TOML preset (flags below override it)
  --dataset NAME         synth_mnist|synth_emnist|synth_fmnist|synth_cifar10|synth_cifar100|synth_small
  --model NAME           manifest model key (default: dataset pairing)
  --compressor NAME      fedavg|dgc|signsgd|stc|3sfc|fedsynth
  --clients N --rounds N --k {1|5|10} --lr F
  --budget-mult {1|2|4}  3SFC budget B, 2B, 4B (m = 1,2,4 samples)
  --syn-steps N --lr-syn F --lambda F
  --no-ef                disable error feedback (Table 4 ablation)
  --topk-rate F          explicit DGC rate (Fig 1 sweeps)
  --alpha F              Dirichlet concentration (default 0.5)
  --train-samples N --test-samples N --seed N --eval-every N
  --metrics PATH         write per-round JSONL
  --schedule NAME        full|uniform|round_robin (default full)
  --client-frac F        fraction of clients per round, in (0,1]
  --server-opt NAME      gd|momentum|fedadam (default gd)
  --server-lr F          server learning rate (default 1.0 = paper Eq. 3)
  --server-momentum F    heavy-ball beta for --server-opt momentum
  --beta1 F --beta2 F --tau F   FedAdam moments + adaptivity
  --network NAME         edge|datacenter|custom (default edge)
  --up-mbps F --down-mbps F --latency-ms F   custom link rates
  --jitter F             per-client bandwidth spread in [0,1) (default 0)
  --session NAME         sync|deadline|async aggregation policy
                         (default sync = the paper's blocking rounds)
  --deadline-s F         semi-sync aggregation deadline, virtual seconds
  --buffer-k N           async: aggregate every K arrivals
  --staleness-decay F    staleness discount base in (0,1] (default 0.5)
  --downlink NAME        identity|3sfc|topk|stc broadcast compression
                         (default identity = dense keyframes; others send
                         compressed model deltas with server-side EF)
  --downlink-gap N       keyframe fallback: clients > N versions behind
                         get a dense keyframe (default 4)
  --downlink-rate F      explicit downlink top-k/STC rate in [0,1]
                         (default 0 = budget-matched)
  --threads N            worker threads for the per-round client fan-out
                         (0 = auto: all cores, or FED3SFC_THREADS;
                         1 = sequential; results identical for any N)
  --faults               enable the [faults] adversarial-reality layer
  --dropout-p F          per-upload dropout probability in [0,1]
  --recover-s F          crash-and-recover window, virtual seconds
  --diurnal-amp F        diurnal availability wave amplitude in [0,1]
  --diurnal-period-s F   diurnal wave period, virtual seconds
  --tiers N              correlated device-class tiers (1 = homogeneous)
  --tier-spread F        tier severity in [0,1]
  --tier-compute-s F     worst-tier extra compute delay, virtual seconds
  --byzantine-frac F     compromised-client fraction in [0,1] (the attack
                         fires only while --faults is on)
  --byzantine-mode NAME  sign_flip|scale|gaussian|collude recon attack
  --fault-trace PATH     JSONL outage trace; replaces the dropout draw
  --aggregator NAME      weighted_mean|trimmed_mean|coordinate_median|
                         krum|multi_krum|norm_clip robust aggregation
  --trim-beta F          trimmed-mean per-side trim fraction in [0,0.5)
  --krum-f N --krum-m N  Krum assumed attackers / Multi-Krum picks
  --clip-tau F           norm-clip threshold (0 = median-norm auto)
  --reliability          quarantine chronically failing clients
  --quarantine-rounds N  rounds a quarantined client sits out (default 3)
  --reliability-alpha F  dropout EWMA smoothing factor in (0,1]
  --reliability-threshold F  EWMA level that triggers quarantine
  --n-shards N           edge-aggregator shards (default 1; trajectories
                         are bit-identical for every N)
  --lazy-state           spill per-client EF state between participations
                         (resident memory O(cohort), not O(clients))
  --spill NAME           boxed|slab spilled-EF representation (default
                         slab = compact wire-format bytes)
  --backend NAME         auto|pjrt|native (default auto: PJRT when the
                         artifact dir exists, else the pure-Rust native
                         backend; FED3SFC_BACKEND overrides auto)

bench scenarios (deterministic stdout, pinned by snapshot tests):
  bench byzantine        attack x aggregator defense matrix on a toy
                         objective [--clients --seed], plus envelope probes
  bench faults           one fault stream through sync|deadline|async
  bench tiers            device-class fate table [--clients --seed --tiers
                         --tier-spread --tier-compute-s --dropout-p]
  bench new [--out PATH] emit a ready-to-run [faults]+[defense] TOML preset
  bench scale            million-client shard/spill accounting [--clients
                         --cohort --shards --rounds --params --measure]

report options: --metrics PATH   (JSONL written by run --metrics)
partition-viz options: --dataset --clients --alpha --samples --seed
list-models / info options: --backend
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(
        argv,
        &["no-ef", "help", "verbose", "faults", "reliability", "lazy-state", "measure"],
    )?;
    if args.has_flag("help") || args.subcommand.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_str() {
        "run" => cmd_run(&args),
        "bench" => fed3sfc::cli::scenarios::cmd_bench(&args),
        "report" => fed3sfc::cli::scenarios::cmd_report(&args),
        "partition-viz" => cmd_partition_viz(&args),
        "list-models" => cmd_list_models(&args),
        "info" => cmd_info(&args),
        other => bail!("unknown subcommand '{other}' (try --help)"),
    }
}

/// Open the backend a bare subcommand asks for (`--backend`, else auto).
fn backend_from_args(args: &Args) -> Result<Box<dyn Backend>> {
    let kind = match args.get("backend") {
        Some(v) => BackendKind::parse(v)?,
        None => BackendKind::Auto,
    };
    open_backend_kind(kind)
}

fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::from_toml_file(path)?
    } else {
        ExperimentConfig::default()
    };
    if let Some(v) = args.get("dataset") {
        cfg.dataset = DatasetKind::parse(v)?;
    }
    if let Some(v) = args.get("model") {
        cfg.model = v.to_string();
    }
    if let Some(v) = args.get("compressor") {
        cfg.compressor = CompressorKind::parse(v)?;
    }
    cfg.n_clients = args.get_usize("clients", cfg.n_clients)?;
    cfg.rounds = args.get_usize("rounds", cfg.rounds)?;
    cfg.k_local = args.get_usize("k", cfg.k_local)?;
    cfg.lr = args.get_f64("lr", cfg.lr as f64)? as f32;
    cfg.budget_mult = args.get_usize("budget-mult", cfg.budget_mult)?;
    cfg.syn_steps = args.get_usize("syn-steps", cfg.syn_steps)?;
    cfg.lr_syn = args.get_f64("lr-syn", cfg.lr_syn as f64)? as f32;
    cfg.lambda = args.get_f64("lambda", cfg.lambda as f64)? as f32;
    if args.has_flag("no-ef") {
        cfg.error_feedback = false;
    }
    cfg.topk_rate = args.get_f64("topk-rate", cfg.topk_rate)?;
    cfg.alpha = args.get_f64("alpha", cfg.alpha)?;
    cfg.train_samples = args.get_usize("train-samples", cfg.train_samples)?;
    cfg.test_samples = args.get_usize("test-samples", cfg.test_samples)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every)?;
    if let Some(v) = args.get("metrics") {
        cfg.metrics_path = v.to_string();
    }
    if let Some(v) = args.get("schedule") {
        cfg.schedule = ScheduleKind::parse(v)?;
    }
    cfg.client_frac = args.get_f64("client-frac", cfg.client_frac)?;
    if let Some(v) = args.get("server-opt") {
        cfg.server_opt = ServerOptKind::parse(v)?;
    }
    cfg.server_lr = args.get_f32("server-lr", cfg.server_lr)?;
    cfg.server_momentum = args.get_f32("server-momentum", cfg.server_momentum)?;
    cfg.adam_beta1 = args.get_f32("beta1", cfg.adam_beta1)?;
    cfg.adam_beta2 = args.get_f32("beta2", cfg.adam_beta2)?;
    cfg.adam_tau = args.get_f32("tau", cfg.adam_tau)?;
    if let Some(v) = args.get("network") {
        cfg.network = NetworkKind::parse(v)?;
    }
    cfg.net_up_mbps = args.get_f64("up-mbps", cfg.net_up_mbps)?;
    cfg.net_down_mbps = args.get_f64("down-mbps", cfg.net_down_mbps)?;
    cfg.net_latency_ms = args.get_f64("latency-ms", cfg.net_latency_ms)?;
    cfg.net_jitter = args.get_f64("jitter", cfg.net_jitter)?;
    if let Some(v) = args.get("session") {
        cfg.session = SessionKind::parse(v)?;
    }
    cfg.deadline_s = args.get_f64("deadline-s", cfg.deadline_s)?;
    cfg.buffer_k = args.get_usize("buffer-k", cfg.buffer_k)?;
    cfg.staleness_decay = args.get_f64("staleness-decay", cfg.staleness_decay)?;
    if let Some(v) = args.get("downlink") {
        cfg.downlink = DownlinkKind::parse(v)?;
    }
    cfg.downlink_gap = args.get_usize("downlink-gap", cfg.downlink_gap)?;
    cfg.downlink_rate = args.get_f64("downlink-rate", cfg.downlink_rate)?;
    if args.has_flag("faults") {
        cfg.faults = true;
    }
    cfg.fault_dropout_p = args.get_f64("dropout-p", cfg.fault_dropout_p)?;
    cfg.fault_recover_s = args.get_f64("recover-s", cfg.fault_recover_s)?;
    cfg.fault_diurnal_amp = args.get_f64("diurnal-amp", cfg.fault_diurnal_amp)?;
    cfg.fault_diurnal_period_s =
        args.get_f64("diurnal-period-s", cfg.fault_diurnal_period_s)?;
    cfg.fault_tiers = args.get_usize("tiers", cfg.fault_tiers)?;
    cfg.fault_tier_spread = args.get_f64("tier-spread", cfg.fault_tier_spread)?;
    cfg.fault_tier_compute_s = args.get_f64("tier-compute-s", cfg.fault_tier_compute_s)?;
    cfg.byzantine_frac = args.get_f64("byzantine-frac", cfg.byzantine_frac)?;
    if let Some(v) = args.get("byzantine-mode") {
        cfg.byzantine_mode = ByzantineMode::parse(v)?;
    }
    if let Some(v) = args.get("fault-trace") {
        cfg.fault_trace = v.to_string();
    }
    if let Some(v) = args.get("aggregator") {
        cfg.aggregator = AggregatorKind::parse(v)?;
    }
    cfg.trim_beta = args.get_f64("trim-beta", cfg.trim_beta)?;
    cfg.krum_f = args.get_usize("krum-f", cfg.krum_f)?;
    cfg.krum_m = args.get_usize("krum-m", cfg.krum_m)?;
    cfg.clip_tau = args.get_f64("clip-tau", cfg.clip_tau)?;
    if args.has_flag("reliability") {
        cfg.reliability = true;
    }
    cfg.quarantine_rounds = args.get_usize("quarantine-rounds", cfg.quarantine_rounds)?;
    cfg.reliability_alpha = args.get_f64("reliability-alpha", cfg.reliability_alpha)?;
    cfg.reliability_threshold =
        args.get_f64("reliability-threshold", cfg.reliability_threshold)?;
    cfg.n_shards = args.get_usize("n-shards", cfg.n_shards)?;
    if args.has_flag("lazy-state") {
        cfg.lazy_state = true;
    }
    if let Some(v) = args.get("spill") {
        cfg.spill = SpillKind::parse(v)?;
    }
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    if let Some(v) = args.get("backend") {
        cfg.backend = BackendKind::parse(v)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let backend = open_backend(&cfg)?;
    println!(
        "fed3sfc run: {} on {} ({} backend, {}), {} clients, {} rounds, K={}, method={}, \
         downlink={} (gap {}), schedule={} (frac {}), server_opt={}, network={} (jitter {}), \
         session={}",
        cfg.model_key(),
        cfg.dataset.name(),
        backend.backend_name(),
        backend.platform(),
        cfg.n_clients,
        cfg.rounds,
        cfg.k_local,
        cfg.compressor.name(),
        cfg.downlink.name(),
        cfg.downlink_gap,
        cfg.effective_schedule().name(),
        cfg.client_frac,
        cfg.server_opt.name(),
        cfg.network.name(),
        cfg.net_jitter,
        cfg.session.name(),
    );
    let mut exp = Experiment::new(cfg, backend.as_ref())?;
    println!("client execution: {} thread(s)", exp.threads());
    for _ in 0..exp.cfg.rounds {
        let rec = exp.run_round()?;
        println!(
            "round {:>4}  acc {:.4}  loss {:.4}  sel {:>3}  up {:>10} B (cum {:>12})  down {:>10} B (cum {:>12})  eff {:.3}  ratio {:>8.1}x  comm {:>7.2}s  vt {:>8.2}s  stale {:.2}  {:>7.0} ms (+{:.0} eval)",
            rec.round,
            rec.test_acc,
            rec.test_loss,
            rec.n_selected,
            rec.up_bytes_round,
            rec.up_bytes_cum,
            rec.down_bytes_round,
            rec.down_bytes_cum,
            rec.efficiency,
            rec.ratio,
            rec.comm_time_s,
            rec.sim_time_s,
            rec.stale_mean,
            rec.wall_ms,
            rec.eval_ms,
        );
    }
    exp.metrics.flush()?;
    let t = exp.traffic();
    println!(
        "done. best acc {:.4}; traffic up {} B / down {} B / total {} B; modeled comm time \
         ({} link): {:.1}s",
        exp.metrics.best_acc(),
        t.uplink_bytes,
        t.downlink_bytes,
        t.total_bytes(),
        exp.cfg.network.name(),
        t.comm_s,
    );
    if let Some(ws) = exp.pool_stats() {
        println!(
            "workers ({}): {} compiles ({:.0} ms), {} executions ({:.0} ms)",
            exp.threads(),
            ws.compiles,
            ws.compile_ms,
            ws.executions,
            ws.execute_ms
        );
    }
    let st = backend.stats();
    println!(
        "backend ({}): {} compiles ({:.0} ms), {} executions ({:.0} ms)",
        backend.backend_name(),
        st.compiles,
        st.compile_ms,
        st.executions,
        st.execute_ms
    );
    Ok(())
}

fn cmd_partition_viz(args: &Args) -> Result<()> {
    let dataset = DatasetKind::parse(args.get("dataset").unwrap_or("synth_mnist"))?;
    let clients = args.get_usize("clients", 20)?;
    let alpha = args.get_f64("alpha", 0.5)?;
    let samples = args.get_usize("samples", 2000)?;
    let seed = args.get_u64("seed", 42)?;
    let ds = Dataset::generate(dataset, samples, seed);
    // detlint: allow(DET003) -- CLI seed plumbing: rebuilds the experiment
    // root from `--seed` so the viz shows the exact partition a run uses.
    let mut rng = Rng::new(seed).split(stream::PARTITION);
    let parts = dirichlet_partition(&ds, clients, alpha, &mut rng);
    println!(
        "Dirichlet(alpha={alpha}) partition of {} ({} samples, {} classes) across {clients} clients:",
        dataset.name(),
        ds.n,
        ds.n_classes
    );
    print!("{}", fed3sfc::data::partition::render_partition(&ds, &parts));
    Ok(())
}

fn cmd_list_models(args: &Args) -> Result<()> {
    let backend = backend_from_args(args)?;
    println!("backend: {}", backend.backend_name());
    for (name, m) in &backend.manifest().models {
        println!(
            "{name:<14} P={:<8} in={:?} classes={} batch={} ops: {}",
            m.params,
            m.input_shape,
            m.n_classes,
            m.train_batch,
            m.ops.keys().cloned().collect::<Vec<_>>().join(", ")
        );
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let backend = backend_from_args(args)?;
    println!("backend:   {}", backend.backend_name());
    println!("models:    {}", backend.manifest().models.len());
    println!("platform:  {}", backend.platform());
    if backend.backend_name() == "pjrt" {
        println!("artifacts: {}", backend.manifest().dir.display());
    }
    Ok(())
}
