//! Mini property-testing harness.
//!
//! `check` runs a property across many seeded cases; on failure it retries
//! the failing case with progressively simpler sizes (shrinking-lite) and
//! reports the smallest reproducing seed/size so the case can be replayed
//! deterministically.

use crate::util::rng::Rng;

/// Per-case context handed to properties.
pub struct Case {
    pub rng: Rng,
    /// Suggested problem size for this case (grows with the case index).
    pub size: usize,
    pub seed: u64,
}

impl Case {
    /// Random f32 vector with values in roughly [-scale, scale].
    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len)
            .map(|_| (self.rng.f32() * 2.0 - 1.0) * scale)
            .collect()
    }

    /// Random length in [1, max].
    pub fn len(&mut self, max: usize) -> usize {
        1 + self.rng.below(max.max(1))
    }

    /// Random f32 vector whose |values| are pairwise distinct — for
    /// properties (top-k / STC selection stability) where magnitude ties
    /// would make the selected *set* legitimately ambiguous.
    pub fn vec_f32_distinct(&mut self, len: usize, scale: f32) -> Vec<f32> {
        let mut v: Vec<f32> = (0..len)
            .map(|i| {
                let sign = if self.rng.f64() < 0.5 { -1.0 } else { 1.0 };
                // Strictly increasing magnitude floor + random jitter that
                // cannot bridge adjacent floors, then shuffled into random
                // positions.
                sign * scale * (1.0 + i as f32 + 0.4 * self.rng.f32())
            })
            .collect();
        self.rng.shuffle(&mut v);
        v
    }

    /// Uniformly random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut p);
        p
    }
}

/// Run `cases` instances of `prop`. Panics with the failing seed/size.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Case) -> Result<(), String>,
{
    for i in 0..cases {
        let seed = 0x9E3779B97F4A7C15u64.wrapping_mul(i as u64 + 1);
        let size = 2 + i * 7 % 97;
        let mut case = Case { rng: Rng::new(seed), size, seed };
        if let Err(msg) = prop(&mut case) {
            // Shrinking-lite: try smaller sizes with the same seed to
            // report the simplest failing configuration.
            let mut simplest = (size, msg.clone());
            let mut s = size;
            while s > 2 {
                s /= 2;
                let mut c = Case { rng: Rng::new(seed), size: s, seed };
                if let Err(m) = prop(&mut c) {
                    simplest = (s, m);
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, size={}): {}",
                simplest.0, simplest.1
            );
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if (x - y).abs() > tol * (1.0 + x.abs().max(y.abs())) {
            return Err(format!("elem {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-reverse", 50, |c| {
            let n = c.len(64);
            let v = c.vec_f32(n, 1.0);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_close(&v, &w, 0.0)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_reports_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }
}
