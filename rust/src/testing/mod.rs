//! Testing substrates (offline replacement for `proptest`).

pub mod prop;
