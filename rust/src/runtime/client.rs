//! The PJRT backend: client + manifest + lazy executable cache, plus the
//! typed fed-op marshalling that binds the AOT HLO artifacts to the
//! [`Backend`] trait.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::model::{Manifest, ModelInfo};
use crate::runtime::backend::{Backend, BackendSpec, RuntimeStats};
use crate::runtime::literal::{f32_literal, i32_literal, scalar_f32, to_f32s, to_scalar_f32};

/// Owns the PJRT CPU client and the compiled-executable cache.
///
/// Single-threaded by design: the `xla` crate's client is not `Send`, so
/// a `PjrtBackend` never crosses a thread boundary. Parallel round
/// execution (see `coordinator::parallel`) instead gives every worker
/// thread its own backend — each with its own executable cache — opened
/// from the shared [`BackendSpec`], and moves plain `Send` data between
/// them.
pub struct PjrtBackend {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

/// The pre-backend-abstraction name; kept so downstream code and docs
/// that say `Runtime::open` keep compiling.
pub type Runtime = PjrtBackend;

impl PjrtBackend {
    /// Open the artifact directory (see [`crate::artifacts_dir`]).
    pub fn open(dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.manifest.model(name)
    }

    /// Compile (or fetch from cache) the executable for `file`.
    pub fn executable(&self, file: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.dir.join(file);
        // detlint: allow(DET001) -- RuntimeStats compile-time diagnostics:
        // reported at exit, never fed into trajectories or the sim clock.
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?,
        );
        let mut st = self.stats.borrow_mut();
        st.compiles += 1;
        st.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with the given input literals; returns the
    /// flattened output tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, file: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let exe = self.executable(file)?;
        // detlint: allow(DET001) -- RuntimeStats execute-time diagnostics:
        // reported at exit, never fed into trajectories or the sim clock.
        let t0 = Instant::now();
        let result = exe.execute::<Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(lit.to_tuple()?)
    }

    fn input_dims(model: &ModelInfo, lead: &[usize]) -> Vec<usize> {
        let mut dims = lead.to_vec();
        dims.extend_from_slice(&model.input_shape);
        dims
    }
}

#[allow(clippy::too_many_arguments)]
impl Backend for PjrtBackend {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    fn spec(&self) -> BackendSpec {
        BackendSpec::Pjrt { artifacts: self.manifest.dir.clone() }
    }

    fn load_init(&self, model: &ModelInfo) -> Result<Vec<f32>> {
        self.manifest.load_init(model)
    }

    fn local_train(
        &self,
        model: &ModelInfo,
        k: usize,
        w: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        let op = model.op(&format!("train_k{k}"))?;
        let b = op.batch;
        ensure!(w.len() == model.params, "w len");
        ensure!(xs.len() == k * b * model.feature_len(), "xs len");
        ensure!(ys.len() == k * b, "ys len");
        let out = self.execute(
            &op.file,
            &[
                f32_literal(&[model.params], w)?,
                f32_literal(&Self::input_dims(model, &[k, b]), xs)?,
                i32_literal(&[k, b], ys)?,
                scalar_f32(lr)?,
            ],
        )?;
        to_f32s(&out[0])
    }

    fn grad_batch(&self, model: &ModelInfo, w: &[f32], x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let op = model.op("grad")?;
        let b = op.batch;
        let out = self.execute(
            &op.file,
            &[
                f32_literal(&[model.params], w)?,
                f32_literal(&Self::input_dims(model, &[b]), x)?,
                i32_literal(&[b], y)?,
            ],
        )?;
        to_f32s(&out[0])
    }

    fn syn_step(
        &self,
        model: &ModelInfo,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let op = model.op(&format!("syn_step_m{m}"))?;
        ensure!(dx.len() == m * model.feature_len(), "dx len");
        ensure!(dy.len() == m * model.n_classes, "dy len");
        let out = self.execute(
            &op.file,
            &[
                f32_literal(&[model.params], w)?,
                f32_literal(&[model.params], g_target)?,
                f32_literal(&Self::input_dims(model, &[m]), dx)?,
                f32_literal(&[m, model.n_classes], dy)?,
                scalar_f32(lr_syn)?,
                scalar_f32(lambda)?,
            ],
        )?;
        Ok((to_f32s(&out[0])?, to_f32s(&out[1])?, to_scalar_f32(&out[2])?))
    }

    fn has_syn_opt(&self, model: &ModelInfo, m: usize, s: usize) -> bool {
        model.ops.contains_key(&format!("syn_opt_m{m}_s{s}"))
    }

    fn syn_opt(
        &self,
        model: &ModelInfo,
        m: usize,
        s: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f32, f32)> {
        let op = model.op(&format!("syn_opt_m{m}_s{s}"))?;
        let out = self.execute(
            &op.file,
            &[
                f32_literal(&[model.params], w)?,
                f32_literal(&[model.params], g_target)?,
                f32_literal(&Self::input_dims(model, &[m]), dx)?,
                f32_literal(&[m, model.n_classes], dy)?,
                scalar_f32(lr_syn)?,
                scalar_f32(lambda)?,
            ],
        )?;
        Ok((
            to_f32s(&out[0])?,
            to_f32s(&out[1])?,
            to_f32s(&out[2])?,
            to_f32s(&out[3])?,
            to_scalar_f32(&out[4])?,
            to_scalar_f32(&out[5])?,
        ))
    }

    fn syn_grad(
        &self,
        model: &ModelInfo,
        m: usize,
        w: &[f32],
        dx: &[f32],
        dy: &[f32],
    ) -> Result<Vec<f32>> {
        let op = model.op(&format!("syn_grad_m{m}"))?;
        let out = self.execute(
            &op.file,
            &[
                f32_literal(&[model.params], w)?,
                f32_literal(&Self::input_dims(model, &[m]), dx)?,
                f32_literal(&[m, model.n_classes], dy)?,
            ],
        )?;
        to_f32s(&out[0])
    }

    fn eval_batch(&self, model: &ModelInfo, w: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let op = model.op("eval")?;
        let b = op.batch;
        ensure!(x.len() == b * model.feature_len(), "x len");
        let out = self.execute(
            &op.file,
            &[
                f32_literal(&[model.params], w)?,
                f32_literal(&Self::input_dims(model, &[b]), x)?,
                i32_literal(&[b], y)?,
            ],
        )?;
        Ok((to_scalar_f32(&out[0])?, to_scalar_f32(&out[1])?))
    }

    fn fedsynth_step(
        &self,
        model: &ModelInfo,
        k: usize,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
        lr_syn: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, Vec<f32>)> {
        let op = model.op(&format!("fedsynth_k{k}_m{m}"))?;
        let out = self.execute(
            &op.file,
            &[
                f32_literal(&[model.params], w)?,
                f32_literal(&[model.params], g_target)?,
                f32_literal(&Self::input_dims(model, &[k, m]), dxs)?,
                f32_literal(&[k, m, model.n_classes], dys)?,
                scalar_f32(lr_inner)?,
                scalar_f32(lr_syn)?,
            ],
        )?;
        Ok((
            to_f32s(&out[0])?,
            to_f32s(&out[1])?,
            to_scalar_f32(&out[2])?,
            to_f32s(&out[3])?,
        ))
    }

    fn fedsynth_apply(
        &self,
        model: &ModelInfo,
        k: usize,
        m: usize,
        w: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
    ) -> Result<Vec<f32>> {
        let op = model.op(&format!("fedsynth_apply_k{k}_m{m}"))?;
        let out = self.execute(
            &op.file,
            &[
                f32_literal(&[model.params], w)?,
                f32_literal(&Self::input_dims(model, &[k, m]), dxs)?,
                f32_literal(&[k, m, model.n_classes], dys)?,
                scalar_f32(lr_inner)?,
            ],
        )?;
        to_f32s(&out[0])
    }
}
