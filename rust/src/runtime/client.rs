//! The PJRT runtime handle: client + manifest + lazy executable cache.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::model::{Manifest, ModelInfo};

/// Counters for the runtime hot path (perf visibility, EXPERIMENTS §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub executions: u64,
    pub compile_ms: f64,
    pub execute_ms: f64,
}

impl RuntimeStats {
    /// Accumulate another snapshot (worker-pool aggregation).
    pub fn merge(&mut self, other: &RuntimeStats) {
        self.compiles += other.compiles;
        self.executions += other.executions;
        self.compile_ms += other.compile_ms;
        self.execute_ms += other.execute_ms;
    }

    /// Counters accumulated since `earlier` (a previous snapshot of the
    /// same runtime).
    pub fn delta(&self, earlier: &RuntimeStats) -> RuntimeStats {
        RuntimeStats {
            compiles: self.compiles - earlier.compiles,
            executions: self.executions - earlier.executions,
            compile_ms: self.compile_ms - earlier.compile_ms,
            execute_ms: self.execute_ms - earlier.execute_ms,
        }
    }
}

/// Owns the PJRT CPU client and the compiled-executable cache.
///
/// Single-threaded by design: the `xla` crate's client is not `Send`, so
/// a `Runtime` never crosses a thread boundary. Parallel round execution
/// (see `coordinator::parallel`) instead gives every worker thread its
/// own `Runtime` — each with its own executable cache — and moves plain
/// `Send` data between them.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    stats: RefCell<RuntimeStats>,
}

impl Runtime {
    /// Open the artifact directory (see [`crate::artifacts_dir`]).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.manifest.model(name)
    }

    /// Compile (or fetch from cache) the executable for `file`.
    pub fn executable(&self, file: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.dir.join(file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))?,
        );
        let mut st = self.stats.borrow_mut();
        st.compiles += 1;
        st.compile_ms += t0.elapsed().as_secs_f64() * 1e3;
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with the given input literals; returns the
    /// flattened output tuple (aot.py lowers with `return_tuple=True`).
    pub fn execute(&self, file: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let exe = self.executable(file)?;
        let t0 = Instant::now();
        let result = exe.execute::<Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        Ok(lit.to_tuple()?)
    }
}
