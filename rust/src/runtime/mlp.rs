//! Pure-Rust MLP fed-op math — the numerics core of the native backend.
//!
//! Implements, for the repo's 2-layer MLP family (`x → relu(x·W1+b1)·W2+b2`
//! with softmax cross-entropy; see `python/compile/models.py::make_mlp`):
//!
//! * forward / hard-label loss+gradient (local training, eval);
//! * soft-label loss with gradients w.r.t. the weights, the **inputs**, and
//!   the **label logits** (the 3SFC/FedSynth synthetic-feature paths,
//!   where labels are `softmax(dy_logits)`);
//! * the ε-tangents of all three gradients under a perturbation of the
//!   weights — *forward-over-reverse* second-order automatic
//!   differentiation with dual numbers, hand-specialized to this
//!   architecture.
//!
//! The tangent machinery is what makes the encoder ops exact: the 3SFC
//! objective gradient is `∇_D |cos(∇_w L(D, w), t)|`, a mixed second
//! derivative. With `u := ∂obj/∂g` held constant, the chain rule gives
//! `∇_D ⟨∇_w L, u⟩`, and by symmetry of second derivatives that equals the
//! u-directional tangent of `∇_D L` — one dual-number pass. The FedSynth
//! unroll backward uses the same pass per inner step: the adjoint update
//! needs the Hessian-vector product `∇_w⟨∇_w L, λ⟩` (the `gw` tangent) and
//! the cross terms `∇_{dx,dy}⟨∇_w L, λ⟩` (the `gx`/`gdy` tangents).
//!
//! All buffers are flat row-major `f32`, matching the artifact layout:
//! `w = [W1 (d×h) | b1 (h) | W2 (h×c) | b2 (c)]`.
//!
//! Matrix products run on the register-blocked kernels in
//! [`crate::runtime::kernels`] (the original naive loops survive as the
//! `kernels::naive` test oracle), and every intermediate comes from the
//! caller's [`Workspace`] — after one warm-up execution per op shape the
//! hot path performs **zero heap allocations** (pinned by
//! `tests/alloc_count_test.rs`). The relu mask is not materialized: since
//! `h1 = relu(z1 + b1)`, the test `h1 > 0` *is* the mask.

// Index loops here deliberately mirror the math derivation (same symbols,
// same subscripts); iterator rewrites would obscure the correspondence.
#![allow(clippy::needless_range_loop)]

use crate::runtime::kernels::{self, Workspace};

/// Static shape of one 2-layer MLP.
#[derive(Clone, Copy, Debug)]
pub struct MlpDims {
    /// Input features.
    pub d: usize,
    /// Hidden width.
    pub h: usize,
    /// Classes.
    pub c: usize,
}

impl MlpDims {
    pub fn params(&self) -> usize {
        self.d * self.h + self.h + self.h * self.c + self.c
    }

    /// Split a flat parameter vector into (W1, b1, W2, b2) slices.
    pub fn split<'a>(&self, w: &'a [f32]) -> (&'a [f32], &'a [f32], &'a [f32], &'a [f32]) {
        assert_eq!(w.len(), self.params(), "flat parameter length");
        let (w1, rest) = w.split_at(self.d * self.h);
        let (b1, rest) = rest.split_at(self.h);
        let (w2, b2) = rest.split_at(self.h * self.c);
        (w1, b1, w2, b2)
    }
}

/// Forward activations kept for the backward passes. The buffers are
/// workspace checkouts; call [`Fwd::release`] when done.
struct Fwd {
    /// relu(z1) `[B×h]` — doubles as the relu mask (`h1 > 0`).
    h1: Vec<f32>,
    /// softmax(z2) `[B×c]`.
    p: Vec<f32>,
    /// log_softmax(z2) `[B×c]`.
    logp: Vec<f32>,
}

impl Fwd {
    fn release(self, ws: &mut Workspace) {
        ws.give(self.h1);
        ws.give(self.p);
        ws.give(self.logp);
    }
}

fn forward(dims: &MlpDims, w: &[f32], x: &[f32], bsz: usize, ws: &mut Workspace) -> Fwd {
    let (w1, b1, w2, b2) = dims.split(w);
    let (d, h, c) = (dims.d, dims.h, dims.c);
    debug_assert_eq!(x.len(), bsz * d);
    let mut z1 = ws.take(bsz * h);
    kernels::mm(x, w1, bsz, d, h, &mut z1);
    let mut h1 = ws.take(bsz * h);
    for i in 0..bsz {
        for j in 0..h {
            let v = z1[i * h + j] + b1[j];
            if v > 0.0 {
                h1[i * h + j] = v;
            }
        }
    }
    let mut z2 = ws.take(bsz * c);
    kernels::mm(&h1, w2, bsz, h, c, &mut z2);
    for i in 0..bsz {
        for j in 0..c {
            z2[i * c + j] += b2[j];
        }
    }
    let mut p = ws.take(bsz * c);
    let mut logp = ws.take(bsz * c);
    kernels::softmax_rows(&z2, bsz, c, &mut p, &mut logp);
    ws.give(z1);
    ws.give(z2);
    Fwd { h1, p, logp }
}

/// Reverse pass w.r.t. the weights from `dz2 = ∂L/∂z2`, written into the
/// flat `gw`; returns `dz1` (a workspace checkout — callers that also want
/// `∂L/∂x` read it, everyone gives it back).
#[allow(clippy::too_many_arguments)]
fn backward_w(
    dims: &MlpDims,
    w: &[f32],
    x: &[f32],
    fwd_h1: &[f32],
    dz2: &[f32],
    bsz: usize,
    ws: &mut Workspace,
    gw: &mut [f32],
) -> Vec<f32> {
    let (_, _, w2, _) = dims.split(w);
    let (d, h, c) = (dims.d, dims.h, dims.c);
    debug_assert_eq!(gw.len(), dims.params());
    gw.fill(0.0);
    let mut dz1 = ws.take(bsz * h);
    {
        let (gw1, rest) = gw.split_at_mut(d * h);
        let (gb1, rest) = rest.split_at_mut(h);
        let (gw2, gb2) = rest.split_at_mut(h * c);
        kernels::mm_at_acc(fwd_h1, dz2, bsz, h, c, gw2);
        kernels::colsum(dz2, bsz, c, gb2);
        kernels::mm_bt_acc(dz2, w2, bsz, c, h, &mut dz1);
        for (v, &hv) in dz1.iter_mut().zip(fwd_h1.iter()) {
            if hv <= 0.0 {
                *v = 0.0;
            }
        }
        kernels::mm_at_acc(x, &dz1, bsz, d, h, gw1);
        kernels::colsum(&dz1, bsz, h, gb1);
    }
    dz1
}

/// Mean hard-label cross-entropy over one batch; the weight gradient is
/// written into `gw` (`[P]`).
pub fn loss_grad_hard(
    dims: &MlpDims,
    w: &[f32],
    x: &[f32],
    y: &[i32],
    ws: &mut Workspace,
    gw: &mut [f32],
) -> f32 {
    let bsz = y.len();
    let c = dims.c;
    let fwd = forward(dims, w, x, bsz, ws);
    let inv_b = 1.0 / bsz as f32;
    let mut loss = 0.0f64;
    let mut dz2 = ws.take(bsz * c);
    dz2.copy_from_slice(&fwd.p);
    for (i, &yi) in y.iter().enumerate() {
        let yi = yi as usize;
        loss -= fwd.logp[i * c + yi] as f64;
        dz2[i * c + yi] -= 1.0;
    }
    for v in dz2.iter_mut() {
        *v *= inv_b;
    }
    let dz1 = backward_w(dims, w, x, &fwd.h1, &dz2, bsz, ws, gw);
    ws.give(dz1);
    ws.give(dz2);
    fwd.release(ws);
    (loss / bsz as f64) as f32
}

/// K SGD steps over pre-batched data (`xs: [k·b·d]`, `ys: [k·b]`); the
/// final weights land in `w_out` (`[P]`).
#[allow(clippy::too_many_arguments)]
pub fn sgd_steps(
    dims: &MlpDims,
    w: &[f32],
    xs: &[f32],
    ys: &[i32],
    k: usize,
    b: usize,
    lr: f32,
    ws: &mut Workspace,
    w_out: &mut [f32],
) {
    let d = dims.d;
    w_out.copy_from_slice(w);
    let mut g = ws.take(dims.params());
    for j in 0..k {
        let x = &xs[j * b * d..(j + 1) * b * d];
        let y = &ys[j * b..(j + 1) * b];
        loss_grad_hard(dims, &*w_out, x, y, ws, &mut g);
        for (wv, gv) in w_out.iter_mut().zip(g.iter()) {
            *wv -= lr * gv;
        }
    }
    ws.give(g);
}

/// Eval over one batch: (Σ per-sample CE loss, #correct). Argmax breaks
/// ties toward the first maximal class (matching `jnp.argmax`).
pub fn eval_batch(
    dims: &MlpDims,
    w: &[f32],
    x: &[f32],
    y: &[i32],
    ws: &mut Workspace,
) -> (f32, f32) {
    let bsz = y.len();
    let c = dims.c;
    let fwd = forward(dims, w, x, bsz, ws);
    let mut loss_sum = 0.0f64;
    let mut correct = 0u32;
    for (i, &yi) in y.iter().enumerate() {
        loss_sum -= fwd.logp[i * c + yi as usize] as f64;
        let row = &fwd.p[i * c..(i + 1) * c];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best as i32 == yi {
            correct += 1;
        }
    }
    fwd.release(ws);
    (loss_sum as f32, correct as f32)
}

/// Soft-label loss/gradients of `L = −(1/m)Σᵢ Σₖ yᵢₖ·logpᵢₖ` with
/// `y = softmax(dy_logits)`, plus (optionally) the ε-tangents of every
/// gradient under the weight perturbation `w + ε·v`.
///
/// Every `Vec` field is a workspace checkout — call [`SoftGrads::release`]
/// once the values have been consumed so the buffers recycle.
pub struct SoftGrads {
    pub loss: f32,
    /// ∇_w L `[P]`.
    pub gw: Vec<f32>,
    /// ∇_x L `[m·d]`.
    pub gx: Vec<f32>,
    /// ∇_{dy_logits} L `[m·c]` (softmax-Jacobian chain included).
    pub gdy: Vec<f32>,
    /// Tangents along `v` (empty when no tangent was requested).
    pub gw_dot: Vec<f32>,
    pub gx_dot: Vec<f32>,
    pub gdy_dot: Vec<f32>,
}

impl SoftGrads {
    /// Return every buffer to the workspace pool.
    pub fn release(self, ws: &mut Workspace) {
        for v in [self.gw, self.gx, self.gdy, self.gw_dot, self.gx_dot, self.gdy_dot] {
            ws.give(v);
        }
    }
}

pub fn soft_grads(
    dims: &MlpDims,
    w: &[f32],
    v: Option<&[f32]>,
    x: &[f32],
    dy_logits: &[f32],
    m: usize,
    ws: &mut Workspace,
) -> SoftGrads {
    let (w1, _, w2, _) = dims.split(w);
    let (d, h, c) = (dims.d, dims.h, dims.c);
    debug_assert_eq!(x.len(), m * d);
    debug_assert_eq!(dy_logits.len(), m * c);
    let inv_m = 1.0 / m as f32;

    // Soft labels y = softmax(dy_logits); independent of w (no tangent).
    let mut y = ws.take(m * c);
    let mut logy = ws.take(m * c);
    kernels::softmax_rows(dy_logits, m, c, &mut y, &mut logy);
    ws.give(logy);

    let fwd = forward(dims, w, x, m, ws);

    // Value pass.
    let mut loss = 0.0f64;
    for i in 0..m * c {
        loss -= (y[i] * fwd.logp[i]) as f64;
    }
    let loss = (loss * inv_m as f64) as f32;

    // dz2 = (p − y)/m.
    let mut dz2 = ws.take(m * c);
    for i in 0..m * c {
        dz2[i] = (fwd.p[i] - y[i]) * inv_m;
    }
    let mut gw = ws.take(dims.params());
    let dz1 = backward_w(dims, w, x, &fwd.h1, &dz2, m, ws, &mut gw);
    // gx = dz1·W1ᵀ.
    let mut gx = ws.take(m * d);
    kernels::mm_bt_acc(&dz1, w1, m, h, d, &mut gx);
    // a = ∂L/∂y = −logp/m; gdy = y ⊙ (a − rowdot(y, a)).
    let mut gdy = ws.take(m * c);
    for i in 0..m {
        let mut rd = 0.0f32;
        for k in 0..c {
            rd += y[i * c + k] * (-fwd.logp[i * c + k] * inv_m);
        }
        for k in 0..c {
            let a = -fwd.logp[i * c + k] * inv_m;
            gdy[i * c + k] = y[i * c + k] * (a - rd);
        }
    }

    let Some(v) = v else {
        ws.give(dz1);
        ws.give(dz2);
        ws.give(y);
        fwd.release(ws);
        return SoftGrads {
            loss,
            gw,
            gx,
            gdy,
            gw_dot: Vec::new(),
            gx_dot: Vec::new(),
            gdy_dot: Vec::new(),
        };
    };

    // ---- Tangent pass: ε-parts under w ← w + ε·v (ẋ = ẏ = 0). The relu
    // mask and the softmax normalizing max are locally constant a.e.
    let (v1, vb1, v2, vb2) = dims.split(v);
    // ż1 = x·V1 + vb1; ḣ1 = ż1 ⊙ mask.
    let mut h1_dot = ws.take(m * h);
    kernels::mm(x, v1, m, d, h, &mut h1_dot);
    for i in 0..m {
        for j in 0..h {
            h1_dot[i * h + j] += vb1[j];
            if fwd.h1[i * h + j] <= 0.0 {
                h1_dot[i * h + j] = 0.0;
            }
        }
    }
    // ż2 = ḣ1·W2 + h1·V2 + vb2.
    let mut z2_dot = ws.take(m * c);
    kernels::mm(&h1_dot, w2, m, h, c, &mut z2_dot);
    kernels::mm_acc(&fwd.h1, v2, m, h, c, &mut z2_dot);
    for i in 0..m {
        for j in 0..c {
            z2_dot[i * c + j] += vb2[j];
        }
    }
    // ṗ = p ⊙ (ż2 − rowdot(p, ż2));  (logp)˙ = ż2 − rowdot(p, ż2).
    let mut p_dot = ws.take(m * c);
    let mut logp_dot = ws.take(m * c);
    for i in 0..m {
        let mut rd = 0.0f32;
        for k in 0..c {
            rd += fwd.p[i * c + k] * z2_dot[i * c + k];
        }
        for k in 0..c {
            logp_dot[i * c + k] = z2_dot[i * c + k] - rd;
            p_dot[i * c + k] = fwd.p[i * c + k] * logp_dot[i * c + k];
        }
    }
    // (dz2)˙ = ṗ/m.
    let mut dz2_dot = ws.take(m * c);
    for i in 0..m * c {
        dz2_dot[i] = p_dot[i] * inv_m;
    }

    // ġW2 = ḣ1ᵀ·dz2 + h1ᵀ·(dz2)˙;  ġb2 = colsum((dz2)˙).
    let mut gw_dot = ws.take(dims.params());
    let (gw1_dot, rest) = gw_dot.split_at_mut(d * h);
    let (gb1_dot, rest) = rest.split_at_mut(h);
    let (gw2_dot, gb2_dot) = rest.split_at_mut(h * c);
    kernels::mm_at_acc(&h1_dot, &dz2, m, h, c, gw2_dot);
    kernels::mm_at_acc(&fwd.h1, &dz2_dot, m, h, c, gw2_dot);
    kernels::colsum(&dz2_dot, m, c, gb2_dot);
    // (dh1)˙ = (dz2)˙·W2ᵀ + dz2·V2ᵀ;  (dz1)˙ = (dh1)˙ ⊙ mask.
    let mut dz1_dot = ws.take(m * h);
    kernels::mm_bt_acc(&dz2_dot, w2, m, c, h, &mut dz1_dot);
    kernels::mm_bt_acc(&dz2, v2, m, c, h, &mut dz1_dot);
    for (vv, &hv) in dz1_dot.iter_mut().zip(fwd.h1.iter()) {
        if hv <= 0.0 {
            *vv = 0.0;
        }
    }
    // ġW1 = xᵀ·(dz1)˙;  ġb1 = colsum((dz1)˙).
    kernels::mm_at_acc(x, &dz1_dot, m, d, h, gw1_dot);
    kernels::colsum(&dz1_dot, m, h, gb1_dot);
    // ġx = (dz1)˙·W1ᵀ + dz1·V1ᵀ.
    let mut gx_dot = ws.take(m * d);
    kernels::mm_bt_acc(&dz1_dot, w1, m, h, d, &mut gx_dot);
    kernels::mm_bt_acc(&dz1, v1, m, h, d, &mut gx_dot);
    // ȧ = −(logp)˙/m;  ġdy = y ⊙ (ȧ − rowdot(y, ȧ)).
    let mut gdy_dot = ws.take(m * c);
    for i in 0..m {
        let mut rd = 0.0f32;
        for k in 0..c {
            rd += y[i * c + k] * (-logp_dot[i * c + k] * inv_m);
        }
        for k in 0..c {
            let ad = -logp_dot[i * c + k] * inv_m;
            gdy_dot[i * c + k] = y[i * c + k] * (ad - rd);
        }
    }

    ws.give(dz1);
    ws.give(dz2);
    ws.give(y);
    ws.give(h1_dot);
    ws.give(z2_dot);
    ws.give(p_dot);
    ws.give(logp_dot);
    ws.give(dz2_dot);
    ws.give(dz1_dot);
    fwd.release(ws);

    SoftGrads { loss, gw, gx, gdy, gw_dot, gx_dot, gdy_dot }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::vecmath;

    const DIMS: MlpDims = MlpDims { d: 5, h: 7, c: 3 };

    fn rand_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, scale);
        v
    }

    /// Convenience wrapper: hard loss + freshly allocated gradient.
    fn loss_grad(
        dims: &MlpDims,
        w: &[f32],
        x: &[f32],
        y: &[i32],
        ws: &mut Workspace,
    ) -> (f32, Vec<f32>) {
        let mut gw = vec![0.0f32; dims.params()];
        let loss = loss_grad_hard(dims, w, x, y, ws, &mut gw);
        (loss, gw)
    }

    /// Vectors agree in direction (cos > 0.999) and magnitude (±2%).
    fn assert_grad_close(analytic: &[f32], fd: &[f32], what: &str) {
        let cos = vecmath::cosine(analytic, fd);
        assert!(cos > 0.999, "{what}: cos(analytic, fd) = {cos}");
        let (na, nf) = (vecmath::norm(analytic), vecmath::norm(fd));
        assert!(
            (na - nf).abs() <= 0.02 * nf.max(1e-6),
            "{what}: norm {na} vs fd {nf}"
        );
    }

    #[test]
    fn hard_grad_matches_finite_differences() {
        let mut rng = Rng::new(31);
        let mut ws = Workspace::new();
        let w = rand_vec(&mut rng, DIMS.params(), 0.5);
        let x = rand_vec(&mut rng, 4 * DIMS.d, 1.0);
        let y = vec![0i32, 2, 1, 0];
        let (_, g) = loss_grad(&DIMS, &w, &x, &y, &mut ws);
        let eps = 1e-2f32;
        let mut fd = vec![0.0f32; w.len()];
        for j in 0..w.len() {
            let mut wp = w.clone();
            wp[j] += eps;
            let (lp, _) = loss_grad(&DIMS, &wp, &x, &y, &mut ws);
            wp[j] = w[j] - eps;
            let (lm, _) = loss_grad(&DIMS, &wp, &x, &y, &mut ws);
            fd[j] = (lp - lm) / (2.0 * eps);
        }
        assert_grad_close(&g, &fd, "hard gw");
    }

    #[test]
    fn soft_grads_match_finite_differences() {
        let mut rng = Rng::new(32);
        let mut ws = Workspace::new();
        let m = 2usize;
        let w = rand_vec(&mut rng, DIMS.params(), 0.5);
        let x = rand_vec(&mut rng, m * DIMS.d, 0.7);
        let dy = rand_vec(&mut rng, m * DIMS.c, 0.3);
        let sg = soft_grads(&DIMS, &w, None, &x, &dy, m, &mut ws);
        let eps = 1e-2f32;

        let loss_at = |w: &[f32], x: &[f32], dy: &[f32], ws: &mut Workspace| {
            let sg = soft_grads(&DIMS, w, None, x, dy, m, ws);
            let loss = sg.loss;
            sg.release(ws);
            loss
        };
        let mut fd_w = vec![0.0f32; w.len()];
        for j in 0..w.len() {
            let mut wp = w.clone();
            wp[j] = w[j] + eps;
            let lp = loss_at(&wp, &x, &dy, &mut ws);
            wp[j] = w[j] - eps;
            let lm = loss_at(&wp, &x, &dy, &mut ws);
            fd_w[j] = (lp - lm) / (2.0 * eps);
        }
        assert_grad_close(&sg.gw, &fd_w, "soft gw");

        let mut fd_x = vec![0.0f32; x.len()];
        for j in 0..x.len() {
            let mut xp = x.clone();
            xp[j] = x[j] + eps;
            let lp = loss_at(&w, &xp, &dy, &mut ws);
            xp[j] = x[j] - eps;
            let lm = loss_at(&w, &xp, &dy, &mut ws);
            fd_x[j] = (lp - lm) / (2.0 * eps);
        }
        assert_grad_close(&sg.gx, &fd_x, "soft gx");

        let mut fd_y = vec![0.0f32; dy.len()];
        for j in 0..dy.len() {
            let mut dyp = dy.clone();
            dyp[j] = dy[j] + eps;
            let lp = loss_at(&w, &x, &dyp, &mut ws);
            dyp[j] = dy[j] - eps;
            let lm = loss_at(&w, &x, &dyp, &mut ws);
            fd_y[j] = (lp - lm) / (2.0 * eps);
        }
        assert_grad_close(&sg.gdy, &fd_y, "soft gdy");
    }

    #[test]
    fn tangents_match_directional_differences() {
        // gw_dot / gx_dot / gdy_dot must equal the directional derivative
        // of the corresponding gradient along v — the second-order core
        // the 3SFC and FedSynth encoders stand on.
        let mut rng = Rng::new(33);
        let mut ws = Workspace::new();
        let m = 2usize;
        let w = rand_vec(&mut rng, DIMS.params(), 0.5);
        let v = rand_vec(&mut rng, DIMS.params(), 0.3);
        let x = rand_vec(&mut rng, m * DIMS.d, 0.7);
        let dy = rand_vec(&mut rng, m * DIMS.c, 0.3);
        let sg = soft_grads(&DIMS, &w, Some(&v), &x, &dy, m, &mut ws);

        let eps = 1e-2f32;
        let mut wp = w.clone();
        let mut wm = w.clone();
        for i in 0..w.len() {
            wp[i] = w[i] + eps * v[i];
            wm[i] = w[i] - eps * v[i];
        }
        let sp = soft_grads(&DIMS, &wp, None, &x, &dy, m, &mut ws);
        let sm = soft_grads(&DIMS, &wm, None, &x, &dy, m, &mut ws);
        let fd = |a: &[f32], b: &[f32]| -> Vec<f32> {
            a.iter().zip(b.iter()).map(|(p, q)| (p - q) / (2.0 * eps)).collect()
        };
        assert_grad_close(&sg.gw_dot, &fd(&sp.gw, &sm.gw), "gw_dot");
        assert_grad_close(&sg.gx_dot, &fd(&sp.gx, &sm.gx), "gx_dot");
        assert_grad_close(&sg.gdy_dot, &fd(&sp.gdy, &sm.gdy), "gdy_dot");
    }

    #[test]
    fn sgd_step_is_w_minus_lr_grad() {
        let mut rng = Rng::new(34);
        let mut ws = Workspace::new();
        let w = rand_vec(&mut rng, DIMS.params(), 0.5);
        let x = rand_vec(&mut rng, 3 * DIMS.d, 1.0);
        let y = vec![1i32, 0, 2];
        let mut w1 = vec![0.0f32; w.len()];
        sgd_steps(&DIMS, &w, &x, &y, 1, 3, 0.1, &mut ws, &mut w1);
        let (_, g) = loss_grad(&DIMS, &w, &x, &y, &mut ws);
        for i in 0..w.len() {
            assert_eq!(w1[i].to_bits(), (w[i] - 0.1 * g[i]).to_bits());
        }
    }

    #[test]
    fn training_separable_batch_reaches_high_accuracy() {
        // Two well-separated clusters must be learnable in a few steps.
        let dims = MlpDims { d: 4, h: 8, c: 2 };
        let mut rng = Rng::new(35);
        let mut ws = Workspace::new();
        let mut w = rand_vec(&mut rng, dims.params(), 0.3);
        let b = 8usize;
        let mut x = vec![0.0f32; b * dims.d];
        let mut y = vec![0i32; b];
        for i in 0..b {
            let cls = i % 2;
            y[i] = cls as i32;
            for j in 0..dims.d {
                x[i * dims.d + j] =
                    if cls == 0 { 1.0 } else { -1.0 } + 0.1 * rng.normal_f32();
            }
        }
        let (loss0, _) = loss_grad(&dims, &w, &x, &y, &mut ws);
        for _ in 0..200 {
            let (_, g) = loss_grad(&dims, &w, &x, &y, &mut ws);
            for (wv, gv) in w.iter_mut().zip(g.iter()) {
                *wv -= 0.5 * gv;
            }
        }
        let (loss1, _) = loss_grad(&dims, &w, &x, &y, &mut ws);
        assert!(loss1 < loss0 * 0.2, "loss {loss0} -> {loss1}");
        let (_, correct) = eval_batch(&dims, &w, &x, &y, &mut ws);
        assert_eq!(correct as usize, b);
    }

    #[test]
    fn eval_counts_and_sums() {
        let dims = MlpDims { d: 2, h: 3, c: 2 };
        let mut rng = Rng::new(36);
        let mut ws = Workspace::new();
        let w = rand_vec(&mut rng, dims.params(), 0.4);
        let x = rand_vec(&mut rng, 5 * dims.d, 1.0);
        let y = vec![0i32, 1, 0, 1, 0];
        let (loss, correct) = eval_batch(&dims, &w, &x, &y, &mut ws);
        assert!(loss.is_finite() && loss > 0.0);
        assert!((0.0..=5.0).contains(&correct));
        // Σ per-sample loss ≥ B·min per-sample loss: sanity vs mean form.
        let (mean_loss, _) = loss_grad(&dims, &w, &x, &y, &mut ws);
        assert!((loss / 5.0 - mean_loss).abs() < 1e-5);
    }
}
