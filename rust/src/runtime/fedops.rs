//! Typed fed-op facade bound to one (backend, model) pair.
//!
//! Thin forwarding layer over the [`Backend`] trait: compressors and the
//! round engine hold a `FedOps` and never care which implementation (PJRT
//! artifacts or the pure-Rust native path) executes the math. Dataset-level
//! evaluation lives here because it is backend-independent batching logic.

use anyhow::{ensure, Result};

use crate::model::ModelInfo;
use crate::runtime::backend::Backend;

/// Fed-op facade bound to one (backend, model) pair.
pub struct FedOps<'a> {
    pub backend: &'a dyn Backend,
    pub model: &'a ModelInfo,
}

impl<'a> FedOps<'a> {
    pub fn new(backend: &'a dyn Backend, model_key: &str) -> Result<FedOps<'a>> {
        let model = backend.manifest().model(model_key)?;
        Ok(FedOps { backend, model })
    }

    /// K local SGD steps: returns the updated local weights.
    pub fn local_train(
        &self,
        k: usize,
        w: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        self.backend.local_train(self.model, k, w, xs, ys, lr)
    }

    /// One-batch gradient (mlp family; tests).
    pub fn grad_batch(&self, w: &[f32], x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        self.backend.grad_batch(self.model, w, x, y)
    }

    /// One 3SFC encoder step. Returns (dx', dy', cos).
    #[allow(clippy::too_many_arguments)]
    pub fn syn_step(
        &self,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        self.backend
            .syn_step(self.model, m, w, g_target, dx, dy, lr_syn, lambda)
    }

    /// True if a fused encoder exists for (m, s) — always false on the
    /// native backend.
    pub fn has_syn_opt(&self, m: usize, s: usize) -> bool {
        self.backend.has_syn_opt(self.model, m, s)
    }

    /// Fused 3SFC encoder: S Adam steps in one dispatch (perf pass).
    /// Returns (dx_final, dy_final, dx_best, dy_best, best_cos, last_cos).
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    pub fn syn_opt(
        &self,
        m: usize,
        s: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f32, f32)> {
        self.backend
            .syn_opt(self.model, m, s, w, g_target, dx, dy, lr_syn, lambda)
    }

    /// Decoder / finalizer: gradient of the loss on the synthetic features.
    pub fn syn_grad(&self, m: usize, w: &[f32], dx: &[f32], dy: &[f32]) -> Result<Vec<f32>> {
        self.backend.syn_grad(self.model, m, w, dx, dy)
    }

    /// Eval over one fixed-size batch: (Σ loss, #correct).
    pub fn eval_batch(&self, w: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        self.backend.eval_batch(self.model, w, x, y)
    }

    /// One FedSynth distillation step (multi-step baseline).
    /// Returns (dxs', dys', fit, per-step grad norms).
    #[allow(clippy::too_many_arguments)]
    pub fn fedsynth_step(
        &self,
        k: usize,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
        lr_syn: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, Vec<f32>)> {
        self.backend
            .fedsynth_step(self.model, k, m, w, g_target, dxs, dys, lr_inner, lr_syn)
    }

    /// FedSynth decoder: replay the K_sim-step simulation, return Δw.
    pub fn fedsynth_apply(
        &self,
        k: usize,
        m: usize,
        w: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
    ) -> Result<Vec<f32>> {
        self.backend
            .fedsynth_apply(self.model, k, m, w, dxs, dys, lr_inner)
    }

    /// Eval over a whole dataset slice, looping fixed-size batches and
    /// padding the tail by wrapping (standard practice; error is O(B/n)).
    /// Backend-independent: both implementations see identical batching.
    pub fn eval_dataset(&self, w: &[f32], xs: &[f32], ys: &[i32]) -> Result<(f64, f64)> {
        let b = self.model.eval_batch;
        let d = self.model.feature_len();
        let n = ys.len();
        ensure!(n >= 1 && xs.len() == n * d, "eval data shape");
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut counted = 0usize;
        let mut xbuf = vec![0.0f32; b * d];
        let mut ybuf = vec![0i32; b];
        let mut off = 0usize;
        while counted < n {
            let take = b.min(n - counted);
            for j in 0..b {
                let src = (off + j) % n;
                xbuf[j * d..(j + 1) * d].copy_from_slice(&xs[src * d..(src + 1) * d]);
                ybuf[j] = ys[src];
            }
            let (l, c) = self.eval_batch(w, &xbuf, &ybuf)?;
            // Only credit the non-padded prefix on the tail batch.
            if take == b {
                loss_sum += l as f64;
                correct += c as f64;
            } else {
                // Re-run accounting host-side is impossible (sums are fused);
                // approximate by prorating the tail batch.
                let frac = take as f64 / b as f64;
                loss_sum += l as f64 * frac;
                correct += c as f64 * frac;
            }
            counted += take;
            off += take;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }
}
