//! Typed wrappers over the AOT fed-op artifacts.
//!
//! Each wrapper checks shapes against the manifest, marshals flat host
//! buffers into literals, runs the executable, and unpacks the tuple.

use anyhow::{ensure, Result};

use crate::model::ModelInfo;
use crate::runtime::literal::{f32_literal, i32_literal, scalar_f32, to_f32s, to_scalar_f32};
use crate::runtime::Runtime;

/// Fed-op facade bound to one (runtime, model) pair.
pub struct FedOps<'a> {
    pub rt: &'a Runtime,
    pub model: &'a ModelInfo,
}

impl<'a> FedOps<'a> {
    pub fn new(rt: &'a Runtime, model_key: &str) -> Result<FedOps<'a>> {
        let model = rt.model(model_key)?;
        Ok(FedOps { rt, model })
    }

    fn input_dims(&self, lead: &[usize]) -> Vec<usize> {
        let mut dims = lead.to_vec();
        dims.extend_from_slice(&self.model.input_shape);
        dims
    }

    /// K local SGD steps: returns the updated local weights.
    pub fn local_train(
        &self,
        k: usize,
        w: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        let op = self.model.op(&format!("train_k{k}"))?;
        let b = op.batch;
        ensure!(w.len() == self.model.params, "w len");
        ensure!(xs.len() == k * b * self.model.feature_len(), "xs len");
        ensure!(ys.len() == k * b, "ys len");
        let out = self.rt.execute(
            &op.file,
            &[
                f32_literal(&[self.model.params], w)?,
                f32_literal(&self.input_dims(&[k, b]), xs)?,
                i32_literal(&[k, b], ys)?,
                scalar_f32(lr)?,
            ],
        )?;
        to_f32s(&out[0])
    }

    /// One-batch gradient (mlp_small only; tests).
    pub fn grad_batch(&self, w: &[f32], x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let op = self.model.op("grad")?;
        let b = op.batch;
        let out = self.rt.execute(
            &op.file,
            &[
                f32_literal(&[self.model.params], w)?,
                f32_literal(&self.input_dims(&[b]), x)?,
                i32_literal(&[b], y)?,
            ],
        )?;
        to_f32s(&out[0])
    }

    /// One 3SFC encoder step. Returns (dx', dy', cos).
    #[allow(clippy::too_many_arguments)]
    pub fn syn_step(
        &self,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let op = self.model.op(&format!("syn_step_m{m}"))?;
        ensure!(dx.len() == m * self.model.feature_len(), "dx len");
        ensure!(dy.len() == m * self.model.n_classes, "dy len");
        let out = self.rt.execute(
            &op.file,
            &[
                f32_literal(&[self.model.params], w)?,
                f32_literal(&[self.model.params], g_target)?,
                f32_literal(&self.input_dims(&[m]), dx)?,
                f32_literal(&[m, self.model.n_classes], dy)?,
                scalar_f32(lr_syn)?,
                scalar_f32(lambda)?,
            ],
        )?;
        Ok((to_f32s(&out[0])?, to_f32s(&out[1])?, to_scalar_f32(&out[2])?))
    }

    /// True if a fused encoder artifact exists for (m, s).
    pub fn has_syn_opt(&self, m: usize, s: usize) -> bool {
        self.model.ops.contains_key(&format!("syn_opt_m{m}_s{s}"))
    }

    /// Fused 3SFC encoder: S Adam steps in one dispatch (perf pass).
    /// Returns (dx_final, dy_final, dx_best, dy_best, best_cos, last_cos).
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    pub fn syn_opt(
        &self,
        m: usize,
        s: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f32, f32)> {
        let op = self.model.op(&format!("syn_opt_m{m}_s{s}"))?;
        let out = self.rt.execute(
            &op.file,
            &[
                f32_literal(&[self.model.params], w)?,
                f32_literal(&[self.model.params], g_target)?,
                f32_literal(&self.input_dims(&[m]), dx)?,
                f32_literal(&[m, self.model.n_classes], dy)?,
                scalar_f32(lr_syn)?,
                scalar_f32(lambda)?,
            ],
        )?;
        Ok((
            to_f32s(&out[0])?,
            to_f32s(&out[1])?,
            to_f32s(&out[2])?,
            to_f32s(&out[3])?,
            to_scalar_f32(&out[4])?,
            to_scalar_f32(&out[5])?,
        ))
    }

    /// Decoder / finalizer: gradient of the loss on the synthetic features.
    pub fn syn_grad(&self, m: usize, w: &[f32], dx: &[f32], dy: &[f32]) -> Result<Vec<f32>> {
        let op = self.model.op(&format!("syn_grad_m{m}"))?;
        let out = self.rt.execute(
            &op.file,
            &[
                f32_literal(&[self.model.params], w)?,
                f32_literal(&self.input_dims(&[m]), dx)?,
                f32_literal(&[m, self.model.n_classes], dy)?,
            ],
        )?;
        to_f32s(&out[0])
    }

    /// Eval over one fixed-size batch: (Σ loss, #correct).
    pub fn eval_batch(&self, w: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let op = self.model.op("eval")?;
        let b = op.batch;
        ensure!(x.len() == b * self.model.feature_len(), "x len");
        let out = self.rt.execute(
            &op.file,
            &[
                f32_literal(&[self.model.params], w)?,
                f32_literal(&self.input_dims(&[b]), x)?,
                i32_literal(&[b], y)?,
            ],
        )?;
        Ok((to_scalar_f32(&out[0])?, to_scalar_f32(&out[1])?))
    }

    /// Eval over a whole dataset slice, looping fixed-size batches and
    /// padding the tail by wrapping (standard practice; error is O(B/n)).
    pub fn eval_dataset(&self, w: &[f32], xs: &[f32], ys: &[i32]) -> Result<(f64, f64)> {
        let op = self.model.op("eval")?;
        let b = op.batch;
        let d = self.model.feature_len();
        let n = ys.len();
        ensure!(n >= 1 && xs.len() == n * d, "eval data shape");
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut counted = 0usize;
        let mut xbuf = vec![0.0f32; b * d];
        let mut ybuf = vec![0i32; b];
        let mut off = 0usize;
        while counted < n {
            let take = b.min(n - counted);
            for j in 0..b {
                let src = (off + j) % n;
                xbuf[j * d..(j + 1) * d].copy_from_slice(&xs[src * d..(src + 1) * d]);
                ybuf[j] = ys[src];
            }
            let (l, c) = self.eval_batch(w, &xbuf, &ybuf)?;
            // Only credit the non-padded prefix on the tail batch.
            if take == b {
                loss_sum += l as f64;
                correct += c as f64;
            } else {
                // Re-run accounting host-side is impossible (sums are fused);
                // approximate by prorating the tail batch.
                let frac = take as f64 / b as f64;
                loss_sum += l as f64 * frac;
                correct += c as f64 * frac;
            }
            counted += take;
            off += take;
        }
        Ok((loss_sum / n as f64, correct / n as f64))
    }

    /// One FedSynth distillation step (multi-step baseline).
    /// Returns (dxs', dys', fit, per-step grad norms).
    #[allow(clippy::too_many_arguments)]
    pub fn fedsynth_step(
        &self,
        k: usize,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
        lr_syn: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, Vec<f32>)> {
        let op = self.model.op(&format!("fedsynth_k{k}_m{m}"))?;
        let out = self.rt.execute(
            &op.file,
            &[
                f32_literal(&[self.model.params], w)?,
                f32_literal(&[self.model.params], g_target)?,
                f32_literal(&self.input_dims(&[k, m]), dxs)?,
                f32_literal(&[k, m, self.model.n_classes], dys)?,
                scalar_f32(lr_inner)?,
                scalar_f32(lr_syn)?,
            ],
        )?;
        Ok((
            to_f32s(&out[0])?,
            to_f32s(&out[1])?,
            to_scalar_f32(&out[2])?,
            to_f32s(&out[3])?,
        ))
    }

    /// FedSynth decoder: replay the K_sim-step simulation, return Δw.
    pub fn fedsynth_apply(
        &self,
        k: usize,
        m: usize,
        w: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
    ) -> Result<Vec<f32>> {
        let op = self.model.op(&format!("fedsynth_apply_k{k}_m{m}"))?;
        let out = self.rt.execute(
            &op.file,
            &[
                f32_literal(&[self.model.params], w)?,
                f32_literal(&self.input_dims(&[k, m]), dxs)?,
                f32_literal(&[k, m, self.model.n_classes], dys)?,
                scalar_f32(lr_inner)?,
            ],
        )?;
        to_f32s(&out[0])
    }
}
