//! The compute-backend abstraction: every fed-op the coordinator needs,
//! behind one trait with two implementations.
//!
//! * [`crate::runtime::PjrtBackend`] — the original path: AOT-lowered HLO
//!   artifacts executed through the PJRT CPU client (`xla` crate). Fast,
//!   faithful to the L1/L2 kernel stack, but requires `make artifacts`
//!   and the `pjrt` cargo feature.
//! * [`crate::runtime::NativeBackend`] — a pure-Rust reference
//!   implementation of the same ops (see [`crate::runtime::mlp`]): no
//!   artifacts, no `xla` dependency, `Send`. It exists so the entire
//!   experiment stack — and the whole integration-test tier — runs in any
//!   container, and so the two implementations can be differentially
//!   tested against each other (`tests/backend_parity_test.rs`).
//!
//! Selection: `[runtime] backend = "native" | "pjrt"` in TOML, `--backend`
//! on the CLI, [`crate::coordinator::ExperimentBuilder::backend`], or the
//! `FED3SFC_BACKEND` environment variable; the default (`auto`) picks PJRT
//! when an artifact directory is present and falls back to native.
//!
//! Backends are deliberately **not** `Send`/`Sync` at the trait level —
//! the PJRT client cannot cross threads. Parallel round execution instead
//! clones a [`BackendSpec`] (plain `Send` data) into every worker, which
//! opens its own backend instance (see `coordinator::parallel`); for the
//! native backend this is a pure in-memory construction.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::{BackendKind, ExperimentConfig};
use crate::model::{Manifest, ModelInfo};

/// Counters for the backend hot path (perf visibility, EXPERIMENTS §Perf).
/// For the native backend `compiles` is always 0; `executions` counts op
/// dispatches for both.
#[derive(Clone, Copy, Debug, Default)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub executions: u64,
    pub compile_ms: f64,
    pub execute_ms: f64,
}

impl RuntimeStats {
    /// Accumulate another snapshot (worker-pool aggregation).
    pub fn merge(&mut self, other: &RuntimeStats) {
        self.compiles += other.compiles;
        self.executions += other.executions;
        self.compile_ms += other.compile_ms;
        self.execute_ms += other.execute_ms;
    }

    /// Counters accumulated since `earlier` (a previous snapshot of the
    /// same backend).
    pub fn delta(&self, earlier: &RuntimeStats) -> RuntimeStats {
        RuntimeStats {
            compiles: self.compiles - earlier.compiles,
            executions: self.executions - earlier.executions,
            compile_ms: self.compile_ms - earlier.compile_ms,
            execute_ms: self.execute_ms - earlier.execute_ms,
        }
    }
}

/// Everything needed to (re)open a backend on another thread: plain
/// `Send + Sync` data, cloned into each worker of the round engine's pool.
#[derive(Clone, Debug)]
pub enum BackendSpec {
    /// Open the artifact directory through the PJRT client.
    Pjrt { artifacts: PathBuf },
    /// Construct the pure-Rust backend (no filesystem access).
    Native,
}

impl BackendSpec {
    /// Open a fresh backend instance described by this spec.
    pub fn open(&self) -> Result<Box<dyn Backend>> {
        match self {
            #[cfg(feature = "pjrt")]
            BackendSpec::Pjrt { artifacts } => {
                Ok(Box::new(crate::runtime::PjrtBackend::open(artifacts)?))
            }
            #[cfg(not(feature = "pjrt"))]
            BackendSpec::Pjrt { .. } => anyhow::bail!(
                "this build has no PJRT support (compiled without the `pjrt` feature); \
                 use the native backend"
            ),
            BackendSpec::Native => Ok(Box::new(crate::runtime::NativeBackend::new())),
        }
    }
}

/// The typed fed-op surface (plus model/weight plumbing) the coordinator
/// consumes. Shapes follow the manifest conventions: `w` is the flat
/// parameter vector `[P]`, batches are flat row-major buffers.
///
/// The op semantics are specified by `python/compile/fedops.py` (the
/// lowering source for the PJRT artifacts); the native backend
/// re-implements the same math in Rust and the two are differentially
/// tested against each other.
pub trait Backend {
    /// Which implementation this is (`"pjrt"` / `"native"`).
    fn backend_name(&self) -> &'static str;

    /// Human-readable platform string (PJRT platform name, or "native").
    fn platform(&self) -> String;

    /// The model table this backend can execute.
    fn manifest(&self) -> &Manifest;

    /// Hot-path counters.
    fn stats(&self) -> RuntimeStats;

    /// A `Send` recipe for opening an equivalent backend on another
    /// thread (worker pool).
    fn spec(&self) -> BackendSpec;

    /// Deterministic initial weights for `model` (He-normal; the PJRT
    /// backend reads the packed `.init.bin` the AOT pass exported).
    fn load_init(&self, model: &ModelInfo) -> Result<Vec<f32>>;

    /// K local SGD steps over pre-batched data `xs: [K·B·d]`, `ys: [K·B]`;
    /// returns the updated local weights.
    fn local_train(
        &self,
        model: &ModelInfo,
        k: usize,
        w: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<Vec<f32>>;

    /// One-batch gradient of the hard-label CE loss.
    fn grad_batch(&self, model: &ModelInfo, w: &[f32], x: &[f32], y: &[i32]) -> Result<Vec<f32>>;

    /// One 3SFC encoder step (Eq. 9 gradient on the synthetic features).
    /// Returns (dx', dy', cos at the pre-step iterate).
    #[allow(clippy::too_many_arguments)]
    fn syn_step(
        &self,
        model: &ModelInfo,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)>;

    /// True if a fused S-step encoder exists for (m, s) — a PJRT artifact
    /// property; the native backend always loops [`Backend::syn_step`].
    fn has_syn_opt(&self, model: &ModelInfo, m: usize, s: usize) -> bool;

    /// Fused 3SFC encoder: S Adam steps in one dispatch (perf pass).
    /// Returns (dx_final, dy_final, dx_best, dy_best, best_cos, last_cos).
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn syn_opt(
        &self,
        model: &ModelInfo,
        m: usize,
        s: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f32, f32)>;

    /// Decoder / finalizer: ∇_w F(D_syn, w) (Eq. 10; caller applies s).
    fn syn_grad(
        &self,
        model: &ModelInfo,
        m: usize,
        w: &[f32],
        dx: &[f32],
        dy: &[f32],
    ) -> Result<Vec<f32>>;

    /// Eval over one fixed-size batch: (Σ loss, #correct).
    fn eval_batch(&self, model: &ModelInfo, w: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)>;

    /// One FedSynth distillation step (multi-step baseline).
    /// Returns (dxs', dys', fit, per-step grad norms).
    #[allow(clippy::too_many_arguments, clippy::type_complexity)]
    fn fedsynth_step(
        &self,
        model: &ModelInfo,
        k: usize,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
        lr_syn: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, Vec<f32>)>;

    /// FedSynth decoder: replay the K_sim-step simulation, return Δw.
    #[allow(clippy::too_many_arguments)]
    fn fedsynth_apply(
        &self,
        model: &ModelInfo,
        k: usize,
        m: usize,
        w: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
    ) -> Result<Vec<f32>>;
}

/// Open the backend an [`ExperimentConfig`] asks for. `auto` resolves in
/// [`open_backend_kind`]: `FED3SFC_BACKEND` if set (an unparseable value
/// is an error, not a silent fallback), else PJRT when artifacts exist,
/// else native.
pub fn open_backend(cfg: &ExperimentConfig) -> Result<Box<dyn Backend>> {
    open_backend_kind(cfg.backend)
}

/// Open a backend by kind; [`BackendKind::Auto`] is resolved here — the
/// single place env/artifact resolution happens.
pub fn open_backend_kind(kind: BackendKind) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => BackendSpec::Native.open(),
        BackendKind::Pjrt => {
            BackendSpec::Pjrt { artifacts: crate::artifacts_dir() }.open()
        }
        BackendKind::Auto => {
            // Env override first (so every entry point honors it), then
            // artifact availability. A value that doesn't parse is a
            // user error and must not silently auto-resolve.
            if let Ok(v) = std::env::var("FED3SFC_BACKEND") {
                let env_kind = BackendKind::parse(v.trim())
                    .map_err(|e| e.context("invalid FED3SFC_BACKEND"))?;
                if env_kind != BackendKind::Auto {
                    return open_backend_kind(env_kind);
                }
            }
            let dir = crate::artifacts_dir();
            if cfg!(feature = "pjrt") && dir.join("manifest.json").exists() {
                BackendSpec::Pjrt { artifacts: dir }.open()
            } else {
                BackendSpec::Native.open()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge_and_delta() {
        let mut a = RuntimeStats { compiles: 2, executions: 10, compile_ms: 5.0, execute_ms: 1.0 };
        let b = RuntimeStats { compiles: 1, executions: 4, compile_ms: 2.0, execute_ms: 0.5 };
        a.merge(&b);
        assert_eq!(a.compiles, 3);
        assert_eq!(a.executions, 14);
        let d = a.delta(&b);
        assert_eq!(d.compiles, 2);
        assert_eq!(d.executions, 10);
    }

    #[test]
    fn native_spec_opens_without_filesystem() {
        let be = BackendSpec::Native.open().unwrap();
        assert_eq!(be.backend_name(), "native");
        assert!(be.manifest().models.contains_key("mlp_small"));
    }
}
