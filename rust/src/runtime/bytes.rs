//! POD byte views: `&[f32]` / `&[i32]` → `&[u8]` reinterpretation.
//!
//! The only raw-pointer casts in the tree live here, in one
//! feature-independent module, so the `cargo miri test` CI job can
//! sanitize them on the native build (the PJRT caller in
//! `runtime::literal` is gated behind FFI miri cannot run).

/// View an f32 slice as its raw little-endian-of-the-host bytes.
pub fn bytes_of_f32(data: &[f32]) -> &[u8] {
    // SAFETY: `f32` is plain-old-data with no padding or invalid bit
    // patterns at `u8`; the pointer and length come from a live slice
    // (`size_of_val` is exactly the byte span), and the returned borrow
    // keeps `data` alive.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data)) }
}

/// View an i32 slice as its raw little-endian-of-the-host bytes.
pub fn bytes_of_i32(data: &[i32]) -> &[u8] {
    // SAFETY: same as `bytes_of_f32` — `i32` is POD, the span is
    // `size_of_val(data)` bytes of a live slice, lifetime is inherited.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_bytes_roundtrip() {
        let data = [1.5f32, -2.0, 0.25, f32::MIN_POSITIVE, 0.0, -0.0];
        let bytes = bytes_of_f32(&data);
        assert_eq!(bytes.len(), data.len() * 4);
        let back: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(back.len(), data.len());
        for (a, b) in back.iter().zip(data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn i32_bytes_roundtrip() {
        let data = [0i32, -1, i32::MAX, i32::MIN, 131];
        let bytes = bytes_of_i32(&data);
        assert_eq!(bytes.len(), data.len() * 4);
        let back: Vec<i32> = bytes
            .chunks_exact(4)
            .map(|c| i32::from_ne_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(back, data);
    }

    #[test]
    fn empty_slices_are_empty_bytes() {
        assert!(bytes_of_f32(&[]).is_empty());
        assert!(bytes_of_i32(&[]).is_empty());
    }
}
