//! `Vec<f32>` / `Vec<i32>` ⇄ `xla::Literal` marshalling.

use anyhow::{ensure, Result};
use xla::{ElementType, Literal};

use super::bytes::{bytes_of_f32, bytes_of_i32};

/// f32 literal of the given logical shape.
pub fn f32_literal(dims: &[usize], data: &[f32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    ensure!(n == data.len(), "shape {dims:?} != data len {}", data.len());
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        dims,
        bytes_of_f32(data),
    )?)
}

/// i32 literal of the given logical shape.
pub fn i32_literal(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let n: usize = dims.iter().product();
    ensure!(n == data.len(), "shape {dims:?} != data len {}", data.len());
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32,
        dims,
        bytes_of_i32(data),
    )?)
}

/// Rank-0 f32 scalar.
pub fn scalar_f32(v: f32) -> Result<Literal> {
    f32_literal(&[], std::slice::from_ref(&v))
}

/// Read back a full f32 literal.
pub fn to_f32s(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Read back a scalar f32 literal.
pub fn to_scalar_f32(lit: &Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let data = vec![1.5f32, -2.0, 0.25, 7.0, 0.0, 9.5];
        let lit = f32_literal(&[2, 3], &data).unwrap();
        assert_eq!(to_f32s(&lit).unwrap(), data);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = scalar_f32(3.25).unwrap();
        assert_eq!(to_scalar_f32(&lit).unwrap(), 3.25);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[2, 2], &[1.0, 2.0, 3.0]).is_err());
        assert!(i32_literal(&[5], &[1, 2, 3]).is_err());
    }
}
