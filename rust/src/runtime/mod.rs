//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
//!
//! The bridge (see /opt/xla-example and DESIGN.md §2): python lowers each
//! fed-op to HLO **text**; here `HloModuleProto::from_text_file` parses it,
//! `PjRtClient::cpu().compile` produces an executable, and typed wrappers
//! in [`fedops`] marshal flat `Vec<f32>`/`Vec<i32>` buffers in and out.
//! Executables are compiled lazily and cached per op.

pub mod client;
pub mod fedops;
pub mod literal;

pub use client::{Runtime, RuntimeStats};
pub use fedops::FedOps;
