//! Runtime layer: the pluggable compute backend behind the coordinator.
//!
//! [`Backend`] is the typed fed-op surface (forward/backward, SGD steps,
//! eval, the 3SFC/FedSynth encoder ops). Two implementations:
//!
//! * [`PjrtBackend`] (feature `pjrt`, default): loads AOT HLO-text
//!   artifacts and executes them through the PJRT CPU client — python
//!   lowers each fed-op once (`make artifacts`), rust compiles lazily and
//!   caches per op. The original, kernel-faithful path.
//! * [`NativeBackend`]: the same ops in pure Rust ([`mlp`]) — no
//!   artifacts, no `xla` crate, runs in any container. The reference
//!   implementation the integration-test tier runs on, and the
//!   differential-testing counterpart of the PJRT kernels
//!   (`tests/backend_parity_test.rs`).
//!
//! [`FedOps`] binds a backend to one model; [`open_backend`] resolves the
//! configured [`crate::config::BackendKind`] (TOML `[runtime] backend`,
//! `--backend`, `FED3SFC_BACKEND`, default auto).

pub mod backend;
pub mod bytes;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod fedops;
pub mod kernels;
#[cfg(feature = "pjrt")]
pub mod literal;
pub mod mlp;
pub mod native;

pub use backend::{open_backend, open_backend_kind, Backend, BackendSpec, RuntimeStats};
pub use kernels::Workspace;
#[cfg(feature = "pjrt")]
pub use client::{PjrtBackend, Runtime};
pub use fedops::FedOps;
pub use native::NativeBackend;
