//! The pure-Rust compute backend: every fed-op implemented over
//! [`crate::runtime::mlp`], no artifacts, no `xla` dependency.
//!
//! Covers the MLP model family (`mlp_small`, `mlp10`, `mlp26` — the
//! paper's MLP pairings); the conv models remain PJRT-only and asking for
//! them returns a clear error. Initial weights are He-normal like the AOT
//! export, drawn from this crate's deterministic PRNG (a *different*
//! stream than numpy's, so absolute trajectories differ from PJRT runs
//! unless the caller pins `initial_weights`; the parity test does).
//!
//! `NativeBackend` is `Send` and construction touches no filesystem, so
//! worker pools and bare containers can spin one up per thread for free.
//!
//! Each backend instance owns a [`Workspace`] scratch arena (behind the
//! same single-thread `RefCell` discipline as the stats counters): every
//! op's intermediates are pooled checkouts, so after one warm-up
//! execution per op the only allocations left are the result vectors the
//! [`Backend`] trait returns — `tests/alloc_count_test.rs` pins the
//! exact counts. Worker pools get per-thread workspaces for free because
//! each worker opens its own backend.

use std::cell::RefCell;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::model::{Manifest, ModelInfo, OpInfo};
use crate::runtime::backend::{Backend, BackendSpec, RuntimeStats};
use crate::runtime::kernels::Workspace;
use crate::runtime::mlp::{self, MlpDims};
use crate::util::rng::Rng;
use crate::util::vecmath;

/// Marker used for `Manifest.dir` / op files of the built-in model table.
const BUILTIN: &str = "<native>";

/// (name, d_in, hidden, classes, train_batch, eval_batch) — mirrors the
/// AOT export's MLP table (`python/compile/aot.py`).
const MODELS: &[(&str, usize, usize, usize, usize, usize)] = &[
    ("mlp_small", 64, 32, 8, 16, 50),
    ("mlp10", 784, 250, 10, 32, 100),
    ("mlp26", 784, 250, 26, 32, 100),
];

fn op(name: &str, kind: &str, k: usize, batch: usize, m: usize) -> (String, OpInfo) {
    (
        name.to_string(),
        OpInfo {
            name: name.to_string(),
            file: BUILTIN.to_string(),
            kind: kind.to_string(),
            k,
            batch,
            m,
        },
    )
}

fn builtin_manifest() -> Manifest {
    let mut models = std::collections::BTreeMap::new();
    for &(name, d, h, c, bt, be) in MODELS {
        let dims = MlpDims { d, h, c };
        let mut ops = std::collections::BTreeMap::new();
        for k in [1usize, 5, 10] {
            ops.extend([op(&format!("train_k{k}"), "train", k, bt, 0)]);
        }
        ops.extend([op("grad", "grad", 0, bt, 0), op("eval", "eval", 0, be, 0)]);
        for m in [1usize, 2, 4] {
            ops.extend([
                op(&format!("syn_step_m{m}"), "syn_step", 0, 0, m),
                op(&format!("syn_grad_m{m}"), "syn_grad", 0, 0, m),
            ]);
        }
        let fed_ks: &[usize] = if name == "mlp_small" { &[1, 2, 4, 8, 16] } else { &[4] };
        for &k in fed_ks {
            ops.extend([
                op(&format!("fedsynth_k{k}_m1"), "fedsynth", k, 0, 1),
                op(&format!("fedsynth_apply_k{k}_m1"), "fedsynth_apply", k, 0, 1),
            ]);
        }
        models.insert(
            name.to_string(),
            ModelInfo {
                name: name.to_string(),
                params: dims.params(),
                input_shape: vec![d],
                n_classes: c,
                train_batch: bt,
                eval_batch: be,
                init_file: BUILTIN.to_string(),
                ops,
            },
        );
    }
    Manifest { dir: PathBuf::from(BUILTIN), models }
}

/// Pure-Rust reference backend (see module docs).
pub struct NativeBackend {
    manifest: Manifest,
    stats: RefCell<RuntimeStats>,
    /// Reusable scratch for every op — zero allocations after warm-up.
    ws: RefCell<Workspace>,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend {
            manifest: builtin_manifest(),
            stats: RefCell::new(RuntimeStats::default()),
            ws: RefCell::new(Workspace::new()),
        }
    }

    /// The MLP shape behind a manifest entry; errors for non-MLP models
    /// (conv architectures are PJRT-only).
    fn dims(&self, model: &ModelInfo) -> Result<MlpDims> {
        ensure!(
            model.input_shape.len() == 1,
            "model '{}' is not supported by the native backend (conv models are PJRT-only)",
            model.name
        );
        let d = model.feature_len();
        let c = model.n_classes;
        let denom = d + c + 1;
        let h = (model.params.saturating_sub(c)) / denom;
        let dims = MlpDims { d, h, c };
        ensure!(
            h >= 1 && dims.params() == model.params,
            "model '{}' parameter count {} does not match a 2-layer MLP over {d}→{c}",
            model.name,
            model.params
        );
        Ok(dims)
    }

    /// Run `f` under the execution counters.
    fn timed<T>(&self, f: impl FnOnce() -> T) -> T {
        // detlint: allow(DET001) -- RuntimeStats wall-time diagnostics:
        // reported at exit, never fed into trajectories or the sim clock.
        let t0 = Instant::now();
        let out = f();
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_ms += t0.elapsed().as_secs_f64() * 1e3;
        out
    }
}

#[allow(clippy::too_many_arguments)]
impl Backend for NativeBackend {
    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn platform(&self) -> String {
        "native (pure rust)".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn stats(&self) -> RuntimeStats {
        *self.stats.borrow()
    }

    fn spec(&self) -> BackendSpec {
        BackendSpec::Native
    }

    fn load_init(&self, model: &ModelInfo) -> Result<Vec<f32>> {
        let dims = self.dims(model)?;
        // He-normal weights, zero biases; one fixed stream per model name
        // so every backend instance hands out identical weights.
        let name_tag = model
            .name
            .bytes()
            .fold(0u64, |acc, b| acc.wrapping_mul(131).wrapping_add(b as u64));
        // detlint: allow(DET003) -- fixed-constant root by design: init
        // weights depend only on the model name, identical on any backend
        // instance (the experiment seed must not perturb them).
        let mut rng = Rng::new(0xF3D_0E17).split(name_tag);
        let mut w = vec![0.0f32; dims.params()];
        {
            let (w1, rest) = w.split_at_mut(dims.d * dims.h);
            let (_b1, rest) = rest.split_at_mut(dims.h);
            let (w2, _b2) = rest.split_at_mut(dims.h * dims.c);
            rng.fill_normal(w1, (2.0f32 / dims.d as f32).sqrt());
            rng.fill_normal(w2, (2.0f32 / dims.h as f32).sqrt());
        }
        Ok(w)
    }

    fn local_train(
        &self,
        model: &ModelInfo,
        k: usize,
        w: &[f32],
        xs: &[f32],
        ys: &[i32],
        lr: f32,
    ) -> Result<Vec<f32>> {
        let dims = self.dims(model)?;
        ensure!(w.len() == model.params, "w len");
        ensure!(k >= 1 && ys.len() % k == 0, "ys len");
        let b = ys.len() / k;
        ensure!(xs.len() == k * b * dims.d, "xs len");
        Ok(self.timed(|| {
            let mut ws = self.ws.borrow_mut();
            let mut out = vec![0.0f32; w.len()];
            mlp::sgd_steps(&dims, w, xs, ys, k, b, lr, &mut ws, &mut out);
            out
        }))
    }

    fn grad_batch(&self, model: &ModelInfo, w: &[f32], x: &[f32], y: &[i32]) -> Result<Vec<f32>> {
        let dims = self.dims(model)?;
        ensure!(x.len() == y.len() * dims.d, "x len");
        Ok(self.timed(|| {
            let mut ws = self.ws.borrow_mut();
            let mut gw = vec![0.0f32; dims.params()];
            mlp::loss_grad_hard(&dims, w, x, y, &mut ws, &mut gw);
            gw
        }))
    }

    fn syn_step(
        &self,
        model: &ModelInfo,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dx: &[f32],
        dy: &[f32],
        lr_syn: f32,
        lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        let dims = self.dims(model)?;
        ensure!(dx.len() == m * dims.d && dy.len() == m * dims.c, "syn shapes");
        ensure!(g_target.len() == model.params, "g_target len");
        Ok(self.timed(|| {
            let mut ws = self.ws.borrow_mut();
            // Value pass: g = ∇_w L(D_syn, w) and the kernels' cosine
            // (ε = 1e-12 inside the rsqrt, matching python/compile).
            let sg = mlp::soft_grads(&dims, w, None, dx, dy, m, &mut ws);
            let g = &sg.gw;
            let dval = vecmath::dot(g, g_target);
            let na = vecmath::norm2(g);
            let nb = vecmath::norm2(g_target);
            let r = 1.0 / (na * nb + 1e-12).sqrt();
            let cos = (dval * r) as f32;
            // u = ∂(−|cos|)/∂g = −sign(cos)·(r·t − d·nb·r³·g).
            let sign = if cos > 0.0 {
                1.0f64
            } else if cos < 0.0 {
                -1.0
            } else {
                0.0
            };
            let r3 = r * r * r;
            let mut u = ws.take(g.len());
            for (uv, (&gi, &ti)) in u.iter_mut().zip(g.iter().zip(g_target.iter())) {
                *uv = (-sign * (r * ti as f64 - dval * nb * r3 * gi as f64)) as f32;
            }
            // Tangent pass: ∇_{dx,dy} ⟨g, u⟩, plus the λ‖D‖² regularizer.
            let tg = mlp::soft_grads(&dims, w, Some(&u), dx, dy, m, &mut ws);
            let dx2: Vec<f32> = dx
                .iter()
                .zip(tg.gx_dot.iter())
                .map(|(&xv, &gv)| xv - lr_syn * (gv + 2.0 * lambda * xv))
                .collect();
            let dy2: Vec<f32> = dy
                .iter()
                .zip(tg.gdy_dot.iter())
                .map(|(&yv, &gv)| yv - lr_syn * (gv + 2.0 * lambda * yv))
                .collect();
            ws.give(u);
            sg.release(&mut ws);
            tg.release(&mut ws);
            (dx2, dy2, cos)
        }))
    }

    fn has_syn_opt(&self, _model: &ModelInfo, _m: usize, _s: usize) -> bool {
        // The fused S-step encoder is an artifact-level optimization; the
        // native path always loops `syn_step` host-side (identical math).
        false
    }

    fn syn_opt(
        &self,
        _model: &ModelInfo,
        m: usize,
        s: usize,
        _w: &[f32],
        _g_target: &[f32],
        _dx: &[f32],
        _dy: &[f32],
        _lr_syn: f32,
        _lambda: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>, f32, f32)> {
        bail!("native backend has no fused syn_opt (m={m}, s={s}); loop syn_step instead")
    }

    fn syn_grad(
        &self,
        model: &ModelInfo,
        m: usize,
        w: &[f32],
        dx: &[f32],
        dy: &[f32],
    ) -> Result<Vec<f32>> {
        let dims = self.dims(model)?;
        ensure!(dx.len() == m * dims.d && dy.len() == m * dims.c, "syn shapes");
        Ok(self.timed(|| {
            let mut ws = self.ws.borrow_mut();
            let sg = mlp::soft_grads(&dims, w, None, dx, dy, m, &mut ws);
            // Move the gradient out (no [P] memcpy); recycle the rest.
            let mlp::SoftGrads { gw, gx, gdy, gw_dot, gx_dot, gdy_dot, loss: _ } = sg;
            for buf in [gx, gdy, gw_dot, gx_dot, gdy_dot] {
                ws.give(buf);
            }
            gw
        }))
    }

    fn eval_batch(&self, model: &ModelInfo, w: &[f32], x: &[f32], y: &[i32]) -> Result<(f32, f32)> {
        let dims = self.dims(model)?;
        ensure!(x.len() == y.len() * dims.d, "x len");
        Ok(self.timed(|| {
            let mut ws = self.ws.borrow_mut();
            mlp::eval_batch(&dims, w, x, y, &mut ws)
        }))
    }

    fn fedsynth_step(
        &self,
        model: &ModelInfo,
        k: usize,
        m: usize,
        w: &[f32],
        g_target: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
        lr_syn: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, f32, Vec<f32>)> {
        let dims = self.dims(model)?;
        let (d, c) = (dims.d, dims.c);
        ensure!(dxs.len() == k * m * d && dys.len() == k * m * c, "fedsynth shapes");
        ensure!(g_target.len() == model.params, "g_target len");
        Ok(self.timed(|| {
            let mut ws = self.ws.borrow_mut();
            // Forward: replay the K_sim inner steps, keeping each step's
            // starting weights for the backward sweep.
            let mut wcs: Vec<Vec<f32>> = Vec::with_capacity(k);
            let mut wc = ws.take(w.len());
            wc.copy_from_slice(w);
            for j in 0..k {
                let mut wj = ws.take(w.len());
                wj.copy_from_slice(&wc);
                wcs.push(wj);
                let sg = mlp::soft_grads(
                    &dims,
                    &wc,
                    None,
                    &dxs[j * m * d..(j + 1) * m * d],
                    &dys[j * m * c..(j + 1) * m * c],
                    m,
                    &mut ws,
                );
                vecmath::axpy(-lr_inner, &sg.gw, &mut wc);
                sg.release(&mut ws);
            }
            // fit = ‖(w − w_K) − g_target‖²; residual drives the adjoint.
            let mut resid = ws.take(w.len());
            for (rv, ((&w0, &wk), &t)) in resid
                .iter_mut()
                .zip(w.iter().zip(wc.iter()).zip(g_target.iter()))
            {
                *rv = (w0 - wk) - t;
            }
            let fit = vecmath::norm2(&resid) as f32;
            // λ_K = ∂fit/∂w_K = −2·resid; walk the unroll backwards. Per
            // step: the synthetic-batch gradients are the cross second
            // derivatives ∇_{dx,dy}⟨∇_w L, λ⟩ scaled by −lr, and the
            // adjoint update needs the HVP ∇_w⟨∇_w L, λ⟩ — all three are
            // the tangents of one dual pass at (w_j, λ_{j+1}).
            let mut lam = ws.take(w.len());
            for (lv, &rv) in lam.iter_mut().zip(resid.iter()) {
                *lv = -2.0 * rv;
            }
            let mut gdxs = ws.take(k * m * d);
            let mut gdys = ws.take(k * m * c);
            let mut norms = vec![0.0f32; k];
            for j in (0..k).rev() {
                let sg = mlp::soft_grads(
                    &dims,
                    &wcs[j],
                    Some(&lam),
                    &dxs[j * m * d..(j + 1) * m * d],
                    &dys[j * m * c..(j + 1) * m * c],
                    m,
                    &mut ws,
                );
                let gdx = &mut gdxs[j * m * d..(j + 1) * m * d];
                for (o, &t) in gdx.iter_mut().zip(sg.gx_dot.iter()) {
                    *o = -lr_inner * t;
                }
                norms[j] = vecmath::norm(gdx) as f32;
                for (o, &t) in gdys[j * m * c..(j + 1) * m * c]
                    .iter_mut()
                    .zip(sg.gdy_dot.iter())
                {
                    *o = -lr_inner * t;
                }
                vecmath::axpy(-lr_inner, &sg.gw_dot, &mut lam);
                sg.release(&mut ws);
            }
            let dxs2: Vec<f32> = dxs
                .iter()
                .zip(gdxs.iter())
                .map(|(&x, &g)| x - lr_syn * g)
                .collect();
            let dys2: Vec<f32> = dys
                .iter()
                .zip(gdys.iter())
                .map(|(&y, &g)| y - lr_syn * g)
                .collect();
            ws.give(wc);
            ws.give(resid);
            ws.give(lam);
            ws.give(gdxs);
            ws.give(gdys);
            for wj in wcs {
                ws.give(wj);
            }
            (dxs2, dys2, fit, norms)
        }))
    }

    fn fedsynth_apply(
        &self,
        model: &ModelInfo,
        k: usize,
        m: usize,
        w: &[f32],
        dxs: &[f32],
        dys: &[f32],
        lr_inner: f32,
    ) -> Result<Vec<f32>> {
        let dims = self.dims(model)?;
        let (d, c) = (dims.d, dims.c);
        ensure!(dxs.len() == k * m * d && dys.len() == k * m * c, "fedsynth shapes");
        Ok(self.timed(|| {
            let mut ws = self.ws.borrow_mut();
            let mut wc = ws.take(w.len());
            wc.copy_from_slice(w);
            for j in 0..k {
                let sg = mlp::soft_grads(
                    &dims,
                    &wc,
                    None,
                    &dxs[j * m * d..(j + 1) * m * d],
                    &dys[j * m * c..(j + 1) * m * c],
                    m,
                    &mut ws,
                );
                vecmath::axpy(-lr_inner, &sg.gw, &mut wc);
                sg.release(&mut ws);
            }
            let out = vecmath::sub(w, &wc);
            ws.give(wc);
            out
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_has_the_mlp_family() {
        let be = NativeBackend::new();
        for (name, params) in [("mlp_small", 2344usize), ("mlp10", 198_760), ("mlp26", 202_776)] {
            let m = be.manifest().model(name).unwrap();
            assert_eq!(m.params, params, "{name}");
            assert!(m.ops.contains_key("eval"));
            assert!(m.ops.contains_key("syn_step_m1"));
            assert!(m.ops.contains_key("train_k5"));
        }
        assert!(be.manifest().model("convnet").is_err());
    }

    #[test]
    fn init_is_deterministic_and_he_scaled() {
        let a = NativeBackend::new();
        let b = NativeBackend::new();
        let model = a.manifest().model("mlp_small").unwrap().clone();
        let wa = a.load_init(&model).unwrap();
        let wb = b.load_init(&model).unwrap();
        assert_eq!(wa.len(), model.params);
        assert_eq!(wa, wb);
        // Biases are zero.
        let dims = a.dims(&model).unwrap();
        let b1 = &wa[dims.d * dims.h..dims.d * dims.h + dims.h];
        assert!(b1.iter().all(|&v| v == 0.0));
        // W1 std ≈ sqrt(2/d).
        let w1 = &wa[..dims.d * dims.h];
        let var = w1.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / w1.len() as f64;
        let want = 2.0 / dims.d as f64;
        assert!((var - want).abs() < 0.3 * want, "var {var} want {want}");
    }

    #[test]
    fn stats_count_executions() {
        let be = NativeBackend::new();
        let model = be.manifest().model("mlp_small").unwrap().clone();
        let w = be.load_init(&model).unwrap();
        let x = vec![0.1f32; 4 * 64];
        let y = vec![0i32, 1, 2, 3];
        be.eval_batch(&model, &w, &x, &y).unwrap();
        be.grad_batch(&model, &w, &x, &y).unwrap();
        let st = be.stats();
        assert_eq!(st.compiles, 0);
        assert_eq!(st.executions, 2);
    }

    #[test]
    fn ops_are_pure_functions_of_inputs_despite_workspace_reuse() {
        // The scratch pool must never leak state between ops: running an
        // unrelated op in between cannot change a result bit.
        let be = NativeBackend::new();
        let model = be.manifest().model("mlp_small").unwrap().clone();
        let w = be.load_init(&model).unwrap();
        let x = vec![0.3f32; 8 * 64];
        let y: Vec<i32> = (0..8).map(|i| (i % 8) as i32).collect();
        let g1 = be.grad_batch(&model, &w, &x, &y).unwrap();
        // Interleave other ops that churn the pool with different shapes.
        be.eval_batch(&model, &w, &x[..64 * 4], &y[..4]).unwrap();
        be.local_train(&model, 2, &w, &x, &y, 0.1).unwrap();
        let g2 = be.grad_batch(&model, &w, &x, &y).unwrap();
        assert_eq!(g1, g2, "grad_batch must be deterministic across pool reuse");
    }
}
