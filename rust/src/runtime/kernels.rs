//! Register-blocked, cache-friendly compute kernels for the native
//! backend, plus the zero-alloc [`Workspace`] buffer arena.
//!
//! The naive `ikj` GEMMs the native backend launched with (kept verbatim
//! in [`naive`] as the differential-testing oracle and the before/after
//! bench reference) re-load and re-store every output element once per
//! depth step: the inner loop is `orow += a[i][l] * b[l]`, so each of the
//! `m·n` outputs round-trips through memory `k` times. The kernels here
//! accumulate a 4×8 register tile across the whole depth loop and touch
//! the output exactly once per tile:
//!
//! * [`mm`] / [`mm_acc`] — `out (+)= a·b`, 4 rows × 8 columns of
//!   accumulators; the depth loop does 32 independent FMAs per iteration,
//!   which LLVM auto-vectorizes (the 8-wide column dimension maps onto
//!   SIMD lanes) with no dependency chain on memory.
//! * [`mm_at_acc`] — `out += aᵀ·b` with the same tiling; both operand
//!   reads are contiguous rows, so the transpose costs nothing.
//! * [`mm_bt_acc`] — `out += a·bᵀ`: a dot-product kernel, blocked 4
//!   b-rows at a time with 4 partial-sum lanes per row to break the
//!   single-accumulator dependency chain of the naive version.
//!
//! Ragged edges (dimensions not divisible by the tile) fall back to the
//! naive loop structure on the remainder strip only. All reductions are
//! sequential with a fixed association order, so results are deterministic
//! for a given shape — `threads = N` stays bit-identical to `threads = 1`
//! — and `tests/kernel_parity_test.rs` pins the tiled kernels against the
//! [`naive`] oracle to ≤ 1e-5 relative error on random (ragged) shapes.

/// Rows of `out` accumulated per register tile.
const MR: usize = 4;
/// Columns of `out` accumulated per register tile (SIMD-lane dimension).
const NR: usize = 8;
/// Partial-sum lanes in the dot-product (`a·bᵀ`) kernel.
const LANES: usize = 4;

/// `out = a·b` for row-major `a: [m×k]`, `b: [k×n]`.
pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    mm_acc(a, b, m, k, n, out);
}

/// `out += a·b` for row-major `a: [m×k]`, `b: [k×n]`.
pub fn mm_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mi = (m / MR) * MR;
    let nj = (n / NR) * NR;
    let mut i = 0;
    while i < mi {
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        let mut j = 0;
        while j < nj {
            let mut acc = [[0.0f32; NR]; MR];
            for l in 0..k {
                let bl: &[f32; NR] = b[l * n + j..l * n + j + NR].try_into().unwrap();
                let (av0, av1, av2, av3) = (a0[l], a1[l], a2[l], a3[l]);
                for c in 0..NR {
                    acc[0][c] += av0 * bl[c];
                    acc[1][c] += av1 * bl[c];
                    acc[2][c] += av2 * bl[c];
                    acc[3][c] += av3 * bl[c];
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let orow = &mut out[(i + r) * n + j..(i + r) * n + j + NR];
                for (o, &v) in orow.iter_mut().zip(accr.iter()) {
                    *o += v;
                }
            }
            j += NR;
        }
        if j < n {
            // Ragged column strip: naive on the last n − nj columns.
            for r in 0..MR {
                let ar = &a[(i + r) * k..(i + r + 1) * k];
                let orow = &mut out[(i + r) * n + j..(i + r) * n + n];
                for (l, &av) in ar.iter().enumerate() {
                    for (o, &bv) in orow.iter_mut().zip(b[l * n + j..l * n + n].iter()) {
                        *o += av * bv;
                    }
                }
            }
        }
        i += MR;
    }
    // Ragged row strip: naive rows (inner loop still vectorizes over n).
    for i in mi..m {
        let ar = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (l, &av) in ar.iter().enumerate() {
            for (o, &bv) in orow.iter_mut().zip(b[l * n..(l + 1) * n].iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out += aᵀ·b` for `a: [k×m]`, `b: [k×n]` → `out: [m×n]`.
pub fn mm_at_acc(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mi = (m / MR) * MR;
    let nj = (n / NR) * NR;
    let mut i = 0;
    while i < mi {
        let mut j = 0;
        while j < nj {
            let mut acc = [[0.0f32; NR]; MR];
            for l in 0..k {
                let al: &[f32; MR] = a[l * m + i..l * m + i + MR].try_into().unwrap();
                let bl: &[f32; NR] = b[l * n + j..l * n + j + NR].try_into().unwrap();
                for c in 0..NR {
                    acc[0][c] += al[0] * bl[c];
                    acc[1][c] += al[1] * bl[c];
                    acc[2][c] += al[2] * bl[c];
                    acc[3][c] += al[3] * bl[c];
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                let orow = &mut out[(i + r) * n + j..(i + r) * n + j + NR];
                for (o, &v) in orow.iter_mut().zip(accr.iter()) {
                    *o += v;
                }
            }
            j += NR;
        }
        if j < n {
            for l in 0..k {
                for r in 0..MR {
                    let av = a[l * m + i + r];
                    let orow = &mut out[(i + r) * n + j..(i + r) * n + n];
                    for (o, &bv) in orow.iter_mut().zip(b[l * n + j..l * n + n].iter()) {
                        *o += av * bv;
                    }
                }
            }
        }
        i += MR;
    }
    for i in mi..m {
        for l in 0..k {
            let av = a[l * m + i];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(b[l * n..(l + 1) * n].iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `out += a·bᵀ` for `a: [m×k]`, `b: [n×k]` → `out: [m×n]`.
pub fn mm_bt_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let nj = (n / LANES) * LANES;
    let kq = (k / LANES) * LANES;
    for i in 0..m {
        let ar = &a[i * k..(i + 1) * k];
        let mut j = 0;
        while j < nj {
            let b0 = &b[j * k..(j + 1) * k];
            let b1 = &b[(j + 1) * k..(j + 2) * k];
            let b2 = &b[(j + 2) * k..(j + 3) * k];
            let b3 = &b[(j + 3) * k..(j + 4) * k];
            let mut acc = [[0.0f32; LANES]; LANES];
            let mut l = 0;
            while l < kq {
                let av: &[f32; LANES] = ar[l..l + LANES].try_into().unwrap();
                let bv0: &[f32; LANES] = b0[l..l + LANES].try_into().unwrap();
                let bv1: &[f32; LANES] = b1[l..l + LANES].try_into().unwrap();
                let bv2: &[f32; LANES] = b2[l..l + LANES].try_into().unwrap();
                let bv3: &[f32; LANES] = b3[l..l + LANES].try_into().unwrap();
                for t in 0..LANES {
                    acc[0][t] += av[t] * bv0[t];
                    acc[1][t] += av[t] * bv1[t];
                    acc[2][t] += av[t] * bv2[t];
                    acc[3][t] += av[t] * bv3[t];
                }
                l += LANES;
            }
            let mut tail = [0.0f32; LANES];
            for l in kq..k {
                let av = ar[l];
                tail[0] += av * b0[l];
                tail[1] += av * b1[l];
                tail[2] += av * b2[l];
                tail[3] += av * b3[l];
            }
            for (c, accc) in acc.iter().enumerate() {
                let s = ((accc[0] + accc[1]) + (accc[2] + accc[3])) + tail[c];
                out[i * n + j + c] += s;
            }
            j += LANES;
        }
        while j < n {
            let br = &b[j * k..(j + 1) * k];
            let mut acc = [0.0f32; LANES];
            let mut l = 0;
            while l < kq {
                let av: &[f32; LANES] = ar[l..l + LANES].try_into().unwrap();
                let bv: &[f32; LANES] = br[l..l + LANES].try_into().unwrap();
                for t in 0..LANES {
                    acc[t] += av[t] * bv[t];
                }
                l += LANES;
            }
            let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            for l in kq..k {
                s += ar[l] * br[l];
            }
            out[i * n + j] += s;
            j += 1;
        }
    }
}

/// Per-row column sum: `out[j] = Σ_i a[i][j]` for `a: [m×n]`.
pub fn colsum(a: &[f32], m: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(out.len(), n);
    out.fill(0.0);
    for row in a.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row.iter()) {
            *o += v;
        }
    }
}

/// Row-wise softmax + log-softmax (max-subtracted, like `jax.nn`).
pub fn softmax_rows(z: &[f32], rows: usize, n: usize, p: &mut [f32], logp: &mut [f32]) {
    debug_assert_eq!(z.len(), rows * n);
    debug_assert_eq!(p.len(), rows * n);
    debug_assert_eq!(logp.len(), rows * n);
    for i in 0..rows {
        let row = &z[i * n..(i + 1) * n];
        let prow = &mut p[i * n..(i + 1) * n];
        let lrow = &mut logp[i * n..(i + 1) * n];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut s = 0.0f32;
        for (pv, &v) in prow.iter_mut().zip(row.iter()) {
            let e = (v - mx).exp();
            *pv = e;
            s += e;
        }
        let ln_s = s.ln();
        for ((pv, lv), &v) in prow.iter_mut().zip(lrow.iter_mut()).zip(row.iter()) {
            *pv /= s;
            *lv = v - mx - ln_s;
        }
    }
}

/// The unoptimized kernels the native backend shipped with — retained as
/// the differential-testing oracle (`tests/kernel_parity_test.rs`) and as
/// the "before" side of the `benches/hotpath.rs` kernel table. Loop
/// structure is the original `ikj` / per-element form, unchanged.
pub mod naive {
    /// `out = a·b` (ikj loop order).
    pub fn mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(out.len(), m * n);
        out.fill(0.0);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (l, &av) in arow.iter().enumerate() {
                let brow = &b[l * n..(l + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
    }

    /// `out += aᵀ·b` for `a: [k×m]`, `b: [k×n]` → `out: [m×n]`.
    pub fn mm_at_acc(a: &[f32], b: &[f32], k: usize, m: usize, n: usize, out: &mut [f32]) {
        debug_assert_eq!(a.len(), k * m);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(out.len(), m * n);
        for l in 0..k {
            let arow = &a[l * m..(l + 1) * m];
            let brow = &b[l * n..(l + 1) * n];
            for (i, &av) in arow.iter().enumerate() {
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
    }

    /// `out += a·bᵀ` for `a: [m×k]`, `b: [n×k]` → `out: [m×n]`.
    pub fn mm_bt_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(out.len(), m * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..n {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (&av, &bv) in arow.iter().zip(brow.iter()) {
                    acc += av * bv;
                }
                out[i * n + j] += acc;
            }
        }
    }
}

/// Reusable scratch-buffer arena: checked-out buffers are owned `Vec`s
/// (no lifetime coupling to the arena), returned with [`Workspace::give`]
/// for reuse. After an op has run once per shape, subsequent executions
/// perform no heap allocation inside the op — only the result vectors the
/// `Backend` trait hands to the caller are freshly allocated
/// (`tests/alloc_count_test.rs` pins the exact counts).
///
/// [`Workspace::take`] always returns a **zeroed** buffer, so op results
/// are pure functions of their inputs regardless of pool history — the
/// property the `threads = 1` vs `threads = N` bit-identity rests on.
#[derive(Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f32>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace { pool: Vec::new() }
    }

    /// Check out a zeroed buffer of length `n`, reusing the pooled vector
    /// with the smallest sufficient capacity (best fit, so small requests
    /// do not starve later large ones).
    pub fn take(&mut self, n: usize) -> Vec<f32> {
        let mut best: Option<(usize, usize)> = None;
        for (i, v) in self.pool.iter().enumerate() {
            let cap = v.capacity();
            let better = match best {
                None => true,
                Some((_, best_cap)) => cap < best_cap,
            };
            if cap >= n && better {
                best = Some((i, cap));
            }
        }
        let mut v = match best {
            Some((i, _)) => self.pool.swap_remove(i),
            // No pooled buffer fits: allocate fresh rather than growing a
            // smaller pooled vector — growing would strip the pool of a
            // buffer some other op is sized for (and ops like `syn_grad`
            // move their checkout out as the result, so a no-fit miss
            // must not cannibalize the pool).
            None => Vec::new(),
        };
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// Return a buffer to the pool. Zero-capacity vectors are dropped —
    /// pooling them would just re-allocate on the next checkout.
    pub fn give(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.pool.push(v);
        }
    }

    /// Buffers currently parked in the pool (test visibility).
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn assert_close(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: len");
        for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
            let tol = 1e-5f32 * w.abs().max(1.0);
            assert!((g - w).abs() <= tol, "{what}[{i}]: {g} vs {w}");
        }
    }

    // Shapes chosen to hit every code path: full tiles, ragged rows,
    // ragged columns, ragged depth, degenerate m = 1 / n = 1 / k = 1.
    const SHAPES: &[(usize, usize, usize)] = &[
        (4, 8, 8),
        (8, 16, 32),
        (1, 7, 5),
        (5, 13, 9),
        (3, 1, 17),
        (7, 10, 1),
        (9, 33, 23),
        (16, 4, 40),
        (2, 100, 3),
    ];

    #[test]
    fn mm_matches_naive_oracle() {
        let mut rng = Rng::new(101);
        for &(m, k, n) in SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut got = vec![0.0f32; m * n];
            let mut want = vec![0.0f32; m * n];
            mm(&a, &b, m, k, n, &mut got);
            naive::mm(&a, &b, m, k, n, &mut want);
            assert_close(&got, &want, &format!("mm {m}x{k}x{n}"));
        }
    }

    #[test]
    fn mm_acc_accumulates_onto_existing_output() {
        let mut rng = Rng::new(102);
        let (m, k, n) = (5, 9, 11);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let base = rand_vec(&mut rng, m * n);
        let mut got = base.clone();
        mm_acc(&a, &b, m, k, n, &mut got);
        let mut prod = vec![0.0f32; m * n];
        naive::mm(&a, &b, m, k, n, &mut prod);
        let want: Vec<f32> = base.iter().zip(prod.iter()).map(|(x, y)| x + y).collect();
        assert_close(&got, &want, "mm_acc");
    }

    #[test]
    fn mm_at_acc_matches_naive_oracle() {
        let mut rng = Rng::new(103);
        for &(m, k, n) in SHAPES {
            let a = rand_vec(&mut rng, k * m);
            let b = rand_vec(&mut rng, k * n);
            let mut got = vec![0.0f32; m * n];
            let mut want = vec![0.0f32; m * n];
            mm_at_acc(&a, &b, k, m, n, &mut got);
            naive::mm_at_acc(&a, &b, k, m, n, &mut want);
            assert_close(&got, &want, &format!("mm_at {k}x{m}x{n}"));
        }
    }

    #[test]
    fn mm_bt_acc_matches_naive_oracle() {
        let mut rng = Rng::new(104);
        for &(m, k, n) in SHAPES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, n * k);
            let mut got = vec![0.0f32; m * n];
            let mut want = vec![0.0f32; m * n];
            mm_bt_acc(&a, &b, m, k, n, &mut got);
            naive::mm_bt_acc(&a, &b, m, k, n, &mut want);
            assert_close(&got, &want, &format!("mm_bt {m}x{k}x{n}"));
        }
    }

    #[test]
    fn colsum_and_softmax_basics() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut s = [0.0f32; 3];
        colsum(&a, 2, 3, &mut s);
        assert_eq!(s, [5.0, 7.0, 9.0]);

        let z = [0.0f32, 1.0, 2.0, -1.0];
        let mut p = [0.0f32; 4];
        let mut lp = [0.0f32; 4];
        softmax_rows(&z, 2, 2, &mut p, &mut lp);
        for row in 0..2 {
            let sum: f32 = p[row * 2..(row + 1) * 2].iter().sum();
            assert!((sum - 1.0).abs() < 1e-6, "row {row} sums to {sum}");
        }
        for i in 0..4 {
            assert!((lp[i].exp() - p[i]).abs() < 1e-6);
        }
        // Second row: z = [2, -1] ⇒ p0 = e^3/(e^3+1).
        let want = (3.0f32).exp() / ((3.0f32).exp() + 1.0);
        assert!((p[2] - want).abs() < 1e-6);
    }

    #[test]
    fn workspace_buffers_are_zeroed_and_reused() {
        let mut ws = Workspace::new();
        let mut v = ws.take(64);
        assert!(v.iter().all(|&x| x == 0.0));
        v.iter_mut().for_each(|x| *x = 7.0);
        let ptr = v.as_ptr();
        ws.give(v);
        assert_eq!(ws.pooled(), 1);
        // Smaller request reuses the same allocation, zeroed again.
        let v2 = ws.take(32);
        assert_eq!(v2.as_ptr(), ptr);
        assert_eq!(v2.len(), 32);
        assert!(v2.iter().all(|&x| x == 0.0));
        ws.give(v2);
    }

    #[test]
    fn workspace_best_fit_prefers_smallest_sufficient() {
        let mut ws = Workspace::new();
        let big = ws.take(1000);
        let small = ws.take(10);
        let (big_ptr, small_ptr) = (big.as_ptr(), small.as_ptr());
        ws.give(big);
        ws.give(small);
        let v = ws.take(8);
        assert_eq!(v.as_ptr(), small_ptr, "best fit picks the small buffer");
        ws.give(v);
        let v = ws.take(500);
        assert_eq!(v.as_ptr(), big_ptr);
        ws.give(v);
        // Empty vectors are not pooled.
        ws.give(Vec::new());
        assert_eq!(ws.pooled(), 2);
    }
}
