//! Network simulator: converts byte counts into wall-clock communication
//! time under a bandwidth/latency model — the paper's motivation is that
//! FL clients sit on slow, unreliable links (§1), so time-to-accuracy is
//! the headline metric, not just bytes.
//!
//! The model is threaded through the round loop itself (see
//! `coordinator::Experiment`): each `RoundRecord` carries a modeled
//! `comm_time_s` computed with synchronous-round semantics — the round
//! finishes when the *slowest selected* client has uploaded
//! ([`NetworkModel::round_time_slowest`]), which matters once a scheduler
//! makes participation partial or payload sizes differ across clients.
//! [`NetworkModel::total_time_s`] remains for post-hoc aggregate
//! estimates from `Traffic` totals. Presets are selected by the
//! `[network]` config table (`edge` / `datacenter` / `custom`).

/// A symmetric-per-client link model.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Client uplink, bits/second.
    pub up_bps: f64,
    /// Client downlink, bits/second.
    pub down_bps: f64,
    /// One-way latency, seconds.
    pub latency_s: f64,
}

impl NetworkModel {
    /// A typical constrained edge client: 10 Mbps up, 50 Mbps down, 30 ms.
    pub fn edge() -> NetworkModel {
        NetworkModel { up_bps: 10e6, down_bps: 50e6, latency_s: 0.030 }
    }

    /// Datacenter-ish link for contrast.
    pub fn datacenter() -> NetworkModel {
        NetworkModel { up_bps: 10e9, down_bps: 10e9, latency_s: 0.0005 }
    }

    /// Arbitrary rates in the units the config file uses.
    pub fn custom(up_mbps: f64, down_mbps: f64, latency_ms: f64) -> NetworkModel {
        NetworkModel {
            up_bps: up_mbps * 1e6,
            down_bps: down_mbps * 1e6,
            latency_s: latency_ms * 1e-3,
        }
    }

    /// Time for one synchronous round: clients transfer in parallel, so the
    /// round cost is the slowest (= any, uniform) client's up+down time.
    pub fn round_time_s(&self, up_bytes_per_client: f64, down_bytes_per_client: f64) -> f64 {
        let up = 8.0 * up_bytes_per_client / self.up_bps;
        let down = 8.0 * down_bytes_per_client / self.down_bps;
        up + down + 2.0 * self.latency_s
    }

    /// One synchronous round with per-client upload sizes: selected
    /// clients transfer in parallel, so the round completes when the
    /// slowest upload lands — `max_i up_i` — plus the (dense, identical)
    /// broadcast and two one-way latencies. Under full participation with
    /// equal payloads this equals [`NetworkModel::round_time_s`].
    pub fn round_time_slowest(&self, up_bytes_each: &[u64], down_bytes_per_client: u64) -> f64 {
        let slowest = up_bytes_each.iter().copied().max().unwrap_or(0);
        self.round_time_s(slowest as f64, down_bytes_per_client as f64)
    }

    /// Total modeled communication time for an experiment.
    pub fn total_time_s(
        &self,
        rounds: u64,
        up_bytes_total: u64,
        down_bytes_total: u64,
        n_clients: usize,
    ) -> f64 {
        if rounds == 0 || n_clients == 0 {
            return 0.0;
        }
        let per_round_up = up_bytes_total as f64 / rounds as f64 / n_clients as f64;
        let per_round_down = down_bytes_total as f64 / rounds as f64 / n_clients as f64;
        rounds as f64 * self.round_time_s(per_round_up, per_round_down)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smaller_payloads_are_faster() {
        let net = NetworkModel::edge();
        let slow = net.round_time_s(800_000.0, 800_000.0);
        let fast = net.round_time_s(300.0, 800_000.0);
        assert!(fast < slow);
        assert!(fast > 2.0 * net.latency_s);
    }

    #[test]
    fn slowest_client_dominates_round_time() {
        let net = NetworkModel::edge();
        let uniform = net.round_time_slowest(&[1000, 1000, 1000], 4000);
        let straggler = net.round_time_slowest(&[1000, 1000, 800_000], 4000);
        assert!(straggler > uniform);
        // equal payloads reduce to the homogeneous formula
        assert!((uniform - net.round_time_s(1000.0, 4000.0)).abs() < 1e-12);
        // empty selection: latency + broadcast only
        let empty = net.round_time_slowest(&[], 4000);
        assert!((empty - net.round_time_s(0.0, 4000.0)).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_payloads_cost_exactly_the_max() {
        // Order-independent, and equal to pricing the slowest client
        // alone — the synchronous-round contract partial participation
        // and mixed compressors rely on.
        let net = NetworkModel::edge();
        let payloads = [120u64, 999_999, 4, 500_000, 31];
        let t = net.round_time_slowest(&payloads, 8_000);
        let mut rev = payloads;
        rev.reverse();
        assert_eq!(t.to_bits(), net.round_time_slowest(&rev, 8_000).to_bits());
        assert!((t - net.round_time_s(999_999.0, 8_000.0)).abs() < 1e-12);
        // Growing any payload beyond the max strictly slows the round;
        // growing a non-max payload below it does nothing.
        let mut bigger = payloads;
        bigger[0] = 2_000_000;
        assert!(net.round_time_slowest(&bigger, 8_000) > t);
        let mut still_dominated = payloads;
        still_dominated[2] = 900_000;
        assert_eq!(
            t.to_bits(),
            net.round_time_slowest(&still_dominated, 8_000).to_bits()
        );
    }

    #[test]
    fn zero_selected_round_costs_broadcast_plus_latency_only() {
        // A round where every client was skipped still broadcasts and
        // pays the RTT — never NaN, never negative.
        let net = NetworkModel::edge();
        let t = net.round_time_slowest(&[], 4_000);
        assert!(t.is_finite() && t > 0.0);
        assert!((t - (8.0 * 4_000.0 / net.down_bps + 2.0 * net.latency_s)).abs() < 1e-12);
        // And with a zero broadcast too: pure latency.
        let t0 = net.round_time_slowest(&[], 0);
        assert!((t0 - 2.0 * net.latency_s).abs() < 1e-15);
    }

    #[test]
    fn custom_rates_convert_units() {
        let net = NetworkModel::custom(10.0, 50.0, 30.0);
        let edge = NetworkModel::edge();
        assert_eq!(net.up_bps, edge.up_bps);
        assert_eq!(net.down_bps, edge.down_bps);
        assert!((net.latency_s - edge.latency_s).abs() < 1e-12);
    }

    #[test]
    fn totals_scale_linearly_in_rounds() {
        let net = NetworkModel::edge();
        let t1 = net.total_time_s(10, 1_000_000, 1_000_000, 10);
        let t2 = net.total_time_s(20, 2_000_000, 2_000_000, 10);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }
}
