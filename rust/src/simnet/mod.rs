//! Network simulator: a virtual clock, per-client links, and a
//! bandwidth/latency model that converts byte counts into modeled
//! communication time — the paper's motivation is that FL clients sit on
//! slow, unreliable links (§1), so time-to-accuracy is the headline
//! metric, not just bytes.
//!
//! The simulator is threaded through the coordinator as an *event queue*
//! (see `coordinator::FedServer`): every message the server sends or
//! receives is scheduled on a [`SimClock`] at a per-client delivery time
//! computed from that client's [`ClientLink`]. Links are derived from the
//! base [`NetworkModel`] preset; the `[network] jitter` knob spreads
//! per-client bandwidth on a dedicated RNG stream
//! ([`NetworkModel::client_links`]) so heterogeneous-link scenarios
//! replay bit-for-bit from the experiment seed.
//!
//! [`NetworkModel::round_time_slowest`] and
//! [`NetworkModel::total_time_s`] remain for post-hoc aggregate estimates
//! from `Traffic` totals (under homogeneous links and synchronous rounds
//! the event queue reduces to exactly those formulas). Presets are
//! selected by the `[network]` config table (`edge` / `datacenter` /
//! `custom`).

use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

pub mod faults;

pub use faults::{
    load_trace, parse_trace, ByzantineMode, ClientFate, FaultLayer, FaultsConfig, TraceWindow,
};

/// A symmetric-per-client link model.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Client uplink, bits/second.
    pub up_bps: f64,
    /// Client downlink, bits/second.
    pub down_bps: f64,
    /// One-way latency, seconds.
    pub latency_s: f64,
}

impl NetworkModel {
    /// A typical constrained edge client: 10 Mbps up, 50 Mbps down, 30 ms.
    pub fn edge() -> NetworkModel {
        NetworkModel { up_bps: 10e6, down_bps: 50e6, latency_s: 0.030 }
    }

    /// Datacenter-ish link for contrast.
    pub fn datacenter() -> NetworkModel {
        NetworkModel { up_bps: 10e9, down_bps: 10e9, latency_s: 0.0005 }
    }

    /// Arbitrary rates in the units the config file uses.
    pub fn custom(up_mbps: f64, down_mbps: f64, latency_ms: f64) -> NetworkModel {
        NetworkModel {
            up_bps: up_mbps * 1e6,
            down_bps: down_mbps * 1e6,
            latency_s: latency_ms * 1e-3,
        }
    }

    /// Time for one synchronous round: clients transfer in parallel, so the
    /// round cost is the slowest (= any, uniform) client's up+down time.
    pub fn round_time_s(&self, up_bytes_per_client: f64, down_bytes_per_client: f64) -> f64 {
        let up = 8.0 * up_bytes_per_client / self.up_bps;
        let down = 8.0 * down_bytes_per_client / self.down_bps;
        up + down + 2.0 * self.latency_s
    }

    /// One synchronous round with per-client upload sizes: selected
    /// clients transfer in parallel, so the round completes when the
    /// slowest upload lands — `max_i up_i` — plus the (dense, identical)
    /// broadcast and two one-way latencies. Under full participation with
    /// equal payloads this equals [`NetworkModel::round_time_s`].
    pub fn round_time_slowest(&self, up_bytes_each: &[u64], down_bytes_per_client: u64) -> f64 {
        let slowest = up_bytes_each.iter().copied().max().unwrap_or(0);
        self.round_time_s(slowest as f64, down_bytes_per_client as f64)
    }

    /// Materialize per-client links from this base model.
    ///
    /// `jitter ∈ [0, 1)` spreads each client's bandwidth by a factor
    /// drawn uniformly from `[1 − jitter, 1 + jitter]` — one factor per
    /// client, applied to both directions (a slow client is slow both
    /// ways); latency is left untouched. `rng` must be a dedicated
    /// stream (see `Experiment::new`): the draw order is the client
    /// index, so link assignments replay bit-for-bit from the seed and
    /// never perturb any other randomness. `jitter = 0` yields links
    /// exactly equal to the base model.
    pub fn client_links(&self, n: usize, jitter: f64, rng: &mut Rng) -> Vec<ClientLink> {
        (0..n)
            .map(|_| {
                let f = if jitter > 0.0 { 1.0 - jitter + 2.0 * jitter * rng.f64() } else { 1.0 };
                ClientLink {
                    up_bps: self.up_bps * f,
                    down_bps: self.down_bps * f,
                    latency_s: self.latency_s,
                }
            })
            .collect()
    }

    /// Total modeled communication time for an experiment.
    pub fn total_time_s(
        &self,
        rounds: u64,
        up_bytes_total: u64,
        down_bytes_total: u64,
        n_clients: usize,
    ) -> f64 {
        if rounds == 0 || n_clients == 0 {
            return 0.0;
        }
        let per_round_up = up_bytes_total as f64 / rounds as f64 / n_clients as f64;
        let per_round_down = down_bytes_total as f64 / rounds as f64 / n_clients as f64;
        rounds as f64 * self.round_time_s(per_round_up, per_round_down)
    }
}

/// One client's link to the server (a jittered instance of the base
/// [`NetworkModel`]).
#[derive(Clone, Copy, Debug)]
pub struct ClientLink {
    pub up_bps: f64,
    pub down_bps: f64,
    pub latency_s: f64,
}

impl ClientLink {
    /// Transfer time for `bytes` on the uplink (excluding latency).
    pub fn up_time_s(&self, bytes: u64) -> f64 {
        8.0 * bytes as f64 / self.up_bps
    }

    /// Transfer time for `bytes` on the downlink (excluding latency).
    pub fn down_time_s(&self, bytes: u64) -> f64 {
        8.0 * bytes as f64 / self.down_bps
    }
}

/// A scheduled delivery: `at` is virtual seconds, `client` the sender
/// (or [`SimClock::NO_CLIENT`] for server-local timers), `payload`
/// whatever message the consumer queued.
#[derive(Debug)]
pub struct SimEvent<T> {
    pub at: f64,
    pub client: usize,
    pub payload: T,
    seq: u64,
}

impl<T> SimEvent<T> {
    /// Deterministic total order: time, then client index, then insertion
    /// sequence. The client tie-break is the contract that makes
    /// simultaneous arrivals (homogeneous links, equal payloads) process
    /// in ascending client order on every run; server-local timers use
    /// `NO_CLIENT = usize::MAX` so a deadline expiring at time `t` fires
    /// *after* every upload that lands exactly at `t`.
    fn key(&self) -> (f64, usize, u64) {
        (self.at, self.client, self.seq)
    }
}

impl<T> PartialEq for SimEvent<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl<T> Eq for SimEvent<T> {}
impl<T> PartialOrd for SimEvent<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for SimEvent<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        let (ta, ca, sa) = self.key();
        let (tb, cb, sb) = other.key();
        // Reversed: BinaryHeap is a max-heap, we want the earliest event.
        tb.total_cmp(&ta).then(cb.cmp(&ca)).then(sb.cmp(&sa))
    }
}

/// Deterministic discrete-event queue over virtual time.
///
/// The clock is the *only* time source of an event-driven session: it
/// advances exactly to each popped event's timestamp, never backwards
/// (pushing an event earlier than `now` panics — virtual sends always
/// happen at or after the present). Ties are broken by client index and
/// then by insertion order, so a run's event sequence is a pure function
/// of what was scheduled, independent of wall clock or thread timing.
#[derive(Debug)]
pub struct SimClock<T> {
    now: f64,
    seq: u64,
    heap: BinaryHeap<SimEvent<T>>,
}

impl<T> Default for SimClock<T> {
    fn default() -> Self {
        SimClock::new()
    }
}

impl<T> SimClock<T> {
    /// Client index reserved for server-local timers (sorts after every
    /// real client at the same timestamp).
    pub const NO_CLIENT: usize = usize::MAX;

    pub fn new() -> SimClock<T> {
        SimClock { now: 0.0, seq: 0, heap: BinaryHeap::new() }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` for delivery at virtual time `at` (≥ `now`).
    pub fn push(&mut self, at: f64, client: usize, payload: T) {
        assert!(
            at >= self.now && at.is_finite(),
            "event scheduled in the past or at a non-finite time: at={at}, now={}",
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(SimEvent { at, client, payload, seq });
    }

    /// Pop the earliest event and advance the clock to it.
    pub fn pop(&mut self) -> Option<SimEvent<T>> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.at >= self.now, "virtual time went backwards");
        self.now = ev.at;
        Some(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smaller_payloads_are_faster() {
        let net = NetworkModel::edge();
        let slow = net.round_time_s(800_000.0, 800_000.0);
        let fast = net.round_time_s(300.0, 800_000.0);
        assert!(fast < slow);
        assert!(fast > 2.0 * net.latency_s);
    }

    #[test]
    fn slowest_client_dominates_round_time() {
        let net = NetworkModel::edge();
        let uniform = net.round_time_slowest(&[1000, 1000, 1000], 4000);
        let straggler = net.round_time_slowest(&[1000, 1000, 800_000], 4000);
        assert!(straggler > uniform);
        // equal payloads reduce to the homogeneous formula
        assert!((uniform - net.round_time_s(1000.0, 4000.0)).abs() < 1e-12);
        // empty selection: latency + broadcast only
        let empty = net.round_time_slowest(&[], 4000);
        assert!((empty - net.round_time_s(0.0, 4000.0)).abs() < 1e-12);
    }

    #[test]
    fn heterogeneous_payloads_cost_exactly_the_max() {
        // Order-independent, and equal to pricing the slowest client
        // alone — the synchronous-round contract partial participation
        // and mixed compressors rely on.
        let net = NetworkModel::edge();
        let payloads = [120u64, 999_999, 4, 500_000, 31];
        let t = net.round_time_slowest(&payloads, 8_000);
        let mut rev = payloads;
        rev.reverse();
        assert_eq!(t.to_bits(), net.round_time_slowest(&rev, 8_000).to_bits());
        assert!((t - net.round_time_s(999_999.0, 8_000.0)).abs() < 1e-12);
        // Growing any payload beyond the max strictly slows the round;
        // growing a non-max payload below it does nothing.
        let mut bigger = payloads;
        bigger[0] = 2_000_000;
        assert!(net.round_time_slowest(&bigger, 8_000) > t);
        let mut still_dominated = payloads;
        still_dominated[2] = 900_000;
        assert_eq!(
            t.to_bits(),
            net.round_time_slowest(&still_dominated, 8_000).to_bits()
        );
    }

    #[test]
    fn zero_selected_round_costs_broadcast_plus_latency_only() {
        // A round where every client was skipped still broadcasts and
        // pays the RTT — never NaN, never negative.
        let net = NetworkModel::edge();
        let t = net.round_time_slowest(&[], 4_000);
        assert!(t.is_finite() && t > 0.0);
        assert!((t - (8.0 * 4_000.0 / net.down_bps + 2.0 * net.latency_s)).abs() < 1e-12);
        // And with a zero broadcast too: pure latency.
        let t0 = net.round_time_slowest(&[], 0);
        assert!((t0 - 2.0 * net.latency_s).abs() < 1e-15);
    }

    #[test]
    fn custom_rates_convert_units() {
        let net = NetworkModel::custom(10.0, 50.0, 30.0);
        let edge = NetworkModel::edge();
        assert_eq!(net.up_bps, edge.up_bps);
        assert_eq!(net.down_bps, edge.down_bps);
        assert!((net.latency_s - edge.latency_s).abs() < 1e-12);
    }

    #[test]
    fn totals_scale_linearly_in_rounds() {
        let net = NetworkModel::edge();
        let t1 = net.total_time_s(10, 1_000_000, 1_000_000, 10);
        let t2 = net.total_time_s(20, 2_000_000, 2_000_000, 10);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn sim_clock_orders_by_time_then_client() {
        let mut clock: SimClock<&'static str> = SimClock::new();
        clock.push(2.0, 0, "late");
        clock.push(1.0, 7, "early-high-client");
        clock.push(1.0, 3, "early-low-client");
        clock.push(1.0, SimClock::<&str>::NO_CLIENT, "timer");
        let order: Vec<&str> = std::iter::from_fn(|| clock.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["early-low-client", "early-high-client", "timer", "late"]);
    }

    #[test]
    fn sim_clock_is_monotone_and_tracks_now() {
        let mut clock: SimClock<u32> = SimClock::new();
        clock.push(0.5, 1, 1);
        clock.push(0.25, 2, 2);
        assert_eq!(clock.now(), 0.0);
        let mut last = 0.0;
        while let Some(ev) = clock.pop() {
            assert!(ev.at >= last, "virtual time regressed");
            assert_eq!(clock.now(), ev.at);
            last = ev.at;
            // Scheduling relative to `now` mid-drain is fine…
            if ev.payload == 2 {
                clock.push(clock.now() + 0.1, 9, 3);
            }
        }
        assert!((last - 0.6).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn sim_clock_rejects_events_in_the_past() {
        let mut clock: SimClock<()> = SimClock::new();
        clock.push(1.0, 0, ());
        let _ = clock.pop();
        clock.push(0.5, 0, ());
    }

    #[test]
    fn sim_clock_same_instant_same_client_keeps_insertion_order() {
        let mut clock: SimClock<u32> = SimClock::new();
        clock.push(1.0, 4, 10);
        clock.push(1.0, 4, 20);
        assert_eq!(clock.pop().unwrap().payload, 10);
        assert_eq!(clock.pop().unwrap().payload, 20);
    }

    #[test]
    fn zero_jitter_links_equal_base_model_exactly() {
        let net = NetworkModel::edge();
        let mut rng = crate::util::rng::Rng::new(3);
        for link in net.client_links(5, 0.0, &mut rng) {
            assert_eq!(link.up_bps.to_bits(), net.up_bps.to_bits());
            assert_eq!(link.down_bps.to_bits(), net.down_bps.to_bits());
            assert_eq!(link.latency_s.to_bits(), net.latency_s.to_bits());
        }
    }

    #[test]
    fn jittered_links_are_bounded_deterministic_and_spread() {
        let net = NetworkModel::edge();
        let links = net.client_links(64, 0.5, &mut crate::util::rng::Rng::new(7));
        let again = net.client_links(64, 0.5, &mut crate::util::rng::Rng::new(7));
        let mut distinct = false;
        for (a, b) in links.iter().zip(again.iter()) {
            assert_eq!(a.up_bps.to_bits(), b.up_bps.to_bits(), "links must replay from seed");
            assert!(a.up_bps >= 0.5 * net.up_bps - 1e-6 && a.up_bps <= 1.5 * net.up_bps + 1e-6);
            // One factor, both directions.
            assert!((a.up_bps / net.up_bps - a.down_bps / net.down_bps).abs() < 1e-12);
            assert_eq!(a.latency_s.to_bits(), net.latency_s.to_bits());
            if (a.up_bps - net.up_bps).abs() > 1e-3 {
                distinct = true;
            }
        }
        assert!(distinct, "jitter produced no spread");
    }

    #[test]
    fn link_transfer_times_match_model_formula() {
        let net = NetworkModel::edge();
        let link = net.client_links(1, 0.0, &mut crate::util::rng::Rng::new(1))[0];
        let t = link.latency_s + link.down_time_s(4_000) + link.latency_s + link.up_time_s(1_000);
        assert!((t - net.round_time_slowest(&[1_000], 4_000)).abs() < 1e-12);
    }
}
