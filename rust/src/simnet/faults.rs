//! Adversarial-reality fault layer: the failure modes a production fleet
//! sees, driven entirely from config (`[faults]`) and one dedicated RNG
//! stream ([`crate::util::rng::stream::FAULTS`]).
//!
//! Modeled faults:
//!
//! * **Mid-round dropout** — at dispatch time every cohort member draws
//!   one Bernoulli against its effective loss probability; a losing
//!   client's upload is *declared lost at submit time* (the envelope
//!   never lands on the virtual clock), exactly the "upload never
//!   arrives" case deadline/async policies already absorb.
//! * **Crash-and-recover windows** — a client that loses an upload is
//!   down for `recover_s` virtual seconds (it is skipped by cohort
//!   selection and re-dispatched, for async sessions, when its
//!   recovery timer fires).
//! * **Diurnal availability waves** — the loss probability is modulated
//!   by a triangle wave of virtual time (amplitude `diurnal_amp`,
//!   period `diurnal_period_s`; outage pressure peaks mid-period).
//!   A triangle — not a sinusoid — keeps the whole layer in exact
//!   `+ − × ÷` arithmetic, reproducible across every libm.
//! * **Correlated device-class tiers** — one uniform draw per client
//!   assigns a tier, and *all three* tier factors (bandwidth multiplier,
//!   extra compute delay, dropout multiplier) are derived from that one
//!   tier index: a slow device is slow, laggy and flaky together, never
//!   independently.
//!
//! * **Byzantine content attacks** — the last `⌈byzantine_frac · n⌋`
//!   clients are compromised: their decoded recons are perturbed at
//!   submit time ([`FaultLayer::corrupt`]) by the configured
//!   [`ByzantineMode`] — sign-flip, scale-amplify, gaussian-noise, or a
//!   colluding shared vector. The envelopes stay *well-formed* (finite
//!   values, honest shapes), so they sail past PR 8's validation
//!   boundary — defeating them is the robust aggregator's job
//!   (`coordinator::robust`).
//! * **Trace-driven schedules** — `[faults] trace = "fleet.jsonl"`
//!   replays a recorded availability log ([`TraceWindow`] per line)
//!   instead of the parametric dropout model: a client is down inside
//!   its logged windows, and an upload in flight when a window opens is
//!   lost, with recovery at the window's logged end.
//!
//! Determinism contract: draws happen in dispatch order on the dedicated
//! stream (tier assignment first, in client order, at construction; a
//! gaussian-noise attacker draws per corrupted coordinate at submit
//! time, in submit order), so fault trajectories replay bit-for-bit
//! from the experiment seed and are independent of worker-thread count —
//! the server is the only caller and it is single-threaded. A disabled
//! layer consumes **zero** draws and scales nothing, so `[faults]`-off
//! runs are bit-identical to builds that predate the layer; likewise
//! `byzantine_frac = 0` perturbs nothing and draws nothing, and a trace
//! replay is draw-free by construction.

use crate::simnet::ClientLink;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};

/// How a compromised client poisons its recon (well-formed, plausible
/// payloads — the envelope validator cannot catch these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ByzantineMode {
    /// `g ← −g`: push the mean uphill.
    SignFlip,
    /// `g ← 10·g`: dominate the mean by magnitude.
    ScaleAmplify,
    /// `g ← g + ε`, `ε ~ N(0, 1)` per coordinate: drown the signal.
    GaussianNoise,
    /// Every attacker submits the same fixed vector: a tight colluding
    /// cluster that targets distance-based defenses like Krum.
    Collude,
}

impl ByzantineMode {
    pub fn parse(s: &str) -> Result<ByzantineMode> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sign_flip" | "sign-flip" | "signflip" => ByzantineMode::SignFlip,
            "scale_amplify" | "scale-amplify" | "scale" => ByzantineMode::ScaleAmplify,
            "gaussian_noise" | "gaussian-noise" | "gaussian" => ByzantineMode::GaussianNoise,
            "collude" | "colluding" => ByzantineMode::Collude,
            other => bail!(
                "unknown byzantine mode '{other}' \
                 (try sign_flip|scale_amplify|gaussian_noise|collude)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ByzantineMode::SignFlip => "sign_flip",
            ByzantineMode::ScaleAmplify => "scale_amplify",
            ByzantineMode::GaussianNoise => "gaussian_noise",
            ByzantineMode::Collude => "collude",
        }
    }
}

/// Scale-amplify attack factor.
const AMPLIFY: f32 = 10.0;
/// The colluding attackers' shared per-coordinate value.
const COLLUDE_VALUE: f32 = -0.1;

/// One logged availability outage: `client` is down over
/// `[down_at, up_at)` in virtual seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceWindow {
    pub client: usize,
    pub down_at: f64,
    pub up_at: f64,
}

/// The `[faults]` config table (see `ExperimentConfig::faults_config`).
#[derive(Clone, Copy, Debug)]
pub struct FaultsConfig {
    /// Master switch; `false` makes the layer a zero-draw no-op.
    pub enabled: bool,
    /// Base per-dispatch upload-loss probability in [0, 1].
    pub dropout_p: f64,
    /// Virtual seconds a client stays down after losing an upload.
    pub recover_s: f64,
    /// Diurnal wave amplitude in [0, 1]; 0 disables the wave.
    pub diurnal_amp: f64,
    /// Diurnal wave period in virtual seconds.
    pub diurnal_period_s: f64,
    /// Number of device-class tiers (1 = homogeneous fleet).
    pub tiers: usize,
    /// How far the worst tier sits from the best, in [0, 1].
    pub tier_spread: f64,
    /// Extra upload delay (seconds) of the worst tier at spread 1.
    pub tier_compute_s: f64,
    /// Fraction of the fleet that is compromised, in [0, 1]; the last
    /// `round(frac · n)` client indices are the attackers. 0 = honest
    /// fleet (and zero attack draws).
    pub byzantine_frac: f64,
    /// The compromised clients' poisoning strategy.
    pub byzantine_mode: ByzantineMode,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            enabled: false,
            dropout_p: 0.1,
            recover_s: 5.0,
            diurnal_amp: 0.0,
            diurnal_period_s: 86_400.0,
            tiers: 1,
            tier_spread: 0.5,
            tier_compute_s: 0.05,
            byzantine_frac: 0.0,
            byzantine_mode: ByzantineMode::SignFlip,
        }
    }
}

/// One client's drawn destiny: its device-class tier, the three factors
/// that tier implies, and its current crash window.
#[derive(Clone, Copy, Debug)]
pub struct ClientFate {
    /// Device-class tier, 0 = best. All other fields are pure functions
    /// of this index — the correlation is by construction.
    pub tier: usize,
    /// Bandwidth multiplier applied to both link directions (1.0 for the
    /// best tier, down to `1/(1 + 3·spread)` for the worst).
    pub bw_mult: f64,
    /// Extra per-upload compute delay in virtual seconds.
    pub compute_s: f64,
    /// Dropout-probability multiplier (1.0 best, `1 + 2·spread` worst).
    pub rel_mult: f64,
    /// Virtual time until which this client is crashed (`-inf` = up).
    pub down_until: f64,
}

/// The fault layer a [`crate::coordinator::FedServer`] consults at
/// dispatch and submit time. Owns its RNG stream; an enabled layer draws
/// exactly once per dispatched broadcast (plus one tier draw per client
/// at construction when `tiers > 1`).
#[derive(Debug)]
pub struct FaultLayer {
    cfg: FaultsConfig,
    fates: Vec<ClientFate>,
    /// `None` only for [`FaultLayer::disabled`]; an enabled layer always
    /// carries its dedicated stream.
    rng: Option<Rng>,
    /// Recorded availability log; non-empty switches the loss model from
    /// parametric draws to deterministic replay (sorted by `down_at`,
    /// then client).
    trace: Vec<TraceWindow>,
    lost: u64,
    recovered: u64,
}

impl FaultLayer {
    /// The zero-draw identity layer (`[faults]` absent or off).
    pub fn disabled(n: usize) -> FaultLayer {
        FaultLayer {
            cfg: FaultsConfig { enabled: false, ..FaultsConfig::default() },
            fates: (0..n).map(|_| ClientFate::best()).collect(),
            rng: None,
            trace: Vec::new(),
            lost: 0,
            recovered: 0,
        }
    }

    /// Build the layer for `n` clients. `rng` must be the dedicated
    /// [`crate::util::rng::stream::FAULTS`] split of the experiment root.
    /// Tier assignment draws once per client, in client order, only when
    /// the layer is enabled with more than one tier.
    pub fn new(cfg: &FaultsConfig, n: usize, mut rng: Rng) -> FaultLayer {
        let tiers = cfg.tiers.max(1);
        let fates = (0..n)
            .map(|_| {
                let tier = if cfg.enabled && tiers > 1 { rng.below(tiers) } else { 0 };
                // One scalar position u ∈ [0, 1] per tier; every factor
                // is a pure function of u so the three degradations are
                // perfectly correlated.
                let u = if tiers > 1 { tier as f64 / (tiers - 1) as f64 } else { 0.0 };
                ClientFate {
                    tier,
                    bw_mult: 1.0 / (1.0 + 3.0 * cfg.tier_spread * u),
                    compute_s: cfg.tier_compute_s * cfg.tier_spread * u,
                    rel_mult: 1.0 + 2.0 * cfg.tier_spread * u,
                    down_until: f64::NEG_INFINITY,
                }
            })
            .collect();
        FaultLayer { cfg: *cfg, fates, rng: Some(rng), trace: Vec::new(), lost: 0, recovered: 0 }
    }

    /// Install a recorded availability log: the parametric dropout model
    /// is replaced by a deterministic, draw-free replay of `windows`.
    pub fn set_trace(&mut self, mut windows: Vec<TraceWindow>) {
        windows.sort_by(|a, b| {
            a.down_at.total_cmp(&b.down_at).then(a.client.cmp(&b.client))
        });
        self.trace = windows;
    }

    /// Is the layer replaying a trace instead of drawing losses?
    pub fn trace_active(&self) -> bool {
        self.cfg.enabled && !self.trace.is_empty()
    }

    /// The logged outage that kills an upload in flight over
    /// `(sent_at, recv_at]` for client `c`, if any: the earliest window
    /// overlapping the transfer. Returns the window's end (the client's
    /// logged recovery time).
    pub fn trace_loss(&self, c: usize, sent_at: f64, recv_at: f64) -> Option<f64> {
        if !self.trace_active() {
            return None;
        }
        self.trace
            .iter()
            .find(|w| w.client == c && w.down_at <= recv_at && w.up_at > sent_at)
            .map(|w| w.up_at)
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn fate(&self, c: usize) -> &ClientFate {
        &self.fates[c]
    }

    pub fn fates(&self) -> &[ClientFate] {
        &self.fates
    }

    /// Uploads lost to a dropout so far.
    pub fn lost(&self) -> u64 {
        self.lost
    }

    /// Crash windows that have ended (recovery events fired).
    pub fn recovered(&self) -> u64 {
        self.recovered
    }

    /// Scale per-client links by each client's tier bandwidth multiplier.
    /// Best-tier (and disabled-layer) multipliers are exactly 1.0, which
    /// is a bitwise no-op on finite rates.
    pub fn scale_links(&self, links: &mut [ClientLink]) {
        for (link, fate) in links.iter_mut().zip(&self.fates) {
            link.up_bps *= fate.bw_mult;
            link.down_bps *= fate.bw_mult;
        }
    }

    /// Diurnal availability wave at virtual time `now`: a triangle in
    /// `[1 − amp, 1 + amp]` with the outage peak at mid-period (sessions
    /// start in the calm trough at t = 0).
    pub fn wave(&self, now: f64) -> f64 {
        if self.cfg.diurnal_amp <= 0.0 {
            return 1.0;
        }
        let pos = (now / self.cfg.diurnal_period_s).rem_euclid(1.0);
        let tri = 1.0 - 4.0 * (pos - 0.5).abs();
        1.0 + self.cfg.diurnal_amp * tri
    }

    /// Effective upload-loss probability for client `c` at time `now`:
    /// base rate × tier reliability × diurnal wave, clamped to [0, 1].
    pub fn loss_probability(&self, c: usize, now: f64) -> f64 {
        (self.cfg.dropout_p * self.fates[c].rel_mult * self.wave(now)).clamp(0.0, 1.0)
    }

    /// One Bernoulli draw for a broadcast dispatched to `c` at `now`.
    /// An enabled layer *always* consumes exactly one draw here — even
    /// at probability 0 — so the stream position depends only on the
    /// dispatch sequence, never on tier or wave values. Disabled layers
    /// draw nothing, and a trace replay draws nothing either (losses are
    /// decided deterministically from the log at submit time).
    pub fn draw_loss(&mut self, c: usize, now: f64) -> bool {
        if !self.cfg.enabled || !self.trace.is_empty() {
            return false;
        }
        let p = self.loss_probability(c, now);
        let u = self.rng.as_mut().expect("enabled fault layer carries its stream").f64();
        u < p
    }

    /// Extra per-upload compute delay of client `c`'s device tier.
    pub fn compute_delay(&self, c: usize) -> f64 {
        self.fates[c].compute_s
    }

    /// Virtual seconds a crashed client stays down.
    pub fn recover_s(&self) -> f64 {
        self.cfg.recover_s
    }

    /// Is client `c` inside a crash window at `now`? Under a trace
    /// replay the logged outage windows count too, so cohort selection
    /// skips clients the log says are offline.
    pub fn is_down(&self, c: usize, now: f64) -> bool {
        if self.fates[c].down_until > now {
            return true;
        }
        self.trace_active()
            && self.trace.iter().any(|w| w.client == c && w.down_at <= now && now < w.up_at)
    }

    /// Open a crash window for `c` until virtual time `until`.
    pub fn mark_down(&mut self, c: usize, until: f64) {
        self.fates[c].down_until = until;
        self.lost += 1;
    }

    /// Close `c`'s crash window (its recovery timer fired).
    pub fn mark_up(&mut self, c: usize) {
        self.fates[c].down_until = f64::NEG_INFINITY;
        self.recovered += 1;
    }

    /// Scenario-harness lever: override the base dropout probability
    /// mid-session (e.g. "the outage ends").
    pub fn set_dropout_p(&mut self, p: f64) {
        self.cfg.dropout_p = p.clamp(0.0, 1.0);
    }

    /// Scenario-harness lever: pin one client's reliability multiplier
    /// (0 makes it immortal, large values make it the designated victim).
    pub fn set_reliability(&mut self, c: usize, mult: f64) {
        self.fates[c].rel_mult = mult;
    }

    /// Number of compromised clients: `round(byzantine_frac · n)`, 0
    /// when the layer is disabled.
    pub fn byzantine_count(&self) -> usize {
        if !self.cfg.enabled {
            return 0;
        }
        ((self.cfg.byzantine_frac * self.fates.len() as f64).round() as usize)
            .min(self.fates.len())
    }

    /// Is client `c` compromised? The attackers are the **last**
    /// `byzantine_count()` client indices — deterministic, draw-free,
    /// and disjoint by construction from the low-index clients most
    /// scenario assertions pin.
    pub fn is_byzantine(&self, c: usize) -> bool {
        let count = self.byzantine_count();
        count > 0 && c >= self.fates.len() - count
    }

    /// Poison client `c`'s decoded recon in place, per the configured
    /// [`ByzantineMode`]. No-op (and draw-free) for honest clients and
    /// disabled layers; only the gaussian mode draws — one normal per
    /// coordinate, on the dedicated stream, in submit order.
    pub fn corrupt(&mut self, c: usize, recon: &mut [f32]) {
        if !self.is_byzantine(c) {
            return;
        }
        match self.cfg.byzantine_mode {
            ByzantineMode::SignFlip => {
                for v in recon.iter_mut() {
                    *v = -*v;
                }
            }
            ByzantineMode::ScaleAmplify => {
                for v in recon.iter_mut() {
                    *v *= AMPLIFY;
                }
            }
            ByzantineMode::GaussianNoise => {
                let rng =
                    self.rng.as_mut().expect("enabled fault layer carries its stream");
                for v in recon.iter_mut() {
                    *v += rng.normal() as f32;
                }
            }
            ByzantineMode::Collude => {
                for v in recon.iter_mut() {
                    *v = COLLUDE_VALUE;
                }
            }
        }
    }
}

/// Parse an availability-log JSONL file: one object per line with
/// numeric `client`, `down_at`, `up_at` fields, e.g.
///
/// ```text
/// {"client": 3, "down_at": 0.8, "up_at": 2.5}
/// ```
///
/// Blank lines and `#` comment lines are skipped. Windows must be
/// finite, non-negative and well-ordered (`up_at > down_at`).
pub fn load_trace(path: &str) -> Result<Vec<TraceWindow>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading [faults] trace '{path}'"))?;
    parse_trace(&text).with_context(|| format!("parsing [faults] trace '{path}'"))
}

/// [`load_trace`] on in-memory text (the testable core).
pub fn parse_trace(text: &str) -> Result<Vec<TraceWindow>> {
    let mut windows = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let n = lineno + 1;
        let client = json_number(line, "client")
            .with_context(|| format!("line {n}: missing numeric \"client\""))?;
        let down_at = json_number(line, "down_at")
            .with_context(|| format!("line {n}: missing numeric \"down_at\""))?;
        let up_at = json_number(line, "up_at")
            .with_context(|| format!("line {n}: missing numeric \"up_at\""))?;
        if client < 0.0 || client.fract() != 0.0 {
            bail!("line {n}: \"client\" must be a non-negative integer, got {client}");
        }
        if !down_at.is_finite() || !up_at.is_finite() || down_at < 0.0 || up_at <= down_at {
            bail!("line {n}: need finite 0 <= down_at < up_at, got [{down_at}, {up_at})");
        }
        windows.push(TraceWindow { client: client as usize, down_at, up_at });
    }
    Ok(windows)
}

/// Extract `"key": <number>` from one JSON object line. A deliberately
/// minimal scanner — the trace schema is flat numeric fields, and the
/// container image bakes in no JSON dependency.
fn json_number(line: &str, key: &str) -> Result<f64> {
    let needle = format!("\"{key}\"");
    let at = line.find(&needle).with_context(|| format!("no \"{key}\" key"))?;
    let rest = &line[at + needle.len()..];
    let rest = rest.trim_start();
    let rest = rest.strip_prefix(':').with_context(|| format!("no ':' after \"{key}\""))?;
    let rest = rest.trim_start();
    let end = rest
        .find(|ch: char| !(ch.is_ascii_digit() || ch == '-' || ch == '+' || ch == '.'
            || ch == 'e' || ch == 'E'))
        .unwrap_or(rest.len());
    rest[..end]
        .parse::<f64>()
        .with_context(|| format!("bad number for \"{key}\": '{}'", &rest[..end]))
}

impl ClientFate {
    /// The best-tier fate: every factor the identity, no crash window.
    fn best() -> ClientFate {
        ClientFate {
            tier: 0,
            bw_mult: 1.0,
            compute_s: 0.0,
            rel_mult: 1.0,
            down_until: f64::NEG_INFINITY,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::NetworkModel;
    use crate::util::rng::{stream, Rng};

    fn cfg(enabled: bool) -> FaultsConfig {
        FaultsConfig { enabled, ..FaultsConfig::default() }
    }

    #[test]
    fn disabled_layer_is_a_bitwise_noop() {
        let mut layer = FaultLayer::disabled(3);
        let base = NetworkModel::edge();
        let mut links = base.client_links(3, 0.0, &mut Rng::new(1));
        layer.scale_links(&mut links);
        for link in &links {
            assert_eq!(link.up_bps.to_bits(), base.up_bps.to_bits());
            assert_eq!(link.down_bps.to_bits(), base.down_bps.to_bits());
        }
        for c in 0..3 {
            assert!(!layer.draw_loss(c, 0.0));
            assert!(!layer.is_down(c, 0.0));
            assert_eq!(layer.fate(c).tier, 0);
            assert_eq!(layer.compute_delay(c), 0.0);
        }
        assert!(!layer.enabled());
    }

    #[test]
    fn enabled_layer_with_identity_knobs_changes_nothing_but_draws() {
        // tiers = 1 and dropout_p = 0: the factors collapse to the exact
        // identity, but every dispatch still consumes one draw (stream
        // stability: turning the probability knob must never shift later
        // draws).
        let c = FaultsConfig { enabled: true, dropout_p: 0.0, ..cfg(true) };
        let mut layer = FaultLayer::new(&c, 4, Rng::new(9).split(stream::FAULTS));
        for i in 0..4 {
            let f = layer.fate(i);
            assert_eq!(f.bw_mult.to_bits(), 1.0f64.to_bits());
            assert_eq!(f.compute_s, 0.0);
            assert_eq!(f.rel_mult.to_bits(), 1.0f64.to_bits());
            assert!(!layer.draw_loss(i, 0.0));
        }
    }

    #[test]
    fn tier_factors_are_correlated_and_monotone() {
        let c = FaultsConfig {
            enabled: true,
            tiers: 4,
            tier_spread: 0.8,
            tier_compute_s: 0.1,
            ..cfg(true)
        };
        let layer = FaultLayer::new(&c, 64, Rng::new(7).split(stream::FAULTS));
        let again = FaultLayer::new(&c, 64, Rng::new(7).split(stream::FAULTS));
        let mut seen = [false; 4];
        for (f, g) in layer.fates().iter().zip(again.fates()) {
            assert_eq!(f.tier, g.tier, "tier assignment must replay from the seed");
            seen[f.tier] = true;
            // Worse tier ⇒ slower link AND slower compute AND flakier,
            // together: each factor is monotone in the tier index.
            let u = f.tier as f64 / 3.0;
            assert!((f.bw_mult - 1.0 / (1.0 + 3.0 * 0.8 * u)).abs() < 1e-15);
            assert!((f.compute_s - 0.1 * 0.8 * u).abs() < 1e-15);
            assert!((f.rel_mult - (1.0 + 2.0 * 0.8 * u)).abs() < 1e-15);
        }
        assert!(seen.iter().all(|&s| s), "64 draws should hit all 4 tiers");
    }

    #[test]
    fn triangle_wave_peaks_mid_period() {
        let c = FaultsConfig {
            enabled: true,
            diurnal_amp: 0.5,
            diurnal_period_s: 4.0,
            ..cfg(true)
        };
        let layer = FaultLayer::new(&c, 1, Rng::new(1).split(stream::FAULTS));
        assert!((layer.wave(0.0) - 0.5).abs() < 1e-15, "trough at t = 0");
        assert!((layer.wave(1.0) - 1.0).abs() < 1e-15);
        assert!((layer.wave(2.0) - 1.5).abs() < 1e-15, "peak at mid-period");
        assert!((layer.wave(3.0) - 1.0).abs() < 1e-15);
        assert!((layer.wave(4.0) - 0.5).abs() < 1e-15, "periodic");
        assert!((layer.wave(6.0) - 1.5).abs() < 1e-15);
    }

    #[test]
    fn loss_probability_clamps_and_certain_loss_always_fires() {
        let c = FaultsConfig { enabled: true, dropout_p: 1.0, ..cfg(true) };
        let mut layer = FaultLayer::new(&c, 2, Rng::new(3).split(stream::FAULTS));
        layer.set_reliability(0, 100.0);
        assert_eq!(layer.loss_probability(0, 0.0), 1.0, "clamped to 1");
        for _ in 0..20 {
            assert!(layer.draw_loss(0, 0.0), "p = 1 must always lose");
        }
        layer.set_reliability(1, 0.0);
        assert_eq!(layer.loss_probability(1, 0.0), 0.0);
        for _ in 0..20 {
            assert!(!layer.draw_loss(1, 0.0), "rel_mult = 0 never loses");
        }
    }

    #[test]
    fn byzantine_marking_is_the_tail_of_the_fleet() {
        let c = FaultsConfig { enabled: true, byzantine_frac: 0.3, ..cfg(true) };
        let layer = FaultLayer::new(&c, 10, Rng::new(1).split(stream::FAULTS));
        assert_eq!(layer.byzantine_count(), 3);
        for i in 0..7 {
            assert!(!layer.is_byzantine(i), "client {i} should be honest");
        }
        for i in 7..10 {
            assert!(layer.is_byzantine(i), "client {i} should be compromised");
        }
        // Disabled layer: nobody is byzantine regardless of the knob.
        let off = FaultLayer::disabled(10);
        assert_eq!(off.byzantine_count(), 0);
    }

    #[test]
    fn corrupt_applies_each_mode_and_only_gaussian_draws() {
        let base = vec![0.5f32, -0.25, 0.125];
        let mk = |mode| FaultsConfig {
            enabled: true,
            byzantine_frac: 1.0,
            byzantine_mode: mode,
            ..cfg(true)
        };
        let mut flip =
            FaultLayer::new(&mk(ByzantineMode::SignFlip), 1, Rng::new(2).split(stream::FAULTS));
        let mut v = base.clone();
        flip.corrupt(0, &mut v);
        assert_eq!(v, vec![-0.5, 0.25, -0.125]);

        let mut amp = FaultLayer::new(
            &mk(ByzantineMode::ScaleAmplify),
            1,
            Rng::new(2).split(stream::FAULTS),
        );
        let mut v = base.clone();
        amp.corrupt(0, &mut v);
        assert_eq!(v, vec![5.0, -2.5, 1.25]);

        let mut col =
            FaultLayer::new(&mk(ByzantineMode::Collude), 1, Rng::new(2).split(stream::FAULTS));
        let mut v = base.clone();
        col.corrupt(0, &mut v);
        assert!(v.iter().all(|&x| x == -0.1));

        // Draw-free modes leave the stream untouched: the next dropout
        // draw matches a fresh layer's first draw.
        let mut fresh =
            FaultLayer::new(&mk(ByzantineMode::SignFlip), 1, Rng::new(2).split(stream::FAULTS));
        flip.set_dropout_p(0.5);
        fresh.set_dropout_p(0.5);
        assert_eq!(flip.draw_loss(0, 0.0), fresh.draw_loss(0, 0.0));

        // Gaussian perturbs with finite noise and consumes draws.
        let mut gau = FaultLayer::new(
            &mk(ByzantineMode::GaussianNoise),
            1,
            Rng::new(2).split(stream::FAULTS),
        );
        let mut v = base.clone();
        gau.corrupt(0, &mut v);
        assert!(v.iter().all(|x| x.is_finite()));
        assert_ne!(v, base);

        // Honest clients are untouched in every mode.
        let c = FaultsConfig { enabled: true, byzantine_frac: 0.5, ..cfg(true) };
        let mut half = FaultLayer::new(&c, 4, Rng::new(2).split(stream::FAULTS));
        let mut v = base.clone();
        half.corrupt(0, &mut v);
        assert_eq!(v, base);
    }

    #[test]
    fn trace_replay_is_draw_free_and_kills_overlapping_transfers() {
        let mut layer = FaultLayer::new(&cfg(true), 2, Rng::new(4).split(stream::FAULTS));
        layer.set_trace(vec![
            TraceWindow { client: 0, down_at: 1.0, up_at: 2.0 },
            TraceWindow { client: 1, down_at: 5.0, up_at: 6.0 },
        ]);
        assert!(layer.trace_active());
        // Selection-time availability follows the log.
        assert!(!layer.is_down(0, 0.5));
        assert!(layer.is_down(0, 1.0));
        assert!(layer.is_down(0, 1.99));
        assert!(!layer.is_down(0, 2.0), "half-open: up exactly at up_at");
        // A transfer overlapping the window is lost, with logged recovery.
        assert_eq!(layer.trace_loss(0, 0.5, 1.5), Some(2.0));
        assert_eq!(layer.trace_loss(0, 0.5, 0.9), None);
        assert_eq!(layer.trace_loss(0, 2.0, 3.0), None);
        assert_eq!(layer.trace_loss(1, 0.5, 1.5), None, "other client's window");
        // No draws: dispatch-time losses never fire in replay mode.
        layer.set_dropout_p(1.0);
        assert!(!layer.draw_loss(0, 0.0));
    }

    #[test]
    fn trace_jsonl_parses_and_rejects_malformed_lines() {
        let text = "\
# fleet availability log
{\"client\": 0, \"down_at\": 1.0, \"up_at\": 2.5}

{\"client\": 3, \"down_at\": 0.25, \"up_at\": 0.75}
";
        let windows = parse_trace(text).unwrap();
        assert_eq!(windows.len(), 2);
        assert_eq!(windows[0], TraceWindow { client: 0, down_at: 1.0, up_at: 2.5 });
        assert_eq!(windows[1], TraceWindow { client: 3, down_at: 0.25, up_at: 0.75 });
        assert!(parse_trace("{\"client\": 0, \"down_at\": 2.0, \"up_at\": 1.0}").is_err());
        assert!(parse_trace("{\"client\": -1, \"down_at\": 0.0, \"up_at\": 1.0}").is_err());
        assert!(parse_trace("{\"down_at\": 0.0, \"up_at\": 1.0}").is_err());
    }

    #[test]
    fn crash_windows_open_and_close() {
        let mut layer = FaultLayer::new(&cfg(true), 2, Rng::new(5).split(stream::FAULTS));
        assert!(!layer.is_down(0, 0.0));
        layer.mark_down(0, 3.5);
        assert!(layer.is_down(0, 0.0));
        assert!(layer.is_down(0, 3.49));
        assert!(!layer.is_down(0, 3.5), "window is half-open: up exactly at its end");
        assert!(!layer.is_down(1, 0.0), "other clients unaffected");
        assert_eq!(layer.lost(), 1);
        layer.mark_up(0);
        assert!(!layer.is_down(0, 0.0));
        assert_eq!(layer.recovered(), 1);
    }
}
