//! Per-client batch sampling for the local-training fed-op.
//!
//! `local_train_K` consumes pre-batched tensors `xs: [K, B, ...]`,
//! `ys: [K, B]`. The sampler cycles through the client's local indices
//! with reshuffling on wrap-around (sampling without replacement per
//! epoch), matching the usual DataLoader semantics.

use crate::data::Dataset;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ClientSampler {
    indices: Vec<u32>,
    cursor: usize,
    rng: Rng,
}

impl ClientSampler {
    /// `indices` may be empty (a best-effort partition can leave a client
    /// without data); the round engine skips such clients, and actually
    /// *sampling* from an empty pool is a bug that panics loudly below.
    pub fn new(mut indices: Vec<u32>, mut rng: Rng) -> Self {
        rng.shuffle(&mut indices);
        ClientSampler { indices, cursor: 0, rng }
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    fn next_index(&mut self) -> u32 {
        assert!(
            !self.indices.is_empty(),
            "sampling from a client with no data (zero-sample clients must be skipped)"
        );
        if self.cursor >= self.indices.len() {
            self.rng.shuffle(&mut self.indices);
            self.cursor = 0;
        }
        let i = self.indices[self.cursor];
        self.cursor += 1;
        i
    }

    /// Fill `k` batches of `b` samples: returns (xs, ys) flat buffers of
    /// shapes [k*b*d] and [k*b].
    pub fn sample_batches(
        &mut self,
        ds: &Dataset,
        k: usize,
        b: usize,
    ) -> (Vec<f32>, Vec<i32>) {
        let d = ds.d;
        let mut xs = vec![0.0f32; k * b * d];
        let mut ys = vec![0i32; k * b];
        for s in 0..k * b {
            let idx = self.next_index() as usize;
            xs[s * d..(s + 1) * d].copy_from_slice(ds.sample(idx));
            ys[s] = ds.label(idx);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;

    #[test]
    fn batches_have_right_shape_and_content() {
        let ds = Dataset::generate(DatasetKind::SynthSmall, 30, 1);
        let mut s = ClientSampler::new((0..30).collect(), Rng::new(2));
        let (xs, ys) = s.sample_batches(&ds, 3, 8);
        assert_eq!(xs.len(), 3 * 8 * ds.d);
        assert_eq!(ys.len(), 24);
        assert!(ys.iter().all(|&y| (y as usize) < ds.n_classes));
    }

    #[test]
    fn epoch_without_replacement() {
        let ds = Dataset::generate(DatasetKind::SynthSmall, 16, 1);
        let mut s = ClientSampler::new((0..16).collect(), Rng::new(3));
        let (_, ys) = s.sample_batches(&ds, 1, 16);
        let mut seen: Vec<i32> = ys.clone();
        seen.sort_unstable();
        let mut expect: Vec<i32> = (0..16).map(|i| ds.label(i)).collect();
        expect.sort_unstable();
        assert_eq!(seen, expect, "one epoch must visit each sample once");
    }

    #[test]
    fn tiny_client_wraps_around() {
        let ds = Dataset::generate(DatasetKind::SynthSmall, 4, 1);
        let mut s = ClientSampler::new(vec![0, 1, 2, 3], Rng::new(4));
        let (xs, ys) = s.sample_batches(&ds, 2, 16); // 32 draws from 4 samples
        assert_eq!(xs.len(), 2 * 16 * ds.d);
        assert_eq!(ys.len(), 32);
    }
}
