//! Data substrate: procedural datasets, non-i.i.d. partitioning, batching.
//!
//! The paper trains on MNIST/EMNIST/FMNIST/Cifar10/Cifar100. This
//! environment has no network access, so we synthesize procedural datasets
//! with the same tensor shapes and class counts (DESIGN.md §3): each class
//! has a smooth random template; samples are jittered/shifted/noised draws
//! around it. The tasks are genuinely learnable but not trivial, and they
//! partition non-i.i.d. exactly like the paper's Fig 5 (Dirichlet).

pub mod batcher;
pub mod generator;
pub mod partition;

pub use batcher::ClientSampler;
pub use generator::Dataset;
pub use partition::dirichlet_partition;
