//! Procedural dataset synthesis (MNIST/…/Cifar100 stand-ins).
//!
//! Per class `c`: a smooth template `T_c` — a sum of low-frequency 2-D
//! sinusoids drawn from a class-seeded PRNG stream. A sample is
//! `clip(scale · shift(T_c) + noise)` recentred to zero mean, with
//! per-dataset texture statistics (FMNIST gets higher-frequency texture,
//! the cifar-like sets get 3 correlated channels). Deterministic in
//! `(kind, seed)` so every experiment replays exactly.

use crate::config::DatasetKind;
use crate::util::rng::{stream, Rng};

/// An in-memory labelled dataset with row-major flat features.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub n: usize,
    pub d: usize,
    pub n_classes: usize,
    pub features: Vec<f32>, // n * d
    pub labels: Vec<i32>,   // n
}

struct Texture {
    n_waves: usize,
    max_freq: f64,
    noise: f32,
    max_shift: i64,
}

fn texture(kind: DatasetKind) -> Texture {
    match kind {
        DatasetKind::SynthMnist | DatasetKind::SynthEmnist => Texture {
            n_waves: 5,
            max_freq: 3.0,
            noise: 0.15,
            max_shift: 3,
        },
        DatasetKind::SynthFmnist => Texture {
            n_waves: 8,
            max_freq: 6.0,
            noise: 0.25,
            max_shift: 2,
        },
        DatasetKind::SynthCifar10 | DatasetKind::SynthCifar100 => Texture {
            n_waves: 6,
            max_freq: 4.0,
            noise: 0.20,
            max_shift: 2,
        },
        DatasetKind::SynthSmall => Texture {
            n_waves: 4,
            max_freq: 4.0,
            noise: 0.20,
            max_shift: 1,
        },
    }
}

/// One smooth (h, w) field from the given stream.
fn smooth_field(rng: &mut Rng, h: usize, w: usize, n_waves: usize, max_freq: f64) -> Vec<f32> {
    let mut field = vec![0.0f32; h * w];
    for _ in 0..n_waves {
        let fu = rng.range_f64(0.5, max_freq);
        let fv = rng.range_f64(0.5, max_freq);
        let phase = rng.range_f64(0.0, std::f64::consts::TAU);
        let amp = rng.range_f64(0.3, 1.0);
        for y in 0..h {
            for x in 0..w {
                let arg = std::f64::consts::TAU
                    * (fu * y as f64 / h as f64 + fv * x as f64 / w.max(1) as f64)
                    + phase;
                field[y * w + x] += (amp * arg.sin()) as f32;
            }
        }
    }
    // Normalize to zero mean, unit-ish scale.
    let mean = field.iter().sum::<f32>() / field.len() as f32;
    let mut var = 0.0f32;
    for v in field.iter_mut() {
        *v -= mean;
        var += *v * *v;
    }
    let std = (var / field.len() as f32).sqrt().max(1e-6);
    for v in field.iter_mut() {
        *v /= std * 2.0; // templates live roughly in [-1, 1]
    }
    field
}

/// Class template: (h, w, c) flattened row-major as h*w*c (NHWC order).
fn class_template(kind: DatasetKind, class: usize, seed: u64) -> Vec<f32> {
    let (h, w, c) = kind.image_dims();
    let tex = texture(kind);
    let mut out = vec![0.0f32; h * w * c];
    // Channels share a base field (class identity) plus per-channel detail,
    // mimicking the channel correlation of natural images.
    // detlint: allow(DET003) -- seed plumbing: derives the class-template
    // root from the dataset seed (xor keeps it distinct from sample draws).
    let mut rng_base = Rng::new(seed ^ 0x5EED_BA5E).split(class as u64);
    let base = smooth_field(&mut rng_base, h, w, tex.n_waves, tex.max_freq);
    for ch in 0..c {
        let mut rng_ch = rng_base.split(1000 + ch as u64);
        let detail = smooth_field(&mut rng_ch, h, w, tex.n_waves / 2 + 1, tex.max_freq);
        for y in 0..h {
            for x in 0..w {
                out[(y * w + x) * c + ch] = 0.8 * base[y * w + x] + 0.4 * detail[y * w + x];
            }
        }
    }
    out
}

fn roll2d(src: &[f32], h: usize, w: usize, c: usize, dy: i64, dx: i64, dst: &mut [f32]) {
    for y in 0..h {
        let sy = (y as i64 - dy).rem_euclid(h as i64) as usize;
        for x in 0..w {
            let sx = (x as i64 - dx).rem_euclid(w as i64) as usize;
            for ch in 0..c {
                dst[(y * w + x) * c + ch] = src[(sy * w + sx) * c + ch];
            }
        }
    }
}

impl Dataset {
    /// Synthesize `n` samples with uniformly random labels.
    ///
    /// `seed` fixes the *task* (class templates) AND the sample stream.
    /// For train/test splits of the same task use [`Dataset::generate_split`].
    pub fn generate(kind: DatasetKind, n: usize, seed: u64) -> Dataset {
        Self::generate_split(kind, n, seed, 0)
    }

    /// Synthesize `n` samples for split `split` (0 = train, 1 = test, ...)
    /// of the task identified by `seed`: all splits share class templates
    /// but draw disjoint sample streams.
    pub fn generate_split(kind: DatasetKind, n: usize, seed: u64, split: u64) -> Dataset {
        let d = kind.feature_len();
        let n_classes = kind.n_classes();
        let (h, w, c) = kind.image_dims();
        let tex = texture(kind);
        let templates: Vec<Vec<f32>> = (0..n_classes)
            .map(|cl| class_template(kind, cl, seed))
            .collect();

        // detlint: allow(DET003) -- seed plumbing: dataset synthesis roots
        // at the experiment seed, one stream per train/test split.
        let mut rng = Rng::new(seed).split(stream::DATA_SPLIT ^ (split.wrapping_mul(0x9E37_79B9)));
        let mut features = vec![0.0f32; n * d];
        let mut labels = Vec::with_capacity(n);
        let mut shifted = vec![0.0f32; d];
        for i in 0..n {
            let class = rng.below(n_classes);
            labels.push(class as i32);
            let dy = rng.below((2 * tex.max_shift + 1) as usize) as i64 - tex.max_shift;
            let dx = rng.below((2 * tex.max_shift + 1) as usize) as i64 - tex.max_shift;
            roll2d(&templates[class], h, w, c, dy, dx, &mut shifted);
            let scale = rng.range_f64(0.8, 1.2) as f32;
            let row = &mut features[i * d..(i + 1) * d];
            for (o, s) in row.iter_mut().zip(shifted.iter()) {
                let v = scale * s + tex.noise * rng.normal_f32();
                *o = v.clamp(-2.0, 2.0);
            }
        }
        Dataset { kind, n, d, n_classes, features, labels }
    }

    #[inline]
    pub fn sample(&self, i: usize) -> &[f32] {
        &self.features[i * self.d..(i + 1) * self.d]
    }

    pub fn label(&self, i: usize) -> i32 {
        self.labels[i]
    }

    /// Per-class sample counts.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.n_classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::vecmath;

    #[test]
    fn deterministic_in_seed() {
        let a = Dataset::generate(DatasetKind::SynthSmall, 50, 7);
        let b = Dataset::generate(DatasetKind::SynthSmall, 50, 7);
        assert_eq!(a.features, b.features);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::generate(DatasetKind::SynthSmall, 50, 8);
        assert_ne!(a.features, c.features);
    }

    #[test]
    fn shapes_and_labels_valid() {
        for kind in [
            DatasetKind::SynthMnist,
            DatasetKind::SynthEmnist,
            DatasetKind::SynthFmnist,
            DatasetKind::SynthCifar10,
            DatasetKind::SynthCifar100,
            DatasetKind::SynthSmall,
        ] {
            let ds = Dataset::generate(kind, 40, 1);
            assert_eq!(ds.features.len(), 40 * kind.feature_len());
            assert!(ds
                .labels
                .iter()
                .all(|&l| (l as usize) < kind.n_classes()));
            // All classes should appear eventually with enough samples.
            let big = Dataset::generate(kind, kind.n_classes() * 40, 1);
            assert!(big.class_histogram().iter().all(|&c| c > 0), "{kind:?}");
        }
    }

    #[test]
    fn classes_are_separated() {
        // Same-class samples should be far more similar than cross-class:
        // the signal a classifier (and the 3SFC encoder) actually learns.
        let ds = Dataset::generate(DatasetKind::SynthMnist, 400, 3);
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..80 {
            for j in (i + 1)..80 {
                let cosv = vecmath::cosine(ds.sample(i), ds.sample(j));
                if ds.label(i) == ds.label(j) {
                    same.push(cosv);
                } else {
                    diff.push(cosv);
                }
            }
        }
        let ms = same.iter().sum::<f64>() / same.len() as f64;
        let md = diff.iter().sum::<f64>() / diff.len() as f64;
        // Shift/noise jitter deliberately weakens raw-pixel similarity
        // (that's what makes the task non-trivial); the margin just has to
        // be clearly positive.
        assert!(ms > md + 0.1, "same {ms:.3} diff {md:.3}");
    }

    #[test]
    fn features_bounded() {
        let ds = Dataset::generate(DatasetKind::SynthCifar10, 64, 2);
        assert!(ds.features.iter().all(|v| v.abs() <= 2.0));
        let mean = ds.features.iter().sum::<f32>() / ds.features.len() as f32;
        assert!(mean.abs() < 0.25, "mean {mean}");
    }
}
