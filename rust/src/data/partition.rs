//! Non-i.i.d. Dirichlet partitioning (paper Fig 5).
//!
//! For every class, the class's samples are split across clients with
//! proportions drawn from `Dir(α)` — the standard FL heterogeneity model
//! (Wang et al. 2020, Li et al. 2022 as cited by the paper). Small α ⇒
//! clients see few classes with very uneven counts.

use crate::data::Dataset;
use crate::util::rng::Rng;

/// Partition sample indices of `ds` across `n_clients`, Dirichlet(α) per
/// class. Every client is guaranteed at least one sample whenever
/// `ds.n >= n_clients` (always true for experiment configs, which
/// validate `train_samples >= n_clients`); with fewer samples than
/// clients the split is best-effort and some shards stay empty — the
/// round engine skips zero-sample clients rather than panicking.
pub fn dirichlet_partition(
    ds: &Dataset,
    n_clients: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<u32>> {
    assert!(n_clients > 0);
    let mut per_class: Vec<Vec<u32>> = vec![Vec::new(); ds.n_classes];
    for (i, &l) in ds.labels.iter().enumerate() {
        per_class[l as usize].push(i as u32);
    }
    let mut clients: Vec<Vec<u32>> = vec![Vec::new(); n_clients];
    for idxs in per_class.iter_mut() {
        rng.shuffle(idxs);
        let props = rng.dirichlet(alpha, n_clients);
        // Largest-remainder apportionment of idxs.len() by props.
        let n = idxs.len();
        let mut counts: Vec<usize> = props.iter().map(|p| (p * n as f64) as usize).collect();
        let mut assigned: usize = counts.iter().sum();
        let mut order: Vec<usize> = (0..n_clients).collect();
        order.sort_by(|&a, &b| {
            let ra = props[a] * n as f64 - counts[a] as f64;
            let rb = props[b] * n as f64 - counts[b] as f64;
            rb.total_cmp(&ra)
        });
        let mut oi = 0;
        while assigned < n {
            counts[order[oi % n_clients]] += 1;
            assigned += 1;
            oi += 1;
        }
        let mut off = 0;
        for (c, &cnt) in counts.iter().enumerate() {
            clients[c].extend_from_slice(&idxs[off..off + cnt]);
            off += cnt;
        }
    }
    // No client may be empty: move one sample from the largest shard.
    // A donor must keep at least one sample itself — the old
    // steal-from-anyone rescue could empty a 1-sample donor that was
    // already checked, reintroducing the empty shard it was fixing. When
    // ds.n >= n_clients a >=2-sample donor always exists while any shard
    // is empty (pigeonhole), so the guarantee holds; otherwise this is
    // best-effort and the leftover shards stay empty.
    for c in 0..n_clients {
        if clients[c].is_empty() {
            let donor = (0..n_clients)
                .filter(|&i| clients[i].len() >= 2)
                .max_by_key(|&i| clients[i].len());
            if let Some(d) = donor {
                let x = clients[d].pop().expect("donor has >= 2 samples");
                clients[c].push(x);
            }
        }
    }
    clients
}

/// Render the Fig-5-style partition histogram as ASCII (one bar per client,
/// segments per class), used by `fed3sfc partition-viz`.
pub fn render_partition(ds: &Dataset, parts: &[Vec<u32>]) -> String {
    let glyphs: Vec<char> = "0123456789abcdefghijklmnopqrstuvwxyz".chars().collect();
    let max_len = parts.iter().map(|p| p.len()).max().unwrap_or(1).max(1);
    let width = 72usize;
    let mut out = String::new();
    out.push_str("client | samples per class (each glyph = one class segment)\n");
    for (c, idxs) in parts.iter().enumerate() {
        let mut hist = vec![0usize; ds.n_classes];
        for &i in idxs {
            hist[ds.labels[i as usize] as usize] += 1;
        }
        let mut bar = String::new();
        for (cls, &cnt) in hist.iter().enumerate() {
            let w = (cnt * width + max_len / 2) / max_len;
            for _ in 0..w {
                bar.push(glyphs[cls % glyphs.len()]);
            }
        }
        out.push_str(&format!("{c:6} | {bar}  ({} samples)\n", idxs.len()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetKind;

    fn setup(n: usize, clients: usize, alpha: f64) -> (Dataset, Vec<Vec<u32>>) {
        let ds = Dataset::generate(DatasetKind::SynthSmall, n, 11);
        let mut rng = Rng::new(5).split(99);
        let parts = dirichlet_partition(&ds, clients, alpha, &mut rng);
        (ds, parts)
    }

    #[test]
    fn covers_all_samples_exactly_once() {
        let (ds, parts) = setup(500, 13, 0.5);
        let mut seen = vec![false; ds.n];
        for p in &parts {
            for &i in p {
                assert!(!seen[i as usize], "duplicate index {i}");
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn no_empty_clients() {
        let (_, parts) = setup(60, 20, 0.1);
        assert!(parts.iter().all(|p| !p.is_empty()));
    }

    #[test]
    fn extreme_alpha_dense_cohort_has_no_empty_shards() {
        // Regression (ISSUE 2): alpha = 0.01 concentrates whole classes on
        // single clients, and with n_clients = train_samples / 2 the
        // rescue pass used to be able to empty a 1-sample donor. Every
        // client must still end up with >= 1 sample.
        for seed in [5u64, 6, 7, 8] {
            let ds = Dataset::generate(DatasetKind::SynthSmall, 64, seed);
            let mut rng = Rng::new(seed).split(99);
            let parts = dirichlet_partition(&ds, 32, 0.01, &mut rng);
            assert!(
                parts.iter().all(|p| !p.is_empty()),
                "seed {seed}: empty shard at alpha=0.01"
            );
            assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 64);
        }
    }

    #[test]
    fn more_clients_than_samples_is_best_effort_not_a_panic() {
        // Direct callers (partition-viz) are not covered by config
        // validation; the split must stay an exact cover without panicking
        // even when some shards must be empty.
        let ds = Dataset::generate(DatasetKind::SynthSmall, 20, 11);
        let mut rng = Rng::new(5).split(99);
        let parts = dirichlet_partition(&ds, 50, 0.01, &mut rng);
        assert_eq!(parts.len(), 50);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 20);
        let mut seen = vec![false; 20];
        for p in &parts {
            for &i in p {
                assert!(!seen[i as usize]);
                seen[i as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn small_alpha_is_more_skewed() {
        // Heterogeneity measure: mean per-client entropy of class mix.
        fn mean_entropy(ds: &Dataset, parts: &[Vec<u32>]) -> f64 {
            let mut tot = 0.0;
            for p in parts {
                let mut h = vec![0f64; ds.n_classes];
                for &i in p {
                    h[ds.labels[i as usize] as usize] += 1.0;
                }
                let n: f64 = h.iter().sum();
                let mut e = 0.0;
                for v in h {
                    if v > 0.0 {
                        let q = v / n;
                        e -= q * q.ln();
                    }
                }
                tot += e;
            }
            tot / parts.len() as f64
        }
        let (ds1, p1) = setup(2000, 10, 0.1);
        let (ds2, p2) = setup(2000, 10, 100.0);
        assert!(
            mean_entropy(&ds1, &p1) + 0.3 < mean_entropy(&ds2, &p2),
            "alpha=0.1 should be more skewed than alpha=100"
        );
    }

    #[test]
    fn render_has_one_row_per_client() {
        let (ds, parts) = setup(200, 6, 0.5);
        let viz = render_partition(&ds, &parts);
        assert_eq!(viz.lines().count(), 7); // header + 6 clients
    }
}
