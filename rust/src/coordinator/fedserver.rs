//! The event-driven federation server: consumes [`ClientMsg`] envelopes
//! off a [`SimClock`] and turns them into global steps through a
//! pluggable [`AggregationPolicy`].
//!
//! `FedServer` is deliberately compute-free — it never trains or encodes
//! anything. It decides *who* gets the model and *when* arrivals become
//! an aggregation, and hands the actual client work back to its driver
//! as [`Directive`]s:
//!
//! * [`Directive::Dispatch`] — a batch of [`Broadcast`] envelopes whose
//!   clients the driver must train-and-compress (the driver may fan the
//!   batch out over a worker pool), answering each with
//!   [`FedServer::submit_upload`];
//! * [`Directive::Step`] — one aggregation was applied to the global
//!   model; the [`StepSummary`] carries everything a `RoundRecord`
//!   needs.
//!
//! Determinism: the virtual clock is the only time source. Delivery
//! times are pure functions of payload bytes and the per-client
//! [`ClientLink`]s, simultaneous arrivals are tie-broken by client
//! index, and a cycle's deadline timer sorts after same-instant uploads
//! — so `Deadline` and `BufferedAsync` sessions replay bit-for-bit from
//! the experiment seed, and `Synchronous` sessions reproduce the classic
//! blocking round loop exactly (aggregation in ascending-client order,
//! staleness multiplier exactly 1).
//!
//! Scale: arrived uploads buffer in an [`EdgeAggregator`] — per-shard
//! queues (`client % n_shards`) whose drain merges back to exact global
//! arrival order, so `[scale] n_shards = K` is bit-identical to the
//! single-queue path for every `K` (see `coordinator::shard`). The
//! server itself holds `O(pending)` uploads plus one exact partial-sum
//! per live shard, never anything proportional to `n_clients` beyond
//! the per-client link/flag vectors.

use std::collections::VecDeque;

use anyhow::{bail, ensure, Result};

use crate::compress::DownlinkTx;
use crate::coordinator::policy::{AggTrigger, AggregationPolicy, PolicyCtx};
use crate::coordinator::protocol::{
    Ack, Broadcast, ClientMsg, ServerMsg, Upload, UploadError,
};
use crate::coordinator::robust::{RobustAggregator, WeightedMean};
use crate::coordinator::schedule::ClientScheduler;
use crate::coordinator::shard::EdgeAggregator;
use crate::coordinator::{Server, Traffic};
use crate::simnet::{ClientLink, FaultLayer, SimClock, SimEvent};

/// What travels on the virtual clock.
enum SessionEvent {
    /// An upload in transit; fires when it lands at the server.
    Upload(Upload),
    /// The semi-sync aggregation timer for one broadcast cycle.
    Deadline { cycle: u64 },
    /// A crashed client's recovery timer (fault layer).
    Recover { client: usize },
}

/// What the driver must do next.
pub enum Directive {
    /// Train-and-compress these clients (all broadcasts in a batch share
    /// one model version) and [`FedServer::submit_upload`] each result.
    Dispatch(Vec<Broadcast>),
    /// One aggregation step was applied to the global model.
    Step(StepSummary),
}

/// Observables of one aggregation step.
#[derive(Clone, Debug)]
pub struct StepSummary {
    /// Server round counter after the step.
    pub round: usize,
    /// Clients whose uploads were aggregated, in aggregation order.
    pub clients: Vec<usize>,
    /// Wire bytes of the aggregated uploads.
    pub up_bytes_step: u64,
    /// Wire bytes of the broadcasts dispatched since the previous step
    /// (the downlink side of this aggregation interval).
    pub down_bytes_step: u64,
    /// Mean client-side compression efficiency cos(ĝ, g+e).
    pub efficiency: f64,
    /// Mean compression ratio (× vs dense).
    pub ratio: f64,
    /// Mean staleness (model versions) of the aggregated updates.
    pub stale_mean: f64,
    /// Uploads the robust aggregator excluded from this step (Krum
    /// rejections; 0 for estimators that reweight rather than reject).
    pub rejected_clients: usize,
    /// Fraction of the batch's influence the aggregator trimmed, clipped
    /// or rejected (estimator-specific; 0 for the plain weighted mean).
    pub trim_frac: f64,
    /// Virtual time consumed by this step (since the previous step).
    pub comm_time_s: f64,
    /// Virtual-clock time at which the step completed.
    pub sim_time_s: f64,
}

/// The message-passing federation server.
pub struct FedServer {
    /// Global model + server optimizer (public for drivers and tests).
    pub server: Server,
    /// Exact wire accounting (uploads charged at arrival, broadcasts at
    /// dispatch).
    pub traffic: Traffic,
    scheduler: Box<dyn ClientScheduler>,
    policy: Box<dyn AggregationPolicy>,
    /// Byzantine-robust aggregation rule applied to each step's decoded
    /// batch before the server-optimizer step. The default
    /// [`WeightedMean`] reproduces `Server::apply_round` bit-for-bit.
    robust: Box<dyn RobustAggregator>,
    clock: SimClock<SessionEvent>,
    links: Vec<ClientLink>,
    /// Clients with data; zero-sample clients are never dispatched.
    active: Vec<bool>,
    /// Clients with a broadcast in flight (dispatched, upload not yet
    /// arrived).
    busy: Vec<bool>,
    /// Clients whose upload has been submitted and is in transit
    /// (guards against duplicate submissions).
    uploading: Vec<bool>,
    in_flight: usize,
    /// Arrived uploads awaiting aggregation, buffered per shard with
    /// global arrival stamps (drains in exact arrival order).
    edge: EdgeAggregator,
    outbox: VecDeque<Directive>,
    /// A broadcast cycle is in progress (async sessions leave their
    /// first cycle open forever).
    cycle_open: bool,
    cycle_id: u64,
    /// Size of the current cycle's dispatch cohort.
    cohort: usize,
    last_step_at: f64,
    /// `traffic.downlink_bytes` at the previous step (prices each step's
    /// `down_bytes_step`).
    down_at_last_step: u64,
    n_clients: usize,
    /// Model parameter count — the only recon length `submit_upload`
    /// accepts.
    n_params: usize,
    /// The adversarial-reality layer consulted at dispatch (loss draws,
    /// crash windows) and submit (compute delay, loss resolution) time.
    faults: FaultLayer,
    /// Clients whose outstanding upload the fault layer declared lost at
    /// dispatch time; resolved (dropped, never scheduled) at submit.
    doomed: Vec<bool>,
    /// Round of each client's outstanding broadcast (envelope validation).
    outstanding_round: Vec<usize>,
    /// Dispatch time of each client's outstanding broadcast — the
    /// earliest legal `Upload::sent_at`.
    outstanding_sent_at: Vec<f64>,
}

impl FedServer {
    pub fn new(
        server: Server,
        scheduler: Box<dyn ClientScheduler>,
        policy: Box<dyn AggregationPolicy>,
        links: Vec<ClientLink>,
        active: Vec<bool>,
        n_params: usize,
    ) -> FedServer {
        let n = links.len();
        FedServer::with_faults(
            server,
            scheduler,
            policy,
            links,
            active,
            n_params,
            FaultLayer::disabled(n),
        )
    }

    /// Like [`FedServer::new`] with an explicit fault layer. A
    /// [`FaultLayer::disabled`] layer is a bitwise no-op — identical
    /// trajectories to a server built before faults existed.
    pub fn with_faults(
        server: Server,
        scheduler: Box<dyn ClientScheduler>,
        policy: Box<dyn AggregationPolicy>,
        links: Vec<ClientLink>,
        active: Vec<bool>,
        n_params: usize,
        faults: FaultLayer,
    ) -> FedServer {
        assert_eq!(links.len(), active.len(), "one link and one data mask per client");
        assert_eq!(server.w.len(), n_params, "model size mismatch");
        assert_eq!(faults.fates().len(), links.len(), "one fate per client");
        let n_clients = links.len();
        FedServer {
            server,
            traffic: Traffic::default(),
            scheduler,
            policy,
            robust: Box::new(WeightedMean),
            clock: SimClock::new(),
            links,
            active,
            busy: vec![false; n_clients],
            uploading: vec![false; n_clients],
            in_flight: 0,
            edge: EdgeAggregator::new(1),
            outbox: VecDeque::new(),
            cycle_open: false,
            cycle_id: 0,
            cohort: 0,
            last_step_at: 0.0,
            down_at_last_step: 0,
            n_clients,
            n_params,
            faults,
            doomed: vec![false; n_clients],
            outstanding_round: vec![0; n_clients],
            outstanding_sent_at: vec![0.0; n_clients],
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// The active aggregation policy's name ("sync" / "deadline" /
    /// "async").
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Broadcasts dispatched whose uploads have not yet arrived.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Uploads arrived but not yet aggregated.
    pub fn pending(&self) -> usize {
        self.edge.len()
    }

    /// Shard count of the edge-aggregation tree (1 = unsharded root).
    pub fn n_shards(&self) -> usize {
        self.edge.n_shards()
    }

    /// Re-shard the edge tree (`[scale] n_shards`). Call before the
    /// first upload arrives — the tree refuses to re-route buffered
    /// uploads. Any value is bit-identical to `n_shards = 1` (drain
    /// order is global arrival order by construction).
    pub fn set_shards(&mut self, n_shards: usize) {
        self.edge.set_shards(n_shards);
    }

    /// Current per-shard queue depths (edge-tier diagnostics).
    pub fn shard_occupancy(&self) -> Vec<usize> {
        self.edge.occupancy()
    }

    /// Lifetime upload arrivals per shard (survives drains).
    pub fn shard_arrivals(&self) -> Vec<u64> {
        self.edge.arrivals()
    }

    /// Uploads the fault layer declared lost so far.
    pub fn lost_uploads(&self) -> u64 {
        self.faults.lost()
    }

    /// Crash windows that have ended (clients back in rotation).
    pub fn recovered_clients(&self) -> u64 {
        self.faults.recovered()
    }

    /// The fault layer (drawn tiers, crash windows, counters).
    pub fn faults(&self) -> &FaultLayer {
        &self.faults
    }

    /// Swap the aggregation rule (see [`crate::coordinator::robust`]).
    /// Call before the first step; the default is the bit-faithful
    /// [`WeightedMean`].
    pub fn set_aggregator(&mut self, robust: Box<dyn RobustAggregator>) {
        self.robust = robust;
    }

    /// The active aggregation rule's name ("weighted_mean" / "krum" / …).
    pub fn aggregator_name(&self) -> &'static str {
        self.robust.name()
    }

    /// Quarantine windows the reliability gate has opened so far (0 when
    /// the scheduler is not reliability-gated).
    pub fn quarantine_events(&self) -> u64 {
        self.scheduler.quarantine_events()
    }

    /// Clients the scheduler currently refuses to select (ascending).
    pub fn quarantined_now(&self) -> Vec<usize> {
        self.scheduler.quarantined(self.server.round)
    }

    /// Scenario-scripting access to the fault layer (e.g. pin a victim's
    /// reliability or end an outage mid-session). Levers only — the
    /// layer's RNG stream position is not exposed.
    pub fn faults_mut(&mut self) -> &mut FaultLayer {
        &mut self.faults
    }

    /// Advance the session until the driver has something to do. The
    /// returned [`Directive`] is either a dispatch batch (compute it and
    /// submit the uploads before calling again) or a completed step.
    ///
    /// `dl` is the driver-owned downlink encoder ([`DownlinkTx`]): the
    /// server stays compute-free and calls it once per dispatched client,
    /// in dispatch order on the caller's thread — which keeps compressed
    /// downlinks bit-identical across worker-thread counts. Pass
    /// [`crate::compress::DenseDownlink`] for the classic dense path.
    pub fn next_directive(&mut self, dl: &mut dyn DownlinkTx) -> Result<Directive> {
        loop {
            if let Some(d) = self.outbox.pop_front() {
                return Ok(d);
            }
            if !self.cycle_open {
                self.start_cycle(dl)?;
                continue;
            }
            match self.clock.pop() {
                Some(ev) => self.handle_event(ev, dl)?,
                None => {
                    // The queue drained mid-cycle. Outstanding dispatches
                    // mean the driver broke the submit-before-pump
                    // contract — a fault-layer loss is *not* this case:
                    // lost uploads are resolved (and `in_flight`
                    // decremented) at submit time, so a nonzero count
                    // here is always a driver bug. Otherwise flush what
                    // arrived (barrier trivially met / end-of-buffer), or
                    // report starvation (an async cohort of zero clients
                    // can never make progress).
                    ensure!(
                        self.in_flight == 0,
                        "event queue drained with {} dispatched upload(s) outstanding — \
                         the driver must submit_upload every broadcast (even ones the \
                         fault layer will drop; {} lost upload(s) are already resolved) \
                         before pumping next_directive",
                        self.in_flight,
                        self.faults.lost()
                    );
                    let ctx = self.ctx();
                    if self.policy.ready(AggTrigger::Drained, &ctx) {
                        self.step();
                    } else {
                        bail!(
                            "session starved: no events in flight, nothing pending \
                             (policy {}, cohort {})",
                            self.policy.name(),
                            self.cohort
                        );
                    }
                }
            }
        }
    }

    /// Deliver a client's upload envelope. The full envelope is
    /// validated *here*, where it enters the server — every rejection is
    /// a typed [`UploadError`] (recover it with
    /// `err.downcast_ref::<UploadError>()`):
    ///
    /// * session-state checks: known client, broadcast outstanding, no
    ///   duplicate submission;
    /// * byzantine-envelope checks: claimed round must match the
    ///   outstanding broadcast (a future round would underflow the
    ///   staleness computation), `recon` must have exactly `n_params`
    ///   finite values, the weight must be finite and non-negative, the
    ///   payload internally consistent
    ///   ([`crate::compress::Payload::shape_error`]), and `sent_at` must
    ///   not predate the broadcast (the virtual clock rejects events in
    ///   the past).
    ///
    /// A valid envelope schedules its arrival (send time + tier compute
    /// delay + one-way latency + uplink transfer) and returns
    /// [`ServerMsg::Ack`] — unless the fault layer doomed this client's
    /// upload at dispatch time, in which case the envelope never lands:
    /// loss-tolerant policies get [`ServerMsg::Dropped`] and the client
    /// enters its crash window; a synchronous barrier gets the
    /// [`UploadError::LossUnderBarrier`] diagnostic, because the cohort
    /// could otherwise never complete.
    pub fn submit_upload(&mut self, msg: ClientMsg) -> Result<ServerMsg> {
        let ClientMsg::Upload(mut up) = msg;
        let c = up.client;
        if c >= self.n_clients {
            return Err(UploadError::UnknownClient { client: c, n_clients: self.n_clients }.into());
        }
        if !self.busy[c] {
            return Err(UploadError::NoBroadcast { client: c }.into());
        }
        if self.uploading[c] {
            return Err(UploadError::Duplicate { client: c }.into());
        }
        if up.round != self.outstanding_round[c] {
            return Err(UploadError::RoundMismatch {
                client: c,
                got: up.round,
                expect: self.outstanding_round[c],
            }
            .into());
        }
        if up.recon.len() != self.n_params {
            return Err(UploadError::WrongLength {
                client: c,
                got: up.recon.len(),
                expect: self.n_params,
            }
            .into());
        }
        if let Some(index) = up.recon.iter().position(|v| !v.is_finite()) {
            return Err(UploadError::NonFiniteRecon { client: c, index }.into());
        }
        if !(up.weight.is_finite() && up.weight >= 0.0) {
            return Err(UploadError::BadWeight { client: c, weight: up.weight }.into());
        }
        if let Some(detail) = up.payload.shape_error() {
            return Err(UploadError::MalformedPayload { client: c, detail }.into());
        }
        let dispatched_at = self.outstanding_sent_at[c];
        if !(up.sent_at.is_finite() && up.sent_at >= dispatched_at) {
            return Err(UploadError::BadSendTime {
                client: c,
                sent_at: up.sent_at,
                dispatched_at,
            }
            .into());
        }
        // The content attack happens *after* the envelope clears
        // validation: a compromised client submits a perfectly
        // well-formed envelope whose recon the fault layer poisons in
        // place (gaussian draws in submit order, on the dedicated
        // stream). Defeating this is the robust aggregator's job.
        self.faults.corrupt(c, &mut up.recon);
        let link = self.links[c];
        let recv_at = up.sent_at
            + self.faults.compute_delay(c)
            + link.latency_s
            + link.up_time_s(up.payload.wire_bytes() as u64);
        let doom = if self.doomed[c] {
            // The dispatch-time Bernoulli said this upload dies on the
            // wire; its crash window runs from the would-be arrival.
            self.doomed[c] = false;
            Some(recv_at + self.faults.recover_s())
        } else {
            // Trace replay: a logged outage overlapping the transfer
            // kills it, with recovery at the window's logged end.
            self.faults.trace_loss(c, up.sent_at, recv_at)
        };
        if let Some(back_at) = doom {
            // Resolve the loss instead of scheduling the arrival. The
            // client's in-flight slot frees NOW (the driver did its
            // part), and the scheduler observes the loss — the
            // reliability gate's quarantine signal.
            self.busy[c] = false;
            self.in_flight -= 1;
            self.faults.mark_down(c, back_at);
            self.scheduler.observe(c, self.server.round, true);
            if !self.policy.tolerates_loss() {
                return Err(UploadError::LossUnderBarrier {
                    client: c,
                    round: up.round,
                    at: recv_at,
                }
                .into());
            }
            self.clock.push(back_at, c, SessionEvent::Recover { client: c });
            return Ok(ServerMsg::Dropped { client: c, round: up.round });
        }
        self.uploading[c] = true;
        let ack = Ack { client: c, round: up.round, recv_at };
        self.clock.push(recv_at, c, SessionEvent::Upload(up));
        Ok(ServerMsg::Ack(ack))
    }

    fn ctx(&self) -> PolicyCtx {
        PolicyCtx {
            pending: self.edge.len(),
            in_flight: self.in_flight,
            cohort: self.cohort,
        }
    }

    /// Begin a broadcast cycle: ask the scheduler for a cohort (among
    /// clients that have data and are not already in flight), emit the
    /// dispatch batch, and arm the policy's deadline timer if it has
    /// one.
    fn start_cycle(&mut self, dl: &mut dyn DownlinkTx) -> Result<()> {
        self.cycle_open = true;
        self.cycle_id += 1;
        let now = self.clock.now();
        let selected = self.scheduler.select(self.server.round, self.n_clients);
        let cohort: Vec<usize> = selected
            .into_iter()
            .filter(|&c| self.active[c] && !self.busy[c] && !self.faults.is_down(c, now))
            .collect();
        self.cohort = cohort.len();
        if let Some(d) = self.policy.deadline_s() {
            self.clock.push(
                self.clock.now() + d,
                SimClock::<SessionEvent>::NO_CLIENT,
                SessionEvent::Deadline { cycle: self.cycle_id },
            );
        }
        self.dispatch(cohort, dl)
    }

    /// Emit broadcast envelopes for `cohort` at the current virtual time.
    /// The downlink encoder prices each envelope individually (a dense
    /// keyframe costs exactly the legacy u32-header + 4P broadcast; a
    /// compressed delta its actual serialization), so per-client delivery
    /// times follow each client's *own* payload bytes and downlink rate.
    fn dispatch(&mut self, cohort: Vec<usize>, dl: &mut dyn DownlinkTx) -> Result<()> {
        if cohort.is_empty() {
            return Ok(());
        }
        let now = self.clock.now();
        let round = self.server.round;
        let mut batch = Vec::with_capacity(cohort.len());
        for c in cohort {
            debug_assert!(!self.busy[c], "client {c} dispatched twice");
            self.busy[c] = true;
            self.in_flight += 1;
            self.outstanding_round[c] = round;
            self.outstanding_sent_at[c] = now;
            // One loss draw per broadcast, in dispatch order — the doomed
            // upload is resolved when the driver submits it.
            if self.faults.draw_loss(c, now) {
                self.doomed[c] = true;
            }
            let (payload, w) = dl.encode(c, round, &self.server.w)?;
            let bytes = payload.wire_bytes() as u64;
            self.traffic.record_broadcast(bytes);
            let link = self.links[c];
            batch.push(Broadcast {
                round,
                client: c,
                payload,
                w,
                sent_at: now,
                recv_at: now + link.latency_s + link.down_time_s(bytes),
            });
        }
        self.outbox.push_back(Directive::Dispatch(batch));
        Ok(())
    }

    fn handle_event(&mut self, ev: SimEvent<SessionEvent>, dl: &mut dyn DownlinkTx) -> Result<()> {
        match ev.payload {
            SessionEvent::Upload(up) => {
                // Validated at submit_upload: busy && uploading && in range.
                let c = up.client;
                self.busy[c] = false;
                self.uploading[c] = false;
                self.in_flight -= 1;
                self.scheduler.observe(c, self.server.round, false);
                self.traffic.record_upload(up.payload.wire_bytes());
                self.edge.push(up);
                let redispatch = self.policy.redispatch();
                if self.policy.ready(AggTrigger::Upload, &self.ctx()) {
                    // Aggregate first: a re-dispatched client must train
                    // on the post-step model (FedBuff semantics).
                    self.step();
                }
                if redispatch && self.active[c] && !self.busy[c] {
                    self.dispatch(vec![c], dl)?;
                }
            }
            SessionEvent::Deadline { cycle } => {
                // Timers from already-closed cycles are inert.
                if cycle == self.cycle_id
                    && self.cycle_open
                    && self.policy.ready(AggTrigger::DeadlineExpired, &self.ctx())
                {
                    self.step();
                }
            }
            SessionEvent::Recover { client } => {
                // Crash window over. Server-paced policies pick the
                // client up at their next cycle (cohort filtering is by
                // `is_down`, which this timer postdates); async sessions
                // re-dispatch it now to restore their concurrency level.
                self.faults.mark_up(client);
                if self.policy.redispatch() && self.active[client] && !self.busy[client] {
                    self.dispatch(vec![client], dl)?;
                }
            }
        }
        Ok(())
    }

    /// Aggregate the pending buffer into a global step and queue its
    /// [`StepSummary`]. An empty buffer is a no-op round: weights stay
    /// put, the round counter advances (exactly like the classic loop's
    /// empty-cohort path).
    fn step(&mut self) {
        let at = self.clock.now();
        let round_before = self.server.round;
        // Drain the edge tree in global arrival order — the canonical
        // reduction order, identical for every shard count.
        let mut batch = self.edge.drain_ordered();
        if self.policy.selection_order() {
            // Synchronous contract: aggregate in ascending-client order
            // regardless of arrival order (the whole cohort shares one
            // round, so this is the classic loop's selection order).
            batch.sort_by_key(|u| u.client);
        }
        let n = batch.len();
        let mut clients = Vec::with_capacity(n);
        let mut recons: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut weights: Vec<f32> = Vec::with_capacity(n);
        let mut up_bytes_step = 0u64;
        let mut eff_sum = 0.0f64;
        let mut ratio_sum = 0.0f64;
        let mut stale_sum = 0.0f64;
        for up in batch {
            // Future rounds are rejected at `submit_upload` (the
            // `RoundMismatch` boundary check); saturate anyway so a
            // release build can never underflow into a 2^64-ish
            // staleness even if that invariant regresses.
            debug_assert!(round_before >= up.round, "upload from the future");
            let staleness = round_before.saturating_sub(up.round);
            stale_sum += staleness as f64;
            up_bytes_step += up.payload.wire_bytes() as u64;
            eff_sum += up.efficiency;
            ratio_sum += up.ratio;
            clients.push(up.client);
            weights.push((up.weight as f64 * self.policy.staleness_weight(staleness)) as f32);
            recons.push(up.recon);
        }
        let outcome = self.robust.aggregate(&clients, &recons, &weights, self.n_params);
        self.server.apply_update(outcome.update.as_deref());
        let comm_time_s = at - self.last_step_at;
        self.last_step_at = at;
        self.traffic.record_comm_time(comm_time_s);
        self.traffic.end_round();
        let down_bytes_step = self.traffic.downlink_bytes - self.down_at_last_step;
        self.down_at_last_step = self.traffic.downlink_bytes;
        if self.policy.server_paced() {
            self.cycle_open = false;
        }
        let denom = n.max(1) as f64;
        self.outbox.push_back(Directive::Step(StepSummary {
            round: self.server.round,
            clients,
            up_bytes_step,
            down_bytes_step,
            efficiency: if n == 0 { 0.0 } else { eff_sum / denom },
            ratio: if n == 0 { 0.0 } else { ratio_sum / denom },
            stale_mean: if n == 0 { 0.0 } else { stale_sum / denom },
            rejected_clients: outcome.rejected.len(),
            trim_frac: outcome.trim_frac,
            comm_time_s,
            sim_time_s: at,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{DenseDownlink, Payload};
    use crate::coordinator::policy::{BufferedAsync, Deadline, Synchronous};
    use crate::coordinator::schedule::FullParticipation;
    use crate::simnet::{ByzantineMode, FaultsConfig, NetworkModel, TraceWindow};
    use crate::util::rng::{stream, Rng};

    /// A tiny hand-driven session: n clients, 1-param model, uploads
    /// fabricated by the test (no real training).
    fn fed(
        n: usize,
        policy: Box<dyn AggregationPolicy>,
        links: Vec<ClientLink>,
    ) -> FedServer {
        FedServer::new(
            Server::new(vec![0.0f32]),
            Box::new(FullParticipation),
            policy,
            links,
            vec![true; n],
            1,
        )
    }

    fn links(n: usize) -> Vec<ClientLink> {
        NetworkModel::edge().client_links(n, 0.0, &mut Rng::new(1))
    }

    fn upload(bc: &Broadcast, value: f32) -> ClientMsg {
        ClientMsg::Upload(Upload {
            client: bc.client,
            round: bc.round,
            sent_at: bc.recv_at,
            payload: Payload::Sign { n: 8, bits: vec![0u8], scale: 1.0 },
            recon: vec![value],
            weight: 1.0,
            efficiency: 1.0,
            ratio: 32.0,
        })
    }

    #[test]
    fn synchronous_session_barriers_on_the_cohort() {
        let mut dl = DenseDownlink::new();
        let mut fed = fed(3, Box::new(Synchronous), links(3));
        let bcasts = match fed.next_directive(&mut dl).unwrap() {
            Directive::Dispatch(b) => b,
            _ => panic!("expected a dispatch first"),
        };
        assert_eq!(bcasts.len(), 3);
        assert_eq!(bcasts[0].round, 0);
        for bc in &bcasts {
            let ServerMsg::Ack(ack) = fed.submit_upload(upload(bc, 1.0)).unwrap() else {
                panic!("submit must ack")
            };
            assert!(ack.recv_at > bc.recv_at);
        }
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else {
            panic!("expected the barrier step")
        };
        assert_eq!(s.round, 1);
        assert_eq!(s.clients, vec![0, 1, 2]);
        assert_eq!(s.stale_mean, 0.0);
        assert!(s.comm_time_s > 0.0);
        assert_eq!(s.sim_time_s, fed.now());
        // w ← w − mean(recons) = −1.
        assert!((fed.server.w[0] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn deadline_session_carries_stragglers_over_with_staleness() {
        // Client 1's uplink is throttled so its upload misses the 50 ms
        // deadline: step 1 aggregates {0} alone, and step 2 aggregates
        // client 0's fresh upload plus the straggler (staleness 1,
        // weight γ^1).
        let base = NetworkModel::custom(10.0, 50.0, 1.0);
        let mut ls = base.client_links(2, 0.0, &mut Rng::new(1));
        ls[1].up_bps = 1_000.0; // 9-byte upload → 72 ms ≫ the deadline
        let gamma = 0.5;
        let mut dl = DenseDownlink::new();
        let mut fed = fed(2, Box::new(Deadline::new(0.05, gamma)), ls);

        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!("dispatch first")
        };
        assert_eq!(bcasts.len(), 2);
        for bc in &bcasts {
            fed.submit_upload(upload(bc, 2.0)).unwrap();
        }
        let Directive::Step(s1) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s1.clients, vec![0], "only the fast client made the deadline");
        assert_eq!(s1.stale_mean, 0.0);
        assert!((s1.comm_time_s - 0.05).abs() < 1e-12, "the deadline paces the step");
        assert!((fed.server.w[0] + 2.0).abs() < 1e-6);

        // Cycle 2 dispatches only the idle client (0); its fresh upload
        // lands first, then the round-0 straggler — both inside the new
        // deadline window.
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(bcasts.len(), 1);
        assert_eq!(bcasts[0].client, 0);
        assert_eq!(bcasts[0].round, 1);
        fed.submit_upload(upload(&bcasts[0], 4.0)).unwrap();
        let Directive::Step(s2) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s2.round, 2);
        assert_eq!(s2.clients, vec![0, 1], "arrival order: fresh upload, then straggler");
        assert!((s2.stale_mean - 0.5).abs() < 1e-12, "one stale of two");
        // Weighted mean: (1·4 + γ·2)/(1 + γ) = 5/1.5; w = −2 − that.
        let expect = -2.0 - (4.0 + gamma as f32 * 2.0) / (1.0 + gamma as f32);
        assert!((fed.server.w[0] - expect).abs() < 1e-5, "{} vs {expect}", fed.server.w[0]);
        // Virtual time is monotone and the second step starts where the
        // first ended.
        assert!(s2.sim_time_s > s1.sim_time_s);
        assert!((s2.sim_time_s - s1.sim_time_s - s2.comm_time_s).abs() < 1e-12);
    }

    #[test]
    fn buffered_async_steps_every_k_and_keeps_clients_in_flight() {
        let mut dl = DenseDownlink::new();
        let mut fed = fed(3, Box::new(BufferedAsync::new(2, 1.0)), links(3));
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(bcasts.len(), 3);
        for bc in &bcasts {
            fed.submit_upload(upload(bc, 3.0)).unwrap();
        }
        // Homogeneous links + equal payloads: the three arrivals tie and
        // are processed in client order. Client 0's arrival only fills
        // the buffer to 1, so it is re-dispatched (still round 0).
        let Directive::Dispatch(b) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!((b.len(), b[0].client, b[0].round), (1, 0, 0));
        fed.submit_upload(upload(&b[0], 3.0)).unwrap();
        // Client 1's arrival reaches K=2 → step over {0, 1}, then client
        // 1 is re-dispatched on the post-step model (round 1).
        let Directive::Step(s1) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s1.clients, vec![0, 1]);
        assert_eq!(s1.round, 1);
        assert!((fed.server.w[0] + 3.0).abs() < 1e-6);
        let Directive::Dispatch(b) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!((b[0].client, b[0].round), (1, 1), "re-dispatch sees the post-step model");
        fed.submit_upload(upload(&b[0], 3.0)).unwrap();
        // Client 2's arrival: buffer back to 1, re-dispatch.
        let Directive::Dispatch(b) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!((b[0].client, b[0].round), (2, 1));
        fed.submit_upload(upload(&b[0], 3.0)).unwrap();
        assert_eq!(fed.in_flight(), 3);
        assert_eq!(fed.pending(), 1);
        // Client 0's second upload completes the next buffer. Both
        // buffered uploads (client 2's first, client 0's second) were
        // computed against the round-0 model and the server is at round
        // 1, so both carry staleness 1.
        let Directive::Step(s2) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s2.round, 2);
        assert_eq!(s2.clients, vec![2, 0]);
        assert_eq!(s2.stale_mean, 1.0, "both buffered uploads trained on the round-0 model");
        assert!(s2.sim_time_s >= s1.sim_time_s);
    }

    #[test]
    fn async_starvation_is_an_error_not_a_hang() {
        // No client has data: the initial cohort is empty and an async
        // session can never make progress.
        let mut fed = FedServer::new(
            Server::new(vec![0.0f32]),
            Box::new(FullParticipation),
            Box::new(BufferedAsync::new(1, 1.0)),
            links(2),
            vec![false, false],
            1,
        );
        let mut dl = DenseDownlink::new();
        let err = fed.next_directive(&mut dl).unwrap_err();
        assert!(err.to_string().contains("starved"), "{err}");
    }

    #[test]
    fn sync_empty_cohort_is_a_noop_step() {
        // All clients zero-sample: the classic loop records a no-op
        // round; the event-driven server must do the same (round
        // advances, weights untouched, virtual time does not move).
        let mut fed = FedServer::new(
            Server::new(vec![5.0f32]),
            Box::new(FullParticipation),
            Box::new(Synchronous),
            links(2),
            vec![false, false],
            1,
        );
        let mut dl = DenseDownlink::new();
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s.round, 1);
        assert_eq!(s.clients, Vec::<usize>::new());
        assert_eq!(s.comm_time_s, 0.0);
        assert_eq!(s.down_bytes_step, 0);
        assert_eq!(fed.server.w, vec![5.0]);
    }

    #[test]
    fn dispatch_charges_downlink_per_payload_and_summarizes() {
        // Identity downlink, P = 1: every envelope is a keyframe priced
        // at the u32 length header + 4·P, the ledger splits by direction,
        // and the step reports the interval's downlink bytes.
        let mut dl = DenseDownlink::new();
        let mut fed = fed(3, Box::new(Synchronous), links(3));
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!()
        };
        for bc in &bcasts {
            assert_eq!(bc.payload.kind(), "keyframe");
            assert_eq!(bc.payload.wire_bytes(), 4 + 4);
            fed.submit_upload(upload(bc, 1.0)).unwrap();
        }
        assert_eq!(fed.traffic.downlink_bytes, 3 * 8);
        assert_eq!(fed.traffic.broadcasts, 3);
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s.down_bytes_step, 3 * 8);
        // Uploads are 9-byte Sign payloads (1 + 4 + 4).
        assert_eq!(fed.traffic.uplink_bytes, 3 * 9);
        assert_eq!(fed.traffic.total_bytes(), 3 * 9 + 3 * 8);
    }

    /// Build a server whose fault layer is live (dedicated stream split
    /// from a fixed seed, exactly as `Experiment::new` wires it).
    fn faulty_fed(
        n: usize,
        policy: Box<dyn AggregationPolicy>,
        cfg: &FaultsConfig,
    ) -> FedServer {
        FedServer::with_faults(
            Server::new(vec![0.0f32]),
            Box::new(FullParticipation),
            policy,
            links(n),
            vec![true; n],
            1,
            FaultLayer::new(cfg, n, Rng::new(1).split(stream::FAULTS)),
        )
    }

    fn reject(fed: &mut FedServer, msg: ClientMsg) -> UploadError {
        fed.submit_upload(msg).unwrap_err().downcast::<UploadError>().unwrap()
    }

    #[test]
    fn byzantine_envelopes_are_rejected_with_typed_errors() {
        let mut dl = DenseDownlink::new();
        // Client 1 has no data, so it never gets a broadcast — the
        // NoBroadcast probe below.
        let mut fed = FedServer::new(
            Server::new(vec![0.0f32]),
            Box::new(FullParticipation),
            Box::new(Synchronous),
            links(2),
            vec![true, false],
            1,
        );
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!()
        };
        assert_eq!(bcasts.len(), 1);
        let bc = &bcasts[0];
        let mk = |client: usize, round: usize, sent_at: f64, recon: Vec<f32>, weight: f32| {
            ClientMsg::Upload(Upload {
                client,
                round,
                sent_at,
                payload: Payload::Sign { n: 8, bits: vec![0u8], scale: 1.0 },
                recon,
                weight,
                efficiency: 1.0,
                ratio: 32.0,
            })
        };
        assert_eq!(
            reject(&mut fed, mk(99, 0, bc.recv_at, vec![1.0], 1.0)),
            UploadError::UnknownClient { client: 99, n_clients: 2 }
        );
        assert_eq!(
            reject(&mut fed, mk(1, 0, bc.recv_at, vec![1.0], 1.0)),
            UploadError::NoBroadcast { client: 1 }
        );
        // A *future* round — before the boundary check this underflowed
        // the staleness subtraction in release builds.
        assert_eq!(
            reject(&mut fed, mk(0, 5, bc.recv_at, vec![1.0], 1.0)),
            UploadError::RoundMismatch { client: 0, got: 5, expect: 0 }
        );
        assert_eq!(
            reject(&mut fed, mk(0, 0, bc.recv_at, vec![1.0, 2.0], 1.0)),
            UploadError::WrongLength { client: 0, got: 2, expect: 1 }
        );
        assert_eq!(
            reject(&mut fed, mk(0, 0, bc.recv_at, vec![f32::NAN], 1.0)),
            UploadError::NonFiniteRecon { client: 0, index: 0 }
        );
        assert!(matches!(
            reject(&mut fed, mk(0, 0, bc.recv_at, vec![1.0], f32::NAN)),
            UploadError::BadWeight { client: 0, .. }
        ));
        assert_eq!(
            reject(&mut fed, mk(0, 0, bc.recv_at, vec![1.0], -1.0)),
            UploadError::BadWeight { client: 0, weight: -1.0 }
        );
        // A lying Sign header (bitset shorter than n says) — would
        // under-price the uplink ledger.
        let lying = ClientMsg::Upload(Upload {
            client: 0,
            round: 0,
            sent_at: bc.recv_at,
            payload: Payload::Sign { n: 8, bits: vec![], scale: 1.0 },
            recon: vec![1.0],
            weight: 1.0,
            efficiency: 1.0,
            ratio: 32.0,
        });
        assert_eq!(
            reject(&mut fed, lying),
            UploadError::MalformedPayload {
                client: 0,
                detail: "sign bitset length disagrees with n"
            }
        );
        // Time travel: a send before the broadcast's dispatch would
        // schedule an event in the virtual past.
        assert!(matches!(
            reject(&mut fed, mk(0, 0, -1.0, vec![1.0], 1.0)),
            UploadError::BadSendTime { client: 0, .. }
        ));
        assert!(matches!(
            reject(&mut fed, mk(0, 0, f64::NAN, vec![1.0], 1.0)),
            UploadError::BadSendTime { client: 0, .. }
        ));
        // None of the rejections disturbed the session: the honest
        // envelope still acks, a duplicate is refused, and the barrier
        // step completes on the honest upload alone.
        assert_eq!(fed.server.w, vec![0.0]);
        let ServerMsg::Ack(_) = fed.submit_upload(upload(bc, 1.0)).unwrap() else {
            panic!("honest upload must ack")
        };
        assert_eq!(reject(&mut fed, upload(bc, 1.0)), UploadError::Duplicate { client: 0 });
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s.clients, vec![0]);
        assert!((fed.server.w[0] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn upload_landing_exactly_at_the_deadline_is_included() {
        // 9-byte Sign upload over a 144 bps uplink = exactly 0.5 s, the
        // deadline. The upload event carries a real client index, the
        // timer NO_CLIENT — same instant, upload first.
        let ls =
            vec![ClientLink { up_bps: 144.0, down_bps: f64::INFINITY, latency_s: 0.0 }];
        let mut dl = DenseDownlink::new();
        let mut fed = fed(1, Box::new(Deadline::new(0.5, 0.5)), ls);
        let Directive::Dispatch(b) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(b[0].recv_at, 0.0, "free downlink: the broadcast lands instantly");
        fed.submit_upload(upload(&b[0], 1.0)).unwrap();
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s.clients, vec![0], "a deadline-instant upload makes the cut");
        assert_eq!(s.sim_time_s, 0.5);
        assert_eq!(s.stale_mean, 0.0);
    }

    /// Barrier-with-timeout test policy: steps when the cohort is in
    /// (like sync) *and* arms a deadline timer — the only way a timer
    /// can outlive its cycle.
    struct SyncWithTimer;
    impl AggregationPolicy for SyncWithTimer {
        fn name(&self) -> &'static str {
            "sync+timer"
        }
        fn ready(&self, trigger: AggTrigger, ctx: &PolicyCtx) -> bool {
            match trigger {
                AggTrigger::Upload => ctx.in_flight == 0,
                AggTrigger::DeadlineExpired | AggTrigger::Drained => true,
            }
        }
        fn deadline_s(&self) -> Option<f64> {
            Some(10.0)
        }
        fn selection_order(&self) -> bool {
            true
        }
    }

    #[test]
    fn timers_from_closed_cycles_are_inert() {
        let mut dl = DenseDownlink::new();
        let mut fed = fed(1, Box::new(SyncWithTimer), links(1));
        // Cycle 1: the barrier closes the cycle long before its 10 s
        // timer fires; the timer stays queued.
        let Directive::Dispatch(b) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        fed.submit_upload(upload(&b[0], 1.0)).unwrap();
        let Directive::Step(s1) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s1.round, 1);
        assert!(s1.sim_time_s < 10.0, "the barrier beat the timer");
        // Cycle 2: hold the upload until after the *stale* cycle-1 timer
        // has popped. If that timer were live it would flush an empty
        // step here; instead the next directive must be cycle 2's real
        // barrier step.
        let Directive::Dispatch(b) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        let late = ClientMsg::Upload(Upload {
            client: 0,
            round: b[0].round,
            sent_at: b[0].recv_at + 15.0,
            payload: Payload::Sign { n: 8, bits: vec![0u8], scale: 1.0 },
            recon: vec![2.0],
            weight: 1.0,
            efficiency: 1.0,
            ratio: 32.0,
        });
        fed.submit_upload(late).unwrap();
        let Directive::Step(s2) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s2.round, 2);
        assert_eq!(s2.clients, vec![0], "the stale timer did not flush an empty step");
        assert!(s2.sim_time_s > 15.0, "the step waited for the held upload");
    }

    #[test]
    fn dropout_under_a_synchronous_barrier_is_a_diagnostic_error() {
        let cfg = FaultsConfig { enabled: true, dropout_p: 1.0, ..FaultsConfig::default() };
        let mut fed = faulty_fed(2, Box::new(Synchronous), &cfg);
        let mut dl = DenseDownlink::new();
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!()
        };
        let err = fed.submit_upload(upload(&bcasts[0], 1.0)).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<UploadError>(),
            Some(UploadError::LossUnderBarrier { client: 0, round: 0, .. })
        ));
        let msg = err.to_string();
        assert!(msg.contains("barrier"), "{msg}");
        assert!(msg.contains("deadline or async"), "the error must point at the fix: {msg}");
        assert_eq!(fed.lost_uploads(), 1);
    }

    #[test]
    fn deadline_session_absorbs_a_dropout_and_skips_the_crashed_client() {
        // Client 0 is made immortal, client 1 always loses: the first
        // step aggregates the survivor alone and the next cycle skips
        // the crashed client (its 5 s recovery window is still open at
        // the 50 ms mark).
        let cfg = FaultsConfig { enabled: true, dropout_p: 1.0, ..FaultsConfig::default() };
        let mut fed = faulty_fed(2, Box::new(Deadline::new(0.05, 0.5)), &cfg);
        fed.faults_mut().set_reliability(0, 0.0);
        let mut dl = DenseDownlink::new();
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!()
        };
        assert_eq!(bcasts.len(), 2);
        let ServerMsg::Ack(_) = fed.submit_upload(upload(&bcasts[0], 1.0)).unwrap() else {
            panic!("the immortal client must ack")
        };
        let ServerMsg::Dropped { client: 1, round: 0 } =
            fed.submit_upload(upload(&bcasts[1], 1.0)).unwrap()
        else {
            panic!("the doomed upload must report as dropped, not error")
        };
        assert_eq!(fed.in_flight(), 1, "the lost upload freed its slot immediately");
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s.clients, vec![0], "the survivor aggregates alone");
        assert_eq!(fed.lost_uploads(), 1);
        let Directive::Dispatch(b) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].client, 0, "the crashed client sits out the next cycle");
        assert_eq!(b[0].round, 1);
    }

    #[test]
    fn byzantine_recon_is_poisoned_at_submit_and_robust_aggregation_survives() {
        use crate::coordinator::robust::TrimmedMean;
        // n = 3 at frac 0.34 ⇒ exactly client 2 is compromised.
        let cfg = FaultsConfig {
            enabled: true,
            dropout_p: 0.0,
            byzantine_frac: 0.34,
            byzantine_mode: ByzantineMode::SignFlip,
            ..FaultsConfig::default()
        };
        let mut dl = DenseDownlink::new();
        let mut fed = faulty_fed(3, Box::new(Synchronous), &cfg);
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!()
        };
        for bc in &bcasts {
            fed.submit_upload(upload(bc, 1.0)).unwrap();
        }
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        // Client 2's recon was flipped to −1 on submit: the default mean
        // aggregates (1 + 1 − 1)/3.
        assert_eq!(fed.aggregator_name(), "weighted_mean");
        assert_eq!(s.rejected_clients, 0);
        assert_eq!(s.trim_frac, 0.0);
        assert!((fed.server.w[0] + 1.0 / 3.0).abs() < 1e-6, "{}", fed.server.w[0]);

        // Same session under a β-trimmed mean: both per-coordinate
        // extremes (the flipped −1 and one honest 1) are trimmed, and
        // the surviving middle value neutralizes the attack.
        let mut fed = faulty_fed(3, Box::new(Synchronous), &cfg);
        fed.set_aggregator(Box::new(TrimmedMean { beta: 0.34 }));
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!()
        };
        for bc in &bcasts {
            fed.submit_upload(upload(bc, 1.0)).unwrap();
        }
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(fed.aggregator_name(), "trimmed_mean");
        assert!((s.trim_frac - 2.0 / 3.0).abs() < 1e-12);
        assert!((fed.server.w[0] + 1.0).abs() < 1e-6, "{}", fed.server.w[0]);
    }

    #[test]
    fn trace_outage_kills_the_overlapping_upload_and_is_draw_free() {
        // dropout_p = 1 would doom everything — but installing a trace
        // switches the loss model to replay, so only the logged window
        // bites: client 1 goes down just after dispatch and its upload
        // is lost mid-transfer.
        let cfg = FaultsConfig { enabled: true, dropout_p: 1.0, ..FaultsConfig::default() };
        let mut fed = faulty_fed(2, Box::new(Deadline::new(0.05, 0.5)), &cfg);
        fed.faults_mut()
            .set_trace(vec![TraceWindow { client: 1, down_at: 0.001, up_at: 10.0 }]);
        let mut dl = DenseDownlink::new();
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!()
        };
        assert_eq!(bcasts.len(), 2, "the log says client 1 is still up at dispatch");
        let ServerMsg::Ack(_) = fed.submit_upload(upload(&bcasts[0], 1.0)).unwrap() else {
            panic!("client 0 has no logged outage")
        };
        let ServerMsg::Dropped { client: 1, round: 0 } =
            fed.submit_upload(upload(&bcasts[1], 1.0)).unwrap()
        else {
            panic!("the logged outage must kill the in-flight upload")
        };
        assert_eq!(fed.lost_uploads(), 1);
        let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(s.clients, vec![0], "the survivor aggregates alone");
        // The next cycle, at the 50 ms deadline, still sits inside the
        // logged window — client 1 is skipped by selection.
        let Directive::Dispatch(b) = fed.next_directive(&mut dl).unwrap() else { panic!() };
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].client, 0);
    }

    #[test]
    fn async_session_recovers_and_redispatches_a_dropped_client() {
        // Clients 0 and 2 immortal, client 1 always loses; a short
        // recovery window so its Recover timer fires while the session
        // is still pumping. The K=2 step aggregates the survivors and
        // the victim is re-dispatched on a post-loss model.
        let cfg = FaultsConfig {
            enabled: true,
            dropout_p: 1.0,
            recover_s: 0.5,
            ..FaultsConfig::default()
        };
        let mut fed = faulty_fed(3, Box::new(BufferedAsync::new(2, 1.0)), &cfg);
        fed.faults_mut().set_reliability(0, 0.0);
        fed.faults_mut().set_reliability(2, 0.0);
        let mut dl = DenseDownlink::new();
        let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
            panic!()
        };
        assert_eq!(bcasts.len(), 3);
        let mut dropped = 0;
        for bc in &bcasts {
            match fed.submit_upload(upload(bc, 1.0)).unwrap() {
                ServerMsg::Dropped { client, round } => {
                    dropped += 1;
                    assert_eq!((client, round), (1, 0));
                }
                ServerMsg::Ack(_) => {}
                other => panic!("unexpected reply {other:?}"),
            }
        }
        assert_eq!(dropped, 1);
        assert_eq!(fed.lost_uploads(), 1);
        // Pump until the recovered victim is re-dispatched, answering
        // every other dispatch honestly along the way.
        let mut first_step = None;
        let mut victim_round = None;
        for _ in 0..80 {
            match fed.next_directive(&mut dl).unwrap() {
                Directive::Dispatch(bs) => {
                    if let Some(bc) = bs.iter().find(|b| b.client == 1) {
                        victim_round = Some(bc.round);
                        break;
                    }
                    for bc in &bs {
                        fed.submit_upload(upload(bc, 1.0)).unwrap();
                    }
                }
                Directive::Step(s) => {
                    if first_step.is_none() {
                        first_step = Some(s);
                    }
                }
            }
        }
        let s = first_step.expect("the survivors must reach the K=2 buffer");
        assert_eq!(s.clients, vec![0, 2], "survivors aggregate without the victim");
        assert_eq!(s.round, 1);
        let r = victim_round.expect("the victim must be re-dispatched after recovery");
        assert!(r >= 1, "recovery re-dispatch sees a post-loss model (round {r})");
        assert_eq!(fed.recovered_clients(), 1);
    }
}
