//! Per-client state: local data sampler, error-feedback memory, RNG.
//!
//! Lifecycle note: experiments never hold a dense `Vec<ClientState>` —
//! states are materialized on demand by the
//! [`crate::coordinator::ClientStore`] (and, under `[scale]
//! lazy_state`, spilled back out between participations). Construction
//! here must therefore be a pure function of `(id, indices, n_params,
//! root_rng)`: [`Rng::split`] is deterministic, so a client built at
//! round 400 is bit-identical to one built at round 0.

use crate::data::{ClientSampler, Dataset};
use crate::util::rng::{stream, Rng};

pub struct ClientState {
    pub id: usize,
    pub sampler: ClientSampler,
    /// Error-feedback memory e_i^t (Eq. 6). All-zero when EF is disabled.
    pub ef: Vec<f32>,
    /// Client-local stream (synthetic-feature init etc.).
    pub rng: Rng,
    /// |D_i| — aggregation weight (the paper's weighted average G).
    pub n_samples: usize,
    /// Rounds this client was selected in (partial-participation stats).
    pub rounds_participated: usize,
    /// Model version of the last broadcast this client reconstructed —
    /// the client-side mirror of the server's downlink ledger
    /// (`compress::downlink`). `None` until first participation.
    pub last_version: Option<usize>,
}

impl ClientState {
    pub fn new(id: usize, indices: Vec<u32>, n_params: usize, root_rng: &Rng) -> ClientState {
        let n_samples = indices.len();
        ClientState {
            id,
            sampler: ClientSampler::new(
                indices,
                root_rng.split(stream::CLIENT_SAMPLER_BASE + id as u64),
            ),
            ef: vec![0.0f32; n_params],
            rng: root_rng.split(stream::CLIENT_LOCAL_BASE + id as u64),
            n_samples,
            rounds_participated: 0,
            last_version: None,
        }
    }

    /// Sample the K×B local batches for one round.
    pub fn sample_round(&mut self, ds: &Dataset, k: usize, b: usize) -> (Vec<f32>, Vec<i32>) {
        self.sampler.sample_batches(ds, k, b)
    }
}
