//! The experiment driver: wires dataset → partition → scheduler → clients
//! → compressor → server-optimizer into the paper's training loop
//! (Algorithm 1), generalized into a composable round engine.
//!
//! Per round: the [`ClientScheduler`] picks the participating set, each
//! selected client trains locally and uploads a compressed payload, the
//! server aggregates over the *selected* clients only and steps through
//! its [`crate::coordinator::ServerOptimizer`], and the [`NetworkModel`]
//! converts the round's
//! payload sizes into a modeled `comm_time_s` (slowest-selected-client
//! semantics). Skipped clients keep all state — in particular their
//! error-feedback memory — untouched until their next participation.
//!
//! The per-client work (local training + the S-step 3SFC encoder, the
//! dominant cost) fans out over a [`WorkerPool`] when `threads > 1`; see
//! [`crate::coordinator::parallel`] for the determinism contract. The
//! round loop itself runs in three phases: sequential batch sampling in
//! selection order, parallel train-and-compress into selection-order
//! slots, then sequential state write-back and accounting — so records
//! are bit-identical for every thread count.
//!
//! Construct experiments with [`ExperimentBuilder`] (or
//! [`Experiment::new`] from a finished [`ExperimentConfig`]).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::compress::{self, Compressor};
use crate::config::{
    BackendKind, CompressorKind, DatasetKind, ExperimentConfig, NetworkKind, ScheduleKind,
    ServerOptKind,
};
use crate::coordinator::opt::build_server_opt;
use crate::coordinator::parallel::{run_client, ClientJob, ClientUpdate, WorkerPool};
use crate::coordinator::schedule::{build_scheduler, ClientScheduler};
use crate::coordinator::{ClientState, MetricsSink, Server, Traffic};
use crate::data::{dirichlet_partition, Dataset};
use crate::runtime::{Backend, FedOps, RuntimeStats};
use crate::simnet::NetworkModel;
use crate::util::rng::Rng;

/// One round's observables.
#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub test_acc: f64,
    pub test_loss: f64,
    /// Clients that participated this round (= n_clients under full
    /// participation).
    pub n_selected: usize,
    pub up_bytes_round: u64,
    pub up_bytes_cum: u64,
    /// Mean per-client compression efficiency cos(ĝ, g+e) (Fig 7).
    pub efficiency: f64,
    /// Mean compression ratio (× vs dense) over this round's payloads.
    pub ratio: f64,
    /// Modeled communication time for this round under the configured
    /// link: slowest selected upload + broadcast + latency.
    pub comm_time_s: f64,
    pub wall_ms: f64,
}

/// A fully-wired FL experiment.
pub struct Experiment<'a> {
    pub cfg: ExperimentConfig,
    pub ops: FedOps<'a>,
    pub server: Server,
    pub clients: Vec<ClientState>,
    pub scheduler: Box<dyn ClientScheduler>,
    pub compressor: Box<dyn Compressor>,
    pub net: NetworkModel,
    pub train: Dataset,
    pub test: Dataset,
    pub traffic: Traffic,
    pub metrics: MetricsSink,
    /// The clients that participated in the most recent round
    /// (tests/diagnostics).
    pub last_selected: Vec<usize>,
    /// Worker pool for the per-round client fan-out; `None` runs the
    /// sequential (seed-exact) path.
    pool: Option<WorkerPool>,
}

impl<'a> Experiment<'a> {
    /// Start a fluent builder over the default (paper-faithful) config.
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::new()
    }

    pub fn new(cfg: ExperimentConfig, backend: &'a dyn Backend) -> Result<Experiment<'a>> {
        cfg.validate()?;
        let ops = FedOps::new(backend, cfg.model_key())?;
        let model = ops.model;
        anyhow::ensure!(
            model.feature_len() == cfg.dataset.feature_len(),
            "model {} expects {} features, dataset {} provides {}",
            model.name,
            model.feature_len(),
            cfg.dataset.name(),
            cfg.dataset.feature_len()
        );
        anyhow::ensure!(
            model.n_classes == cfg.dataset.n_classes(),
            "model/dataset class count mismatch"
        );

        let root = Rng::new(cfg.seed);
        // Same task (class templates) for both splits, disjoint sample streams.
        let train = Dataset::generate_split(cfg.dataset, cfg.train_samples, cfg.seed, 0);
        let test = Dataset::generate_split(cfg.dataset, cfg.test_samples, cfg.seed, 1);
        let mut part_rng = root.split(0x9A87_1710);
        let parts = dirichlet_partition(&train, cfg.n_clients, cfg.alpha, &mut part_rng);
        let clients: Vec<ClientState> = parts
            .into_iter()
            .enumerate()
            .map(|(i, idxs)| ClientState::new(i, idxs, model.params, &root))
            .collect();

        let w0 = match &cfg.init_weights {
            Some(w) => {
                anyhow::ensure!(
                    w.len() == model.params,
                    "init_weights has {} values, model {} needs {}",
                    w.len(),
                    model.name,
                    model.params
                );
                w.clone()
            }
            None => backend.load_init(model)?,
        };
        let scheduler = build_scheduler(&cfg, &root);
        let server = Server::with_optimizer(w0, build_server_opt(&cfg));
        let net = cfg.network_model();
        let compressor = compress::build(&cfg, model);
        let metrics = MetricsSink::new(&cfg.metrics_path)?;
        // One worker per thread, never more workers than clients; a
        // single thread skips the pool entirely and reproduces the
        // original sequential loop on this experiment's own backend.
        // Workers re-open the *same* backend from its `Send` spec — the
        // per-worker-instance dance only actually costs anything on PJRT
        // (the native backend is a pure in-memory construction).
        let threads = cfg.effective_threads().min(cfg.n_clients);
        let pool = if threads > 1 {
            Some(WorkerPool::new(backend.spec(), &cfg, threads)?)
        } else {
            None
        };
        Ok(Experiment {
            cfg,
            ops,
            server,
            clients,
            scheduler,
            compressor,
            net,
            train,
            test,
            traffic: Traffic::default(),
            metrics,
            last_selected: Vec::new(),
            pool,
        })
    }

    /// Number of threads executing clients each round (1 = sequential).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.workers()).unwrap_or(1)
    }

    /// Aggregated runtime counters of the worker pool, if one is running
    /// (the main backend's counters are reported by `Backend::stats`).
    pub fn pool_stats(&self) -> Option<RuntimeStats> {
        self.pool.as_ref().map(|p| p.stats())
    }

    /// Run one communication round; returns the record (evaluation only on
    /// eval rounds, otherwise acc/loss carry the last evaluation — seeded
    /// with a real round-0 evaluation of the initial weights).
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let model = self.ops.model;
        let k = self.cfg.k_local;
        let b = model.train_batch;
        // One clone of the weights per round, shared by both execution
        // paths (and the pool workers) through the Arc.
        let w_global: Arc<Vec<f32>> = Arc::new(self.server.w.clone());

        let selected = self.scheduler.select(self.server.round, self.clients.len());
        // Zero-sample clients (possible only when a best-effort partition
        // cannot give everyone data) carry zero aggregation weight: skip
        // them instead of panicking in empty-pool sampling or a
        // zero-total aggregate.
        let active: Vec<usize> = selected
            .iter()
            .copied()
            .filter(|&ci| self.clients[ci].n_samples > 0)
            .collect();

        // Phase 1 (sequential, selection order): draw each active
        // client's local batches and snapshot the state its job needs —
        // the data-loader streams advance exactly as in the sequential
        // loop, independent of thread count.
        let mut jobs: Vec<ClientJob> = Vec::with_capacity(active.len());
        for (slot, &ci) in active.iter().enumerate() {
            let client = &mut self.clients[ci];
            let (xs, ys) = client.sample_round(&self.train, k, b);
            // Clone (don't take) the EF memory: if the round errors out
            // mid-flight the client must keep its accumulated error, not
            // be silently reset to zeros.
            let ef = if self.cfg.error_feedback {
                client.ef.clone()
            } else {
                Vec::new()
            };
            jobs.push(ClientJob {
                slot,
                xs,
                ys,
                ef,
                rng: client.rng.clone(),
                weight: client.n_samples as f32,
            });
        }

        // Phase 2 (parallel): train + compress every client. Updates come
        // back in slots indexed by selection order; per-client math is
        // identical on both paths (same `run_client`), so the trajectory
        // is bit-identical for any thread count.
        let updates: Vec<ClientUpdate> = match &self.pool {
            Some(pool) if jobs.len() > 1 => {
                pool.run_clients(Arc::clone(&w_global), jobs)?
            }
            _ => jobs
                .into_iter()
                .map(|job| {
                    run_client(&self.ops, self.compressor.as_ref(), &self.cfg, &w_global, job)
                })
                .collect::<Result<Vec<_>>>()?,
        };

        // Phase 3 (sequential, selection order): write client state back
        // and account traffic/efficiency exactly as the sequential loop
        // did.
        let mut recons: Vec<Vec<f32>> = Vec::with_capacity(active.len());
        let mut weights: Vec<f32> = Vec::with_capacity(active.len());
        let mut up_bytes_each: Vec<u64> = Vec::with_capacity(active.len());
        let mut round_bytes = 0u64;
        let mut eff_sum = 0.0f64;
        let mut ratio_sum = 0.0f64;
        for u in updates {
            let client = &mut self.clients[active[u.slot]];
            if self.cfg.error_feedback {
                client.ef = u.ef;
            }
            client.rng = u.rng;
            client.rounds_participated += 1;

            round_bytes += u.wire_bytes;
            up_bytes_each.push(u.wire_bytes);
            ratio_sum += u.ratio;
            eff_sum += u.efficiency;
            self.traffic.record_upload(u.wire_bytes as usize);
            recons.push(u.recon);
            weights.push(u.weight);
        }

        // Aggregation over the selected set + server-optimizer step
        // (a no-op round if every selected client was skipped).
        self.server.apply_round(&recons, &weights);
        self.traffic.record_broadcast(model.params, active.len());
        let comm_time_s = self
            .net
            .round_time_slowest(&up_bytes_each, (4 * model.params) as u64);
        self.traffic.record_comm_time(comm_time_s);
        self.traffic.end_round();

        // 7. Evaluation. Non-eval rounds carry the previous evaluation
        // forward; before any evaluation exists, evaluate the pre-round
        // (round-0) weights instead of recording NaN placeholders.
        let round = self.server.round;
        let (test_loss, test_acc) = if round % self.cfg.eval_every.max(1) == 0 {
            self.ops
                .eval_dataset(&self.server.w, &self.test.features, &self.test.labels)?
        } else {
            match self.metrics.last() {
                Some(r) => (r.test_loss, r.test_acc),
                None => self
                    .ops
                    .eval_dataset(&w_global, &self.test.features, &self.test.labels)?,
            }
        };

        let n_selected = active.len();
        self.last_selected = active;
        let rec = RoundRecord {
            round,
            test_acc,
            test_loss,
            n_selected,
            up_bytes_round: round_bytes,
            up_bytes_cum: self.traffic.up_bytes,
            efficiency: if n_selected == 0 { 0.0 } else { eff_sum / n_selected as f64 },
            ratio: if n_selected == 0 { 0.0 } else { ratio_sum / n_selected as f64 },
            comm_time_s,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        self.metrics.push(rec)?;
        Ok(rec)
    }

    /// Run the configured number of rounds; returns all records.
    pub fn run(&mut self) -> Result<Vec<RoundRecord>> {
        for _ in 0..self.cfg.rounds {
            self.run_round()?;
        }
        self.metrics.flush()?;
        Ok(self.metrics.records.clone())
    }

    /// Convenience label "method (ratio×)" like the paper's tables. The
    /// ratio is the *mean* over all recorded rounds — a single round's
    /// value is noisy under partial participation — and the suffix is
    /// omitted before any round has run.
    pub fn label(&self) -> String {
        let ratio = self.metrics.mean_ratio();
        if ratio.is_finite() {
            format!("{} ({:.1}x)", self.compressor.name(), ratio)
        } else {
            self.compressor.name()
        }
    }

    /// Compressor-kind accessor for reporting.
    pub fn kind(&self) -> CompressorKind {
        self.cfg.compressor
    }
}

/// Fluent construction of an [`Experiment`] — examples and benches set
/// only what differs from the paper-faithful defaults instead of filling
/// an [`ExperimentConfig`] field-by-field.
///
/// ```no_run
/// # use fed3sfc::config::{CompressorKind, DatasetKind, ScheduleKind, ServerOptKind};
/// # use fed3sfc::coordinator::experiment::Experiment;
/// # fn main() -> anyhow::Result<()> {
/// let builder = Experiment::builder()
///     .dataset(DatasetKind::SynthSmall)
///     .compressor(CompressorKind::ThreeSfc)
///     .clients(100)
///     .schedule(ScheduleKind::Uniform)
///     .client_frac(0.1)
///     .server_opt(ServerOptKind::FedAdam)
///     .rounds(20);
/// // PJRT artifacts when available, pure-Rust native backend otherwise
/// // (or force one with `.backend(...)` / FED3SFC_BACKEND).
/// let backend = fed3sfc::runtime::open_backend(builder.config())?;
/// let mut exp = builder.build(backend.as_ref())?;
/// exp.run()?;
/// # Ok(()) }
/// ```
#[derive(Clone, Debug, Default)]
pub struct ExperimentBuilder {
    cfg: ExperimentConfig,
}

impl ExperimentBuilder {
    pub fn new() -> ExperimentBuilder {
        ExperimentBuilder { cfg: ExperimentConfig::default() }
    }

    /// Seed the builder from an existing config (e.g. a TOML preset).
    pub fn from_config(cfg: ExperimentConfig) -> ExperimentBuilder {
        ExperimentBuilder { cfg }
    }

    /// The accumulated config (for inspection before `build`).
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.cfg.name = name.into();
        self
    }

    pub fn dataset(mut self, ds: DatasetKind) -> Self {
        self.cfg.dataset = ds;
        self
    }

    pub fn model(mut self, key: impl Into<String>) -> Self {
        self.cfg.model = key.into();
        self
    }

    pub fn compressor(mut self, kind: CompressorKind) -> Self {
        self.cfg.compressor = kind;
        self
    }

    pub fn clients(mut self, n: usize) -> Self {
        self.cfg.n_clients = n;
        self
    }

    pub fn rounds(mut self, n: usize) -> Self {
        self.cfg.rounds = n;
        self
    }

    pub fn k_local(mut self, k: usize) -> Self {
        self.cfg.k_local = k;
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.cfg.lr = lr;
        self
    }

    pub fn budget_mult(mut self, m: usize) -> Self {
        self.cfg.budget_mult = m;
        self
    }

    pub fn syn_steps(mut self, s: usize) -> Self {
        self.cfg.syn_steps = s;
        self
    }

    pub fn lr_syn(mut self, lr: f32) -> Self {
        self.cfg.lr_syn = lr;
        self
    }

    pub fn error_feedback(mut self, on: bool) -> Self {
        self.cfg.error_feedback = on;
        self
    }

    pub fn topk_rate(mut self, rate: f64) -> Self {
        self.cfg.topk_rate = rate;
        self
    }

    pub fn alpha(mut self, alpha: f64) -> Self {
        self.cfg.alpha = alpha;
        self
    }

    pub fn train_samples(mut self, n: usize) -> Self {
        self.cfg.train_samples = n;
        self
    }

    pub fn test_samples(mut self, n: usize) -> Self {
        self.cfg.test_samples = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn eval_every(mut self, n: usize) -> Self {
        self.cfg.eval_every = n;
        self
    }

    pub fn fedsynth_ksim(mut self, k: usize) -> Self {
        self.cfg.fedsynth_ksim = k;
        self
    }

    pub fn fedsynth_steps(mut self, s: usize) -> Self {
        self.cfg.fedsynth_steps = s;
        self
    }

    pub fn metrics_path(mut self, path: impl Into<String>) -> Self {
        self.cfg.metrics_path = path.into();
        self
    }

    /// Worker threads for the per-round client fan-out: `0` = auto
    /// (available parallelism, overridable with `FED3SFC_THREADS`),
    /// `1` = the sequential seed path. Any value yields bit-identical
    /// trajectories.
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    /// Compute backend: PJRT artifacts, the pure-Rust native path, or
    /// auto (resolved against `FED3SFC_BACKEND` / artifact presence by
    /// [`crate::runtime::open_backend`]).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.cfg.backend = kind;
        self
    }

    /// Pin the initial global weights instead of asking the backend for
    /// its deterministic init (warm starts; the backend-parity test).
    pub fn initial_weights(mut self, w0: Vec<f32>) -> Self {
        self.cfg.init_weights = Some(w0);
        self
    }

    pub fn schedule(mut self, kind: ScheduleKind) -> Self {
        self.cfg.schedule = kind;
        self
    }

    pub fn client_frac(mut self, frac: f64) -> Self {
        self.cfg.client_frac = frac;
        self
    }

    pub fn server_opt(mut self, kind: ServerOptKind) -> Self {
        self.cfg.server_opt = kind;
        self
    }

    pub fn server_lr(mut self, lr: f32) -> Self {
        self.cfg.server_lr = lr;
        self
    }

    pub fn server_momentum(mut self, beta: f32) -> Self {
        self.cfg.server_momentum = beta;
        self
    }

    pub fn adam_params(mut self, beta1: f32, beta2: f32, tau: f32) -> Self {
        self.cfg.adam_beta1 = beta1;
        self.cfg.adam_beta2 = beta2;
        self.cfg.adam_tau = tau;
        self
    }

    pub fn network(mut self, kind: NetworkKind) -> Self {
        self.cfg.network = kind;
        self
    }

    pub fn custom_network(mut self, up_mbps: f64, down_mbps: f64, latency_ms: f64) -> Self {
        self.cfg.network = NetworkKind::Custom;
        self.cfg.net_up_mbps = up_mbps;
        self.cfg.net_down_mbps = down_mbps;
        self.cfg.net_latency_ms = latency_ms;
        self
    }

    /// Validate and wire the experiment against a backend.
    pub fn build(self, backend: &dyn Backend) -> Result<Experiment<'_>> {
        Experiment::new(self.cfg, backend)
    }
}
