//! The experiment driver: wires dataset → partition → clients →
//! compressor → event-driven [`FedServer`] into the paper's training
//! loop (Algorithm 1), generalized into message-passing federation
//! sessions.
//!
//! [`Experiment::run_round`] is a thin driver: it pumps
//! [`FedServer::next_directive`] — computing each
//! [`Directive::Dispatch`] batch (local training + encode, fanned out
//! over a [`WorkerPool`] when `threads > 1`) and answering with
//! [`crate::coordinator::protocol::Upload`] envelopes — until one
//! aggregation [`Directive::Step`] completes, then evaluates and
//! records. *When* arrivals become a step is the session's
//! [`crate::coordinator::AggregationPolicy`] (`[session] mode`):
//! synchronous cohort barriers reproduce the classic loop bit-for-bit,
//! deadline and buffered-async sessions run on the same driver with the
//! simnet virtual clock as their only time source. Skipped clients keep
//! all state — in particular their error-feedback memory — untouched
//! until their next participation.
//!
//! Scale: per-client state lives in a [`ClientStore`]
//! (`coordinator::shard`) that materializes a client only when it is
//! dispatched — construction never allocates `n_clients` dense EF
//! vectors, and with `[scale] lazy_state = true` each client is evicted
//! (EF spilled to a compact slab) right after its upload is submitted,
//! so the driver holds `O(cohort)` dense vectors at any instant.
//!
//! Determinism: batches are sampled sequentially in dispatch order,
//! per-client work fans out into dispatch-order slots (see
//! [`crate::coordinator::parallel`]), and state write-back happens in
//! slot order before uploads are submitted — so trajectories are
//! bit-identical for every thread count, in every session mode.
//!
//! Construct experiments with [`ExperimentBuilder`] (or
//! [`Experiment::new`] from a finished [`ExperimentConfig`]).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::compress::{self, Compressor, DownlinkTx};
use crate::config::{
    AggregatorKind, BackendKind, CompressorKind, DatasetKind, DownlinkKind,
    ExperimentConfig, NetworkKind, ScheduleKind, ServerOptKind, SessionKind,
    SpillKind,
};
use crate::coordinator::fedserver::{Directive, FedServer};
use crate::coordinator::opt::build_server_opt;
use crate::coordinator::parallel::{run_client, ClientJob, ClientUpdate, WorkerPool};
use crate::coordinator::policy::build_policy;
use crate::coordinator::protocol::{Broadcast, ClientMsg, Upload};
use crate::coordinator::robust::build_aggregator;
use crate::coordinator::schedule::build_scheduler;
use crate::coordinator::{ClientStore, MetricsSink, Server, Traffic};
use crate::data::{dirichlet_partition, Dataset};
use crate::runtime::{Backend, FedOps, RuntimeStats};
use crate::simnet::{load_trace, ByzantineMode, FaultLayer};
use crate::util::rng::{stream, Rng};

/// One aggregation step's observables ("round" in the synchronous
/// protocol; one server step in deadline/async sessions).
#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub test_acc: f64,
    pub test_loss: f64,
    /// Clients whose uploads were aggregated this step (= n_clients
    /// under synchronous full participation).
    pub n_selected: usize,
    pub up_bytes_round: u64,
    pub up_bytes_cum: u64,
    /// Downlink wire bytes of the broadcasts dispatched in this step's
    /// interval (keyframes and/or compressed deltas, priced per
    /// envelope).
    pub down_bytes_round: u64,
    pub down_bytes_cum: u64,
    /// Mean per-client compression efficiency cos(ĝ, g+e) (Fig 7).
    pub efficiency: f64,
    /// Mean compression ratio (× vs dense) over this step's payloads.
    pub ratio: f64,
    /// Virtual time this step consumed under the configured link model
    /// (for a synchronous round: slowest selected upload + broadcast +
    /// latency).
    pub comm_time_s: f64,
    /// Cumulative virtual-clock time at which this step completed.
    pub sim_time_s: f64,
    /// Mean staleness (model versions) of the aggregated updates —
    /// always 0 in synchronous sessions.
    pub stale_mean: f64,
    /// Uploads the robust aggregator rejected wholesale this step
    /// ((Multi-)Krum non-selection; 0 for reweighting estimators).
    pub rejected_clients: usize,
    /// Fraction of the batch's influence the aggregator trimmed, clipped
    /// or rejected (0 for the plain weighted mean).
    pub trim_frac: f64,
    /// Wall-clock milliseconds of client compute + aggregation only;
    /// evaluation is reported separately in `eval_ms` so eval cadence
    /// (`eval_every`) never pollutes per-round throughput numbers.
    pub wall_ms: f64,
    /// Wall-clock milliseconds spent evaluating this round (≈ 0 when
    /// the round carried the previous evaluation forward).
    pub eval_ms: f64,
}

/// A fully-wired FL experiment.
pub struct Experiment<'a> {
    pub cfg: ExperimentConfig,
    pub ops: FedOps<'a>,
    /// The event-driven server (global model, scheduler, aggregation
    /// policy, virtual clock, traffic accounting).
    pub fed: FedServer,
    /// Per-client state, materialized on demand (and — under `[scale]
    /// lazy_state` — evicted to spill slabs between participations).
    pub clients: ClientStore,
    pub compressor: Box<dyn Compressor>,
    pub train: Dataset,
    pub test: Dataset,
    pub metrics: MetricsSink,
    /// The clients aggregated in the most recent step
    /// (tests/diagnostics).
    pub last_selected: Vec<usize>,
    /// Worker pool for the dispatch-batch client fan-out; `None` runs
    /// the sequential (seed-exact) path.
    pool: Option<WorkerPool>,
    /// Server-side downlink encoder (`[downlink]`): the per-client
    /// version ledger + shadow-replica EF, or the dense keyframe path.
    /// Driver-owned and passed into every `next_directive` pump so the
    /// server itself stays compute-free.
    downlink: Box<dyn DownlinkTx + 'a>,
}

impl<'a> Experiment<'a> {
    /// Start a fluent builder over the default (paper-faithful) config.
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::new()
    }

    pub fn new(cfg: ExperimentConfig, backend: &'a dyn Backend) -> Result<Experiment<'a>> {
        cfg.validate()?;
        let ops = FedOps::new(backend, cfg.model_key())?;
        let model = ops.model;
        anyhow::ensure!(
            model.feature_len() == cfg.dataset.feature_len(),
            "model {} expects {} features, dataset {} provides {}",
            model.name,
            model.feature_len(),
            cfg.dataset.name(),
            cfg.dataset.feature_len()
        );
        anyhow::ensure!(
            model.n_classes == cfg.dataset.n_classes(),
            "model/dataset class count mismatch"
        );

        // detlint: allow(DET003) -- the experiment root: the single seeded
        // entry point every other stream descends from via `split`.
        let root = Rng::new(cfg.seed);
        // Same task (class templates) for both splits, disjoint sample streams.
        let train = Dataset::generate_split(cfg.dataset, cfg.train_samples, cfg.seed, 0);
        let test = Dataset::generate_split(cfg.dataset, cfg.test_samples, cfg.seed, 1);
        let mut part_rng = root.split(stream::PARTITION);
        let parts = dirichlet_partition(&train, cfg.n_clients, cfg.alpha, &mut part_rng);
        // No ClientState is built here: the store materializes each
        // client on first dispatch (Rng::split is pure, so late
        // construction is bit-identical to the old eager loop).
        let clients =
            ClientStore::new(parts, model.params, &root, cfg.lazy_state, cfg.spill);

        let w0 = match &cfg.init_weights {
            Some(w) => {
                anyhow::ensure!(
                    w.len() == model.params,
                    "init_weights has {} values, model {} needs {}",
                    w.len(),
                    model.name,
                    model.params
                );
                w.clone()
            }
            None => backend.load_init(model)?,
        };
        let scheduler = build_scheduler(&cfg, &root);
        let server = Server::with_optimizer(w0, build_server_opt(&cfg));
        // Per-client links on a dedicated stream: `[network] jitter`
        // spreads bandwidth without perturbing any other randomness.
        let mut link_rng = root.split(stream::LINK_JITTER);
        let mut links = cfg
            .network_model()
            .client_links(cfg.n_clients, cfg.net_jitter, &mut link_rng);
        // The fault layer owns its dedicated stream; `[faults]` off means
        // zero draws and identity link scaling — bit-identical to a
        // server built without the layer.
        let faults =
            FaultLayer::new(&cfg.faults_config(), cfg.n_clients, root.split(stream::FAULTS));
        faults.scale_links(&mut links);
        let active: Vec<bool> = clients.active_mask();
        let mut fed = FedServer::with_faults(
            server,
            scheduler,
            build_policy(&cfg),
            links,
            active,
            model.params,
            faults,
        );
        // Both defense hooks are draw-free, so installing them here
        // leaves every RNG stream's draw order untouched — and so is
        // re-sharding the (still empty) edge-aggregation tree.
        fed.set_aggregator(build_aggregator(&cfg));
        fed.set_shards(cfg.n_shards);
        if !cfg.fault_trace.is_empty() {
            fed.faults_mut().set_trace(load_trace(&cfg.fault_trace)?);
        }
        let compressor = compress::build(&cfg, model);
        // The downlink encoder runs on the main thread (sequentially, in
        // dispatch order) with its own FedOps handle and RNG stream — so
        // compressed broadcasts are identical for every thread count.
        let downlink = compress::build_downlink(
            &cfg,
            model,
            FedOps::new(backend, cfg.model_key())?,
            root.split(stream::DOWNLINK),
        );
        let metrics = MetricsSink::new(&cfg.metrics_path)?;
        // One worker per thread, never more workers than clients; a
        // single thread skips the pool entirely and reproduces the
        // original sequential loop on this experiment's own backend.
        // Workers re-open the *same* backend from its `Send` spec — the
        // per-worker-instance dance only actually costs anything on PJRT
        // (the native backend is a pure in-memory construction).
        let threads = cfg.effective_threads().min(cfg.n_clients);
        let pool = if threads > 1 {
            Some(WorkerPool::new(backend.spec(), &cfg, threads)?)
        } else {
            None
        };
        Ok(Experiment {
            cfg,
            ops,
            fed,
            clients,
            compressor,
            train,
            test,
            metrics,
            last_selected: Vec::new(),
            pool,
            downlink,
        })
    }

    /// Cumulative wire traffic (owned by the [`FedServer`]).
    pub fn traffic(&self) -> Traffic {
        self.fed.traffic
    }

    /// Number of threads executing clients each round (1 = sequential).
    pub fn threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.workers()).unwrap_or(1)
    }

    /// Aggregated runtime counters of the worker pool, if one is running
    /// (the main backend's counters are reported by `Backend::stats`).
    pub fn pool_stats(&self) -> Option<RuntimeStats> {
        self.pool.as_ref().map(|p| p.stats())
    }

    /// Run the session until one aggregation step completes; returns the
    /// record (evaluation only on eval rounds, otherwise acc/loss carry
    /// the last evaluation — seeded with a real round-0 evaluation of
    /// the initial weights).
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        let t0 = Instant::now();
        // Fallback evaluation target for a non-eval first record: the
        // pre-step weights (= the initial weights, since no step has
        // been applied before the first record exists).
        let w_before: Option<Vec<f32>> = if self.metrics.records.is_empty() {
            Some(self.fed.server.w.clone())
        } else {
            None
        };

        // Pump the server: compute every dispatch batch it emits until
        // its policy turns arrivals into an aggregation step.
        let summary = loop {
            match self.fed.next_directive(self.downlink.as_mut())? {
                Directive::Dispatch(bcasts) => self.compute_and_submit(&bcasts)?,
                Directive::Step(s) => break s,
            }
        };
        // Snapshot compute+aggregate time *before* evaluation so eval
        // cadence never pollutes per-round throughput numbers.
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;

        let round = summary.round;
        let t_eval = Instant::now();
        let (test_loss, test_acc) = if round % self.cfg.eval_every.max(1) == 0 {
            self.ops
                .eval_dataset(&self.fed.server.w, &self.test.features, &self.test.labels)?
        } else {
            match self.metrics.last() {
                Some(r) => (r.test_loss, r.test_acc),
                None => {
                    let w0 = w_before.as_ref().expect("first record snapshots pre-step weights");
                    self.ops
                        .eval_dataset(w0, &self.test.features, &self.test.labels)?
                }
            }
        };
        let eval_ms = t_eval.elapsed().as_secs_f64() * 1e3;

        let n_selected = summary.clients.len();
        self.last_selected = summary.clients;
        let rec = RoundRecord {
            round,
            test_acc,
            test_loss,
            n_selected,
            up_bytes_round: summary.up_bytes_step,
            up_bytes_cum: self.fed.traffic.uplink_bytes,
            down_bytes_round: summary.down_bytes_step,
            down_bytes_cum: self.fed.traffic.downlink_bytes,
            efficiency: summary.efficiency,
            ratio: summary.ratio,
            comm_time_s: summary.comm_time_s,
            sim_time_s: summary.sim_time_s,
            stale_mean: summary.stale_mean,
            rejected_clients: summary.rejected_clients,
            trim_frac: summary.trim_frac,
            wall_ms,
            eval_ms,
        };
        self.metrics.push(rec)?;
        Ok(rec)
    }

    /// Execute one dispatch batch: sample local batches sequentially in
    /// dispatch order, fan train-and-compress out over the pool (bit-
    /// identical to the sequential path — same `run_client`, results in
    /// dispatch-order slots), write client state back in slot order, and
    /// answer the server with one upload envelope per client.
    fn compute_and_submit(&mut self, bcasts: &[Broadcast]) -> Result<()> {
        let k = self.cfg.k_local;
        let b = self.ops.model.train_batch;
        debug_assert!(!bcasts.is_empty(), "dispatch batches are never empty");

        // Each client trains on its *own* broadcast reconstruction
        // (`bc.w`): with a compressed downlink the cohort's weights can
        // differ per client (ledger/EF state); dense keyframes share one
        // Arc so the classic path still clones nothing.
        let mut jobs: Vec<(Arc<Vec<f32>>, ClientJob)> = Vec::with_capacity(bcasts.len());
        for (slot, bc) in bcasts.iter().enumerate() {
            let client = self.clients.client(bc.client);
            let (xs, ys) = client.sample_round(&self.train, k, b);
            // Clone (don't take) the EF memory: if the batch errors out
            // mid-flight the client must keep its accumulated error, not
            // be silently reset to zeros.
            let ef = if self.cfg.error_feedback {
                client.ef.clone()
            } else {
                Vec::new()
            };
            jobs.push((
                Arc::clone(&bc.w),
                ClientJob {
                    slot,
                    xs,
                    ys,
                    ef,
                    rng: client.rng.clone(),
                    weight: client.n_samples as f32,
                },
            ));
        }

        let updates: Vec<ClientUpdate> = match &self.pool {
            Some(pool) if jobs.len() > 1 => pool.run_clients(jobs)?,
            _ => jobs
                .into_iter()
                .map(|(w, job)| {
                    run_client(&self.ops, self.compressor.as_ref(), &self.cfg, &w, job)
                })
                .collect::<Result<Vec<_>>>()?,
        };

        for u in updates {
            let bc = &bcasts[u.slot];
            let client = self.clients.client(bc.client);
            if self.cfg.error_feedback {
                client.ef = u.ef;
            }
            client.rng = u.rng;
            client.rounds_participated += 1;
            client.last_version = Some(bc.round);
            let _ack = self.fed.submit_upload(ClientMsg::Upload(Upload {
                client: bc.client,
                round: bc.round,
                sent_at: bc.recv_at,
                payload: u.payload,
                recon: u.recon,
                weight: u.weight,
                efficiency: u.efficiency,
                ratio: u.ratio,
            }))?;
            // Participation over: a lazy store evicts the client here
            // (EF spilled bit-exactly), bounding resident dense state
            // to this dispatch batch.
            self.clients.release(bc.client);
        }
        Ok(())
    }

    /// Run the configured number of rounds; returns all records.
    pub fn run(&mut self) -> Result<Vec<RoundRecord>> {
        for _ in 0..self.cfg.rounds {
            self.run_round()?;
        }
        self.metrics.flush()?;
        Ok(self.metrics.records.clone())
    }

    /// Convenience label "method (ratio×)" like the paper's tables. The
    /// ratio is the *mean* over all recorded rounds — a single round's
    /// value is noisy under partial participation — and the suffix is
    /// omitted before any round has run. With a compressed downlink a
    /// `/ down <name> (ratio×)` segment reports the broadcast direction
    /// too.
    pub fn label(&self) -> String {
        let ratio = self.metrics.mean_ratio();
        let mut label = if ratio.is_finite() {
            format!("{} ({:.1}x)", self.compressor.name(), ratio)
        } else {
            self.compressor.name()
        };
        if self.cfg.downlink != DownlinkKind::Identity {
            let dense = (4 + 4 * self.ops.model.params) as u64;
            let down = self.fed.traffic.down_ratio(dense);
            if down.is_finite() {
                label.push_str(&format!(
                    " / down {} ({:.1}x)",
                    self.downlink.name(),
                    down
                ));
            } else {
                label.push_str(&format!(" / down {}", self.downlink.name()));
            }
        }
        label
    }

    /// Compressor-kind accessor for reporting.
    pub fn kind(&self) -> CompressorKind {
        self.cfg.compressor
    }
}

/// Fluent construction of an [`Experiment`] — examples and benches set
/// only what differs from the paper-faithful defaults instead of filling
/// an [`ExperimentConfig`] field-by-field.
///
/// ```no_run
/// # use fed3sfc::config::{CompressorKind, DatasetKind, ScheduleKind, ServerOptKind};
/// # use fed3sfc::coordinator::experiment::Experiment;
/// # fn main() -> anyhow::Result<()> {
/// let builder = Experiment::builder()
///     .dataset(DatasetKind::SynthSmall)
///     .compressor(CompressorKind::ThreeSfc)
///     .clients(100)
///     .schedule(ScheduleKind::Uniform)
///     .client_frac(0.1)
///     .server_opt(ServerOptKind::FedAdam)
///     .rounds(20);
/// // PJRT artifacts when available, pure-Rust native backend otherwise
/// // (or force one with `.backend(...)` / FED3SFC_BACKEND).
/// let backend = fed3sfc::runtime::open_backend(builder.config())?;
/// let mut exp = builder.build(backend.as_ref())?;
/// exp.run()?;
/// # Ok(()) }
/// ```
#[derive(Clone, Debug, Default)]
pub struct ExperimentBuilder {
    cfg: ExperimentConfig,
}

impl ExperimentBuilder {
    pub fn new() -> ExperimentBuilder {
        ExperimentBuilder { cfg: ExperimentConfig::default() }
    }

    /// Seed the builder from an existing config (e.g. a TOML preset).
    pub fn from_config(cfg: ExperimentConfig) -> ExperimentBuilder {
        ExperimentBuilder { cfg }
    }

    /// The accumulated config (for inspection before `build`).
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.cfg.name = name.into();
        self
    }

    pub fn dataset(mut self, ds: DatasetKind) -> Self {
        self.cfg.dataset = ds;
        self
    }

    pub fn model(mut self, key: impl Into<String>) -> Self {
        self.cfg.model = key.into();
        self
    }

    pub fn compressor(mut self, kind: CompressorKind) -> Self {
        self.cfg.compressor = kind;
        self
    }

    pub fn clients(mut self, n: usize) -> Self {
        self.cfg.n_clients = n;
        self
    }

    pub fn rounds(mut self, n: usize) -> Self {
        self.cfg.rounds = n;
        self
    }

    pub fn k_local(mut self, k: usize) -> Self {
        self.cfg.k_local = k;
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.cfg.lr = lr;
        self
    }

    pub fn budget_mult(mut self, m: usize) -> Self {
        self.cfg.budget_mult = m;
        self
    }

    pub fn syn_steps(mut self, s: usize) -> Self {
        self.cfg.syn_steps = s;
        self
    }

    pub fn lr_syn(mut self, lr: f32) -> Self {
        self.cfg.lr_syn = lr;
        self
    }

    pub fn error_feedback(mut self, on: bool) -> Self {
        self.cfg.error_feedback = on;
        self
    }

    pub fn topk_rate(mut self, rate: f64) -> Self {
        self.cfg.topk_rate = rate;
        self
    }

    pub fn alpha(mut self, alpha: f64) -> Self {
        self.cfg.alpha = alpha;
        self
    }

    pub fn train_samples(mut self, n: usize) -> Self {
        self.cfg.train_samples = n;
        self
    }

    pub fn test_samples(mut self, n: usize) -> Self {
        self.cfg.test_samples = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn eval_every(mut self, n: usize) -> Self {
        self.cfg.eval_every = n;
        self
    }

    pub fn fedsynth_ksim(mut self, k: usize) -> Self {
        self.cfg.fedsynth_ksim = k;
        self
    }

    pub fn fedsynth_steps(mut self, s: usize) -> Self {
        self.cfg.fedsynth_steps = s;
        self
    }

    pub fn metrics_path(mut self, path: impl Into<String>) -> Self {
        self.cfg.metrics_path = path.into();
        self
    }

    /// Worker threads for the per-round client fan-out: `0` = auto
    /// (available parallelism, overridable with `FED3SFC_THREADS`),
    /// `1` = the sequential seed path. Any value yields bit-identical
    /// trajectories.
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.threads = n;
        self
    }

    /// Compute backend: PJRT artifacts, the pure-Rust native path, or
    /// auto (resolved against `FED3SFC_BACKEND` / artifact presence by
    /// [`crate::runtime::open_backend`]).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.cfg.backend = kind;
        self
    }

    /// Pin the initial global weights instead of asking the backend for
    /// its deterministic init (warm starts; the backend-parity test).
    pub fn initial_weights(mut self, w0: Vec<f32>) -> Self {
        self.cfg.init_weights = Some(w0);
        self
    }

    pub fn schedule(mut self, kind: ScheduleKind) -> Self {
        self.cfg.schedule = kind;
        self
    }

    pub fn client_frac(mut self, frac: f64) -> Self {
        self.cfg.client_frac = frac;
        self
    }

    pub fn server_opt(mut self, kind: ServerOptKind) -> Self {
        self.cfg.server_opt = kind;
        self
    }

    pub fn server_lr(mut self, lr: f32) -> Self {
        self.cfg.server_lr = lr;
        self
    }

    pub fn server_momentum(mut self, beta: f32) -> Self {
        self.cfg.server_momentum = beta;
        self
    }

    pub fn adam_params(mut self, beta1: f32, beta2: f32, tau: f32) -> Self {
        self.cfg.adam_beta1 = beta1;
        self.cfg.adam_beta2 = beta2;
        self.cfg.adam_tau = tau;
        self
    }

    pub fn network(mut self, kind: NetworkKind) -> Self {
        self.cfg.network = kind;
        self
    }

    pub fn custom_network(mut self, up_mbps: f64, down_mbps: f64, latency_ms: f64) -> Self {
        self.cfg.network = NetworkKind::Custom;
        self.cfg.net_up_mbps = up_mbps;
        self.cfg.net_down_mbps = down_mbps;
        self.cfg.net_latency_ms = latency_ms;
        self
    }

    /// Per-client bandwidth spread in [0, 1) (`[network] jitter`): each
    /// client's link rates are scaled by a seed-deterministic factor in
    /// `[1 − jitter, 1 + jitter]`.
    pub fn jitter(mut self, jitter: f64) -> Self {
        self.cfg.net_jitter = jitter;
        self
    }

    /// Aggregation policy of the event-driven session (`[session] mode`):
    /// synchronous cohort barrier (default), per-round deadline, or
    /// FedBuff-style buffered asynchrony.
    pub fn session(mut self, kind: SessionKind) -> Self {
        self.cfg.session = kind;
        self
    }

    /// Semi-sync aggregation deadline in virtual seconds
    /// (`session = Deadline`).
    pub fn deadline_s(mut self, s: f64) -> Self {
        self.cfg.deadline_s = s;
        self
    }

    /// Aggregate every K arrivals (`session = Async`).
    pub fn buffer_k(mut self, k: usize) -> Self {
        self.cfg.buffer_k = k;
        self
    }

    /// Staleness discount base γ ∈ (0, 1] for deadline/async weighting
    /// (`|D_i| · γ^staleness`; 1.0 disables the discount).
    pub fn staleness_decay(mut self, gamma: f64) -> Self {
        self.cfg.staleness_decay = gamma;
        self
    }

    /// Downlink broadcast compression (`[downlink] kind`): identity
    /// keyframes (default, bit-identical to the classic dense path),
    /// or 3sfc/top-k/STC on the per-client model delta.
    pub fn downlink(mut self, kind: DownlinkKind) -> Self {
        self.cfg.downlink = kind;
        self
    }

    /// Keyframe fallback threshold (`[downlink] gap`): clients more than
    /// `gap` model versions behind get a dense keyframe.
    pub fn downlink_gap(mut self, gap: usize) -> Self {
        self.cfg.downlink_gap = gap;
        self
    }

    /// Explicit downlink sparsity rate (`[downlink] rate`); 0 keeps the
    /// budget-matched default.
    pub fn downlink_rate(mut self, rate: f64) -> Self {
        self.cfg.downlink_rate = rate;
        self
    }

    /// Adversarial fault layer master switch (`[faults] enabled`).
    pub fn faults(mut self, on: bool) -> Self {
        self.cfg.faults = on;
        self
    }

    /// Base per-dispatch upload-loss probability (`[faults] dropout_p`).
    pub fn dropout_p(mut self, p: f64) -> Self {
        self.cfg.fault_dropout_p = p;
        self
    }

    /// Crash-window length in virtual seconds (`[faults] recover_s`).
    pub fn fault_recovery(mut self, s: f64) -> Self {
        self.cfg.fault_recover_s = s;
        self
    }

    /// Diurnal availability wave (`[faults] diurnal_amp` /
    /// `diurnal_period_s`): loss probability swings by ±`amp` over each
    /// `period_s` of virtual time.
    pub fn diurnal(mut self, amp: f64, period_s: f64) -> Self {
        self.cfg.fault_diurnal_amp = amp;
        self.cfg.fault_diurnal_period_s = period_s;
        self
    }

    /// Correlated device-class tiers (`[faults] tiers` / `tier_spread` /
    /// `tier_compute_s`): one draw per client decides bandwidth, compute
    /// delay and reliability together.
    pub fn device_tiers(mut self, tiers: usize, spread: f64, compute_s: f64) -> Self {
        self.cfg.fault_tiers = tiers;
        self.cfg.fault_tier_spread = spread;
        self.cfg.fault_tier_compute_s = compute_s;
        self
    }

    /// Byzantine content attack (`[faults] byzantine_frac` /
    /// `byzantine_mode`): the last `round(frac * n)` client indices
    /// submit poisoned recons whenever the fault layer is enabled.
    pub fn byzantine(mut self, frac: f64, mode: ByzantineMode) -> Self {
        self.cfg.byzantine_frac = frac;
        self.cfg.byzantine_mode = mode;
        self
    }

    /// Trace-driven outage schedule (`[faults] trace`): a JSONL file of
    /// per-client `[down_at, up_at)` windows that replaces the parametric
    /// dropout draw entirely.
    pub fn fault_trace(mut self, path: impl Into<String>) -> Self {
        self.cfg.fault_trace = path.into();
        self
    }

    /// Robust aggregation rule (`[defense] aggregator`).
    pub fn aggregator(mut self, kind: AggregatorKind) -> Self {
        self.cfg.aggregator = kind;
        self
    }

    /// Per-side trim fraction for the trimmed mean (`[defense]
    /// trim_beta`).
    pub fn trim_beta(mut self, beta: f64) -> Self {
        self.cfg.trim_beta = beta;
        self
    }

    /// Krum parameters (`[defense] krum_f` / `krum_m`): assumed attacker
    /// count `f` and Multi-Krum selection size `m` (0 = defaults).
    pub fn krum(mut self, f: usize, m: usize) -> Self {
        self.cfg.krum_f = f;
        self.cfg.krum_m = m;
        self
    }

    /// Norm-clip threshold (`[defense] clip_tau`; 0 = median-norm
    /// auto-threshold).
    pub fn clip_tau(mut self, tau: f64) -> Self {
        self.cfg.clip_tau = tau;
        self
    }

    /// Reliability-aware cohort gating (`[defense] reliability`):
    /// quarantine chronically failing clients off the EWMA loss signal.
    pub fn reliability(mut self, on: bool) -> Self {
        self.cfg.reliability = on;
        self
    }

    /// Rounds a quarantined client sits out (`[defense]
    /// quarantine_rounds`).
    pub fn quarantine_rounds(mut self, n: usize) -> Self {
        self.cfg.quarantine_rounds = n;
        self
    }

    /// Reliability EWMA tuning (`[defense] ewma_alpha` / `threshold`).
    pub fn reliability_ewma(mut self, alpha: f64, threshold: f64) -> Self {
        self.cfg.reliability_alpha = alpha;
        self.cfg.reliability_threshold = threshold;
        self
    }

    /// Edge-aggregator shard count (`[scale] n_shards`): uploads buffer
    /// per shard (`client % n_shards`) and drain in exact global arrival
    /// order, so any value is bit-identical to the unsharded path.
    pub fn n_shards(mut self, n: usize) -> Self {
        self.cfg.n_shards = n;
        self
    }

    /// Lazy client state (`[scale] lazy_state`): evict each client after
    /// participation, spilling its EF residual to a compact slab —
    /// resident dense state becomes `O(cohort)`, trajectories unchanged.
    pub fn lazy_state(mut self, on: bool) -> Self {
        self.cfg.lazy_state = on;
        self
    }

    /// EF spill slab encoding (`[scale] spill`): boxed f32 vectors or
    /// dense-payload byte slabs (both bit-exact).
    pub fn spill(mut self, kind: SpillKind) -> Self {
        self.cfg.spill = kind;
        self
    }

    /// Validate and wire the experiment against a backend.
    pub fn build(self, backend: &dyn Backend) -> Result<Experiment<'_>> {
        Experiment::new(self.cfg, backend)
    }
}
