//! The experiment driver: wires dataset → partition → clients → compressor
//! → server into the paper's training loop (Algorithm 1).

use std::time::Instant;

use anyhow::Result;

use crate::compress::{self, Compressor, EncodeCtx};
use crate::config::{CompressorKind, ExperimentConfig};
use crate::coordinator::{ClientState, MetricsSink, Server, Traffic};
use crate::data::{dirichlet_partition, Dataset};
use crate::runtime::{FedOps, Runtime};
use crate::util::rng::Rng;
use crate::util::vecmath;

/// One round's observables.
#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub test_acc: f64,
    pub test_loss: f64,
    pub up_bytes_round: u64,
    pub up_bytes_cum: u64,
    /// Mean per-client compression efficiency cos(ĝ, g+e) (Fig 7).
    pub efficiency: f64,
    /// Compression ratio (× vs dense) of this round's payloads.
    pub ratio: f64,
    pub wall_ms: f64,
}

/// A fully-wired FL experiment.
pub struct Experiment<'a> {
    pub cfg: ExperimentConfig,
    pub ops: FedOps<'a>,
    pub server: Server,
    pub clients: Vec<ClientState>,
    pub compressor: Box<dyn Compressor>,
    pub train: Dataset,
    pub test: Dataset,
    pub traffic: Traffic,
    pub metrics: MetricsSink,
}

impl<'a> Experiment<'a> {
    pub fn new(cfg: ExperimentConfig, rt: &'a Runtime) -> Result<Experiment<'a>> {
        cfg.validate()?;
        let ops = FedOps::new(rt, cfg.model_key())?;
        let model = ops.model;
        anyhow::ensure!(
            model.feature_len() == cfg.dataset.feature_len(),
            "model {} expects {} features, dataset {} provides {}",
            model.name,
            model.feature_len(),
            cfg.dataset.name(),
            cfg.dataset.feature_len()
        );
        anyhow::ensure!(
            model.n_classes == cfg.dataset.n_classes(),
            "model/dataset class count mismatch"
        );

        let root = Rng::new(cfg.seed);
        // Same task (class templates) for both splits, disjoint sample streams.
        let train = Dataset::generate_split(cfg.dataset, cfg.train_samples, cfg.seed, 0);
        let test = Dataset::generate_split(cfg.dataset, cfg.test_samples, cfg.seed, 1);
        let mut part_rng = root.split(0x9A87_1710);
        let parts = dirichlet_partition(&train, cfg.n_clients, cfg.alpha, &mut part_rng);
        let clients: Vec<ClientState> = parts
            .into_iter()
            .enumerate()
            .map(|(i, idxs)| ClientState::new(i, idxs, model.params, &root))
            .collect();

        let w0 = rt.manifest.load_init(model)?;
        let compressor = compress::build(&cfg, model);
        let metrics = MetricsSink::new(&cfg.metrics_path)?;
        Ok(Experiment {
            cfg,
            ops,
            server: Server::new(w0),
            clients,
            compressor,
            train,
            test,
            traffic: Traffic::default(),
            metrics,
        })
    }

    /// Run one communication round; returns the record (evaluation only on
    /// eval rounds, otherwise acc/loss copy the previous record).
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let cfg = &self.cfg;
        let model = self.ops.model;
        let k = cfg.k_local;
        let b = model.train_batch;
        let w_global = self.server.w.clone();

        let mut recons: Vec<Vec<f32>> = Vec::with_capacity(self.clients.len());
        let mut weights: Vec<f32> = Vec::with_capacity(self.clients.len());
        let mut round_bytes = 0u64;
        let mut eff_sum = 0.0f64;
        let mut ratio = 0.0f64;

        for client in &mut self.clients {
            // 1. Local training (Algorithm 1, lines 3-5).
            let (xs, ys) = client.sample_round(&self.train, k, b);
            let w_local = self.ops.local_train(k, &w_global, &xs, &ys, cfg.lr)?;
            let g = vecmath::sub(&w_global, &w_local);

            // 2. Error-feedback target (Eq. 6).
            let mut target = g;
            if cfg.error_feedback {
                vecmath::add_assign(&mut target, &client.ef);
            }

            // 3. Compress.
            let mut ctx = EncodeCtx {
                ops: &self.ops,
                w_global: &w_global,
                rng: &mut client.rng,
            };
            let (payload, recon) = self.compressor.encode(&mut ctx, &target)?;

            // 4. EF update: e ← target − ĝ.
            if cfg.error_feedback {
                client.ef = vecmath::sub(&target, &recon);
            }

            // 5. Traffic + efficiency accounting.
            round_bytes += payload.wire_bytes() as u64;
            ratio = payload.ratio(model.params);
            eff_sum += vecmath::cosine(&recon, &target);
            self.traffic.record_upload(payload.wire_bytes());

            recons.push(recon);
            weights.push(client.n_samples as f32);
        }

        // 6. Server aggregation + global step (Eq. 3).
        self.server.apply_round(&recons, &weights);
        self.traffic
            .record_broadcast(model.params, self.clients.len());
        self.traffic.end_round();

        // 7. Evaluation.
        let round = self.server.round;
        let (test_loss, test_acc) = if round % self.cfg.eval_every.max(1) == 0 {
            let (l, a) = self
                .ops
                .eval_dataset(&self.server.w, &self.test.features, &self.test.labels)?;
            (l, a)
        } else {
            self.metrics
                .last()
                .map(|r| (r.test_loss, r.test_acc))
                .unwrap_or((f64::NAN, f64::NAN))
        };

        let rec = RoundRecord {
            round,
            test_acc,
            test_loss,
            up_bytes_round: round_bytes,
            up_bytes_cum: self.traffic.up_bytes,
            efficiency: eff_sum / self.clients.len() as f64,
            ratio,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        self.metrics.push(rec)?;
        Ok(rec)
    }

    /// Run the configured number of rounds; returns all records.
    pub fn run(&mut self) -> Result<Vec<RoundRecord>> {
        for _ in 0..self.cfg.rounds {
            self.run_round()?;
        }
        self.metrics.flush()?;
        Ok(self.metrics.records.clone())
    }

    /// Convenience label "method (ratio×)" like the paper's tables.
    pub fn label(&self) -> String {
        let ratio = self
            .metrics
            .last()
            .map(|r| r.ratio)
            .unwrap_or(f64::NAN);
        format!("{} ({:.1}x)", self.compressor.name(), ratio)
    }

    /// Compressor-kind accessor for reporting.
    pub fn kind(&self) -> CompressorKind {
        self.cfg.compressor
    }
}
