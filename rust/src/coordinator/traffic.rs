//! Communication accounting: exact bytes on the (simulated) wire,
//! split by direction — since the downlink subsystem the broadcast side
//! is charged per *envelope* (each broadcast's own payload wire bytes),
//! not as a flat dense price.

/// Cumulative traffic for one experiment.
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    /// Client→server payload bytes (the compressed uploads).
    pub uplink_bytes: u64,
    /// Server→client payload bytes (keyframes and/or compressed deltas).
    pub downlink_bytes: u64,
    /// Number of broadcast envelopes charged.
    pub broadcasts: u64,
    /// Cumulative modeled communication time (simnet, slowest-client
    /// round semantics) in seconds.
    pub comm_s: f64,
    pub rounds: u64,
}

impl Traffic {
    pub fn record_upload(&mut self, bytes: usize) {
        self.uplink_bytes += bytes as u64;
    }

    pub fn record_comm_time(&mut self, seconds: f64) {
        self.comm_s += seconds;
    }

    /// Charge one broadcast envelope at its exact wire size.
    ///
    /// Wire-honesty is symmetric with the upload path: `bytes` is the
    /// payload's own `wire_bytes()`
    /// ([`crate::compress::DeltaPayload::wire_bytes`]) — a dense keyframe
    /// prices exactly like the legacy dense broadcast (u32 length header
    /// + 4·P), a compressed delta its actual serialization.
    pub fn record_broadcast(&mut self, bytes: u64) {
        self.downlink_bytes += bytes;
        self.broadcasts += 1;
    }

    pub fn end_round(&mut self) {
        self.rounds += 1;
    }

    /// Both directions combined.
    pub fn total_bytes(&self) -> u64 {
        self.uplink_bytes + self.downlink_bytes
    }

    /// Mean upload bytes per round. NaN before any round completes —
    /// the ledger-wide no-data sentinel ([`Traffic::down_ratio`] and
    /// `MetricsSink::mean_ratio` already use NaN; a literal `0.0` here
    /// read as "zero bytes per round", which is a real measurement, not
    /// "no rounds yet"). Display code is expected to guard with
    /// `is_finite()` and omit the figure.
    pub fn up_per_round(&self) -> f64 {
        if self.rounds == 0 {
            f64::NAN
        } else {
            self.uplink_bytes as f64 / self.rounds as f64
        }
    }

    /// Downlink compression ratio vs pricing every sent envelope at the
    /// dense broadcast cost `dense_bytes` (= 4 + 4·P). NaN before any
    /// broadcast.
    pub fn down_ratio(&self, dense_bytes: u64) -> f64 {
        (self.broadcasts * dense_bytes) as f64 / self.downlink_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_data_sentinels_are_nan_in_both_directions() {
        // Before any round/broadcast the per-round figures are *unknown*,
        // not zero: both directions agree on NaN.
        let t = Traffic::default();
        assert!(t.up_per_round().is_nan());
        assert!(t.down_ratio(44).is_nan());
    }

    #[test]
    fn accounting() {
        let mut t = Traffic::default();
        t.record_upload(100);
        t.record_upload(50);
        t.record_comm_time(1.5);
        t.record_comm_time(0.5);
        t.end_round();
        // Per-envelope broadcast charging: 3 dense keyframes of a P=10
        // model (4-byte u32 length header + 4·P each)…
        for _ in 0..3 {
            t.record_broadcast(4 + 40);
        }
        // …and one compressed delta.
        t.record_broadcast(13);
        assert_eq!(t.uplink_bytes, 150);
        assert_eq!(t.downlink_bytes, 3 * (4 + 40) + 13);
        assert_eq!(t.broadcasts, 4);
        assert_eq!(t.total_bytes(), 150 + 3 * 44 + 13);
        assert_eq!(t.up_per_round(), 150.0);
        assert_eq!(t.comm_s, 2.0);
        assert!((t.down_ratio(44) - (4.0 * 44.0) / 145.0).abs() < 1e-12);
    }
}
