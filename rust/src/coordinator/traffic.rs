//! Communication accounting: exact bytes on the (simulated) wire.

/// Cumulative traffic for one experiment.
#[derive(Clone, Copy, Debug, Default)]
pub struct Traffic {
    /// Client→server payload bytes (the compressed uploads).
    pub up_bytes: u64,
    /// Server→client bytes (dense global-model broadcasts).
    pub down_bytes: u64,
    /// Cumulative modeled communication time (simnet, slowest-client
    /// round semantics) in seconds.
    pub comm_s: f64,
    pub rounds: u64,
}

impl Traffic {
    pub fn record_upload(&mut self, bytes: usize) {
        self.up_bytes += bytes as u64;
    }

    pub fn record_comm_time(&mut self, seconds: f64) {
        self.comm_s += seconds;
    }

    /// Charge one dense model broadcast to `n_clients` receivers.
    ///
    /// Wire-honesty is symmetric with the upload path: each per-client
    /// broadcast is priced as the dense f32 vector *plus the same u32
    /// length header* every upload payload charges
    /// ([`crate::compress::Payload::wire_bytes`]) — a real serializer
    /// frames the buffer in both directions.
    pub fn record_broadcast(&mut self, n_params: usize, n_clients: usize) {
        self.down_bytes += ((4 + 4 * n_params) * n_clients) as u64;
    }

    pub fn end_round(&mut self) {
        self.rounds += 1;
    }

    /// Mean upload bytes per round.
    pub fn up_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.up_bytes as f64 / self.rounds as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting() {
        let mut t = Traffic::default();
        t.record_upload(100);
        t.record_upload(50);
        t.record_comm_time(1.5);
        t.record_comm_time(0.5);
        t.end_round();
        // Broadcast framing is symmetric with the upload path: 4-byte
        // u32 length header + 4·P per receiving client.
        t.record_broadcast(10, 3);
        assert_eq!(t.up_bytes, 150);
        assert_eq!(t.down_bytes, 3 * (4 + 40));
        assert_eq!(t.up_per_round(), 150.0);
        assert_eq!(t.comm_s, 2.0);
    }
}
