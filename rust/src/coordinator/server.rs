//! The FL server: holds the global model and applies the aggregated
//! (reconstructed) gradients — Eq. 3/6.

use crate::util::vecmath;

pub struct Server {
    /// Global flat weights w^t.
    pub w: Vec<f32>,
    pub round: usize,
}

impl Server {
    pub fn new(w0: Vec<f32>) -> Server {
        Server { w: w0, round: 0 }
    }

    /// Aggregate reconstructed gradients with the given weights (the paper's
    /// G: weighted average, Σ weights normalized to 1) and step the model:
    /// `w ← w − Σ_i λ_i ĝ_i`.
    pub fn apply_round(&mut self, recons: &[Vec<f32>], weights: &[f32]) {
        assert_eq!(recons.len(), weights.len());
        assert!(!recons.is_empty());
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        assert!(total > 0.0);
        let mut agg = vec![0.0f32; self.w.len()];
        for (g, &wt) in recons.iter().zip(weights.iter()) {
            vecmath::weighted_add(&mut agg, g, (wt as f64 / total) as f32);
        }
        vecmath::axpy(-1.0, &agg, &mut self.w);
        self.round += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_average_step() {
        let mut s = Server::new(vec![1.0, 1.0]);
        let g1 = vec![1.0f32, 0.0];
        let g2 = vec![0.0f32, 2.0];
        s.apply_round(&[g1, g2], &[3.0, 1.0]);
        // agg = 0.75*[1,0] + 0.25*[0,2] = [0.75, 0.5]
        assert!((s.w[0] - 0.25).abs() < 1e-6);
        assert!((s.w[1] - 0.5).abs() < 1e-6);
        assert_eq!(s.round, 1);
    }
}
