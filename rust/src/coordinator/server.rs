//! The FL server: holds the global model, aggregates the reconstructed
//! client updates (Eq. 3/6), and delegates the global step to a pluggable
//! [`ServerOptimizer`] (GD / momentum / FedAdam — see
//! [`crate::coordinator::opt`]).

use crate::coordinator::opt::{ServerGd, ServerOptimizer};
use crate::util::vecmath;

pub struct Server {
    /// Global flat weights w^t.
    pub w: Vec<f32>,
    pub round: usize,
    opt: Box<dyn ServerOptimizer>,
}

impl Server {
    /// Paper-faithful server: plain GD with a unit step (Eq. 3).
    pub fn new(w0: Vec<f32>) -> Server {
        Server::with_optimizer(w0, Box::new(ServerGd { lr: 1.0 }))
    }

    pub fn with_optimizer(w0: Vec<f32>, opt: Box<dyn ServerOptimizer>) -> Server {
        Server { w: w0, round: 0, opt }
    }

    pub fn optimizer_name(&self) -> &'static str {
        self.opt.name()
    }

    /// Aggregate reconstructed gradients with the given weights (the
    /// paper's G: weighted average, Σ weights normalized to 1 — over the
    /// *selected* clients only under partial participation) and hand the
    /// result to the server optimizer for the global step.
    ///
    /// An empty or all-zero-weight cohort (possible when a best-effort
    /// partition leaves selected clients without data) is a no-op round:
    /// the weights stay put but the round counter still advances so
    /// schedules and metrics move on.
    pub fn apply_round(&mut self, recons: &[Vec<f32>], weights: &[f32]) {
        assert_eq!(recons.len(), weights.len());
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        if recons.is_empty() || total <= 0.0 {
            self.round += 1;
            return;
        }
        let mut agg = vec![0.0f32; self.w.len()];
        for (g, &wt) in recons.iter().zip(weights.iter()) {
            vecmath::weighted_add(&mut agg, g, (wt as f64 / total) as f32);
        }
        self.opt.step(&mut self.w, &agg);
        self.round += 1;
    }

    /// Apply a pre-aggregated update (the output of a
    /// [`crate::coordinator::robust::RobustAggregator`]). `None` is the
    /// no-op round: weights stay put, the round counter advances —
    /// exactly [`Server::apply_round`]'s empty-cohort path.
    pub fn apply_update(&mut self, agg: Option<&[f32]>) {
        if let Some(agg) = agg {
            self.opt.step(&mut self.w, agg);
        }
        self.round += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::opt::ServerMomentum;

    #[test]
    fn weighted_average_step() {
        let mut s = Server::new(vec![1.0, 1.0]);
        let g1 = vec![1.0f32, 0.0];
        let g2 = vec![0.0f32, 2.0];
        s.apply_round(&[g1, g2], &[3.0, 1.0]);
        // agg = 0.75*[1,0] + 0.25*[0,2] = [0.75, 0.5]
        assert!((s.w[0] - 0.25).abs() < 1e-6);
        assert!((s.w[1] - 0.5).abs() < 1e-6);
        assert_eq!(s.round, 1);
    }

    #[test]
    fn custom_optimizer_is_used() {
        // Momentum at β=0.5 with two identical rounds: second step = 1.5×.
        let mut s =
            Server::with_optimizer(vec![0.0f32], Box::new(ServerMomentum::new(1.0, 0.5)));
        s.apply_round(&[vec![1.0f32]], &[1.0]);
        assert!((s.w[0] + 1.0).abs() < 1e-6);
        s.apply_round(&[vec![1.0f32]], &[1.0]);
        assert!((s.w[0] + 2.5).abs() < 1e-6);
        assert_eq!(s.optimizer_name(), "momentum");
    }

    #[test]
    fn empty_cohort_is_a_noop_round() {
        let mut s = Server::new(vec![1.5f32, -2.0]);
        s.apply_round(&[], &[]);
        assert_eq!(s.w, vec![1.5, -2.0]);
        assert_eq!(s.round, 1);
        // All-zero weights likewise must not divide by zero.
        s.apply_round(&[vec![1.0f32, 1.0]], &[0.0]);
        assert_eq!(s.w, vec![1.5, -2.0]);
        assert_eq!(s.round, 2);
    }

    #[test]
    fn normalization_is_over_provided_clients_only() {
        // A subset of two (of what could be many) clients must average to
        // 1 over that subset — partial-participation semantics.
        let mut s = Server::new(vec![0.0f32]);
        s.apply_round(&[vec![2.0f32], vec![4.0f32]], &[1.0, 1.0]);
        assert!((s.w[0] + 3.0).abs() < 1e-6);
    }
}
