//! Aggregation policies: *when* the event-driven server turns buffered
//! uploads into a global step.
//!
//! The [`crate::coordinator::FedServer`] processes arrivals off the
//! simnet virtual clock and consults an [`AggregationPolicy`] at each
//! trigger; the policy decides whether to aggregate now, whether the
//! uploading client is immediately re-dispatched (asynchrony), and how
//! staleness discounts aggregation weights. Three implementations cover
//! the scenario matrix ([`crate::config::SessionKind`]):
//!
//! * [`Synchronous`] — barrier on the selected cohort; reproduces the
//!   classic synchronous round loop bit-for-bit (staleness is always 0
//!   and the weight multiplier exactly 1).
//! * [`Deadline`] — semi-sync: aggregate whatever arrived within
//!   `deadline_s` virtual seconds of the broadcast; stragglers' uploads
//!   stay queued and join a later aggregation with a staleness discount.
//! * [`BufferedAsync`] — FedBuff-style: aggregate every `buffer_k`
//!   arrivals; each finished client is instantly re-dispatched on the
//!   current model, so staleness accrues naturally.
//!
//! Staleness weighting: an update whose broadcast round is `s` server
//! steps behind the aggregation is weighted `|D_i| · γ^s` with
//! `γ = staleness_decay ∈ (0, 1]` (γ = 1 disables the discount;
//! `γ^0 = 1` exactly, which is what keeps [`Synchronous`] bit-faithful).
//!
//! Policies are downlink-agnostic: they only decide *when* a step
//! happens, never what a broadcast carries, so every policy composes
//! with any [`crate::compress::DownlinkTx`]. The one interaction worth
//! knowing: [`Deadline`] carry-over and [`BufferedAsync`] re-dispatch
//! mean a client can be sent several versions while holding an older
//! one — exactly the gap the downlink ledger's keyframe fallback
//! (`[downlink] gap`) resynchronizes.
//!
//! Policies are also content-agnostic: *what* the aggregate is — plain
//! weighted mean or a byzantine-robust estimator — is the
//! [`crate::coordinator::RobustAggregator`] seam downstream of every
//! trigger. The staleness multiplier folds into the per-client weight
//! *before* the estimator runs, so a robust aggregate discounts stale
//! contributions exactly as the historical weighted mean did.
//!
//! Scale note (`[scale]`, [`crate::coordinator::shard`]): no policy
//! ever sees the full fleet — triggers consume the buffered-upload
//! count and the pending batch, both `O(cohort)`. With `lazy_state`
//! the streaming cohort path keeps only the dispatched clients' dense
//! state plus one exact partial-sum per live shard resident, so a
//! policy's memory footprint is bounded by its *own* barrier/buffer
//! size even at `n_clients ~ 10⁶`.

use crate::config::{ExperimentConfig, SessionKind};

/// What just happened on the virtual clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggTrigger {
    /// An upload landed at the server (already counted in
    /// [`PolicyCtx::pending`]).
    Upload,
    /// The per-cycle deadline timer fired.
    DeadlineExpired,
    /// The event queue drained with uploads still buffered (e.g. the
    /// experiment's last partial buffer) — flush semantics.
    Drained,
}

/// Server state snapshot handed to the policy at each trigger.
#[derive(Clone, Copy, Debug)]
pub struct PolicyCtx {
    /// Uploads buffered and not yet aggregated.
    pub pending: usize,
    /// Broadcasts dispatched whose uploads have not yet arrived.
    pub in_flight: usize,
    /// Size of the most recent dispatch cohort.
    pub cohort: usize,
}

/// Decides when buffered uploads become a global step.
pub trait AggregationPolicy {
    fn name(&self) -> &'static str;

    /// Should the server aggregate the pending buffer now?
    fn ready(&self, trigger: AggTrigger, ctx: &PolicyCtx) -> bool;

    /// Virtual seconds after each broadcast at which the server stops
    /// waiting (`None` = no timer; barrier / arrival-count policies).
    fn deadline_s(&self) -> Option<f64> {
        None
    }

    /// Server-paced sessions begin a fresh broadcast cycle after every
    /// aggregation step (sync / deadline). Async sessions instead keep
    /// clients perpetually in flight via [`Self::redispatch`].
    fn server_paced(&self) -> bool {
        true
    }

    /// Re-dispatch a client on the current model the moment its upload
    /// arrives (after any aggregation that arrival triggered).
    fn redispatch(&self) -> bool {
        false
    }

    /// Aggregate in ascending-client (selection) order rather than
    /// arrival order. Only meaningful when every buffered upload is from
    /// the same cycle — the synchronous bit-identity contract.
    fn selection_order(&self) -> bool {
        false
    }

    /// Aggregation-weight multiplier for an update `staleness` model
    /// versions old.
    fn staleness_weight(&self, _staleness: usize) -> f64 {
        1.0
    }

    /// Whether the session survives an upload that legitimately never
    /// arrives (the fault layer declared the client dead mid-transfer).
    /// Barrier policies cannot — their cohort would block forever — so
    /// the server turns the loss into a diagnostic error instead of
    /// starving ([`crate::coordinator::protocol::UploadError::LossUnderBarrier`]).
    fn tolerates_loss(&self) -> bool {
        false
    }
}

/// Saturating `γ^s` for staleness discounting: `usize` staleness values
/// beyond `i32::MAX` clamp instead of wrapping — a (byzantine or buggy)
/// huge staleness must *discount toward zero*, never wrap negative and
/// inflate the weight (`powi` of a negative exponent is `1/γ^|s|`).
pub fn decay_pow(decay: f64, staleness: usize) -> f64 {
    decay.powi(i32::try_from(staleness).unwrap_or(i32::MAX))
}

/// Barrier on the selected cohort (the paper's protocol; default).
pub struct Synchronous;

impl AggregationPolicy for Synchronous {
    fn name(&self) -> &'static str {
        "sync"
    }

    fn ready(&self, trigger: AggTrigger, ctx: &PolicyCtx) -> bool {
        match trigger {
            AggTrigger::Upload => ctx.in_flight == 0,
            // A cycle whose cohort was entirely zero-sample clients has
            // nothing to wait for: flush (possibly empty) immediately.
            AggTrigger::Drained | AggTrigger::DeadlineExpired => true,
        }
    }

    fn selection_order(&self) -> bool {
        true
    }
}

/// Semi-synchronous: a per-cycle deadline bounds the wait.
pub struct Deadline {
    deadline_s: f64,
    decay: f64,
}

impl Deadline {
    pub fn new(deadline_s: f64, decay: f64) -> Deadline {
        assert!(deadline_s > 0.0, "deadline must be positive");
        assert!(decay > 0.0 && decay <= 1.0, "decay must be in (0, 1]");
        Deadline { deadline_s, decay }
    }
}

impl AggregationPolicy for Deadline {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn ready(&self, trigger: AggTrigger, ctx: &PolicyCtx) -> bool {
        match trigger {
            // Uploads wait for the timer (an upload landing exactly at
            // the deadline is included: the timer event sorts after
            // same-instant uploads — see `SimClock::NO_CLIENT`).
            AggTrigger::Upload => false,
            AggTrigger::DeadlineExpired => true,
            AggTrigger::Drained => ctx.pending > 0,
        }
    }

    fn deadline_s(&self) -> Option<f64> {
        Some(self.deadline_s)
    }

    fn staleness_weight(&self, staleness: usize) -> f64 {
        decay_pow(self.decay, staleness)
    }

    fn tolerates_loss(&self) -> bool {
        true
    }
}

/// FedBuff-style buffered asynchrony: aggregate every K arrivals.
///
/// Not server-paced: the scheduler is consulted once, when the session
/// starts, and that cohort becomes the *fixed* in-flight set — each
/// finisher is re-dispatched immediately (FedBuff's "M concurrent
/// clients" model). Under a partial-participation schedule this caps
/// concurrency at the initial cohort; clients outside it never
/// participate (pinned by `tests/session_test.rs`).
pub struct BufferedAsync {
    k: usize,
    decay: f64,
}

impl BufferedAsync {
    pub fn new(k: usize, decay: f64) -> BufferedAsync {
        assert!(k >= 1, "buffer_k must be >= 1");
        assert!(decay > 0.0 && decay <= 1.0, "decay must be in (0, 1]");
        BufferedAsync { k, decay }
    }
}

impl AggregationPolicy for BufferedAsync {
    fn name(&self) -> &'static str {
        "async"
    }

    fn ready(&self, trigger: AggTrigger, ctx: &PolicyCtx) -> bool {
        match trigger {
            AggTrigger::Upload => ctx.pending >= self.k,
            AggTrigger::DeadlineExpired => false,
            AggTrigger::Drained => ctx.pending > 0,
        }
    }

    fn server_paced(&self) -> bool {
        false
    }

    fn redispatch(&self) -> bool {
        true
    }

    fn staleness_weight(&self, staleness: usize) -> f64 {
        decay_pow(self.decay, staleness)
    }

    fn tolerates_loss(&self) -> bool {
        true
    }
}

/// Build the policy an [`ExperimentConfig`]'s `[session]` table asks for.
pub fn build_policy(cfg: &ExperimentConfig) -> Box<dyn AggregationPolicy> {
    match cfg.session {
        SessionKind::Sync => Box::new(Synchronous),
        SessionKind::Deadline => {
            Box::new(Deadline::new(cfg.deadline_s, cfg.staleness_decay))
        }
        SessionKind::Async => {
            Box::new(BufferedAsync::new(cfg.buffer_k, cfg.staleness_decay))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(pending: usize, in_flight: usize, cohort: usize) -> PolicyCtx {
        PolicyCtx { pending, in_flight, cohort }
    }

    #[test]
    fn synchronous_waits_for_the_whole_cohort() {
        let p = Synchronous;
        assert!(!p.ready(AggTrigger::Upload, &ctx(1, 3, 4)));
        assert!(!p.ready(AggTrigger::Upload, &ctx(3, 1, 4)));
        assert!(p.ready(AggTrigger::Upload, &ctx(4, 0, 4)));
        assert!(p.selection_order());
        assert!(p.server_paced());
        assert!(!p.redispatch());
        assert_eq!(p.deadline_s(), None);
        // Sync never discounts — the bit-identity contract.
        for s in 0..5 {
            assert_eq!(p.staleness_weight(s).to_bits(), 1.0f64.to_bits());
        }
    }

    #[test]
    fn deadline_aggregates_on_timer_not_arrivals() {
        let p = Deadline::new(0.25, 0.5);
        assert!(!p.ready(AggTrigger::Upload, &ctx(4, 0, 4)));
        assert!(p.ready(AggTrigger::DeadlineExpired, &ctx(2, 2, 4)));
        assert!(p.ready(AggTrigger::DeadlineExpired, &ctx(0, 4, 4)));
        assert_eq!(p.deadline_s(), Some(0.25));
        assert!(p.server_paced());
        assert!(!p.selection_order());
    }

    #[test]
    fn buffered_async_steps_every_k_and_redispatches() {
        let p = BufferedAsync::new(3, 0.5);
        assert!(!p.ready(AggTrigger::Upload, &ctx(2, 5, 8)));
        assert!(p.ready(AggTrigger::Upload, &ctx(3, 5, 8)));
        assert!(p.ready(AggTrigger::Upload, &ctx(4, 5, 8)));
        assert!(p.redispatch());
        assert!(!p.server_paced());
        assert!(p.ready(AggTrigger::Drained, &ctx(1, 0, 8)));
        assert!(!p.ready(AggTrigger::Drained, &ctx(0, 0, 8)));
    }

    #[test]
    fn staleness_weights_decay_geometrically() {
        let p = BufferedAsync::new(2, 0.5);
        assert_eq!(p.staleness_weight(0).to_bits(), 1.0f64.to_bits());
        assert!((p.staleness_weight(1) - 0.5).abs() < 1e-15);
        assert!((p.staleness_weight(3) - 0.125).abs() < 1e-15);
        // γ = 1 disables the discount entirely.
        let flat = Deadline::new(1.0, 1.0);
        assert_eq!(flat.staleness_weight(7).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn huge_staleness_saturates_instead_of_inflating() {
        // Before the saturating exponent, `staleness as i32` wrapped
        // negative for values past i32::MAX and `γ^(-s) = 1/γ^s` *blew
        // the weight up* instead of discounting it. Pin the fix: a
        // byzantine-huge staleness discounts to (essentially) zero.
        let p = Deadline::new(1.0, 0.5);
        let w = p.staleness_weight(usize::MAX);
        assert!((0.0..1.0).contains(&w), "weight {w} must stay in [0, 1)");
        let q = BufferedAsync::new(2, 0.9);
        let w = q.staleness_weight((i32::MAX as usize) + 1);
        assert!((0.0..1.0).contains(&w), "weight {w} must stay in [0, 1)");
        // And the saturation point itself behaves.
        assert_eq!(decay_pow(0.5, 0).to_bits(), 1.0f64.to_bits());
        assert!(decay_pow(0.5, i32::MAX as usize) < 1e-300);
    }

    #[test]
    fn loss_tolerance_matches_policy_semantics() {
        assert!(!Synchronous.tolerates_loss());
        assert!(Deadline::new(0.5, 0.5).tolerates_loss());
        assert!(BufferedAsync::new(2, 0.5).tolerates_loss());
    }

    #[test]
    fn build_policy_matches_session_kind() {
        let mut cfg = ExperimentConfig::default();
        assert_eq!(build_policy(&cfg).name(), "sync");
        cfg.session = SessionKind::Deadline;
        assert_eq!(build_policy(&cfg).name(), "deadline");
        cfg.session = SessionKind::Async;
        cfg.buffer_k = 4;
        assert_eq!(build_policy(&cfg).name(), "async");
    }
}
