//! Parallel client execution: a fixed worker pool that fans the selected
//! cohort's train-and-compress work out over threads, deterministically.
//!
//! Backends are not `Send` (the PJRT client can never cross a thread
//! boundary). Instead each worker thread *owns* a full stack — its own
//! [`Backend`] opened from the experiment's [`BackendSpec`] (for PJRT,
//! its own client + compiled-executable cache; for the native backend a
//! free in-memory construction), a [`FedOps`] facade, and a compressor
//! instance built from the same config — and client work items travel to
//! it as plain `Send` data:
//!
//! * a [`ClientJob`] carries everything one client contributes to a round
//!   — the pre-sampled local batches, the error-feedback memory, the
//!   client RNG stream, and a `slot` index (the client's position in the
//!   round's selection order);
//! * [`run_client`] is the *single* per-client routine — local training
//!   (Algorithm 1 lines 3–5), EF correction (Eq. 6), encode, EF update —
//!   used verbatim by both the sequential (`threads = 1`) path and the
//!   pool workers, so the math cannot drift between the two;
//! * a [`ClientUpdate`] carries the results back, and the experiment
//!   drains them into slots indexed by selection order before doing any
//!   accounting. Per-client computations are independent (each owns its
//!   RNG/EF state; the compressor is `&self`-concurrent), so trajectories
//!   are **bit-identical for every thread count**.
//!
//! Work distribution is a shared queue (`Mutex<Receiver>`), so stragglers
//! (3SFC's S-step encoder dominates, Eq. 9) never idle the other workers.
//!
//! Because a [`ClientJob`] already carries *owned* EF memory and RNG
//! (snapshots moved in, results moved back out), the pool is oblivious
//! to where that state lives between rounds — the lazy
//! [`crate::coordinator::ClientStore`] materializes it just before job
//! construction and spills it right after the update lands, with no
//! change to the worker protocol.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::compress::{self, Compressor, EncodeCtx, Payload};
use crate::config::ExperimentConfig;
use crate::runtime::{Backend, BackendSpec, FedOps, RuntimeStats};
use crate::util::rng::Rng;
use crate::util::vecmath;

/// Everything one selected client needs computed this round, as owned
/// `Send` data (the client's `ClientState` itself stays on the main
/// thread; batches are pre-sampled there so data-loader order is
/// identical for every thread count).
pub struct ClientJob {
    /// Position in this round's selection order — results land back in
    /// slot order, making aggregation order-independent of scheduling.
    pub slot: usize,
    /// Pre-sampled local batches, shapes [K·B·d] / [K·B].
    pub xs: Vec<f32>,
    pub ys: Vec<i32>,
    /// Error-feedback memory e_i^t (empty when EF is disabled).
    pub ef: Vec<f32>,
    /// The client's private RNG stream (returned advanced).
    pub rng: Rng,
    /// Aggregation weight |D_i|.
    pub weight: f32,
}

/// One client's round outcome, in wire/aggregation order fields.
pub struct ClientUpdate {
    pub slot: usize,
    /// The wire payload itself (`payload.wire_bytes()` is what the
    /// uplink is priced at; the upload envelope carries it to the
    /// server).
    pub payload: Payload,
    /// Reconstructed (decoded) update the server aggregates.
    pub recon: Vec<f32>,
    /// Updated EF memory (empty when EF is disabled).
    pub ef: Vec<f32>,
    /// The advanced RNG stream, to write back into the client.
    pub rng: Rng,
    pub weight: f32,
    /// Compression ratio (× vs dense) of this payload.
    pub ratio: f64,
    /// cos(ĝ, g+e) — the paper's compression-efficiency metric (Fig 7).
    pub efficiency: f64,
}

/// Train + compress one client. This is the entire per-client body of the
/// round loop; the sequential path and every pool worker call exactly
/// this function, which is what makes `threads = N` bit-identical to
/// `threads = 1`.
pub fn run_client(
    ops: &FedOps,
    comp: &dyn Compressor,
    cfg: &ExperimentConfig,
    w_global: &[f32],
    mut job: ClientJob,
) -> Result<ClientUpdate> {
    // 1. Local training (Algorithm 1, lines 3-5).
    let w_local = ops.local_train(cfg.k_local, w_global, &job.xs, &job.ys, cfg.lr)?;
    let g = vecmath::sub(w_global, &w_local);

    // 2. Error-feedback target (Eq. 6).
    let mut target = g;
    if cfg.error_feedback {
        vecmath::add_assign(&mut target, &job.ef);
    }

    // 3. Compress.
    let mut ctx = EncodeCtx { ops, w_global, rng: &mut job.rng };
    let (payload, recon, _stats) = comp.encode(&mut ctx, &target)?;

    // 4. EF update: e ← target − ĝ.
    let ef = if cfg.error_feedback {
        vecmath::sub(&target, &recon)
    } else {
        job.ef
    };

    Ok(ClientUpdate {
        slot: job.slot,
        efficiency: vecmath::cosine(&recon, &target),
        ratio: payload.ratio(ops.model.params),
        weight: job.weight,
        ef,
        rng: job.rng,
        recon,
        payload,
    })
}

enum Job {
    Client { w_global: Arc<Vec<f32>>, job: ClientJob },
}

/// Fixed pool of worker threads, each owning an independent
/// backend/compressor stack. Construction blocks until every worker has
/// opened its backend (so artifact problems surface immediately);
/// dropping the pool shuts the workers down and joins them.
pub struct WorkerPool {
    job_tx: Option<Sender<Job>>,
    res_rx: Receiver<Result<ClientUpdate>>,
    handles: Vec<JoinHandle<()>>,
    stats: Arc<Mutex<RuntimeStats>>,
    workers: usize,
}

impl WorkerPool {
    pub fn new(spec: BackendSpec, cfg: &ExperimentConfig, threads: usize) -> Result<WorkerPool> {
        let workers = threads.max(1);
        let (job_tx, job_rx) = channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = channel();
        let (ready_tx, ready_rx) = channel();
        let stats = Arc::new(Mutex::new(RuntimeStats::default()));
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let spec = spec.clone();
            let cfg = cfg.clone();
            let job_rx = Arc::clone(&job_rx);
            let res_tx = res_tx.clone();
            let ready_tx = ready_tx.clone();
            let stats = Arc::clone(&stats);
            let handle = std::thread::Builder::new()
                .name(format!("fed3sfc-worker-{i}"))
                .spawn(move || worker_main(spec, cfg, job_rx, res_tx, ready_tx, stats))
                .context("spawning worker thread")?;
            handles.push(handle);
        }
        drop(ready_tx);
        let mut startup: Result<()> = Ok(());
        for _ in 0..workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    startup = Err(e.context("starting worker runtime"));
                }
                Err(_) => {
                    if startup.is_ok() {
                        startup = Err(anyhow!("worker exited before reporting ready"));
                    }
                }
            }
        }
        let mut pool = WorkerPool { job_tx: Some(job_tx), res_rx, handles, stats, workers };
        if let Err(e) = startup {
            pool.shutdown();
            return Err(e);
        }
        Ok(pool)
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Aggregated runtime counters across all workers.
    pub fn stats(&self) -> RuntimeStats {
        *self.stats.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Execute one round's client jobs on the pool, each against its own
    /// weight snapshot (with a compressed downlink the cohort's
    /// reconstructions differ per client; dense keyframes share one Arc,
    /// so this costs nothing in the classic path). Returns the updates
    /// sorted by `slot` (selection order); fails if any client failed.
    pub fn run_clients(
        &self,
        jobs: Vec<(Arc<Vec<f32>>, ClientJob)>,
    ) -> Result<Vec<ClientUpdate>> {
        let n = jobs.len();
        let tx = self.job_tx.as_ref().expect("pool is alive");
        for (w_global, job) in jobs {
            tx.send(Job::Client { w_global, job })
                .map_err(|_| anyhow!("worker pool has shut down"))?;
        }
        let mut slots: Vec<Option<ClientUpdate>> = (0..n).map(|_| None).collect();
        let mut first_err: Option<anyhow::Error> = None;
        for _ in 0..n {
            match self.res_rx.recv() {
                Ok(Ok(u)) => {
                    let slot = u.slot;
                    anyhow::ensure!(
                        slot < n && slots[slot].is_none(),
                        "worker returned bad slot {slot}"
                    );
                    slots[slot] = Some(u);
                }
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow!("all workers died mid-round"));
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        slots
            .into_iter()
            .map(|s| s.ok_or_else(|| anyhow!("missing client result")))
            .collect()
    }

    fn shutdown(&mut self) {
        // Closing the job channel makes every worker's recv fail → exit.
        self.job_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_main(
    spec: BackendSpec,
    cfg: ExperimentConfig,
    job_rx: Arc<Mutex<Receiver<Job>>>,
    res_tx: Sender<Result<ClientUpdate>>,
    ready_tx: Sender<Result<()>>,
    pool_stats: Arc<Mutex<RuntimeStats>>,
) {
    // Own the full stack locally — backends never cross threads.
    let setup = (|| -> Result<(Box<dyn Backend>, Box<dyn Compressor>)> {
        let backend = spec.open()?;
        let model = backend.manifest().model(cfg.model_key())?;
        let comp = compress::build(&cfg, model);
        Ok((backend, comp))
    })();
    let (backend, comp) = match setup {
        Ok(ok) => {
            let _ = ready_tx.send(Ok(()));
            ok
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };
    let ops = match FedOps::new(backend.as_ref(), cfg.model_key()) {
        Ok(ops) => ops,
        // model_key was validated during setup; this cannot fail now.
        Err(_) => return,
    };
    drop(ready_tx);

    let mut reported = RuntimeStats::default();
    loop {
        // Standard shared-queue pattern: the guard is a temporary, so the
        // lock is released as soon as `recv` hands us a job.
        let job = job_rx.lock().unwrap_or_else(|p| p.into_inner()).recv();
        let Ok(Job::Client { w_global, job }) = job else {
            break; // channel closed: pool dropped
        };
        // A panicking job (e.g. an assert deep in a compressor) must not
        // deadlock the round — convert it into an error result.
        let out = catch_unwind(AssertUnwindSafe(|| {
            run_client(&ops, comp.as_ref(), &cfg, &w_global, job)
        }))
        .unwrap_or_else(|panic| {
            let msg = panic
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| panic.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "worker panicked".into());
            Err(anyhow!("client job panicked: {msg}"))
        });
        // Publish this worker's backend-counter delta.
        let now = backend.stats();
        let delta = now.delta(&reported);
        reported = now;
        if let Ok(mut agg) = pool_stats.lock() {
            agg.merge(&delta);
        }
        if res_tx.send(out).is_err() {
            break; // pool gone
        }
    }
}
