//! Byzantine-robust aggregation over decoded client recons.
//!
//! PR 8 hardened the upload *envelope*: malformed messages are rejected
//! at `submit_upload` with typed errors. A well-formed, plausible-but-
//! poisoned recon still sailed straight into the weighted mean. A
//! [`RobustAggregator`] closes that gap: it sits between the batch an
//! [`crate::coordinator::AggregationPolicy`] collected and the server
//! optimizer step, replacing the plain weighted mean with an estimator
//! that bounds the influence of any `f` compromised contributors
//! (Blanchard et al., "Machine Learning with Adversaries"; Yin et al.,
//! "Byzantine-Robust Distributed Learning"; Sattler et al.,
//! arXiv 1903.02891 for the FL + compression co-design argument).
//!
//! Determinism contract (the repo's core invariant):
//!
//! * [`WeightedMean`] is **bit-identical** to the pre-defense path: the
//!   same `f64` weight total, the same `weighted_add` accumulation in
//!   the same batch order as [`crate::coordinator::Server::apply_round`].
//! * Every robust estimator first sorts the batch by **client index**
//!   (ties by batch position), so its output is invariant under upload
//!   arrival order — deadline/async sessions aggregate in arrival order,
//!   and the estimator must not inherit that nondeterminism. Score and
//!   value ties everywhere break toward the **lowest client index**.
//! * Staleness-discounted weights are folded in wherever the estimator
//!   admits weights: the mean family weights survivors, the median is a
//!   weighted median, Krum uses geometry only for *selection* and the
//!   weights for the final combination.
//!
//! The aggregate handed back is the normalized convex combination the
//! server optimizer expects (`None` = no survivor, no-op round).

use crate::config::{AggregatorKind, ExperimentConfig};
use crate::util::vecmath;

/// Outcome of one robust aggregation step.
pub struct AggOutcome {
    /// Normalized aggregate for the optimizer; `None` when nothing
    /// survived (empty batch or zero surviving weight) — the round
    /// counter still advances, the weights stay put.
    pub update: Option<Vec<f32>>,
    /// Clients whose contribution was discarded *wholesale* this step
    /// (Krum/Multi-Krum non-selection), ascending client index.
    /// Coordinate-wise estimators trim per coordinate and report mass
    /// through `trim_frac` instead.
    pub rejected: Vec<usize>,
    /// Fraction of the batch's contribution mass trimmed, clipped or
    /// rejected: `2k/n` for the β-trimmed mean, `(n−1)/n` for the
    /// median, `rejected/n` for Krum, `clipped/n` for norm-clipping,
    /// `0` for the plain mean.
    pub trim_frac: f64,
}

impl AggOutcome {
    fn empty() -> AggOutcome {
        AggOutcome { update: None, rejected: Vec::new(), trim_frac: 0.0 }
    }
}

/// A robust estimator over one aggregation batch.
///
/// `clients[i]` / `recons[i]` / `weights[i]` describe upload `i` in the
/// order the policy collected the batch (sync pre-sorts by client,
/// deadline/async are arrival-ordered). Implementations must be pure
/// functions of the batch (no RNG, no wall clock — detlint-enforced)
/// and deterministic under batch permutation, except [`WeightedMean`]
/// which deliberately preserves batch order to stay bit-identical to
/// the historical path.
pub trait RobustAggregator: Send {
    /// Short name for logs/labels.
    fn name(&self) -> &'static str;

    fn aggregate(
        &self,
        clients: &[usize],
        recons: &[Vec<f32>],
        weights: &[f32],
        n_params: usize,
    ) -> AggOutcome;
}

/// Batch positions sorted by (client index, batch position) — the
/// canonical order every robust estimator works in.
fn client_order(clients: &[usize]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..clients.len()).collect();
    idx.sort_by_key(|&i| (clients[i], i));
    idx
}

/// Normalized weighted mean over the batch positions in `idx`, in `idx`
/// order. With `idx = 0..n` this is arithmetic-identical (same op
/// sequence, bit for bit) to [`crate::coordinator::Server::apply_round`].
fn weighted_mean_of(
    idx: &[usize],
    recons: &[Vec<f32>],
    weights: &[f32],
    n_params: usize,
) -> Option<Vec<f32>> {
    let total: f64 = idx.iter().map(|&i| weights[i] as f64).sum();
    if idx.is_empty() || total <= 0.0 {
        return None;
    }
    let mut agg = vec![0.0f32; n_params];
    for &i in idx {
        vecmath::weighted_add(&mut agg, &recons[i], (weights[i] as f64 / total) as f32);
    }
    Some(agg)
}

fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = *x as f64 - *y as f64;
        s += d * d;
    }
    s
}

// ---------------------------------------------------------------------
// WeightedMean — today's path, bit-identical.

/// The pre-defense aggregate: normalized weighted mean in batch order.
pub struct WeightedMean;

impl RobustAggregator for WeightedMean {
    fn name(&self) -> &'static str {
        "weighted_mean"
    }

    fn aggregate(
        &self,
        clients: &[usize],
        recons: &[Vec<f32>],
        weights: &[f32],
        n_params: usize,
    ) -> AggOutcome {
        let idx: Vec<usize> = (0..clients.len()).collect();
        AggOutcome {
            update: weighted_mean_of(&idx, recons, weights, n_params),
            rejected: Vec::new(),
            trim_frac: 0.0,
        }
    }
}

// ---------------------------------------------------------------------
// TrimmedMean — coordinate-wise β-trim (Yin et al.).

/// Coordinate-wise trimmed mean: per coordinate, drop the `⌊β·n⌋`
/// smallest and largest values (value ties broken by client index) and
/// take the weighted mean of the survivors. `β = 0` degenerates to the
/// weighted mean over the client-sorted batch, bit for bit.
pub struct TrimmedMean {
    /// Trim fraction per tail, `0 ≤ β < 0.5`.
    pub beta: f64,
}

impl RobustAggregator for TrimmedMean {
    fn name(&self) -> &'static str {
        "trimmed_mean"
    }

    fn aggregate(
        &self,
        clients: &[usize],
        recons: &[Vec<f32>],
        weights: &[f32],
        n_params: usize,
    ) -> AggOutcome {
        let n = clients.len();
        if n == 0 {
            return AggOutcome::empty();
        }
        let order = client_order(clients);
        let k = ((self.beta * n as f64).floor() as usize).min((n - 1) / 2);
        if k == 0 {
            return AggOutcome {
                update: weighted_mean_of(&order, recons, weights, n_params),
                rejected: Vec::new(),
                trim_frac: 0.0,
            };
        }
        let mut agg = vec![0.0f32; n_params];
        let mut any = false;
        let mut pairs: Vec<(f32, usize)> = Vec::with_capacity(n);
        let mut survivors: Vec<usize> = Vec::with_capacity(n - 2 * k);
        for j in 0..n_params {
            pairs.clear();
            for (rank, &i) in order.iter().enumerate() {
                pairs.push((recons[i][j], rank));
            }
            pairs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            survivors.clear();
            survivors.extend(pairs[k..n - k].iter().map(|p| p.1));
            // Accumulate survivors in client order so the result is a
            // pure function of the (client → value) map.
            survivors.sort_unstable();
            let total: f64 = survivors.iter().map(|&r| weights[order[r]] as f64).sum();
            if total <= 0.0 {
                continue;
            }
            any = true;
            for &r in &survivors {
                let i = order[r];
                agg[j] += (weights[i] as f64 / total) as f32 * recons[i][j];
            }
        }
        AggOutcome {
            update: if any { Some(agg) } else { None },
            rejected: Vec::new(),
            trim_frac: (2 * k) as f64 / n as f64,
        }
    }
}

// ---------------------------------------------------------------------
// CoordinateMedian — coordinate-wise weighted median.

/// Coordinate-wise weighted median: per coordinate, the smallest value
/// whose cumulative weight reaches half the total (value ties broken by
/// client index). The 50%-breakdown member of the family.
pub struct CoordinateMedian;

impl RobustAggregator for CoordinateMedian {
    fn name(&self) -> &'static str {
        "coordinate_median"
    }

    fn aggregate(
        &self,
        clients: &[usize],
        recons: &[Vec<f32>],
        weights: &[f32],
        n_params: usize,
    ) -> AggOutcome {
        let n = clients.len();
        if n == 0 {
            return AggOutcome::empty();
        }
        let order = client_order(clients);
        let total: f64 = order.iter().map(|&i| weights[i] as f64).sum();
        if total <= 0.0 {
            return AggOutcome::empty();
        }
        let half = total / 2.0;
        let mut agg = vec![0.0f32; n_params];
        let mut pairs: Vec<(f32, usize)> = Vec::with_capacity(n);
        for (j, slot) in agg.iter_mut().enumerate() {
            pairs.clear();
            for (rank, &i) in order.iter().enumerate() {
                pairs.push((recons[i][j], rank));
            }
            pairs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let mut cum = 0.0f64;
            for &(v, rank) in &pairs {
                cum += weights[order[rank]] as f64;
                if cum >= half {
                    *slot = v;
                    break;
                }
            }
        }
        AggOutcome {
            update: Some(agg),
            rejected: Vec::new(),
            trim_frac: (n - 1) as f64 / n as f64,
        }
    }
}

// ---------------------------------------------------------------------
// Krum / Multi-Krum (Blanchard et al.).

/// Multi-Krum selection: score each candidate by the sum of its
/// `n − f − 2` smallest squared distances to the others, keep the `m`
/// best-scored (score ties broken by client index), weighted-mean the
/// survivors. `m = 1` is classic Krum (the name reflects it); `m = 0`
/// auto-sizes to `n − f`, which at `f = 0` keeps everyone and
/// degenerates to the weighted mean over the client-sorted batch.
pub struct MultiKrum {
    /// Assumed number of byzantine contributors.
    pub f: usize,
    /// Selection size; `0` = auto (`n − f`, at least 1).
    pub m: usize,
}

impl RobustAggregator for MultiKrum {
    fn name(&self) -> &'static str {
        if self.m == 1 {
            "krum"
        } else {
            "multi_krum"
        }
    }

    fn aggregate(
        &self,
        clients: &[usize],
        recons: &[Vec<f32>],
        weights: &[f32],
        n_params: usize,
    ) -> AggOutcome {
        let n = clients.len();
        if n == 0 {
            return AggOutcome::empty();
        }
        let order = client_order(clients);
        let m_eff = if self.m == 0 {
            n.saturating_sub(self.f).max(1)
        } else {
            self.m.min(n)
        };
        let mut ranks: Vec<usize> = (0..n).collect();
        if m_eff < n {
            // Pairwise squared distances over the client-ordered batch.
            let mut d = vec![0.0f64; n * n];
            for a in 0..n {
                for b in a + 1..n {
                    let dist = sq_dist(&recons[order[a]], &recons[order[b]]);
                    d[a * n + b] = dist;
                    d[b * n + a] = dist;
                }
            }
            let neigh = n.saturating_sub(self.f + 2).max(1).min(n - 1);
            let mut scores = vec![0.0f64; n];
            let mut row: Vec<f64> = Vec::with_capacity(n - 1);
            for (a, score) in scores.iter_mut().enumerate() {
                row.clear();
                for b in 0..n {
                    if b != a {
                        row.push(d[a * n + b]);
                    }
                }
                row.sort_by(f64::total_cmp);
                *score = row[..neigh].iter().sum();
            }
            // Rank by (score, client index) — `order` is ascending by
            // client, so the rank itself is the deterministic tie-break.
            ranks.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
            ranks.truncate(m_eff);
            ranks.sort_unstable();
        }
        let selected: Vec<usize> = ranks.iter().map(|&r| order[r]).collect();
        let mut rejected: Vec<usize> = (0..n)
            .filter(|r| !ranks.contains(r))
            .map(|r| clients[order[r]])
            .collect();
        rejected.sort_unstable();
        AggOutcome {
            update: weighted_mean_of(&selected, recons, weights, n_params),
            trim_frac: rejected.len() as f64 / n as f64,
            rejected,
        }
    }
}

// ---------------------------------------------------------------------
// NormClip — bound every contribution's L2 norm.

/// Norm clipping: any recon with `‖g‖ > τ` is rescaled to norm `τ`
/// before the weighted mean — scale-amplify attackers lose their
/// leverage but keep their vote. `τ ≤ 0` disables clipping and
/// degenerates to the weighted mean over the client-sorted batch.
pub struct NormClip {
    /// L2 clip threshold; `0` = disabled.
    pub tau: f64,
}

impl RobustAggregator for NormClip {
    fn name(&self) -> &'static str {
        "norm_clip"
    }

    fn aggregate(
        &self,
        clients: &[usize],
        recons: &[Vec<f32>],
        weights: &[f32],
        n_params: usize,
    ) -> AggOutcome {
        let n = clients.len();
        if n == 0 {
            return AggOutcome::empty();
        }
        let order = client_order(clients);
        if self.tau <= 0.0 {
            return AggOutcome {
                update: weighted_mean_of(&order, recons, weights, n_params),
                rejected: Vec::new(),
                trim_frac: 0.0,
            };
        }
        let total: f64 = order.iter().map(|&i| weights[i] as f64).sum();
        if total <= 0.0 {
            return AggOutcome::empty();
        }
        let mut agg = vec![0.0f32; n_params];
        let mut clipped = 0usize;
        for &i in &order {
            let wnorm = (weights[i] as f64 / total) as f32;
            let norm = vecmath::norm(&recons[i]);
            if norm > self.tau {
                clipped += 1;
                let scale = (self.tau / norm) as f32;
                for (slot, &x) in agg.iter_mut().zip(recons[i].iter()) {
                    *slot += wnorm * (scale * x);
                }
            } else {
                vecmath::weighted_add(&mut agg, &recons[i], wnorm);
            }
        }
        AggOutcome {
            update: Some(agg),
            rejected: Vec::new(),
            trim_frac: clipped as f64 / n as f64,
        }
    }
}

/// Build the aggregator an [`ExperimentConfig`] describes.
pub fn build_aggregator(cfg: &ExperimentConfig) -> Box<dyn RobustAggregator> {
    match cfg.aggregator {
        AggregatorKind::WeightedMean => Box::new(WeightedMean),
        AggregatorKind::TrimmedMean => Box::new(TrimmedMean { beta: cfg.trim_beta }),
        AggregatorKind::CoordinateMedian => Box::new(CoordinateMedian),
        AggregatorKind::Krum => Box::new(MultiKrum { f: cfg.krum_f, m: 1 }),
        AggregatorKind::MultiKrum => Box::new(MultiKrum { f: cfg.krum_f, m: cfg.krum_m }),
        AggregatorKind::NormClip => Box::new(NormClip { tau: cfg.clip_tau }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch() -> (Vec<usize>, Vec<Vec<f32>>, Vec<f32>) {
        let clients = vec![0usize, 1, 2, 3, 4];
        let recons = vec![
            vec![0.10f32, -0.20, 0.30],
            vec![0.12f32, -0.18, 0.28],
            vec![0.08f32, -0.22, 0.33],
            vec![0.11f32, -0.19, 0.31],
            vec![0.09f32, -0.21, 0.29],
        ];
        let weights = vec![1.0f32, 2.0, 1.0, 1.5, 1.0];
        (clients, recons, weights)
    }

    #[test]
    fn weighted_mean_matches_apply_round_bitwise() {
        let (clients, recons, weights) = batch();
        let out = WeightedMean.aggregate(&clients, &recons, &weights, 3);
        let agg = out.update.unwrap();
        // Independent replica of Server::apply_round's arithmetic.
        let total: f64 = weights.iter().map(|&w| w as f64).sum();
        let mut want = vec![0.0f32; 3];
        for (g, &wt) in recons.iter().zip(weights.iter()) {
            vecmath::weighted_add(&mut want, g, (wt as f64 / total) as f32);
        }
        for (a, b) in agg.iter().zip(want.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(out.rejected.is_empty());
        assert_eq!(out.trim_frac, 0.0);
    }

    #[test]
    fn trimmed_mean_drops_the_outlier() {
        let (mut clients, mut recons, mut weights) = batch();
        clients.push(5);
        recons.push(vec![100.0f32, -100.0, 100.0]); // attacker
        weights.push(1.0);
        let out = TrimmedMean { beta: 0.2 }.aggregate(&clients, &recons, &weights, 3);
        let agg = out.update.unwrap();
        assert!(agg.iter().all(|v| v.abs() < 1.0), "outlier leaked: {agg:?}");
        assert!((out.trim_frac - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn coordinate_median_is_the_middle_value() {
        let clients = vec![0usize, 1, 2];
        let recons = vec![vec![1.0f32], vec![5.0f32], vec![2.0f32]];
        let weights = vec![1.0f32, 1.0, 1.0];
        let out = CoordinateMedian.aggregate(&clients, &recons, &weights, 1);
        assert_eq!(out.update.unwrap(), vec![2.0f32]);
    }

    #[test]
    fn krum_selects_the_cluster_center_and_reports_rejections() {
        let clients = vec![0usize, 1, 2, 3];
        let recons = vec![
            vec![0.10f32, 0.10],
            vec![0.11f32, 0.09],
            vec![0.10f32, 0.11],
            vec![9.0f32, -9.0], // attacker, far away
        ];
        let weights = vec![1.0f32; 4];
        let out = MultiKrum { f: 1, m: 1 }.aggregate(&clients, &recons, &weights, 2);
        let agg = out.update.unwrap();
        assert!(agg[0] < 1.0 && agg[1] < 1.0, "krum picked the attacker: {agg:?}");
        assert_eq!(out.rejected.len(), 3);
        assert!(out.rejected.contains(&3));
    }

    #[test]
    fn norm_clip_caps_the_amplified_recon() {
        let clients = vec![0usize, 1];
        let recons = vec![vec![3.0f32, 4.0], vec![0.3f32, 0.4]];
        let weights = vec![1.0f32, 1.0];
        let out = NormClip { tau: 0.5 }.aggregate(&clients, &recons, &weights, 2);
        let agg = out.update.unwrap();
        // Both end up at norm ≤ 0.5; the mean's norm is ≤ 0.5 too.
        assert!(vecmath::norm(&agg) <= 0.5 + 1e-6);
        assert!((out.trim_frac - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_and_zero_weight_batches_are_noop() {
        let aggs: Vec<Box<dyn RobustAggregator>> = vec![
            Box::new(WeightedMean),
            Box::new(TrimmedMean { beta: 0.2 }),
            Box::new(CoordinateMedian),
            Box::new(MultiKrum { f: 0, m: 0 }),
            Box::new(NormClip { tau: 1.0 }),
        ];
        for a in &aggs {
            assert!(a.aggregate(&[], &[], &[], 4).update.is_none(), "{}", a.name());
            // A zero surviving weight total is a no-op round everywhere.
            let out = a.aggregate(&[0], &[vec![1.0f32; 4]], &[0.0], 4);
            assert!(out.update.is_none(), "{}", a.name());
        }
    }
}
