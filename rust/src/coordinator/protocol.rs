//! Typed wire envelopes of the event-driven federation session.
//!
//! A session is message passing over the simnet virtual clock: the
//! server emits [`ServerMsg`]s (model broadcasts, upload acks) and
//! clients answer with [`ClientMsg`]s (compressed uploads). Every
//! envelope carries the round metadata the aggregation policies need —
//! [`Upload::round`] is the model *version* the client trained against,
//! so staleness at aggregation time is simply
//! `server_round − upload.round`.
//!
//! Byte accounting stays wire-honest in *both* directions: the upload
//! envelope carries the actual [`Payload`] (its `wire_bytes()` —
//! including the u32 framing headers — is what the uplink transfer is
//! priced at), and the broadcast carries a [`DeltaPayload`] (keyframe or
//! compressed model delta; `compress::downlink`) priced the same way
//! ([`crate::coordinator::Traffic::record_broadcast`]). Each envelope
//! additionally carries the receiving side's reconstruction so the
//! simulation decodes once — `tests/prop_compressor_test.rs` pins
//! `Compressor::decode(payload) == recon` bit-for-bit for uploads, and
//! the downlink encoder returns the client's exact reconstruction for
//! broadcasts ([`Broadcast::w`]) — caches of the wire decode, not side
//! channels.
//!
//! Scale note: [`Upload::client`] doubles as the shard routing key —
//! the server's edge tier ([`crate::coordinator::EdgeAggregator`])
//! buffers envelopes per `client % n_shards` and drains them in global
//! arrival order, so the envelope format needs no shard field and the
//! wire bytes are identical for every shard count.
//!
//! Threat-model note: envelope *integrity* faults (doomed transfers,
//! outage windows — `simnet::faults`) attack whether a message arrives;
//! byzantine *content* faults attack what it says. The latter are
//! modeled as a corruption of [`Upload::recon`] at the server boundary
//! ([`crate::simnet::FaultLayer::corrupt`]) — the wire payload is
//! treated as already decoded, and the defense lives one layer up in
//! [`crate::coordinator::RobustAggregator`].

use std::sync::Arc;

use crate::compress::{DeltaPayload, Payload};

/// Server → client: the global model for one training task.
#[derive(Clone, Debug)]
pub struct Broadcast {
    /// Model version (the server round counter at send time).
    pub round: usize,
    /// Addressee.
    pub client: usize,
    /// The wire payload — a dense keyframe or a compressed delta against
    /// this client's last acked version; `payload.wire_bytes()` prices
    /// the downlink transfer.
    pub payload: DeltaPayload,
    /// The weights the client reconstructs from `payload` (the downlink
    /// mirror of [`Upload::recon`]; shared, not copied, per cohort on
    /// keyframes). The client trains on exactly these.
    pub w: Arc<Vec<f32>>,
    /// Virtual send time at the server.
    pub sent_at: f64,
    /// Virtual delivery time at the client: `sent_at` + one-way latency
    /// + this payload's transfer on the client's downlink.
    pub recv_at: f64,
}

/// Server → client: receipt confirmation for an upload (the round trip
/// that lets a real client free its send buffer; here it closes the
/// loop for diagnostics and tests).
#[derive(Clone, Copy, Debug)]
pub struct Ack {
    pub client: usize,
    /// The round of the acknowledged upload.
    pub round: usize,
    /// Virtual time the upload lands at the server (= when the policy
    /// sees it).
    pub recv_at: f64,
}

/// Everything the server can send.
#[derive(Clone, Debug)]
pub enum ServerMsg {
    Broadcast(Broadcast),
    Ack(Ack),
    /// The fault layer declared this upload lost mid-transfer: the
    /// envelope will never land on the virtual clock and the client is
    /// down for its recovery window. Only loss-tolerant policies
    /// (deadline/async) ever see this — under a synchronous barrier the
    /// same event is the [`UploadError::LossUnderBarrier`] error.
    Dropped {
        client: usize,
        /// The round of the lost upload.
        round: usize,
    },
}

/// Typed rejections of [`crate::coordinator::FedServer::submit_upload`] —
/// every way a client envelope can fail validation at the server
/// boundary, plus the one legitimate loss a barrier policy cannot
/// absorb. Carried inside `anyhow::Error`; recover the variant with
/// `err.downcast_ref::<UploadError>()`.
#[derive(Clone, Debug, PartialEq)]
pub enum UploadError {
    /// `client` index out of range for the fleet.
    UnknownClient { client: usize, n_clients: usize },
    /// No broadcast outstanding for this client.
    NoBroadcast { client: usize },
    /// A second submission for one broadcast.
    Duplicate { client: usize },
    /// The envelope's claimed round does not match the outstanding
    /// broadcast — a future round would *underflow* the staleness
    /// computation and inflate the aggregation weight, so it is rejected
    /// here at the boundary.
    RoundMismatch { client: usize, got: usize, expect: usize },
    /// `recon` length differs from the model's parameter count.
    WrongLength { client: usize, got: usize, expect: usize },
    /// `recon[index]` is NaN or infinite.
    NonFiniteRecon { client: usize, index: usize },
    /// Aggregation weight is NaN, infinite, or negative.
    BadWeight { client: usize, weight: f32 },
    /// Payload shape is internally inconsistent (see
    /// [`crate::compress::Payload::shape_error`]).
    MalformedPayload { client: usize, detail: &'static str },
    /// `sent_at` is non-finite or predates the broadcast's dispatch —
    /// accepting it would schedule an event in the virtual past.
    BadSendTime { client: usize, sent_at: f64, dispatched_at: f64 },
    /// The fault layer declared the upload lost, and the active policy
    /// is a barrier that can never complete without it.
    LossUnderBarrier { client: usize, round: usize, at: f64 },
}

impl std::fmt::Display for UploadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UploadError::UnknownClient { client, n_clients } => {
                write!(f, "upload from unknown client {client} (fleet has {n_clients})")
            }
            UploadError::NoBroadcast { client } => {
                write!(f, "upload from client {client} with no broadcast outstanding")
            }
            UploadError::Duplicate { client } => {
                write!(f, "duplicate upload from client {client} for one broadcast")
            }
            UploadError::RoundMismatch { client, got, expect } => write!(
                f,
                "byzantine envelope from client {client}: claims round {got}, \
                 outstanding broadcast is round {expect}"
            ),
            UploadError::WrongLength { client, got, expect } => write!(
                f,
                "byzantine envelope from client {client}: recon has {got} values, \
                 model has {expect} parameters"
            ),
            UploadError::NonFiniteRecon { client, index } => write!(
                f,
                "byzantine envelope from client {client}: recon[{index}] is not finite"
            ),
            UploadError::BadWeight { client, weight } => write!(
                f,
                "byzantine envelope from client {client}: aggregation weight {weight} \
                 must be finite and non-negative"
            ),
            UploadError::MalformedPayload { client, detail } => write!(
                f,
                "byzantine envelope from client {client}: malformed payload ({detail})"
            ),
            UploadError::BadSendTime { client, sent_at, dispatched_at } => write!(
                f,
                "byzantine envelope from client {client}: sent_at {sent_at} predates \
                 its broadcast (dispatched at {dispatched_at})"
            ),
            UploadError::LossUnderBarrier { client, round, at } => write!(
                f,
                "client {client} dropped mid-round at t={at:.3}s (round {round}): a \
                 synchronous barrier can never complete under faults — use a deadline \
                 or async session, or disable [faults]"
            ),
        }
    }
}

impl std::error::Error for UploadError {}

/// Client → server: one compressed model update.
#[derive(Clone, Debug)]
pub struct Upload {
    pub client: usize,
    /// The [`Broadcast::round`] this update was computed against.
    pub round: usize,
    /// Virtual send time at the client (= the broadcast's `recv_at`;
    /// local compute is free on the virtual clock — the session models
    /// communication, the wall-clock benches model compute).
    pub sent_at: f64,
    /// The wire payload; `payload.wire_bytes()` prices the uplink.
    pub payload: Payload,
    /// Decoded update (bit-identical to `Compressor::decode(payload)`;
    /// see module docs).
    pub recon: Vec<f32>,
    /// Aggregation weight |D_i|.
    pub weight: f32,
    /// Client-side diagnostic cos(ĝ, g+e) (Fig 7).
    pub efficiency: f64,
    /// Compression ratio (× vs dense) of this payload.
    pub ratio: f64,
}

/// Everything a client can send.
#[derive(Clone, Debug)]
pub enum ClientMsg {
    Upload(Upload),
}
