//! Typed wire envelopes of the event-driven federation session.
//!
//! A session is message passing over the simnet virtual clock: the
//! server emits [`ServerMsg`]s (model broadcasts, upload acks) and
//! clients answer with [`ClientMsg`]s (compressed uploads). Every
//! envelope carries the round metadata the aggregation policies need —
//! [`Upload::round`] is the model *version* the client trained against,
//! so staleness at aggregation time is simply
//! `server_round − upload.round`.
//!
//! Byte accounting stays wire-honest in *both* directions: the upload
//! envelope carries the actual [`Payload`] (its `wire_bytes()` —
//! including the u32 framing headers — is what the uplink transfer is
//! priced at), and the broadcast carries a [`DeltaPayload`] (keyframe or
//! compressed model delta; `compress::downlink`) priced the same way
//! ([`crate::coordinator::Traffic::record_broadcast`]). Each envelope
//! additionally carries the receiving side's reconstruction so the
//! simulation decodes once — `tests/prop_compressor_test.rs` pins
//! `Compressor::decode(payload) == recon` bit-for-bit for uploads, and
//! the downlink encoder returns the client's exact reconstruction for
//! broadcasts ([`Broadcast::w`]) — caches of the wire decode, not side
//! channels.

use std::sync::Arc;

use crate::compress::{DeltaPayload, Payload};

/// Server → client: the global model for one training task.
#[derive(Clone, Debug)]
pub struct Broadcast {
    /// Model version (the server round counter at send time).
    pub round: usize,
    /// Addressee.
    pub client: usize,
    /// The wire payload — a dense keyframe or a compressed delta against
    /// this client's last acked version; `payload.wire_bytes()` prices
    /// the downlink transfer.
    pub payload: DeltaPayload,
    /// The weights the client reconstructs from `payload` (the downlink
    /// mirror of [`Upload::recon`]; shared, not copied, per cohort on
    /// keyframes). The client trains on exactly these.
    pub w: Arc<Vec<f32>>,
    /// Virtual send time at the server.
    pub sent_at: f64,
    /// Virtual delivery time at the client: `sent_at` + one-way latency
    /// + this payload's transfer on the client's downlink.
    pub recv_at: f64,
}

/// Server → client: receipt confirmation for an upload (the round trip
/// that lets a real client free its send buffer; here it closes the
/// loop for diagnostics and tests).
#[derive(Clone, Copy, Debug)]
pub struct Ack {
    pub client: usize,
    /// The round of the acknowledged upload.
    pub round: usize,
    /// Virtual time the upload lands at the server (= when the policy
    /// sees it).
    pub recv_at: f64,
}

/// Everything the server can send.
#[derive(Clone, Debug)]
pub enum ServerMsg {
    Broadcast(Broadcast),
    Ack(Ack),
}

/// Client → server: one compressed model update.
#[derive(Clone, Debug)]
pub struct Upload {
    pub client: usize,
    /// The [`Broadcast::round`] this update was computed against.
    pub round: usize,
    /// Virtual send time at the client (= the broadcast's `recv_at`;
    /// local compute is free on the virtual clock — the session models
    /// communication, the wall-clock benches model compute).
    pub sent_at: f64,
    /// The wire payload; `payload.wire_bytes()` prices the uplink.
    pub payload: Payload,
    /// Decoded update (bit-identical to `Compressor::decode(payload)`;
    /// see module docs).
    pub recon: Vec<f32>,
    /// Aggregation weight |D_i|.
    pub weight: f32,
    /// Client-side diagnostic cos(ĝ, g+e) (Fig 7).
    pub efficiency: f64,
    /// Compression ratio (× vs dense) of this payload.
    pub ratio: f64,
}

/// Everything a client can send.
#[derive(Clone, Debug)]
pub enum ClientMsg {
    Upload(Upload),
}
