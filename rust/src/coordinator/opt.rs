//! Server optimizers: how the aggregated (reconstructed) pseudo-gradient
//! becomes a global-model update.
//!
//! The seed hardwired the paper's unit step `w ← w − ḡ` (Eq. 3). A
//! [`ServerOptimizer`] makes that step pluggable, following the adaptive
//! federated optimization family (Reddi et al., "Adaptive Federated
//! Optimization"):
//!
//! * [`ServerGd`] — `w ← w − η_s·ḡ`; at `η_s = 1` this is bit-for-bit the
//!   seed/paper update (the default).
//! * [`ServerMomentum`] — heavy-ball: `v ← β·v + ḡ`, `w ← w − η_s·v`;
//!   reduces exactly to [`ServerGd`] at `β = 0`.
//! * [`FedAdam`] — `m ← β₁·m + (1−β₁)·ḡ`, `v ← β₂·v + (1−β₂)·ḡ²`,
//!   `w ← w − η_s·m/(√v + τ)` (no bias correction, per FedAdam). In the
//!   `β₁ = β₂ = 0`, large-`τ` limit the step is `(η_s/τ)·ḡ`, i.e. plain
//!   GD with learning rate `η_s/τ`.
//!
//! All state (momentum/moment buffers) lives in the optimizer, so the
//! server itself stays a plain weight holder.

use crate::config::{ExperimentConfig, ServerOptKind};
use crate::util::vecmath;

/// Applies one global-model update from the aggregated pseudo-gradient.
pub trait ServerOptimizer {
    /// In-place update of `w` given `agg`, the sample-weighted average of
    /// the round's reconstructed client updates.
    fn step(&mut self, w: &mut [f32], agg: &[f32]);

    /// Short name for logs/labels.
    fn name(&self) -> &'static str;
}

/// Plain gradient descent with a server learning rate.
pub struct ServerGd {
    pub lr: f32,
}

impl ServerOptimizer for ServerGd {
    fn step(&mut self, w: &mut [f32], agg: &[f32]) {
        vecmath::axpy(-self.lr, agg, w);
    }

    fn name(&self) -> &'static str {
        "gd"
    }
}

/// Heavy-ball server momentum.
pub struct ServerMomentum {
    lr: f32,
    beta: f32,
    v: Vec<f32>,
}

impl ServerMomentum {
    pub fn new(lr: f32, beta: f32) -> ServerMomentum {
        ServerMomentum { lr, beta, v: Vec::new() }
    }
}

impl ServerOptimizer for ServerMomentum {
    fn step(&mut self, w: &mut [f32], agg: &[f32]) {
        if self.v.is_empty() {
            self.v = vec![0.0f32; agg.len()];
        }
        for (vi, gi) in self.v.iter_mut().zip(agg.iter()) {
            *vi = self.beta * *vi + *gi;
        }
        vecmath::axpy(-self.lr, &self.v, w);
    }

    fn name(&self) -> &'static str {
        "momentum"
    }
}

/// FedAdam (Reddi et al., Algorithm 2): per-coordinate adaptive server
/// step with adaptivity degree `tau`.
pub struct FedAdam {
    lr: f32,
    beta1: f32,
    beta2: f32,
    tau: f32,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl FedAdam {
    pub fn new(lr: f32, beta1: f32, beta2: f32, tau: f32) -> FedAdam {
        FedAdam { lr, beta1, beta2, tau, m: Vec::new(), v: Vec::new() }
    }
}

impl ServerOptimizer for FedAdam {
    fn step(&mut self, w: &mut [f32], agg: &[f32]) {
        if self.m.is_empty() {
            self.m = vec![0.0f32; agg.len()];
            self.v = vec![0.0f32; agg.len()];
        }
        for i in 0..agg.len() {
            let g = agg[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            w[i] -= self.lr * self.m[i] / (self.v[i].sqrt() + self.tau);
        }
    }

    fn name(&self) -> &'static str {
        "fedadam"
    }
}

/// Build the server optimizer an [`ExperimentConfig`] describes.
pub fn build_server_opt(cfg: &ExperimentConfig) -> Box<dyn ServerOptimizer> {
    match cfg.server_opt {
        ServerOptKind::Gd => Box::new(ServerGd { lr: cfg.server_lr }),
        ServerOptKind::Momentum => {
            Box::new(ServerMomentum::new(cfg.server_lr, cfg.server_momentum))
        }
        ServerOptKind::FedAdam => Box::new(FedAdam::new(
            cfg.server_lr,
            cfg.adam_beta1,
            cfg.adam_beta2,
            cfg.adam_tau,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_steps(opt: &mut dyn ServerOptimizer, w0: &[f32], grads: &[Vec<f32>]) -> Vec<f32> {
        let mut w = w0.to_vec();
        for g in grads {
            opt.step(&mut w, g);
        }
        w
    }

    #[test]
    fn gd_matches_hand_computation() {
        let mut opt = ServerGd { lr: 0.5 };
        let mut w = vec![1.0f32, -2.0, 0.0];
        opt.step(&mut w, &[2.0, 2.0, -4.0]);
        assert_eq!(w, vec![0.0, -3.0, 2.0]);
    }

    #[test]
    fn momentum_reduces_to_gd_at_zero_beta() {
        // Satellite: β = 0 momentum must equal plain GD exactly, over
        // multiple steps (state carried, but never mixed in).
        let w0 = [0.3f32, -1.2, 4.0, 0.0];
        let grads: Vec<Vec<f32>> = vec![
            vec![1.0, -0.5, 0.25, 2.0],
            vec![-2.0, 0.5, 1.0, -1.0],
            vec![0.1, 0.2, -0.3, 0.4],
        ];
        let gd = run_steps(&mut ServerGd { lr: 0.7 }, &w0, &grads);
        let mom = run_steps(&mut ServerMomentum::new(0.7, 0.0), &w0, &grads);
        assert_eq!(gd, mom);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        // Two identical gradients: second step must be larger than the first.
        let mut opt = ServerMomentum::new(1.0, 0.9);
        let mut w = vec![0.0f32];
        opt.step(&mut w, &[1.0]);
        let first = -w[0];
        let before = w[0];
        opt.step(&mut w, &[1.0]);
        let second = before - w[0];
        assert!((first - 1.0).abs() < 1e-6);
        assert!((second - 1.9).abs() < 1e-6);
    }

    #[test]
    fn fedadam_reduces_to_gd_in_large_tau_zero_beta_limit() {
        // Satellite: with β₁ = β₂ = 0 the moments are just ḡ and ḡ²; with
        // τ ≫ |ḡ| the denominator is ≈ τ, so FedAdam(lr = η·τ) ≈ GD(η).
        let eta = 0.05f32;
        let tau = 1e6f32;
        let w0 = [1.0f32, -0.5, 2.0, 0.25];
        let grads: Vec<Vec<f32>> = vec![
            vec![0.5, -1.0, 0.75, 0.1],
            vec![-0.25, 0.5, -0.5, 1.0],
        ];
        let gd = run_steps(&mut ServerGd { lr: eta }, &w0, &grads);
        let adam = run_steps(&mut FedAdam::new(eta * tau, 0.0, 0.0, tau), &w0, &grads);
        for (a, b) in gd.iter().zip(adam.iter()) {
            assert!((a - b).abs() < 1e-5, "gd {a} vs fedadam {b}");
        }
    }

    #[test]
    fn fedadam_step_is_bounded_by_lr() {
        // The adaptive step magnitude is < lr per coordinate once v ≈ g².
        let mut opt = FedAdam::new(0.1, 0.9, 0.99, 1e-3);
        let mut w = vec![0.0f32; 3];
        for _ in 0..50 {
            opt.step(&mut w, &[10.0, -10.0, 0.0]);
        }
        // 50 steps of at most ~lr each.
        assert!(w[0] < 0.0 && w[0] > -0.11 * 50.0);
        assert!(w[1] > 0.0 && w[1] < 0.11 * 50.0);
        assert_eq!(w[2], 0.0);
    }
}
