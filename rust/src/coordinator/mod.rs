//! The federated-learning coordinator (L3): clients, server, round
//! scheduler, traffic accounting and metrics — the system the paper's
//! compressors plug into.
//!
//! One process simulates the cluster (exactly like the paper's testbed,
//! §5: "evaluated on a simulated 40 clients cluster"), but messages,
//! byte accounting and client/server state are kept strictly separate so
//! the compressors see the same interface a distributed deployment would.

pub mod client;
pub mod experiment;
pub mod metrics;
pub mod server;
pub mod traffic;

pub use client::ClientState;
pub use experiment::{Experiment, RoundRecord};
pub use metrics::MetricsSink;
pub use server::Server;
pub use traffic::Traffic;
