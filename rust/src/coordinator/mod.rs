//! The federated-learning coordinator (L3): event-driven federation
//! sessions — a message-passing server, typed wire envelopes, client
//! schedulers, aggregation policies on a virtual clock, server
//! optimizers, traffic accounting, and metrics — that the paper's
//! compressors plug into.
//!
//! One process simulates the cluster (exactly like the paper's testbed,
//! §5: "evaluated on a simulated 40 clients cluster"), but messages,
//! byte accounting and client/server state are kept strictly separate so
//! the compressors see the same interface a distributed deployment would
//! — the server consumes [`protocol`] envelopes off a
//! [`crate::simnet::SimClock`], never client internals.
//!
//! A session is assembled from pluggable pieces, all chosen by
//! [`crate::config::ExperimentConfig`] (or the [`ExperimentBuilder`]):
//!
//! * a [`ClientScheduler`] ([`schedule`]) decides which clients each
//!   broadcast cycle reaches — full participation (the paper's
//!   protocol), uniform random `client_frac` sampling, or round-robin
//!   cohorts. Skipped clients keep their error-feedback memory untouched
//!   until they next participate, and aggregation normalizes over the
//!   aggregated set only;
//! * an [`AggregationPolicy`] ([`policy`]) decides *when* arrived
//!   uploads become a global step — [`Synchronous`] cohort barrier
//!   (reproduces the classic blocking round loop bit-for-bit),
//!   [`Deadline`] semi-sync with straggler carry-over, or
//!   [`BufferedAsync`] FedBuff-style every-K aggregation with
//!   staleness-discounted weights;
//! * a [`ServerOptimizer`] ([`opt`]) turns the aggregated pseudo-gradient
//!   into the global step — plain GD (`server_lr = 1` reproduces the
//!   paper's Eq. 3 bit-for-bit), server momentum, or FedAdam;
//! * a [`RobustAggregator`] ([`robust`], `[defense]`) combines each
//!   step's decoded batch before the optimizer sees it — the default
//!   [`WeightedMean`] reproduces the classic weighted average
//!   bit-for-bit; trimmed mean, coordinate median, (Multi-)Krum and
//!   norm clipping survive byzantine content attacks
//!   (`[faults] byzantine_frac`);
//! * a [`crate::simnet::NetworkModel`] plus `[network] jitter` derive
//!   per-client links; every envelope's delivery time comes from them,
//!   and each [`RoundRecord`] carries the step's virtual-time cost;
//! * the `[scale]` table ([`shard`]) makes million-client federations
//!   tractable: a [`ClientStore`] materializes per-client state only
//!   while a client is in a cohort (EF residuals spilled to compact
//!   slabs between participations), and an [`EdgeAggregator`] buffers
//!   uploads per shard with an arrival-order-preserving drain — both
//!   bit-identical to the dense/unsharded path by construction.
//!
//! [`FedServer`] ([`fedserver`]) owns the event loop and hands compute
//! back to its driver as [`fedserver::Directive`]s; [`Experiment`] is
//! that driver. Dispatch batches fan out over a fixed worker pool
//! ([`parallel`]; `[runtime] threads`, `--threads`; `1` = the original
//! sequential path) into dispatch-order slots before any state is
//! touched, so trajectories are bit-identical for every thread count.
//! Broadcasts go through a driver-owned downlink encoder
//! ([`crate::compress::DownlinkTx`], `[downlink]`): dense keyframes by
//! default (bit-identical to the classic path), or E-3SFC-style
//! compressed model deltas against each client's last acked version with
//! server-side error feedback — both priced per envelope in [`Traffic`].
//! All of it runs against a pluggable [`crate::runtime::Backend`] — PJRT
//! artifacts or the pure-Rust native implementation — with identical
//! semantics.

pub mod client;
pub mod experiment;
pub mod fedserver;
pub mod metrics;
pub mod opt;
pub mod parallel;
pub mod policy;
pub mod protocol;
pub mod robust;
pub mod schedule;
pub mod server;
pub mod shard;
pub mod traffic;

pub use client::ClientState;
pub use experiment::{Experiment, ExperimentBuilder, RoundRecord};
pub use fedserver::{Directive, FedServer, StepSummary};
pub use metrics::MetricsSink;
pub use opt::{build_server_opt, FedAdam, ServerGd, ServerMomentum, ServerOptimizer};
pub use parallel::{run_client, ClientJob, ClientUpdate, WorkerPool};
pub use policy::{
    build_policy, AggTrigger, AggregationPolicy, BufferedAsync, Deadline, PolicyCtx,
    Synchronous,
};
pub use protocol::{Ack, Broadcast, ClientMsg, ServerMsg, Upload, UploadError};
pub use robust::{
    build_aggregator, AggOutcome, CoordinateMedian, MultiKrum, NormClip,
    RobustAggregator, TrimmedMean, WeightedMean,
};
pub use schedule::{
    build_scheduler, ClientScheduler, FullParticipation, ReliabilityGate, RoundRobin,
    UniformSampler,
};
pub use server::Server;
pub use shard::{ClientStore, EdgeAggregator};
pub use traffic::Traffic;
