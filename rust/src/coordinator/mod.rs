//! The federated-learning coordinator (L3): a composable round engine —
//! client schedulers, per-client state, server optimizers, traffic and
//! network-time accounting, and metrics — that the paper's compressors
//! plug into.
//!
//! One process simulates the cluster (exactly like the paper's testbed,
//! §5: "evaluated on a simulated 40 clients cluster"), but messages,
//! byte accounting and client/server state are kept strictly separate so
//! the compressors see the same interface a distributed deployment would.
//!
//! The round engine is assembled from three pluggable pieces, all chosen
//! by [`crate::config::ExperimentConfig`] (or the [`ExperimentBuilder`]):
//!
//! * a [`ClientScheduler`] ([`schedule`]) decides which clients act each
//!   round — full participation (the paper's protocol), uniform random
//!   `client_frac` sampling, or round-robin cohorts. Skipped clients keep
//!   their error-feedback memory untouched until they next participate,
//!   and aggregation normalizes over the selected set only;
//! * a [`ServerOptimizer`] ([`opt`]) turns the aggregated pseudo-gradient
//!   into the global step — plain GD (`server_lr = 1` reproduces the
//!   paper's Eq. 3 bit-for-bit), server momentum, or FedAdam;
//! * a [`crate::simnet::NetworkModel`] converts each round's payload
//!   sizes into a modeled `comm_time_s` with slowest-selected-client
//!   semantics, recorded on every [`RoundRecord`].
//!
//! Execution within a round is parallel ([`parallel`]): the selected
//! clients' train-and-compress work fans out over a fixed worker pool
//! (`[runtime] threads` in config, `--threads` on the CLI; default: all
//! available cores, `1` = the original sequential path). Results are
//! collected into slots indexed by selection order before any state or
//! accounting is touched, so trajectories are bit-identical for every
//! thread count. All of it runs against a pluggable
//! [`crate::runtime::Backend`] — PJRT artifacts or the pure-Rust native
//! implementation — with identical semantics.

pub mod client;
pub mod experiment;
pub mod metrics;
pub mod opt;
pub mod parallel;
pub mod schedule;
pub mod server;
pub mod traffic;

pub use client::ClientState;
pub use experiment::{Experiment, ExperimentBuilder, RoundRecord};
pub use metrics::MetricsSink;
pub use opt::{build_server_opt, FedAdam, ServerGd, ServerMomentum, ServerOptimizer};
pub use parallel::{run_client, ClientJob, ClientUpdate, WorkerPool};
pub use schedule::{
    build_scheduler, ClientScheduler, FullParticipation, RoundRobin, UniformSampler,
};
pub use server::Server;
pub use traffic::Traffic;
