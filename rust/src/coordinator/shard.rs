//! Million-client scale: lazy client state + sharded edge aggregation.
//!
//! Two pieces, both wired so that every scale knob degenerates to the
//! historical path **bitwise** (the same contract [`WeightedMean`]
//! honors in `robust.rs`):
//!
//! * [`ClientStore`] — owns all per-client state but materializes a
//!   dense [`ClientState`] (EF memory, sampler, RNG) only when a client
//!   is actually in a cohort. With `[scale] lazy_state = true` the
//!   store evicts a client after each participation, spilling its EF
//!   residual to a compact slab (`compress::spill`), so resident state
//!   is `O(cohort)` instead of `O(n_clients)`. With `lazy_state =
//!   false` materialized clients simply stay resident — but
//!   construction is *always* on-demand, so building an experiment
//!   never allocates `n_clients` dense EF vectors up front.
//!
//!   Lazy materialization is sound because [`crate::util::rng::Rng::split`]
//!   is a pure function of the root seed and the stream tag: client `i`
//!   built at round 40 is bit-identical to client `i` built at round 0.
//!
//! * [`EdgeAggregator`] — a two-level aggregation tree: uploads land in
//!   per-shard buffers (shard = `client_index % n_shards`, the fixed
//!   deterministic assignment) and the root drains them in one pass per
//!   step. Bitwise invariance across shard counts is achieved by
//!   **order-preserving grouping**: every push is stamped with a global
//!   arrival sequence number, and [`EdgeAggregator::drain_ordered`]
//!   merges the shard queues by minimum sequence — exactly
//!   reconstructing flat arrival order, so the (non-associative) f32
//!   reduction happens once at the root in a canonical order and
//!   `shards = 1` vs `K` trajectories are bit-identical by
//!   construction. Per-shard partial sums are kept only in exact
//!   arithmetic (f64 weight totals, integer arrival counts) as
//!   edge-tier diagnostics.
//!
//! The allocation contract — nothing on the shard path scales with
//! `n_clients` except the store's own index-keyed slabs — is pinned by
//! a targeted test in `tests/shard_test.rs` (a 10⁶-client store must
//! stay `O(cohort)` resident).

use std::collections::{BTreeMap, VecDeque};

use crate::compress::spill::{restore, spill, SpilledEf};
use crate::config::SpillKind;
use crate::coordinator::client::ClientState;
use crate::coordinator::protocol::Upload;
use crate::data::ClientSampler;
use crate::util::rng::Rng;

/// A client's state between participations: everything a re-admission
/// needs to resume bit-identically, with the dense EF vector replaced
/// by its spill slab.
///
/// The sampler travels **by value**: [`ClientSampler::new`] shuffles
/// its index set at construction, so rebuilding it from the partition
/// would re-draw the shuffle and fork the trajectory.
#[derive(Clone, Debug)]
struct SpilledClient {
    sampler: ClientSampler,
    rng: Rng,
    ef: SpilledEf,
    n_samples: usize,
    rounds_participated: usize,
    last_version: Option<usize>,
}

/// Lazy, index-keyed store of per-client federation state.
pub struct ClientStore {
    n_params: usize,
    /// Experiment root RNG (cloned at construction): `split` is pure,
    /// so late materialization draws the same per-client streams the
    /// eager constructor would have.
    root: Rng,
    lazy: bool,
    spill_kind: SpillKind,
    /// Partition slots, taken on first materialization (`None` after).
    parts: Vec<Option<Vec<u32>>>,
    /// `|D_i|` per client — needed for the active mask and aggregation
    /// weights without materializing anyone (4 bytes/client).
    n_samples: Vec<u32>,
    /// Materialized clients, keyed by index. `BTreeMap`, not `HashMap`:
    /// deterministic iteration order (detlint DET002).
    resident: BTreeMap<usize, ClientState>,
    /// Evicted clients' compact state (lazy mode only).
    spilled: BTreeMap<usize, SpilledClient>,
    peak_resident: usize,
    spill_events: u64,
}

impl ClientStore {
    /// Build a store over a data partition. No [`ClientState`] is
    /// constructed here — `parts` and the sample counts are the only
    /// `O(n_clients)` allocations, and they are the partition itself.
    pub fn new(
        parts: Vec<Vec<u32>>,
        n_params: usize,
        root: &Rng,
        lazy: bool,
        spill_kind: SpillKind,
    ) -> ClientStore {
        let n_samples: Vec<u32> = parts.iter().map(|p| p.len() as u32).collect();
        ClientStore {
            n_params,
            root: root.clone(),
            lazy,
            spill_kind,
            parts: parts.into_iter().map(Some).collect(),
            n_samples,
            resident: BTreeMap::new(),
            spilled: BTreeMap::new(),
            peak_resident: 0,
            spill_events: 0,
        }
    }

    /// Total clients (materialized or not).
    pub fn len(&self) -> usize {
        self.parts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }

    /// Whether released clients are evicted and spilled.
    pub fn is_lazy(&self) -> bool {
        self.lazy
    }

    /// `|D_i|` without materializing client `id`.
    pub fn n_samples(&self, id: usize) -> usize {
        self.n_samples[id] as usize
    }

    /// Per-client has-data mask (what the server's dispatch filter
    /// consumes) — computable for a million clients without building
    /// one of them.
    pub fn active_mask(&self) -> Vec<bool> {
        self.n_samples.iter().map(|&n| n > 0).collect()
    }

    /// Materialize (or fetch) client `id` for participation. First
    /// touch constructs the state from the partition slot; a re-touch
    /// after a lazy eviction restores the spilled EF bit-exactly.
    pub fn client(&mut self, id: usize) -> &mut ClientState {
        if !self.resident.contains_key(&id) {
            let state = if let Some(s) = self.spilled.remove(&id) {
                ClientState {
                    id,
                    sampler: s.sampler,
                    ef: restore(&s.ef, self.n_params),
                    rng: s.rng,
                    n_samples: s.n_samples,
                    rounds_participated: s.rounds_participated,
                    last_version: s.last_version,
                }
            } else {
                let indices = self.parts[id]
                    .take()
                    .expect("client slot taken but neither resident nor spilled");
                ClientState::new(id, indices, self.n_params, &self.root)
            };
            self.resident.insert(id, state);
            self.peak_resident = self.peak_resident.max(self.resident.len());
        }
        self.resident.get_mut(&id).expect("just inserted")
    }

    /// Participation over: in lazy mode, evict `id` and spill its EF;
    /// otherwise a no-op (the client stays resident, matching the
    /// historical dense-vector semantics exactly).
    pub fn release(&mut self, id: usize) {
        if !self.lazy {
            return;
        }
        if let Some(c) = self.resident.remove(&id) {
            self.spill_events += 1;
            self.spilled.insert(
                id,
                SpilledClient {
                    sampler: c.sampler,
                    rng: c.rng,
                    ef: spill(&c.ef, self.spill_kind),
                    n_samples: c.n_samples,
                    rounds_participated: c.rounds_participated,
                    last_version: c.last_version,
                },
            );
        }
    }

    /// Currently materialized clients.
    pub fn resident_count(&self) -> usize {
        self.resident.len()
    }

    /// High-water mark of simultaneous residents — the store's
    /// `O(cohort)` claim, as a measured number.
    pub fn peak_resident(&self) -> usize {
        self.peak_resident
    }

    /// Clients currently evicted to spill slabs.
    pub fn spilled_count(&self) -> usize {
        self.spilled.len()
    }

    /// Total evictions performed (a client re-admitted and re-released
    /// counts twice).
    pub fn spill_events(&self) -> u64 {
        self.spill_events
    }

    /// Heap bytes held by spill slabs (zero-elided residuals are free).
    pub fn spilled_bytes(&self) -> usize {
        self.spilled.values().map(|s| s.ef.spilled_bytes()).sum()
    }

    /// Client `id`'s EF residual wherever it lives: resident vector,
    /// spill slab, or — for a never-materialized client — the all-zero
    /// vector a fresh [`ClientState`] would carry.
    pub fn ef_of(&self, id: usize) -> Vec<f32> {
        if let Some(c) = self.resident.get(&id) {
            c.ef.clone()
        } else if let Some(s) = self.spilled.get(&id) {
            restore(&s.ef, self.n_params)
        } else {
            vec![0.0f32; self.n_params]
        }
    }

    /// All EF residuals, densified (tests/diagnostics — this is the one
    /// deliberately `O(n_clients · n_params)` accessor; never on the
    /// training path).
    pub fn ef_snapshots(&self) -> Vec<Vec<f32>> {
        (0..self.len()).map(|id| self.ef_of(id)).collect()
    }

    /// Rounds client `id` has participated in (0 if never materialized).
    pub fn rounds_participated(&self, id: usize) -> usize {
        if let Some(c) = self.resident.get(&id) {
            c.rounds_participated
        } else if let Some(s) = self.spilled.get(&id) {
            s.rounds_participated
        } else {
            0
        }
    }

    /// Per-client participation counts (partial-participation stats).
    pub fn participation_counts(&self) -> Vec<usize> {
        (0..self.len()).map(|id| self.rounds_participated(id)).collect()
    }
}

/// One edge tier's buffer: a seq-stamped queue (always in increasing
/// sequence order — pushes are monotone) plus exact-arithmetic partial
/// aggregates.
#[derive(Debug, Default)]
struct ShardBuffer {
    queue: VecDeque<(u64, Upload)>,
    /// Σ upload weights since the last drain — f64, so the edge-tier
    /// pre-combine is exact and shard count can never perturb it.
    weight_total: f64,
    /// Lifetime arrivals routed to this shard.
    arrivals: u64,
}

/// Two-level aggregation tree: per-shard upload buffers pre-grouped at
/// the edge, drained by the root in global arrival order.
pub struct EdgeAggregator {
    n_shards: usize,
    /// Global arrival stamp — the canonical reduction order.
    next_seq: u64,
    shards: Vec<ShardBuffer>,
}

impl EdgeAggregator {
    /// `n_shards = 1` is the degenerate single-queue path (today's
    /// behavior, bitwise).
    pub fn new(n_shards: usize) -> EdgeAggregator {
        assert!(n_shards >= 1, "at least one shard");
        EdgeAggregator {
            n_shards,
            next_seq: 0,
            shards: (0..n_shards).map(|_| ShardBuffer::default()).collect(),
        }
    }

    /// Re-shard an *empty* tree (call before any upload arrives —
    /// re-routing buffered uploads would be an ordering hazard).
    pub fn set_shards(&mut self, n_shards: usize) {
        assert!(n_shards >= 1, "at least one shard");
        assert!(
            self.is_empty() && self.next_seq == 0,
            "re-sharding a live aggregation tree"
        );
        self.n_shards = n_shards;
        self.shards = (0..n_shards).map(|_| ShardBuffer::default()).collect();
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Route one upload to its shard (`client % n_shards`), stamped
    /// with the global arrival sequence.
    pub fn push(&mut self, up: Upload) {
        let shard = up.client % self.n_shards;
        let buf = &mut self.shards[shard];
        buf.weight_total += up.weight as f64;
        buf.arrivals += 1;
        buf.queue.push_back((self.next_seq, up));
        self.next_seq += 1;
    }

    /// Buffered uploads across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.queue.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.queue.is_empty())
    }

    /// Current queue depth per shard (edge-tier diagnostics).
    pub fn occupancy(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.queue.len()).collect()
    }

    /// Lifetime arrivals per shard.
    pub fn arrivals(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.arrivals).collect()
    }

    /// Exact pre-combined upload weight per shard since the last drain.
    pub fn weight_totals(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.weight_total).collect()
    }

    /// Drain every shard, merging by minimum sequence stamp — the
    /// result is exactly the flat arrival order, independent of
    /// `n_shards`. Resets the per-shard weight partial sums.
    pub fn drain_ordered(&mut self) -> Vec<Upload> {
        let total = self.len();
        let mut out = Vec::with_capacity(total);
        // Each queue is internally seq-sorted, so a K-way merge on the
        // fronts reconstructs the global order. K is small (shard
        // count), so the linear front-scan beats a heap here.
        for _ in 0..total {
            let winner = self
                .shards
                .iter()
                .enumerate()
                .filter_map(|(i, s)| s.queue.front().map(|(seq, _)| (*seq, i)))
                .min()
                .map(|(_, i)| i)
                .expect("len() said an upload remains");
            let (_, up) = self.shards[winner].queue.pop_front().expect("front just seen");
            out.push(up);
        }
        for s in &mut self.shards {
            s.weight_total = 0.0;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Payload;

    fn up(client: usize, round: usize, weight: f32) -> Upload {
        Upload {
            client,
            round,
            sent_at: 0.0,
            payload: Payload::Dense { g: vec![client as f32] },
            recon: vec![client as f32],
            weight,
            efficiency: 1.0,
            ratio: 1.0,
        }
    }

    #[test]
    fn drain_order_is_arrival_order_for_any_shard_count() {
        // An adversarial arrival order (not sorted by client, with
        // repeats) must come back verbatim for every shard count.
        let arrivals = [7usize, 2, 9, 0, 7, 13, 1, 6, 5, 14, 3, 2];
        let flat: Vec<usize> = {
            let mut e = EdgeAggregator::new(1);
            for (r, &c) in arrivals.iter().enumerate() {
                e.push(up(c, r, 1.0));
            }
            e.drain_ordered().iter().map(|u| u.client).collect()
        };
        assert_eq!(flat, arrivals.to_vec());
        for k in [2usize, 3, 7, 16] {
            let mut e = EdgeAggregator::new(k);
            for (r, &c) in arrivals.iter().enumerate() {
                e.push(up(c, r, 1.0));
            }
            let rounds: Vec<usize> =
                e.shards.iter().flat_map(|s| s.queue.iter().map(|(_, u)| u.round)).collect();
            // Sanity: the shards really did split the stream.
            assert_eq!(rounds.len(), arrivals.len());
            let drained: Vec<usize> = e.drain_ordered().iter().map(|u| u.client).collect();
            assert_eq!(drained, flat, "shards = {k}");
            assert!(e.is_empty());
        }
    }

    #[test]
    fn shard_assignment_is_client_mod_k() {
        let mut e = EdgeAggregator::new(4);
        for c in 0..10 {
            e.push(up(c, 0, 1.0));
        }
        assert_eq!(e.occupancy(), vec![3, 3, 2, 2]);
        assert_eq!(e.arrivals(), vec![3, 3, 2, 2]);
        assert_eq!(e.len(), 10);
    }

    #[test]
    fn weight_partials_are_exact_and_reset_on_drain() {
        let mut e = EdgeAggregator::new(2);
        e.push(up(0, 0, 1.5));
        e.push(up(1, 0, 2.0));
        e.push(up(2, 0, 0.25));
        assert_eq!(e.weight_totals(), vec![1.75, 2.0]);
        e.drain_ordered();
        assert_eq!(e.weight_totals(), vec![0.0, 0.0]);
        assert_eq!(e.arrivals(), vec![2, 1], "arrivals are lifetime counters");
    }

    #[test]
    fn reshard_requires_an_untouched_tree() {
        let mut e = EdgeAggregator::new(1);
        e.set_shards(8);
        assert_eq!(e.n_shards(), 8);
        e.push(up(3, 0, 1.0));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e.set_shards(2);
        }));
        assert!(r.is_err(), "re-sharding a live tree must panic");
    }

    fn store(n: usize, lazy: bool) -> ClientStore {
        // detlint: allow(DET003) -- test-local root seed.
        let root = Rng::new(7);
        let parts: Vec<Vec<u32>> = (0..n).map(|i| vec![i as u32]).collect();
        ClientStore::new(parts, 4, &root, lazy, SpillKind::Slab)
    }

    #[test]
    fn lazy_materialization_matches_eager_construction() {
        // Same root, same client id, materialized in different orders →
        // identical sampler shuffles, RNG streams, and zero EF.
        let mut a = store(6, false);
        let mut b = store(6, true);
        // a touches 0..6 in order; b in reverse.
        for id in 0..6 {
            a.client(id);
        }
        for id in (0..6).rev() {
            let cb = b.client(id);
            assert_eq!(cb.n_samples, 1);
        }
        for id in 0..6 {
            let ra = a.client(id).rng.clone();
            let rb = b.client(id).rng.clone();
            // Drive both clones: identical draw sequences.
            let mut ra = ra;
            let mut rb = rb;
            for _ in 0..8 {
                assert_eq!(ra.next_u64(), rb.next_u64(), "client {id}");
            }
            assert_eq!(a.ef_of(id), b.ef_of(id));
        }
    }

    #[test]
    fn release_spills_and_readmission_restores_bitwise() {
        let mut s = store(3, true);
        {
            let c = s.client(1);
            c.ef = vec![1.0, -0.0, f32::from_bits(0x7FC0_0001), 2.5];
            c.rounds_participated = 3;
            c.last_version = Some(9);
        }
        s.release(1);
        assert_eq!(s.resident_count(), 0);
        assert_eq!(s.spilled_count(), 1);
        assert_eq!(s.spill_events(), 1);
        assert!(s.spilled_bytes() > 0);
        // Readable without re-materializing…
        let ef = s.ef_of(1);
        assert_eq!(ef[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(s.rounds_participated(1), 3);
        // …and re-admission restores everything bit-for-bit.
        let c = s.client(1);
        assert_eq!(c.ef.iter().map(|x| x.to_bits()).collect::<Vec<_>>(), vec![
            1.0f32.to_bits(),
            (-0.0f32).to_bits(),
            0x7FC0_0001,
            2.5f32.to_bits(),
        ]);
        assert_eq!(c.rounds_participated, 3);
        assert_eq!(c.last_version, Some(9));
        assert_eq!(s.spilled_count(), 0);
    }

    #[test]
    fn eager_store_never_evicts() {
        let mut s = store(3, false);
        s.client(0);
        s.release(0);
        assert_eq!(s.resident_count(), 1, "release is a no-op when not lazy");
        assert_eq!(s.spill_events(), 0);
    }

    #[test]
    fn peak_resident_tracks_the_high_water_mark() {
        let mut s = store(8, true);
        for id in 0..4 {
            s.client(id);
        }
        for id in 0..4 {
            s.release(id);
        }
        for id in 4..6 {
            s.client(id);
        }
        assert_eq!(s.resident_count(), 2);
        assert_eq!(s.peak_resident(), 4);
        assert_eq!(s.spilled_count(), 4);
    }

    #[test]
    fn zero_ef_spills_for_free() {
        let mut s = store(2, true);
        s.client(0);
        s.release(0);
        assert_eq!(s.spilled_bytes(), 0, "an untouched (all-zero) EF is elided");
    }
}
