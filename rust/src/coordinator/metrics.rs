//! Metrics sink: per-round records, optional JSONL file output.

use std::fs::File;
use std::io::{BufWriter, Write};

use anyhow::Result;

use crate::util::json::ObjWriter;

use super::experiment::RoundRecord;

pub struct MetricsSink {
    file: Option<BufWriter<File>>,
    pub records: Vec<RoundRecord>,
}

impl MetricsSink {
    /// `path = ""` keeps records in memory only.
    pub fn new(path: &str) -> Result<MetricsSink> {
        let file = if path.is_empty() {
            None
        } else {
            Some(BufWriter::new(File::create(path)?))
        };
        Ok(MetricsSink { file, records: Vec::new() })
    }

    pub fn push(&mut self, rec: RoundRecord) -> Result<()> {
        if let Some(f) = &mut self.file {
            let line = ObjWriter::new()
                .int("round", rec.round as i64)
                .num("test_acc", rec.test_acc)
                .num("test_loss", rec.test_loss)
                .int("n_selected", rec.n_selected as i64)
                .int("up_bytes_round", rec.up_bytes_round as i64)
                .int("up_bytes_cum", rec.up_bytes_cum as i64)
                .num("efficiency", rec.efficiency)
                .num("ratio", rec.ratio)
                .num("comm_time_s", rec.comm_time_s)
                .num("wall_ms", rec.wall_ms)
                .finish();
            writeln!(f, "{line}")?;
        }
        self.records.push(rec);
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.records.last()
    }

    /// Best (max) test accuracy seen.
    pub fn best_acc(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.test_acc)
            .fold(f64::NAN, f64::max)
    }
}
