//! Metrics sink: per-round records, optional JSONL file output.

use std::fs::File;
use std::io::{BufWriter, Write};

use anyhow::Result;

use crate::util::json::ObjWriter;

use super::experiment::RoundRecord;

pub struct MetricsSink {
    file: Option<BufWriter<File>>,
    pub records: Vec<RoundRecord>,
}

impl MetricsSink {
    /// `path = ""` keeps records in memory only.
    pub fn new(path: &str) -> Result<MetricsSink> {
        let file = if path.is_empty() {
            None
        } else {
            Some(BufWriter::new(File::create(path)?))
        };
        Ok(MetricsSink { file, records: Vec::new() })
    }

    pub fn push(&mut self, rec: RoundRecord) -> Result<()> {
        if let Some(f) = &mut self.file {
            let line = ObjWriter::new()
                .int("round", rec.round as i64)
                .num("test_acc", rec.test_acc)
                .num("test_loss", rec.test_loss)
                .int("n_selected", rec.n_selected as i64)
                .int("up_bytes_round", rec.up_bytes_round as i64)
                .int("up_bytes_cum", rec.up_bytes_cum as i64)
                .int("down_bytes_round", rec.down_bytes_round as i64)
                .int("down_bytes_cum", rec.down_bytes_cum as i64)
                .num("efficiency", rec.efficiency)
                .num("ratio", rec.ratio)
                .num("comm_time_s", rec.comm_time_s)
                .num("sim_time_s", rec.sim_time_s)
                .num("stale_mean", rec.stale_mean)
                .int("rejected_clients", rec.rejected_clients as i64)
                .num("trim_frac", rec.trim_frac)
                .num("wall_ms", rec.wall_ms)
                .num("eval_ms", rec.eval_ms)
                .finish();
            writeln!(f, "{line}")?;
        }
        self.records.push(rec);
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        if let Some(f) = &mut self.file {
            f.flush()?;
        }
        Ok(())
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.records.last()
    }

    /// Best (max) test accuracy seen.
    pub fn best_acc(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.test_acc)
            .fold(f64::NAN, f64::max)
    }

    /// Mean compression ratio over every recorded round with at least
    /// one participant — the stable summary for labels/tables (a single
    /// round's ratio is noisy under partial participation, and no-op
    /// rounds carry a 0.0 sentinel that must not deflate the mean). NaN
    /// when no such round has run yet.
    pub fn mean_ratio(&self) -> f64 {
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for r in self.records.iter().filter(|r| r.n_selected > 0) {
            sum += r.ratio;
            n += 1;
        }
        if n == 0 {
            return f64::NAN;
        }
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, ratio: f64) -> RoundRecord {
        RoundRecord {
            round,
            test_acc: 0.5,
            test_loss: 1.0,
            n_selected: 2,
            up_bytes_round: 10,
            up_bytes_cum: 10 * (round as u64 + 1),
            down_bytes_round: 88,
            down_bytes_cum: 88 * (round as u64 + 1),
            efficiency: 0.9,
            ratio,
            comm_time_s: 0.1,
            sim_time_s: 0.1 * (round as f64 + 1.0),
            stale_mean: 0.0,
            rejected_clients: 0,
            trim_frac: 0.0,
            wall_ms: 1.0,
            eval_ms: 0.0,
        }
    }

    #[test]
    fn mean_ratio_averages_all_rounds_not_just_the_last() {
        let mut m = MetricsSink::new("").unwrap();
        assert!(m.mean_ratio().is_nan());
        m.push(rec(0, 10.0)).unwrap();
        m.push(rec(1, 30.0)).unwrap();
        m.push(rec(2, 20.0)).unwrap();
        assert!((m.mean_ratio() - 20.0).abs() < 1e-12);
        // the last record alone would have said 20.0 only by accident;
        // make the distinction explicit with a skewed tail
        m.push(rec(3, 100.0)).unwrap();
        assert!((m.mean_ratio() - 40.0).abs() < 1e-12);
        assert_eq!(m.last().unwrap().ratio, 100.0);
    }

    #[test]
    fn mean_ratio_ignores_noop_rounds() {
        // A round with no participants records the 0.0 sentinel; it must
        // not deflate the mean.
        let mut m = MetricsSink::new("").unwrap();
        m.push(rec(0, 40.0)).unwrap();
        let mut empty = rec(1, 0.0);
        empty.n_selected = 0;
        m.push(empty).unwrap();
        assert!((m.mean_ratio() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn mean_ratio_over_only_skipped_rounds_is_nan() {
        // Every round skipped (tiny client_frac + unlucky partition):
        // there is no ratio to report, and NaN — not 0 — must say so, so
        // `Experiment::label()` omits the suffix instead of printing 0.0x.
        let mut m = MetricsSink::new("").unwrap();
        for round in 0..3 {
            let mut empty = rec(round, 0.0);
            empty.n_selected = 0;
            m.push(empty).unwrap();
        }
        assert!(m.mean_ratio().is_nan());
        // The first participating round flips it to that round's ratio.
        m.push(rec(3, 25.0)).unwrap();
        assert!((m.mean_ratio() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn mean_ratio_interleaves_skips_without_bias() {
        // skip, 10×, skip, 30× → mean 20, however the skips interleave.
        let mut m = MetricsSink::new("").unwrap();
        let mut skip0 = rec(0, 0.0);
        skip0.n_selected = 0;
        m.push(skip0).unwrap();
        m.push(rec(1, 10.0)).unwrap();
        let mut skip2 = rec(2, 0.0);
        skip2.n_selected = 0;
        m.push(skip2).unwrap();
        m.push(rec(3, 30.0)).unwrap();
        assert!((m.mean_ratio() - 20.0).abs() < 1e-12);
    }
}
