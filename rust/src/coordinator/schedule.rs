//! Client schedulers: which clients act each round.
//!
//! The paper's tables use full participation, but time-to-accuracy under
//! constrained links (§1) depends heavily on *who* uploads each round —
//! related compressor evaluations (STC, FedSZ) all report partial
//! participation. A [`ClientScheduler`] owns that decision so the round
//! loop in [`crate::coordinator::Experiment`] stays scenario-agnostic:
//!
//! * [`FullParticipation`] — every client, every round (the seed/paper
//!   protocol; the default).
//! * [`UniformSampler`] — `⌈frac·n⌉` clients drawn uniformly without
//!   replacement from a dedicated RNG stream (independent of data/batch
//!   sampling, so changing the schedule never perturbs local training).
//! * [`RoundRobin`] — a rotating contiguous cohort of `⌈frac·n⌉` clients;
//!   covers all `n` clients within `⌈1/frac⌉` rounds.
//!
//! Clients skipped in a round keep all their state (in particular the
//! error-feedback memory) untouched until their next participation.
//!
//! Any scheduler can be wrapped in a [`ReliabilityGate`]: an EWMA of
//! observed per-client upload losses (fed by `FedServer` from the same
//! signals behind `lost_uploads()`/`recovered_clients()`) that
//! quarantines chronically failing clients for `quarantine_rounds`
//! selection rounds before re-admitting them. Quarantine composes with
//! the lazy [`crate::coordinator::ClientStore`]: a quarantined client's
//! spilled EF slab sits untouched for however long the gate holds it
//! out, and re-admission restores it bit-exactly (pinned by
//! `tests/shard_test.rs`).

use crate::config::{ExperimentConfig, ScheduleKind};
use crate::util::rng::{stream, Rng};

/// Decides the participating client set for each round.
pub trait ClientScheduler {
    /// Indices (ascending, non-empty, ≤ `n_clients`) of the clients that
    /// train and upload in `round`. Stateful: round-robin advances its
    /// cursor, the uniform sampler consumes its RNG stream.
    fn select(&mut self, round: usize, n_clients: usize) -> Vec<usize>;

    /// Short name for logs/labels.
    fn name(&self) -> &'static str;

    /// Observe the outcome of one dispatched upload: `lost = true` when
    /// the fault layer killed it mid-transfer, `false` when it landed.
    /// Base schedulers ignore outcomes; reliability decorators feed
    /// their per-client estimate from here.
    fn observe(&mut self, _client: usize, _round: usize, _lost: bool) {}

    /// Clients this scheduler refuses to select at `round` (ascending).
    fn quarantined(&self, _round: usize) -> Vec<usize> {
        Vec::new()
    }

    /// Quarantine windows opened so far.
    fn quarantine_events(&self) -> u64 {
        0
    }
}

/// Cohort size for a participation fraction: `⌈frac·n⌉`, clamped to [1, n].
/// The epsilon absorbs f64 products that land just above an integer
/// (0.07 × 100 = 7.000000000000001 must mean 7 clients, not 8).
fn cohort_size(frac: f64, n: usize) -> usize {
    ((frac * n as f64 - 1e-9).ceil() as usize).clamp(1, n)
}

/// Every client participates every round (the paper's Table-2 protocol).
pub struct FullParticipation;

impl ClientScheduler for FullParticipation {
    fn select(&mut self, _round: usize, n_clients: usize) -> Vec<usize> {
        (0..n_clients).collect()
    }

    fn name(&self) -> &'static str {
        "full"
    }
}

/// Uniform random sampling without replacement at a fixed fraction.
pub struct UniformSampler {
    frac: f64,
    rng: Rng,
}

impl UniformSampler {
    /// `rng` must be a dedicated stream (see `Experiment::new`): the
    /// scheduler draws from it every round, and sharing it with any other
    /// consumer would entangle the schedule with training randomness.
    pub fn new(frac: f64, rng: Rng) -> UniformSampler {
        UniformSampler { frac, rng }
    }
}

impl ClientScheduler for UniformSampler {
    fn select(&mut self, _round: usize, n_clients: usize) -> Vec<usize> {
        let m = cohort_size(self.frac, n_clients);
        // Partial Fisher–Yates: the first m slots are a uniform sample.
        let mut pool: Vec<usize> = (0..n_clients).collect();
        for i in 0..m {
            let j = i + self.rng.below(n_clients - i);
            pool.swap(i, j);
        }
        pool.truncate(m);
        pool.sort_unstable();
        pool
    }

    fn name(&self) -> &'static str {
        "uniform"
    }
}

/// Deterministic rotating cohort: rounds take consecutive blocks of
/// `⌈frac·n⌉` clients modulo `n`, so every client participates within
/// `⌈1/frac⌉` rounds of its last turn.
pub struct RoundRobin {
    frac: f64,
    cursor: usize,
}

impl RoundRobin {
    pub fn new(frac: f64) -> RoundRobin {
        RoundRobin { frac, cursor: 0 }
    }
}

impl ClientScheduler for RoundRobin {
    fn select(&mut self, _round: usize, n_clients: usize) -> Vec<usize> {
        let m = cohort_size(self.frac, n_clients);
        let mut sel: Vec<usize> = (0..m).map(|i| (self.cursor + i) % n_clients).collect();
        self.cursor = (self.cursor + m) % n_clients;
        sel.sort_unstable();
        sel
    }

    fn name(&self) -> &'static str {
        "round_robin"
    }
}

/// Reliability-aware cohort gate: wraps any scheduler and filters its
/// selection through a per-client EWMA of observed upload losses.
///
/// * Each dispatched upload's outcome updates the client's estimate:
///   `e ← (1 − α)·e + α·[lost]`.
/// * When `e` crosses `threshold` the client is quarantined — skipped
///   by `select` for the next `quarantine_rounds` rounds — and its
///   estimate resets to 0 so re-admission starts from a clean slate.
/// * If quarantine would empty the cohort entirely, the gate steps
///   aside and returns the inner selection unfiltered: a starved
///   session is worse than a flaky one.
///
/// Fully deterministic: no draws, pure function of the observed loss
/// sequence, so gated trajectories stay bit-identical across thread
/// counts.
pub struct ReliabilityGate {
    inner: Box<dyn ClientScheduler>,
    alpha: f64,
    threshold: f64,
    quarantine_rounds: usize,
    /// Per-client loss EWMA, sized lazily to the fleet.
    ewma: Vec<f64>,
    /// Per-client quarantine horizon: skipped while `round < until[c]`.
    until: Vec<usize>,
    events: u64,
}

impl ReliabilityGate {
    pub fn new(
        inner: Box<dyn ClientScheduler>,
        alpha: f64,
        threshold: f64,
        quarantine_rounds: usize,
        n_clients: usize,
    ) -> ReliabilityGate {
        ReliabilityGate {
            inner,
            alpha,
            threshold,
            quarantine_rounds,
            ewma: vec![0.0; n_clients],
            until: vec![0; n_clients],
            events: 0,
        }
    }

    fn ensure(&mut self, n: usize) {
        if self.ewma.len() < n {
            self.ewma.resize(n, 0.0);
            self.until.resize(n, 0);
        }
    }

    /// The current loss estimate for one client (diagnostics/tests).
    pub fn estimate(&self, client: usize) -> f64 {
        self.ewma.get(client).copied().unwrap_or(0.0)
    }
}

impl ClientScheduler for ReliabilityGate {
    fn select(&mut self, round: usize, n_clients: usize) -> Vec<usize> {
        self.ensure(n_clients);
        let base = self.inner.select(round, n_clients);
        let kept: Vec<usize> =
            base.iter().copied().filter(|&c| round >= self.until[c]).collect();
        if kept.is_empty() {
            return base;
        }
        kept
    }

    fn name(&self) -> &'static str {
        "reliability"
    }

    fn observe(&mut self, client: usize, round: usize, lost: bool) {
        self.ensure(client + 1);
        self.inner.observe(client, round, lost);
        let x = if lost { 1.0 } else { 0.0 };
        self.ewma[client] = (1.0 - self.alpha) * self.ewma[client] + self.alpha * x;
        if round >= self.until[client] && self.ewma[client] > self.threshold {
            // Quarantine: skip rounds round+1 ..= round+quarantine_rounds.
            self.until[client] = round + 1 + self.quarantine_rounds;
            self.ewma[client] = 0.0;
            self.events += 1;
        }
    }

    fn quarantined(&self, round: usize) -> Vec<usize> {
        (0..self.until.len()).filter(|&c| round < self.until[c]).collect()
    }

    fn quarantine_events(&self) -> u64 {
        self.events
    }
}

/// Build the scheduler an [`ExperimentConfig`] describes (via
/// `effective_schedule`, so `client_frac < 1` alone selects uniform
/// sampling). `root` is the experiment's root RNG; the uniform sampler
/// splits its own stream off it so schedules replay bit-for-bit from the
/// experiment seed. `[defense] reliability = true` wraps the result in a
/// [`ReliabilityGate`].
pub fn build_scheduler(cfg: &ExperimentConfig, root: &Rng) -> Box<dyn ClientScheduler> {
    let base: Box<dyn ClientScheduler> = match cfg.effective_schedule() {
        ScheduleKind::Full => Box::new(FullParticipation),
        ScheduleKind::Uniform => Box::new(UniformSampler::new(
            cfg.client_frac,
            root.split(stream::SCHEDULE),
        )),
        ScheduleKind::RoundRobin => Box::new(RoundRobin::new(cfg.client_frac)),
    };
    if cfg.reliability {
        Box::new(ReliabilityGate::new(
            base,
            cfg.reliability_alpha,
            cfg.reliability_threshold,
            cfg.quarantine_rounds,
            cfg.n_clients,
        ))
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_selects_everyone() {
        let mut s = FullParticipation;
        assert_eq!(s.select(0, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(s.select(9, 3), vec![0, 1, 2]);
    }

    #[test]
    fn uniform_is_deterministic_under_fixed_seed() {
        // Satellite: same selected-set sequence across two identical runs.
        let root = Rng::new(42);
        let mut a = UniformSampler::new(0.3, root.split(stream::SCHEDULE));
        let mut b = UniformSampler::new(0.3, root.split(stream::SCHEDULE));
        for round in 0..50 {
            assert_eq!(a.select(round, 10), b.select(round, 10));
        }
    }

    #[test]
    fn uniform_sample_is_valid_and_varies() {
        let mut s = UniformSampler::new(0.3, Rng::new(7));
        let mut distinct = std::collections::BTreeSet::new();
        for round in 0..20 {
            let sel = s.select(round, 10);
            assert_eq!(sel.len(), 3);
            // ascending, in-range, no duplicates
            assert!(sel.windows(2).all(|w| w[0] < w[1]));
            assert!(sel.iter().all(|&i| i < 10));
            distinct.insert(sel);
        }
        assert!(distinct.len() > 1, "sampler never varied its cohort");
    }

    #[test]
    fn uniform_frac_one_is_full_participation() {
        let mut s = UniformSampler::new(1.0, Rng::new(1));
        assert_eq!(s.select(0, 6), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn round_robin_covers_all_clients_in_ceil_inv_frac_rounds() {
        // Satellite: coverage of all n clients within ⌈1/frac⌉ rounds.
        for (frac, n) in [(0.3f64, 10usize), (0.5, 4), (0.1, 100), (0.25, 7)] {
            let mut s = RoundRobin::new(frac);
            let budget = (1.0 / frac).ceil() as usize;
            let mut seen = std::collections::BTreeSet::new();
            for round in 0..budget {
                for i in s.select(round, n) {
                    seen.insert(i);
                }
            }
            assert_eq!(seen.len(), n, "frac={frac} n={n} budget={budget}");
        }
    }

    #[test]
    fn round_robin_cohorts_rotate() {
        let mut s = RoundRobin::new(0.5);
        assert_eq!(s.select(0, 4), vec![0, 1]);
        assert_eq!(s.select(1, 4), vec![2, 3]);
        assert_eq!(s.select(2, 4), vec![0, 1]);
    }

    #[test]
    fn round_robin_wraps_mid_cohort_when_n_not_divisible() {
        // n = 5, cohort 2: the third cohort wraps around the end of the
        // client range mid-cohort — [4, 0] — and the rotation keeps its
        // phase afterwards (no client skipped, none double-covered per
        // wrap cycle).
        let mut s = RoundRobin::new(0.4);
        assert_eq!(s.select(0, 5), vec![0, 1]);
        assert_eq!(s.select(1, 5), vec![2, 3]);
        assert_eq!(s.select(2, 5), vec![0, 4], "wrap-around cohort, returned ascending");
        assert_eq!(s.select(3, 5), vec![1, 2]);
        assert_eq!(s.select(4, 5), vec![3, 4]);
        // After 5 cohorts of 2 over 5 clients, every client served
        // exactly twice and the cursor is back at 0.
        assert_eq!(s.select(5, 5), vec![0, 1]);
        // n = 7 at frac 0.5 (cohort 4): wrap places the cursor so that
        // successive cohorts stay contiguous mod n.
        let mut s = RoundRobin::new(0.5);
        assert_eq!(s.select(0, 7), vec![0, 1, 2, 3]);
        assert_eq!(s.select(1, 7), vec![0, 4, 5, 6]);
        assert_eq!(s.select(2, 7), vec![1, 2, 3, 4]);
    }

    #[test]
    fn uniform_sampler_at_frac_extremes() {
        // frac → 0 clamps to a single-client cohort (never empty)…
        let mut tiny = UniformSampler::new(1e-12, Rng::new(9));
        for round in 0..10 {
            let sel = tiny.select(round, 10);
            assert_eq!(sel.len(), 1, "cohort floor is one client");
            assert!(sel[0] < 10);
        }
        // …and frac → 1 (just below) selects everyone, exactly once.
        let mut full = UniformSampler::new(1.0 - 1e-12, Rng::new(9));
        assert_eq!(full.select(0, 10), (0..10).collect::<Vec<_>>());
        // Single-client populations are served at any fraction.
        let mut one = UniformSampler::new(0.3, Rng::new(9));
        assert_eq!(one.select(0, 1), vec![0]);
    }

    #[test]
    fn reliability_gate_quarantine_lifecycle() {
        // α = 0.5, threshold = 0.5: two consecutive losses push the EWMA
        // to 0.75 > 0.5 and open a 3-round quarantine.
        let mut g =
            ReliabilityGate::new(Box::new(FullParticipation), 0.5, 0.5, 3, 4);
        assert_eq!(g.select(0, 4), vec![0, 1, 2, 3], "clean slate selects everyone");
        g.observe(2, 0, true);
        assert!((g.estimate(2) - 0.5).abs() < 1e-12);
        assert_eq!(g.select(1, 4), vec![0, 1, 2, 3], "at the threshold, not past it");
        g.observe(2, 1, true);
        assert_eq!(g.quarantine_events(), 1);
        assert_eq!(g.estimate(2), 0.0, "estimate resets on quarantine entry");
        // Skipped for exactly quarantine_rounds = 3 selection rounds…
        assert_eq!(g.select(2, 4), vec![0, 1, 3]);
        assert_eq!(g.select(3, 4), vec![0, 1, 3]);
        assert_eq!(g.select(4, 4), vec![0, 1, 3]);
        assert_eq!(g.quarantined(4), vec![2]);
        // …then re-admitted, and a healthy upload keeps it in.
        assert_eq!(g.select(5, 4), vec![0, 1, 2, 3], "re-admitted after serving time");
        g.observe(2, 5, false);
        assert_eq!(g.select(6, 4), vec![0, 1, 2, 3]);
        assert_eq!(g.quarantine_events(), 1, "no re-trigger from the clean upload");
    }

    #[test]
    fn reliability_gate_never_starves_the_session() {
        let mut g =
            ReliabilityGate::new(Box::new(FullParticipation), 1.0, 0.5, 10, 2);
        g.observe(0, 0, true);
        g.observe(1, 0, true);
        assert_eq!(g.quarantine_events(), 2, "α = 1 trips on a single loss");
        // Everyone is quarantined — the gate must step aside.
        assert_eq!(g.select(1, 2), vec![0, 1], "an empty cohort would hang the session");
    }

    #[test]
    fn reliability_gate_losses_decay_without_quarantine() {
        // Isolated losses between successes never cross a 0.6 threshold
        // at α = 0.3: the gate tolerates background flakiness.
        let mut g =
            ReliabilityGate::new(Box::new(FullParticipation), 0.3, 0.6, 3, 3);
        for round in 0..20 {
            g.observe(1, round, round % 3 == 0);
            assert!(g.estimate(1) < 0.6, "round {round}: {}", g.estimate(1));
        }
        assert_eq!(g.quarantine_events(), 0);
        assert_eq!(g.select(20, 3), vec![0, 1, 2]);
    }

    #[test]
    fn cohort_size_bounds() {
        assert_eq!(cohort_size(0.1, 10), 1);
        assert_eq!(cohort_size(0.1, 5), 1); // ceil(0.5) = 1
        assert_eq!(cohort_size(1.0, 10), 10);
        assert_eq!(cohort_size(0.05, 10), 1); // clamped up to 1
        assert_eq!(cohort_size(0.34, 10), 4); // ceil(3.4)
        // f64 products just above an integer must not inflate the cohort
        assert_eq!(cohort_size(0.07, 100), 7); // 0.07*100 = 7.000000000000001
        assert_eq!(cohort_size(0.56, 25), 14); // 0.56*25 = 14.000000000000002
    }
}
