//! Bench harness (offline replacement for `criterion`): timing with
//! warmup + repeated samples, fixed-width table printing shared by every
//! `benches/*.rs` target (`harness = false`), and the machine-readable
//! trajectory format behind `BENCH_hotpath.json` (schema documented in
//! EXPERIMENTS.md §Perf) that the CI perf-smoke job diffs across commits.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::util::json::{self, Value};

/// Samples from one timed closure, in milliseconds. Robust summaries
/// (median / p95) are first-class because container timing is jittery:
/// a mean is one noisy-neighbor page fault away from a fake regression.
#[derive(Clone, Debug)]
pub struct Timing {
    /// Sorted ascending.
    samples: Vec<f64>,
}

impl Timing {
    pub fn from_samples(mut samples: Vec<f64>) -> Timing {
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Timing { samples }
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }
    pub fn min(&self) -> f64 {
        self.samples.first().copied().unwrap_or(0.0)
    }
    pub fn max(&self) -> f64 {
        self.samples.last().copied().unwrap_or(0.0)
    }
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    pub fn median(&self) -> f64 {
        self.at_percentile(50.0)
    }
    pub fn p95(&self) -> f64 {
        self.at_percentile(95.0)
    }

    /// Nearest-rank percentile, indexing the already-sorted samples
    /// (same convention as `util::stats::percentile`, without the
    /// clone + re-sort).
    fn at_percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let rank = ((p / 100.0) * (self.samples.len() as f64 - 1.0)).round() as usize;
        self.samples[rank.min(self.samples.len() - 1)]
    }
}

/// Time `f` with `warmup` throwaway calls and `iters` measured calls.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Timing::from_samples(samples)
}

/// Print a `name  median ms (p95, min..max, n)` line.
pub fn report(name: &str, t: &Timing) {
    println!(
        "{name:<44} {:>9.3} ms med (p95 {:>8.3}, min {:.3}, max {:.3}, n={})",
        t.median(),
        t.p95(),
        t.min(),
        t.max(),
        t.count()
    );
}

/// One named measurement destined for the trajectory JSON.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    /// Stable key — baselines are diffed by this name across commits.
    pub name: String,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub mean_ns: f64,
    pub n: usize,
}

impl BenchRecord {
    pub fn new(name: &str, t: &Timing) -> BenchRecord {
        BenchRecord {
            name: name.to_string(),
            median_ns: t.median() * 1e6,
            p95_ns: t.p95() * 1e6,
            mean_ns: t.mean() * 1e6,
            n: t.count(),
        }
    }
}

/// Best-effort commit id for the trajectory record: `GITHUB_SHA` in CI,
/// `git rev-parse HEAD` locally, `"unknown"` when neither resolves.
pub fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.trim().is_empty() {
            return sha.trim().to_string();
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Serialize one bench run (schema 1, EXPERIMENTS.md §Perf). `calibrated`
/// marks numbers measured on real hardware; the seeded placeholder
/// baseline carries `false` so CI never gates on made-up figures.
pub fn bench_json(
    backend: &str,
    model: &str,
    params: usize,
    calibrated: bool,
    records: &[BenchRecord],
) -> String {
    let mut ops = String::from("{");
    for (i, r) in records.iter().enumerate() {
        if i > 0 {
            ops.push(',');
        }
        let _ = write!(
            ops,
            "\"{}\":{{\"median_ns\":{:.1},\"p95_ns\":{:.1},\"mean_ns\":{:.1},\"n\":{}}}",
            json::escape(&r.name),
            r.median_ns,
            r.p95_ns,
            r.mean_ns,
            r.n
        );
    }
    ops.push('}');
    format!(
        "{{\"schema\":1,\"backend\":\"{}\",\"model\":\"{}\",\"params\":{},\"git_sha\":\"{}\",\
         \"calibrated\":{},\"ops\":{}}}\n",
        json::escape(backend),
        json::escape(model),
        params,
        json::escape(&git_sha()),
        calibrated,
        ops
    )
}

/// A parsed baseline: (calibrated, op name → median ns).
pub fn parse_bench_json(text: &str) -> Result<(bool, BTreeMap<String, f64>)> {
    let v = json::parse(text)?;
    ensure!(
        v.req("schema")?.as_usize()? == 1,
        "unsupported bench schema (want 1)"
    );
    let calibrated = matches!(v.req("calibrated")?, Value::Bool(true));
    let mut ops = BTreeMap::new();
    for (name, op) in v.req("ops")?.as_obj()? {
        ops.insert(name.clone(), op.req("median_ns")?.as_f64()?);
    }
    Ok((calibrated, ops))
}

/// Ops whose current median exceeds `max_ratio ×` the baseline median.
/// Only names present in both runs are compared, so adding or renaming
/// benches never fails the smoke job by itself.
pub fn regressions(
    current: &BTreeMap<String, f64>,
    baseline: &BTreeMap<String, f64>,
    max_ratio: f64,
) -> Vec<String> {
    let mut bad = Vec::new();
    for (name, &base) in baseline {
        if let Some(&cur) = current.get(name) {
            if base > 0.0 && cur > base * max_ratio {
                bad.push(format!(
                    "{name}: {:.0} ns vs baseline {:.0} ns ({:.1}x > {max_ratio}x)",
                    cur,
                    base,
                    cur / base
                ));
            }
        }
    }
    bad
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(widths: &[usize]) -> Table {
        Table { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            line.push_str(&format!("{cell:<w$} "));
        }
        println!("{}", line.trim_end());
    }

    pub fn sep(&self) {
        let total: usize = self.widths.iter().sum::<usize>() + self.widths.len();
        println!("{}", "-".repeat(total));
    }
}

/// `ENV`-style knob for scaling bench workloads, e.g. `ROUNDS=40`.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`), or `None` where the probe does not exist
/// (non-Linux, or a hardened procfs). The high-water mark — not the
/// current RSS — is what `bench scale` reports: it is monotone over the
/// run, so it captures the worst cohort the process ever held.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
            return Some(kb * 1024);
        }
    }
    None
}

/// Render an optional byte count for bench tables: `12.3 MiB`, or the
/// `-` sentinel when the probe is unavailable (keeps snapshot goldens
/// platform-independent).
pub fn fmt_bytes_opt(bytes: Option<u64>) -> String {
    match bytes {
        Some(b) => format!("{:.1} MiB", b as f64 / (1024.0 * 1024.0)),
        None => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_collects_samples() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.count(), 5);
        assert!(t.mean() >= 0.0);
        assert!(t.median() >= t.min() && t.median() <= t.max());
        assert!(t.p95() >= t.median());
    }

    #[test]
    fn timing_percentiles_on_known_data() {
        let t = Timing::from_samples(vec![5.0, 1.0, 3.0, 2.0, 4.0]);
        assert_eq!(t.median(), 3.0);
        assert_eq!(t.min(), 1.0);
        assert_eq!(t.max(), 5.0);
        assert_eq!(t.p95(), 5.0);
        assert!((t.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn bench_json_roundtrips_through_parser() {
        let recs = vec![
            BenchRecord {
                name: "local_train_k5".into(),
                median_ns: 1234.5,
                p95_ns: 2000.0,
                mean_ns: 1300.0,
                n: 10,
            },
            BenchRecord {
                name: "eval_batch".into(),
                median_ns: 10.0,
                p95_ns: 12.0,
                mean_ns: 10.5,
                n: 20,
            },
        ];
        let doc = bench_json("native", "mlp10", 198_760, true, &recs);
        let (calibrated, ops) = parse_bench_json(&doc).unwrap();
        assert!(calibrated);
        assert_eq!(ops.len(), 2);
        assert!((ops["local_train_k5"] - 1234.5).abs() < 1e-6);
        assert!((ops["eval_batch"] - 10.0).abs() < 1e-6);
    }

    #[test]
    fn regressions_flag_only_shared_slow_ops() {
        let mut base = BTreeMap::new();
        base.insert("a".to_string(), 100.0);
        base.insert("b".to_string(), 100.0);
        base.insert("gone".to_string(), 100.0);
        let mut cur = BTreeMap::new();
        cur.insert("a".to_string(), 250.0); // 2.5x: fine at 3x
        cur.insert("b".to_string(), 400.0); // 4x: regression
        cur.insert("new".to_string(), 9999.0); // not in baseline: ignored
        let bad = regressions(&cur, &base, 3.0);
        assert_eq!(bad.len(), 1);
        assert!(bad[0].starts_with("b:"), "{}", bad[0]);
    }

    #[test]
    fn uncalibrated_baseline_parses() {
        let seed = "{\"schema\":1,\"backend\":\"native\",\"model\":\"mlp10\",\"params\":198760,\
                    \"git_sha\":\"seed\",\"calibrated\":false,\"ops\":{}}";
        let (calibrated, ops) = parse_bench_json(seed).unwrap();
        assert!(!calibrated);
        assert!(ops.is_empty());
    }

    #[test]
    fn env_knob_defaults() {
        assert_eq!(env_usize("FED3SFC_DEFINITELY_UNSET", 7), 7);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn peak_rss_probe_reads_vmhwm() {
        let peak = peak_rss_bytes().expect("Linux exposes VmHWM");
        // Any running process has touched at least a page.
        assert!(peak >= 4096, "implausible peak RSS {peak}");
    }

    #[test]
    fn byte_formatter_has_a_portable_sentinel() {
        assert_eq!(fmt_bytes_opt(None), "-");
        assert_eq!(fmt_bytes_opt(Some(12 * 1024 * 1024)), "12.0 MiB");
    }
}
