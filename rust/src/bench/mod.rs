//! Bench harness (offline replacement for `criterion`): timing with
//! warmup + repeated samples, and fixed-width table printing shared by
//! every `benches/*.rs` target (`harness = false`).

use std::time::Instant;

use crate::util::stats::OnlineStats;

/// Time `f` with `warmup` throwaway calls and `iters` measured calls.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> OnlineStats {
    for _ in 0..warmup {
        f();
    }
    let mut stats = OnlineStats::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    stats
}

/// Print a `name  mean ± std ms  (min..max, n)` line.
pub fn report(name: &str, stats: &OnlineStats) {
    println!(
        "{name:<44} {:>9.3} ms ± {:>7.3}  (min {:.3}, max {:.3}, n={})",
        stats.mean(),
        stats.std(),
        stats.min(),
        stats.max(),
        stats.count()
    );
}

/// Fixed-width table printer for paper-style rows.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    pub fn new(widths: &[usize]) -> Table {
        Table { widths: widths.to_vec() }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (i, cell) in cells.iter().enumerate() {
            let w = self.widths.get(i).copied().unwrap_or(12);
            line.push_str(&format!("{cell:<w$} "));
        }
        println!("{}", line.trim_end());
    }

    pub fn sep(&self) {
        let total: usize = self.widths.iter().sum::<usize>() + self.widths.len();
        println!("{}", "-".repeat(total));
    }
}

/// `ENV`-style knob for scaling bench workloads, e.g. `ROUNDS=40`.
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_collects_samples() {
        let stats = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(stats.count(), 5);
        assert!(stats.mean() >= 0.0);
    }

    #[test]
    fn env_knob_defaults() {
        assert_eq!(env_usize("FED3SFC_DEFINITELY_UNSET", 7), 7);
    }
}
