//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed from `artifacts/manifest.json`.

pub mod manifest;

pub use manifest::{Manifest, ModelInfo, OpInfo};
