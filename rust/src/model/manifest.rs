//! `artifacts/manifest.json` loader — every static shape the runtime needs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json;

/// One lowered fed-op variant.
#[derive(Clone, Debug)]
pub struct OpInfo {
    pub name: String,
    pub file: String,
    pub kind: String,
    /// Local iterations K (train/fedsynth ops).
    pub k: usize,
    /// Batch size (train/grad/eval ops).
    pub batch: usize,
    /// Synthetic sample count m (syn/fedsynth ops).
    pub m: usize,
}

/// One model's artifact set.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub params: usize,
    pub input_shape: Vec<usize>,
    pub n_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub init_file: String,
    pub ops: BTreeMap<String, OpInfo>,
}

impl ModelInfo {
    pub fn feature_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    pub fn op(&self, name: &str) -> Result<&OpInfo> {
        self.ops
            .get(name)
            .ok_or_else(|| anyhow!("model '{}' has no op '{name}'", self.name))
    }

    /// 3SFC payload bytes for m synthetic samples: m·(d+C)+1 floats (Eq. 7's
    /// ‖D‖₀ + 1 budget accounting) plus the u32 `m` header the wire format
    /// charges (see [`crate::compress::Payload::wire_bytes`]).
    pub fn syn_payload_bytes(&self, m: usize) -> usize {
        4 * (m * (self.feature_len() + self.n_classes) + 1) + 4
    }

    /// Uncompressed gradient payload (4P bytes).
    pub fn dense_payload_bytes(&self) -> usize {
        4 * self.params
    }
}

/// The whole artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let root = json::parse(text)?;
        let mut models = BTreeMap::new();
        for (name, mv) in root.req("models")?.as_obj()? {
            let mut ops = BTreeMap::new();
            for (op_name, ov) in mv.req("ops")?.as_obj()? {
                ops.insert(
                    op_name.clone(),
                    OpInfo {
                        name: op_name.clone(),
                        file: ov.req("file")?.as_str()?.to_string(),
                        kind: ov.req("kind")?.as_str()?.to_string(),
                        k: ov.get("k").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
                        batch: ov
                            .get("batch")
                            .map(|v| v.as_usize())
                            .transpose()?
                            .unwrap_or(0),
                        m: ov.get("m").map(|v| v.as_usize()).transpose()?.unwrap_or(0),
                    },
                );
            }
            let input_shape = mv
                .req("input_shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<Vec<_>>>()?;
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    params: mv.req("params")?.as_usize()?,
                    input_shape,
                    n_classes: mv.req("n_classes")?.as_usize()?,
                    train_batch: mv.req("train_batch")?.as_usize()?,
                    eval_batch: mv.req("eval_batch")?.as_usize()?,
                    init_file: mv.req("init")?.as_str()?.to_string(),
                    ops,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("manifest has no model '{name}'"))
    }

    /// Load a model's packed initial weights.
    pub fn load_init(&self, model: &ModelInfo) -> Result<Vec<f32>> {
        let path = self.dir.join(&model.init_file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        anyhow::ensure!(
            bytes.len() == model.params * 4,
            "init file {} has {} bytes, expected {}",
            model.init_file,
            bytes.len(),
            model.params * 4
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "version": 1,
      "models": {
        "mlp_small": {
          "params": 2344,
          "input_shape": [64],
          "n_classes": 8,
          "train_batch": 16,
          "eval_batch": 50,
          "init": "mlp_small.init.bin",
          "ops": {
            "train_k5": {"file": "mlp_small__train_k5.hlo.txt", "kind": "train", "k": 5, "batch": 16},
            "syn_step_m1": {"file": "mlp_small__syn_step_m1.hlo.txt", "kind": "syn_step", "m": 1}
          }
        }
      }
    }"#;

    #[test]
    fn parses_models_and_ops() {
        let m = Manifest::parse(Path::new("/tmp"), DOC).unwrap();
        let mdl = m.model("mlp_small").unwrap();
        assert_eq!(mdl.params, 2344);
        assert_eq!(mdl.feature_len(), 64);
        assert_eq!(mdl.op("train_k5").unwrap().k, 5);
        assert_eq!(mdl.op("syn_step_m1").unwrap().m, 1);
        assert!(mdl.op("nope").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn payload_math() {
        let m = Manifest::parse(Path::new("/tmp"), DOC).unwrap();
        let mdl = m.model("mlp_small").unwrap();
        assert_eq!(mdl.syn_payload_bytes(1), 4 * (64 + 8 + 1) + 4);
        assert_eq!(mdl.dense_payload_bytes(), 4 * 2344);
    }
}
