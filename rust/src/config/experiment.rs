//! Typed experiment configuration — the single source of truth a run,
//! example, or bench consumes. Built from a TOML preset and/or CLI flags.

use anyhow::{bail, Result};

use super::toml::{parse_toml, TomlDoc};

/// Which procedural dataset to synthesize (paper → substitution, DESIGN §3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    SynthMnist,
    SynthEmnist,
    SynthFmnist,
    SynthCifar10,
    SynthCifar100,
    /// 64-d toy set matching `mlp_small` (tests / CI).
    SynthSmall,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "synth_mnist" | "mnist" => DatasetKind::SynthMnist,
            "synth_emnist" | "emnist" => DatasetKind::SynthEmnist,
            "synth_fmnist" | "fmnist" => DatasetKind::SynthFmnist,
            "synth_cifar10" | "cifar10" => DatasetKind::SynthCifar10,
            "synth_cifar100" | "cifar100" => DatasetKind::SynthCifar100,
            "synth_small" | "small" => DatasetKind::SynthSmall,
            _ => bail!("unknown dataset '{s}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::SynthMnist => "synth_mnist",
            DatasetKind::SynthEmnist => "synth_emnist",
            DatasetKind::SynthFmnist => "synth_fmnist",
            DatasetKind::SynthCifar10 => "synth_cifar10",
            DatasetKind::SynthCifar100 => "synth_cifar100",
            DatasetKind::SynthSmall => "synth_small",
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            DatasetKind::SynthEmnist => 26,
            DatasetKind::SynthCifar100 => 20, // 100→20 scale-down, DESIGN §3
            DatasetKind::SynthSmall => 8,
            _ => 10,
        }
    }

    /// Per-sample feature length (matches the manifest input shapes).
    pub fn feature_len(&self) -> usize {
        match self {
            DatasetKind::SynthMnist | DatasetKind::SynthEmnist | DatasetKind::SynthFmnist => 784,
            DatasetKind::SynthCifar10 | DatasetKind::SynthCifar100 => 16 * 16 * 3,
            DatasetKind::SynthSmall => 64,
        }
    }

    /// Image layout (h, w, c); 1×d×1 for flat sets.
    pub fn image_dims(&self) -> (usize, usize, usize) {
        match self {
            DatasetKind::SynthMnist | DatasetKind::SynthEmnist | DatasetKind::SynthFmnist => {
                (28, 28, 1)
            }
            DatasetKind::SynthCifar10 | DatasetKind::SynthCifar100 => (16, 16, 3),
            DatasetKind::SynthSmall => (1, 64, 1),
        }
    }

    /// Default model key for this dataset (paper's main pairings).
    pub fn default_model(&self) -> &'static str {
        match self {
            DatasetKind::SynthMnist | DatasetKind::SynthFmnist => "mlp10",
            DatasetKind::SynthEmnist => "mlp26",
            DatasetKind::SynthCifar10 => "convnet",
            DatasetKind::SynthCifar100 => "resnet8_c20",
            DatasetKind::SynthSmall => "mlp_small",
        }
    }
}

/// Which clients act each round (see `coordinator::schedule`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    /// Every client, every round (the paper's protocol; default).
    Full,
    /// `⌈client_frac·n⌉` clients drawn uniformly without replacement.
    Uniform,
    /// Rotating cohort of `⌈client_frac·n⌉` clients.
    RoundRobin,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "full" => ScheduleKind::Full,
            "uniform" | "random" => ScheduleKind::Uniform,
            "round_robin" | "roundrobin" | "rr" => ScheduleKind::RoundRobin,
            _ => bail!("unknown schedule '{s}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Full => "full",
            ScheduleKind::Uniform => "uniform",
            ScheduleKind::RoundRobin => "round_robin",
        }
    }
}

/// Server-side optimizer applied to the aggregated pseudo-gradient
/// (see `coordinator::opt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ServerOptKind {
    /// `w ← w − server_lr·ḡ`; `server_lr = 1` is the paper's Eq. 3 (default).
    Gd,
    /// Heavy-ball momentum with coefficient `server_momentum`.
    Momentum,
    /// FedAdam (Reddi et al.) with `adam_beta1/adam_beta2/adam_tau`.
    FedAdam,
}

impl ServerOptKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gd" | "sgd" => ServerOptKind::Gd,
            "momentum" => ServerOptKind::Momentum,
            "fedadam" | "adam" => ServerOptKind::FedAdam,
            _ => bail!("unknown server optimizer '{s}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServerOptKind::Gd => "gd",
            ServerOptKind::Momentum => "momentum",
            ServerOptKind::FedAdam => "fedadam",
        }
    }
}

/// Byzantine-robust aggregation rule applied to each step's decoded
/// batch before the server-optimizer step (see `coordinator::robust`;
/// `[defense]` table / `--aggregator`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AggregatorKind {
    /// Plain weighted mean — bit-identical to the pre-defense path
    /// (default).
    WeightedMean,
    /// Coordinate-wise β-trimmed mean (Yin et al.), `defense.trim_beta`.
    TrimmedMean,
    /// Coordinate-wise weighted median.
    CoordinateMedian,
    /// Classic Krum: keep the single best-scored recon under an assumed
    /// `defense.krum_f` attackers (Blanchard et al.).
    Krum,
    /// Multi-Krum: keep the `defense.krum_m` best-scored recons
    /// (0 = auto, n − f).
    MultiKrum,
    /// L2 norm clipping at `defense.clip_tau` before the weighted mean.
    NormClip,
}

impl AggregatorKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "weighted_mean" | "mean" => AggregatorKind::WeightedMean,
            "trimmed_mean" | "trimmed" => AggregatorKind::TrimmedMean,
            "coordinate_median" | "median" => AggregatorKind::CoordinateMedian,
            "krum" => AggregatorKind::Krum,
            "multi_krum" | "multikrum" => AggregatorKind::MultiKrum,
            "norm_clip" | "clip" => AggregatorKind::NormClip,
            _ => bail!(
                "unknown aggregator '{s}' (want weighted_mean|trimmed_mean|\
                 coordinate_median|krum|multi_krum|norm_clip)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggregatorKind::WeightedMean => "weighted_mean",
            AggregatorKind::TrimmedMean => "trimmed_mean",
            AggregatorKind::CoordinateMedian => "coordinate_median",
            AggregatorKind::Krum => "krum",
            AggregatorKind::MultiKrum => "multi_krum",
            AggregatorKind::NormClip => "norm_clip",
        }
    }
}

/// Link model preset for the in-loop round-time accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NetworkKind {
    /// Constrained edge client: 10 Mbps up / 50 Mbps down / 30 ms (default).
    Edge,
    /// Datacenter link: 10 Gbps symmetric / 0.5 ms.
    Datacenter,
    /// Rates taken from `net_up_mbps`/`net_down_mbps`/`net_latency_ms`.
    Custom,
}

impl NetworkKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "edge" => NetworkKind::Edge,
            "datacenter" | "dc" => NetworkKind::Datacenter,
            "custom" => NetworkKind::Custom,
            _ => bail!("unknown network '{s}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            NetworkKind::Edge => "edge",
            NetworkKind::Datacenter => "datacenter",
            NetworkKind::Custom => "custom",
        }
    }
}

/// How the server turns client uploads into global steps (see
/// `coordinator::policy`). All three run on the simnet virtual clock;
/// they differ in *when* the server aggregates.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SessionKind {
    /// Barrier on the selected cohort: aggregate when every selected
    /// upload has arrived (the paper's protocol; default — reproduces
    /// the synchronous round loop bit-for-bit).
    Sync,
    /// Semi-synchronous: aggregate whatever arrived within `deadline_s`
    /// virtual seconds of the broadcast; stragglers' uploads carry over
    /// into the next aggregation with a staleness discount.
    Deadline,
    /// FedBuff-style buffered asynchrony: aggregate every `buffer_k`
    /// arrivals with staleness-discounted weights; finished clients are
    /// immediately re-dispatched on the current model. The scheduler is
    /// consulted once, at session start: its cohort becomes the fixed
    /// in-flight set (FedBuff's "M clients training concurrently"), so
    /// a partial-participation schedule caps concurrency rather than
    /// rotating participants.
    Async,
}

impl SessionKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sync" | "synchronous" => SessionKind::Sync,
            "deadline" | "semi_sync" | "semisync" => SessionKind::Deadline,
            "async" | "buffered_async" | "fedbuff" => SessionKind::Async,
            _ => bail!("unknown session mode '{s}' (want sync|deadline|async)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SessionKind::Sync => "sync",
            SessionKind::Deadline => "deadline",
            SessionKind::Async => "async",
        }
    }
}

/// Which compute backend executes the fed-ops (see `runtime::backend`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Resolve at open time: `FED3SFC_BACKEND` env var if set, else PJRT
    /// when an artifact directory is present, else native (default).
    Auto,
    /// AOT HLO artifacts through the PJRT CPU client (`pjrt` feature).
    Pjrt,
    /// Pure-Rust reference implementation — no artifacts required.
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => BackendKind::Auto,
            "pjrt" | "xla" => BackendKind::Pjrt,
            "native" | "rust" => BackendKind::Native,
            _ => bail!("unknown backend '{s}' (want auto|pjrt|native)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
        }
    }
}

/// Compression method (the paper's competitor zoo + the contribution).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CompressorKind {
    /// FedAvg — no compression (1× baseline).
    FedAvg,
    /// DGC-style top-k sparsification with error feedback.
    Dgc,
    /// signSGD with error feedback (1 bit + scale).
    SignSgd,
    /// STC — top-k + mean-magnitude ternarization + EF.
    Stc,
    /// 3SFC — the paper's single-step synthetic-features compressor.
    ThreeSfc,
    /// FedSynth — multi-step L2 data-distillation baseline (Table 1).
    FedSynth,
}

impl CompressorKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fedavg" | "none" => CompressorKind::FedAvg,
            "dgc" | "topk" => CompressorKind::Dgc,
            "signsgd" | "sign" => CompressorKind::SignSgd,
            "stc" => CompressorKind::Stc,
            "3sfc" | "threesfc" => CompressorKind::ThreeSfc,
            "fedsynth" => CompressorKind::FedSynth,
            _ => bail!("unknown compressor '{s}'"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompressorKind::FedAvg => "fedavg",
            CompressorKind::Dgc => "dgc",
            CompressorKind::SignSgd => "signsgd",
            CompressorKind::Stc => "stc",
            CompressorKind::ThreeSfc => "3sfc",
            CompressorKind::FedSynth => "fedsynth",
        }
    }
}

/// Downlink (broadcast) compression method (`[downlink]` table /
/// `--downlink`). The server compresses its model *delta* against each
/// client's last acked version with server-side error feedback
/// (E-3SFC's double-way construction; see `compress::downlink`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DownlinkKind {
    /// Dense keyframe broadcasts — bit-identical to the pre-downlink
    /// ledger (default).
    Identity,
    /// 3SFC synthesizing the model delta (the E-3SFC extension).
    ThreeSfc,
    /// DGC-style top-k on the model delta.
    TopK,
    /// STC ternary top-k on the model delta.
    Stc,
}

impl DownlinkKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "identity" | "dense" | "none" => DownlinkKind::Identity,
            "3sfc" | "threesfc" => DownlinkKind::ThreeSfc,
            "topk" | "dgc" => DownlinkKind::TopK,
            "stc" => DownlinkKind::Stc,
            _ => bail!("unknown downlink '{s}' (want identity|3sfc|topk|stc)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DownlinkKind::Identity => "identity",
            DownlinkKind::ThreeSfc => "3sfc",
            DownlinkKind::TopK => "topk",
            DownlinkKind::Stc => "stc",
        }
    }
}

/// How a lazy client store encodes an evicted client's EF residual
/// (`[scale] spill` / `--spill`; see `compress::spill`). Both encodings
/// are bit-exact — the knob trades transcoding work against slab layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpillKind {
    /// The f32 vector moved off the resident path as-is.
    Boxed,
    /// Dense-payload byte slab (flat little-endian f32 through the wire
    /// codec; default).
    Slab,
}

impl SpillKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "boxed" | "box" => SpillKind::Boxed,
            "slab" | "bytes" => SpillKind::Slab,
            _ => bail!("unknown spill encoding '{s}' (want boxed|slab)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            SpillKind::Boxed => "boxed",
            SpillKind::Slab => "slab",
        }
    }
}

/// Full experiment description. Defaults mirror the paper's §6.1 settings
/// (lr=0.01, K=5, λ=0, EF on) at the scaled-down workload sizes of DESIGN §3.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub dataset: DatasetKind,
    /// Manifest model key; empty → dataset default.
    pub model: String,
    pub n_clients: usize,
    pub rounds: usize,
    /// Local SGD iterations per round (paper K; artifacts exist for 1/5/10).
    pub k_local: usize,
    pub lr: f32,
    pub compressor: CompressorKind,
    /// Budget multiplier: 1→m=1 synthetic sample, 2→m=2, 4→m=4 (Tables 3/4).
    pub budget_mult: usize,
    /// 3SFC encoder iterations S (Algorithm 1 line 7).
    pub syn_steps: usize,
    pub lr_syn: f32,
    /// λ regularization in Eq. 7 (paper uses 0).
    pub lambda: f32,
    /// Error feedback on/off (Table 4 ablation).
    pub error_feedback: bool,
    /// Explicit top-k rate for DGC; 0 → match 3SFC's byte budget (paper's
    /// "same compression rate" protocol).
    pub topk_rate: f64,
    /// Dirichlet concentration for the non-i.i.d. partition (Fig 5).
    pub alpha: f64,
    /// Total training samples synthesized across clients.
    pub train_samples: usize,
    pub test_samples: usize,
    pub seed: u64,
    pub eval_every: usize,
    /// FedSynth settings (Table 1 / Figs 2–3).
    pub fedsynth_ksim: usize,
    pub fedsynth_lr_inner: f32,
    pub fedsynth_steps: usize,
    pub fedsynth_lr_syn: f32,
    /// Optional metrics JSONL path ("" → none).
    pub metrics_path: String,
    /// Client participation schedule (`[schedule]` table).
    pub schedule: ScheduleKind,
    /// Fraction of clients per round for uniform/round-robin schedules.
    pub client_frac: f64,
    /// Server optimizer (`[server_opt]` table).
    pub server_opt: ServerOptKind,
    /// Server learning rate η_s (1.0 ≡ the paper's unit step).
    pub server_lr: f32,
    /// Heavy-ball coefficient for `server_opt = "momentum"`.
    pub server_momentum: f32,
    /// FedAdam first/second-moment decay and adaptivity degree τ.
    pub adam_beta1: f32,
    pub adam_beta2: f32,
    pub adam_tau: f32,
    /// Link model for in-loop round-time accounting (`[network]` table).
    pub network: NetworkKind,
    pub net_up_mbps: f64,
    pub net_down_mbps: f64,
    pub net_latency_ms: f64,
    /// Per-client bandwidth spread in [0, 1): each client's up/down rate
    /// is scaled by a factor drawn from `[1 − jitter, 1 + jitter]` on a
    /// dedicated RNG stream (`[network] jitter`). 0 = homogeneous links.
    pub net_jitter: f64,
    /// Aggregation policy for the event-driven session (`[session]`
    /// table / `--session`).
    pub session: SessionKind,
    /// Semi-sync aggregation deadline in virtual seconds after each
    /// broadcast (`session = "deadline"` only).
    pub deadline_s: f64,
    /// Aggregate every K arrivals (`session = "async"` only).
    pub buffer_k: usize,
    /// Staleness discount base γ ∈ (0, 1]: an update `s` model versions
    /// old is aggregation-weighted by `|D_i| · γ^s` (deadline/async).
    pub staleness_decay: f64,
    /// Worker threads for the per-round client fan-out (`[runtime]`
    /// table / `--threads`): `0` = auto (available parallelism, or the
    /// `FED3SFC_THREADS` env var when set), `1` = the sequential seed
    /// path. Trajectories are bit-identical for every value.
    pub threads: usize,
    /// Compute backend (`[runtime] backend` / `--backend` /
    /// `FED3SFC_BACKEND`): PJRT artifacts or the pure-Rust native path.
    pub backend: BackendKind,
    /// Explicit initial global weights (builder-only; e.g. the
    /// backend-parity test pins both backends to one init). `None` asks
    /// the backend for its deterministic He-normal init.
    pub init_weights: Option<Vec<f32>>,
    /// Downlink broadcast compression (`[downlink]` table / `--downlink`).
    pub downlink: DownlinkKind,
    /// Keyframe fallback threshold: clients more than `gap` model
    /// versions behind get a dense keyframe instead of a delta.
    pub downlink_gap: usize,
    /// Explicit sparsity rate for a top-k/STC downlink; 0 → top-k matches
    /// 3SFC's byte budget and STC uses its natural 1/32 (same protocol as
    /// the uplink zoo).
    pub downlink_rate: f64,
    /// Adversarial fault layer master switch (`[faults]` table /
    /// `--faults`). Off by default; off means *zero* RNG draws and
    /// bit-identical trajectories to pre-fault builds.
    pub faults: bool,
    /// Base per-dispatch upload-loss probability in [0, 1].
    pub fault_dropout_p: f64,
    /// Virtual seconds a client stays down after losing an upload.
    pub fault_recover_s: f64,
    /// Diurnal availability-wave amplitude in [0, 1]; 0 disables it.
    pub fault_diurnal_amp: f64,
    /// Diurnal wave period in virtual seconds.
    pub fault_diurnal_period_s: f64,
    /// Device-class tiers (1 = homogeneous; >1 draws one correlated
    /// compute × bandwidth × reliability tier per client).
    pub fault_tiers: usize,
    /// How far the worst tier sits from the best, in [0, 1].
    pub fault_tier_spread: f64,
    /// Extra upload delay (seconds) of the worst tier at spread 1.
    pub fault_tier_compute_s: f64,
    /// Fraction of the fleet the byzantine attacker controls, in [0, 1]
    /// (`[faults] byzantine_frac`); the last `round(frac·n)` client
    /// indices are compromised. Active only while `faults` is on.
    pub byzantine_frac: f64,
    /// The compromised clients' poisoning strategy
    /// (`[faults] byzantine_mode`).
    pub byzantine_mode: crate::simnet::ByzantineMode,
    /// Availability-trace JSONL path (`faults.trace`); non-empty replays
    /// the recorded log instead of the parametric dropout model.
    pub fault_trace: String,
    /// Robust aggregation rule (`[defense]` table / `--aggregator`).
    pub aggregator: AggregatorKind,
    /// Per-tail trim fraction β ∈ [0, 0.5) for the trimmed mean.
    pub trim_beta: f64,
    /// Assumed byzantine count f for (multi-)Krum scoring.
    pub krum_f: usize,
    /// Multi-Krum selection size; 0 = auto (`n − f`).
    pub krum_m: usize,
    /// L2 clip threshold τ for norm clipping; 0 disables the clip.
    pub clip_tau: f64,
    /// Reliability-aware cohort gating (`[defense] reliability`): wrap
    /// the scheduler in an EWMA quarantine gate fed by observed upload
    /// losses.
    pub reliability: bool,
    /// Selection rounds a quarantined client sits out.
    pub quarantine_rounds: usize,
    /// EWMA step α ∈ (0, 1] of the per-client loss estimate.
    pub reliability_alpha: f64,
    /// Quarantine trigger threshold on the loss EWMA, in (0, 1].
    pub reliability_threshold: f64,
    /// Edge-aggregator shard count (`[scale] n_shards` / `--n-shards`):
    /// uploads buffer per shard (`client % n_shards`) and drain in exact
    /// global arrival order — any value is bit-identical to 1.
    pub n_shards: usize,
    /// Lazy client state (`[scale] lazy_state` / `--lazy-state`): evict
    /// each client after participation, spilling its EF residual, so
    /// resident dense state is `O(cohort)` instead of `O(n_clients)`.
    /// Trajectories are bit-identical either way.
    pub lazy_state: bool,
    /// EF spill slab encoding for the lazy store (`[scale] spill`).
    pub spill: SpillKind,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            dataset: DatasetKind::SynthMnist,
            model: String::new(),
            n_clients: 10,
            rounds: 30,
            k_local: 5,
            lr: 0.01,
            compressor: CompressorKind::ThreeSfc,
            budget_mult: 1,
            syn_steps: 30,
            lr_syn: 5.0,
            lambda: 0.0,
            error_feedback: true,
            topk_rate: 0.0,
            alpha: 0.5,
            train_samples: 2000,
            test_samples: 500,
            seed: 42,
            eval_every: 1,
            fedsynth_ksim: 4,
            fedsynth_lr_inner: 0.01,
            fedsynth_steps: 30,
            fedsynth_lr_syn: 0.5,
            metrics_path: String::new(),
            schedule: ScheduleKind::Full,
            client_frac: 1.0,
            server_opt: ServerOptKind::Gd,
            server_lr: 1.0,
            server_momentum: 0.9,
            adam_beta1: 0.9,
            adam_beta2: 0.99,
            adam_tau: 1e-3,
            network: NetworkKind::Edge,
            net_up_mbps: 10.0,
            net_down_mbps: 50.0,
            net_latency_ms: 30.0,
            net_jitter: 0.0,
            session: SessionKind::Sync,
            deadline_s: 0.5,
            buffer_k: 1,
            staleness_decay: 0.5,
            threads: 0,
            backend: BackendKind::Auto,
            init_weights: None,
            downlink: DownlinkKind::Identity,
            downlink_gap: 4,
            downlink_rate: 0.0,
            faults: false,
            fault_dropout_p: 0.1,
            fault_recover_s: 5.0,
            fault_diurnal_amp: 0.0,
            fault_diurnal_period_s: 86_400.0,
            fault_tiers: 1,
            fault_tier_spread: 0.5,
            fault_tier_compute_s: 0.05,
            byzantine_frac: 0.0,
            byzantine_mode: crate::simnet::ByzantineMode::SignFlip,
            fault_trace: String::new(),
            aggregator: AggregatorKind::WeightedMean,
            trim_beta: 0.2,
            krum_f: 0,
            krum_m: 0,
            clip_tau: 0.0,
            reliability: false,
            quarantine_rounds: 3,
            reliability_alpha: 0.3,
            reliability_threshold: 0.5,
            n_shards: 1,
            lazy_state: false,
            spill: SpillKind::Slab,
        }
    }
}

impl ExperimentConfig {
    /// Resolved model key (dataset default when unset).
    pub fn model_key(&self) -> &str {
        if self.model.is_empty() {
            self.dataset.default_model()
        } else {
            &self.model
        }
    }

    /// The schedule the round engine actually runs: asking for partial
    /// participation (`client_frac < 1`) without naming a schedule means
    /// uniform sampling — so `--client-frac 0.1` alone does what it says
    /// instead of silently keeping full participation.
    pub fn effective_schedule(&self) -> ScheduleKind {
        if self.schedule == ScheduleKind::Full && self.client_frac < 1.0 {
            ScheduleKind::Uniform
        } else {
            self.schedule
        }
    }

    /// The link model this config describes (presets or custom rates).
    pub fn network_model(&self) -> crate::simnet::NetworkModel {
        match self.network {
            NetworkKind::Edge => crate::simnet::NetworkModel::edge(),
            NetworkKind::Datacenter => crate::simnet::NetworkModel::datacenter(),
            NetworkKind::Custom => crate::simnet::NetworkModel::custom(
                self.net_up_mbps,
                self.net_down_mbps,
                self.net_latency_ms,
            ),
        }
    }

    /// Resolved worker-thread count for the per-round client fan-out:
    /// the explicit `threads` setting, else the `FED3SFC_THREADS` env
    /// var, else the machine's available parallelism. Always ≥ 1.
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        if let Ok(v) = std::env::var("FED3SFC_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n > 0 {
                    return n;
                }
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }

    /// The `[faults]` table as the simnet layer consumes it.
    pub fn faults_config(&self) -> crate::simnet::FaultsConfig {
        crate::simnet::FaultsConfig {
            enabled: self.faults,
            dropout_p: self.fault_dropout_p,
            recover_s: self.fault_recover_s,
            diurnal_amp: self.fault_diurnal_amp,
            diurnal_period_s: self.fault_diurnal_period_s,
            tiers: self.fault_tiers,
            tier_spread: self.fault_tier_spread,
            tier_compute_s: self.fault_tier_compute_s,
            byzantine_frac: self.byzantine_frac,
            byzantine_mode: self.byzantine_mode,
        }
    }

    /// Synthetic sample count m for 3SFC at this budget multiplier.
    pub fn syn_m(&self) -> usize {
        match self.budget_mult {
            0 | 1 => 1,
            2 => 2,
            _ => 4,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_clients == 0 {
            bail!("n_clients must be > 0");
        }
        if self.rounds == 0 {
            bail!("rounds must be > 0");
        }
        if !matches!(self.k_local, 1 | 5 | 10) {
            bail!("k_local must be 1, 5 or 10 (artifacts exist for these)");
        }
        if !matches!(self.budget_mult, 1 | 2 | 4) {
            bail!("budget_mult must be 1, 2 or 4");
        }
        if self.lr <= 0.0 || self.lr_syn <= 0.0 {
            bail!("learning rates must be positive");
        }
        if self.alpha <= 0.0 {
            bail!("dirichlet alpha must be positive");
        }
        if self.train_samples < self.n_clients {
            bail!("need at least one training sample per client");
        }
        if !(self.client_frac > 0.0 && self.client_frac <= 1.0) {
            bail!("client_frac must be in (0, 1], got {}", self.client_frac);
        }
        if self.server_lr <= 0.0 {
            bail!("server_lr must be positive");
        }
        if !(0.0..1.0).contains(&self.server_momentum) {
            bail!("server momentum must be in [0, 1)");
        }
        if !(0.0..1.0).contains(&self.adam_beta1) || !(0.0..1.0).contains(&self.adam_beta2) {
            bail!("adam betas must be in [0, 1)");
        }
        if self.adam_tau <= 0.0 {
            bail!("adam tau must be positive");
        }
        if self.net_up_mbps <= 0.0 || self.net_down_mbps <= 0.0 || self.net_latency_ms < 0.0 {
            bail!("network rates must be positive and latency non-negative");
        }
        if !(0.0..1.0).contains(&self.net_jitter) {
            bail!("network jitter must be in [0, 1), got {}", self.net_jitter);
        }
        if self.deadline_s <= 0.0 {
            bail!("session deadline_s must be positive, got {}", self.deadline_s);
        }
        if self.buffer_k == 0 {
            bail!("session buffer_k must be >= 1");
        }
        if !(self.staleness_decay > 0.0 && self.staleness_decay <= 1.0) {
            bail!("staleness_decay must be in (0, 1], got {}", self.staleness_decay);
        }
        if !(0.0..=1.0).contains(&self.downlink_rate) {
            bail!("downlink_rate must be in [0, 1], got {}", self.downlink_rate);
        }
        if !(0.0..=1.0).contains(&self.fault_dropout_p) {
            bail!("faults dropout_p must be in [0, 1], got {}", self.fault_dropout_p);
        }
        if !(self.fault_recover_s >= 0.0) {
            bail!("faults recover_s must be non-negative, got {}", self.fault_recover_s);
        }
        if !(0.0..=1.0).contains(&self.fault_diurnal_amp) {
            bail!("faults diurnal_amp must be in [0, 1], got {}", self.fault_diurnal_amp);
        }
        if !(self.fault_diurnal_period_s > 0.0) {
            bail!(
                "faults diurnal_period_s must be positive, got {}",
                self.fault_diurnal_period_s
            );
        }
        if self.fault_tiers == 0 {
            bail!("faults tiers must be >= 1");
        }
        if !(0.0..=1.0).contains(&self.fault_tier_spread) {
            bail!("faults tier_spread must be in [0, 1], got {}", self.fault_tier_spread);
        }
        if !(self.fault_tier_compute_s >= 0.0) {
            bail!(
                "faults tier_compute_s must be non-negative, got {}",
                self.fault_tier_compute_s
            );
        }
        if !(0.0..=1.0).contains(&self.byzantine_frac) {
            bail!("faults byzantine_frac must be in [0, 1], got {}", self.byzantine_frac);
        }
        if !(0.0..0.5).contains(&self.trim_beta) {
            bail!("defense trim_beta must be in [0, 0.5), got {}", self.trim_beta);
        }
        if self.clip_tau.is_nan() || self.clip_tau < 0.0 {
            bail!("defense clip_tau must be non-negative, got {}", self.clip_tau);
        }
        if !(self.reliability_alpha > 0.0 && self.reliability_alpha <= 1.0) {
            bail!(
                "defense ewma_alpha must be in (0, 1], got {}",
                self.reliability_alpha
            );
        }
        if !(self.reliability_threshold > 0.0 && self.reliability_threshold <= 1.0) {
            bail!(
                "defense threshold must be in (0, 1], got {}",
                self.reliability_threshold
            );
        }
        if self.n_shards == 0 {
            bail!("scale n_shards must be >= 1");
        }
        Ok(())
    }

    /// Apply a parsed TOML document on top of the current values.
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        for (k, v) in doc {
            match k.as_str() {
                "name" => self.name = v.as_str()?.to_string(),
                "dataset" => self.dataset = DatasetKind::parse(v.as_str()?)?,
                "model" => self.model = v.as_str()?.to_string(),
                "n_clients" | "clients" => self.n_clients = v.as_i64()? as usize,
                "rounds" => self.rounds = v.as_i64()? as usize,
                "k_local" | "k" => self.k_local = v.as_i64()? as usize,
                "lr" => self.lr = v.as_f64()? as f32,
                "compressor" | "method" => {
                    self.compressor = CompressorKind::parse(v.as_str()?)?
                }
                "budget_mult" => self.budget_mult = v.as_i64()? as usize,
                "syn_steps" => self.syn_steps = v.as_i64()? as usize,
                "lr_syn" => self.lr_syn = v.as_f64()? as f32,
                "lambda" => self.lambda = v.as_f64()? as f32,
                "error_feedback" | "ef" => self.error_feedback = v.as_bool()?,
                "topk_rate" => self.topk_rate = v.as_f64()?,
                "alpha" => self.alpha = v.as_f64()?,
                "train_samples" => self.train_samples = v.as_i64()? as usize,
                "test_samples" => self.test_samples = v.as_i64()? as usize,
                "seed" => self.seed = v.as_i64()? as u64,
                "eval_every" => self.eval_every = v.as_i64()? as usize,
                "fedsynth_ksim" => self.fedsynth_ksim = v.as_i64()? as usize,
                "fedsynth_lr_inner" => self.fedsynth_lr_inner = v.as_f64()? as f32,
                "fedsynth_steps" => self.fedsynth_steps = v.as_i64()? as usize,
                "fedsynth_lr_syn" => self.fedsynth_lr_syn = v.as_f64()? as f32,
                "metrics_path" => self.metrics_path = v.as_str()?.to_string(),
                "client_frac" | "schedule.client_frac" | "schedule.frac" => {
                    self.client_frac = v.as_f64()?
                }
                "schedule.kind" => self.schedule = ScheduleKind::parse(v.as_str()?)?,
                "server_opt.kind" => self.server_opt = ServerOptKind::parse(v.as_str()?)?,
                "server_lr" | "server_opt.lr" => self.server_lr = v.as_f64()? as f32,
                "server_opt.momentum" => self.server_momentum = v.as_f64()? as f32,
                "server_opt.beta1" => self.adam_beta1 = v.as_f64()? as f32,
                "server_opt.beta2" => self.adam_beta2 = v.as_f64()? as f32,
                "server_opt.tau" => self.adam_tau = v.as_f64()? as f32,
                "network.kind" => self.network = NetworkKind::parse(v.as_str()?)?,
                "network.up_mbps" => self.net_up_mbps = v.as_f64()?,
                "network.down_mbps" => self.net_down_mbps = v.as_f64()?,
                "network.latency_ms" => self.net_latency_ms = v.as_f64()?,
                "jitter" | "network.jitter" => self.net_jitter = v.as_f64()?,
                "session.mode" | "session.kind" => {
                    self.session = SessionKind::parse(v.as_str()?)?
                }
                "deadline_s" | "session.deadline_s" => self.deadline_s = v.as_f64()?,
                "buffer_k" | "session.buffer_k" => self.buffer_k = v.as_i64()? as usize,
                "staleness_decay" | "session.staleness_decay" => {
                    self.staleness_decay = v.as_f64()?
                }
                "threads" | "runtime.threads" => self.threads = v.as_i64()? as usize,
                "backend" | "runtime.backend" => {
                    self.backend = BackendKind::parse(v.as_str()?)?
                }
                "downlink" | "downlink.kind" => {
                    self.downlink = DownlinkKind::parse(v.as_str()?)?
                }
                "downlink_gap" | "downlink.gap" => {
                    self.downlink_gap = v.as_i64()? as usize
                }
                "downlink_rate" | "downlink.rate" => self.downlink_rate = v.as_f64()?,
                "faults" | "faults.enabled" => self.faults = v.as_bool()?,
                "dropout_p" | "faults.dropout_p" => self.fault_dropout_p = v.as_f64()?,
                "faults.recover_s" => self.fault_recover_s = v.as_f64()?,
                "faults.diurnal_amp" => self.fault_diurnal_amp = v.as_f64()?,
                "faults.diurnal_period_s" => self.fault_diurnal_period_s = v.as_f64()?,
                "faults.tiers" => self.fault_tiers = v.as_i64()? as usize,
                "faults.tier_spread" => self.fault_tier_spread = v.as_f64()?,
                "faults.tier_compute_s" => self.fault_tier_compute_s = v.as_f64()?,
                "byzantine_frac" | "faults.byzantine_frac" => {
                    self.byzantine_frac = v.as_f64()?
                }
                "byzantine_mode" | "faults.byzantine_mode" => {
                    self.byzantine_mode = crate::simnet::ByzantineMode::parse(v.as_str()?)?
                }
                "faults.trace" => self.fault_trace = v.as_str()?.to_string(),
                "aggregator" | "defense.aggregator" => {
                    self.aggregator = AggregatorKind::parse(v.as_str()?)?
                }
                "trim_beta" | "defense.trim_beta" => self.trim_beta = v.as_f64()?,
                "defense.krum_f" => self.krum_f = v.as_i64()? as usize,
                "defense.krum_m" => self.krum_m = v.as_i64()? as usize,
                "clip_tau" | "defense.clip_tau" => self.clip_tau = v.as_f64()?,
                "reliability" | "defense.reliability" => self.reliability = v.as_bool()?,
                "quarantine_rounds" | "defense.quarantine_rounds" => {
                    self.quarantine_rounds = v.as_i64()? as usize
                }
                "defense.ewma_alpha" => self.reliability_alpha = v.as_f64()?,
                "defense.threshold" => self.reliability_threshold = v.as_f64()?,
                "n_shards" | "scale.n_shards" => self.n_shards = v.as_i64()? as usize,
                "lazy_state" | "scale.lazy_state" => self.lazy_state = v.as_bool()?,
                "spill" | "scale.spill" => self.spill = SpillKind::parse(v.as_str()?)?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        Ok(())
    }

    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_toml(&doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_toml_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml_str(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            name = "t2-mnist"
            dataset = "synth_mnist"
            compressor = "dgc"
            n_clients = 20
            rounds = 10
            k = 5
            lr = 0.01
            ef = true
            alpha = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "t2-mnist");
        assert_eq!(cfg.dataset, DatasetKind::SynthMnist);
        assert_eq!(cfg.compressor, CompressorKind::Dgc);
        assert_eq!(cfg.n_clients, 20);
        assert_eq!(cfg.model_key(), "mlp10");
    }

    #[test]
    fn rejects_bad_values() {
        let mut cfg = ExperimentConfig::default();
        cfg.k_local = 3;
        assert!(cfg.validate().is_err());
        cfg.k_local = 5;
        cfg.budget_mult = 3;
        assert!(cfg.validate().is_err());
        assert!(ExperimentConfig::from_toml_str("bogus_key = 1").is_err());
    }

    #[test]
    fn round_engine_toml_tables() {
        // The acceptance scenario: 100 clients, 10% uniform sampling,
        // FedAdam server optimizer, edge link.
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            clients = 100
            rounds = 5

            [schedule]
            kind = "uniform"
            client_frac = 0.1

            [server_opt]
            kind = "fedadam"
            lr = 0.05
            tau = 0.001

            [network]
            kind = "edge"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.schedule, ScheduleKind::Uniform);
        assert_eq!(cfg.client_frac, 0.1);
        assert_eq!(cfg.server_opt, ServerOptKind::FedAdam);
        assert_eq!(cfg.server_lr, 0.05);
        assert_eq!(cfg.network, NetworkKind::Edge);
        let net = cfg.network_model();
        assert_eq!(net.up_bps, 10e6);
    }

    #[test]
    fn client_frac_alone_implies_uniform_sampling() {
        let cfg = ExperimentConfig::from_toml_str("client_frac = 0.1").unwrap();
        assert_eq!(cfg.schedule, ScheduleKind::Full);
        assert_eq!(cfg.effective_schedule(), ScheduleKind::Uniform);
        // Explicit schedules and full participation are left alone.
        let full = ExperimentConfig::default();
        assert_eq!(full.effective_schedule(), ScheduleKind::Full);
        let rr =
            ExperimentConfig::from_toml_str("[schedule]\nkind = \"rr\"\nclient_frac = 0.5\n")
                .unwrap();
        assert_eq!(rr.effective_schedule(), ScheduleKind::RoundRobin);
    }

    #[test]
    fn runtime_threads_table() {
        let cfg = ExperimentConfig::from_toml_str("[runtime]\nthreads = 4\n").unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.effective_threads(), 4);
        // bare key works too (CLI-style flat configs)
        let cfg = ExperimentConfig::from_toml_str("threads = 2").unwrap();
        assert_eq!(cfg.threads, 2);
        // 0 = auto: resolves to something >= 1
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.threads, 0);
        assert!(cfg.effective_threads() >= 1);
    }

    #[test]
    fn backend_key_parses_and_defaults_to_auto() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.backend, BackendKind::Auto);
        let cfg = ExperimentConfig::from_toml_str("[runtime]\nbackend = \"native\"\n").unwrap();
        assert_eq!(cfg.backend, BackendKind::Native);
        let cfg = ExperimentConfig::from_toml_str("backend = \"pjrt\"").unwrap();
        assert_eq!(cfg.backend, BackendKind::Pjrt);
        assert!(ExperimentConfig::from_toml_str("backend = \"tpu\"").is_err());
        for kind in [BackendKind::Auto, BackendKind::Pjrt, BackendKind::Native] {
            assert_eq!(BackendKind::parse(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn faults_table_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            rounds = 5

            [faults]
            enabled = true
            dropout_p = 0.25
            recover_s = 2.0
            diurnal_amp = 0.4
            diurnal_period_s = 120.0
            tiers = 3
            tier_spread = 0.8
            tier_compute_s = 0.1
            "#,
        )
        .unwrap();
        assert!(cfg.faults);
        let fc = cfg.faults_config();
        assert!(fc.enabled);
        assert_eq!(fc.dropout_p, 0.25);
        assert_eq!(fc.recover_s, 2.0);
        assert_eq!(fc.diurnal_amp, 0.4);
        assert_eq!(fc.diurnal_period_s, 120.0);
        assert_eq!(fc.tiers, 3);
        assert_eq!(fc.tier_spread, 0.8);
        assert_eq!(fc.tier_compute_s, 0.1);
        // Bare keys work for CLI-style flat configs, and the default is
        // firmly off.
        let cfg = ExperimentConfig::from_toml_str("faults = true\ndropout_p = 0.5\n").unwrap();
        assert!(cfg.faults);
        assert_eq!(cfg.fault_dropout_p, 0.5);
        assert!(!ExperimentConfig::default().faults_config().enabled);
    }

    #[test]
    fn faults_knobs_are_range_checked() {
        let mut cfg = ExperimentConfig::default();
        cfg.fault_dropout_p = 1.5;
        assert!(cfg.validate().unwrap_err().to_string().contains("dropout_p"));
        cfg.fault_dropout_p = 0.1;
        cfg.fault_recover_s = -1.0;
        assert!(cfg.validate().unwrap_err().to_string().contains("recover_s"));
        cfg.fault_recover_s = 5.0;
        cfg.fault_diurnal_amp = 2.0;
        assert!(cfg.validate().unwrap_err().to_string().contains("diurnal_amp"));
        cfg.fault_diurnal_amp = 0.0;
        cfg.fault_diurnal_period_s = 0.0;
        assert!(cfg.validate().unwrap_err().to_string().contains("diurnal_period_s"));
        cfg.fault_diurnal_period_s = 60.0;
        cfg.fault_tiers = 0;
        assert!(cfg.validate().unwrap_err().to_string().contains("tiers"));
        cfg.fault_tiers = 2;
        cfg.fault_tier_spread = -0.1;
        assert!(cfg.validate().unwrap_err().to_string().contains("tier_spread"));
        cfg.fault_tier_spread = 0.5;
        cfg.fault_tier_compute_s = -0.5;
        assert!(cfg.validate().unwrap_err().to_string().contains("tier_compute_s"));
        cfg.fault_tier_compute_s = 0.0;
        cfg.validate().unwrap();
    }

    #[test]
    fn defense_table_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            rounds = 5

            [faults]
            enabled = true
            byzantine_frac = 0.3
            byzantine_mode = "scale_amplify"
            trace = "fleet.jsonl"

            [defense]
            aggregator = "trimmed_mean"
            trim_beta = 0.3
            krum_f = 2
            krum_m = 5
            clip_tau = 1.5
            reliability = true
            quarantine_rounds = 4
            ewma_alpha = 0.4
            threshold = 0.6
            "#,
        )
        .unwrap();
        assert_eq!(cfg.byzantine_frac, 0.3);
        assert_eq!(cfg.byzantine_mode, crate::simnet::ByzantineMode::ScaleAmplify);
        assert_eq!(cfg.fault_trace, "fleet.jsonl");
        assert_eq!(cfg.aggregator, AggregatorKind::TrimmedMean);
        assert_eq!(cfg.trim_beta, 0.3);
        assert_eq!(cfg.krum_f, 2);
        assert_eq!(cfg.krum_m, 5);
        assert_eq!(cfg.clip_tau, 1.5);
        assert!(cfg.reliability);
        assert_eq!(cfg.quarantine_rounds, 4);
        assert_eq!(cfg.reliability_alpha, 0.4);
        assert_eq!(cfg.reliability_threshold, 0.6);
        // The faults table carries the attacker through to the simnet layer.
        let fc = cfg.faults_config();
        assert_eq!(fc.byzantine_frac, 0.3);
        assert_eq!(fc.byzantine_mode, crate::simnet::ByzantineMode::ScaleAmplify);
        // Bare keys work for CLI-style flat configs; defaults are benign.
        let cfg = ExperimentConfig::from_toml_str(
            "aggregator = \"krum\"\nbyzantine_frac = 0.2\nreliability = true\n",
        )
        .unwrap();
        assert_eq!(cfg.aggregator, AggregatorKind::Krum);
        assert_eq!(cfg.byzantine_frac, 0.2);
        assert!(cfg.reliability);
        let d = ExperimentConfig::default();
        assert_eq!(d.aggregator, AggregatorKind::WeightedMean);
        assert_eq!(d.byzantine_frac, 0.0);
        assert!(!d.reliability);
        for kind in [
            AggregatorKind::WeightedMean,
            AggregatorKind::TrimmedMean,
            AggregatorKind::CoordinateMedian,
            AggregatorKind::Krum,
            AggregatorKind::MultiKrum,
            AggregatorKind::NormClip,
        ] {
            assert_eq!(AggregatorKind::parse(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn defense_knobs_are_range_checked() {
        assert!(ExperimentConfig::from_toml_str("byzantine_frac = 1.5").is_err());
        assert!(ExperimentConfig::from_toml_str("byzantine_frac = -0.1").is_err());
        assert!(ExperimentConfig::from_toml_str("byzantine_mode = \"subtle\"").is_err());
        assert!(ExperimentConfig::from_toml_str("trim_beta = 0.5").is_err());
        assert!(ExperimentConfig::from_toml_str("trim_beta = -0.1").is_err());
        assert!(ExperimentConfig::from_toml_str("clip_tau = -1.0").is_err());
        assert!(ExperimentConfig::from_toml_str("aggregator = \"average\"").is_err());
        assert!(ExperimentConfig::from_toml_str("[defense]\newma_alpha = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("[defense]\newma_alpha = 1.5").is_err());
        assert!(ExperimentConfig::from_toml_str("[defense]\nthreshold = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("[defense]\nthreshold = 1.1").is_err());
    }

    #[test]
    fn custom_network_rates() {
        let cfg = ExperimentConfig::from_toml_str(
            "[network]\nkind = \"custom\"\nup_mbps = 2.5\ndown_mbps = 20\nlatency_ms = 80\n",
        )
        .unwrap();
        let net = cfg.network_model();
        assert_eq!(net.up_bps, 2.5e6);
        assert_eq!(net.down_bps, 20e6);
        assert!((net.latency_s - 0.080).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_round_engine_values() {
        assert!(ExperimentConfig::from_toml_str("client_frac = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("client_frac = 1.5").is_err());
        assert!(ExperimentConfig::from_toml_str("[schedule]\nkind = \"lottery\"").is_err());
        assert!(ExperimentConfig::from_toml_str("[server_opt]\nkind = \"lbfgs\"").is_err());
        assert!(ExperimentConfig::from_toml_str("[server_opt]\nmomentum = 1.0").is_err());
        assert!(ExperimentConfig::from_toml_str("[network]\nkind = \"carrier_pigeon\"").is_err());
        assert!(ExperimentConfig::from_toml_str("server_lr = 0.0").is_err());
    }

    #[test]
    fn session_toml_table() {
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            clients = 40

            [session]
            mode = "deadline"
            deadline_s = 0.25
            staleness_decay = 0.8

            [network]
            kind = "edge"
            jitter = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(cfg.session, SessionKind::Deadline);
        assert_eq!(cfg.deadline_s, 0.25);
        assert_eq!(cfg.staleness_decay, 0.8);
        assert_eq!(cfg.net_jitter, 0.5);
        // Async spelling + bare keys work too.
        let cfg = ExperimentConfig::from_toml_str(
            "[session]\nkind = \"fedbuff\"\nbuffer_k = 4\n",
        )
        .unwrap();
        assert_eq!(cfg.session, SessionKind::Async);
        assert_eq!(cfg.buffer_k, 4);
        for kind in [SessionKind::Sync, SessionKind::Deadline, SessionKind::Async] {
            assert_eq!(SessionKind::parse(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn downlink_toml_table() {
        // Defaults: identity, gap 4, budget-matched rate.
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.downlink, DownlinkKind::Identity);
        assert_eq!(cfg.downlink_gap, 4);
        assert_eq!(cfg.downlink_rate, 0.0);
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            [downlink]
            kind = "3sfc"
            gap = 2
            "#,
        )
        .unwrap();
        assert_eq!(cfg.downlink, DownlinkKind::ThreeSfc);
        assert_eq!(cfg.downlink_gap, 2);
        // Bare keys (CLI-style flat configs) and every alias.
        let cfg =
            ExperimentConfig::from_toml_str("downlink = \"dgc\"\ndownlink_rate = 0.02\n")
                .unwrap();
        assert_eq!(cfg.downlink, DownlinkKind::TopK);
        assert_eq!(cfg.downlink_rate, 0.02);
        for kind in [
            DownlinkKind::Identity,
            DownlinkKind::ThreeSfc,
            DownlinkKind::TopK,
            DownlinkKind::Stc,
        ] {
            assert_eq!(DownlinkKind::parse(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn scale_toml_table() {
        // Defaults: unsharded, eager, slab spill — the historical path.
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.n_shards, 1);
        assert!(!cfg.lazy_state);
        assert_eq!(cfg.spill, SpillKind::Slab);
        let cfg = ExperimentConfig::from_toml_str(
            r#"
            [scale]
            n_shards = 8
            lazy_state = true
            spill = "boxed"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.n_shards, 8);
        assert!(cfg.lazy_state);
        assert_eq!(cfg.spill, SpillKind::Boxed);
        // Bare keys (CLI-style flat configs) and every alias.
        let cfg =
            ExperimentConfig::from_toml_str("n_shards = 4\nlazy_state = true\nspill = \"bytes\"\n")
                .unwrap();
        assert_eq!(cfg.n_shards, 4);
        assert!(cfg.lazy_state);
        assert_eq!(cfg.spill, SpillKind::Slab);
        for kind in [SpillKind::Boxed, SpillKind::Slab] {
            assert_eq!(SpillKind::parse(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn rejects_bad_scale_values() {
        assert!(ExperimentConfig::from_toml_str("[scale]\nn_shards = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[scale]\nspill = \"gzip\"").is_err());
    }

    #[test]
    fn rejects_bad_downlink_values() {
        assert!(ExperimentConfig::from_toml_str("[downlink]\nkind = \"zip\"").is_err());
        assert!(ExperimentConfig::from_toml_str("[downlink]\nrate = 1.5").is_err());
        assert!(ExperimentConfig::from_toml_str("downlink_rate = -0.1").is_err());
    }

    #[test]
    fn rejects_bad_session_values() {
        assert!(ExperimentConfig::from_toml_str("[session]\nmode = \"psychic\"").is_err());
        assert!(ExperimentConfig::from_toml_str("[session]\ndeadline_s = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("[session]\nbuffer_k = 0").is_err());
        assert!(ExperimentConfig::from_toml_str("[session]\nstaleness_decay = 0.0").is_err());
        assert!(ExperimentConfig::from_toml_str("[session]\nstaleness_decay = 1.5").is_err());
        assert!(ExperimentConfig::from_toml_str("[network]\njitter = 1.0").is_err());
        assert!(ExperimentConfig::from_toml_str("[network]\njitter = -0.1").is_err());
    }

    #[test]
    fn dataset_metadata_consistent() {
        for ds in [
            DatasetKind::SynthMnist,
            DatasetKind::SynthEmnist,
            DatasetKind::SynthFmnist,
            DatasetKind::SynthCifar10,
            DatasetKind::SynthCifar100,
            DatasetKind::SynthSmall,
        ] {
            let (h, w, c) = ds.image_dims();
            assert_eq!(h * w * c, ds.feature_len(), "{ds:?}");
            assert!(ds.n_classes() >= 2);
            assert!(DatasetKind::parse(ds.name()).unwrap() == ds);
        }
    }
}
