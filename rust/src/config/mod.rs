//! Configuration system: a TOML-subset parser plus the typed
//! [`ExperimentConfig`] every runner/bench consumes.

pub mod experiment;
pub mod toml;

pub use experiment::{
    AggregatorKind, BackendKind, CompressorKind, DatasetKind, DownlinkKind,
    ExperimentConfig, NetworkKind, ScheduleKind, ServerOptKind, SessionKind,
    SpillKind,
};
pub use toml::{parse_toml, TomlValue};
