//! TOML-subset parser (offline substrate for the `toml` crate).
//!
//! Supported grammar — everything the experiment presets use:
//! `[table]` / `[a.b]` headers, `key = value` with string, integer, float,
//! boolean and flat-array values, `#` comments, blank lines.
//!
//! # Round-engine tables
//!
//! Besides the root-level experiment keys (see
//! `ExperimentConfig::apply_toml`), presets may configure the round
//! engine with three tables:
//!
//! ```toml
//! [schedule]
//! kind = "uniform"        # full | uniform | round_robin   (default: full)
//! client_frac = 0.1       # fraction of clients per round, in (0, 1]
//!
//! [server_opt]
//! kind = "fedadam"        # gd | momentum | fedadam        (default: gd)
//! lr = 0.05               # server learning rate η_s       (default: 1.0)
//! momentum = 0.9          # heavy-ball β, kind = "momentum"
//! beta1 = 0.9             # FedAdam first-moment decay
//! beta2 = 0.99            # FedAdam second-moment decay
//! tau = 0.001             # FedAdam adaptivity degree τ
//!
//! [network]
//! kind = "edge"           # edge | datacenter | custom     (default: edge)
//! up_mbps = 10.0          # kind = "custom" only
//! down_mbps = 50.0
//! latency_ms = 30.0
//!
//! [runtime]
//! threads = 4             # per-round client fan-out: 0 = auto (all
//!                         # cores / FED3SFC_THREADS), 1 = sequential.
//!                         # Trajectories are identical for any value.
//! ```
//!
//! `client_frac`, `server_lr` and `threads` are also accepted at the
//! root level for flat (CLI-style) presets, and `client_frac < 1`
//! without an explicit `schedule.kind` implies uniform sampling (see
//! `ExperimentConfig::effective_schedule`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            _ => bail!("expected integer, got {self:?}"),
        }
    }
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => bail!("expected float, got {self:?}"),
        }
    }
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }
}

/// Flat map of `table.key -> value` (root keys have no prefix).
pub type TomlDoc = BTreeMap<String, TomlValue>;

pub fn parse_toml(input: &str) -> Result<TomlDoc> {
    let mut doc = TomlDoc::new();
    let mut prefix = String::new();
    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unclosed table header", lineno + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty table name", lineno + 1);
            }
            prefix = format!("{name}.");
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        doc.insert(format!("{prefix}{key}"), val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(inner) = s.strip_prefix('"') {
        let end = inner
            .find('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(TomlValue::Str(inner[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{s}'")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_scalars() {
        let doc = parse_toml(
            r#"
            # experiment preset
            name = "table2"
            rounds = 40        # scaled down
            lr = 0.01
            non_iid = true

            [dataset]
            kind = "synth_mnist"
            alpha = 0.5
            "#,
        )
        .unwrap();
        assert_eq!(doc["name"], TomlValue::Str("table2".into()));
        assert_eq!(doc["rounds"], TomlValue::Int(40));
        assert_eq!(doc["lr"], TomlValue::Float(0.01));
        assert_eq!(doc["non_iid"], TomlValue::Bool(true));
        assert_eq!(doc["dataset.kind"], TomlValue::Str("synth_mnist".into()));
        assert_eq!(doc["dataset.alpha"], TomlValue::Float(0.5));
    }

    #[test]
    fn parses_arrays() {
        let doc = parse_toml("ks = [1, 5, 10]\nnames = [\"a\", \"b\"]").unwrap();
        assert_eq!(
            doc["ks"],
            TomlValue::Arr(vec![
                TomlValue::Int(1),
                TomlValue::Int(5),
                TomlValue::Int(10)
            ])
        );
        assert_eq!(
            doc["names"],
            TomlValue::Arr(vec![
                TomlValue::Str("a".into()),
                TomlValue::Str("b".into())
            ])
        );
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse_toml("k = \"a#b\"").unwrap();
        assert_eq!(doc["k"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_toml("[unclosed").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("k = ").is_err());
    }
}
