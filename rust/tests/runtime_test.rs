//! Runtime integration: HLO artifacts load, compile and compute the same
//! math the python oracle verified at build time.

mod common;

use fed3sfc::runtime::FedOps;
use fed3sfc::util::vecmath;

fn test_batch(d: usize, b: usize, classes: usize) -> (Vec<f32>, Vec<i32>) {
    let mut rng = fed3sfc::util::rng::Rng::new(123);
    let mut x = vec![0.0f32; b * d];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..b).map(|i| (i % classes) as i32).collect();
    (x, y)
}

#[test]
fn manifest_lists_expected_models() {
    let _g = common::lock();
    let rt = common::runtime();
    for m in [
        "mlp_small",
        "mlp10",
        "mlp26",
        "mnistnet",
        "convnet",
        "resnet8_c10",
        "resnet8_c20",
        "regnet_c10",
        "regnet_c20",
    ] {
        let info = rt.model(m).unwrap();
        assert!(info.params > 0);
        assert!(info.ops.contains_key("eval"), "{m} missing eval");
        assert!(info.ops.contains_key("syn_step_m1"));
    }
    // Paper's MLP scale (Fig 1 caption: 199,210 params; same architecture).
    assert_eq!(rt.model("mlp10").unwrap().params, 198_760);
}

#[test]
fn local_train_k1_matches_grad_batch() {
    // train_k1 must be exactly w - lr * grad(batch).
    let _g = common::lock();
    let rt = common::runtime();
    let ops = FedOps::new(&rt, "mlp_small").unwrap();
    let model = ops.model;
    let w = rt.manifest.load_init(model).unwrap();
    let (x, y) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let lr = 0.05f32;

    let w1 = ops.local_train(1, &w, &x, &y, lr).unwrap();
    let g = ops.grad_batch(&w, &x, &y).unwrap();
    let mut want = w.clone();
    vecmath::axpy(-lr, &g, &mut want);
    for (a, b) in w1.iter().zip(want.iter()) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn local_training_reduces_loss() {
    let _g = common::lock();
    let rt = common::runtime();
    let ops = FedOps::new(&rt, "mlp_small").unwrap();
    let model = ops.model;
    let mut w = rt.manifest.load_init(model).unwrap();
    let (x, y) = test_batch(model.feature_len(), model.eval_batch, model.n_classes);
    let (loss0, _) = ops.eval_batch(&w, &x, &y).unwrap();

    // 10 rounds of K=5 training on (a subset of) the same data.
    let (xt, yt) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let xs: Vec<f32> = xt.iter().cloned().cycle().take(5 * xt.len()).collect();
    let ys: Vec<i32> = yt.iter().cloned().cycle().take(5 * yt.len()).collect();
    for _ in 0..10 {
        w = ops.local_train(5, &w, &xs, &ys, 0.05).unwrap();
    }
    let (loss1, _) = ops.eval_batch(&w, &x, &y).unwrap();
    // Train and eval batches share the synthetic distribution shape only
    // loosely here; the training batch loss is the real check:
    let w0 = rt.manifest.load_init(model).unwrap();
    let g0 = ops.grad_batch(&w0, &xt, &yt).unwrap();
    let g1 = ops.grad_batch(&w, &xt, &yt).unwrap();
    assert!(
        vecmath::norm(&g1) < vecmath::norm(&g0),
        "gradient should shrink as the batch is fit"
    );
    assert!(loss1.is_finite() && loss0.is_finite());
}

#[test]
fn syn_step_improves_cosine_and_syn_grad_agrees() {
    let _g = common::lock();
    let rt = common::runtime();
    let ops = FedOps::new(&rt, "mlp_small").unwrap();
    let model = ops.model;
    let w = rt.manifest.load_init(model).unwrap();

    // Build a realistic target: one local training delta.
    let (xt, yt) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let xs: Vec<f32> = xt.iter().cloned().cycle().take(5 * xt.len()).collect();
    let ys: Vec<i32> = yt.iter().cloned().cycle().take(5 * yt.len()).collect();
    let w_local = ops.local_train(5, &w, &xs, &ys, 0.05).unwrap();
    let target = vecmath::sub(&w, &w_local);

    let mut rng = fed3sfc::util::rng::Rng::new(7);
    let mut dx = vec![0.0f32; model.feature_len()];
    rng.fill_normal(&mut dx, 0.5);
    let mut dy = vec![0.0f32; model.n_classes];

    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..25 {
        let (ndx, ndy, cos) = ops
            .syn_step(1, &w, &target, &dx, &dy, 5.0, 0.0)
            .unwrap();
        if first.is_none() {
            first = Some(cos.abs());
        }
        last = cos.abs();
        dx = ndx;
        dy = ndy;
    }
    assert!(last > first.unwrap(), "{:?} -> {last}", first);

    // syn_grad at the optimized features matches the cosine the step reported.
    let g = ops.syn_grad(1, &w, &dx, &dy).unwrap();
    let cos_host = vecmath::cosine(&g, &target).abs() as f32;
    assert!((cos_host - last).abs() < 0.15, "{cos_host} vs {last}");
}

#[test]
fn eval_dataset_loops_batches_consistently() {
    let _g = common::lock();
    let rt = common::runtime();
    let ops = FedOps::new(&rt, "mlp_small").unwrap();
    let model = ops.model;
    let w = rt.manifest.load_init(model).unwrap();
    let b = model.eval_batch;
    let (x, y) = test_batch(model.feature_len(), 2 * b, model.n_classes);
    let (loss_all, acc_all) = ops.eval_dataset(&w, &x, &y).unwrap();

    let (l1, c1) = ops
        .eval_batch(&w, &x[..b * model.feature_len()], &y[..b])
        .unwrap();
    let (l2, c2) = ops
        .eval_batch(&w, &x[b * model.feature_len()..], &y[b..])
        .unwrap();
    let want_loss = (l1 + l2) as f64 / (2 * b) as f64;
    let want_acc = (c1 + c2) as f64 / (2 * b) as f64;
    assert!((loss_all - want_loss).abs() < 1e-5);
    assert!((acc_all - want_acc).abs() < 1e-9);
}

#[test]
fn fedsynth_apply_matches_step_fit() {
    let _g = common::lock();
    let rt = common::runtime();
    let ops = FedOps::new(&rt, "mlp_small").unwrap();
    let model = ops.model;
    let w = rt.manifest.load_init(model).unwrap();
    let (xt, yt) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let xs: Vec<f32> = xt.iter().cloned().cycle().take(5 * xt.len()).collect();
    let ys: Vec<i32> = yt.iter().cloned().cycle().take(5 * yt.len()).collect();
    let w_local = ops.local_train(5, &w, &xs, &ys, 0.05).unwrap();
    let target = vecmath::sub(&w, &w_local);

    let k = 4;
    let mut rng = fed3sfc::util::rng::Rng::new(9);
    let mut dxs = vec![0.0f32; k * model.feature_len()];
    rng.fill_normal(&mut dxs, 0.5);
    let dys = vec![0.0f32; k * model.n_classes];

    let (_, _, fit, norms) = ops
        .fedsynth_step(k, 1, &w, &target, &dxs, &dys, 0.05, 0.0)
        .unwrap();
    assert_eq!(norms.len(), k);
    let delta = ops.fedsynth_apply(k, 1, &w, &dxs, &dys, 0.05).unwrap();
    let err = vecmath::sub(&delta, &target);
    let want_fit = vecmath::norm2(&err) as f32;
    assert!(
        (fit - want_fit).abs() < 1e-3 * (1.0 + want_fit.abs()),
        "{fit} vs {want_fit}"
    );
}
