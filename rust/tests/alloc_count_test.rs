//! Allocation-count regression test for the native backend's zero-alloc
//! op claim (EXPERIMENTS.md §Perf): after warm-up, every hot-path op's
//! intermediates come from the backend's `Workspace` pool, so the only
//! heap allocations left are the result vectors the `Backend` trait
//! hands back to the caller. A counting global allocator pins the exact
//! counts — any new `vec![...]` sneaking into the op bodies fails here.
//!
//! Everything runs inside ONE `#[test]` so no concurrent test body can
//! perturb the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use fed3sfc::runtime::{Backend, NativeBackend};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Allocations performed while running `f`.
fn allocs_during<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let before = ALLOCS.load(Ordering::Relaxed);
    let out = f();
    (ALLOCS.load(Ordering::Relaxed) - before, out)
}

#[test]
fn native_ops_allocate_only_their_results_after_warmup() {
    let be = NativeBackend::new();
    let model = be.manifest().model("mlp_small").unwrap().clone();
    let w = be.load_init(&model).unwrap();
    let d = model.feature_len();

    let bsz = 8usize;
    let x: Vec<f32> = (0..bsz * d).map(|i| ((i as f32) * 0.37).sin()).collect();
    let y: Vec<i32> = (0..bsz).map(|i| (i % model.n_classes) as i32).collect();
    let k = 2usize; // local_train consumes x as k=2 batches of 4
    let mut dx = vec![0.25f32; d];
    dx[0] = 1.0;
    let dy = vec![0.0f32; model.n_classes];
    let g_target = be.grad_batch(&model, &w, &x, &y).unwrap();

    // Warm up every op a few times so the workspace pool reaches its
    // steady state (capacities are monotone, so a handful of cycles in
    // measurement order suffices).
    for _ in 0..5 {
        be.eval_batch(&model, &w, &x, &y).unwrap();
        be.grad_batch(&model, &w, &x, &y).unwrap();
        be.local_train(&model, k, &w, &x, &y, 0.1).unwrap();
        be.syn_grad(&model, 1, &w, &dx, &dy).unwrap();
        be.syn_step(&model, 1, &w, &g_target, &dx, &dy, 1.0, 0.0).unwrap();
    }

    // eval_batch returns scalars: fully zero-alloc.
    let (n, _) = allocs_during(|| be.eval_batch(&model, &w, &x, &y).unwrap());
    assert_eq!(n, 0, "eval_batch allocated {n} times (want 0)");

    // grad_batch returns one [P] vector.
    let (n, _) = allocs_during(|| be.grad_batch(&model, &w, &x, &y).unwrap());
    assert_eq!(n, 1, "grad_batch allocated {n} times (want 1: the gradient)");

    // local_train returns one [P] vector.
    let (n, _) = allocs_during(|| be.local_train(&model, k, &w, &x, &y, 0.1).unwrap());
    assert_eq!(n, 1, "local_train allocated {n} times (want 1: the weights)");

    // syn_grad moves its [P] pool checkout out as the result, so each
    // call consumes one pooled P-sized buffer. Drain the warm surplus
    // first so the steady-state count (exactly one fresh [P] allocation
    // per call, pool otherwise untouched) is deterministic.
    for _ in 0..8 {
        be.syn_grad(&model, 1, &w, &dx, &dy).unwrap();
    }
    let (n, _) = allocs_during(|| be.syn_grad(&model, 1, &w, &dx, &dy).unwrap());
    assert_eq!(n, 1, "syn_grad allocated {n} times (want 1: the gradient)");

    // Re-warm syn_step (the drain above consumed the pool's spare [P]
    // buffers), then pin it: returns (dx', dy', cos) — two vectors.
    for _ in 0..3 {
        be.syn_step(&model, 1, &w, &g_target, &dx, &dy, 1.0, 0.0).unwrap();
    }
    let (n, _) =
        allocs_during(|| be.syn_step(&model, 1, &w, &g_target, &dx, &dy, 1.0, 0.0).unwrap());
    assert_eq!(n, 2, "syn_step allocated {n} times (want 2: dx' and dy')");

    // fedsynth_step returns (dxs', dys', fit, norms) plus the unroll's
    // bookkeeping spine — bounded, though not strictly output-only.
    let dxs = [&dx[..], &dx[..]].concat();
    let dys = vec![0.0f32; 2 * model.n_classes];
    for _ in 0..5 {
        be.fedsynth_step(&model, 2, 1, &w, &g_target, &dxs, &dys, 0.1, 1.0).unwrap();
    }
    let (n, _) = allocs_during(|| {
        be.fedsynth_step(&model, 2, 1, &w, &g_target, &dxs, &dys, 0.1, 1.0).unwrap()
    });
    assert!(n <= 8, "fedsynth_step allocated {n} times (want ≤ 8)");
}
