//! Self-check: the real `src/` tree stays detlint-clean.
//!
//! This is the library-level twin of the CI job that runs
//! `cargo run -p detlint -- check` — having it in the test suite means a
//! plain `cargo test` catches a determinism/wire-honesty regression (or a
//! stale/un-reasoned pragma, which is a DET000 error) without the extra
//! binary invocation.

use std::path::Path;

#[test]
fn src_tree_is_detlint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let result = detlint::lint_tree(&root).expect("lint src tree");
    assert!(
        result.diagnostics.is_empty(),
        "detlint found {} issue(s) in src/:\n{}",
        result.diagnostics.len(),
        detlint::render_text(&result.diagnostics, "src")
    );
    // The scan actually covered the tree (guards against a path typo
    // silently turning this test into a no-op).
    assert!(result.files > 40, "only {} files scanned", result.files);
}
