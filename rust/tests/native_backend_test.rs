//! Native-backend math integration — the artifact-free mirror of
//! tests/runtime_test.rs: the pure-Rust fed-ops satisfy the same
//! semantic contracts the python oracle verified for the PJRT artifacts.

mod common;

use fed3sfc::runtime::{Backend, FedOps};
use fed3sfc::util::vecmath;

fn test_batch(d: usize, b: usize, classes: usize) -> (Vec<f32>, Vec<i32>) {
    let mut rng = fed3sfc::util::rng::Rng::new(123);
    let mut x = vec![0.0f32; b * d];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..b).map(|i| (i % classes) as i32).collect();
    (x, y)
}

#[test]
fn manifest_lists_the_mlp_family() {
    let be = common::native();
    for m in ["mlp_small", "mlp10", "mlp26"] {
        let info = be.manifest().model(m).unwrap();
        assert!(info.params > 0);
        assert!(info.ops.contains_key("eval"), "{m} missing eval");
        assert!(info.ops.contains_key("syn_step_m1"));
    }
    // Same parameter counts as the AOT manifest exports.
    assert_eq!(be.manifest().model("mlp10").unwrap().params, 198_760);
    assert_eq!(be.manifest().model("mlp_small").unwrap().params, 2344);
    // Conv models are PJRT-only and must fail with a clear error, not
    // garbage numerics.
    assert!(be.manifest().model("convnet").is_err());
}

#[test]
fn local_train_k1_matches_grad_batch() {
    // train_k1 must be exactly w - lr * grad(batch).
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let model = ops.model;
    let w = be.load_init(model).unwrap();
    let (x, y) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let lr = 0.05f32;

    let w1 = ops.local_train(1, &w, &x, &y, lr).unwrap();
    let g = ops.grad_batch(&w, &x, &y).unwrap();
    let mut want = w.clone();
    vecmath::axpy(-lr, &g, &mut want);
    for (a, b) in w1.iter().zip(want.iter()) {
        assert!((a - b).abs() < 1e-6, "{a} vs {b}");
    }
}

#[test]
fn local_training_reduces_loss() {
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let model = ops.model;
    let mut w = be.load_init(model).unwrap();
    let (x, y) = test_batch(model.feature_len(), model.eval_batch, model.n_classes);
    let (loss0, _) = ops.eval_batch(&w, &x, &y).unwrap();

    let (xt, yt) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let xs: Vec<f32> = xt.iter().cloned().cycle().take(5 * xt.len()).collect();
    let ys: Vec<i32> = yt.iter().cloned().cycle().take(5 * yt.len()).collect();
    for _ in 0..10 {
        w = ops.local_train(5, &w, &xs, &ys, 0.05).unwrap();
    }
    let (loss1, _) = ops.eval_batch(&w, &x, &y).unwrap();
    let w0 = be.load_init(model).unwrap();
    let g0 = ops.grad_batch(&w0, &xt, &yt).unwrap();
    let g1 = ops.grad_batch(&w, &xt, &yt).unwrap();
    assert!(
        vecmath::norm(&g1) < vecmath::norm(&g0),
        "gradient should shrink as the batch is fit"
    );
    assert!(loss1.is_finite() && loss0.is_finite());
}

#[test]
fn syn_step_improves_cosine_and_syn_grad_agrees() {
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let model = ops.model;
    let w = be.load_init(model).unwrap();

    // Build a realistic target: one local training delta.
    let (xt, yt) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let xs: Vec<f32> = xt.iter().cloned().cycle().take(5 * xt.len()).collect();
    let ys: Vec<i32> = yt.iter().cloned().cycle().take(5 * yt.len()).collect();
    let w_local = ops.local_train(5, &w, &xs, &ys, 0.05).unwrap();
    let target = vecmath::sub(&w, &w_local);

    let mut rng = fed3sfc::util::rng::Rng::new(7);
    let mut dx = vec![0.0f32; model.feature_len()];
    rng.fill_normal(&mut dx, 0.5);
    let mut dy = vec![0.0f32; model.n_classes];

    let mut first = None;
    let mut last = 0.0f32;
    for _ in 0..25 {
        let (ndx, ndy, cos) = ops
            .syn_step(1, &w, &target, &dx, &dy, 5.0, 0.0)
            .unwrap();
        if first.is_none() {
            first = Some(cos.abs());
        }
        last = cos.abs();
        dx = ndx;
        dy = ndy;
    }
    assert!(last > first.unwrap(), "{:?} -> {last}", first);

    // syn_grad at the optimized features matches the cosine the step reported.
    let g = ops.syn_grad(1, &w, &dx, &dy).unwrap();
    let cos_host = vecmath::cosine(&g, &target).abs() as f32;
    assert!((cos_host - last).abs() < 0.15, "{cos_host} vs {last}");
}

#[test]
fn syn_step_gradient_descends_the_objective() {
    // A small-enough step on the Eq. 9 objective must not increase
    // 1 − |cos| (λ = 0): the native encoder gradient points downhill.
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let model = ops.model;
    let w = be.load_init(model).unwrap();
    let (xt, yt) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let w_local = ops.local_train(1, &w, &xt, &yt, 0.05).unwrap();
    let target = vecmath::sub(&w, &w_local);

    let mut rng = fed3sfc::util::rng::Rng::new(77);
    let mut dx = vec![0.0f32; model.feature_len()];
    rng.fill_normal(&mut dx, 0.5);
    let dy = vec![0.0f32; model.n_classes];

    let cos_at = |dx: &[f32], dy: &[f32]| -> f64 {
        let g = ops.syn_grad(1, &w, dx, dy).unwrap();
        vecmath::cosine(&g, &target).abs()
    };
    let before = cos_at(&dx, &dy);
    let (ndx, ndy, _) = ops.syn_step(1, &w, &target, &dx, &dy, 0.05, 0.0).unwrap();
    let after = cos_at(&ndx, &ndy);
    assert!(
        after >= before - 1e-4,
        "tiny syn_step increased the objective: |cos| {before} -> {after}"
    );
}

#[test]
fn eval_dataset_loops_batches_consistently() {
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let model = ops.model;
    let w = be.load_init(model).unwrap();
    let b = model.eval_batch;
    let (x, y) = test_batch(model.feature_len(), 2 * b, model.n_classes);
    let (loss_all, acc_all) = ops.eval_dataset(&w, &x, &y).unwrap();

    let (l1, c1) = ops
        .eval_batch(&w, &x[..b * model.feature_len()], &y[..b])
        .unwrap();
    let (l2, c2) = ops
        .eval_batch(&w, &x[b * model.feature_len()..], &y[b..])
        .unwrap();
    let want_loss = (l1 + l2) as f64 / (2 * b) as f64;
    let want_acc = (c1 + c2) as f64 / (2 * b) as f64;
    assert!((loss_all - want_loss).abs() < 1e-5);
    assert!((acc_all - want_acc).abs() < 1e-9);
}

#[test]
fn fedsynth_apply_matches_step_fit() {
    // The forward replay inside fedsynth_step and the standalone decoder
    // must agree on the simulated delta: fit == ‖apply(D) − target‖².
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let model = ops.model;
    let w = be.load_init(model).unwrap();
    let (xt, yt) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let xs: Vec<f32> = xt.iter().cloned().cycle().take(5 * xt.len()).collect();
    let ys: Vec<i32> = yt.iter().cloned().cycle().take(5 * yt.len()).collect();
    let w_local = ops.local_train(5, &w, &xs, &ys, 0.05).unwrap();
    let target = vecmath::sub(&w, &w_local);

    let k = 4;
    let mut rng = fed3sfc::util::rng::Rng::new(9);
    let mut dxs = vec![0.0f32; k * model.feature_len()];
    rng.fill_normal(&mut dxs, 0.5);
    let dys = vec![0.0f32; k * model.n_classes];

    let (_, _, fit, norms) = ops
        .fedsynth_step(k, 1, &w, &target, &dxs, &dys, 0.05, 0.0)
        .unwrap();
    assert_eq!(norms.len(), k);
    assert!(norms.iter().all(|n| n.is_finite()));
    let delta = ops.fedsynth_apply(k, 1, &w, &dxs, &dys, 0.05).unwrap();
    let err = vecmath::sub(&delta, &target);
    let want_fit = vecmath::norm2(&err) as f32;
    assert!(
        (fit - want_fit).abs() < 1e-3 * (1.0 + want_fit.abs()),
        "{fit} vs {want_fit}"
    );
}

#[test]
fn fedsynth_outer_steps_reduce_fit() {
    // The distillation objective must (at a modest lr) actually descend:
    // its gradient comes from the hand-rolled unroll backward, so a sign
    // error anywhere would show up here immediately.
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let model = ops.model;
    let w = be.load_init(model).unwrap();
    let (xt, yt) = test_batch(model.feature_len(), model.train_batch, model.n_classes);
    let w_local = ops.local_train(1, &w, &xt, &yt, 0.05).unwrap();
    let target = vecmath::sub(&w, &w_local);

    let k = 2;
    let mut rng = fed3sfc::util::rng::Rng::new(15);
    let mut dxs = vec![0.0f32; k * model.feature_len()];
    rng.fill_normal(&mut dxs, 0.5);
    let mut dys = vec![0.0f32; k * model.n_classes];

    let mut first = None;
    let mut last = f32::NAN;
    for _ in 0..12 {
        let (ndxs, ndys, fit, _) = ops
            .fedsynth_step(k, 1, &w, &target, &dxs, &dys, 0.05, 0.25)
            .unwrap();
        if first.is_none() {
            first = Some(fit);
        }
        last = fit;
        dxs = ndxs;
        dys = ndys;
    }
    assert!(
        last < first.unwrap(),
        "fit did not decrease: {first:?} -> {last}"
    );
}

#[test]
fn grad_batch_matches_soft_grad_with_onehot_labels() {
    // Hard labels are the one-hot limit of the soft-label path: push the
    // label logits far toward one-hot and the two gradients converge.
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let model = ops.model;
    let w = be.load_init(model).unwrap();
    let m = 4usize;
    let (x, y) = test_batch(model.feature_len(), m, model.n_classes);
    let g_hard = ops.grad_batch(&w, &x, &y).unwrap();
    let mut dy = vec![-40.0f32; m * model.n_classes];
    for (i, &yi) in y.iter().enumerate() {
        dy[i * model.n_classes + yi as usize] = 40.0;
    }
    let g_soft = ops.syn_grad(m, &w, &x, &dy).unwrap();
    let cos = vecmath::cosine(&g_hard, &g_soft);
    assert!(cos > 0.9999, "hard/soft gradient cos {cos}");
}
