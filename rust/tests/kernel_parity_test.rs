//! Kernel-parity suite: the register-blocked GEMM kernels
//! (`runtime::kernels`) must match the retained naive oracles
//! (`runtime::kernels::naive`) to ≤ 1e-5 relative error on random shapes,
//! including ragged dimensions that are not multiples of the 4×8 register
//! tile — every tail path (row strip, column strip, depth remainder) gets
//! exercised by the size sweep.

use fed3sfc::runtime::kernels;
use fed3sfc::testing::prop::check;

fn rel_close(got: &[f32], want: &[f32]) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("len {} vs {}", got.len(), want.len()));
    }
    for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
        let tol = 1e-5f32 * w.abs().max(1.0);
        if (g - w).abs() > tol {
            return Err(format!("[{i}] tiled {g} vs naive {w}"));
        }
    }
    Ok(())
}

#[test]
fn prop_mm_matches_naive_oracle() {
    check("mm-parity", 80, |c| {
        let m = c.len(13);
        let k = c.len(48);
        let n = c.len(41);
        let a = c.vec_f32(m * k, 1.0);
        let b = c.vec_f32(k * n, 1.0);
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        kernels::mm(&a, &b, m, k, n, &mut got);
        kernels::naive::mm(&a, &b, m, k, n, &mut want);
        rel_close(&got, &want).map_err(|e| format!("mm {m}x{k}x{n} {e}"))
    });
}

#[test]
fn prop_mm_at_acc_matches_naive_oracle() {
    check("mm-at-parity", 80, |c| {
        let k = c.len(13);
        let m = c.len(48);
        let n = c.len(41);
        let a = c.vec_f32(k * m, 1.0);
        let b = c.vec_f32(k * n, 1.0);
        // Accumulate onto an identical random base to cover the `+=`
        // contract, not just the zero-start case.
        let base = c.vec_f32(m * n, 1.0);
        let mut got = base.clone();
        let mut want = base;
        kernels::mm_at_acc(&a, &b, k, m, n, &mut got);
        kernels::naive::mm_at_acc(&a, &b, k, m, n, &mut want);
        rel_close(&got, &want).map_err(|e| format!("mm_at {k}x{m}x{n} {e}"))
    });
}

#[test]
fn prop_mm_bt_acc_matches_naive_oracle() {
    check("mm-bt-parity", 80, |c| {
        let m = c.len(13);
        let k = c.len(48);
        let n = c.len(41);
        let a = c.vec_f32(m * k, 1.0);
        let b = c.vec_f32(n * k, 1.0);
        let base = c.vec_f32(m * n, 1.0);
        let mut got = base.clone();
        let mut want = base;
        kernels::mm_bt_acc(&a, &b, m, k, n, &mut got);
        kernels::naive::mm_bt_acc(&a, &b, m, k, n, &mut want);
        rel_close(&got, &want).map_err(|e| format!("mm_bt {m}x{k}x{n} {e}"))
    });
}

#[test]
fn tile_boundary_shapes_exact_paths() {
    // Deterministic sweep across the exact tile boundaries: 1 below, at,
    // and 1 above the 4-row / 8-column / 4-lane tile sizes.
    for &m in &[1usize, 3, 4, 5, 8] {
        for &k in &[1usize, 3, 4, 5, 16] {
            for &n in &[1usize, 7, 8, 9, 16] {
                let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
                let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
                let mut got = vec![0.0f32; m * n];
                let mut want = vec![0.0f32; m * n];
                kernels::mm(&a, &b, m, k, n, &mut got);
                kernels::naive::mm(&a, &b, m, k, n, &mut want);
                for (i, (&g, &w)) in got.iter().zip(want.iter()).enumerate() {
                    assert!(
                        (g - w).abs() <= 1e-5 * w.abs().max(1.0),
                        "mm {m}x{k}x{n} [{i}]: {g} vs {w}"
                    );
                }
            }
        }
    }
}
