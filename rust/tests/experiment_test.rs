//! End-to-end coordinator integration on the small model: every method
//! trains, determinism holds, EF matters, traffic accounting is exact.
//!
//! The full suite runs unconditionally on the native backend; a pjrt
//! variant of the core assertions re-runs on the artifact path when an
//! artifact bundle is available (see tests/common/mod.rs).

mod common;

use fed3sfc::config::{CompressorKind, DatasetKind, ExperimentConfig};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::Backend;

fn small_cfg(method: CompressorKind) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: method,
        n_clients: 4,
        rounds: 12,
        k_local: 5,
        lr: 0.05,
        syn_steps: 10,
        train_samples: 320,
        test_samples: 100,
        eval_every: 12,
        seed: 42,
        ..ExperimentConfig::default()
    }
}

fn run_on(cfg: ExperimentConfig, backend: &dyn Backend) -> Vec<fed3sfc::RoundRecord> {
    let mut exp = Experiment::new(cfg, backend).unwrap();
    exp.run().unwrap()
}

fn run(cfg: ExperimentConfig) -> Vec<fed3sfc::RoundRecord> {
    let be = common::native();
    run_on(cfg, &be)
}

fn check_every_method_improves(backend: &dyn Backend) {
    for method in [
        CompressorKind::FedAvg,
        CompressorKind::Dgc,
        CompressorKind::SignSgd,
        CompressorKind::Stc,
        CompressorKind::ThreeSfc,
    ] {
        let recs = run_on(small_cfg(method), backend);
        let last = recs.last().unwrap();
        assert!(
            last.test_acc > 0.25,
            "{method:?}: acc {} after {} rounds (chance = 0.125)",
            last.test_acc,
            recs.len()
        );
        assert!(last.test_loss.is_finite());
    }
}

#[test]
fn every_method_improves_over_init() {
    let be = common::native();
    check_every_method_improves(&be);
}

#[test]
fn pjrt_every_method_improves_over_init() {
    let _g = common::lock();
    let Some(be) = common::pjrt() else { return };
    check_every_method_improves(be.as_ref());
}

fn check_deterministic_replay(backend: &dyn Backend) {
    let a = run_on(small_cfg(CompressorKind::ThreeSfc), backend);
    let b = run_on(small_cfg(CompressorKind::ThreeSfc), backend);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits());
        assert_eq!(x.up_bytes_cum, y.up_bytes_cum);
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits());
        assert_eq!(x.comm_time_s.to_bits(), y.comm_time_s.to_bits());
    }
}

#[test]
fn deterministic_replay() {
    let be = common::native();
    check_deterministic_replay(&be);
}

#[test]
fn pjrt_deterministic_replay() {
    let _g = common::lock();
    let Some(be) = common::pjrt() else { return };
    check_deterministic_replay(be.as_ref());
}

#[test]
fn non_eval_rounds_carry_real_initial_evaluation() {
    // eval_every = 12 means rounds 1..11 are non-eval; they must carry a
    // real round-0 evaluation of the initial weights, never NaN.
    let recs = run(small_cfg(CompressorKind::ThreeSfc));
    for r in &recs {
        assert!(r.test_acc.is_finite(), "round {}: acc NaN", r.round);
        assert!(r.test_loss.is_finite(), "round {}: loss NaN", r.round);
    }
    // All pre-eval rounds share the same (round-0) evaluation.
    for w in recs[..11].windows(2) {
        assert_eq!(w[0].test_acc.to_bits(), w[1].test_acc.to_bits());
    }
    // The terminal eval round re-evaluates the trained model.
    assert_ne!(recs[0].test_loss.to_bits(), recs[11].test_loss.to_bits());
}

#[test]
fn seeds_change_trajectories() {
    let a = run(small_cfg(CompressorKind::ThreeSfc));
    let mut cfg = small_cfg(CompressorKind::ThreeSfc);
    cfg.seed = 43;
    let b = run(cfg);
    assert_ne!(
        a.last().unwrap().efficiency,
        b.last().unwrap().efficiency
    );
}

#[test]
fn error_feedback_ablation_changes_dynamics() {
    // Table 4: EF off must change (and generally hurt) the trajectory.
    let with_ef = run(small_cfg(CompressorKind::ThreeSfc));
    let mut cfg = small_cfg(CompressorKind::ThreeSfc);
    cfg.error_feedback = false;
    let without = run(cfg);
    assert_ne!(
        with_ef.last().unwrap().test_acc,
        without.last().unwrap().test_acc
    );
}

#[test]
fn traffic_accounting_is_exact() {
    let be = common::native();
    let cfg = small_cfg(CompressorKind::ThreeSfc);
    let rounds = cfg.rounds as u64;
    let clients = cfg.n_clients as u64;
    let mut exp = Experiment::new(cfg, &be).unwrap();
    exp.run().unwrap();
    let model = exp.ops.model;
    // 3SFC payload is fixed-size: m(d+C)+1 floats per client per round.
    let per = model.syn_payload_bytes(1) as u64;
    assert_eq!(exp.traffic().uplink_bytes, per * clients * rounds);
    // Downlink framing mirrors the upload path: u32 length header + 4P
    // per receiving client (the identity downlink ships one keyframe per
    // broadcast, priced exactly like the legacy dense path).
    assert_eq!(
        exp.traffic().downlink_bytes,
        (4 + 4 * model.params as u64) * clients * rounds
    );
    assert_eq!(
        exp.traffic().total_bytes(),
        exp.traffic().uplink_bytes + exp.traffic().downlink_bytes
    );
    assert_eq!(exp.traffic().rounds, rounds);
    // Full participation: every round selects every client, and the
    // modeled per-round comm time accumulates into the traffic totals.
    assert!(exp
        .metrics
        .records
        .iter()
        .all(|r| r.n_selected == clients as usize));
    assert!(exp.traffic().comm_s > 0.0);
    let sum: f64 = exp.metrics.records.iter().map(|r| r.comm_time_s).sum();
    assert!((exp.traffic().comm_s - sum).abs() < 1e-9);
    // The virtual clock is cumulative: the last record's sim_time_s is
    // the total modeled communication time.
    let last = exp.metrics.records.last().unwrap();
    assert!((last.sim_time_s - exp.traffic().comm_s).abs() < 1e-9);
}

#[test]
fn compression_ratios_ordered_as_paper() {
    // 3SFC (m=1) must communicate less per round than signSGD, which
    // communicates less than FedAvg. (Table 2's ratio columns.)
    let bytes_of = |method| {
        let recs = run(small_cfg(method));
        recs.last().unwrap().up_bytes_round
    };
    let b3 = bytes_of(CompressorKind::ThreeSfc);
    let bs = bytes_of(CompressorKind::SignSgd);
    let bf = bytes_of(CompressorKind::FedAvg);
    assert!(b3 < bf, "3sfc {b3} vs fedavg {bf}");
    assert!(bs < bf);
}

#[test]
fn extreme_alpha_tiny_shards_train_without_panicking() {
    // Regression (ISSUE 2): alpha = 0.01 with n_clients = train_samples/2
    // used to be able to leave a client with an empty shard, which killed
    // the round in empty-pool sampling (or tripped the aggregation
    // assert). The partition now guarantees >= 1 sample per client at
    // this density, and the round loop skips zero-weight clients anyway.
    let mut cfg = small_cfg(CompressorKind::Dgc);
    cfg.alpha = 0.01;
    cfg.n_clients = 32;
    cfg.train_samples = 64;
    cfg.rounds = 2;
    cfg.k_local = 1;
    cfg.eval_every = 2;
    let recs = run(cfg);
    assert_eq!(recs.len(), 2);
    for r in &recs {
        assert!(r.n_selected > 0);
        assert!(r.test_loss.is_finite());
    }
}

#[test]
fn efficiency_metric_in_range() {
    let recs = run(small_cfg(CompressorKind::Dgc));
    for r in &recs {
        assert!((-1.0..=1.0).contains(&r.efficiency), "{}", r.efficiency);
        assert!(r.efficiency > 0.0, "top-k efficiency must be positive");
    }
}

#[test]
fn metrics_jsonl_roundtrip() {
    let dir = std::env::temp_dir().join("fed3sfc_test_metrics.jsonl");
    let mut cfg = small_cfg(CompressorKind::Dgc);
    cfg.rounds = 3;
    cfg.metrics_path = dir.to_str().unwrap().to_string();
    let _ = run(cfg);
    let text = std::fs::read_to_string(&dir).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3);
    for line in lines {
        let v = fed3sfc::util::json::parse(line).unwrap();
        assert!(v.get("round").is_some());
        assert!(v.get("test_acc").is_some());
        assert!(v.get("up_bytes_cum").is_some());
    }
    std::fs::remove_file(dir).ok();
}

#[test]
fn fedsynth_trains_end_to_end_on_native() {
    // The multi-step baseline exercises the second-order unroll backward
    // (HVP + cross terms); 4 rounds must run and stay finite.
    let mut cfg = small_cfg(CompressorKind::FedSynth);
    cfg.rounds = 4;
    cfg.eval_every = 4;
    cfg.fedsynth_steps = 5;
    let recs = run(cfg);
    assert_eq!(recs.len(), 4);
    assert!(recs.last().unwrap().test_loss.is_finite());
}
