//! Event-driven session acceptance tests.
//!
//! * **Golden equivalence**: the `Synchronous` policy through the new
//!   message-passing `FedServer` must reproduce the pre-refactor
//!   blocking round loop **bit-for-bit** — weights, bytes, EF state —
//!   for `threads ∈ {1, 4}`. The reference is an independent replica of
//!   the old loop (selection-order sequential `run_client`, aggregate,
//!   server step) built from the same public pieces.
//! * **Determinism**: `Deadline` and `BufferedAsync` sessions are pure
//!   functions of the seed — the virtual clock is the only time source,
//!   ties break by client index — and virtual time is monotone.

mod common;

use fed3sfc::compress;
use fed3sfc::config::{
    CompressorKind, DatasetKind, ExperimentConfig, NetworkKind, ScheduleKind, SessionKind,
};
use fed3sfc::coordinator::{
    build_scheduler, build_server_opt, run_client, ClientJob, ClientState, Experiment, Server,
};
use fed3sfc::data::{dirichlet_partition, Dataset};
use fed3sfc::runtime::{Backend, FedOps};
use fed3sfc::util::rng::Rng;
use fed3sfc::RoundRecord;

fn golden_cfg(method: CompressorKind, threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: method,
        n_clients: 5,
        rounds: 5,
        k_local: 5,
        lr: 0.05,
        syn_steps: 6,
        train_samples: 200,
        test_samples: 50,
        eval_every: 5,
        seed: 42,
        // Partial participation exercises the scheduler stream and EF
        // persistence across skips on both sides of the comparison.
        schedule: ScheduleKind::Uniform,
        client_frac: 0.6,
        threads,
        ..ExperimentConfig::default()
    }
}

/// Per-round observables of the legacy loop (the fields the golden
/// contract pins bit-for-bit; comm/wall times are not part of it).
struct LegacyRound {
    n_selected: usize,
    up_bytes: u64,
    efficiency: f64,
    ratio: f64,
}

struct LegacyRun {
    weights: Vec<f32>,
    efs: Vec<Vec<f32>>,
    rounds: Vec<LegacyRound>,
    up_cum: u64,
    down_cum: u64,
}

/// The pre-refactor round loop, replicated from the same public pieces
/// the experiment wires together (identical RNG stream derivations):
/// select → filter zero-sample → sample batches in selection order →
/// sequential `run_client` → write-back → weighted aggregate → server
/// step.
fn legacy_run(cfg: &ExperimentConfig, backend: &dyn Backend) -> LegacyRun {
    let ops = FedOps::new(backend, cfg.model_key()).unwrap();
    let model = ops.model;
    let root = Rng::new(cfg.seed);
    let train = Dataset::generate_split(cfg.dataset, cfg.train_samples, cfg.seed, 0);
    let mut part_rng = root.split(0x9A87_1710);
    let parts = dirichlet_partition(&train, cfg.n_clients, cfg.alpha, &mut part_rng);
    let mut clients: Vec<ClientState> = parts
        .into_iter()
        .enumerate()
        .map(|(i, idxs)| ClientState::new(i, idxs, model.params, &root))
        .collect();
    let w0 = backend.load_init(model).unwrap();
    let mut scheduler = build_scheduler(cfg, &root);
    let mut server = Server::with_optimizer(w0, build_server_opt(cfg));
    let compressor = compress::build(cfg, model);

    let mut rounds = Vec::with_capacity(cfg.rounds);
    let mut up_cum = 0u64;
    let mut down_cum = 0u64;
    for _ in 0..cfg.rounds {
        let w_global = server.w.clone();
        let selected = scheduler.select(server.round, clients.len());
        let active: Vec<usize> = selected
            .into_iter()
            .filter(|&ci| clients[ci].n_samples > 0)
            .collect();
        let mut recons: Vec<Vec<f32>> = Vec::new();
        let mut weights: Vec<f32> = Vec::new();
        let mut bytes = 0u64;
        let mut eff = 0.0f64;
        let mut ratio = 0.0f64;
        for (slot, &ci) in active.iter().enumerate() {
            let client = &mut clients[ci];
            let (xs, ys) = client.sample_round(&train, cfg.k_local, model.train_batch);
            let ef = if cfg.error_feedback { client.ef.clone() } else { Vec::new() };
            let job = ClientJob {
                slot,
                xs,
                ys,
                ef,
                rng: client.rng.clone(),
                weight: client.n_samples as f32,
            };
            let u = run_client(&ops, compressor.as_ref(), cfg, &w_global, job).unwrap();
            if cfg.error_feedback {
                client.ef = u.ef;
            }
            client.rng = u.rng;
            bytes += u.payload.wire_bytes() as u64;
            eff += u.efficiency;
            ratio += u.ratio;
            recons.push(u.recon);
            weights.push(u.weight);
        }
        server.apply_round(&recons, &weights);
        up_cum += bytes;
        down_cum += (4 + 4 * model.params as u64) * active.len() as u64;
        let n = active.len();
        rounds.push(LegacyRound {
            n_selected: n,
            up_bytes: bytes,
            efficiency: if n == 0 { 0.0 } else { eff / n as f64 },
            ratio: if n == 0 { 0.0 } else { ratio / n as f64 },
        });
    }
    LegacyRun {
        weights: server.w,
        efs: clients.into_iter().map(|c| c.ef).collect(),
        rounds,
        up_cum,
        down_cum,
    }
}

fn check_golden(method: CompressorKind, threads: usize) {
    let be = common::native();
    let cfg = golden_cfg(method, threads);
    let legacy = legacy_run(&cfg, &be);

    let mut exp = Experiment::new(cfg, &be).unwrap();
    let recs = exp.run().unwrap();

    assert_eq!(recs.len(), legacy.rounds.len());
    for (r, l) in recs.iter().zip(legacy.rounds.iter()) {
        assert_eq!(r.n_selected, l.n_selected, "round {}", r.round);
        assert_eq!(r.up_bytes_round, l.up_bytes, "round {}", r.round);
        assert_eq!(
            r.efficiency.to_bits(),
            l.efficiency.to_bits(),
            "round {} efficiency",
            r.round
        );
        assert_eq!(r.ratio.to_bits(), l.ratio.to_bits(), "round {} ratio", r.round);
        assert_eq!(r.stale_mean, 0.0, "sync staleness is identically zero");
    }
    // Global weights bit-identical after the full trajectory.
    assert_eq!(exp.fed.server.w.len(), legacy.weights.len());
    for (i, (a, b)) in exp.fed.server.w.iter().zip(legacy.weights.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "w[{i}] (threads={threads})");
    }
    // Per-client error-feedback state bit-identical (densified through
    // the store, wherever each client's EF currently lives).
    for (ci, (a, b)) in exp.clients.ef_snapshots().iter().zip(legacy.efs.iter()).enumerate() {
        assert_eq!(a.len(), b.len(), "client {ci}");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "client {ci} ef[{i}]");
        }
    }
    // Exact traffic totals (uploads and header-framed broadcasts; the
    // identity downlink prices every keyframe exactly like the legacy
    // dense broadcast).
    assert_eq!(exp.traffic().uplink_bytes, legacy.up_cum);
    assert_eq!(exp.traffic().downlink_bytes, legacy.down_cum);
}

#[test]
fn golden_sync_equals_legacy_loop_threesfc_threads1() {
    check_golden(CompressorKind::ThreeSfc, 1);
}

#[test]
fn golden_sync_equals_legacy_loop_threesfc_threads4() {
    check_golden(CompressorKind::ThreeSfc, 4);
}

#[test]
fn golden_sync_equals_legacy_loop_dgc_threads1() {
    check_golden(CompressorKind::Dgc, 1);
}

#[test]
fn golden_sync_equals_legacy_loop_dgc_threads4() {
    check_golden(CompressorKind::Dgc, 4);
}

// ---------------------------------------------------------------------
// Deadline / async determinism on the virtual clock.

fn deadline_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 6,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        train_samples: 240,
        test_samples: 50,
        eval_every: 6,
        seed: 42,
        session: SessionKind::Deadline,
        // Slow asymmetric custom link + wide jitter: transfer times
        // dominate latency, so the deadline genuinely splits the cohort
        // and stragglers carry over.
        network: NetworkKind::Custom,
        net_up_mbps: 0.1,
        net_down_mbps: 1.0,
        net_latency_ms: 1.0,
        net_jitter: 0.5,
        deadline_s: 0.08,
        staleness_decay: 0.5,
        threads,
        ..ExperimentConfig::default()
    }
}

fn async_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 4,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        train_samples: 200,
        test_samples: 50,
        eval_every: 6,
        seed: 42,
        session: SessionKind::Async,
        buffer_k: 2,
        staleness_decay: 0.5,
        net_jitter: 0.3,
        threads,
        ..ExperimentConfig::default()
    }
}

fn run_records(cfg: ExperimentConfig) -> (Vec<RoundRecord>, Vec<Vec<f32>>) {
    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    let recs = exp.run().unwrap();
    let efs = exp.clients.ef_snapshots();
    (recs, efs)
}

fn assert_records_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.n_selected, y.n_selected, "round {}", x.round);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {}", x.round);
        assert_eq!(x.up_bytes_round, y.up_bytes_round, "round {}", x.round);
        assert_eq!(x.up_bytes_cum, y.up_bytes_cum, "round {}", x.round);
        assert_eq!(x.down_bytes_round, y.down_bytes_round, "round {}", x.round);
        assert_eq!(x.down_bytes_cum, y.down_bytes_cum, "round {}", x.round);
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "round {}", x.round);
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "round {}", x.round);
        assert_eq!(x.stale_mean.to_bits(), y.stale_mean.to_bits(), "round {}", x.round);
        assert_eq!(x.comm_time_s.to_bits(), y.comm_time_s.to_bits(), "round {}", x.round);
    }
}

fn assert_virtual_time_monotone(recs: &[RoundRecord]) {
    let mut last = 0.0f64;
    for r in recs {
        assert!(r.comm_time_s >= 0.0, "round {}: negative step time", r.round);
        assert!(r.sim_time_s >= last, "round {}: virtual time regressed", r.round);
        assert!(
            (r.sim_time_s - last - r.comm_time_s).abs() < 1e-9,
            "round {}: sim_time_s must accumulate comm_time_s",
            r.round
        );
        last = r.sim_time_s;
    }
}

#[test]
fn deadline_session_is_deterministic_and_monotone() {
    let (a, ef_a) = run_records(deadline_cfg(1));
    let (b, ef_b) = run_records(deadline_cfg(1));
    assert_records_bit_identical(&a, &b);
    assert_eq!(ef_a, ef_b);
    assert_virtual_time_monotone(&a);
    // The deadline paces the session: every step consumes at least one
    // full deadline window of virtual time.
    for r in &a {
        assert!(r.comm_time_s >= 0.08 - 1e-12, "round {}: {}", r.round, r.comm_time_s);
    }
    // The slow jittered links actually produce stragglers: some step
    // aggregates a stale (carried-over) upload, and some step misses
    // part of the cohort.
    assert!(a.iter().any(|r| r.stale_mean > 0.0), "no straggler ever carried over");
    assert!(a.iter().any(|r| r.n_selected < 6), "deadline never split the cohort");
    assert!(a.iter().all(|r| r.test_acc.is_finite() && r.test_loss.is_finite()));
}

#[test]
fn deadline_session_is_thread_count_independent() {
    let (a, ef_a) = run_records(deadline_cfg(1));
    let (b, ef_b) = run_records(deadline_cfg(4));
    assert_records_bit_identical(&a, &b);
    assert_eq!(ef_a, ef_b);
}

#[test]
fn async_session_is_deterministic_and_monotone() {
    let (a, ef_a) = run_records(async_cfg(1));
    let (b, ef_b) = run_records(async_cfg(1));
    assert_records_bit_identical(&a, &b);
    assert_eq!(ef_a, ef_b);
    assert_virtual_time_monotone(&a);
    // With every client perpetually in flight, each step aggregates
    // exactly buffer_k uploads.
    assert!(a.iter().all(|r| r.n_selected == 2), "every async step is K arrivals");
    // Buffered uploads trained against an older model accrue staleness.
    assert!(a.iter().any(|r| r.stale_mean > 0.0), "async never observed staleness");
    assert!(a.iter().all(|r| r.test_acc.is_finite() && r.test_loss.is_finite()));
}

#[test]
fn async_partial_schedule_fixes_the_inflight_set() {
    // Documented semantic: in async mode the scheduler runs once, at
    // session start, and its cohort becomes the fixed concurrency set
    // (FedBuff's "M concurrent clients") — clients outside the initial
    // cohort never participate.
    let mut cfg = async_cfg(1);
    cfg.n_clients = 6;
    cfg.train_samples = 240;
    cfg.schedule = ScheduleKind::Uniform;
    cfg.client_frac = 0.5;
    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    let recs = exp.run().unwrap();
    // Steps still aggregate exactly buffer_k uploads each…
    assert!(recs.iter().all(|r| r.n_selected == 2));
    // …but only the 3 clients of the initial cohort (⌈0.5·6⌉) ever
    // train; everyone else sits outside the in-flight set.
    let counts = exp.clients.participation_counts();
    let participants = counts.iter().filter(|&&r| r > 0).count();
    assert_eq!(participants, 3, "exactly the initial cohort participates");
    let dispatched: usize = counts.iter().sum();
    // Every aggregated upload came from a dispatch (stragglers may still
    // be in flight at the end, so dispatches ≥ aggregations).
    let aggregated: usize = recs.iter().map(|r| r.n_selected).sum();
    assert!(dispatched >= aggregated);
}

#[test]
fn async_session_is_thread_count_independent() {
    let (a, ef_a) = run_records(async_cfg(1));
    let (b, ef_b) = run_records(async_cfg(2));
    assert_records_bit_identical(&a, &b);
    assert_eq!(ef_a, ef_b);
}

#[test]
fn sync_trajectory_is_invariant_to_link_jitter() {
    // Jitter reshuffles *arrival order*, but the synchronous barrier
    // aggregates in selection order — so the training trajectory (and
    // every byte) is identical; only modeled times change.
    let mut jittered = golden_cfg(CompressorKind::Dgc, 1);
    jittered.net_jitter = 0.8;
    let (a, ef_a) = run_records(golden_cfg(CompressorKind::Dgc, 1));
    let (b, ef_b) = run_records(jittered);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {}", x.round);
        assert_eq!(x.up_bytes_cum, y.up_bytes_cum);
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits());
        assert_eq!(x.n_selected, y.n_selected);
    }
    assert_eq!(ef_a, ef_b);
}
