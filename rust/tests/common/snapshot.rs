//! Zero-dependency CLI snapshot harness (insta_cmd-style, hand-rolled:
//! the container is offline, so no `insta`/`insta-cmd` crates).
//!
//! Each assertion spawns the real `fed3sfc` binary, renders argv + exit
//! status + stdout + stderr into one canonical text block, and
//! byte-compares it against the committed golden in `tests/snapshots/`.
//!
//! Review workflow on a mismatch: the harness writes the fresh render
//! next to the golden as `<name>.snap.new` and panics with both paths —
//! diff them, then either fix the regression or bless the new output by
//! re-running with `FED3SFC_SNAP=update` (which rewrites the goldens
//! in-place; commit the diff). CI fails if any `.snap.new` files exist
//! after the test run, so an un-reviewed mismatch can never land.
//!
//! Scenario commands must keep their stdout machine-independent: virtual
//! clock only (no wall time), fixed seeds, no thread-count dependence,
//! no absolute paths.

use std::path::PathBuf;
use std::process::{Command, Output};

fn snapshot_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/snapshots")
}

/// Render one CLI invocation the way the `.snap` goldens store it.
fn render(args: &[&str], out: &Output) -> String {
    format!(
        "---\nsource: tests/cli_snapshot_test.rs\nexpression: \"fed3sfc {}\"\n---\n\
         success: {}\nexit_code: {}\n----- stdout -----\n{}----- stderr -----\n{}",
        args.join(" "),
        out.status.success(),
        out.status
            .code()
            .map(|c| c.to_string())
            .unwrap_or_else(|| "signal".to_string()),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    )
}

/// Run `fed3sfc <args>` (from the crate root, so relative fixture paths
/// are stable) and compare the rendered transcript against
/// `tests/snapshots/<name>.snap`.
pub fn assert_cli_snapshot(name: &str, args: &[&str]) {
    let exe = env!("CARGO_BIN_EXE_fed3sfc");
    let out = Command::new(exe)
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .unwrap_or_else(|e| panic!("failed to spawn {exe}: {e}"));
    let rendered = render(args, &out);
    let dir = snapshot_dir();
    let snap = dir.join(format!("{name}.snap"));
    if std::env::var("FED3SFC_SNAP").as_deref() == Ok("update") {
        std::fs::create_dir_all(&dir).expect("create tests/snapshots");
        std::fs::write(&snap, rendered.as_bytes()).expect("write snapshot");
        return;
    }
    let expected = std::fs::read_to_string(&snap).unwrap_or_else(|_| {
        panic!(
            "missing snapshot {} — record it with FED3SFC_SNAP=update and commit it",
            snap.display()
        )
    });
    if rendered != expected {
        let new = dir.join(format!("{name}.snap.new"));
        std::fs::write(&new, rendered.as_bytes()).expect("write .snap.new");
        panic!(
            "CLI snapshot '{name}' changed.\n  golden: {}\n  fresh:  {}\n\
             Diff the two; fix the regression, or bless the change with \
             FED3SFC_SNAP=update and commit the updated golden.",
            snap.display(),
            new.display()
        );
    }
}
