//! Shared helpers for integration tests — the two test tiers.
//!
//! * **native-always**: every test that exercises coordinator/compressor
//!   semantics builds a [`NativeBackend`] (pure Rust, no artifacts) and
//!   runs unconditionally, in any container.
//! * **pjrt-when-artifacts**: tests that exercise the artifact path call
//!   [`pjrt()`]; it returns `None` — with a skip message, never a panic —
//!   when the artifact bundle is absent, when `FED3SFC_BACKEND=native`
//!   pins the run to the native tier, or when the build has no `pjrt`
//!   feature. See EXPERIMENTS.md §Testing.
//!
//! The PJRT CPU client spins up thread pools; pjrt-tier tests serialize
//! runtime creation behind [`lock()`] so parallel test threads don't
//! stack clients.

#![allow(dead_code)] // each integration-test binary uses a subset

pub mod snapshot;

use std::sync::{Mutex, MutexGuard, OnceLock};

use fed3sfc::runtime::{Backend, NativeBackend};

static LOCK: OnceLock<Mutex<()>> = OnceLock::new();

/// Grab the pjrt-runtime serialization lock (held for the whole test).
pub fn lock() -> MutexGuard<'static, ()> {
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// The always-available pure-Rust backend.
pub fn native() -> NativeBackend {
    NativeBackend::new()
}

/// The PJRT backend, if this environment can provide one. `None` means
/// "skip the pjrt tier" — callers return early without failing.
#[cfg(feature = "pjrt")]
pub fn pjrt() -> Option<Box<dyn Backend>> {
    // Respect the env pin through the same parser every entry point
    // uses (so aliases like "rust" and stray whitespace behave alike).
    if let Ok(v) = std::env::var("FED3SFC_BACKEND") {
        match fed3sfc::config::BackendKind::parse(v.trim()) {
            Ok(fed3sfc::config::BackendKind::Native) => {
                eprintln!("skipping pjrt tier: FED3SFC_BACKEND pins the native backend");
                return None;
            }
            Ok(_) => {}
            Err(_) => {
                eprintln!("skipping pjrt tier: unparseable FED3SFC_BACKEND {v:?}");
                return None;
            }
        }
    }
    let dir = fed3sfc::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "skipping pjrt tier: no artifacts at {} (run `make artifacts` to enable)",
            dir.display()
        );
        return None;
    }
    match fed3sfc::runtime::PjrtBackend::open(&dir) {
        Ok(rt) => Some(Box::new(rt)),
        Err(e) => {
            eprintln!("skipping pjrt tier: artifacts present but unusable: {e:#}");
            None
        }
    }
}

/// Without the `pjrt` feature there is no pjrt tier to run.
#[cfg(not(feature = "pjrt"))]
pub fn pjrt() -> Option<Box<dyn Backend>> {
    eprintln!("skipping pjrt tier: built without the `pjrt` feature");
    None
}
