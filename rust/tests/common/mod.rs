//! Shared helpers for integration tests.
//!
//! The PJRT CPU client spins up thread pools; tests serialize runtime
//! creation behind a global lock so parallel test threads don't stack
//! clients (the `xla` client is !Send, so each test builds its own).

use std::sync::{Mutex, MutexGuard, OnceLock};

use fed3sfc::runtime::Runtime;

static LOCK: OnceLock<Mutex<()>> = OnceLock::new();

/// Grab the runtime serialization lock (held for the whole test).
pub fn lock() -> MutexGuard<'static, ()> {
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

pub fn runtime() -> Runtime {
    Runtime::open(&fed3sfc::artifacts_dir()).expect("run `make artifacts` first")
}
