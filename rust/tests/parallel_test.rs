//! Determinism under parallelism: `threads = N` must reproduce
//! `threads = 1` bit-for-bit — weights trajectory, traffic accounting,
//! efficiency metrics, and per-client error-feedback state — because the
//! round engine collects per-client results into selection-order slots
//! before touching any shared state.
//!
//! Runs unconditionally on the native backend (whose worker pool opens a
//! fresh in-memory backend per thread); one pjrt variant guards the
//! artifact path when a bundle is available.

mod common;

use fed3sfc::config::{CompressorKind, DatasetKind, ExperimentConfig, ScheduleKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::Backend;
use fed3sfc::RoundRecord;

fn cfg(method: CompressorKind, threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: method,
        n_clients: 6,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        syn_steps: 8,
        train_samples: 240,
        test_samples: 80,
        eval_every: 2,
        seed: 42,
        // uniform partial participation: the scheduler stream and
        // per-client EF persistence across skipped rounds must also be
        // thread-count independent
        schedule: ScheduleKind::Uniform,
        client_frac: 0.5,
        threads,
        ..ExperimentConfig::default()
    }
}

/// Run to completion, returning (records, per-client EF state).
fn run_on(cfg: ExperimentConfig, backend: &dyn Backend) -> (Vec<RoundRecord>, Vec<Vec<f32>>) {
    let mut exp = Experiment::new(cfg, backend).unwrap();
    let recs = exp.run().unwrap();
    let efs = exp.clients.ef_snapshots();
    (recs, efs)
}

fn run(cfg: ExperimentConfig) -> (Vec<RoundRecord>, Vec<Vec<f32>>) {
    let be = common::native();
    run_on(cfg, &be)
}

fn assert_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.n_selected, y.n_selected);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {}", x.round);
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "round {}", x.round);
        assert_eq!(x.up_bytes_round, y.up_bytes_round, "round {}", x.round);
        assert_eq!(x.up_bytes_cum, y.up_bytes_cum, "round {}", x.round);
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "round {}", x.round);
        assert_eq!(x.ratio.to_bits(), y.ratio.to_bits(), "round {}", x.round);
        assert_eq!(x.comm_time_s.to_bits(), y.comm_time_s.to_bits(), "round {}", x.round);
    }
}

fn assert_ef_identical(a: &[Vec<f32>], b: &[Vec<f32>]) {
    assert_eq!(a.len(), b.len());
    for (ci, (ea, eb)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(ea.len(), eb.len(), "client {ci}");
        for (i, (x, y)) in ea.iter().zip(eb.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "client {ci} ef[{i}]");
        }
    }
}

#[test]
fn threesfc_parallel_matches_sequential_bitwise() {
    let (seq, seq_ef) = run(cfg(CompressorKind::ThreeSfc, 1));
    let (par, par_ef) = run(cfg(CompressorKind::ThreeSfc, 4));
    assert_bit_identical(&seq, &par);
    assert_ef_identical(&seq_ef, &par_ef);
}

#[test]
fn topk_parallel_matches_sequential_bitwise() {
    let (seq, seq_ef) = run(cfg(CompressorKind::Dgc, 1));
    let (par, par_ef) = run(cfg(CompressorKind::Dgc, 4));
    assert_bit_identical(&seq, &par);
    assert_ef_identical(&seq_ef, &par_ef);
}

#[test]
fn thread_count_is_not_part_of_the_trajectory() {
    // 2 and 4 workers agree too (not just 1 vs N).
    let (a, _) = run(cfg(CompressorKind::ThreeSfc, 2));
    let (b, _) = run(cfg(CompressorKind::ThreeSfc, 4));
    assert_bit_identical(&a, &b);
}

#[test]
fn parallel_experiment_reports_its_worker_count() {
    let be = common::native();
    let exp = Experiment::new(cfg(CompressorKind::Dgc, 3), &be).unwrap();
    assert_eq!(exp.threads(), 3);
    assert!(exp.pool_stats().is_some());
    let seq = Experiment::new(cfg(CompressorKind::Dgc, 1), &be).unwrap();
    assert_eq!(seq.threads(), 1);
    assert!(seq.pool_stats().is_none());
}

#[test]
fn pool_workers_report_execution_stats() {
    // The native workers must publish their op counters back to the pool
    // aggregate, exactly like the per-worker PJRT runtimes do.
    let be = common::native();
    let mut exp = Experiment::new(cfg(CompressorKind::ThreeSfc, 3), &be).unwrap();
    exp.run().unwrap();
    let ws = exp.pool_stats().expect("pool is running");
    assert!(ws.executions > 0, "workers executed nothing");
    assert_eq!(ws.compiles, 0, "native backend never compiles");
}

#[test]
fn pjrt_threesfc_parallel_matches_sequential_bitwise() {
    let _g = common::lock();
    let Some(be) = common::pjrt() else { return };
    let (seq, seq_ef) = run_on(cfg(CompressorKind::ThreeSfc, 1), be.as_ref());
    let (par, par_ef) = run_on(cfg(CompressorKind::ThreeSfc, 4), be.as_ref());
    assert_bit_identical(&seq, &par);
    assert_ef_identical(&seq_ef, &par_ef);
}
