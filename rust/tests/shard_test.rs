//! `[scale]` subsystem acceptance tests: lazy client store, EF spill,
//! and the sharded edge-aggregation tree, end to end through
//! [`Experiment`] on the native backend.
//!
//! The contract pinned here:
//!
//! * spill → restore is bit-exact for arbitrary f32 bit patterns, in
//!   both slab encodings;
//! * `shards ∈ {1, 2, 7}` × `lazy_state ∈ {false, true}` × `threads ∈
//!   {1, 4}` all reproduce the `shards = 1, lazy_state = false,
//!   threads = 1` trajectory **bit-for-bit** in all three session
//!   modes — records, final weights, and every client's EF residual;
//! * a quarantined client's spilled EF survives the quarantine and its
//!   re-admission resumes bit-identically to an eager run;
//! * a million-client store stays `O(cohort)` resident — nothing on
//!   the shard path allocates dense per-client state up front.

mod common;

use fed3sfc::compress::{restore, spill, Payload};
use fed3sfc::config::{
    CompressorKind, DatasetKind, ExperimentConfig, NetworkKind, SessionKind, SpillKind,
};
use fed3sfc::coordinator::{ClientStore, EdgeAggregator, Experiment, RoundRecord, Upload};
use fed3sfc::util::rng::Rng;

fn assert_records_bit_identical(a: &[RoundRecord], b: &[RoundRecord], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round, "{tag}");
        assert_eq!(x.n_selected, y.n_selected, "{tag} round {}", x.round);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "{tag} round {}", x.round);
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "{tag} round {}", x.round);
        assert_eq!(x.up_bytes_cum, y.up_bytes_cum, "{tag} round {}", x.round);
        assert_eq!(x.down_bytes_cum, y.down_bytes_cum, "{tag} round {}", x.round);
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "{tag} round {}", x.round);
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{tag} round {}", x.round);
        assert_eq!(x.stale_mean.to_bits(), y.stale_mean.to_bits(), "{tag} round {}", x.round);
    }
}

fn ef_bits(efs: &[Vec<f32>]) -> Vec<Vec<u32>> {
    efs.iter().map(|ef| ef.iter().map(|x| x.to_bits()).collect()).collect()
}

// ---------------------------------------------------------------------
// Spill codec properties.

#[test]
fn spill_roundtrip_is_bit_exact_for_random_bit_patterns() {
    // Raw RNG words reinterpreted as f32 cover NaN payloads, infinities,
    // subnormals and both zeros; every one must come back bit-for-bit.
    let mut rng = Rng::new(0xE0F);
    for len in [1usize, 7, 64, 1000] {
        for kind in [SpillKind::Boxed, SpillKind::Slab] {
            let ef: Vec<f32> =
                (0..len).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
            let back = restore(&spill(&ef, kind), len);
            assert_eq!(
                ef_bits(&[back]),
                ef_bits(&[ef]),
                "len {len}, kind {}",
                kind.name()
            );
        }
    }
}

// ---------------------------------------------------------------------
// Trajectory invariance: shards × lazy × threads, per session mode.

fn scale_cfg(
    session: SessionKind,
    shards: usize,
    lazy: bool,
    threads: usize,
) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 6,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        train_samples: 240,
        test_samples: 50,
        eval_every: 6,
        seed: 42,
        session,
        threads,
        n_shards: shards,
        lazy_state: lazy,
        ..ExperimentConfig::default()
    };
    match session {
        SessionKind::Sync => {}
        SessionKind::Deadline => {
            // Slow jittered links so the deadline genuinely splits the
            // cohort and stragglers carry over (the interesting case for
            // a store that evicts between participations).
            cfg.network = NetworkKind::Custom;
            cfg.net_up_mbps = 0.1;
            cfg.net_down_mbps = 1.0;
            cfg.net_latency_ms = 1.0;
            cfg.net_jitter = 0.5;
            cfg.deadline_s = 0.08;
            cfg.staleness_decay = 0.5;
        }
        SessionKind::Async => {
            cfg.buffer_k = 2;
            cfg.staleness_decay = 0.5;
            cfg.net_jitter = 0.3;
        }
    }
    cfg
}

/// (records, final weights, EF snapshots, store spill events).
fn run_full(cfg: ExperimentConfig) -> (Vec<RoundRecord>, Vec<f32>, Vec<Vec<f32>>, u64) {
    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    let recs = exp.run().unwrap();
    let efs = exp.clients.ef_snapshots();
    let spills = exp.clients.spill_events();
    (recs, exp.fed.server.w.clone(), efs, spills)
}

fn check_session(session: SessionKind) {
    let (base_recs, base_w, base_efs, _) = run_full(scale_cfg(session, 1, false, 1));
    let base_w: Vec<u32> = base_w.iter().map(|x| x.to_bits()).collect();
    let base_efs = ef_bits(&base_efs);
    for (shards, lazy, threads) in
        [(1usize, true, 1usize), (2, true, 1), (7, false, 1), (7, true, 4)]
    {
        let tag = format!("{session:?} shards={shards} lazy={lazy} threads={threads}");
        let (recs, w, efs, spills) = run_full(scale_cfg(session, shards, lazy, threads));
        assert_records_bit_identical(&base_recs, &recs, &tag);
        let w: Vec<u32> = w.iter().map(|x| x.to_bits()).collect();
        assert_eq!(base_w, w, "{tag}: final weights");
        assert_eq!(base_efs, ef_bits(&efs), "{tag}: EF residuals");
        if lazy {
            assert!(spills > 0, "{tag}: lazy run never actually spilled");
        } else {
            assert_eq!(spills, 0, "{tag}: eager run must never spill");
        }
    }
}

#[test]
fn sync_trajectory_is_invariant_to_shards_lazy_and_threads() {
    check_session(SessionKind::Sync);
}

#[test]
fn deadline_trajectory_is_invariant_to_shards_lazy_and_threads() {
    check_session(SessionKind::Deadline);
}

#[test]
fn async_trajectory_is_invariant_to_shards_lazy_and_threads() {
    check_session(SessionKind::Async);
}

#[test]
fn config_shard_count_reaches_the_edge_tree() {
    let be = common::native();
    let exp = Experiment::new(scale_cfg(SessionKind::Sync, 7, true, 1), &be).unwrap();
    assert_eq!(exp.fed.n_shards(), 7);
    assert_eq!(exp.fed.shard_occupancy().len(), 7);
    assert!(exp.clients.is_lazy());
}

// ---------------------------------------------------------------------
// Quarantine × lazy state: the spilled EF outlives the gate.

#[test]
fn quarantined_clients_spilled_ef_survives_readmission() {
    // Client 2 is down over [0, 1.2) virtual seconds: the reliability
    // gate quarantines it for 2 rounds and re-admits it. In the lazy
    // run its EF sits in a spill slab the whole time; the trajectory —
    // including its post-re-admission uploads — must be bit-identical
    // to the eager run that kept everything resident.
    let dir = std::env::temp_dir();
    let path = dir.join(format!("fed3sfc_shard_trace_{}.jsonl", std::process::id()));
    std::fs::write(
        &path,
        "# client 2: one outage window over its first upload\n\
         {\"client\": 2, \"down_at\": 0.0, \"up_at\": 1.2}\n",
    )
    .unwrap();
    let mk = |lazy: bool| ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 3,
        rounds: 5,
        k_local: 5,
        lr: 0.05,
        train_samples: 150,
        test_samples: 50,
        eval_every: 5,
        seed: 11,
        session: SessionKind::Deadline,
        deadline_s: 5.0,
        staleness_decay: 0.5,
        faults: true,
        fault_dropout_p: 1.0, // would doom everything — the trace replaces it
        fault_trace: path.to_str().unwrap().to_string(),
        reliability: true,
        quarantine_rounds: 2,
        reliability_alpha: 1.0,
        reliability_threshold: 0.5,
        n_shards: 2,
        lazy_state: lazy,
        ..ExperimentConfig::default()
    };
    let be = common::native();
    let mut lazy = Experiment::new(mk(true), &be).unwrap();
    let a = lazy.run().unwrap();
    let mut eager = Experiment::new(mk(false), &be).unwrap();
    let b = eager.run().unwrap();
    std::fs::remove_file(&path).ok();

    assert_records_bit_identical(&a, &b, "quarantine lazy vs eager");
    assert_eq!(lazy.fed.quarantine_events(), 1);
    assert_eq!(eager.fed.quarantine_events(), 1);
    // The gate really did sideline client 2 and re-admit it.
    assert_eq!(a[1].n_selected, 2, "round 1: client 2 quarantined");
    assert_eq!(a[3].n_selected, 3, "round 3: client 2 re-admitted");
    // Its EF residual — spilled across the quarantine in the lazy run —
    // is bit-identical to the eager twin's.
    assert_eq!(
        ef_bits(&[lazy.clients.ef_of(2)]),
        ef_bits(&[eager.clients.ef_of(2)]),
        "client 2 EF must survive the quarantine bit-exactly"
    );
    assert!(lazy.clients.spill_events() > 0, "lazy run must actually spill");
    assert_eq!(eager.clients.spill_events(), 0);
}

// ---------------------------------------------------------------------
// The allocation contract at a million clients.

#[test]
fn million_client_store_stays_cohort_resident() {
    let n = 1_000_000usize;
    let parts: Vec<Vec<u32>> = (0..n).map(|i| vec![i as u32]).collect();
    let root = Rng::new(99);
    let mut store = ClientStore::new(parts, 16, &root, true, SpillKind::Slab);
    assert_eq!(store.len(), n);
    assert_eq!(store.resident_count(), 0, "construction materializes nobody");
    assert_eq!(store.peak_resident(), 0);
    assert_eq!(store.active_mask().len(), n);

    // A cohort's worth of touches — spread across the whole index
    // range — is all that ever goes dense.
    let cohort: Vec<usize> = (0..64).map(|i| i * (n / 64)).collect();
    for &id in &cohort {
        assert_eq!(store.client(id).n_samples, 1);
    }
    assert_eq!(store.resident_count(), 64);
    assert_eq!(store.peak_resident(), 64);
    for &id in &cohort {
        store.release(id);
    }
    assert_eq!(store.resident_count(), 0);
    assert_eq!(store.spilled_count(), 64);
    // Untouched (all-zero) EF residuals spill for free.
    assert_eq!(store.spilled_bytes(), 0);

    // The edge tier scales with shards + buffered uploads, never with
    // the fleet: route one cohort through 8 shards.
    let mut edge = EdgeAggregator::new(8);
    for (r, &id) in cohort.iter().enumerate() {
        edge.push(Upload {
            client: id,
            round: r,
            sent_at: 0.0,
            payload: Payload::Dense { g: vec![1.0] },
            recon: vec![1.0],
            weight: 1.0,
            efficiency: 1.0,
            ratio: 1.0,
        });
    }
    assert_eq!(edge.len(), 64);
    assert_eq!(edge.occupancy().iter().sum::<usize>(), 64);
    let drained: Vec<usize> = edge.drain_ordered().iter().map(|u| u.client).collect();
    assert_eq!(drained, cohort, "drain order is arrival order");
}
