//! Defense-stack integration tests: byzantine content attacks, robust
//! aggregation, trace-driven fault schedules and the reliability
//! quarantine, end to end through [`Experiment`] on the native backend.
//!
//! The acceptance contract pinned here:
//!
//! * under a sign-flip attack (`byzantine_frac = 0.3`) the trimmed mean
//!   and (Multi-)Krum finish within 10% of the attack-free baseline's
//!   final loss, while the undefended weighted mean measurably diverges;
//! * defense-on trajectories are bit-identical for 1 vs 4 worker
//!   threads in all three session modes;
//! * `weighted_mean` with `[faults]` off is bit-identical to a config
//!   that never mentions the `[defense]` table — the robust seam adds
//!   zero arithmetic to the historical path;
//! * a trace-driven outage quarantines the chronically failing client,
//!   sits it out for `quarantine_rounds`, re-admits it, and its first
//!   post-quarantine upload aggregates normally.

mod common;

use fed3sfc::config::{
    AggregatorKind, CompressorKind, DatasetKind, ExperimentConfig, NetworkKind,
    SessionKind,
};
use fed3sfc::coordinator::{Experiment, RoundRecord};
use fed3sfc::simnet::ByzantineMode;

fn assert_records_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.n_selected, y.n_selected, "round {}", x.round);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {}", x.round);
        assert_eq!(x.test_loss.to_bits(), y.test_loss.to_bits(), "round {}", x.round);
        assert_eq!(x.up_bytes_cum, y.up_bytes_cum, "round {}", x.round);
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "round {}", x.round);
        assert_eq!(x.stale_mean.to_bits(), y.stale_mean.to_bits(), "round {}", x.round);
        assert_eq!(x.rejected_clients, y.rejected_clients, "round {}", x.round);
        assert_eq!(x.trim_frac.to_bits(), y.trim_frac.to_bits(), "round {}", x.round);
    }
}

/// The fig-1-shaped workload scaled to tier-1 size: 3SFC uplink, sync
/// barrier, near-iid partition (`alpha = 100`) so a Krum-selected
/// single contribution tracks the cohort mean.
fn attack_cfg(frac: f64, aggregator: AggregatorKind) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::ThreeSfc,
        n_clients: 6,
        rounds: 12,
        k_local: 5,
        lr: 0.05,
        alpha: 100.0,
        train_samples: 240,
        test_samples: 60,
        eval_every: 1,
        seed: 42,
        faults: true,
        byzantine_frac: frac,
        byzantine_mode: ByzantineMode::SignFlip,
        aggregator,
        trim_beta: 0.34, // floor(0.34·6) = 2 per side — covers the 2 attackers
        krum_f: 2,
        ..ExperimentConfig::default()
    }
}

fn final_loss(cfg: ExperimentConfig) -> f64 {
    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    let recs = exp.run().unwrap();
    let last = recs.last().unwrap();
    assert!(last.test_loss.is_finite(), "loss diverged to non-finite");
    last.test_loss
}

#[test]
fn robust_aggregators_survive_the_sign_flip_attack_the_mean_does_not() {
    let base = final_loss(attack_cfg(0.0, AggregatorKind::WeightedMean));
    let mean = final_loss(attack_cfg(0.3, AggregatorKind::WeightedMean));
    let trimmed = final_loss(attack_cfg(0.3, AggregatorKind::TrimmedMean));
    let krum = final_loss(attack_cfg(0.3, AggregatorKind::Krum));
    // The defenses track the attack-free baseline within 10%.
    assert!(
        trimmed <= base * 1.10,
        "trimmed mean lost the baseline: {trimmed:.4} vs {base:.4}"
    );
    assert!(krum <= base * 1.10, "krum lost the baseline: {krum:.4} vs {base:.4}");
    // The undefended mean measurably diverges: outside the 10% band and
    // strictly worse than both defenses.
    assert!(
        mean > base * 1.10,
        "sign-flip should hurt the plain mean: {mean:.4} vs {base:.4}"
    );
    assert!(mean > trimmed && mean > krum, "defenses must beat the mean under attack");
}

fn defended_cfg(session: SessionKind, threads: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 6,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        train_samples: 240,
        test_samples: 50,
        eval_every: 6,
        seed: 42,
        session,
        threads,
        faults: true,
        byzantine_frac: 0.3,
        byzantine_mode: ByzantineMode::SignFlip,
        aggregator: AggregatorKind::TrimmedMean,
        trim_beta: 0.34,
        reliability: true,
        quarantine_rounds: 2,
        reliability_alpha: 0.5,
        reliability_threshold: 0.7,
        ..ExperimentConfig::default()
    };
    match session {
        // The barrier cannot absorb losses: content attack only.
        SessionKind::Sync => {}
        SessionKind::Deadline => {
            cfg.network = NetworkKind::Custom;
            cfg.net_up_mbps = 0.1;
            cfg.net_down_mbps = 1.0;
            cfg.net_latency_ms = 1.0;
            cfg.net_jitter = 0.5;
            cfg.deadline_s = 0.08;
            cfg.staleness_decay = 0.5;
            cfg.fault_dropout_p = 0.3;
            cfg.fault_recover_s = 0.5;
        }
        SessionKind::Async => {
            cfg.buffer_k = 2;
            cfg.staleness_decay = 0.5;
            cfg.net_jitter = 0.3;
            cfg.fault_dropout_p = 0.25;
            cfg.fault_recover_s = 0.3;
        }
    }
    cfg
}

#[test]
fn defended_trajectories_are_thread_count_independent_in_all_session_modes() {
    for session in [SessionKind::Sync, SessionKind::Deadline, SessionKind::Async] {
        let be = common::native();
        let mut one = Experiment::new(defended_cfg(session, 1), &be).unwrap();
        let a = one.run().unwrap();
        let mut four = Experiment::new(defended_cfg(session, 4), &be).unwrap();
        let b = four.run().unwrap();
        assert_records_bit_identical(&a, &b);
        assert_eq!(
            one.fed.quarantine_events(),
            four.fed.quarantine_events(),
            "{session:?}: quarantine ledger must not see threads"
        );
    }
}

#[test]
fn default_defense_table_is_bit_identical_to_a_config_that_never_mentions_it() {
    // weighted_mean + faults off must reproduce the pre-defense
    // trajectory bit for bit, even with every inert defense knob set.
    let plain = ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::ThreeSfc,
        n_clients: 4,
        rounds: 4,
        k_local: 5,
        lr: 0.05,
        train_samples: 200,
        test_samples: 50,
        eval_every: 4,
        seed: 7,
        net_jitter: 0.4,
        ..ExperimentConfig::default()
    };
    let mut inert = plain.clone();
    inert.byzantine_frac = 0.9; // faults off ⇒ zero compromised clients
    inert.byzantine_mode = ByzantineMode::Collude;
    inert.trim_beta = 0.4;
    inert.krum_f = 3;
    inert.clip_tau = 0.001;
    let be = common::native();
    let a = Experiment::new(plain, &be).unwrap().run().unwrap();
    let b = Experiment::new(inert, &be).unwrap().run().unwrap();
    assert_records_bit_identical(&a, &b);
    assert!(a.iter().all(|r| r.rejected_clients == 0 && r.trim_frac == 0.0));
}

#[test]
fn trace_outage_quarantines_then_readmits_the_failing_client() {
    // Client 2 is down over [0, 1.2) virtual seconds: its round-0 upload
    // dies (trace-driven, draw-free), the reliability gate quarantines
    // it for 2 rounds, and its first post-quarantine upload aggregates
    // normally once the outage window has passed.
    let dir = std::env::temp_dir();
    let path = dir.join(format!("fed3sfc_trace_{}.jsonl", std::process::id()));
    std::fs::write(
        &path,
        "# client 2: one outage window over its first upload\n\
         {\"client\": 2, \"down_at\": 0.0, \"up_at\": 1.2}\n",
    )
    .unwrap();
    let cfg = ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 3,
        rounds: 5,
        k_local: 5,
        lr: 0.05,
        train_samples: 150,
        test_samples: 50,
        eval_every: 5,
        seed: 11,
        session: SessionKind::Deadline,
        deadline_s: 5.0,
        staleness_decay: 0.5,
        faults: true,
        fault_dropout_p: 1.0, // would doom everything — the trace replaces it
        fault_trace: path.to_str().unwrap().to_string(),
        reliability: true,
        quarantine_rounds: 2,
        reliability_alpha: 1.0,
        reliability_threshold: 0.5,
        ..ExperimentConfig::default()
    };
    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    let recs = exp.run().unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(recs.len(), 5);
    // Round 0: the outage kills client 2's upload mid-transfer.
    assert_eq!(recs[0].n_selected, 2, "round 0 must lose client 2");
    assert_eq!(exp.fed.lost_uploads(), 1, "the trace dooms exactly one upload");
    // Rounds 1–2: quarantined (EWMA 1.0 > 0.5), not even dispatched.
    assert_eq!(recs[1].n_selected, 2, "round 1: client 2 quarantined");
    assert_eq!(recs[2].n_selected, 2, "round 2: client 2 quarantined");
    // Round 3+: re-admitted; the window is long gone, the upload lands
    // and aggregates like any other.
    assert_eq!(recs[3].n_selected, 3, "round 3: client 2 re-admitted");
    assert_eq!(recs[4].n_selected, 3, "round 4: client 2 stays");
    assert_eq!(exp.fed.quarantine_events(), 1);
    assert!(exp.fed.quarantined_now().is_empty(), "quarantine must have expired");
    assert!(recs.iter().all(|r| r.test_loss.is_finite()));
}
