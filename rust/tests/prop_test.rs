//! Property tests (hand-rolled harness, see `testing::prop`) over the
//! coordinator's pure invariants — no PJRT needed, so these are fast and
//! run hundreds of cases.

use fed3sfc::compress::payload::{get_bit, pack_bits};
use fed3sfc::compress::Payload;
use fed3sfc::config::DatasetKind;
use fed3sfc::data::{dirichlet_partition, ClientSampler, Dataset};
use fed3sfc::testing::prop::{assert_close, check};
use fed3sfc::util::rng::Rng;
use fed3sfc::util::vecmath;

#[test]
fn prop_topk_reconstruction_is_best_k_term_approx() {
    check("topk-optimal", 120, |c| {
        let n = 4 + c.len(400);
        let v = c.vec_f32(n, 2.0);
        let k = 1 + c.rng.below(n);
        let idx = vecmath::topk_indices(&v, k);
        if idx.len() != k.min(n) {
            return Err(format!("got {} indices, want {}", idx.len(), k));
        }
        // Any coordinate kept must dominate any dropped coordinate.
        let kept: Vec<f32> = idx.iter().map(|&i| v[i as usize].abs()).collect();
        let min_kept = kept.iter().cloned().fold(f32::INFINITY, f32::min);
        for (i, x) in v.iter().enumerate() {
            if !idx.contains(&(i as u32)) && x.abs() > min_kept + 1e-6 {
                return Err(format!("dropped {} > kept {}", x.abs(), min_kept));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kth_magnitude_matches_sort() {
    check("kth-magnitude", 120, |c| {
        let n = 1 + c.len(200);
        let v = c.vec_f32(n, 3.0);
        let k = 1 + c.rng.below(n);
        let got = vecmath::kth_magnitude(&v, k);
        let mut mags: Vec<f32> = v.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let want = mags[k - 1];
        if (got - want).abs() > 1e-6 {
            return Err(format!("{got} vs {want}"));
        }
        Ok(())
    });
}

#[test]
fn prop_bitset_roundtrip() {
    check("bitset", 100, |c| {
        let n = c.len(300);
        let signs: Vec<bool> = (0..n).map(|_| c.rng.f64() < 0.5).collect();
        let bits = pack_bits(signs.iter().copied(), n);
        for (i, &s) in signs.iter().enumerate() {
            if get_bit(&bits, i) != s {
                return Err(format!("bit {i}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dot_is_bilinear() {
    check("dot-bilinear", 80, |c| {
        let n = c.len(256);
        let a = c.vec_f32(n, 1.0);
        let b = c.vec_f32(n, 1.0);
        let d = c.vec_f32(n, 1.0);
        let lhs = vecmath::dot(&a, &vecmath::sub(&b, &d));
        let rhs = vecmath::dot(&a, &b) - vecmath::dot(&a, &d);
        if (lhs - rhs).abs() > 1e-3 {
            return Err(format!("{lhs} vs {rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_axpy_linearity() {
    check("axpy-linear", 80, |c| {
        let n = c.len(256);
        let x = c.vec_f32(n, 1.0);
        let y = c.vec_f32(n, 1.0);
        let alpha = (c.rng.f32() - 0.5) * 4.0;
        let mut got = y.clone();
        vecmath::axpy(alpha, &x, &mut got);
        let want: Vec<f32> = x.iter().zip(y.iter()).map(|(a, b)| b + alpha * a).collect();
        assert_close(&got, &want, 1e-6)
    });
}

#[test]
fn prop_partition_is_exact_cover() {
    check("partition-cover", 25, |c| {
        let n = 50 + c.len(300);
        let clients = 2 + c.rng.below(12);
        let alpha = 0.1 + c.rng.f64() * 5.0;
        let ds = Dataset::generate(DatasetKind::SynthSmall, n, c.seed);
        let mut rng = Rng::new(c.seed ^ 1);
        let parts = dirichlet_partition(&ds, clients, alpha, &mut rng);
        let mut seen = vec![0u8; n];
        for p in &parts {
            if p.is_empty() {
                return Err("empty client".into());
            }
            for &i in p {
                seen[i as usize] += 1;
            }
        }
        if seen.iter().any(|&s| s != 1) {
            return Err("not an exact cover".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sampler_epoch_is_permutation() {
    check("sampler-epoch", 40, |c| {
        let n = 4 + c.len(60);
        let ds = Dataset::generate(DatasetKind::SynthSmall, n, c.seed);
        let mut s = ClientSampler::new((0..n as u32).collect(), Rng::new(c.seed));
        let (_, ys) = s.sample_batches(&ds, 1, n);
        let mut got: Vec<i32> = ys;
        let mut want: Vec<i32> = (0..n).map(|i| ds.label(i)).collect();
        got.sort_unstable();
        want.sort_unstable();
        if got != want {
            return Err("epoch not a permutation".into());
        }
        Ok(())
    });
}

#[test]
fn prop_payload_rate_consistent_with_bytes() {
    check("payload-rate", 60, |c| {
        let n = 10 + c.len(10_000);
        let k = 1 + c.rng.below(n.min(500));
        let payloads = vec![
            Payload::Dense { g: vec![0.0; n] },
            Payload::TopK { n, idx: vec![0; k], val: vec![0.0; k] },
            Payload::Sign { n, bits: vec![0; n.div_ceil(8)], scale: 1.0 },
            Payload::Ternary { n, idx: vec![0; k], neg: vec![0; k.div_ceil(8)], mu: 1.0 },
        ];
        for p in payloads {
            let r = p.rate(n);
            let want = p.wire_bytes() as f64 / (4.0 * n as f64);
            if (r - want).abs() > 1e-12 {
                return Err(format!("{r} vs {want}"));
            }
            if (p.ratio(n) * r - 1.0).abs() > 1e-9 {
                return Err("ratio != 1/rate".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_aggregation_is_convex() {
    // Server output must lie in the convex hull of client reconstructions
    // (coordinate-wise, since weights are a convex combination).
    check("agg-convex", 60, |c| {
        let n = c.len(64);
        let m = 2 + c.rng.below(6);
        let recons: Vec<Vec<f32>> = (0..m).map(|_| c.vec_f32(n, 2.0)).collect();
        let weights: Vec<f32> = (0..m).map(|_| 0.01 + c.rng.f32()).collect();
        let mut server = fed3sfc::coordinator::Server::new(vec![0.0; n]);
        server.apply_round(&recons, &weights);
        for j in 0..n {
            let lo = recons.iter().map(|r| r[j]).fold(f32::INFINITY, f32::min);
            let hi = recons.iter().map(|r| r[j]).fold(f32::NEG_INFINITY, f32::max);
            let got = -server.w[j]; // w started at 0, step = -agg
            if got < lo - 1e-4 || got > hi + 1e-4 {
                return Err(format!("coord {j}: {got} outside [{lo}, {hi}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dataset_split_shares_task() {
    // Splits of the same seed must have the same class structure: a
    // template-matched nearest-class classifier trained on split 0
    // transfers to split 1 far above chance.
    check("split-task", 8, |c| {
        let kind = DatasetKind::SynthSmall;
        let train = Dataset::generate_split(kind, 160, c.seed, 0);
        let test = Dataset::generate_split(kind, 80, c.seed, 1);
        // class means from train
        let d = train.d;
        let mut means = vec![vec![0.0f32; d]; train.n_classes];
        let mut counts = vec![0usize; train.n_classes];
        for i in 0..train.n {
            let cls = train.label(i) as usize;
            for (m, v) in means[cls].iter_mut().zip(train.sample(i)) {
                *m += v;
            }
            counts[cls] += 1;
        }
        for (m, &cnt) in means.iter_mut().zip(&counts) {
            if cnt > 0 {
                for v in m.iter_mut() {
                    *v /= cnt as f32;
                }
            }
        }
        let mut correct = 0;
        for i in 0..test.n {
            let best = (0..test.n_classes)
                .max_by(|&a, &b| {
                    vecmath::cosine(test.sample(i), &means[a])
                        .partial_cmp(&vecmath::cosine(test.sample(i), &means[b]))
                        .unwrap()
                })
                .unwrap();
            if best as i32 == test.label(i) {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.n as f64;
        if acc < 0.5 {
            return Err(format!("cross-split transfer acc {acc} < 0.5"));
        }
        Ok(())
    });
}
