//! Compressor zoo integration: encode/decode agreement, byte budgets,
//! error-feedback telescoping, and the paper's budget-matching protocol.
//!
//! Entirely backend-generic math, so the whole file runs on the native
//! backend — no artifacts required.

mod common;

use fed3sfc::compress::{
    Compressor, DecodeCtx, EncodeCtx, FedSynth, Identity, Payload, SignSgd, Stc, ThreeSfc, TopK,
};
use fed3sfc::runtime::{Backend, FedOps};
use fed3sfc::util::rng::Rng;
use fed3sfc::util::vecmath;

fn target_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_normal(&mut v, 0.01);
    // make it heavy-tailed like real gradients
    for (i, x) in v.iter_mut().enumerate() {
        if i % 97 == 0 {
            *x *= 20.0;
        }
    }
    v
}

/// encode() must return exactly what decode() reconstructs — the
/// client-side EF update and the server-side aggregation must agree.
fn assert_encode_decode_agree(comp: &dyn Compressor) {
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let w = be.load_init(ops.model).unwrap();
    let target = target_vec(ops.model.params, 5);
    let mut rng = Rng::new(11);
    let mut ctx = EncodeCtx { ops: &ops, w_global: &w, rng: &mut rng };
    let (payload, recon, _stats) = comp.encode(&mut ctx, &target).unwrap();
    let dctx = DecodeCtx { ops: &ops, w_global: &w };
    let decoded = comp.decode(&dctx, &payload).unwrap();
    assert_eq!(recon.len(), target.len());
    for (a, b) in recon.iter().zip(decoded.iter()) {
        assert!((a - b).abs() <= 1e-6 * (1.0 + a.abs()), "{a} vs {b}");
    }
    // The wire accounting is backed by a real serializer.
    assert_eq!(payload.serialize().len(), payload.wire_bytes());
}

#[test]
fn identity_roundtrip() {
    assert_encode_decode_agree(&Identity::new());
}

#[test]
fn topk_roundtrip() {
    assert_encode_decode_agree(&TopK::new(37));
}

#[test]
fn signsgd_roundtrip() {
    assert_encode_decode_agree(&SignSgd::new());
}

#[test]
fn stc_roundtrip() {
    assert_encode_decode_agree(&Stc::new(53));
}

#[test]
fn threesfc_roundtrip() {
    assert_encode_decode_agree(&ThreeSfc::new(1, 5, 5.0, 0.0));
}

#[test]
fn fedsynth_roundtrip() {
    assert_encode_decode_agree(&FedSynth::new(2, 1, 3, 0.05, 0.5));
}

#[test]
fn byte_budgets_match_paper_protocol() {
    let be = common::native();
    let model = be.manifest().model("mlp10").unwrap();
    let n = model.params;

    // 3SFC m=1 on the paper MLP: (784+10+1+... )·4 bytes ≈ 250× ratio.
    let syn = Payload::Syn {
        m: 1,
        dx: vec![0.0; 784],
        dy: vec![0.0; 10],
        s: 1.0,
    };
    let ratio = syn.ratio(n);
    assert!(
        (200.0..300.0).contains(&ratio),
        "paper reports 250x for MLP, got {ratio:.1}x"
    );

    // signSGD is pinned at ~32×.
    let sign = Payload::Sign { n, bits: vec![0; n.div_ceil(8)], scale: 1.0 };
    let r = sign.ratio(n);
    assert!((30.0..33.0).contains(&r), "{r}");

    // STC::with_rate(1/32) should land within 5% of 32×.
    let stc = Stc::with_rate(n, 1.0 / 32.0);
    let k = stc.k();
    let tern = Payload::Ternary {
        n,
        idx: vec![0; k],
        neg: vec![0; k.div_ceil(8)],
        mu: 1.0,
    };
    let r = tern.ratio(n);
    assert!((30.0..34.0).contains(&r), "{r}");
}

#[test]
fn topk_respects_budget_and_picks_largest() {
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let w = be.load_init(ops.model).unwrap();
    let target = target_vec(ops.model.params, 6);
    let mut rng = Rng::new(12);
    let comp = TopK::new(10);
    let mut ctx = EncodeCtx { ops: &ops, w_global: &w, rng: &mut rng };
    let (payload, recon, _stats) = comp.encode(&mut ctx, &target).unwrap();
    let Payload::TopK { idx, val, .. } = &payload else { panic!() };
    assert_eq!(idx.len(), 10);
    assert_eq!(val.len(), 10);
    let kept_min = val.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
    let dropped_max = target
        .iter()
        .enumerate()
        .filter(|(i, _)| !idx.contains(&(*i as u32)))
        .map(|(_, v)| v.abs())
        .fold(0.0f32, f32::max);
    assert!(kept_min >= dropped_max);
    // reconstruction error is exactly the dropped mass
    let err = vecmath::sub(&target, &recon);
    let e2 = vecmath::norm2(&err);
    let t2 = vecmath::norm2(&target);
    let kept2: f64 = val.iter().map(|&v| (v as f64) * (v as f64)).sum();
    assert!((e2 - (t2 - kept2)).abs() < 1e-6 * t2);
}

#[test]
fn error_feedback_telescopes() {
    // Σ_t recon_t + e_T = Σ_t target-contributions + e_0: nothing is lost,
    // only delayed — the EF invariant that makes compression unbiased in
    // the limit.
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let w = be.load_init(ops.model).unwrap();
    let n = ops.model.params;
    let comp = TopK::new(20);
    let mut rng = Rng::new(13);

    let mut ef = vec![0.0f32; n];
    let mut sum_g = vec![0.0f32; n];
    let mut sum_recon = vec![0.0f32; n];
    for t in 0..5 {
        let g = target_vec(n, 100 + t);
        vecmath::add_assign(&mut sum_g, &g);
        let mut target = g.clone();
        vecmath::add_assign(&mut target, &ef);
        let mut ctx = EncodeCtx { ops: &ops, w_global: &w, rng: &mut rng };
        let (_, recon, _stats) = comp.encode(&mut ctx, &target).unwrap();
        ef = vecmath::sub(&target, &recon);
        vecmath::add_assign(&mut sum_recon, &recon);
    }
    // sum_recon + ef == sum_g  (telescoping)
    let mut lhs = sum_recon.clone();
    vecmath::add_assign(&mut lhs, &ef);
    for (a, b) in lhs.iter().zip(sum_g.iter()) {
        assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
    }
}

#[test]
fn threesfc_scale_is_l2_optimal() {
    let g_syn = vec![1.0f32, 2.0, -1.0, 0.5];
    let target = vec![2.0f32, 3.9, -2.1, 1.2];
    let s = ThreeSfc::optimal_scale(&target, &g_syn);
    let err = |sc: f32| -> f64 {
        g_syn
            .iter()
            .zip(target.iter())
            .map(|(g, t)| ((sc * g - t) as f64).powi(2))
            .sum()
    };
    let e_star = err(s);
    for ds in [-0.05f32, 0.05, -0.2, 0.2] {
        assert!(e_star <= err(s + ds) + 1e-9);
    }
    // degenerate gradient → zero scale, no NaN
    assert_eq!(ThreeSfc::optimal_scale(&target, &[0.0; 4]), 0.0);
}

#[test]
fn threesfc_reconstruction_correlates_with_target() {
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let w = be.load_init(ops.model).unwrap();
    // realistic target: an actual local-training delta
    let mut rng = Rng::new(21);
    let mut x = vec![0.0f32; 5 * ops.model.train_batch * ops.model.feature_len()];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..5 * ops.model.train_batch)
        .map(|i| (i % ops.model.n_classes) as i32)
        .collect();
    let w_local = ops.local_train(5, &w, &x, &y, 0.05).unwrap();
    let target = vecmath::sub(&w, &w_local);

    let comp = ThreeSfc::new(1, 25, 5.0, 0.0);
    let mut ctx = EncodeCtx { ops: &ops, w_global: &w, rng: &mut rng };
    let (payload, recon, stats) = comp.encode(&mut ctx, &target).unwrap();
    let cos = vecmath::cosine(&recon, &target);
    assert!(cos > 0.2, "3SFC reconstruction cosine too low: {cos}");
    assert!(stats.cos > 0.2);
    // scale must be applied: recon ≈ s * syn_grad
    let Payload::Syn { s, .. } = payload else { panic!() };
    assert!(s.is_finite() && s != 0.0);
}
