//! Differential testing of the two compute backends: the pure-Rust
//! native implementation and the PJRT artifact path must agree — same
//! init, same data, same config ⇒ trajectories within float tolerance.
//!
//! This is the only cross-checking the XLA kernel stack gets (the python
//! oracle verifies the lowering once at build time; nothing else
//! re-derives the numbers), and conversely it anchors the native backend
//! to the kernels the paper's figures were produced with.
//!
//! Skips (never fails) when no artifact bundle is present.

mod common;

use fed3sfc::config::{CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::{Experiment, ExperimentBuilder};
use fed3sfc::runtime::Backend;
use fed3sfc::util::vecmath;
use fed3sfc::RoundRecord;

/// Relative agreement for scalar observables after 3 rounds. The two
/// implementations accumulate f32 rounding differently (Pallas tiled
/// matmuls vs naive loops), so this is a tolerance, not bit-equality.
const REL_TOL: f64 = 1e-4;

fn rel_close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

fn builder(method: CompressorKind) -> ExperimentBuilder {
    Experiment::builder()
        .dataset(DatasetKind::SynthSmall)
        .compressor(method)
        .clients(4)
        .rounds(3)
        .lr(0.05)
        // 8 steps: no fused syn_opt artifact exists for S=8, so *both*
        // backends run the host-side Adam loop over syn_step — the
        // comparison isolates the op numerics, not encoder structure.
        .syn_steps(8)
        .train_samples(240)
        .test_samples(80)
        .eval_every(1)
        .seed(42)
        .threads(1)
}

/// Run one config on both backends from identical initial weights.
fn run_both(
    method: CompressorKind,
    pjrt: &dyn Backend,
) -> (Vec<RoundRecord>, Vec<RoundRecord>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let native = common::native();
    // One shared init: the artifact bundle's packed weights (numpy He
    // init), pinned on both sides through the builder.
    let model = pjrt.manifest().model("mlp_small").unwrap();
    let w0 = pjrt.load_init(model).unwrap();

    let mut exp_n = builder(method)
        .initial_weights(w0.clone())
        .build(&native)
        .unwrap();
    let recs_n = exp_n.run().unwrap();
    let efs_n: Vec<Vec<f32>> = exp_n.clients.ef_snapshots();

    let mut exp_p = builder(method).initial_weights(w0).build(pjrt).unwrap();
    let recs_p = exp_p.run().unwrap();
    let efs_p: Vec<Vec<f32>> = exp_p.clients.ef_snapshots();
    (recs_n, recs_p, efs_n, efs_p)
}

fn assert_trajectories_agree(method: CompressorKind, pjrt: &dyn Backend) {
    let (recs_n, recs_p, efs_n, efs_p) = run_both(method, pjrt);
    assert_eq!(recs_n.len(), recs_p.len());
    for (rn, rp) in recs_n.iter().zip(recs_p.iter()) {
        assert!(
            rel_close(rn.test_loss, rp.test_loss, REL_TOL),
            "{method:?} round {}: loss native {} vs pjrt {}",
            rn.round,
            rn.test_loss,
            rp.test_loss
        );
        assert!(
            rel_close(rn.test_acc, rp.test_acc, 0.02),
            "{method:?} round {}: acc native {} vs pjrt {}",
            rn.round,
            rn.test_acc,
            rp.test_acc
        );
        // Byte accounting is pure host arithmetic: must agree exactly.
        assert_eq!(rn.up_bytes_round, rp.up_bytes_round, "{method:?} bytes");
        assert_eq!(rn.n_selected, rp.n_selected);
        assert_eq!(rn.comm_time_s.to_bits(), rp.comm_time_s.to_bits());
    }
    // Error-feedback state: same direction and magnitude per client.
    for (ci, (en, ep)) in efs_n.iter().zip(efs_p.iter()).enumerate() {
        let nn = vecmath::norm(en);
        let np = vecmath::norm(ep);
        if nn < 1e-9 && np < 1e-9 {
            continue; // FedAvg: no residual on either side
        }
        let cos = vecmath::cosine(en, ep);
        assert!(cos > 0.99, "{method:?} client {ci}: EF cos {cos}");
        assert!(
            rel_close(nn, np, 0.02),
            "{method:?} client {ci}: EF norm native {nn} vs pjrt {np}"
        );
    }
}

#[test]
fn fedavg_backends_agree() {
    let _g = common::lock();
    let Some(pjrt) = common::pjrt() else { return };
    assert_trajectories_agree(CompressorKind::FedAvg, pjrt.as_ref());
}

#[test]
fn topk_backends_agree() {
    let _g = common::lock();
    let Some(pjrt) = common::pjrt() else { return };
    assert_trajectories_agree(CompressorKind::Dgc, pjrt.as_ref());
}

#[test]
fn threesfc_backends_agree() {
    let _g = common::lock();
    let Some(pjrt) = common::pjrt() else { return };
    assert_trajectories_agree(CompressorKind::ThreeSfc, pjrt.as_ref());
}

#[test]
fn fedop_level_parity_on_one_batch() {
    // Below the round loop: raw op outputs on identical inputs.
    let _g = common::lock();
    let Some(pjrt) = common::pjrt() else { return };
    let native = common::native();
    let pmodel = pjrt.manifest().model("mlp_small").unwrap();
    let nmodel = native.manifest().model("mlp_small").unwrap();
    let w = pjrt.load_init(pmodel).unwrap();

    let mut rng = fed3sfc::util::rng::Rng::new(5);
    let b = pmodel.train_batch;
    let d = pmodel.feature_len();
    let mut x = vec![0.0f32; b * d];
    rng.fill_normal(&mut x, 1.0);
    let y: Vec<i32> = (0..b).map(|i| (i % pmodel.n_classes) as i32).collect();

    // Gradient parity.
    let gp = pjrt.grad_batch(pmodel, &w, &x, &y).unwrap();
    let gn = native.grad_batch(nmodel, &w, &x, &y).unwrap();
    let cos = vecmath::cosine(&gp, &gn);
    assert!(cos > 0.999999, "grad cos {cos}");
    assert!(rel_close(vecmath::norm(&gp), vecmath::norm(&gn), 1e-4));

    // Local-train parity (K = 5).
    let xs: Vec<f32> = x.iter().cloned().cycle().take(5 * x.len()).collect();
    let ys: Vec<i32> = y.iter().cloned().cycle().take(5 * y.len()).collect();
    let wp = pjrt.local_train(pmodel, 5, &w, &xs, &ys, 0.05).unwrap();
    let wn = native.local_train(nmodel, 5, &w, &xs, &ys, 0.05).unwrap();
    let dp = vecmath::sub(&w, &wp);
    let dn = vecmath::sub(&w, &wn);
    let cos = vecmath::cosine(&dp, &dn);
    assert!(cos > 0.9999, "train delta cos {cos}");

    // Eval parity (eval has its own batch size).
    let be_sz = pmodel.eval_batch;
    let mut xe = vec![0.0f32; be_sz * d];
    let mut r2 = fed3sfc::util::rng::Rng::new(6);
    r2.fill_normal(&mut xe, 1.0);
    let ye: Vec<i32> = (0..be_sz).map(|i| (i % pmodel.n_classes) as i32).collect();
    let (loss_p, correct_p) = pjrt.eval_batch(pmodel, &w, &xe, &ye).unwrap();
    let (loss_n, correct_n) = native.eval_batch(nmodel, &w, &xe, &ye).unwrap();
    assert!(
        rel_close(loss_p as f64, loss_n as f64, 1e-4),
        "eval loss {loss_p} vs {loss_n}"
    );
    assert_eq!(correct_p, correct_n, "eval #correct");

    // 3SFC decoder parity on a fixed synthetic sample.
    let mut dx = vec![0.0f32; d];
    rng.fill_normal(&mut dx, 0.5);
    let dy = vec![0.0f32; pmodel.n_classes];
    let sp = pjrt.syn_grad(pmodel, 1, &w, &dx, &dy).unwrap();
    let sn = native.syn_grad(nmodel, 1, &w, &dx, &dy).unwrap();
    let cos = vecmath::cosine(&sp, &sn);
    assert!(cos > 0.9999, "syn_grad cos {cos}");
}
