//! Property tests (testing::prop harness) for compressor invariants,
//! running the real encoders on the native backend — so the whole suite
//! is artifact-free and covers the full zoo:
//!
//! * decode(encode(g)) equals the encoder-reported reconstruction, and
//!   identity's EF residual is exactly zero (lossless);
//! * `wire_bytes` equals the length of an actual serialization, and the
//!   serialize→deserialize→decode pipeline reproduces the reconstruction;
//! * top-k and STC selection commutes with coordinate permutations
//!   (for tie-free magnitudes);
//! * 3SFC's encoder never keeps an iterate with a worse similarity
//!   objective than its initialization (the best-|cos| contract).

mod common;

use fed3sfc::compress::{
    Compressor, DecodeCtx, DeltaPayload, EncodeCtx, FedSynth, Identity, Payload, SignSgd, Stc,
    ThreeSfc, TopK,
};
use std::sync::Arc;
use fed3sfc::runtime::{Backend, FedOps, NativeBackend};
use fed3sfc::testing::prop::{assert_close, check, Case};
use fed3sfc::util::rng::Rng;
use fed3sfc::util::vecmath;

/// All five baseline compressors at sizes fitting mlp_small's P.
fn zoo(n: usize) -> Vec<Box<dyn Compressor>> {
    vec![
        Box::new(Identity::new()),
        Box::new(TopK::new((n / 20).max(1))),
        Box::new(SignSgd::new()),
        Box::new(Stc::new((n / 30).max(1))),
        Box::new(ThreeSfc::new(1, 4, 5.0, 0.0)),
        Box::new(FedSynth::new(2, 1, 2, 0.05, 0.5)),
    ]
}

fn encode_with(
    backend: &NativeBackend,
    comp: &dyn Compressor,
    target: &[f32],
    seed: u64,
) -> (Payload, Vec<f32>) {
    let ops = FedOps::new(backend, "mlp_small").unwrap();
    let w = backend.load_init(ops.model).unwrap();
    let mut rng = Rng::new(seed);
    let mut ctx = EncodeCtx { ops: &ops, w_global: &w, rng: &mut rng };
    let (payload, recon, _stats) = comp.encode(&mut ctx, target).unwrap();
    (payload, recon)
}

fn heavy_tailed_target(case: &mut Case, n: usize) -> Vec<f32> {
    let mut v = case.vec_f32(n, 0.01);
    for (i, x) in v.iter_mut().enumerate() {
        if i % 37 == 0 {
            *x *= 15.0;
        }
    }
    v
}

#[test]
fn prop_decode_matches_recon_and_identity_is_lossless() {
    let backend = common::native();
    let n = backend.manifest().model("mlp_small").unwrap().params;
    check("decode-matches-recon", 6, |c| {
        let target = heavy_tailed_target(c, n);
        for comp in zoo(n) {
            let (payload, recon) = encode_with(&backend, comp.as_ref(), &target, c.seed);
            let ops = FedOps::new(&backend, "mlp_small").unwrap();
            let w = backend.load_init(ops.model).unwrap();
            let dctx = DecodeCtx { ops: &ops, w_global: &w };
            let decoded = comp.decode(&dctx, &payload).unwrap();
            assert_close(&recon, &decoded, 1e-6)
                .map_err(|e| format!("{}: {e}", payload.kind()))?;
            // Identity: the EF residual target − recon is exactly zero.
            if payload.kind() == "dense" {
                for (i, (t, r)) in target.iter().zip(recon.iter()).enumerate() {
                    if t.to_bits() != r.to_bits() {
                        return Err(format!("identity lost coord {i}: {t} vs {r}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_bytes_is_a_real_serialized_length() {
    let backend = common::native();
    let model = backend.manifest().model("mlp_small").unwrap().clone();
    let n = model.params;
    check("wire-bytes-honest", 6, |c| {
        let target = heavy_tailed_target(c, n);
        for comp in zoo(n) {
            let (payload, recon) = encode_with(&backend, comp.as_ref(), &target, c.seed);
            let bytes = payload.serialize();
            if bytes.len() != payload.wire_bytes() {
                return Err(format!(
                    "{}: serialized {} B but wire_bytes charges {} B",
                    payload.kind(),
                    bytes.len(),
                    payload.wire_bytes()
                ));
            }
            // And the wire roundtrip decodes to the same reconstruction.
            let back = Payload::deserialize(
                payload.kind(),
                &bytes,
                n,
                model.feature_len(),
                model.n_classes,
            )
            .map_err(|e| format!("{}: {e}", payload.kind()))?;
            let ops = FedOps::new(&backend, "mlp_small").unwrap();
            let w = backend.load_init(ops.model).unwrap();
            let dctx = DecodeCtx { ops: &ops, w_global: &w };
            let decoded = comp.decode(&dctx, &back).unwrap();
            assert_close(&recon, &decoded, 1e-6)
                .map_err(|e| format!("{} wire roundtrip: {e}", payload.kind()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_downlink_delta_payloads_are_wire_honest_over_the_zoo() {
    // The downlink envelope (compress::downlink) must keep the uplink's
    // wire-honesty contract for every inner payload the zoo can produce,
    // plus the keyframe variant: `serialize().len() == wire_bytes()`, the
    // byte roundtrip reproduces kind + base version, and decoding the
    // roundtripped inner payload reproduces the encoder's reconstruction.
    let backend = common::native();
    let model = backend.manifest().model("mlp_small").unwrap().clone();
    let n = model.params;
    check("downlink-delta-wire-honest", 6, |c| {
        let target = heavy_tailed_target(c, n);
        let base = c.rng.below(1000) as u32;
        for comp in zoo(n) {
            let (inner, recon) = encode_with(&backend, comp.as_ref(), &target, c.seed);
            let dp = DeltaPayload::Delta { base, inner };
            let bytes = dp.serialize();
            if bytes.len() != dp.wire_bytes() {
                return Err(format!(
                    "{}: serialized {} B but wire_bytes charges {} B",
                    dp.kind(),
                    bytes.len(),
                    dp.wire_bytes()
                ));
            }
            let back = DeltaPayload::deserialize(
                &dp.kind(),
                &bytes,
                n,
                model.feature_len(),
                model.n_classes,
            )
            .map_err(|e| format!("{}: {e}", dp.kind()))?;
            if back.base_version() != Some(base as usize) {
                return Err(format!(
                    "{}: base {:?} after roundtrip, wanted {base}",
                    dp.kind(),
                    back.base_version()
                ));
            }
            let DeltaPayload::Delta { inner: inner_back, .. } = back else {
                return Err(format!("{}: roundtripped to a keyframe", dp.kind()));
            };
            let ops = FedOps::new(&backend, "mlp_small").unwrap();
            let w = backend.load_init(ops.model).unwrap();
            let dctx = DecodeCtx { ops: &ops, w_global: &w };
            let decoded = comp.decode(&dctx, &inner_back).unwrap();
            assert_close(&recon, &decoded, 1e-6)
                .map_err(|e| format!("{} wire roundtrip: {e}", dp.kind()))?;
        }
        // Keyframe variant: dense pricing (4 + 4P) and a bit-exact
        // roundtrip of the weights themselves.
        let kf = DeltaPayload::Keyframe { w: Arc::new(target.clone()) };
        let bytes = kf.serialize();
        if bytes.len() != kf.wire_bytes() || kf.wire_bytes() != 4 + 4 * n {
            return Err(format!(
                "keyframe: serialized {} B, wire_bytes {} B, dense charge {} B",
                bytes.len(),
                kf.wire_bytes(),
                4 + 4 * n
            ));
        }
        let back = DeltaPayload::deserialize(
            &kf.kind(),
            &bytes,
            n,
            model.feature_len(),
            model.n_classes,
        )
        .map_err(|e| format!("keyframe: {e}"))?;
        if back.base_version().is_some() {
            return Err("keyframe: roundtrip grew a base version".into());
        }
        let DeltaPayload::Keyframe { w } = back else {
            return Err("keyframe: roundtripped to a delta".into());
        };
        for (i, (a, b)) in target.iter().zip(w.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("keyframe lost coord {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topk_and_stc_selection_is_permutation_stable() {
    // encode(π(t)) must equal π(encode(t)) coordinate-wise when all
    // magnitudes are distinct (with ties the selected set is ambiguous by
    // construction, so the harness generates tie-free vectors).
    let backend = common::native();
    check("selection-permutation-stable", 40, |c| {
        let n = 8 + c.len(300);
        let k = 1 + c.rng.below(n);
        let target = c.vec_f32_distinct(n, 0.05);
        let perm = c.permutation(n);
        let mut permuted = vec![0.0f32; n];
        for (src, &dst) in perm.iter().enumerate() {
            permuted[dst] = target[src];
        }
        let comps: Vec<Box<dyn Compressor>> =
            vec![Box::new(TopK::new(k)), Box::new(Stc::new(k))];
        for comp in comps {
            let (_, recon) = encode_with(&backend, comp.as_ref(), &target, c.seed);
            let (_, recon_p) = encode_with(&backend, comp.as_ref(), &permuted, c.seed);
            for (src, &dst) in perm.iter().enumerate() {
                let (a, b) = (recon[src], recon_p[dst]);
                // The selected *set* must map exactly through π…
                if (a == 0.0) != (b == 0.0) {
                    return Err(format!(
                        "selection not permutation-stable at {src}→{dst} (k={k}, n={n})"
                    ));
                }
                // …and the kept values agree (STC's μ is a float sum, so
                // its summation order legitimately shifts the last ulp).
                if (a - b).abs() > 1e-6 * (1.0 + a.abs()) {
                    return Err(format!(
                        "coord {src}→{dst}: {a} vs {b} (k={k}, n={n})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_threesfc_never_keeps_a_worse_iterate_than_init() {
    // The encoder tracks the best-|cos| iterate and scores the final one
    // too, so the kept |cos| — i.e. the similarity objective 1 − |cos| —
    // can only improve on the initialization (Eq. 9 at λ = 0).
    let backend = common::native();
    let ops = FedOps::new(&backend, "mlp_small").unwrap();
    let model = ops.model;
    let w = backend.load_init(model).unwrap();
    let (d, cls, n) = (model.feature_len(), model.n_classes, model.params);
    check("threesfc-keeps-best", 12, |c| {
        let target = heavy_tailed_target(c, n);
        let comp = ThreeSfc::new(1, 4, 5.0, 0.0);
        // Replicate the encoder's init draw from a clone of the stream it
        // will consume, to score the starting iterate independently.
        let mut rng = Rng::new(c.seed ^ 0xA5);
        let mut init_rng = rng.clone();
        let mut dx0 = vec![0.0f32; d];
        init_rng.fill_normal(&mut dx0, comp.init_scale);
        let dy0 = vec![0.0f32; cls];
        let g0 = ops.syn_grad(1, &w, &dx0, &dy0).unwrap();
        let cos0 = vecmath::cosine(&g0, &target).abs();

        let mut ctx = EncodeCtx { ops: &ops, w_global: &w, rng: &mut rng };
        let (_, _, stats) = comp.encode(&mut ctx, &target).unwrap();
        if (stats.cos as f64) < cos0 - 1e-3 {
            return Err(format!(
                "kept |cos| {} worse than init {cos0}",
                stats.cos
            ));
        }
        Ok(())
    });
}
