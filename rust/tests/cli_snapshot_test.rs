//! Snapshot tests for the CLI surface: every `bench` scenario and
//! `report` rendering is pinned byte-for-byte against a committed golden
//! in `tests/snapshots/` (see `tests/common/snapshot.rs` for the
//! record/review workflow).
//!
//! These are the acceptance gate for the adversarial scenario pack: the
//! byzantine-envelope rejection table plus the attack × aggregator
//! defense matrix, the faults-vs-policies matrix, the tier fate table,
//! the `[faults]`+`[defense]` preset, and the NaN-sentinel (`-`)
//! rendering of `report` all live here.

mod common;

use common::snapshot::assert_cli_snapshot;

#[test]
fn help_screen() {
    assert_cli_snapshot("help", &["--help"]);
}

#[test]
fn unknown_subcommand_is_a_clean_error() {
    assert_cli_snapshot("unknown_subcommand", &["frobnicate"]);
}

#[test]
fn unknown_bench_scenario_is_a_clean_error() {
    assert_cli_snapshot("bench_unknown", &["bench", "frobnicate"]);
}

#[test]
fn bench_byzantine_pins_the_envelope_boundary_and_defense_matrix() {
    assert_cli_snapshot("bench_byzantine", &["bench", "byzantine"]);
}

#[test]
fn bench_faults_pins_the_policy_matrix() {
    assert_cli_snapshot("bench_faults", &["bench", "faults"]);
}

#[test]
fn bench_tiers_pins_the_device_class_fates() {
    assert_cli_snapshot("bench_tiers", &["bench", "tiers"]);
}

#[test]
fn bench_new_emits_the_faults_preset() {
    assert_cli_snapshot("bench_new", &["bench", "new"]);
}

#[test]
fn bench_scale_pins_the_shard_and_spill_accounting() {
    // No --measure: the wall-clock/RSS line renders its deterministic
    // sentinel form, so the golden stays byte-stable across machines.
    assert_cli_snapshot("bench_scale", &["bench", "scale"]);
}

#[test]
fn report_renders_nan_sentinels_as_dashes() {
    assert_cli_snapshot("report_demo", &["report", "--metrics", "tests/fixtures/report_demo.jsonl"]);
}

#[test]
fn report_of_an_empty_run_is_not_an_error() {
    assert_cli_snapshot(
        "report_empty",
        &["report", "--metrics", "tests/fixtures/report_empty.jsonl"],
    );
}

#[test]
fn report_missing_file_is_a_stable_error() {
    assert_cli_snapshot(
        "report_missing",
        &["report", "--metrics", "tests/fixtures/nope.jsonl"],
    );
}

/// Not a snapshot: `bench new --out` must write a file that round-trips
/// through the real TOML config parser with the fault layer enabled.
#[test]
fn bench_new_out_writes_a_valid_preset() {
    let path = std::env::temp_dir().join(format!("fed3sfc_preset_{}.toml", std::process::id()));
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_fed3sfc"))
        .args(["bench", "new", "--out", path.to_str().unwrap()])
        .output()
        .expect("spawn fed3sfc");
    assert!(out.status.success(), "bench new --out failed: {out:?}");
    let cfg = fed3sfc::config::ExperimentConfig::from_toml_file(path.to_str().unwrap())
        .expect("emitted preset must parse and validate");
    assert!(cfg.faults, "preset must enable the fault layer");
    assert_eq!(cfg.fault_tiers, 3);
    assert_eq!(cfg.aggregator, fed3sfc::config::AggregatorKind::TrimmedMean);
    assert!(cfg.reliability, "preset must enable the reliability gate");
    assert!((cfg.byzantine_frac - 0.25).abs() < 1e-12);
    std::fs::remove_file(&path).ok();
}
