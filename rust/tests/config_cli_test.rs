//! Config/CLI system integration: presets parse into valid experiments,
//! every paper table's settings are expressible, errors are caught early.

use fed3sfc::config::{
    BackendKind, CompressorKind, DatasetKind, ExperimentConfig, NetworkKind, ScheduleKind,
    ServerOptKind,
};

#[test]
fn paper_table2_presets_are_expressible() {
    // One preset per Table 2 panel cell family.
    for (ds, model) in [
        ("synth_mnist", "mlp10"),
        ("synth_emnist", "mlp26"),
        ("synth_fmnist", "mnistnet"),
        ("synth_cifar10", "convnet"),
        ("synth_cifar10", "resnet8_c10"),
        ("synth_cifar10", "regnet_c10"),
        ("synth_cifar100", "resnet8_c20"),
        ("synth_cifar100", "regnet_c20"),
    ] {
        for clients in [10usize, 20, 40] {
            for method in ["fedavg", "dgc", "signsgd", "stc", "3sfc"] {
                let toml = format!(
                    "dataset = \"{ds}\"\nmodel = \"{model}\"\ncompressor = \"{method}\"\n\
                     clients = {clients}\nrounds = 5\nk = 5\nlr = 0.01\n"
                );
                let cfg = ExperimentConfig::from_toml_str(&toml).unwrap();
                assert_eq!(cfg.n_clients, clients);
                assert_eq!(cfg.model_key(), model);
                // dataset/model shapes must agree (Experiment::new re-checks)
                assert_eq!(
                    cfg.dataset.feature_len() > 0,
                    true
                );
            }
        }
    }
}

#[test]
fn table4_ablation_settings() {
    let base = ExperimentConfig::from_toml_str(
        "dataset = \"synth_mnist\"\ncompressor = \"3sfc\"\nrounds = 5\n",
    )
    .unwrap();
    assert!(base.error_feedback);
    assert_eq!(base.budget_mult, 1);
    assert_eq!(base.k_local, 5);

    let no_ef = ExperimentConfig::from_toml_str(
        "dataset = \"synth_mnist\"\ncompressor = \"3sfc\"\nrounds = 5\nef = false\n",
    )
    .unwrap();
    assert!(!no_ef.error_feedback);

    for (mult, m) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let cfg = ExperimentConfig::from_toml_str(&format!(
            "dataset = \"synth_mnist\"\ncompressor = \"3sfc\"\nrounds = 5\nbudget_mult = {mult}\n"
        ))
        .unwrap();
        assert_eq!(cfg.syn_m(), m);
    }
    for k in [1usize, 5, 10] {
        let cfg = ExperimentConfig::from_toml_str(&format!(
            "dataset = \"synth_mnist\"\ncompressor = \"3sfc\"\nrounds = 5\nk = {k}\n"
        ))
        .unwrap();
        assert_eq!(cfg.k_local, k);
    }
}

#[test]
fn fig1_sweep_settings() {
    for rate in [0.1, 0.01, 0.001] {
        let cfg = ExperimentConfig::from_toml_str(&format!(
            "dataset = \"synth_mnist\"\ncompressor = \"dgc\"\nrounds = 5\ntopk_rate = {rate}\n"
        ))
        .unwrap();
        assert_eq!(cfg.topk_rate, rate);
        assert_eq!(cfg.compressor, CompressorKind::Dgc);
    }
}

#[test]
fn invalid_configs_rejected() {
    // K not in {1,5,10} (no artifact)
    assert!(ExperimentConfig::from_toml_str("k = 3").is_err());
    // unknown method/dataset/key
    assert!(ExperimentConfig::from_toml_str("compressor = \"zip\"").is_err());
    assert!(ExperimentConfig::from_toml_str("dataset = \"imagenet\"").is_err());
    assert!(ExperimentConfig::from_toml_str("no_such_key = 1").is_err());
    // bad budget multiplier
    assert!(ExperimentConfig::from_toml_str("budget_mult = 3").is_err());
}

#[test]
fn dataset_defaults_pair_with_manifest_models() {
    for ds in [
        DatasetKind::SynthMnist,
        DatasetKind::SynthEmnist,
        DatasetKind::SynthFmnist,
        DatasetKind::SynthCifar10,
        DatasetKind::SynthCifar100,
        DatasetKind::SynthSmall,
    ] {
        let cfg = ExperimentConfig {
            dataset: ds,
            ..ExperimentConfig::default()
        };
        // default model key must be non-empty and stable
        assert!(!cfg.model_key().is_empty());
    }
}

#[test]
fn round_engine_preset_is_expressible() {
    // The acceptance scenario from the round-engine redesign: 100 clients,
    // 10% uniform sampling, FedAdam server, edge link — one TOML preset.
    let cfg = ExperimentConfig::from_toml_str(
        r#"
        dataset = "synth_mnist"
        compressor = "3sfc"
        clients = 100
        rounds = 10

        [schedule]
        kind = "uniform"
        client_frac = 0.1

        [server_opt]
        kind = "fedadam"
        lr = 0.02
        beta1 = 0.9
        beta2 = 0.99
        tau = 0.001

        [network]
        kind = "edge"
        "#,
    )
    .unwrap();
    assert_eq!(cfg.n_clients, 100);
    assert_eq!(cfg.schedule, ScheduleKind::Uniform);
    assert_eq!(cfg.client_frac, 0.1);
    assert_eq!(cfg.server_opt, ServerOptKind::FedAdam);
    assert_eq!(cfg.server_lr, 0.02);
    assert_eq!(cfg.network, NetworkKind::Edge);

    // Defaults stay the seed/paper protocol.
    let default = ExperimentConfig::default();
    assert_eq!(default.schedule, ScheduleKind::Full);
    assert_eq!(default.client_frac, 1.0);
    assert_eq!(default.server_opt, ServerOptKind::Gd);
    assert_eq!(default.server_lr, 1.0);
}

#[test]
fn round_engine_cli_flags_parse() {
    use fed3sfc::cli::Args;
    let argv: Vec<String> = [
        "run",
        "--schedule",
        "uniform",
        "--client-frac",
        "0.1",
        "--server-opt",
        "fedadam",
        "--server-lr",
        "0.02",
        "--network",
        "custom",
        "--up-mbps",
        "2.5",
        "--latency-ms",
        "80",
        "--threads",
        "4",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let args = Args::parse(argv, &[]).unwrap();
    assert_eq!(
        ScheduleKind::parse(args.get("schedule").unwrap()).unwrap(),
        ScheduleKind::Uniform
    );
    assert_eq!(args.get_f64("client-frac", 1.0).unwrap(), 0.1);
    assert_eq!(
        ServerOptKind::parse(args.get("server-opt").unwrap()).unwrap(),
        ServerOptKind::FedAdam
    );
    assert_eq!(args.get_f32("server-lr", 1.0).unwrap(), 0.02);
    assert_eq!(
        NetworkKind::parse(args.get("network").unwrap()).unwrap(),
        NetworkKind::Custom
    );
    assert_eq!(args.get_f64("up-mbps", 10.0).unwrap(), 2.5);
    assert_eq!(args.get_f64("latency-ms", 30.0).unwrap(), 80.0);
    assert_eq!(args.get_usize("threads", 0).unwrap(), 4);
}

#[test]
fn backend_preset_and_cli_flag_parse() {
    // TOML: [runtime] table and bare key.
    let cfg = ExperimentConfig::from_toml_str(
        "dataset = \"synth_mnist\"\ncompressor = \"3sfc\"\nrounds = 5\n\n[runtime]\nbackend = \"native\"\n",
    )
    .unwrap();
    assert_eq!(cfg.backend, BackendKind::Native);
    let cfg = ExperimentConfig::from_toml_str("backend = \"pjrt\"").unwrap();
    assert_eq!(cfg.backend, BackendKind::Pjrt);
    assert!(ExperimentConfig::from_toml_str("backend = \"gpu\"").is_err());

    // CLI flag value parses through the same enum.
    use fed3sfc::cli::Args;
    let argv: Vec<String> = ["run", "--backend", "native"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = Args::parse(argv, &[]).unwrap();
    assert_eq!(
        BackendKind::parse(args.get("backend").unwrap()).unwrap(),
        BackendKind::Native
    );
}

#[test]
fn runtime_threads_preset_is_expressible() {
    let cfg = ExperimentConfig::from_toml_str(
        "dataset = \"synth_mnist\"\ncompressor = \"3sfc\"\nrounds = 5\n\n[runtime]\nthreads = 4\n",
    )
    .unwrap();
    assert_eq!(cfg.threads, 4);
    assert_eq!(cfg.effective_threads(), 4);
}

#[test]
fn cli_args_build_run_configs() {
    use fed3sfc::cli::Args;
    let argv: Vec<String> = [
        "run",
        "--dataset",
        "synth_fmnist",
        "--compressor",
        "stc",
        "--clients",
        "20",
        "--rounds",
        "7",
        "--no-ef",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let args = Args::parse(argv, &["no-ef"]).unwrap();
    assert_eq!(args.subcommand, "run");
    assert_eq!(args.get("dataset"), Some("synth_fmnist"));
    assert_eq!(args.get_usize("clients", 0).unwrap(), 20);
    assert!(args.has_flag("no-ef"));
}
