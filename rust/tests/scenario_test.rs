//! Adversarial-reality scenario tests: the `[faults]` layer end-to-end.
//!
//! The acceptance contract for the fault layer, pinned here:
//!
//! * with dropouts active, deadline and async experiments complete and
//!   their trajectories are bit-identical across worker-thread counts;
//! * the same faults under a synchronous barrier fail fast with the
//!   typed [`UploadError::LossUnderBarrier`] diagnostic — never a hang;
//! * a disabled layer consumes zero RNG draws, so `[faults]`-off runs
//!   are bit-identical to configs that never mention the table;
//! * fuzzed byzantine envelopes are all rejected with typed errors at
//!   `submit_upload` and leave no residue in the server;
//! * device-class tier fates are correlated by construction and the
//!   diurnal wave stays inside its advertised bounds.

mod common;

use fed3sfc::compress::{DenseDownlink, Payload};
use fed3sfc::config::{
    CompressorKind, DatasetKind, ExperimentConfig, NetworkKind, SessionKind,
};
use fed3sfc::coordinator::{
    ClientMsg, Directive, Experiment, FedServer, FullParticipation, RoundRecord, Server,
    Synchronous, Upload, UploadError,
};
use fed3sfc::simnet::{FaultLayer, FaultsConfig, NetworkModel};
use fed3sfc::util::rng::{stream, Rng};

// ---------------------------------------------------------------------
// Faulty experiment configs (SynthSmall keeps these tier-1 fast).

fn faulty_deadline_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 6,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        train_samples: 240,
        test_samples: 50,
        eval_every: 6,
        seed: 42,
        session: SessionKind::Deadline,
        network: NetworkKind::Custom,
        net_up_mbps: 0.1,
        net_down_mbps: 1.0,
        net_latency_ms: 1.0,
        net_jitter: 0.5,
        deadline_s: 0.08,
        staleness_decay: 0.5,
        threads,
        // The full adversarial stack: dropouts, crash windows, a diurnal
        // wave, and three correlated device-class tiers. Seed 42 dooms
        // client 5's very first upload (checked below), so the loss path
        // is exercised deterministically.
        faults: true,
        fault_dropout_p: 0.3,
        fault_recover_s: 0.5,
        fault_diurnal_amp: 0.5,
        fault_diurnal_period_s: 5.0,
        fault_tiers: 3,
        fault_tier_spread: 0.6,
        fault_tier_compute_s: 0.02,
        ..ExperimentConfig::default()
    }
}

fn faulty_async_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 4,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        train_samples: 200,
        test_samples: 50,
        eval_every: 6,
        seed: 42,
        session: SessionKind::Async,
        buffer_k: 2,
        staleness_decay: 0.5,
        net_jitter: 0.3,
        threads,
        faults: true,
        fault_dropout_p: 0.25,
        fault_recover_s: 0.3,
        ..ExperimentConfig::default()
    }
}

/// Run to completion; return the records plus the fault-layer ledger.
fn run_faulty(cfg: ExperimentConfig) -> (Vec<RoundRecord>, u64, u64) {
    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    let recs = exp.run().unwrap();
    let lost = exp.fed.lost_uploads();
    let recovered = exp.fed.recovered_clients();
    (recs, lost, recovered)
}

fn assert_records_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.n_selected, y.n_selected, "round {}", x.round);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {}", x.round);
        assert_eq!(x.up_bytes_cum, y.up_bytes_cum, "round {}", x.round);
        assert_eq!(x.down_bytes_cum, y.down_bytes_cum, "round {}", x.round);
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "round {}", x.round);
        assert_eq!(x.stale_mean.to_bits(), y.stale_mean.to_bits(), "round {}", x.round);
        assert_eq!(x.comm_time_s.to_bits(), y.comm_time_s.to_bits(), "round {}", x.round);
    }
}

#[test]
fn deadline_session_absorbs_dropouts_and_completes() {
    let (recs, lost, recovered) = run_faulty(faulty_deadline_cfg(1));
    assert_eq!(recs.len(), 6, "every round completes despite the faults");
    assert!(lost >= 1, "seed 42 dooms an upload in the first cycle");
    assert!(recovered <= lost, "a client recovers at most once per loss");
    assert!(recs.iter().all(|r| r.test_acc.is_finite() && r.test_loss.is_finite()));
    // Lost uploads thin at least one cycle's aggregation.
    assert!(recs.iter().any(|r| r.n_selected < 6), "no step ever missed a casualty");
}

#[test]
fn deadline_faults_are_thread_count_independent() {
    let (a, lost_a, rec_a) = run_faulty(faulty_deadline_cfg(1));
    let (b, lost_b, rec_b) = run_faulty(faulty_deadline_cfg(4));
    assert_records_bit_identical(&a, &b);
    assert_eq!((lost_a, rec_a), (lost_b, rec_b), "fault ledger must not see threads");
}

#[test]
fn async_session_absorbs_dropouts_and_completes() {
    let (recs, lost, _) = run_faulty(faulty_async_cfg(1));
    assert_eq!(recs.len(), 6);
    assert!(lost >= 1, "seed 42's fault stream dooms the fifth dispatch");
    assert!(recs.iter().all(|r| r.n_selected == 2), "async still steps every K arrivals");
}

#[test]
fn async_faults_are_thread_count_independent() {
    let (a, lost_a, rec_a) = run_faulty(faulty_async_cfg(1));
    let (b, lost_b, rec_b) = run_faulty(faulty_async_cfg(4));
    assert_records_bit_identical(&a, &b);
    assert_eq!((lost_a, rec_a), (lost_b, rec_b), "fault ledger must not see threads");
}

#[test]
fn sync_barrier_under_faults_fails_with_the_typed_diagnostic() {
    // dropout_p = 1.0 clamps every effective loss probability to 1: the
    // very first submitted upload is doomed, and a barrier cannot absorb
    // it — the run must fail fast with the typed diagnostic, not hang.
    let cfg = ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 4,
        rounds: 3,
        k_local: 5,
        lr: 0.05,
        train_samples: 200,
        test_samples: 50,
        eval_every: 3,
        seed: 42,
        faults: true,
        fault_dropout_p: 1.0,
        ..ExperimentConfig::default()
    };
    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    let err = exp.run().expect_err("a barrier cannot survive certain dropouts");
    let typed = err
        .downcast_ref::<UploadError>()
        .unwrap_or_else(|| panic!("diagnostic must stay typed through the stack: {err:#}"));
    assert!(
        matches!(typed, UploadError::LossUnderBarrier { round: 0, .. }),
        "wrong variant: {typed:?}"
    );
    assert!(err.to_string().contains("disable [faults]"), "diagnostic must name the fix");
}

#[test]
fn disabled_faults_consume_zero_draws_and_change_nothing() {
    // `enabled = false` with every other knob cranked must be
    // bit-identical to a config that never mentions the `[faults]`
    // table: the layer draws nothing when off.
    let plain = ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::ThreeSfc,
        n_clients: 4,
        rounds: 4,
        k_local: 5,
        lr: 0.05,
        train_samples: 200,
        test_samples: 50,
        eval_every: 4,
        seed: 7,
        net_jitter: 0.4,
        ..ExperimentConfig::default()
    };
    let mut off = plain.clone();
    off.faults = false;
    off.fault_dropout_p = 0.9;
    off.fault_recover_s = 0.1;
    off.fault_diurnal_amp = 1.0;
    off.fault_tiers = 7;
    off.fault_tier_spread = 1.0;
    off.fault_tier_compute_s = 3.0;
    let (a, lost_a, _) = run_faulty(plain);
    let (b, lost_b, _) = run_faulty(off);
    assert_records_bit_identical(&a, &b);
    assert_eq!(lost_a, 0);
    assert_eq!(lost_b, 0);
}

// ---------------------------------------------------------------------
// The envelope boundary under fuzz.

fn honest_upload(client: usize, sent_at: f64) -> Upload {
    Upload {
        client,
        round: 0,
        sent_at,
        payload: Payload::Sign { n: 8, bits: vec![0u8], scale: 1.0 },
        recon: vec![0.1; 8],
        weight: 1.0,
        efficiency: 1.0,
        ratio: 32.0,
    }
}

#[test]
fn fuzzed_byzantine_envelopes_never_corrupt_the_server() {
    let links =
        NetworkModel::custom(2.0, 20.0, 10.0).client_links(4, 0.0, &mut Rng::new(3));
    let mut fed = FedServer::new(
        Server::new(vec![0.0f32; 8]),
        Box::new(FullParticipation),
        Box::new(Synchronous),
        links,
        vec![true; 4],
        8,
    );
    let mut dl = DenseDownlink::new();
    let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else {
        panic!("expected the opening dispatch");
    };
    let w_before: Vec<u32> = fed.server.w.iter().map(|v| v.to_bits()).collect();

    let mut rng = Rng::new(0xB12A);
    for i in 0..300 {
        let c = rng.below(4);
        let mut up = honest_upload(c, bcasts[c].recv_at);
        match rng.below(8) {
            0 => up.round = 1 + rng.below(1000),
            1 => up.recon.truncate(rng.below(8)),
            2 => up.recon[rng.below(8)] = f32::NAN,
            3 => {
                up.weight =
                    if rng.below(2) == 0 { -1.0 - rng.f32() } else { f32::INFINITY };
            }
            4 => up.payload = Payload::Sign { n: 8, bits: vec![0u8; 3], scale: 1.0 },
            5 => up.payload = Payload::Sign { n: 8, bits: vec![0u8], scale: f32::NAN },
            6 => up.sent_at = -0.001 - rng.f64(),
            _ => up.client = 4 + rng.below(1000),
        }
        let err = fed
            .submit_upload(ClientMsg::Upload(up))
            .expect_err("every mutation must be rejected");
        assert!(
            err.downcast_ref::<UploadError>().is_some(),
            "fuzz case {i}: rejection lost its type: {err:#}"
        );
    }

    // No residue: the model never moved, and the honest cohort still
    // completes its barrier as if nothing happened.
    let w_after: Vec<u32> = fed.server.w.iter().map(|v| v.to_bits()).collect();
    assert_eq!(w_before, w_after, "a rejected envelope moved the model");
    for bc in &bcasts {
        fed.submit_upload(ClientMsg::Upload(honest_upload(bc.client, bc.recv_at))).unwrap();
    }
    let Directive::Step(s) = fed.next_directive(&mut dl).unwrap() else {
        panic!("expected the barrier step");
    };
    assert_eq!(s.round, 1);
    assert_eq!(s.clients, vec![0, 1, 2, 3]);
}

// ---------------------------------------------------------------------
// Fate correlation and the diurnal wave.

#[test]
fn tier_fates_are_correlated_and_monotone() {
    let cfg = FaultsConfig {
        enabled: true,
        dropout_p: 0.1,
        tiers: 4,
        tier_spread: 0.8,
        tier_compute_s: 0.1,
        ..FaultsConfig::default()
    };
    let layer = FaultLayer::new(&cfg, 32, Rng::new(5).split(stream::FAULTS));
    let fates = layer.fates();
    assert!(fates.iter().any(|f| f.tier > 0), "32 draws over 4 tiers hit a slow tier");
    for a in fates {
        for b in fates {
            if a.tier <= b.tier {
                // One draw decides everything: a worse tier is worse on
                // every axis at once, never a mix.
                assert!(a.bw_mult >= b.bw_mult);
                assert!(a.compute_s <= b.compute_s);
                assert!(a.rel_mult <= b.rel_mult);
            }
            if a.tier == b.tier {
                assert_eq!(a.bw_mult.to_bits(), b.bw_mult.to_bits());
            }
        }
    }
    // Best tier is undegraded; loss probability respects its clamp even
    // for the worst tier under a cranked base rate.
    let best = fates.iter().min_by_key(|f| f.tier).unwrap();
    assert_eq!(best.tier, 0);
    assert_eq!(best.bw_mult.to_bits(), 1.0f64.to_bits());
    assert_eq!(best.compute_s.to_bits(), 0.0f64.to_bits());
    let cranked = FaultsConfig { dropout_p: 0.9, ..cfg };
    let hot = FaultLayer::new(&cranked, 32, Rng::new(5).split(stream::FAULTS));
    for c in 0..32 {
        let p = hot.loss_probability(c, 0.0);
        assert!((0.0..=1.0).contains(&p), "client {c}: p={p} escaped the clamp");
    }
}

#[test]
fn diurnal_wave_stays_inside_its_advertised_bounds() {
    let cfg = FaultsConfig {
        enabled: true,
        diurnal_amp: 0.4,
        diurnal_period_s: 60.0,
        ..FaultsConfig::default()
    };
    let layer = FaultLayer::new(&cfg, 1, Rng::new(6).split(stream::FAULTS));
    // Trough at each period boundary, crest at each half period.
    assert!((layer.wave(0.0) - 0.6).abs() < 1e-12);
    assert!((layer.wave(30.0) - 1.4).abs() < 1e-12);
    assert!((layer.wave(60.0) - 0.6).abs() < 1e-12);
    for i in 0..600 {
        let w = layer.wave(i as f64 * 0.73);
        assert!((0.6..=1.4).contains(&w), "t={}: wave {w} out of bounds", i as f64 * 0.73);
    }
    // amp = 0 means a flat wave — and zero perturbation of loss rates.
    let flat = FaultLayer::new(
        &FaultsConfig { enabled: true, ..FaultsConfig::default() },
        1,
        Rng::new(6).split(stream::FAULTS),
    );
    for i in 0..10 {
        assert_eq!(flat.wave(i as f64 * 13.7).to_bits(), 1.0f64.to_bits());
    }
}
