//! Downlink-compression acceptance tests (`compress::downlink`).
//!
//! * **Dense equivalence**: `gap = 0` forces a keyframe whenever the
//!   model version advanced, so in server-paced sessions (sync,
//!   deadline) a compressed downlink degenerates to the dense path —
//!   bit-for-bit, bytes included. This is also the version-gap
//!   reconstruction contract: a client that missed rounds (deadline
//!   straggler carry-over) is resynchronized by keyframe and the run
//!   ends bit-identical to a dense-broadcast run.
//! * **Thread independence**: downlink encoding happens on the main
//!   thread in dispatch order, so compressed-downlink sessions are
//!   bit-identical for `threads ∈ {1, 4}` in all three session modes.
//! * **Ledger semantics**: a hand-driven deadline session pins the
//!   keyframe/delta decisions, the base versions, and — through an
//!   actual serialize → deserialize → decode → apply client replica —
//!   that every broadcast's reconstruction cache `Broadcast::w` is
//!   exactly what a remote client would reconstruct from the wire.
//! * **Traffic**: compressing the downlink cuts total (up + down) wire
//!   bytes by well over the 40% acceptance bar at equal rounds.

mod common;

use std::sync::Arc;

use fed3sfc::compress::{Compressor, DecodeCtx, DeltaDownlink, DeltaPayload, TopK};
use fed3sfc::config::{
    CompressorKind, DatasetKind, DownlinkKind, ExperimentConfig, NetworkKind, ScheduleKind,
    SessionKind,
};
use fed3sfc::coordinator::{
    Broadcast, ClientMsg, Deadline, Directive, Experiment, FedServer, FullParticipation, Server,
    Upload,
};
use fed3sfc::runtime::{Backend, FedOps};
use fed3sfc::simnet::NetworkModel;
use fed3sfc::util::rng::Rng;
use fed3sfc::util::vecmath;
use fed3sfc::RoundRecord;

// ---------------------------------------------------------------------
// Shared harness (mirrors tests/session_test.rs).

fn sync_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 5,
        rounds: 5,
        k_local: 5,
        lr: 0.05,
        train_samples: 200,
        test_samples: 50,
        eval_every: 5,
        seed: 42,
        schedule: ScheduleKind::Uniform,
        client_frac: 0.6,
        threads,
        ..ExperimentConfig::default()
    }
}

fn deadline_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 6,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        train_samples: 240,
        test_samples: 50,
        eval_every: 6,
        seed: 42,
        session: SessionKind::Deadline,
        network: NetworkKind::Custom,
        net_up_mbps: 0.1,
        net_down_mbps: 1.0,
        net_latency_ms: 1.0,
        net_jitter: 0.5,
        deadline_s: 0.08,
        staleness_decay: 0.5,
        threads,
        ..ExperimentConfig::default()
    }
}

fn async_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::Dgc,
        n_clients: 4,
        rounds: 6,
        k_local: 5,
        lr: 0.05,
        train_samples: 200,
        test_samples: 50,
        eval_every: 6,
        seed: 42,
        session: SessionKind::Async,
        buffer_k: 2,
        staleness_decay: 0.5,
        net_jitter: 0.3,
        threads,
        ..ExperimentConfig::default()
    }
}

fn with_downlink(
    mut cfg: ExperimentConfig,
    kind: DownlinkKind,
    gap: usize,
    rate: f64,
) -> ExperimentConfig {
    cfg.downlink = kind;
    cfg.downlink_gap = gap;
    cfg.downlink_rate = rate;
    cfg
}

/// Records + final weights + per-client EF of one full run.
fn run_full(cfg: ExperimentConfig) -> (Vec<RoundRecord>, Vec<f32>, Vec<Vec<f32>>) {
    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    let recs = exp.run().unwrap();
    let efs = exp.clients.ef_snapshots();
    (recs, exp.fed.server.w.clone(), efs)
}

fn assert_records_bit_identical(a: &[RoundRecord], b: &[RoundRecord]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.n_selected, y.n_selected, "round {}", x.round);
        assert_eq!(x.test_acc.to_bits(), y.test_acc.to_bits(), "round {}", x.round);
        assert_eq!(x.up_bytes_round, y.up_bytes_round, "round {}", x.round);
        assert_eq!(x.up_bytes_cum, y.up_bytes_cum, "round {}", x.round);
        assert_eq!(x.down_bytes_round, y.down_bytes_round, "round {}", x.round);
        assert_eq!(x.down_bytes_cum, y.down_bytes_cum, "round {}", x.round);
        assert_eq!(x.efficiency.to_bits(), y.efficiency.to_bits(), "round {}", x.round);
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "round {}", x.round);
        assert_eq!(x.stale_mean.to_bits(), y.stale_mean.to_bits(), "round {}", x.round);
        assert_eq!(x.comm_time_s.to_bits(), y.comm_time_s.to_bits(), "round {}", x.round);
    }
}

fn assert_runs_bit_identical(
    a: &(Vec<RoundRecord>, Vec<f32>, Vec<Vec<f32>>),
    b: &(Vec<RoundRecord>, Vec<f32>, Vec<Vec<f32>>),
) {
    assert_records_bit_identical(&a.0, &b.0);
    assert_eq!(a.1.len(), b.1.len());
    for (i, (x, y)) in a.1.iter().zip(b.1.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "w[{i}]");
    }
    assert_eq!(a.2, b.2, "per-client EF state");
}

// ---------------------------------------------------------------------
// Dense equivalence: gap = 0 in server-paced sessions is the dense path.

#[test]
fn gap_zero_downlink_is_bit_identical_to_dense_in_sync() {
    // In a sync session every dispatch follows a step, so the ledger is
    // always exactly one version behind and `gap = 0` keyframes every
    // broadcast — bytes, times, and trajectory must match identity.
    let dense = run_full(sync_cfg(1));
    let gap0 = run_full(with_downlink(sync_cfg(1), DownlinkKind::TopK, 0, 0.05));
    assert_runs_bit_identical(&dense, &gap0);
}

#[test]
fn gap_zero_downlink_is_bit_identical_to_dense_under_deadline_stragglers() {
    // Version-gap reconstruction (satellite): the jittery slow links make
    // clients miss whole aggregation windows, so redispatches see ledger
    // gaps > 1 — every one of them must come back as a keyframe, leaving
    // the run bit-identical to the dense-broadcast run.
    let dense = run_full(deadline_cfg(1));
    let gap0 = run_full(with_downlink(deadline_cfg(1), DownlinkKind::TopK, 0, 0.05));
    assert_runs_bit_identical(&dense, &gap0);
    // The scenario really exercises carried-over stragglers.
    assert!(dense.0.iter().any(|r| r.stale_mean > 0.0), "no straggler carried over");
}

// ---------------------------------------------------------------------
// Thread-count independence with a *compressing* downlink.

#[test]
fn compressed_downlink_is_thread_independent_in_sync() {
    let a = run_full(with_downlink(sync_cfg(1), DownlinkKind::TopK, 4, 0.05));
    let b = run_full(with_downlink(sync_cfg(4), DownlinkKind::TopK, 4, 0.05));
    assert_runs_bit_identical(&a, &b);
}

#[test]
fn compressed_downlink_is_thread_independent_under_deadline() {
    let a = run_full(with_downlink(deadline_cfg(1), DownlinkKind::TopK, 4, 0.05));
    let b = run_full(with_downlink(deadline_cfg(4), DownlinkKind::TopK, 4, 0.05));
    assert_runs_bit_identical(&a, &b);
}

#[test]
fn compressed_downlink_is_thread_independent_in_async() {
    let a = run_full(with_downlink(async_cfg(1), DownlinkKind::TopK, 2, 0.05));
    let b = run_full(with_downlink(async_cfg(4), DownlinkKind::TopK, 2, 0.05));
    assert_runs_bit_identical(&a, &b);
}

#[test]
fn threesfc_downlink_is_thread_independent_and_trains() {
    // The synthesizing downlink consumes its own RNG stream per encode;
    // main-thread dispatch-order encoding keeps that stream identical
    // for any worker count.
    let mut cfg = sync_cfg(1);
    cfg.syn_steps = 6;
    let a = run_full(with_downlink(cfg.clone(), DownlinkKind::ThreeSfc, 4, 0.0));
    let mut cfg4 = cfg;
    cfg4.threads = 4;
    let b = run_full(with_downlink(cfg4, DownlinkKind::ThreeSfc, 4, 0.0));
    assert_runs_bit_identical(&a, &b);
    assert!(a.0.iter().all(|r| r.test_acc.is_finite() && r.test_loss.is_finite()));
}

// ---------------------------------------------------------------------
// Traffic: both-way compression at equal rounds.

#[test]
fn compressed_downlink_cuts_total_traffic_at_least_40pct_at_equal_rounds() {
    let mut base = sync_cfg(1);
    base.schedule = ScheduleKind::Full;
    base.client_frac = 1.0;
    base.rounds = 8;
    base.eval_every = 8;
    base.topk_rate = 0.01;
    let be = common::native();

    let mut dense = Experiment::new(base.clone(), &be).unwrap();
    let dense_recs = dense.run().unwrap();
    let mut comp =
        Experiment::new(with_downlink(base, DownlinkKind::TopK, 4, 0.01), &be).unwrap();
    let comp_recs = comp.run().unwrap();

    assert_eq!(dense_recs.len(), comp_recs.len(), "equal rounds");
    let (td, tc) = (dense.traffic(), comp.traffic());
    // Fixed-size top-k uploads: the uplink trajectory prices identically.
    assert_eq!(td.uplink_bytes, tc.uplink_bytes);
    assert!(tc.downlink_bytes < td.downlink_bytes);
    let saved = 1.0 - tc.total_bytes() as f64 / td.total_bytes() as f64;
    assert!(
        saved >= 0.40,
        "total wire bytes only dropped {:.1}% ({} -> {})",
        100.0 * saved,
        td.total_bytes(),
        tc.total_bytes()
    );
    // The label surfaces the downlink method + measured ratio.
    assert!(comp.label().contains("down "), "label: {}", comp.label());
}

#[test]
fn async_compressed_downlink_is_deterministic_and_cheaper_than_dense() {
    // Async sessions redispatch on upload arrival — sometimes at an
    // unchanged model version (a pure EF-residual delta), sometimes
    // several versions later. The ledger must keep the run deterministic
    // and strictly cheaper than keyframing every broadcast.
    let cfg = with_downlink(async_cfg(1), DownlinkKind::TopK, 2, 0.02);
    let a = run_full(cfg.clone());
    let b = run_full(cfg.clone());
    assert_runs_bit_identical(&a, &b);
    assert!(a.0.iter().all(|r| r.test_acc.is_finite() && r.test_loss.is_finite()));

    let be = common::native();
    let mut exp = Experiment::new(cfg, &be).unwrap();
    exp.run().unwrap();
    let t = exp.traffic();
    let dense_price = (4 + 4 * exp.ops.model.params as u64) * t.broadcasts;
    assert!(
        t.downlink_bytes < dense_price,
        "{} broadcast(s) cost {} B, dense would be {} B",
        t.broadcasts,
        t.downlink_bytes,
        dense_price
    );
}

// ---------------------------------------------------------------------
// Hand-driven ledger semantics: keyframe/delta decisions, base versions,
// and the wire → client-replica reconstruction contract.

fn fake_upload(bc: &Broadcast, n: usize, value: f32) -> ClientMsg {
    ClientMsg::Upload(Upload {
        client: bc.client,
        round: bc.round,
        sent_at: bc.recv_at,
        payload: fed3sfc::compress::Payload::Sign { n: 8, bits: vec![0u8], scale: 1.0 },
        recon: vec![value; n],
        weight: 1.0,
        efficiency: 1.0,
        ratio: 32.0,
    })
}

/// What a remote client would do with the envelope: deserialize the
/// actual wire bytes, decode against the weights it holds, apply — and
/// the result must be bit-identical to the envelope's reconstruction
/// cache `bc.w` (and therefore to the server's shadow).
fn client_reconstruct(
    ops: &FedOps,
    comp: &dyn Compressor,
    replica: &mut Option<(usize, Vec<f32>)>,
    bc: &Broadcast,
) {
    let model = ops.model;
    let bytes = bc.payload.serialize();
    assert_eq!(bytes.len(), bc.payload.wire_bytes(), "wire-honest broadcast");
    let decoded = DeltaPayload::deserialize(
        &bc.payload.kind(),
        &bytes,
        model.params,
        model.feature_len(),
        model.n_classes,
    )
    .unwrap();
    let w_new = match decoded {
        DeltaPayload::Keyframe { w } => w.as_ref().clone(),
        DeltaPayload::Delta { base, inner } => {
            let (ver, w_held) = replica.as_ref().expect("delta sent to a cold client");
            assert_eq!(*ver, base as usize, "delta base must be the held version");
            let dctx = DecodeCtx { ops, w_global: w_held };
            let d = comp.decode(&dctx, &inner).unwrap();
            let mut w = w_held.clone();
            vecmath::add_assign(&mut w, &d);
            w
        }
    };
    for (i, (a, b)) in w_new.iter().zip(bc.w.iter()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "client {} coord {i}: wire reconstruction != Broadcast::w",
            bc.client
        );
    }
    *replica = Some((bc.round, w_new));
}

#[test]
fn deadline_ledger_keyframes_past_the_gap_and_deltas_within() {
    // Two clients on a deadline session, client 1's uplink throttled so
    // it misses every 50 ms window (the fedserver straggler scenario),
    // downlink = top-k with gap 1:
    //   cycle 1 (v0): both cold            → keyframes.
    //   cycle 2 (v1): client 0 alone, lag 1 → delta on base 0.
    //   cycle 3 (v2): client 0 lag 1 → delta on base 1;
    //                 client 1 lag 2 > gap  → keyframe resync.
    let be = common::native();
    let ops = FedOps::new(&be, "mlp_small").unwrap();
    let n = ops.model.params;
    let w0 = be.load_init(ops.model).unwrap();

    let k = (n / 10).max(1);
    let dl_ops = FedOps::new(&be, "mlp_small").unwrap();
    let mut dl = DeltaDownlink::new(dl_ops, Box::new(TopK::new(k)), 2, 1, Rng::new(7));
    let decode_comp = TopK::new(k);

    let base_net = NetworkModel::custom(10.0, 50.0, 1.0);
    let mut ls = base_net.client_links(2, 0.0, &mut Rng::new(1));
    ls[1].up_bps = 1_000.0; // 9-byte upload → 72 ms ≫ the deadline
    let mut fed = FedServer::new(
        Server::new(w0),
        Box::new(FullParticipation),
        Box::new(Deadline::new(0.05, 0.5)),
        ls,
        vec![true; 2],
        n,
    );
    let mut replicas: Vec<Option<(usize, Vec<f32>)>> = vec![None, None];

    // Cycle 1: both clients cold → dense keyframes at version 0.
    let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else { panic!() };
    assert_eq!(bcasts.len(), 2);
    for bc in &bcasts {
        assert_eq!(bc.payload.kind(), "keyframe");
        assert_eq!(bc.payload.wire_bytes(), 4 + 4 * n, "dense keyframe price");
        client_reconstruct(&ops, &decode_comp, &mut replicas[bc.client], bc);
        fed.submit_upload(fake_upload(bc, n, 0.01)).unwrap();
    }
    // Cohort keyframes share one allocation (per-version Arc cache).
    assert!(Arc::ptr_eq(&bcasts[0].w, &bcasts[1].w));

    // Step 1 aggregates the fast client alone; the straggler flies on.
    let Directive::Step(s1) = fed.next_directive(&mut dl).unwrap() else { panic!() };
    assert_eq!(s1.clients, vec![0]);

    // Cycle 2: only client 0 is free; one version behind → delta.
    let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else { panic!() };
    assert_eq!((bcasts.len(), bcasts[0].client), (1, 0));
    assert_eq!(bcasts[0].payload.kind(), "delta:topk");
    assert_eq!(bcasts[0].payload.base_version(), Some(0));
    assert!(bcasts[0].payload.wire_bytes() < 4 + 4 * n, "delta beats dense");
    client_reconstruct(&ops, &decode_comp, &mut replicas[0], &bcasts[0]);
    fed.submit_upload(fake_upload(&bcasts[0], n, 0.02)).unwrap();

    // Step 2 absorbs the fresh upload + the round-0 straggler.
    let Directive::Step(s2) = fed.next_directive(&mut dl).unwrap() else { panic!() };
    assert_eq!(s2.clients, vec![0, 1]);

    // Cycle 3: client 0 is 1 behind (delta on base 1); client 1 is 2
    // behind — past gap 1 — and must be keyframed back in sync.
    let Directive::Dispatch(bcasts) = fed.next_directive(&mut dl).unwrap() else { panic!() };
    assert_eq!(bcasts.len(), 2);
    let by_client = |c: usize| bcasts.iter().find(|b| b.client == c).unwrap();
    assert_eq!(by_client(0).payload.kind(), "delta:topk");
    assert_eq!(by_client(0).payload.base_version(), Some(1));
    assert_eq!(by_client(1).payload.kind(), "keyframe", "stale past the gap → keyframe");
    for bc in &bcasts {
        client_reconstruct(&ops, &decode_comp, &mut replicas[bc.client], bc);
    }
    assert_eq!((dl.keyframes, dl.deltas), (3, 2));

    // The server's shadow ledger is exactly each client replica.
    for c in 0..2 {
        assert_eq!(dl.ledger_version(c), Some(2));
        let (_, replica_w) = replicas[c].as_ref().unwrap();
        let shadow = dl.shadow(c).unwrap();
        for (i, (a, b)) in shadow.iter().zip(replica_w.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "client {c} shadow[{i}]");
        }
    }
    // And the keyframed straggler holds the *current* global weights.
    let (_, r1) = replicas[1].as_ref().unwrap();
    for (a, b) in r1.iter().zip(fed.server.w.iter()) {
        assert_eq!(a.to_bits(), b.to_bits(), "keyframe resync = current model");
    }
}
