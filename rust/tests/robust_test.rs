//! Property tests for the byzantine-robust aggregators
//! (`coordinator::robust`): the determinism contract every estimator
//! must honor, pinned independently of any session.
//!
//! * disabled thresholds (`β = 0`, `f = 0, m = 0`, `τ = 0`) degenerate
//!   to the plain weighted mean **bitwise** on a client-sorted batch;
//! * robust estimators are invariant under batch permutation (arrival
//!   order must not leak into deadline/async aggregates);
//! * Krum breaks score ties toward the lowest client index, so tied
//!   geometries cannot make two runs disagree.

use fed3sfc::coordinator::{
    AggOutcome, CoordinateMedian, MultiKrum, NormClip, RobustAggregator, TrimmedMean,
    WeightedMean,
};

/// A heterogeneous client-sorted batch: 5 clients, 6 params, distinct
/// weights — every estimator has something to chew on.
fn batch() -> (Vec<usize>, Vec<Vec<f32>>, Vec<f32>) {
    let clients = vec![0usize, 1, 2, 3, 4];
    let recons = vec![
        vec![0.10f32, -0.20, 0.30, 0.01, -0.05, 0.40],
        vec![0.12f32, -0.18, 0.28, 0.02, -0.04, 0.38],
        vec![0.08f32, -0.22, 0.33, 0.00, -0.06, 0.41],
        vec![0.11f32, -0.19, 0.31, 0.015, -0.045, 0.39],
        vec![2.50f32, 2.50, -2.50, 2.50, -2.50, 2.50], // outlier
    ];
    let weights = vec![1.0f32, 2.0, 1.0, 1.5, 1.0];
    (clients, recons, weights)
}

/// Apply `perm` to the batch: position `i` of the result holds what was
/// at position `perm[i]` — the same (client → recon, weight) map in a
/// different arrival order.
fn permute(
    perm: &[usize],
    clients: &[usize],
    recons: &[Vec<f32>],
    weights: &[f32],
) -> (Vec<usize>, Vec<Vec<f32>>, Vec<f32>) {
    (
        perm.iter().map(|&i| clients[i]).collect(),
        perm.iter().map(|&i| recons[i].clone()).collect(),
        perm.iter().map(|&i| weights[i]).collect(),
    )
}

fn assert_update_bits_equal(a: &AggOutcome, b: &AggOutcome, what: &str) {
    let (ua, ub) = (a.update.as_ref().unwrap(), b.update.as_ref().unwrap());
    assert_eq!(ua.len(), ub.len(), "{what}: length mismatch");
    for (j, (x, y)) in ua.iter().zip(ub.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: coord {j}: {x} vs {y}");
    }
}

#[test]
fn disabled_thresholds_degenerate_to_the_weighted_mean_bitwise() {
    let (clients, recons, weights) = batch();
    let want = WeightedMean.aggregate(&clients, &recons, &weights, 6);
    let disabled: Vec<(&str, Box<dyn RobustAggregator>)> = vec![
        ("trimmed beta=0", Box::new(TrimmedMean { beta: 0.0 })),
        ("krum f=0 m=0", Box::new(MultiKrum { f: 0, m: 0 })),
        ("clip tau=0", Box::new(NormClip { tau: 0.0 })),
    ];
    for (what, agg) in &disabled {
        let got = agg.aggregate(&clients, &recons, &weights, 6);
        assert_update_bits_equal(&got, &want, what);
        assert!(got.rejected.is_empty(), "{what}: rejected without a threshold");
        assert_eq!(got.trim_frac, 0.0, "{what}: trimmed without a threshold");
    }
}

#[test]
fn robust_estimators_are_permutation_invariant() {
    let (clients, recons, weights) = batch();
    // Every cyclic shift plus a hand-picked scramble: if arrival order
    // leaks anywhere, one of these catches it.
    let perms: Vec<Vec<usize>> = vec![
        vec![0, 1, 2, 3, 4],
        vec![4, 3, 2, 1, 0],
        vec![2, 4, 0, 3, 1],
        vec![1, 2, 3, 4, 0],
        vec![3, 0, 4, 1, 2],
    ];
    let estimators: Vec<(&str, Box<dyn RobustAggregator>)> = vec![
        ("trimmed beta=0.2", Box::new(TrimmedMean { beta: 0.2 })),
        ("median", Box::new(CoordinateMedian)),
        ("krum f=1", Box::new(MultiKrum { f: 1, m: 1 })),
        ("multi_krum f=1 m=3", Box::new(MultiKrum { f: 1, m: 3 })),
        ("clip tau=0.5", Box::new(NormClip { tau: 0.5 })),
    ];
    for (what, agg) in &estimators {
        let want = agg.aggregate(&clients, &recons, &weights, 6);
        for perm in &perms {
            let (pc, pr, pw) = permute(perm, &clients, &recons, &weights);
            let got = agg.aggregate(&pc, &pr, &pw, 6);
            assert_update_bits_equal(&got, &want, &format!("{what} perm {perm:?}"));
            assert_eq!(got.rejected, want.rejected, "{what} perm {perm:?}: rejected");
            assert_eq!(
                got.trim_frac.to_bits(),
                want.trim_frac.to_bits(),
                "{what} perm {perm:?}: trim_frac"
            );
        }
    }
}

#[test]
fn krum_breaks_score_ties_toward_the_lowest_client_index() {
    // Two identical pairs: within-pair distance 0, across-pair distance
    // 2, so with f=0 every candidate's neighbour sum ties at exactly the
    // same score. The winner must be client 0 — the lowest index — no
    // matter how the batch arrives.
    let clients = vec![0usize, 1, 2, 3];
    let recons = vec![
        vec![1.0f32, 0.0],
        vec![1.0f32, 0.0],
        vec![0.0f32, 1.0],
        vec![0.0f32, 1.0],
    ];
    let weights = vec![1.0f32; 4];
    let krum = MultiKrum { f: 0, m: 1 };
    for perm in [vec![0usize, 1, 2, 3], vec![3, 2, 1, 0], vec![2, 0, 3, 1]] {
        let (pc, pr, pw) = permute(&perm, &clients, &recons, &weights);
        let out = krum.aggregate(&pc, &pr, &pw, 2);
        let u = out.update.unwrap();
        assert_eq!(
            (u[0].to_bits(), u[1].to_bits()),
            (1.0f32.to_bits(), 0.0f32.to_bits()),
            "perm {perm:?} did not select client 0's recon"
        );
        assert_eq!(out.rejected, vec![1, 2, 3], "perm {perm:?}");
        assert!((out.trim_frac - 0.75).abs() < 1e-12);
    }
}

#[test]
fn weighted_median_follows_the_dominant_weight() {
    // One client holds more than half the total weight: the weighted
    // median is its value on every coordinate, wherever it sorts.
    let clients = vec![0usize, 1, 2];
    let recons = vec![vec![-1.0f32, 5.0], vec![0.0f32, -3.0], vec![1.0f32, 0.5]];
    let weights = vec![1.0f32, 4.0, 1.0];
    let out = CoordinateMedian.aggregate(&clients, &recons, &weights, 2);
    let u = out.update.unwrap();
    assert_eq!(u[0].to_bits(), 0.0f32.to_bits());
    assert_eq!(u[1].to_bits(), (-3.0f32).to_bits());
}
