//! Round-engine integration: client schedulers, server optimizers and
//! simnet-aware accounting composed into full experiments on the small
//! model — including the EF-persistence regression for skipped clients.
//!
//! Runs unconditionally on the native backend; the acceptance scenario
//! re-runs on pjrt when artifacts are available.

mod common;

use fed3sfc::config::{
    CompressorKind, DatasetKind, ExperimentConfig, NetworkKind, ScheduleKind, ServerOptKind,
};
use fed3sfc::coordinator::experiment::{Experiment, ExperimentBuilder};
use fed3sfc::runtime::Backend;

fn partial_cfg(schedule: ScheduleKind, frac: f64) -> ExperimentConfig {
    ExperimentConfig {
        dataset: DatasetKind::SynthSmall,
        compressor: CompressorKind::ThreeSfc,
        n_clients: 4,
        rounds: 8,
        k_local: 5,
        lr: 0.05,
        syn_steps: 10,
        train_samples: 320,
        test_samples: 100,
        eval_every: 8,
        seed: 42,
        schedule,
        client_frac: frac,
        ..ExperimentConfig::default()
    }
}

#[test]
fn uniform_schedule_is_deterministic_across_runs() {
    // Same seed → same selected set every round, and identical records.
    let be = common::native();
    let mut selections: Vec<Vec<Vec<usize>>> = Vec::new();
    let mut finals = Vec::new();
    for _ in 0..2 {
        let mut exp = Experiment::new(partial_cfg(ScheduleKind::Uniform, 0.5), &be).unwrap();
        let mut sel = Vec::new();
        for _ in 0..exp.cfg.rounds {
            let rec = exp.run_round().unwrap();
            assert_eq!(rec.n_selected, 2, "frac 0.5 of 4 clients");
            sel.push(exp.last_selected.clone());
        }
        selections.push(sel);
        finals.push(exp.metrics.last().unwrap().test_acc.to_bits());
    }
    assert_eq!(selections[0], selections[1]);
    assert_eq!(finals[0], finals[1]);
    // The schedule must actually vary across rounds (it is a sampler).
    let distinct: std::collections::BTreeSet<_> = selections[0].iter().cloned().collect();
    assert!(distinct.len() > 1, "uniform sampler never varied: {selections:?}");
}

#[test]
fn round_robin_covers_every_client_e2e() {
    let be = common::native();
    let mut exp = Experiment::new(partial_cfg(ScheduleKind::RoundRobin, 0.5), &be).unwrap();
    // ceil(1/0.5) = 2 rounds must cover all 4 clients.
    exp.run_round().unwrap();
    let first = exp.last_selected.clone();
    exp.run_round().unwrap();
    let mut seen = first;
    seen.extend(exp.last_selected.iter().copied());
    seen.sort_unstable();
    assert_eq!(seen, vec![0, 1, 2, 3]);
    assert!(exp.clients.participation_counts().iter().all(|&r| r == 1));
}

#[test]
fn skipped_clients_keep_error_feedback_untouched() {
    // Regression (3SFC + client_frac = 0.5): a skipped client's EF memory
    // must be bit-identical across the round, and must be consumed (i.e.
    // the memory changes) at its next participation.
    let be = common::native();
    let mut exp = Experiment::new(partial_cfg(ScheduleKind::Uniform, 0.5), &be).unwrap();
    let n = exp.clients.len();
    let mut pending_nonzero_ef: Vec<bool> = vec![false; n];
    let mut consumed_after_skip = 0usize;
    for _ in 0..20 {
        let before: Vec<Vec<f32>> = exp.clients.ef_snapshots();
        exp.run_round().unwrap();
        for id in 0..n {
            let selected = exp.last_selected.contains(&id);
            if !selected {
                assert_eq!(
                    exp.clients.ef_of(id),
                    before[id],
                    "client {id}: EF mutated while skipped"
                );
                if before[id].iter().any(|&v| v != 0.0) {
                    pending_nonzero_ef[id] = true;
                }
            } else {
                // EF update e ← target − ĝ ran; with a lossy compressor the
                // memory is (generically) rewritten every participation.
                if pending_nonzero_ef[id] && exp.clients.ef_of(id) != before[id] {
                    consumed_after_skip += 1;
                    pending_nonzero_ef[id] = false;
                }
            }
        }
    }
    assert!(
        consumed_after_skip > 0,
        "no client ever carried EF across a skip and consumed it"
    );
}

#[test]
fn partial_participation_halves_round_traffic() {
    let be = common::native();
    let full = Experiment::new(partial_cfg(ScheduleKind::Full, 1.0), &be)
        .unwrap()
        .run()
        .map(|recs| recs[0].up_bytes_round)
        .unwrap();
    let mut exp = Experiment::new(partial_cfg(ScheduleKind::Uniform, 0.5), &be).unwrap();
    let recs = exp.run().unwrap();
    // 3SFC payloads are fixed-size, so half the clients → half the bytes,
    // and the broadcast only reaches the selected clients.
    assert_eq!(recs[0].up_bytes_round * 2, full);
    // Broadcast framing is wire-symmetric with uploads: u32 header + 4P
    // per selected client.
    let params = exp.ops.model.params as u64;
    assert_eq!(
        exp.traffic().downlink_bytes,
        (4 + 4 * params) * 2 * exp.cfg.rounds as u64
    );
    // Modeled comm time is present and positive on every record.
    assert!(recs.iter().all(|r| r.comm_time_s > 0.0));
}

#[test]
fn server_optimizers_run_and_differ() {
    let be = common::native();
    let run = |opt: ServerOptKind, server_lr: f32| {
        let mut cfg = partial_cfg(ScheduleKind::Full, 1.0);
        cfg.server_opt = opt;
        cfg.server_lr = server_lr;
        cfg.eval_every = 1;
        let mut exp = Experiment::new(cfg, &be).unwrap();
        let recs = exp.run().unwrap();
        let last = recs.last().unwrap();
        assert!(last.test_loss.is_finite(), "{opt:?} diverged");
        last.test_acc
    };
    let gd = run(ServerOptKind::Gd, 1.0);
    let momentum = run(ServerOptKind::Momentum, 0.5);
    let fedadam = run(ServerOptKind::FedAdam, 0.01);
    assert!(gd > 0.15, "gd acc {gd} (chance = 0.125)");
    // Different server optimizers must change the trajectory.
    assert_ne!(gd.to_bits(), momentum.to_bits());
    assert_ne!(gd.to_bits(), fedadam.to_bits());
}

fn check_acceptance_scenario(backend: &dyn Backend) {
    // The issue's acceptance config: many clients, 10% uniform sampling,
    // FedAdam server optimizer, edge network — per-round comm_time_s out.
    let mut exp = ExperimentBuilder::new()
        .dataset(DatasetKind::SynthSmall)
        .compressor(CompressorKind::ThreeSfc)
        .clients(20)
        .rounds(4)
        .lr(0.05)
        .syn_steps(5)
        .train_samples(400)
        .test_samples(50)
        .eval_every(4)
        .schedule(ScheduleKind::Uniform)
        .client_frac(0.1)
        .server_opt(ServerOptKind::FedAdam)
        .server_lr(0.01)
        .network(NetworkKind::Edge)
        .build(backend)
        .unwrap();
    let recs = exp.run().unwrap();
    for r in &recs {
        assert_eq!(r.n_selected, 2, "10% of 20 clients");
        assert!(r.comm_time_s > 0.0);
        assert!(r.test_acc.is_finite());
    }
}

#[test]
fn acceptance_scenario_via_builder() {
    let be = common::native();
    check_acceptance_scenario(&be);
}

#[test]
fn pjrt_acceptance_scenario_via_builder() {
    let _g = common::lock();
    let Some(be) = common::pjrt() else { return };
    check_acceptance_scenario(be.as_ref());
}
