//! Figure 1: convergence rate degrades as the top-k compression rate
//! shrinks (MLP on non-i.i.d. MNIST-like data, 20 clients).
//!
//! Regenerates the paper's motivation plot: test accuracy per round for
//! top-k at rates {1 (FedAvg), 0.1, 0.01, 0.001}.
//!
//! Scale knobs (env): ROUNDS (default 6), CLIENTS (8), TRAIN (800),
//! THREADS (0 = all cores; 1 = sequential). Doubling as the
//! round-throughput benchmark (EXPERIMENTS.md §Perf): run with
//! `CLIENTS=100 THREADS=1` and `CLIENTS=100 THREADS=0` and compare the
//! reported rounds/s — trajectories are bit-identical, only wall clock
//! changes.

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{BackendKind, CompressorKind, DatasetKind, DownlinkKind, SessionKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 6);
    let clients = env_usize("CLIENTS", 8);
    let train = env_usize("TRAIN", 800);
    let threads = env_usize("THREADS", 0);
    // PJRT when artifacts exist, native otherwise (FED3SFC_BACKEND pins).
    let backend = open_backend_kind(BackendKind::Auto)?;

    println!(
        "== Figure 1: top-k rate vs convergence (MLP, non-iid synth-MNIST, {clients} clients, {} backend) ==",
        backend.backend_name()
    );
    let rates = [1.0f64, 0.1, 0.01, 0.001];
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    let mut wall_total_ms = 0.0f64;
    let mut rounds_total = 0usize;
    let mut threads_used = 1;
    for &rate in &rates {
        let method = if rate >= 1.0 { CompressorKind::FedAvg } else { CompressorKind::Dgc };
        let mut exp = Experiment::builder()
            .name(format!("fig1-rate{rate}"))
            .dataset(DatasetKind::SynthMnist)
            .compressor(method)
            .topk_rate(rate)
            .clients(clients)
            .rounds(rounds)
            .train_samples(train)
            .test_samples(500)
            .lr(0.05)
            .eval_every(1)
            .threads(threads)
            .build(backend.as_ref())?;
        threads_used = exp.threads();
        let recs = exp.run()?;
        let wall_ms: f64 = recs.iter().map(|r| r.wall_ms).sum();
        wall_total_ms += wall_ms;
        rounds_total += recs.len();
        println!(
            "rate {rate:>6}: final acc {:.4}  (ratio {:.0}x)  {:.0} ms/round",
            recs.last().unwrap().test_acc,
            recs.last().unwrap().ratio,
            wall_ms / recs.len() as f64,
        );
        series.push((
            format!("rate={rate}"),
            recs.iter().map(|r| r.test_acc).collect(),
        ));
    }
    println!(
        "\nround throughput: {:.3} rounds/s over {} rounds with {} thread(s) \
         ({:.0} ms/round mean; compare THREADS=1 vs THREADS=0)",
        1e3 * rounds_total as f64 / wall_total_ms,
        rounds_total,
        threads_used,
        wall_total_ms / rounds_total as f64,
    );

    println!("\nper-round accuracy series (paper Fig 1 y-axis):");
    let t = Table::new(&[8, 12, 12, 12, 12]);
    t.row(&[
        "round".into(),
        series[0].0.clone(),
        series[1].0.clone(),
        series[2].0.clone(),
        series[3].0.clone(),
    ]);
    t.sep();
    for r in 0..rounds {
        t.row(&[
            format!("{}", r + 1),
            format!("{:.4}", series[0].1[r]),
            format!("{:.4}", series[1].1[r]),
            format!("{:.4}", series[2].1[r]),
            format!("{:.4}", series[3].1[r]),
        ]);
    }
    println!("\nexpected shape: lower rate => slower convergence (paper Fig 1).");

    // -----------------------------------------------------------------
    // Session-mode extension: sync vs deadline vs async time-to-accuracy
    // on the edge preset (±50% per-client bandwidth jitter). Each policy
    // runs the same number of aggregation steps; the table reports the
    // modeled virtual time to reach a shared loss target (the loosest
    // final loss across the three runs, so every row is reachable).
    println!(
        "\n== session modes: virtual time-to-loss on the jittery edge link \
         ({clients} clients, top-k 0.01) =="
    );
    let modes = [SessionKind::Sync, SessionKind::Deadline, SessionKind::Async];
    let mut runs: Vec<(SessionKind, Vec<fed3sfc::RoundRecord>)> = Vec::new();
    for mode in modes {
        let mut exp = Experiment::builder()
            .name(format!("fig1-session-{}", mode.name()))
            .dataset(DatasetKind::SynthMnist)
            .compressor(CompressorKind::Dgc)
            .topk_rate(0.01)
            .clients(clients)
            .rounds(rounds)
            .train_samples(train)
            .test_samples(500)
            .lr(0.05)
            .eval_every(1)
            .threads(threads)
            .jitter(0.5)
            .session(mode)
            .deadline_s(0.15)
            .buffer_k(clients.div_ceil(2).max(1))
            .staleness_decay(0.5)
            .build(backend.as_ref())?;
        let recs = exp.run()?;
        runs.push((mode, recs));
    }
    let target = runs
        .iter()
        .map(|(_, recs)| recs.last().unwrap().test_loss)
        .fold(f64::MIN, f64::max);
    println!("loss target: {target:.4} (loosest final loss across modes)");
    let t = Table::new(&[10, 12, 14, 12, 12]);
    t.row(&[
        "session".into(),
        "steps->tgt".into(),
        "vtime->tgt (s)".into(),
        "final acc".into(),
        "stale mean".into(),
    ]);
    t.sep();
    for (mode, recs) in &runs {
        let hit = recs.iter().find(|r| r.test_loss <= target);
        let stale: f64 =
            recs.iter().map(|r| r.stale_mean).sum::<f64>() / recs.len() as f64;
        let (steps_col, vtime_col) = match hit {
            Some(r) => (format!("{}", r.round), format!("{:.2}", r.sim_time_s)),
            None => ("-".into(), "-".into()),
        };
        t.row(&[
            mode.name().into(),
            steps_col,
            vtime_col,
            format!("{:.4}", recs.last().unwrap().test_acc),
            format!("{:.2}", stale),
        ]);
    }
    println!(
        "\nexpected shape: the barrier pays the slowest straggler every step, so \
         deadline/async reach the target in less virtual time on jittery links \
         (at the cost of staleness)."
    );

    // -----------------------------------------------------------------
    // Downlink extension (EXPERIMENTS.md §Downlink): the same workload
    // with the broadcast direction compressed too. Every run does the
    // same number of rounds; the table reports exact wire bytes per
    // direction and the total saving vs the dense-broadcast baseline
    // (identity row — bit-identical to the classic path).
    println!(
        "\n== downlink compression: both-way traffic at equal rounds \
         ({clients} clients, uplink = top-k 0.01) =="
    );
    let kinds = [DownlinkKind::Identity, DownlinkKind::TopK, DownlinkKind::ThreeSfc];
    let mut dense_total = 0u64;
    let t = Table::new(&[10, 14, 14, 14, 10, 12, 12]);
    t.row(&[
        "downlink".into(),
        "up B".into(),
        "down B".into(),
        "total B".into(),
        "saved".into(),
        "final acc".into(),
        "final loss".into(),
    ]);
    t.sep();
    for kind in kinds {
        let mut exp = Experiment::builder()
            .name(format!("fig1-downlink-{}", kind.name()))
            .dataset(DatasetKind::SynthMnist)
            .compressor(CompressorKind::Dgc)
            .topk_rate(0.01)
            .clients(clients)
            .rounds(rounds)
            .train_samples(train)
            .test_samples(500)
            .lr(0.05)
            .eval_every(1)
            .threads(threads)
            .downlink(kind)
            .downlink_rate(0.01) // top-k/STC only; 3SFC sizes by syn budget
            .build(backend.as_ref())?;
        let recs = exp.run()?;
        let tr = exp.traffic();
        let total = tr.total_bytes();
        if kind == DownlinkKind::Identity {
            dense_total = total;
        }
        let saved = 100.0 * (1.0 - total as f64 / dense_total as f64);
        let last = recs.last().unwrap();
        t.row(&[
            kind.name().into(),
            format!("{}", tr.uplink_bytes),
            format!("{}", tr.downlink_bytes),
            format!("{total}"),
            format!("{saved:.1}%"),
            format!("{:.4}", last.test_acc),
            format!("{:.4}", last.test_loss),
        ]);
    }
    println!(
        "\nexpected shape: with the uplink already sparse, dense broadcasts dominate \
         the wire; compressing them drops total (up + down) bytes well past the 40% \
         acceptance bar at equal rounds, with the identity row unchanged bit-for-bit."
    );
    Ok(())
}
