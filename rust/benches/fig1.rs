//! Figure 1: convergence rate degrades as the top-k compression rate
//! shrinks (MLP on non-i.i.d. MNIST-like data, 20 clients).
//!
//! Regenerates the paper's motivation plot: test accuracy per round for
//! top-k at rates {1 (FedAvg), 0.1, 0.01, 0.001}.
//!
//! Scale knobs (env): ROUNDS (default 12), CLIENTS (20), TRAIN (2000).

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 6);
    let clients = env_usize("CLIENTS", 8);
    let train = env_usize("TRAIN", 800);
    let rt = Runtime::open(&fed3sfc::artifacts_dir())?;

    println!("== Figure 1: top-k rate vs convergence (MLP, non-iid synth-MNIST, {clients} clients) ==");
    let rates = [1.0f64, 0.1, 0.01, 0.001];
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for &rate in &rates {
        let method = if rate >= 1.0 { CompressorKind::FedAvg } else { CompressorKind::Dgc };
        let mut exp = Experiment::builder()
            .name(format!("fig1-rate{rate}"))
            .dataset(DatasetKind::SynthMnist)
            .compressor(method)
            .topk_rate(rate)
            .clients(clients)
            .rounds(rounds)
            .train_samples(train)
            .test_samples(500)
            .lr(0.05)
            .eval_every(1)
            .build(&rt)?;
        let recs = exp.run()?;
        println!(
            "rate {rate:>6}: final acc {:.4}  (ratio {:.0}x)",
            recs.last().unwrap().test_acc,
            recs.last().unwrap().ratio
        );
        series.push((
            format!("rate={rate}"),
            recs.iter().map(|r| r.test_acc).collect(),
        ));
    }

    println!("\nper-round accuracy series (paper Fig 1 y-axis):");
    let t = Table::new(&[8, 12, 12, 12, 12]);
    t.row(&[
        "round".into(),
        series[0].0.clone(),
        series[1].0.clone(),
        series[2].0.clone(),
        series[3].0.clone(),
    ]);
    t.sep();
    for r in 0..rounds {
        t.row(&[
            format!("{}", r + 1),
            format!("{:.4}", series[0].1[r]),
            format!("{:.4}", series[1].1[r]),
            format!("{:.4}", series[2].1[r]),
            format!("{:.4}", series[3].1[r]),
        ]);
    }
    println!("\nexpected shape: lower rate => slower convergence (paper Fig 1).");
    Ok(())
}
