//! Figure 1: convergence rate degrades as the top-k compression rate
//! shrinks (MLP on non-i.i.d. MNIST-like data, 20 clients).
//!
//! Regenerates the paper's motivation plot: test accuracy per round for
//! top-k at rates {1 (FedAvg), 0.1, 0.01, 0.001}.
//!
//! Scale knobs (env): ROUNDS (default 6), CLIENTS (8), TRAIN (800),
//! THREADS (0 = all cores; 1 = sequential). Doubling as the
//! round-throughput benchmark (EXPERIMENTS.md §Perf): run with
//! `CLIENTS=100 THREADS=1` and `CLIENTS=100 THREADS=0` and compare the
//! reported rounds/s — trajectories are bit-identical, only wall clock
//! changes.

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{BackendKind, CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 6);
    let clients = env_usize("CLIENTS", 8);
    let train = env_usize("TRAIN", 800);
    let threads = env_usize("THREADS", 0);
    // PJRT when artifacts exist, native otherwise (FED3SFC_BACKEND pins).
    let backend = open_backend_kind(BackendKind::Auto)?;

    println!(
        "== Figure 1: top-k rate vs convergence (MLP, non-iid synth-MNIST, {clients} clients, {} backend) ==",
        backend.backend_name()
    );
    let rates = [1.0f64, 0.1, 0.01, 0.001];
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    let mut wall_total_ms = 0.0f64;
    let mut rounds_total = 0usize;
    let mut threads_used = 1;
    for &rate in &rates {
        let method = if rate >= 1.0 { CompressorKind::FedAvg } else { CompressorKind::Dgc };
        let mut exp = Experiment::builder()
            .name(format!("fig1-rate{rate}"))
            .dataset(DatasetKind::SynthMnist)
            .compressor(method)
            .topk_rate(rate)
            .clients(clients)
            .rounds(rounds)
            .train_samples(train)
            .test_samples(500)
            .lr(0.05)
            .eval_every(1)
            .threads(threads)
            .build(backend.as_ref())?;
        threads_used = exp.threads();
        let recs = exp.run()?;
        let wall_ms: f64 = recs.iter().map(|r| r.wall_ms).sum();
        wall_total_ms += wall_ms;
        rounds_total += recs.len();
        println!(
            "rate {rate:>6}: final acc {:.4}  (ratio {:.0}x)  {:.0} ms/round",
            recs.last().unwrap().test_acc,
            recs.last().unwrap().ratio,
            wall_ms / recs.len() as f64,
        );
        series.push((
            format!("rate={rate}"),
            recs.iter().map(|r| r.test_acc).collect(),
        ));
    }
    println!(
        "\nround throughput: {:.3} rounds/s over {} rounds with {} thread(s) \
         ({:.0} ms/round mean; compare THREADS=1 vs THREADS=0)",
        1e3 * rounds_total as f64 / wall_total_ms,
        rounds_total,
        threads_used,
        wall_total_ms / rounds_total as f64,
    );

    println!("\nper-round accuracy series (paper Fig 1 y-axis):");
    let t = Table::new(&[8, 12, 12, 12, 12]);
    t.row(&[
        "round".into(),
        series[0].0.clone(),
        series[1].0.clone(),
        series[2].0.clone(),
        series[3].0.clone(),
    ]);
    t.sep();
    for r in 0..rounds {
        t.row(&[
            format!("{}", r + 1),
            format!("{:.4}", series[0].1[r]),
            format!("{:.4}", series[1].1[r]),
            format!("{:.4}", series[2].1[r]),
            format!("{:.4}", series[3].1[r]),
        ]);
    }
    println!("\nexpected shape: lower rate => slower convergence (paper Fig 1).");
    Ok(())
}
