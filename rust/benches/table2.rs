//! Table 2: the paper's main grid — test accuracy (and compression ratio)
//! for FedAvg / DGC / signSGD / STC / 3SFC across all dataset+model pairs.
//!
//! DGC is budget-matched to 3SFC (paper's protocol); signSGD/STC run at
//! their natural 32×. Client counts via CLIENTS (default 10; paper runs
//! 10/20/40 — pass CLIENTS=20 etc. to regenerate those panels).
//!
//! Scale knobs: ROUNDS (8), CLIENTS (10), TRAIN (1200), PAIRS (all|mlp).

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{BackendKind, CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn pairs(which: &str) -> Vec<(&'static str, DatasetKind, &'static str)> {
    let mlp = vec![
        ("MNIST+MLP", DatasetKind::SynthMnist, "mlp10"),
        ("EMNIST+MLP", DatasetKind::SynthEmnist, "mlp26"),
        ("FMNIST+MLP", DatasetKind::SynthFmnist, "mlp10"),
    ];
    if which == "mlp" {
        return mlp;
    }
    let mut all = mlp;
    all.extend([
        ("FMNIST+Mnistnet", DatasetKind::SynthFmnist, "mnistnet"),
        ("Cifar10+ConvNet", DatasetKind::SynthCifar10, "convnet"),
        ("Cifar10+ResNet", DatasetKind::SynthCifar10, "resnet8_c10"),
        ("Cifar10+RegNet", DatasetKind::SynthCifar10, "regnet_c10"),
        ("Cifar100+ResNet", DatasetKind::SynthCifar100, "resnet8_c20"),
        ("Cifar100+RegNet", DatasetKind::SynthCifar100, "regnet_c20"),
    ]);
    all
}

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 5);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 700);
    // FRAC (percent) reruns the grid under uniform partial participation.
    let frac = (env_usize("FRAC", 100) as f64 / 100.0).clamp(0.01, 1.0);
    let which = std::env::var("PAIRS").unwrap_or_else(|_| "mlp".into());
    let rt = open_backend_kind(BackendKind::Auto)?;

    let methods = [
        CompressorKind::FedAvg,
        CompressorKind::Dgc,
        CompressorKind::SignSgd,
        CompressorKind::Stc,
        CompressorKind::ThreeSfc,
    ];

    println!("== Table 2: accuracy x compression ratio ({clients} clients, {rounds} rounds) ==\n");
    let t = Table::new(&[18, 20, 20, 20, 20, 20]);
    let mut header = vec!["Dataset+Model".to_string()];
    header.extend(methods.iter().map(|m| m.name().to_string()));
    t.row(&header);
    t.sep();

    for (label, ds, model) in pairs(&which) {
        let mut cells = vec![label.to_string()];
        if rt.manifest().model(model).is_err() {
            cells.push(format!("(needs pjrt: {model})"));
            while cells.len() < methods.len() + 1 {
                cells.push("-".into());
            }
            t.row(&cells);
            continue;
        }
        for method in methods {
            // client_frac < 1 implies uniform sampling (effective_schedule).
            let mut exp = Experiment::builder()
                .name(format!("t2-{label}-{}", method.name()))
                .dataset(ds)
                .model(model)
                .compressor(method)
                .clients(clients)
                .rounds(rounds)
                .train_samples(train)
                .test_samples(300)
                .lr(0.05)
                .eval_every(rounds)
                .syn_steps(20)
                .client_frac(frac)
                .build(rt.as_ref())?;
            let recs = exp.run()?;
            let last = recs.last().unwrap();
            cells.push(format!("{:.4} ({:.0}x)", last.test_acc, last.ratio));
        }
        t.row(&cells);
    }
    println!("\nexpected shape (paper Table 2): 3SFC >= DGC at the same (high) ratio;");
    println!("3SFC competitive with STC/signSGD while communicating far less.");
    Ok(())
}
