//! Table 4: 3SFC ablation — error feedback on/off, budget B/2B/4B, local
//! iterations K ∈ {1, 5, 10}.
//!
//! Scale knobs: ROUNDS (10), CLIENTS (10), TRAIN (1200), PAIRS (mlp|all).

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{BackendKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

struct Variant {
    label: &'static str,
    ef: bool,
    budget: usize,
    k: usize,
}

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 5);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 700);
    let which = std::env::var("PAIRS").unwrap_or_else(|_| "mlp".into());
    let rt = open_backend_kind(BackendKind::Auto)?;

    let variants = [
        Variant { label: "3SFC w/ EF (base)", ef: true, budget: 1, k: 5 },
        Variant { label: "3SFC w/o EF", ef: false, budget: 1, k: 5 },
        Variant { label: "3SFC w/ EF (2xB)", ef: true, budget: 2, k: 5 },
        Variant { label: "3SFC w/ EF (4xB)", ef: true, budget: 4, k: 5 },
        Variant { label: "3SFC w/ EF (K=1)", ef: true, budget: 1, k: 1 },
        Variant { label: "3SFC w/ EF (K=10)", ef: true, budget: 1, k: 10 },
    ];

    let mut pairs: Vec<(&str, DatasetKind, &str)> = vec![
        ("MNIST+MLP", DatasetKind::SynthMnist, "mlp10"),
        ("EMNIST+MLP", DatasetKind::SynthEmnist, "mlp26"),
        ("FMNIST+MLP", DatasetKind::SynthFmnist, "mlp10"),
    ];
    if which == "all" {
        pairs.extend([
            ("FMNIST+Mnistnet", DatasetKind::SynthFmnist, "mnistnet"),
            ("Cifar10+ConvNet", DatasetKind::SynthCifar10, "convnet"),
            ("Cifar10+ResNet", DatasetKind::SynthCifar10, "resnet8_c10"),
            ("Cifar100+RegNet", DatasetKind::SynthCifar100, "regnet_c20"),
        ]);
    }

    println!("== Table 4: 3SFC ablation ({clients} clients, {rounds} rounds) ==\n");
    let mut widths = vec![20usize];
    widths.extend(std::iter::repeat(18).take(pairs.len()));
    let t = Table::new(&widths);
    let mut header = vec!["Variant".to_string()];
    header.extend(pairs.iter().map(|p| p.0.to_string()));
    t.row(&header);
    t.sep();

    for v in &variants {
        let mut cells = vec![v.label.to_string()];
        for (label, ds, model) in &pairs {
            if rt.manifest().model(model).is_err() {
                cells.push("(needs pjrt)".into());
                continue;
            }
            let mut exp = Experiment::builder()
                .name(format!("t4-{label}-{}", v.label))
                .dataset(*ds)
                .model(*model)
                .error_feedback(v.ef)
                .budget_mult(v.budget)
                .k_local(v.k)
                .clients(clients)
                .rounds(rounds)
                .train_samples(train)
                .test_samples(300)
                .lr(0.05)
                .eval_every(rounds)
                .syn_steps(20)
                .build(rt.as_ref())?;
            let recs = exp.run()?;
            cells.push(format!("{:.4}", recs.last().unwrap().test_acc));
        }
        t.row(&cells);
    }
    println!("\nexpected shape (paper Table 4): w/o EF degrades sharply; 2xB/4xB and K=10 improve; K=1 degrades.");
    Ok(())
}
