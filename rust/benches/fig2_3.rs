//! Figures 2 & 3: why multi-step L2 distillation (FedSynth) fails and
//! single-step similarity (3SFC) does not.
//!
//! Fig 2 — fitting progress: FedSynth fit loss ‖Δw_sim − g‖² per outer
//!   step for K_sim ∈ {1, 4, 8, 16} vs 3SFC's |cos| trajectory.
//! Fig 3 — per-step gradient magnitudes of the FedSynth unroll: the
//!   backward (step K → step 1) growth that precedes the collapse.
//!
//! Scale knobs: STEPS (default 25).

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::BackendKind;
use fed3sfc::runtime::{open_backend_kind, Backend, FedOps};
use fed3sfc::util::rng::Rng;
use fed3sfc::util::vecmath;

fn main() -> anyhow::Result<()> {
    let steps = env_usize("STEPS", 15);
    let rt = open_backend_kind(BackendKind::Auto)?;
    let ops = FedOps::new(rt.as_ref(), "mlp_small")?;
    let model = ops.model;
    let w = rt.load_init(model)?;

    // Fixed target: a genuine K=5 local-training delta.
    let mut rng = Rng::new(42);
    let mut xs = vec![0.0f32; 5 * model.train_batch * model.feature_len()];
    rng.fill_normal(&mut xs, 1.0);
    let ys: Vec<i32> = (0..5 * model.train_batch)
        .map(|i| (i % model.n_classes) as i32)
        .collect();
    let w_local = ops.local_train(5, &w, &xs, &ys, 0.05)?;
    let target = vecmath::sub(&w, &w_local);
    let tnorm = vecmath::norm2(&target);

    println!("== Figure 2: fitting a fixed local delta (mlp_small, {steps} outer steps) ==");
    println!("(normalized fit = ||sim - g||^2 / ||g||^2 ; lower is better)\n");

    let depths = [1usize, 4, 8, 16];
    let mut fed_series: Vec<(usize, Vec<f64>, Vec<f32>)> = Vec::new();
    for &k in &depths {
        let mut dxs = vec![0.0f32; k * model.feature_len()];
        let mut r = Rng::new(7).split(k as u64);
        r.fill_normal(&mut dxs, 0.5);
        let mut dys = vec![0.0f32; k * model.n_classes];
        let mut fits = Vec::new();
        let mut norms = Vec::new();
        for _ in 0..steps {
            let (ndxs, ndys, fit, stepnorms) =
                ops.fedsynth_step(k, 1, &w, &target, &dxs, &dys, 0.05, 0.5)?;
            dxs = ndxs;
            dys = ndys;
            fits.push(fit as f64 / tnorm);
            norms = stepnorms;
        }
        fed_series.push((k, fits, norms));
    }

    // 3SFC similarity fitting (single simulation step).
    let mut dx = vec![0.0f32; model.feature_len()];
    let mut r = Rng::new(9);
    r.fill_normal(&mut dx, 0.5);
    let mut dy = vec![0.0f32; model.n_classes];
    let mut coses = Vec::new();
    for _ in 0..steps {
        let (ndx, ndy, cos) = ops.syn_step(1, &w, &target, &dx, &dy, 5.0, 0.0)?;
        dx = ndx;
        dy = ndy;
        coses.push(cos.abs() as f64);
    }
    // Final 3SFC normalized fit with the optimal (Eq. 8) scale:
    let g = ops.syn_grad(1, &w, &dx, &dy)?;
    let s = (vecmath::dot(&target, &g) / vecmath::norm2(&g).max(1e-30)) as f32;
    let mut recon = g;
    vecmath::scale_assign(&mut recon, s);
    let resid = vecmath::sub(&recon, &target);
    let fit_3sfc = vecmath::norm2(&resid) / tnorm;

    let t = Table::new(&[6, 14, 14, 14, 14, 12]);
    t.row(&[
        "step".into(),
        "fedsynth K=1".into(),
        "fedsynth K=4".into(),
        "fedsynth K=8".into(),
        "fedsynth K=16".into(),
        "3sfc |cos|".into(),
    ]);
    t.sep();
    for i in 0..steps {
        t.row(&[
            format!("{}", i + 1),
            format!("{:.4}", fed_series[0].1[i]),
            format!("{:.4}", fed_series[1].1[i]),
            format!("{:.4}", fed_series[2].1[i]),
            format!("{:.4}", fed_series[3].1[i]),
            format!("{:.4}", coses[i]),
        ]);
    }
    println!("\n3SFC final normalized fit (with Eq.8 scale): {fit_3sfc:.4}");
    println!("expected shape: deeper unrolls fit slower / less stably (Fig 2).");

    println!("\n== Figure 3: per-step grad magnitude of the FedSynth unroll ==");
    println!("(||dfit/d dxs[j]||, j = simulation step; backprop runs K -> 1)\n");
    println!("-- at the bench inner lr (0.05): mild compounding --");
    for (k, _, norms) in &fed_series {
        let cells: Vec<String> = norms.iter().map(|n| format!("{n:.2e}")).collect();
        println!("K={k:<3} [{}]", cells.join(", "));
        if *k >= 4 {
            let grow = norms.first().unwrap() / norms.last().unwrap().max(1e-30);
            println!("      step1/stepK magnitude ratio = {grow:.2}");
        }
    }
    // The paper's Fig 3 regime: significant per-step updates compound
    // through the unroll and the backward pass amplifies toward step 1.
    // Averaged over random inits (single draws are noisy at m=1).
    println!("\n-- at an aggressive inner lr (0.5), mean over 8 inits: the explosion regime --");
    let reps = 8u64;
    for &k in &depths {
        let mut acc = vec![0.0f64; k];
        for rep in 0..reps {
            let mut dxs = vec![0.0f32; k * model.feature_len()];
            let mut r = Rng::new(17 + rep).split(k as u64);
            r.fill_normal(&mut dxs, 0.5);
            let dys = vec![0.0f32; k * model.n_classes];
            let (_, _, _, norms) =
                ops.fedsynth_step(k, 1, &w, &target, &dxs, &dys, 0.5, 0.5)?;
            for (a, n) in acc.iter_mut().zip(norms.iter()) {
                *a += *n as f64 / reps as f64;
            }
        }
        let cells: Vec<String> = acc.iter().map(|n| format!("{n:.2e}")).collect();
        println!("K={k:<3} [{}]", cells.join(", "));
        if k >= 4 {
            let half = k / 2;
            let early: f64 = acc[..half].iter().sum::<f64>() / half as f64;
            let late: f64 = acc[half..].iter().sum::<f64>() / (k - half) as f64;
            println!(
                "      mean |grad| first-half/second-half = {:.2}  (paper Fig 3: grows toward step 1)",
                early / late.max(1e-30)
            );
        }
    }
    Ok(())
}
