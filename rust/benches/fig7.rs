//! Figure 7: per-round compression efficiency — cosine similarity between
//! the reconstructed and EF-corrected gradients — for 3SFC vs DGC at the
//! SAME compression rate, with FedAvg (≡ 1.0) as reference.
//!
//! Scale knobs: ROUNDS (15), CLIENTS (10), TRAIN (1500).

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{BackendKind, CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 8);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 800);
    let rt = open_backend_kind(BackendKind::Auto)?;
    println!("backend: {}", rt.backend_name());

    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for method in [
        CompressorKind::ThreeSfc,
        CompressorKind::Dgc, // budget-matched to 3SFC by default
        CompressorKind::FedAvg,
    ] {
        let mut exp = Experiment::builder()
            .name(format!("fig7-{}", method.name()))
            .dataset(DatasetKind::SynthMnist)
            .compressor(method)
            .clients(clients)
            .rounds(rounds)
            .train_samples(train)
            .test_samples(200)
            .lr(0.05)
            .eval_every(rounds) // efficiency is the point here
            .syn_steps(40)
            .build(rt.as_ref())?;
        let recs = exp.run()?;
        series.push((
            method.name().to_string(),
            recs.iter().map(|r| r.efficiency).collect(),
        ));
    }

    println!("== Figure 7: compression efficiency per round (equal rate for 3SFC and DGC) ==\n");
    let t = Table::new(&[8, 12, 12, 12]);
    t.row(&[
        "round".into(),
        "3sfc".into(),
        "dgc".into(),
        "fedavg".into(),
    ]);
    t.sep();
    for r in 0..rounds {
        t.row(&[
            format!("{}", r + 1),
            format!("{:.4}", series[0].1[r]),
            format!("{:.4}", series[1].1[r]),
            format!("{:.4}", series[2].1[r]),
        ]);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "\nmean efficiency: 3sfc {:.4}  dgc {:.4}  fedavg {:.4}",
        mean(&series[0].1),
        mean(&series[1].1),
        mean(&series[2].1)
    );
    println!("expected shape: 3sfc > dgc every round; both decay as EF mass accumulates (Fig 7).");
    Ok(())
}
