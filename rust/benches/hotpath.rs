//! Hot-path microbenchmarks + the persistent bench-trajectory harness
//! (EXPERIMENTS.md §Perf).
//!
//! Sections:
//! * L3 host paths — top-k selection, axpy/EF accumulation, cosine
//!   metric, aggregation (the agg buffer is preallocated and `fill(0.0)`
//!   per iteration, so the number measures the kernel, not the
//!   allocator);
//! * GEMM kernels — naive oracle vs the register-blocked kernels at
//!   mlp10 shapes (the before/after table in EXPERIMENTS.md);
//! * backend op paths — local_train / syn_step / syn_grad / eval on
//!   mlp10 (the paper-scale MLP).
//!
//! On the native backend the run is appended to the trajectory record:
//! per-op median/p95 ns land in `BENCH_hotpath.json` at the repo root
//! (override with `FED3SFC_BENCH_OUT`), and when a *calibrated* baseline
//! exists at `FED3SFC_BENCH_BASELINE` (default: the committed JSON) any
//! op slower than `FED3SFC_BENCH_MAX_REGRESSION`× (default 3×) its
//! baseline median fails the run — the CI perf-smoke job is exactly this
//! invocation.

use fed3sfc::bench::{
    bench_json, parse_bench_json, regressions, report, time_it, BenchRecord, Timing,
};
use fed3sfc::config::BackendKind;
use fed3sfc::runtime::{kernels, open_backend_kind, Backend, FedOps};
use fed3sfc::util::rng::Rng;
use fed3sfc::util::vecmath;

fn main() -> anyhow::Result<()> {
    let rt = open_backend_kind(BackendKind::Auto)?;
    let ops = FedOps::new(rt.as_ref(), "mlp10")?;
    let model = ops.model;
    let n = model.params;
    println!(
        "== hot-path microbenchmarks (P = {n}, {} backend) ==\n",
        rt.backend_name()
    );

    let mut records: Vec<BenchRecord> = Vec::new();
    let mut record = |name: &str, t: &Timing| {
        report(name, t);
        records.push(BenchRecord::new(name, t));
    };

    let mut rng = Rng::new(1);
    let mut g = vec![0.0f32; n];
    rng.fill_normal(&mut g, 0.01);
    let mut ef = vec![0.0f32; n];

    println!("-- L3 host paths --");
    record(
        "topk_dgc_select",
        &time_it(3, 20, || {
            std::hint::black_box(vecmath::topk_indices(&g, n / 250));
        }),
    );
    record(
        "axpy_ef",
        &time_it(3, 50, || {
            vecmath::axpy(1.0, &g, &mut ef);
        }),
    );
    record(
        "cosine_metric",
        &time_it(3, 50, || {
            std::hint::black_box(vecmath::cosine(&g, &ef));
        }),
    );
    // Preallocated accumulator: the measured closure must time the
    // weighted-add kernel, not a fresh `vec![0.0; n]` per iteration.
    let mut agg = vec![0.0f32; n];
    record(
        "weighted_agg_10",
        &time_it(3, 20, || {
            agg.fill(0.0);
            for _ in 0..10 {
                vecmath::weighted_add(&mut agg, &g, 0.1);
            }
            std::hint::black_box(&agg);
        }),
    );

    // GEMM microkernels at mlp10 shapes (d=784, h=250, B=32): naive
    // oracle vs the register-blocked kernels — the §Perf kernel table.
    println!("\n-- GEMM kernels (naive vs tiled, mlp10 shapes) --");
    let (bm, kd, kh) = (32usize, 784usize, 250usize);
    let mut ka = vec![0.0f32; bm * kd];
    let mut kb = vec![0.0f32; kd * kh];
    rng.fill_normal(&mut ka, 1.0);
    rng.fill_normal(&mut kb, 0.1);
    let mut kout = vec![0.0f32; bm * kh];
    record(
        "kern_mm_naive",
        &time_it(2, 12, || {
            kernels::naive::mm(&ka, &kb, bm, kd, kh, &mut kout);
        }),
    );
    record(
        "kern_mm_tiled",
        &time_it(2, 12, || {
            kernels::mm(&ka, &kb, bm, kd, kh, &mut kout);
        }),
    );
    // aᵀ·b at the gW1 shape: [B×d]ᵀ·[B×h] → [d×h].
    let mut kdz = vec![0.0f32; bm * kh];
    rng.fill_normal(&mut kdz, 0.1);
    let mut kgw = vec![0.0f32; kd * kh];
    record(
        "kern_mm_at_naive",
        &time_it(2, 12, || {
            kernels::naive::mm_at_acc(&ka, &kdz, bm, kd, kh, &mut kgw);
        }),
    );
    record(
        "kern_mm_at_tiled",
        &time_it(2, 12, || {
            kernels::mm_at_acc(&ka, &kdz, bm, kd, kh, &mut kgw);
        }),
    );
    // a·bᵀ at the gx shape: [B×h]·[d×h]ᵀ → [B×d].
    let mut kw1 = vec![0.0f32; kd * kh];
    rng.fill_normal(&mut kw1, 0.1);
    let mut kgx = vec![0.0f32; bm * kd];
    record(
        "kern_mm_bt_naive",
        &time_it(2, 12, || {
            kernels::naive::mm_bt_acc(&kdz, &kw1, bm, kh, kd, &mut kgx);
        }),
    );
    record(
        "kern_mm_bt_tiled",
        &time_it(2, 12, || {
            kernels::mm_bt_acc(&kdz, &kw1, bm, kh, kd, &mut kgx);
        }),
    );

    println!("\n-- backend paths ({}, mlp10) --", rt.backend_name());
    let w = rt.load_init(model)?;
    let k = 5;
    let b = model.train_batch;
    let mut xs = vec![0.0f32; k * b * model.feature_len()];
    rng.fill_normal(&mut xs, 1.0);
    let ys: Vec<i32> = (0..k * b).map(|i| (i % model.n_classes) as i32).collect();
    record(
        "local_train_k5",
        &time_it(2, 10, || {
            std::hint::black_box(ops.local_train(k, &w, &xs, &ys, 0.05).unwrap());
        }),
    );

    let target = {
        let wl = ops.local_train(k, &w, &xs, &ys, 0.05)?;
        vecmath::sub(&w, &wl)
    };
    let mut dx = vec![0.0f32; model.feature_len()];
    rng.fill_normal(&mut dx, 0.5);
    let dy = vec![0.0f32; model.n_classes];
    record(
        "syn_step_m1",
        &time_it(2, 10, || {
            std::hint::black_box(
                ops.syn_step(1, &w, &target, &dx, &dy, 5.0, 0.0).unwrap(),
            );
        }),
    );
    record(
        "syn_grad_m1",
        &time_it(2, 10, || {
            std::hint::black_box(ops.syn_grad(1, &w, &dx, &dy).unwrap());
        }),
    );

    let be = model.eval_batch;
    let mut xe = vec![0.0f32; be * model.feature_len()];
    rng.fill_normal(&mut xe, 1.0);
    let ye: Vec<i32> = (0..be).map(|i| (i % model.n_classes) as i32).collect();
    record(
        "eval_batch",
        &time_it(2, 10, || {
            std::hint::black_box(ops.eval_batch(&w, &xe, &ye).unwrap());
        }),
    );

    let st = rt.stats();
    println!(
        "\nbackend totals: {} compiles {:.0} ms, {} execs {:.0} ms",
        st.compiles, st.compile_ms, st.executions, st.execute_ms
    );

    // Trajectory record + regression gate — native backend only (the
    // committed baseline is the native perf record; pjrt timings are not
    // comparable to it).
    if rt.backend_name() != "native" {
        println!("(backend is not native: skipping BENCH_hotpath.json emit/check)");
        return Ok(());
    }
    let baseline_path = std::env::var("FED3SFC_BENCH_BASELINE")
        .unwrap_or_else(|_| "../BENCH_hotpath.json".to_string());
    let max_ratio: f64 = std::env::var("FED3SFC_BENCH_MAX_REGRESSION")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3.0);
    let current: std::collections::BTreeMap<String, f64> = records
        .iter()
        .map(|r| (r.name.clone(), r.median_ns))
        .collect();
    // Read the baseline BEFORE writing the fresh record (locally the two
    // default to the same path), then persist, then gate — a failing run
    // must still leave its numbers on disk for diagnosis.
    let baseline_text = std::fs::read_to_string(&baseline_path).ok();
    let out_path = std::env::var("FED3SFC_BENCH_OUT")
        .unwrap_or_else(|_| "../BENCH_hotpath.json".to_string());
    // `calibrated` is opt-in (CI sets it): a casual local run must never
    // produce a record that, if committed, arms the gate against the
    // wrong hardware.
    let calibrate = std::env::var("FED3SFC_BENCH_CALIBRATE").map(|v| v == "1").unwrap_or(false);
    let doc = bench_json("native", "mlp10", n, calibrate, &records);
    std::fs::write(&out_path, doc)?;
    println!("wrote trajectory record to {out_path} (calibrated: {calibrate})");
    match baseline_text {
        Some(text) => {
            let (calibrated, baseline) = parse_bench_json(&text)?;
            if !calibrated {
                println!(
                    "baseline {baseline_path} is uncalibrated (seed placeholder): \
                     recording only, no regression gate"
                );
            } else {
                let bad = regressions(&current, &baseline, max_ratio);
                if bad.is_empty() {
                    let shared = baseline
                        .keys()
                        .filter(|name| current.contains_key(name.as_str()))
                        .count();
                    println!("perf smoke OK: {shared} ops within {max_ratio}x of baseline");
                } else {
                    for line in &bad {
                        eprintln!("PERF REGRESSION {line}");
                    }
                    anyhow::bail!("{} op(s) regressed beyond {max_ratio}x", bad.len());
                }
            }
        }
        None => println!("no baseline at {baseline_path}: recording only"),
    }
    Ok(())
}
