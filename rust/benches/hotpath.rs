//! Hot-path microbenchmarks — the perf pass baseline (EXPERIMENTS §Perf).
//!
//! L3 host paths: top-k selection, axpy/EF accumulation, cosine metric,
//! aggregation; runtime paths: literal marshalling, local_train /
//! syn_step / syn_grad / eval executions on mlp10 (the paper-scale MLP).

use fed3sfc::bench::{report, time_it};
use fed3sfc::config::BackendKind;
use fed3sfc::runtime::{open_backend_kind, Backend, FedOps};
use fed3sfc::util::rng::Rng;
use fed3sfc::util::vecmath;

fn main() -> anyhow::Result<()> {
    let rt = open_backend_kind(BackendKind::Auto)?;
    let ops = FedOps::new(rt.as_ref(), "mlp10")?;
    let model = ops.model;
    let n = model.params;
    println!(
        "== hot-path microbenchmarks (P = {n}, {} backend) ==\n",
        rt.backend_name()
    );

    let mut rng = Rng::new(1);
    let mut g = vec![0.0f32; n];
    rng.fill_normal(&mut g, 0.01);
    let mut ef = vec![0.0f32; n];

    println!("-- L3 host paths --");
    report(
        "topk_indices k=P/250 (DGC select)",
        &time_it(3, 20, || {
            std::hint::black_box(vecmath::topk_indices(&g, n / 250));
        }),
    );
    report(
        "axpy (EF accumulate)",
        &time_it(3, 50, || {
            vecmath::axpy(1.0, &g, &mut ef);
        }),
    );
    report(
        "cosine (efficiency metric)",
        &time_it(3, 50, || {
            std::hint::black_box(vecmath::cosine(&g, &ef));
        }),
    );
    report(
        "weighted aggregation of 10 clients",
        &time_it(3, 20, || {
            let mut agg = vec![0.0f32; n];
            for _ in 0..10 {
                vecmath::weighted_add(&mut agg, &g, 0.1);
            }
            std::hint::black_box(agg);
        }),
    );

    println!("\n-- backend paths ({}, mlp10) --", rt.backend_name());
    let w = rt.load_init(model)?;
    let k = 5;
    let b = model.train_batch;
    let mut xs = vec![0.0f32; k * b * model.feature_len()];
    rng.fill_normal(&mut xs, 1.0);
    let ys: Vec<i32> = (0..k * b).map(|i| (i % model.n_classes) as i32).collect();
    report(
        "local_train K=5 (B=32)",
        &time_it(2, 10, || {
            std::hint::black_box(ops.local_train(k, &w, &xs, &ys, 0.05).unwrap());
        }),
    );

    let target = {
        let wl = ops.local_train(k, &w, &xs, &ys, 0.05)?;
        vecmath::sub(&w, &wl)
    };
    let mut dx = vec![0.0f32; model.feature_len()];
    rng.fill_normal(&mut dx, 0.5);
    let dy = vec![0.0f32; model.n_classes];
    report(
        "syn_step m=1 (2nd-order encoder step)",
        &time_it(2, 10, || {
            std::hint::black_box(
                ops.syn_step(1, &w, &target, &dx, &dy, 5.0, 0.0).unwrap(),
            );
        }),
    );
    report(
        "syn_grad m=1 (decoder)",
        &time_it(2, 10, || {
            std::hint::black_box(ops.syn_grad(1, &w, &dx, &dy).unwrap());
        }),
    );

    let be = model.eval_batch;
    let mut xe = vec![0.0f32; be * model.feature_len()];
    rng.fill_normal(&mut xe, 1.0);
    let ye: Vec<i32> = (0..be).map(|i| (i % model.n_classes) as i32).collect();
    report(
        "eval_batch (B=100)",
        &time_it(2, 10, || {
            std::hint::black_box(ops.eval_batch(&w, &xe, &ye).unwrap());
        }),
    );

    let st = rt.stats();
    println!(
        "\nbackend totals: {} compiles {:.0} ms, {} execs {:.0} ms",
        st.compiles, st.compile_ms, st.executions, st.execute_ms
    );
    Ok(())
}
