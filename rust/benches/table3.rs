//! Table 3: dedicated STC vs 3SFC comparison — 3SFC with doubled (2×B)
//! and quadrupled (4×B) budgets still compresses far more than STC's 32×
//! while matching or beating its accuracy.
//!
//! Scale knobs: ROUNDS (8), CLIENTS (10), TRAIN (1200), PAIRS (all|mlp).

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{BackendKind, CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn pairs(which: &str) -> Vec<(&'static str, DatasetKind, &'static str)> {
    let mlp = vec![
        ("MNIST+MLP", DatasetKind::SynthMnist, "mlp10"),
        ("EMNIST+MLP", DatasetKind::SynthEmnist, "mlp26"),
        ("FMNIST+MLP", DatasetKind::SynthFmnist, "mlp10"),
    ];
    if which == "mlp" {
        return mlp;
    }
    let mut all = mlp;
    all.extend([
        ("FMNIST+Mnistnet", DatasetKind::SynthFmnist, "mnistnet"),
        ("Cifar10+ResNet", DatasetKind::SynthCifar10, "resnet8_c10"),
        ("Cifar10+RegNet", DatasetKind::SynthCifar10, "regnet_c10"),
        ("Cifar100+ResNet", DatasetKind::SynthCifar100, "resnet8_c20"),
        ("Cifar100+RegNet", DatasetKind::SynthCifar100, "regnet_c20"),
    ]);
    all
}

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 5);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 700);
    let which = std::env::var("PAIRS").unwrap_or_else(|_| "mlp".into());
    let rt = open_backend_kind(BackendKind::Auto)?;

    println!("== Table 3: STC vs 3SFC at 2xB and 4xB ({clients} clients, {rounds} rounds) ==\n");
    let t = Table::new(&[18, 20, 20, 20]);
    t.row(&[
        "Dataset+Model".into(),
        "STC".into(),
        "3SFC (2xB)".into(),
        "3SFC (4xB)".into(),
    ]);
    t.sep();

    for (label, ds, model) in pairs(&which) {
        let mut cells = vec![label.to_string()];
        if rt.manifest().model(model).is_err() {
            cells.push(format!("(needs pjrt: {model})"));
            cells.push("-".into());
            cells.push("-".into());
            t.row(&cells);
            continue;
        }
        for (method, budget) in [
            (CompressorKind::Stc, 1usize),
            (CompressorKind::ThreeSfc, 2),
            (CompressorKind::ThreeSfc, 4),
        ] {
            let mut exp = Experiment::builder()
                .name(format!("t3-{label}-{}-{budget}", method.name()))
                .dataset(ds)
                .model(model)
                .compressor(method)
                .budget_mult(budget)
                .clients(clients)
                .rounds(rounds)
                .train_samples(train)
                .test_samples(300)
                .lr(0.05)
                .eval_every(rounds)
                .syn_steps(20)
                .build(rt.as_ref())?;
            let recs = exp.run()?;
            let last = recs.last().unwrap();
            cells.push(format!("{:.4} ({:.0}x)", last.test_acc, last.ratio));
        }
        t.row(&cells);
    }
    println!("\nexpected shape (paper Table 3): 3SFC(2B/4B) ~ or > STC with a much higher ratio.");
    Ok(())
}
