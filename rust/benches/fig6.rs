//! Figure 6: test accuracy / training loss **vs communicated traffic**.
//!
//! The paper's key visualization: at equal x-axis bytes, 3SFC converges
//! fastest because each of its (tiny) uploads carries more signal.
//!
//! Scale knobs: ROUNDS (12), CLIENTS (10), TRAIN (1500).

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{BackendKind, CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 6);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 800);
    let rt = open_backend_kind(BackendKind::Auto)?;

    let methods = [
        CompressorKind::FedAvg,
        CompressorKind::Dgc,
        CompressorKind::SignSgd,
        CompressorKind::Stc,
        CompressorKind::ThreeSfc,
    ];
    println!(
        "== Figure 6: accuracy/loss vs cumulative upload bytes (synth-MNIST + MLP, {clients} clients, {} backend) ==\n",
        rt.backend_name()
    );
    let t = Table::new(&[10, 8, 16, 10, 10]);
    t.row(&[
        "method".into(),
        "round".into(),
        "up_bytes_cum".into(),
        "test_acc".into(),
        "loss".into(),
    ]);
    t.sep();
    for method in methods {
        let mut exp = Experiment::builder()
            .name(format!("fig6-{}", method.name()))
            .dataset(DatasetKind::SynthMnist)
            .compressor(method)
            .clients(clients)
            .rounds(rounds)
            .train_samples(train)
            .test_samples(400)
            .lr(0.05)
            .eval_every(1)
            .syn_steps(30)
            .build(rt.as_ref())?;
        let recs = exp.run()?;
        for r in &recs {
            t.row(&[
                method.name().into(),
                format!("{}", r.round),
                format!("{}", r.up_bytes_cum),
                format!("{:.4}", r.test_acc),
                format!("{:.4}", r.test_loss),
            ]);
        }
        t.sep();
    }
    println!("expected shape: at a fixed byte budget (x), 3SFC's accuracy is highest (Fig 6).");
    Ok(())
}
