//! Table 1: FedSynth (multi-step distillation) barely optimizes the model
//! at high compression, while FedAvg trains fine — the preliminary
//! experiment that justifies excluding FedSynth from Table 2.
//!
//! Pairs (paper): MNIST+MLP, EMNIST+MLP, FMNIST+MLP, FMNIST+MnistNet,
//! 10 clients. Scale knobs: ROUNDS (10), CLIENTS (10), TRAIN (1200).

use fed3sfc::bench::{env_usize, Table};
use fed3sfc::config::{BackendKind, CompressorKind, DatasetKind};
use fed3sfc::coordinator::experiment::Experiment;
use fed3sfc::runtime::{open_backend_kind, Backend};

fn main() -> anyhow::Result<()> {
    let rounds = env_usize("ROUNDS", 5);
    let clients = env_usize("CLIENTS", 6);
    let train = env_usize("TRAIN", 700);
    let rt = open_backend_kind(BackendKind::Auto)?;

    let pairs: [(&str, DatasetKind, &str); 4] = [
        ("MNIST+MLP", DatasetKind::SynthMnist, "mlp10"),
        ("EMNIST+MLP", DatasetKind::SynthEmnist, "mlp26"),
        ("FMNIST+MLP", DatasetKind::SynthFmnist, "mlp10"),
        ("FMNIST+Mnistnet", DatasetKind::SynthFmnist, "mnistnet"),
    ];

    println!("== Table 1: FedSynth preliminary ({clients} clients, {rounds} rounds) ==\n");
    let t = Table::new(&[18, 16, 22, 14]);
    t.row(&[
        "Dataset+Model".into(),
        "FedAvg (1x)".into(),
        "FedSynth (ratio)".into(),
        "3SFC (ratio)".into(),
    ]);
    t.sep();
    for (label, ds, model) in pairs {
        if rt.manifest().model(model).is_err() {
            t.row(&[
                label.into(),
                format!("(needs pjrt: {model})"),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        let mut accs = Vec::new();
        for method in [
            CompressorKind::FedAvg,
            CompressorKind::FedSynth,
            CompressorKind::ThreeSfc,
        ] {
            let mut exp = Experiment::builder()
                .name(format!("t1-{label}-{}", method.name()))
                .dataset(ds)
                .model(model)
                .compressor(method)
                .clients(clients)
                .rounds(rounds)
                .train_samples(train)
                .test_samples(300)
                .lr(0.05)
                .eval_every(rounds)
                .syn_steps(20)
                .fedsynth_ksim(4)
                .fedsynth_steps(20)
                .build(rt.as_ref())?;
            let recs = exp.run()?;
            let last = recs.last().unwrap();
            accs.push((last.test_acc, last.ratio));
        }
        t.row(&[
            label.into(),
            format!("{:.4}", accs[0].0),
            format!("{:.4} ({:.0}x)", accs[1].0, accs[1].1),
            format!("{:.4} ({:.0}x)", accs[2].0, accs[2].1),
        ]);
    }
    println!("\nexpected shape: FedSynth lags FedAvg and 3SFC at comparable extreme ratios (Table 1).");
    Ok(())
}
