//! A minimal hand-rolled Rust lexer — just enough structure for the
//! detlint rules: a comment/string-free token stream with 1-based
//! line/col positions, plus the comments kept aside (pragmas and
//! `// SAFETY:` annotations live there).
//!
//! Handled: line and (nested) block comments, plain/byte/raw string
//! literals (`"…"`, `b"…"`, `r"…"`, `r#"…"#`), char literals vs
//! lifetimes, identifiers, integer-ish literals (`0x9A87_1710` comes out
//! as one token), and single-char punctuation. Anything fancier is not
//! needed: rules match short token patterns, never full syntax.

/// Token class, to the extent the rules care.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Numeric literal (starts with an ASCII digit; `0x…`/`_` kept whole).
    Int,
    /// Single punctuation character.
    Punct,
}

/// One source token with its 1-based position.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// A comment, anchored at the line/col it starts on. `text` includes the
/// `//` / `/*` markers.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub col: u32,
    /// True when no token precedes the comment on its line (a whole-line
    /// comment, as opposed to one trailing code).
    pub own_line: bool,
    pub text: String,
}

/// Lexer output: the token stream and the comment sidecar.
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

struct Cursor {
    cs: Vec<char>,
    i: usize,
    line: u32,
    col: u32,
}

impl Cursor {
    fn peek(&self, k: usize) -> Option<char> {
        self.cs.get(self.i + k).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.cs.get(self.i).copied()?;
        self.i += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }
}

/// True when the cursor (sitting on `r` or `b`) starts a raw/byte string
/// literal rather than an identifier (`r#ident` raw identifiers and plain
/// `r`/`b` variables fall through to the identifier path).
fn is_string_start(cur: &Cursor) -> bool {
    let mut k = 0;
    if cur.peek(k) == Some('b') {
        k += 1;
    }
    if cur.peek(k) == Some('r') {
        k += 1;
        while cur.peek(k) == Some('#') {
            k += 1;
        }
    }
    k > 0 && cur.peek(k) == Some('"')
}

/// Consume a string literal (cursor on `"`, `b`, or `r`).
fn consume_string(cur: &mut Cursor) {
    if cur.peek(0) == Some('b') {
        cur.bump();
    }
    let raw = cur.peek(0) == Some('r');
    if raw {
        cur.bump();
    }
    let mut hashes = 0usize;
    while cur.peek(0) == Some('#') {
        hashes += 1;
        cur.bump();
    }
    if cur.peek(0) != Some('"') {
        return;
    }
    cur.bump();
    loop {
        let Some(ch) = cur.peek(0) else { break };
        if !raw && ch == '\\' {
            cur.bump();
            cur.bump();
            continue;
        }
        if ch == '"' {
            let closed = (0..hashes).all(|k| cur.peek(1 + k) == Some('#'));
            if closed {
                cur.bump();
                for _ in 0..hashes {
                    cur.bump();
                }
                break;
            }
        }
        cur.bump();
    }
}

/// Lex `src` into tokens + comments.
pub fn lex(src: &str) -> Lexed {
    let mut cur = Cursor { cs: src.chars().collect(), i: 0, line: 1, col: 1 };
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    // Line of the most recent token, for `own_line` comment tracking.
    let mut last_tok_line: u32 = 0;

    loop {
        let Some(c) = cur.peek(0) else { break };
        let (line0, col0) = (cur.line, cur.col);

        if c.is_whitespace() {
            cur.bump();
            continue;
        }

        // Line comment (incl. `///` docs).
        if c == '/' && cur.peek(1) == Some('/') {
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if ch == '\n' {
                    break;
                }
                text.push(ch);
                cur.bump();
            }
            comments.push(Comment { line: line0, col: col0, own_line: last_tok_line != line0, text });
            continue;
        }

        // Block comment; Rust block comments nest.
        if c == '/' && cur.peek(1) == Some('*') {
            let mut depth = 0i32;
            let mut text = String::new();
            while let Some(ch) = cur.peek(0) {
                if ch == '/' && cur.peek(1) == Some('*') {
                    depth += 1;
                    text.push_str("/*");
                    cur.bump();
                    cur.bump();
                    continue;
                }
                if ch == '*' && cur.peek(1) == Some('/') {
                    depth -= 1;
                    text.push_str("*/");
                    cur.bump();
                    cur.bump();
                    if depth == 0 {
                        break;
                    }
                    continue;
                }
                text.push(ch);
                cur.bump();
            }
            comments.push(Comment { line: line0, col: col0, own_line: last_tok_line != line0, text });
            continue;
        }

        // String literals contribute no tokens.
        if c == '"' || ((c == 'r' || c == 'b') && is_string_start(&cur)) {
            consume_string(&mut cur);
            continue;
        }

        // Char literal vs lifetime.
        if c == '\'' {
            let c1 = cur.peek(1);
            let is_char = match c1 {
                Some('\\') => true,
                Some(x) if x != '\'' => cur.peek(2) == Some('\''),
                _ => false,
            };
            cur.bump();
            if is_char {
                if cur.peek(0) == Some('\\') {
                    cur.bump();
                }
                cur.bump();
                if cur.peek(0) == Some('\'') {
                    cur.bump();
                }
            } else {
                // Lifetime: `'ident`, no closing quote.
                while matches!(cur.peek(0), Some(x) if x.is_alphanumeric() || x == '_') {
                    cur.bump();
                }
            }
            continue;
        }

        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let mut text = String::new();
            while let Some(x) = cur.peek(0) {
                if x.is_alphanumeric() || x == '_' {
                    text.push(x);
                    cur.bump();
                } else {
                    break;
                }
            }
            toks.push(Tok { kind: TokKind::Ident, text, line: line0, col: col0 });
            last_tok_line = line0;
            continue;
        }

        // Number: consume the alphanumeric/underscore run so `0x9A87_1710`
        // (and suffixed forms like `1u64`) stay one token.
        if c.is_ascii_digit() {
            let mut text = String::new();
            while let Some(x) = cur.peek(0) {
                if x.is_alphanumeric() || x == '_' {
                    text.push(x);
                    cur.bump();
                } else {
                    break;
                }
            }
            toks.push(Tok { kind: TokKind::Int, text, line: line0, col: col0 });
            last_tok_line = line0;
            continue;
        }

        // Everything else: one punctuation character per token.
        cur.bump();
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line: line0, col: col0 });
        last_tok_line = line0;
    }

    Lexed { toks, comments }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strips_comments_and_strings() {
        let src = "let a = \"Instant::now\"; // Instant::now\n/* thread_rng */ let b = 1;";
        let t = texts(src);
        assert!(!t.contains(&"Instant".to_string()));
        assert!(!t.contains(&"thread_rng".to_string()));
        assert_eq!(t.iter().filter(|x| x.as_str() == "let").count(), 2);
        let lexed = lex(src);
        assert_eq!(lexed.comments.len(), 2);
        assert!(!lexed.comments[0].own_line);
    }

    #[test]
    fn raw_strings_and_chars() {
        let src = "let s = r#\"quote \" inside\"#; let c = '\\''; let l: &'static str = \"x\";";
        let t = texts(src);
        assert!(!t.contains(&"inside".to_string()));
        assert!(!t.contains(&"static".to_string()));
        assert_eq!(t.iter().filter(|x| x.as_str() == "let").count(), 3);
    }

    #[test]
    fn hex_literals_are_single_tokens() {
        let lexed = lex("root.split(0x9A87_1710);");
        let ints: Vec<&Tok> = lexed.toks.iter().filter(|t| t.kind == TokKind::Int).collect();
        assert_eq!(ints.len(), 1);
        assert_eq!(ints[0].text, "0x9A87_1710");
        assert_eq!(ints[0].line, 1);
    }

    #[test]
    fn nested_block_comments() {
        let t = texts("/* outer /* inner */ still comment */ fn f() {}");
        assert_eq!(t[0], "fn");
    }

    #[test]
    fn positions_are_one_based() {
        let lexed = lex("a\n  bb");
        assert_eq!((lexed.toks[0].line, lexed.toks[0].col), (1, 1));
        assert_eq!((lexed.toks[1].line, lexed.toks[1].col), (2, 3));
    }
}
